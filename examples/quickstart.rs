//! Quickstart: build the paper's Figure-1 trajectory tree, inspect its DFS
//! serialization, and run one Tree Training step against the baseline.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (compiles the tiny model's HLO programs).

use std::sync::Arc;

use tree_train::runtime::Runtime;
use tree_train::trainer::{AdamWConfig, BaselineTrainer, TreeTrainer};
use tree_train::tree::{dfs, metrics, serialize, NodeSpec, TrajectoryTree};

fn main() -> anyhow::Result<()> {
    // ── 1. the Figure-1 tree: one task, K = 3 execution paths ───────────
    // node text in the paper: red = model output (trained), black = input
    let tree = TrajectoryTree::new(vec![
        NodeSpec::new(-1, vec![11, 12, 13, 14]).with_trainable(vec![0., 0., 0., 0.]), // n0 prompt
        NodeSpec::new(0, vec![21, 22, 23]),  // n1 shared reasoning (g = 2)
        NodeSpec::new(1, vec![31, 32]),      // n3 tool call A
        NodeSpec::new(1, vec![41, 42, 43]),  // n4 tool call B (concurrent)
        NodeSpec::new(0, vec![51, 52, 53]),  // n2 think-mode discard branch
    ])?;
    let acc = metrics::accounting(&tree);
    println!("Fig-1 tree: {} nodes, K = {} paths", tree.len(), tree.num_paths());
    println!("  N_tree = {} unique tokens, N_flat = {} flattened", acc.n_tree, acc.n_flat);
    println!("  POR = {:.1}%  =>  speedup bound 1/(1-POR) = {:.2}x", acc.por * 100.0, acc.speedup_bound);

    // ── 2. DFS serialization (Eq. 8) and the per-token metadata (§3.2) ──
    let meta = serialize(&tree);
    println!("\nDFS sequence ({} tokens):", meta.size());
    println!("  tokens       {:?}", meta.tokens);
    println!("  pos_ids      {:?}  (per-path positions, Eq. 9)", meta.pos_ids);
    println!("  subtree_exit {:?}  (interval tree mask)", meta.subtree_exit);
    println!("  g            {:?}  (paths through node)", meta.g);
    println!("  lambda       {:?}  (g/K * trainable, Eq. 4)", meta.weights);
    println!("  prev_idx     {:?}  (loss gathers logits here)", dfs::prev_indices(&meta));

    // ── 3. one training step: Tree Training vs sep-avg baseline ─────────
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Arc::new(Runtime::from_dir(&artifacts)?);
    let mut tree_tr = TreeTrainer::new(rt.clone(), "tiny", AdamWConfig::default())?;
    let mut base_tr = BaselineTrainer::new(rt, "tiny", AdamWConfig::default())?;

    // warm both paths once (first PJRT execution pays one-time setup)
    tree_tr.train_step(std::slice::from_ref(&tree))?;
    base_tr.train_step(std::slice::from_ref(&tree))?;
    let mt = tree_tr.train_step(std::slice::from_ref(&tree))?;
    let mb = base_tr.train_step(std::slice::from_ref(&tree))?;
    println!("\none step on the Fig-1 tree (tiny model):");
    println!("  tree training:  loss {:.4}  wall {:?}  ({} program call)", mt.loss, mt.wall, mt.exec_calls);
    println!("  baseline:       loss {:.4}  wall {:?}  ({} program calls)", mb.loss, mb.wall, mb.exec_calls);
    println!("  loss rel err:   {:.2e}  (the Eq. 1-5 equivalence, in f32)",
             (mt.loss - mb.loss).abs() / mb.loss.abs());
    Ok(())
}
