//! Agentic RL on trajectory trees: per-token advantage-weighted policy
//! gradients (the paper's RL objective, §3.1) trained with Tree Training.
//!
//! Rollout trees carry per-token advantages A_t; the loss is
//! ell_t = -A_t log p(y_t | x_<t), which folds into the same lambda_t
//! weighting machinery (lambda_t = g_t/K * A_t).  Branches with positive
//! advantage are reinforced, negative-advantage branches suppressed — here
//! we verify that on a two-branch bandit-style tree the model shifts
//! probability mass toward the rewarded branch.
//!
//!     cargo run --release --example rl_tree -- [steps]

use std::sync::Arc;

use tree_train::runtime::Runtime;
use tree_train::trainer::grads::GradBuffer;
use tree_train::trainer::{AdamWConfig, TreeTrainer};
use tree_train::tree::{gen, NodeSpec, TrajectoryTree};

/// A rollout: shared prompt, two candidate continuations; the "good" branch
/// gets advantage +1, the "bad" branch -1 (GRPO-style group baseline).
fn rollout(seed: u64, vocab: i32) -> (TrajectoryTree, Vec<i32>, Vec<i32>) {
    let mut r = gen::rng(seed);
    let mut state = r.i32(0, vocab);
    let prompt = gen::markov_segments(&mut r, vocab, 8, &mut state);
    let good: Vec<i32> = (0..6).map(|i| (100 + i) % vocab).collect();
    let bad: Vec<i32> = (0..6).map(|i| (200 + i * 3) % vocab).collect();
    let n = prompt.len();
    let tree = TrajectoryTree::new(vec![
        NodeSpec::new(-1, prompt).with_trainable(vec![0.0; n]),
        NodeSpec::new(0, good.clone()).with_advantage(vec![1.0; 6]),
        NodeSpec::new(0, bad.clone()).with_advantage(vec![-1.0; 6]),
    ])
    .unwrap();
    (tree, good, bad)
}

/// Mean logprob of a continuation given the prompt (uses eval_loss with
/// weight 1 on the continuation tokens).
fn branch_logprob(
    tr: &TreeTrainer,
    prompt_tree: &TrajectoryTree,
    branch: usize,
) -> anyhow::Result<f64> {
    let mut t = prompt_tree.clone();
    // keep only the chosen branch, weight 1, advantage +1
    let keep = [0usize, branch];
    let nodes: Vec<NodeSpec> = keep
        .iter()
        .enumerate()
        .map(|(d, &n)| NodeSpec {
            parent: d as i32 - 1,
            advantage: vec![1.0; t.nodes[n].tokens.len()],
            ..t.nodes[n].clone()
        })
        .collect();
    t = TrajectoryTree::new(nodes)?;
    let mut gb = GradBuffer::zeros(tr.params());
    tr.accumulate_tree(&t, &mut gb)?;
    Ok(-gb.mean_loss()) // mean logprob of trained tokens
}

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Arc::new(Runtime::from_dir(&artifacts)?);
    let mut tr = TreeTrainer::new(rt, "tiny", AdamWConfig { lr: 2e-3, ..Default::default() })?;
    let vocab = 256;

    let (probe, _, _) = rollout(999, vocab);
    let lp_good_0 = branch_logprob(&tr, &probe, 1)?;
    let lp_bad_0 = branch_logprob(&tr, &probe, 2)?;

    println!("RL on trajectory trees: {} steps, tiny model", steps);
    for step in 0..steps {
        let (tree, _, _) = rollout(step % 8, vocab);
        let m = tr.train_step(std::slice::from_ref(&tree))?;
        if step % 10 == 0 {
            println!("  step {:>3}: pg-loss {:+.4}, grad norm {:.3}", step, m.loss, m.grad_norm);
        }
    }

    let lp_good = branch_logprob(&tr, &probe, 1)?;
    let lp_bad = branch_logprob(&tr, &probe, 2)?;
    println!("\nmean logprob of rewarded branch:   {lp_good_0:.4} -> {lp_good:.4}");
    println!("mean logprob of penalized branch:  {lp_bad_0:.4} -> {lp_bad:.4}");
    assert!(lp_good > lp_good_0, "policy must reinforce the +A branch");
    assert!(
        lp_good - lp_bad > lp_good_0 - lp_bad_0,
        "margin toward the rewarded branch must grow"
    );
    println!("RL objective drives probability mass toward the rewarded branch. OK");
    Ok(())
}
