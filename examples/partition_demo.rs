//! Redundancy-Free Tree Partitioning walkthrough (§3.3 + Appendix B).
//!
//! Builds a tree larger than the device capacity, shows the bin-packing
//! plan, runs the partitioned gradient relay, and checks it against the
//! whole-tree gradients (App. B.8).
//!
//!     cargo run --release --example partition_demo

use std::sync::Arc;

use tree_train::partition::{greedy_pack, plan, validate_assignment};
use tree_train::runtime::Runtime;
use tree_train::trainer::grads::GradBuffer;
use tree_train::trainer::{AdamWConfig, TreeTrainer};
use tree_train::tree::gen;

fn main() -> anyhow::Result<()> {
    // a tree that fits the tiny c64 bucket — so we can compare the
    // partitioned relay against the unsplit reference exactly
    let tree = gen::uniform(11, 10, 5, 0.7);
    println!("tree: {} nodes, {} unique tokens, {} paths", tree.len(), tree.n_tree(), tree.num_paths());

    // ── plan: connected subtrees at node boundaries ──────────────────────
    let capacity = 24; // force several partitions
    let assignment = greedy_pack(&tree, capacity)?;
    validate_assignment(&tree, &assignment)?;
    let pl = plan(&tree, &assignment)?;
    println!("\npacking at C = {capacity}: {} partitions", pl.parts.len());
    for (i, p) in pl.parts.iter().enumerate() {
        println!(
            "  P{i}: nodes {:?}, {} tokens + {} boundary targets, gateway {} rows, pos_offset {}",
            p.nodes,
            p.meta.size(),
            p.virtuals.len(),
            p.anc_slots.len(),
            p.pos_offset
        );
    }
    assert_eq!(pl.total_real_tokens(), tree.n_tree(), "zero redundant computation");
    println!("zero-redundancy check: sum of partition tokens == N_tree == {}", tree.n_tree());

    // ── run both paths through the runtime and compare gradients ────────
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Arc::new(Runtime::from_dir(&artifacts)?);
    let mut tr = TreeTrainer::new(rt, "tiny", AdamWConfig::default())?;
    // run the relay with the same packing budget as the printed plan
    tr.partition_budget = Some(capacity);

    let mut whole = GradBuffer::zeros(tr.params());
    tr.accumulate_tree(&tree, &mut whole)?;
    let mut parted = GradBuffer::zeros(tr.params());
    tr.accumulate_tree_partitioned(&tree, &mut parted)?;

    let loss_rel = (whole.loss_sum - parted.loss_sum).abs() / whole.loss_sum.abs();
    let mut grad_rel = 0.0f64;
    for (a, b) in whole.grads.iter().zip(&parted.grads) {
        for (&x, &y) in a.iter().zip(b) {
            grad_rel = grad_rel.max((x - y).abs() / x.abs().max(1e-3));
        }
    }
    println!("\nwhole-tree vs partitioned (differentiable gateways):");
    println!("  loss  rel err: {loss_rel:.2e}");
    println!("  grads rel err: {grad_rel:.2e}   (paper App. B.8: < 1e-4 in f32)");
    assert!(loss_rel < 1e-4 && grad_rel < 1e-3);
    println!("partition relay reproduces the unsplit gradients. OK");
    Ok(())
}
