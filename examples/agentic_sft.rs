//! End-to-end agentic SFT: train the `small` transformer (~13M params) on a
//! synthetic multi-turn agentic corpus (think-mode on, high POR) and log the
//! loss curve for Tree Training vs the sep-avg baseline.
//!
//!     cargo run --release --example agentic_sft -- [steps] [mode]
//!
//! `mode` = tree | baseline | both (default both, fewer steps).  Results are
//! appended to results/agentic_sft_<mode>.csv and recorded in EXPERIMENTS.md.

use std::sync::Arc;

use tree_train::coordinator::{Coordinator, CorpusFormat, Mode, RunConfig, SyntheticSpec};
use tree_train::runtime::Runtime;
use tree_train::tree::metrics;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    let mode = args.get(2).map(String::as_str).unwrap_or("both");

    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let results = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&results)?;
    let rt = Arc::new(Runtime::from_dir(&artifacts)?);

    let modes: Vec<Mode> = match mode {
        "tree" => vec![Mode::Tree],
        "baseline" => vec![Mode::Baseline],
        _ => vec![Mode::Tree, Mode::Baseline],
    };

    for m in modes {
        let tag = match m {
            Mode::Tree => "tree",
            Mode::Baseline => "baseline",
        };
        let synthetic = SyntheticSpec {
            overlap: "high".into(),
            n_trees: 48,
            // eff. think-mode turns = 8x: keeps the deepest path inside
            // the gateway bucket (ancestor rows <= A = 256)
            turns: 2,
            vocab: 512,
        };
        // the sep-avg baseline cannot pack paths longer than its bucket
        // (tree training would simply partition them); keep the comparison
        // on the common subset
        let cap = 243usize;
        let mut trees = synthetic.generate(7)?;
        trees.retain(|t| {
            t.paths()
                .iter()
                .all(|p| p.iter().map(|&n| t.nodes[n].real_len()).sum::<usize>() <= cap)
        });
        let por = metrics::dataset_por(&trees);
        let n_trees = trees.len();
        let cfg = RunConfig {
            model: "small".into(),
            mode: m,
            steps,
            trees_per_batch: 1,
            lr: 3e-3,
            warmup: steps / 10,
            seed: 7,
            corpus: None,
            corpus_format: CorpusFormat::Trees,
            ingest: Default::default(),
            synthetic: Some(synthetic),
            metrics_csv: Some(results.join(format!("agentic_sft_{tag}.csv"))),
            forest_packing: true,
            pipeline_depth: 1,
            shuffle_window: 0,
            ranks: 1,
        };
        let mut coord = Coordinator::with_corpus(rt.clone(), cfg, trees)?;
        println!(
            "\n=== agentic SFT [{tag}] — {n_trees} trees, dataset POR {:.1}% ===",
            por * 100.0
        );
        let t0 = std::time::Instant::now();
        let ms = coord.run()?;
        let total = t0.elapsed();
        // per-step losses are per-tree (batch of 1): compare window means
        let w = (ms.len() / 4).max(1);
        let first = ms[..w].iter().map(|m| m.loss).sum::<f64>() / w as f64;
        let last = ms[ms.len() - w..].iter().map(|m| m.loss).sum::<f64>() / w as f64;
        println!(
            "[{tag}] {} steps in {total:.1?}: loss {first:.4} -> {last:.4} \
             ({:.0} tree-tokens/s, {} exec calls/step avg)",
            ms.len(),
            ms.iter().map(|m| m.tokens_per_sec()).sum::<f64>() / ms.len() as f64,
            ms.iter().map(|m| m.exec_calls).sum::<u64>() / ms.len() as u64,
        );
        assert!(last < first, "training must reduce loss ({first:.4} -> {last:.4})");
    }
    Ok(())
}
