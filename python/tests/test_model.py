"""Model-level unit tests: building blocks, program shapes, and the
manifest contract used by the Rust runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import batching, model, treemeta
from compile.treemeta import NodeSpec


def small_tree(rng):
    return [NodeSpec(-1, rng.integers(0, 64, 4)),
            NodeSpec(0, rng.integers(0, 64, 3)),
            NodeSpec(0, rng.integers(0, 64, 2))]


class TestBlocks:
    def test_rope_rotation_is_norm_preserving(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((6, 2, 8)).astype(np.float32))
        pos = jnp.arange(6, dtype=jnp.int32)
        y = model.apply_rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_rope_position_zero_is_identity(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((3, 1, 8)).astype(np.float32))
        y = model.apply_rope(x, jnp.zeros(3, jnp.int32), 10000.0)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)

    def test_rope_relative_property(self):
        """RoPE dot products depend only on relative positions."""
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.standard_normal((1, 1, 16)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 1, 16)).astype(np.float32))

        def score(pq, pk):
            qr = model.apply_rope(q, jnp.asarray([pq], jnp.int32), 10000.0)
            kr = model.apply_rope(k, jnp.asarray([pk], jnp.int32), 10000.0)
            return float(jnp.sum(qr * kr))

        assert abs(score(5, 3) - score(9, 7)) < 1e-4
        assert abs(score(5, 3) - score(6, 3)) > 1e-6

    def test_top_k_by_argmax_matches_lax(self):
        rng = np.random.default_rng(3)
        probs = jnp.asarray(rng.random((16, 8)).astype(np.float32))
        v1, i1 = model._top_k_by_argmax(probs, 2)
        v2, i2 = jax.lax.top_k(probs, 2)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)
        assert (np.asarray(i1) == np.asarray(i2)).all()

    def test_moe_aux_positive_and_grads_flow(self):
        cfg = model.TINY_MOE
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((8, cfg.d_model)).astype(np.float32))
        layer = params["layer_1"]
        assert "router" in layer
        out, aux = model.moe_ffn(x, layer, cfg)
        assert out.shape == (8, cfg.d_model)
        assert float(aux) > 0.0

        def loss(w):
            o, _ = model.moe_ffn(x, {**layer, "moe_w1": w}, cfg)
            return jnp.sum(o ** 2)

        g = jax.grad(loss)(layer["moe_w1"])
        assert float(jnp.abs(g).max()) > 0.0


class TestPrograms:
    @pytest.mark.parametrize("cfg", [model.TINY, model.TINY_MOE, model.TINY_HYBRID],
                             ids=lambda c: c.name)
    def test_step_program_runs(self, cfg):
        rng = np.random.default_rng(5)
        nodes = small_tree(rng)
        kw = {}
        if cfg.kind == "hybrid":
            nodes = treemeta.pad_nodes_for_chunks(nodes, cfg.chunk_size)
            kw = dict(chunk_size=cfg.chunk_size, conv_kernel=cfg.conv_kernel)
        meta = treemeta.dfs_serialize(nodes)
        cap = ((meta.size + 16) // 16 + 1) * 16
        batch = batching.build_batch(meta, cap, **kw)
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        loss, wsum, grads = model.step_program(cfg)(params, batch)
        assert np.isfinite(float(loss))
        assert float(wsum) > 0
        flat = jax.tree_util.tree_leaves(grads)
        assert all(np.isfinite(np.asarray(g)).all() for g in flat)

    def test_logprob_matches_loss(self):
        cfg = model.TINY
        rng = np.random.default_rng(6)
        nodes = small_tree(rng)
        meta = treemeta.dfs_serialize(nodes)
        batch = batching.build_batch(meta, 16)
        params = model.init_params(jax.random.PRNGKey(1), cfg)
        lp = model.logprob_program(cfg)(params, batch)
        loss, (wsum, _) = model.loss_fn(params, cfg, batch)
        manual = -float(jnp.sum(batch["weights"] * lp))
        assert abs(float(loss) - manual) < 1e-4 * max(1.0, abs(manual))

    def test_weight_sum_uses_abs(self):
        """RL advantages must not cancel the normalization denominator."""
        cfg = model.TINY
        rng = np.random.default_rng(7)
        nodes = [NodeSpec(-1, rng.integers(0, 64, 4),
                          advantage=np.array([1, 1, -1, -1], np.float32))]
        meta = treemeta.dfs_serialize(nodes)
        batch = batching.build_batch(meta, 8)
        params = model.init_params(jax.random.PRNGKey(2), cfg)
        _, (wsum, _) = model.loss_fn(params, cfg, batch)
        assert float(wsum) > 0.5  # |w| sum, not the cancelling sum

    def test_param_entry_order_deterministic(self):
        from compile import aot
        e1, _, _ = aot.param_entries(model.TINY)
        e2, _, _ = aot.param_entries(model.TINY)
        assert [n for n, _ in e1] == [n for n, _ in e2]
        assert e1[0][0] == "embed"

    def test_gateway_fwd_bwd_shapes(self):
        cfg = model.TINY
        rng = np.random.default_rng(8)
        nodes = small_tree(rng)
        meta = treemeta.dfs_serialize(nodes)
        A, C = 8, 16
        from compile.kernels import tree_attention as ta
        bias = np.zeros(A, np.float32)
        batch = batching.build_batch(meta, C, past_len=A, past_bias=bias)
        params = model.init_params(jax.random.PRNGKey(3), cfg)
        na, H, hd = 2, cfg.n_heads, cfg.head_dim
        k_in = jnp.zeros((na, A, H, hd), jnp.float32)
        loss, wsum, kp, vp = model.part_fwd_program(cfg)(params, batch, k_in, k_in)
        assert kp.shape == (na, C, H, hd)
        out = model.part_bwd_program(cfg)(
            params, batch, k_in, k_in, jnp.zeros_like(kp), jnp.zeros_like(vp),
            jnp.asarray(1.0, jnp.float32))
        loss2, wsum2, grads, dk, dv = out
        assert abs(float(loss) - float(loss2)) < 1e-5
        assert dk.shape == (na, A, H, hd)
