"""Serializer invariants: DFS layout, Eq. 9 positions, interval-mask
reduction, loss-weight algebra (Eq. 2-4)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import treemeta
from compile.treemeta import NodeSpec


def fig1_tree(rng=None):
    """The paper's Figure-1 tree: K=3 paths, shared root + one shared branch."""
    rng = rng or np.random.default_rng(7)
    return [
        NodeSpec(-1, rng.integers(0, 64, 4)),   # n0 root
        NodeSpec(0, rng.integers(0, 64, 3)),    # n1 (shared, g=2)
        NodeSpec(1, rng.integers(0, 64, 2)),    # n3 leaf
        NodeSpec(1, rng.integers(0, 64, 5)),    # n4 leaf
        NodeSpec(0, rng.integers(0, 64, 3)),    # n2 leaf
    ]


def trees(draw_seed):
    rng = np.random.default_rng(draw_seed)
    return treemeta.random_tree(rng, max_nodes=int(rng.integers(1, 16)))


class TestSerialize:
    def test_fig1_counts(self):
        nodes = fig1_tree()
        meta = treemeta.dfs_serialize(nodes)
        assert meta.num_paths == 3
        assert meta.size == 4 + 3 + 2 + 5 + 3
        # g: root counted on 3 paths, n1 on 2, leaves on 1
        assert list(meta.g[:4]) == [3] * 4
        assert list(meta.g[4:7]) == [2] * 3

    def test_fig1_positions(self):
        nodes = fig1_tree()
        meta = treemeta.dfs_serialize(nodes)
        # sibling nodes at the same depth share the same position range (§3.2)
        # n3 starts after n0+n1 = 7; n4 too; n2 starts after n0 = 4
        n3_first = meta.node_start[2]
        n4_first = meta.node_start[3]
        n2_first = meta.node_start[4]
        assert meta.pos_ids[n3_first] == 7
        assert meta.pos_ids[n4_first] == 7
        assert meta.pos_ids[n2_first] == 4

    def test_tokens_appear_once(self):
        nodes = fig1_tree()
        meta = treemeta.dfs_serialize(nodes)
        # Eq. 8: DFS sequence holds each node segment exactly once
        total = sum(len(n.tokens) for n in nodes)
        assert meta.size == total

    def test_preorder_validation(self):
        with pytest.raises(ValueError):
            treemeta.dfs_serialize([NodeSpec(-1, [1]), NodeSpec(1, [2])])

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 10_000))
    def test_interval_mask_equals_ancestor_mask(self, seed):
        nodes = trees(seed)
        meta = treemeta.dfs_serialize(nodes)
        dense = treemeta.dense_tree_mask(meta)
        interval = treemeta.interval_tree_mask(meta.subtree_exit)
        assert (dense == interval).all()

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 10_000))
    def test_positions_match_paths(self, seed):
        """Eq. 9: each token's pos equals its offset in every standalone path."""
        nodes = trees(seed)
        meta = treemeta.dfs_serialize(nodes)
        for path in treemeta.paths(nodes):
            idx = treemeta.path_token_indices(meta, path)
            assert (meta.pos_ids[idx] == np.arange(len(idx))).all()

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 10_000))
    def test_weight_algebra(self, seed):
        """Eq. 2: sum_t g_t == sum over paths of path length."""
        nodes = trees(seed)
        meta = treemeta.dfs_serialize(nodes)
        flat_tokens = sum(
            len(treemeta.path_token_indices(meta, p)) for p in treemeta.paths(nodes))
        assert meta.g.sum() == flat_tokens
        # Eq. 4 with trainable == 1: lambda_t = g_t / K
        np.testing.assert_allclose(meta.weights, meta.g / meta.num_paths, rtol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000))
    def test_g_equals_paths_through_node(self, seed):
        nodes = trees(seed)
        meta = treemeta.dfs_serialize(nodes)
        all_paths = treemeta.paths(nodes)
        assert meta.num_paths == len(all_paths)
        for n in range(len(nodes)):
            thru = sum(1 for p in all_paths if n in p)
            s = meta.node_start[n]
            if meta.node_len[n]:
                assert meta.g[s] == thru

    def test_por_fig5_example(self):
        """Paper §4.1: POR = 1 - 83k/164k for the Fig. 5 tree (scaled down)."""
        # two-leaf tree: root 52, children 15+16 -> tree 83, flat 52+15+52+16=135
        nodes = [NodeSpec(-1, np.zeros(52, np.int32)),
                 NodeSpec(0, np.zeros(15, np.int32)),
                 NodeSpec(0, np.zeros(16, np.int32))]
        meta = treemeta.dfs_serialize(nodes)
        assert abs(treemeta.por(meta, nodes) - (1 - 83 / 135)) < 1e-9


class TestPads:
    def test_pad_alignment(self):
        rng = np.random.default_rng(3)
        nodes = fig1_tree(rng)
        padded = treemeta.pad_nodes_for_chunks(nodes, 4)
        meta = treemeta.dfs_serialize(padded)
        assert meta.size % 4 == 0
        cpm = treemeta.chunk_parent_map(meta, 4)
        assert cpm[0] == -1
        # every chunk's parent chunk precedes it (DFS guarantee, §3.2)
        assert all(cpm[i] < i for i in range(len(cpm)))

    def test_pads_zero_weight_and_islands(self):
        rng = np.random.default_rng(3)
        padded = treemeta.pad_nodes_for_chunks(fig1_tree(rng), 8)
        meta = treemeta.dfs_serialize(padded)
        assert meta.weights[meta.pad_mask].sum() == 0
        dense = treemeta.dense_tree_mask(meta)
        interval = treemeta.interval_tree_mask(meta.subtree_exit)
        assert (dense == interval).all()
        # pad rows: self plus real ancestors only; pad cols invisible elsewhere
        for i in np.where(meta.pad_mask)[0]:
            assert dense[i, i]
        for j in np.where(meta.pad_mask)[0]:
            col = dense[:, j].copy()
            col[j] = False
            assert not col.any()

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]))
    def test_padded_interval_mask(self, seed, chunk):
        nodes = treemeta.pad_nodes_for_chunks(trees(seed), chunk)
        meta = treemeta.dfs_serialize(nodes)
        assert (treemeta.dense_tree_mask(meta)
                == treemeta.interval_tree_mask(meta.subtree_exit)).all()
        # positions still path-exact with pads skipped
        for path in treemeta.paths(nodes):
            idx = treemeta.path_token_indices(meta, path)
            assert (meta.pos_ids[idx] == np.arange(len(idx))).all()
