"""Python mirror of the multi-process launcher wire protocol.

Mirrors ``rust/src/coordinator/launcher.rs`` (the word codec, the
``StarMsg``/``MeshMsg`` layouts, ``ctrl_frame`` carrying u64 words as f64
bit patterns on ``CTRL_BUCKET``) and the hardening contracts of
``rust/src/coordinator/collective/socket.rs``: the bounded frame decoder
(``Frame::decode_from_bounded``), the hello-verified accept loop, and the
complete-line / duplicate-rank / run-generation rendezvous parsing.

Protocol contracts being mirrored:

* control messages are sequences of u64 words carried as the f64 payload
  of an ordinary collective frame — ``to_bits``/``from_bits`` are pure
  transmutes, so arbitrary words (NaN bit patterns included) survive the
  f64 round trip bit-exactly;
* every truncation of a control message raises instead of misparsing;
* a frame header claiming more than ``max_frame_elems`` payload elements
  is refused *before* any allocation;
* silent, foreign-rank, and duplicate-hello dialers never consume an
  accept slot — the pending-children set drains only on genuine hellos;
* only ``\\n``-terminated rendezvous lines are parsed, duplicate lines for
  one rank are a hard error, and a ``run <id>`` header naming a different
  generation is refused;
* a vanished rank becomes a named-rank parent error, not a hang.

Keep in lockstep with the Rust tests (``launcher.rs`` unit tests and the
``adversarial`` suite in ``rust/tests/dist_equivalence.rs``).
"""

import contextlib
import math
import re
import struct

from test_bucket_reduce import FRAME_HEADER, decode_frame, encode_frame


@contextlib.contextmanager
def raises(exc, match=None):
    """Minimal raises stand-in so the mirror runs standalone in CI
    (``python3 python/tests/test_launcher_protocol.py``) and under pytest."""
    try:
        yield
    except exc as e:
        if match is not None and not re.search(match, str(e)):
            raise AssertionError(f"raised {e!r}, no match for {match!r}") from e
    else:
        raise AssertionError(f"{exc} not raised")

# ── tags (launcher.rs) ─────────────────────────────────────────────────────

TAG_READY = 1
TAG_HEARTBEAT = 2
TAG_RESULT = 3
TAG_ERR = 4
TAG_DONE = 5
TAG_APPLY = 6
TAG_MESH_ACC = 8
TAG_MESH_ERR = 9

CTRL_BUCKET = 2**32 - 2  # u32::MAX - 1; u32::MAX is drain()'s no-frame key


def f64_bits(v):
    return struct.unpack("<Q", struct.pack("<d", v))[0]


def bits_f64(b):
    return struct.unpack("<d", struct.pack("<Q", b))[0]


# ── word codec (launcher.rs WordWriter / WordReader) ───────────────────────


class WordWriter:
    def __init__(self, tag):
        self.words = [tag]

    def u64(self, v):
        self.words.append(v)

    def f64(self, v):
        self.words.append(f64_bits(v))

    def f64s(self, vs):
        self.u64(len(vs))
        for v in vs:
            self.f64(v)

    def str_(self, s):
        b = s.encode("utf-8")
        self.u64(len(b))
        for i in range(0, len(b), 8):
            chunk = b[i : i + 8]
            self.words.append(struct.unpack("<Q", chunk + b"\0" * (8 - len(chunk)))[0])


class Truncated(ValueError):
    pass


class WordReader:
    def __init__(self, words):
        self.words = words
        self.pos = 0

    def u64(self):
        if self.pos >= len(self.words):
            raise Truncated(f"truncated control message ({len(self.words)} words)")
        v = self.words[self.pos]
        self.pos += 1
        return v

    def f64(self):
        return bits_f64(self.u64())

    def f64s(self):
        n = self.u64()
        if n > len(self.words) - self.pos:
            raise Truncated(f"claims {n} payload words, fewer remain")
        return [self.f64() for _ in range(n)]

    def str_(self):
        length = self.u64()
        nwords = -(-length // 8)
        if nwords > len(self.words) - self.pos:
            raise Truncated(f"claims a {length}-byte string, frame is shorter")
        raw = b"".join(struct.pack("<Q", self.u64()) for _ in range(nwords))
        return raw[:length].decode("utf-8", errors="replace")


# ── StarMsg / MeshMsg layouts (launcher.rs) ────────────────────────────────
#
# Messages are dicts with a "tag" key; field order below IS the wire
# layout and must match launcher.rs encode()/decode() word for word.


def encode_star(m):
    t = m["tag"]
    w = WordWriter(t)
    if t == TAG_READY or t == TAG_DONE:
        w.u64(m["rank"])
    elif t == TAG_HEARTBEAT:
        w.u64(m["rank"])
        w.u64(m["step"])
    elif t == TAG_RESULT:
        w.u64(m["step"])
        w.f64(m["loss_sum"])
        w.f64(m["weight_sum"])
        w.f64s(m["d_embed"])
        w.u64(m["hash"])
        w.u64(m["batches"])
        w.u64(m["device_tokens"])
        for c in m["cache"]:
            w.u64(c)
        w.f64s(m["rank_walls"])
        w.f64(m["reduce_ms"])
        w.f64(m["reduce_overlap_ms"])
        w.f64(m["bucket_overlap_ms"])
        w.u64(m["collective_bytes"])
        w.u64(m["buckets"])
    elif t == TAG_ERR:
        w.u64(m["rank"])
        w.u64(m["step"])
        w.str_(m["msg"])
    elif t == TAG_APPLY:
        w.u64(m["step"])
        w.f64(m["lr"])
        w.f64(m["weight_sum"])
        w.f64s(m["d_embed"])
    else:
        raise ValueError(f"unknown star tag {t}")
    return w.words


def decode_star(words):
    r = WordReader(words)
    t = r.u64()
    if t == TAG_READY or t == TAG_DONE:
        return {"tag": t, "rank": r.u64()}
    if t == TAG_HEARTBEAT:
        return {"tag": t, "rank": r.u64(), "step": r.u64()}
    if t == TAG_RESULT:
        return {
            "tag": t,
            "step": r.u64(),
            "loss_sum": r.f64(),
            "weight_sum": r.f64(),
            "d_embed": r.f64s(),
            "hash": r.u64(),
            "batches": r.u64(),
            "device_tokens": r.u64(),
            "cache": [r.u64() for _ in range(4)],
            "rank_walls": r.f64s(),
            "reduce_ms": r.f64(),
            "reduce_overlap_ms": r.f64(),
            "bucket_overlap_ms": r.f64(),
            "collective_bytes": r.u64(),
            "buckets": r.u64(),
        }
    if t == TAG_ERR:
        return {"tag": t, "rank": r.u64(), "step": r.u64(), "msg": r.str_()}
    if t == TAG_APPLY:
        return {
            "tag": t,
            "step": r.u64(),
            "lr": r.f64(),
            "weight_sum": r.f64(),
            "d_embed": r.f64s(),
        }
    raise ValueError(f"unknown star control tag {t}")


def encode_mesh(m):
    t = m["tag"]
    w = WordWriter(t)
    if t == TAG_MESH_ACC:
        w.f64(m["loss_sum"])
        w.f64(m["weight_sum"])
        w.u64(m["hash"])
        w.u64(m["batches"])
        for c in m["cache"]:
            w.u64(c)
        w.u64(m["device_tokens"])
        w.f64(m["merge_ms"])
        w.u64(len(m["walls"]))
        for rank, ms in m["walls"]:
            w.u64(rank)
            w.f64(ms)
        w.f64(m["since_exec_end_ms"])
        w.f64(m["bucket_overlap_ms"])
        w.u64(m["collective_bytes"])
        w.u64(m["buckets"])
    elif t == TAG_MESH_ERR:
        w.u64(m["rank"])
        w.str_(m["msg"])
    else:
        raise ValueError(f"unknown mesh tag {t}")
    return w.words


def decode_mesh(words):
    r = WordReader(words)
    t = r.u64()
    if t == TAG_MESH_ACC:
        out = {
            "tag": t,
            "loss_sum": r.f64(),
            "weight_sum": r.f64(),
            "hash": r.u64(),
            "batches": r.u64(),
            "cache": [r.u64() for _ in range(4)],
            "device_tokens": r.u64(),
            "merge_ms": r.f64(),
        }
        n = r.u64()
        out["walls"] = [(r.u64(), r.f64()) for _ in range(n)]
        out["since_exec_end_ms"] = r.f64()
        out["bucket_overlap_ms"] = r.f64()
        out["collective_bytes"] = r.u64()
        out["buckets"] = r.u64()
        return out
    if t == TAG_MESH_ERR:
        return {"tag": t, "rank": r.u64(), "msg": r.str_()}
    raise ValueError(f"unknown mesh control tag {t}")


# ── fixtures ───────────────────────────────────────────────────────────────

NAN_BITS = 0x7FF8_DEAD_BEEF_CAFE  # a payload-carrying NaN pattern


def star_fixtures():
    return [
        {"tag": TAG_READY, "rank": 3},
        {"tag": TAG_HEARTBEAT, "rank": 1, "step": 41},
        {
            "tag": TAG_RESULT,
            "step": 7,
            "loss_sum": 12.25,
            "weight_sum": 3.5,
            "d_embed": [0.0, -1.5, bits_f64(NAN_BITS)],
            "hash": 0xDEAD_BEEF_0BAD_F00D,
            "batches": 6,
            "device_tokens": 4096,
            "cache": [9, 2, 800, 1],
            "rank_walls": [1.25, 0.5, 2.0],
            "reduce_ms": 0.75,
            "reduce_overlap_ms": 0.25,
            "bucket_overlap_ms": 0.125,
            "collective_bytes": 65536,
            "buckets": 4,
        },
        {"tag": TAG_ERR, "rank": 2, "step": 5, "msg": "rank 2 lost its mesh peer — déjà vu ☠"},
        {"tag": TAG_DONE, "rank": 0},
        {
            "tag": TAG_APPLY,
            "step": 7,
            "lr": 1e-2,
            "weight_sum": 3.5,
            "d_embed": [2.0**-52, -0.0, 1e308],
        },
    ]


def mesh_fixtures():
    return [
        {
            "tag": TAG_MESH_ACC,
            "loss_sum": -4.75,
            "weight_sum": 2.0,
            "hash": 0x0123_4567_89AB_CDEF,
            "batches": 3,
            "cache": [1, 2, 3, 4],
            "device_tokens": 777,
            "merge_ms": 0.5,
            "walls": [(1, 1.5), (3, 0.25)],
            "since_exec_end_ms": 0.125,
            "bucket_overlap_ms": 0.0625,
            "collective_bytes": 1024,
            "buckets": 2,
        },
        {"tag": TAG_MESH_ERR, "rank": 5, "msg": ""},
    ]


def eq_bits(a, b):
    """Structural equality with f64s compared by bit pattern (NaN-safe)."""
    if isinstance(a, float) and isinstance(b, float):
        return f64_bits(a) == f64_bits(b)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(eq_bits(x, y) for x, y in zip(a, b))
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(eq_bits(a[k], b[k]) for k in a)
    return a == b


# ── round trips ────────────────────────────────────────────────────────────


def test_star_messages_round_trip_bit_exactly():
    for m in star_fixtures():
        assert eq_bits(decode_star(encode_star(m)), m), m["tag"]


def test_mesh_messages_round_trip_bit_exactly():
    for m in mesh_fixtures():
        assert eq_bits(decode_mesh(encode_mesh(m)), m), m["tag"]


def test_nan_word_survives_the_f64_frame_payload():
    # the ctrl_frame carriage: words -> f64 payload -> wire frame -> words.
    # A NaN bit pattern must come back identical (Rust side: from_bits /
    # to_bits transmutes; here: struct pack/unpack round trip).
    words = [TAG_HEARTBEAT, NAN_BITS, 0]
    payload = [f64_bits(bits_f64(w)) for w in words]
    assert payload == words
    frame = encode_frame(9, CTRL_BUCKET, 3, payload)
    (seq, bucket, from_, bits), _ = decode_frame(frame)
    assert (seq, bucket, from_) == (9, CTRL_BUCKET, 3)
    assert bits == words
    assert math.isnan(bits_f64(bits[1]))


def test_every_truncation_raises_instead_of_misparsing():
    for m in star_fixtures():
        full = encode_star(m)
        for cut in range(len(full)):
            with raises((Truncated, ValueError)):
                decode_star(full[:cut])
    for m in mesh_fixtures():
        full = encode_mesh(m)
        for cut in range(len(full)):
            with raises((Truncated, ValueError)):
                decode_mesh(full[:cut])


def test_hostile_length_prefixes_are_refused():
    # a Result whose d_embed length word claims 2^60 payload words: the
    # reader must refuse before materializing anything
    words = [TAG_RESULT, 7, f64_bits(0.0), f64_bits(1.0), 2**60]
    with raises(Truncated):
        decode_star(words)
    # same for a string length in an Err
    words = [TAG_ERR, 1, 5, 2**60]
    with raises(Truncated):
        decode_star(words)


def test_ctrl_bucket_stays_clear_of_reserved_keys():
    assert CTRL_BUCKET == 2**32 - 2
    assert CTRL_BUCKET != 2**32 - 1  # drain()'s reserved no-frame key
    # dense data buckets start at 0; any realistic gradient stays far below
    assert CTRL_BUCKET > 2**20


# ── bounded frame decode (Frame::decode_from_bounded) ──────────────────────


def decode_frame_bounded(buf, max_elems):
    """Mirror of the hardened decoder: the header's claimed element count
    is checked against the bound *before* the payload is touched."""
    if len(buf) == 0:
        return None
    if len(buf) < FRAME_HEADER.size:
        raise ValueError("stream ended mid-frame-header")
    seq, bucket, from_, nelems = FRAME_HEADER.unpack_from(buf, 0)
    if max_elems is not None and nelems > max_elems:
        raise ValueError(
            f"frame from rank {from_} claims {nelems} elems > bound {max_elems}"
        )
    if len(buf) - FRAME_HEADER.size < 8 * nelems:
        raise ValueError("stream ended mid-frame-body")
    bits = [
        struct.unpack_from("<Q", buf, FRAME_HEADER.size + 8 * i)[0]
        for i in range(nelems)
    ]
    return (seq, bucket, from_, bits)


def test_oversized_header_is_rejected_before_the_payload():
    evil = FRAME_HEADER.pack(1, 0, 1, 2**32 - 1)  # claims ~32 GiB
    with raises(ValueError, match="claims"):
        decode_frame_bounded(evil, 64)
    # an in-bound frame still decodes, and the bound is inclusive
    ok = encode_frame(1, 0, 1, [f64_bits(2.5)] * 64)
    assert decode_frame_bounded(ok, 64)[3] == [f64_bits(2.5)] * 64
    with raises(ValueError, match="claims"):
        decode_frame_bounded(ok, 63)
    # unbounded (None) keeps the legacy in-process behavior
    assert decode_frame_bounded(ok, None) is not None
    assert decode_frame_bounded(b"", 64) is None  # clean EOF


# ── hello-verified accept loop (socket.rs connect_opts step 3) ─────────────


def accept_loop(pending, dialers):
    """Mirror of the accept loop: each dialer is ``None`` (silent — hello
    read times out) or a claimed rank.  Returns (accepted, still_pending);
    adversaries are dropped without consuming a slot."""
    pending = list(pending)
    accepted = []
    for hello in dialers:
        if not pending:
            break
        if hello is None:
            continue  # silent or half-open dialer: not a child
        if hello not in pending:
            continue  # foreign rank or duplicate hello: drop
        pending.remove(hello)
        accepted.append(hello)
    return accepted, pending


def test_adversarial_dialers_never_consume_accept_slots():
    # silent dialer, foreign rank 7, genuine 1, duplicate 1, genuine 2
    accepted, pending = accept_loop([1, 2], [None, 7, 1, 1, 2])
    assert accepted == [1, 2]
    assert pending == []
    # adversaries alone never complete the mesh
    accepted, pending = accept_loop([1, 2], [None, 7, 9, None])
    assert accepted == []
    assert pending == [2, 1] or pending == [1, 2]


# ── rendezvous parsing (socket.rs) ─────────────────────────────────────────


def complete_lines(text):
    return [l[:-1].rstrip() for l in text.splitlines(keepends=True) if l.endswith("\n")]


def wait_for_line(text, rank):
    """One poll iteration of socket.rs::wait_for_line: returns the address,
    None if not yet published, or raises on a duplicate."""
    prefix = f"{rank} "
    found = None
    for line in complete_lines(text):
        if line.startswith(prefix):
            if found is not None:
                raise ValueError(f"duplicate line for rank {rank} — stale file")
            found = line[len(prefix) :].strip()
    return found


def check_run_header(text, run_id):
    """One poll iteration of socket.rs::wait_for_run_header."""
    for line in complete_lines(text):
        if line.startswith("run "):
            seen = line[4:]
            if seen != run_id:
                raise ValueError(f"run generation {seen!r}, not {run_id!r}")
            return True
    return False


def test_torn_final_line_is_not_parsed_until_terminated():
    torn = "run g1\n0 127.0.0.1:45123\n1 127.0.0.1:451"
    assert wait_for_line(torn, 0) == "127.0.0.1:45123"
    assert wait_for_line(torn, 1) is None  # would dial a truncated port
    assert wait_for_line(torn + "24\n", 1) == "127.0.0.1:45124"


def test_duplicate_rank_lines_are_a_hard_error():
    stale = "0 127.0.0.1:1000\n0 127.0.0.1:2000\n"
    with raises(ValueError, match="duplicate"):
        wait_for_line(stale, 0)
    # ...but a rank whose line is unique still resolves (prefix match is
    # exact: rank 1 does not match rank 10's line)
    assert wait_for_line("10 a:1\n1 b:2\n", 1) == "b:2"


def test_run_header_pins_the_generation():
    assert check_run_header("run gen-7\n0 a:1\n", "gen-7")
    assert not check_run_header("0 a:1\n", "gen-7")  # not yet written
    assert not check_run_header("run gen", "gen")  # torn header line
    with raises(ValueError, match="generation"):
        check_run_header("run gen-OLD\n", "gen-7")


# ── parent watchdog (launcher.rs await_result) ─────────────────────────────


def await_result(events, step, n_ranks):
    """Mirror of the launcher's per-step event loop: returns the Result
    payload, or raises a named-rank error on Err / a vanished process.
    ``events`` is the star inbox: ("msg", rank, StarMsg-dict) or
    ("gone", rank, exit_status) entries, plus a trailing "timeout"."""
    done = [False] * n_ranks
    for ev in events:
        kind = ev[0]
        if kind == "timeout":
            raise TimeoutError(f"no result for step {step} within the deadline")
        _, rank, payload = ev
        if kind == "gone":
            if not done[rank]:
                raise RuntimeError(
                    f"rank {rank} process exited ({payload}) before step {step} completed"
                )
            continue
        tag = payload["tag"]
        if tag == TAG_HEARTBEAT:
            continue
        if tag == TAG_ERR:
            raise RuntimeError(
                f"rank {payload['rank']} failed at step {payload['step']}: {payload['msg']}"
            )
        if tag == TAG_DONE:
            done[rank] = True
            continue
        if tag == TAG_RESULT and payload["step"] == step:
            return payload
    raise TimeoutError(f"star inbox drained before step {step}")


def test_a_vanished_rank_becomes_a_named_rank_error():
    hb = {"tag": TAG_HEARTBEAT, "rank": 1, "step": 3}
    with raises(RuntimeError, match="rank 1 process exited"):
        await_result([("msg", 1, hb), ("gone", 1, "signal: 9")], 3, 2)
    # an Err frame from the root names the failing rank too
    err = {"tag": TAG_ERR, "rank": 0, "step": 3, "msg": "collective peer rank 1 disconnected"}
    with raises(RuntimeError, match="rank 0 failed at step 3"):
        await_result([("msg", 0, err)], 3, 2)
    # a rank that already sent Done may exit freely
    res = {
        "tag": TAG_RESULT,
        "step": 3,
        "loss_sum": 1.0,
        "weight_sum": 1.0,
        "d_embed": [],
        "hash": 0,
        "batches": 1,
        "device_tokens": 1,
        "cache": [0, 0, 0, 0],
        "rank_walls": [0.0],
        "reduce_ms": 0.0,
        "reduce_overlap_ms": 0.0,
        "bucket_overlap_ms": 0.0,
        "collective_bytes": 0,
        "buckets": 1,
    }
    done = {"tag": TAG_DONE, "rank": 1}
    got = await_result(
        [("msg", 1, done), ("gone", 1, "exit: 0"), ("msg", 0, res)], 3, 2
    )
    assert got["step"] == 3


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
