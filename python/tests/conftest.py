import os
import sys

# tests are run from python/ (``cd python && pytest tests``); make the
# ``compile`` package importable regardless of invocation directory.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

# oracles accumulate in float64 (App. B.8 verifies against f32/f64 refs)
jax.config.update("jax_enable_x64", True)
