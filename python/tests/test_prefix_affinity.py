"""Python mirror of the cross-step prefix-reuse schedule tier.

Mirrors ``rust/src/partition/affinity.rs`` + the cache bookkeeping of
``rust/src/trainer/prefix_cache.rs`` (docs/prefix_reuse.md):

* ``prefix_stream``: the root-chain token stream of a tree — the root node
  and every single-child descendant, ending with (and including) the first
  multi-child node's own tokens; nodes carrying alignment pads stop the
  stream before their tokens.  Elements are ``(token, trainable-bits,
  advantage-bits)`` triples — a supervision flip diverges like the ingest
  trie's ``NodeSig``.
* ``prefix_sig``: FNV-1a over the little-endian triple bytes (the exact
  cache key the Rust side stamps onto forest members).
* grouping: each tree annotates with the deepest trie node on its stream
  shared by >= 2 trees; same node => same affine group; loners become
  singleton groups with ``prefix_len == 0``.
* ``affine_order`` / ``affine_bins``: group-major FFD — groups by
  decreasing summed cost, members by decreasing cost, member prefers a bin
  already holding its group, then first-fit, else a new bin.
* ``shard_affine``: deterministic LPT over whole groups (summed member
  cost), so a group never splits across ranks.
* ``PrefixCache``: exact ``(sig, len)`` keys, strictly-monotone LRU clock
  under a token budget, and the staleness contract — any version change
  drops every entry (not counted as an eviction).

Runs standalone (``python3 test_prefix_affinity.py``) — pure stdlib, no
jax, so the CI job can execute it without the compile toolchain.
"""

import struct

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x0000010000000001B3
MASK64 = (1 << 64) - 1


def fnv1a(h, data):
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


class Node:
    def __init__(self, parent, tokens, trainable=None, advantage=None, pad_tail=0):
        self.parent = parent
        self.tokens = tokens
        self.trainable = trainable if trainable is not None else [1.0] * len(tokens)
        self.advantage = advantage if advantage is not None else [1.0] * len(tokens)
        self.pad_tail = pad_tail


def children(nodes):
    ch = [[] for _ in nodes]
    for i, n in enumerate(nodes):
        if n.parent >= 0:
            ch[n.parent].append(i)
    return ch


MAX_STREAM = 4096


def prefix_stream(nodes):
    """affinity.rs prefix_stream: the root-chain triple stream."""
    ch = children(nodes)
    out = []
    cur = 0
    while True:
        n = nodes[cur]
        if n.pad_tail != 0:
            break
        for t in range(len(n.tokens)):
            if len(out) >= MAX_STREAM:
                return out
            out.append((n.tokens[t], f32_bits(n.trainable[t]), f32_bits(n.advantage[t])))
        if len(ch[cur]) != 1:
            break
        cur = ch[cur][0]
    return out


def prefix_sig(stream, length):
    h = FNV_OFFSET
    for tok, tr, adv in stream[:length]:
        h = fnv1a(h, struct.pack("<i", tok))
        h = fnv1a(h, struct.pack("<I", tr))
        h = fnv1a(h, struct.pack("<I", adv))
    return h


def build_index(trees):
    """affinity.rs AffinityIndex::build over lists of Nodes.

    Returns (annots, groups): annots[i] = (group, prefix_len, sig),
    groups[g] = (members, prefix_len, sig).
    """
    streams = [prefix_stream(t) for t in trees]
    arena = [{"children": [], "count": 0}]
    paths = []
    for s in streams:
        cur = 0
        path = []
        for trip in s:
            nxt = None
            for k, c in arena[cur]["children"]:
                if k == trip:
                    nxt = c
                    break
            if nxt is None:
                arena.append({"children": [], "count": 0})
                nxt = len(arena) - 1
                arena[cur]["children"].append((trip, nxt))
            arena[nxt]["count"] += 1
            path.append(nxt)
            cur = nxt
        paths.append(path)
    group_of_node = {}
    annots = []
    groups = []
    for i, path in enumerate(paths):
        best = None
        for d, node in enumerate(path):
            if arena[node]["count"] >= 2:
                best = (node, d + 1)
        if best is not None:
            node, depth = best
            sig = prefix_sig(streams[i], depth)
            if node not in group_of_node:
                groups.append(([], depth, sig))
                group_of_node[node] = len(groups) - 1
            g = group_of_node[node]
            annots.append((g, depth, sig))
        else:
            groups.append(([], 0, 0))
            annots.append((len(groups) - 1, 0, 0))
        groups[annots[-1][0]][0].append(i)
    return annots, groups


def affine_order(annots, groups, costs):
    group_cost = [sum(costs[i] for i in g[0]) for g in groups]
    gorder = sorted(range(len(groups)), key=lambda g: -group_cost[g])
    out = []
    for g in gorder:
        out.extend(sorted(groups[g][0], key=lambda i: -costs[i]))
    return out


def affine_bins(annots, groups, sizes, costs, capacity):
    bins = []  # (used, members, group-set)
    for i in affine_order(annots, groups, costs):
        s = sizes[i]
        assert s <= capacity
        g = annots[i][0]
        slot = None
        for bi, b in enumerate(bins):
            if g in b[2] and b[0] + s <= capacity:
                slot = bi
                break
        if slot is None:
            for bi, b in enumerate(bins):
                if b[0] + s <= capacity:
                    slot = bi
                    break
        if slot is None:
            bins.append([s, [i], {g}])
        else:
            bins[slot][0] += s
            bins[slot][1].append(i)
            bins[slot][2].add(g)
    return [b[1] for b in bins]


def shard_by_cost(costs, n_ranks):
    """forest.rs LPT: stable decreasing order, lowest-rank tie-break."""
    order = sorted(range(len(costs)), key=lambda i: -costs[i])
    ranks = [[] for _ in range(n_ranks)]
    loads = [0] * n_ranks
    for i in order:
        r = min(range(n_ranks), key=lambda k: loads[k])
        loads[r] += costs[i]
        ranks[r].append(i)
    return [sorted(r) for r in ranks], loads


def shard_affine(annots, groups, costs, n_ranks):
    group_costs = [sum(costs[i] for i in g[0]) for g in groups]
    granks, loads = shard_by_cost(group_costs, n_ranks)
    ranks = [sorted(m for g in gs for m in groups[g][0]) for gs in granks]
    return ranks, loads


class PrefixCache:
    """prefix_cache.rs bookkeeping (payload-free)."""

    def __init__(self, budget):
        self.budget = budget
        self.version = 0
        self.clock = 0
        self.used = 0
        self.map = {}  # (sig, len) -> stamp
        self.hits = self.misses = self.hit_tokens = self.evictions = 0

    def set_version(self, v):
        if v != self.version:
            self.map.clear()
            self.used = 0
            self.version = v

    def lookup(self, sig, length):
        if self.budget == 0 or length == 0:
            return False
        self.clock += 1
        if (sig, length) in self.map:
            self.map[(sig, length)] = self.clock
            self.hits += 1
            self.hit_tokens += length
            return True
        self.misses += 1
        return False

    def insert(self, sig, length):
        if self.budget == 0 or length == 0 or length > self.budget:
            return
        if (sig, length) in self.map:
            del self.map[(sig, length)]
            self.used -= length
        while self.used + length > self.budget:
            victim = min(self.map, key=self.map.get)
            self.used -= victim[1]
            del self.map[victim]
            self.evictions += 1
        self.clock += 1
        self.used += length
        self.map[(sig, length)] = self.clock


def reuse_ratio(total, hit):
    if total == 0 or hit >= total:
        return 1.0
    return total / (total - hit)


# ───────────────────────────── fixtures ──────────────────────────────────


def chain(prefix, leaves):
    """Root node with `prefix` tokens, one leaf node per entry."""
    return [Node(-1, list(prefix))] + [Node(0, list(l)) for l in leaves]


# ─────────────────────────────── tests ───────────────────────────────────


def test_stream_follows_root_chain_and_includes_divergence_node():
    t = [Node(-1, [1, 2]), Node(0, [3]), Node(1, [4]), Node(1, [5])]
    assert [x[0] for x in prefix_stream(t)] == [1, 2, 3]
    # pads stop the stream before the padded node's tokens
    t2 = [Node(-1, [1, 2]), Node(0, [3], pad_tail=1)]
    assert [x[0] for x in prefix_stream(t2)] == [1, 2]


def test_sig_matches_rust_fnv_constants():
    # empty stream hashes to the offset basis, like the Rust fingerprints
    assert prefix_sig([], 0) == FNV_OFFSET
    s = [(3, f32_bits(1.0), f32_bits(1.0))]
    h = fnv1a(FNV_OFFSET, struct.pack("<i", 3))
    h = fnv1a(h, struct.pack("<I", f32_bits(1.0)))
    h = fnv1a(h, struct.pack("<I", f32_bits(1.0)))
    assert prefix_sig(s, 1) == h
    assert prefix_sig(s, 1) != FNV_OFFSET


def test_supervision_flip_diverges_like_the_ingest_trie():
    a = chain([7, 8, 9], [[1], [2]])
    b = chain([7, 8, 9], [[3], [4]])
    b[0].trainable = [1.0, 0.0, 1.0]
    annots, _ = build_index([a, b])
    # token 7 matches, token 8 diverges on trainable bits
    assert annots[0][1] == 1 and annots[0][0] == annots[1][0]
    b2 = chain([7, 8, 9], [[3], [4]])
    annots2, _ = build_index([a, b2])
    assert annots2[0][1] == 3
    assert annots2[0][2] == annots2[1][2] != 0


def test_deepest_shared_node_wins_and_loners_are_singletons():
    a = chain([1, 2, 3, 4], [[9], [8]])
    c = chain([1, 2, 3, 5], [[9], [8]])
    b = chain([1, 2, 7], [[9], [8]])
    lone = chain([40, 41], [[9]])
    annots, groups = build_index([a, b, c, lone])
    assert annots[0][1] == 3 and annots[2][1] == 3
    assert annots[0][0] == annots[2][0]
    assert annots[1][1] == 2 and annots[1][0] != annots[0][0]
    assert annots[3] == (annots[3][0], 0, 0)
    assert len(groups) == 3


def test_affine_order_is_group_major_by_total_cost():
    t0 = chain([1, 1, 1], [[2], [3]])
    t1 = chain([1, 1, 1], [[4], [5]])
    t2 = chain([9, 9], [[2], [3]])
    annots, groups = build_index([t0, t1, t2])
    assert affine_order(annots, groups, [5, 2, 6]) == [0, 1, 2]
    assert affine_order(annots, groups, [1, 3, 9]) == [2, 1, 0]


def test_affine_bins_colocate_groups_then_first_fit():
    trees = [
        chain([1, 1], [[100], [101]]),
        chain([2, 2], [[100], [101]]),
        chain([1, 1], [[100], [101]]),
        chain([2, 2], [[100], [101]]),
    ]
    annots, groups = build_index(trees)
    bins = affine_bins(annots, groups, [6, 6, 4, 4], [6, 6, 4, 4], 10)
    find = lambda i: next(bi for bi, b in enumerate(bins) if i in b)
    assert find(0) == find(2) and find(1) == find(3) and find(0) != find(1)
    # capacity is respected and every tree lands exactly once
    assert sorted(i for b in bins for i in b) == [0, 1, 2, 3]


def test_shard_affine_keeps_groups_rank_local():
    trees = [
        chain([1, 1], [[100], [101]]),
        chain([2, 2], [[100], [101]]),
        chain([1, 1], [[100], [101]]),
        chain([2, 2], [[100], [101]]),
        chain([3, 3], [[100], [101]]),
        chain([3, 3], [[100], [101]]),
    ]
    annots, groups = build_index(trees)
    ranks, loads = shard_affine(annots, groups, [10] * 6, 3)
    rank_of = lambda i: next(r for r, ms in enumerate(ranks) if i in ms)
    for members, _, _ in groups:
        assert len({rank_of(m) for m in members}) == 1
    assert sorted(i for r in ranks for i in r) == list(range(6))
    assert sum(loads) == 60


def test_lpt_matches_rust_tie_breaks():
    # equal costs keep input order; equal loads pick the lowest rank
    ranks, loads = shard_by_cost([5, 5, 5, 5], 2)
    assert ranks == [[0, 2], [1, 3]]
    assert loads == [10, 10]


def test_cache_exact_length_rule_and_lru():
    c = PrefixCache(25)
    assert not c.lookup(1, 10)
    c.insert(1, 10)
    assert not c.lookup(1, 6), "shorter prefix of the same sig is a different key"
    c.insert(2, 10)
    assert c.lookup(1, 10)  # refresh: sig 2 is now least recent
    c.insert(3, 10)  # 20 + 10 > 25: evicts sig 2
    assert not c.lookup(2, 10)
    assert c.lookup(1, 10) and c.lookup(3, 10)
    assert c.evictions == 1 and c.used <= 25


def test_version_change_clears_without_counting_evictions():
    c = PrefixCache(100)
    c.insert(1, 10)
    c.set_version(1)
    assert not c.lookup(1, 10)
    assert c.evictions == 0
    c.insert(1, 10)
    c.set_version(1)  # same version: no-op
    assert c.lookup(1, 10)


def test_reuse_ratio_definition():
    assert reuse_ratio(0, 0) == 1.0
    assert reuse_ratio(100, 0) == 1.0
    assert reuse_ratio(100, 50) == 2.0
    assert reuse_ratio(100, 100) == 1.0


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
