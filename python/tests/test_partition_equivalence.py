"""App. B.8 numerical verification: Redundancy-Free Tree Partitioning must
reproduce the unsplit whole-tree loss AND parameter gradients.

The executor here mirrors the Rust coordinator exactly:
  1. topological order:  part_fwd -> per-layer (k_part, v_part)
  2. host gather: each child's gateway = ancestor token rows, collected from
     whichever partition produced them (copy; chain rule through a copy is
     the identity — the AOT equivalent of App. B's retained-graph relay)
  3. reverse topological order: part_bwd with the f32-accumulated KV
     cotangents scattered back from every descendant (App. B.5)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import batching, model, partplan, treemeta
from compile.treemeta import NodeSpec


def run_partitioned(cfg, params, nodes, assignment, capacity, past_capacity):
    """Execute the partition plan; returns (loss_sum, grads)."""
    full_meta, parts = partplan.plan(nodes, assignment)
    n_attn = sum(0 if cfg.is_gdn_layer(i) else 1 for i in range(cfg.n_layers))
    H, hd = cfg.n_heads, cfg.head_dim

    # map full-DFS slot -> (partition, local slot)
    owner = {}
    for pi, p in enumerate(parts):
        lid = {orig: j for j, orig in enumerate(p.nodes)}
        for orig in p.nodes:
            fs, ls = int(full_meta.node_start[orig]), int(p.meta.node_start[lid[orig]])
            for t in range(int(full_meta.node_len[orig])):
                owner[fs + t] = (pi, ls + t)

    fwd = model.part_fwd_program(cfg)
    bwd = model.part_bwd_program(cfg)

    order = partplan.topo_order(parts)
    batches, kv_parts = {}, {}
    kv_ins = {}
    for pi in order:
        p = parts[pi]
        b = partition_batch_jnp(p, capacity, past_capacity, cfg)
        k_in = np.zeros((n_attn, past_capacity, H, hd), np.float32)
        v_in = np.zeros((n_attn, past_capacity, H, hd), np.float32)
        for a, slot in enumerate(p.anc_slots):
            src_pi, src_ls = owner[int(slot)]
            k_in[:, a] = np.asarray(kv_parts[src_pi][0][:, src_ls])
            v_in[:, a] = np.asarray(kv_parts[src_pi][1][:, src_ls])
        kv_ins[pi] = (k_in, v_in)
        loss, wsum, k_part, v_part = fwd(params, b, jnp.asarray(k_in),
                                         jnp.asarray(v_in))
        batches[pi] = b
        kv_parts[pi] = (np.asarray(k_part), np.asarray(v_part))

    # reverse topo: chain cotangents
    d_kv = {pi: (np.zeros((n_attn, capacity, H, hd), np.float64),
                 np.zeros((n_attn, capacity, H, hd), np.float64))
            for pi in order}
    total_loss = 0.0
    grads_acc = None
    for pi in reversed(order):
        p = parts[pi]
        k_in, v_in = kv_ins[pi]
        dk_p, dv_p = d_kv[pi]
        loss, wsum, grads, d_k_in, d_v_in = bwd(
            params, batches[pi], jnp.asarray(k_in), jnp.asarray(v_in),
            jnp.asarray(dk_p.astype(np.float32)),
            jnp.asarray(dv_p.astype(np.float32)),
            jnp.asarray(1.0, jnp.float32))
        total_loss += float(loss)
        grads_acc = grads if grads_acc is None else jax.tree_util.tree_map(
            jnp.add, grads_acc, grads)
        # scatter gateway cotangents to producer partitions (f64 accumulators
        # stand in for the paper's f32 hooks — strictly tighter)
        d_k_in, d_v_in = np.asarray(d_k_in), np.asarray(d_v_in)
        for a, slot in enumerate(p.anc_slots):
            src_pi, src_ls = owner[int(slot)]
            d_kv[src_pi][0][:, src_ls] += d_k_in[:, a]
            d_kv[src_pi][1][:, src_ls] += d_v_in[:, a]
    return total_loss, grads_acc


def partition_batch_jnp(p, capacity, past_capacity, cfg):
    kw = {}
    if cfg.kind == "hybrid":
        kw = dict(chunk_size=cfg.chunk_size, conv_kernel=cfg.conv_kernel)
    return partplan.partition_batch(p, capacity, past_capacity, **kw)


def whole_tree(cfg, params, nodes, capacity):
    meta = treemeta.dfs_serialize(nodes)
    batch = batching.build_batch(meta, capacity)
    (loss, (wsum, _)), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, cfg, batch)
    return float(loss), grads


TREE = None


def three_part_tree(rng):
    """root(5) -> [a(3) -> [b(4), c(2)], d(4)]; cut into 3 partitions."""
    return [NodeSpec(-1, rng.integers(0, 64, 5)),
            NodeSpec(0, rng.integers(0, 64, 3)),
            NodeSpec(1, rng.integers(0, 64, 4)),
            NodeSpec(1, rng.integers(0, 64, 2)),
            NodeSpec(0, rng.integers(0, 64, 4))]


class TestPartitionEquivalence:
    @pytest.mark.parametrize("assignment,n_parts", [
        ([0, 0, 0, 0, 0], 1),          # no cut (degenerate)
        ([0, 1, 1, 1, 0], 2),          # cut below root: subtree of a
        ([0, 0, 1, 2, 0], 3),          # two children of the same cut node
        ([0, 1, 1, 2, 3], 4),          # aggressive: almost per-node
    ])
    def test_dense_grads_match_unsplit(self, assignment, n_parts):
        cfg = model.TINY
        rng = np.random.default_rng(42)
        nodes = three_part_tree(rng)
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        l_full, g_full = whole_tree(cfg, params, nodes, 32)
        l_part, g_part = run_partitioned(cfg, params, nodes, assignment,
                                         capacity=32, past_capacity=16)
        # paper tolerance: max-relative < 1e-4 (f32)
        assert abs(l_part - l_full) < 1e-4 * max(1.0, abs(l_full))
        for a, b in zip(jax.tree_util.tree_leaves(g_part),
                        jax.tree_util.tree_leaves(g_full)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)

    def test_self_consistency_exact_zero(self):
        """Two identical partitioned runs must agree EXACTLY (App. B.8)."""
        cfg = model.TINY
        rng = np.random.default_rng(1)
        nodes = three_part_tree(rng)
        params = model.init_params(jax.random.PRNGKey(1), cfg)
        r1 = run_partitioned(cfg, params, nodes, [0, 0, 1, 2, 0], 32, 16)
        r2 = run_partitioned(cfg, params, nodes, [0, 0, 1, 2, 0], 32, 16)
        assert r1[0] == r2[0]
        for a, b in zip(jax.tree_util.tree_leaves(r1[1]),
                        jax.tree_util.tree_leaves(r2[1])):
            assert (np.asarray(a) == np.asarray(b)).all()

    def test_loss_conserved_across_partitions(self):
        """sum of partition loss_sums == whole-tree loss_sum (boundary
        virtual targets account for cut-edge losses)."""
        cfg = model.TINY
        rng = np.random.default_rng(3)
        nodes = three_part_tree(rng)
        params = model.init_params(jax.random.PRNGKey(3), cfg)
        l_full, _ = whole_tree(cfg, params, nodes, 32)
        l_part, _ = run_partitioned(cfg, params, nodes, [0, 1, 2, 1, 3], 32, 16)
        assert abs(l_part - l_full) < 1e-4 * max(1.0, abs(l_full))

    def test_moe_partitioned(self):
        cfg = model.ModelConfig(**{**model.TINY_MOE.__dict__,
                                   "aux_coef": 0.0, "name": "tiny-moe-part"})
        rng = np.random.default_rng(5)
        nodes = three_part_tree(rng)
        params = model.init_params(jax.random.PRNGKey(5), cfg)
        l_full, g_full = whole_tree(cfg, params, nodes, 32)
        l_part, g_part = run_partitioned(cfg, params, nodes, [0, 1, 1, 1, 0], 32, 16)
        assert abs(l_part - l_full) < 1e-4 * max(1.0, abs(l_full))
        for a, b in zip(jax.tree_util.tree_leaves(g_part),
                        jax.tree_util.tree_leaves(g_full)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)

    def test_deep_chain_partitions(self):
        """Long chain split at every node — sequence-packing degenerate case
        (a sequence is a special case of a prefix tree, §2)."""
        cfg = model.TINY
        rng = np.random.default_rng(6)
        nodes = [NodeSpec(-1, rng.integers(0, 64, 4)),
                 NodeSpec(0, rng.integers(0, 64, 4)),
                 NodeSpec(1, rng.integers(0, 64, 4)),
                 NodeSpec(2, rng.integers(0, 64, 4))]
        params = model.init_params(jax.random.PRNGKey(6), cfg)
        l_full, g_full = whole_tree(cfg, params, nodes, 16)
        l_part, g_part = run_partitioned(cfg, params, nodes, [0, 1, 2, 3],
                                         capacity=16, past_capacity=16)
        assert abs(l_part - l_full) < 1e-4 * max(1.0, abs(l_full))
        for a, b in zip(jax.tree_util.tree_leaves(g_part),
                        jax.tree_util.tree_leaves(g_full)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)
