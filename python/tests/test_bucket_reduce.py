"""Python mirror of the Rust bucketed collective reduce.

Mirrors ``rust/src/coordinator/collective/mod.rs`` (``bucket_ranges``, the
frame wire format, the ``FrameStash``) and the bucketed fold discipline of
``rust/src/coordinator/dist.rs::execute_bucketed``: a rank finishes its own
accumulation first, then folds each bucket's children strictly in bracket
round order, regardless of frame arrival order (out-of-order frames park in
a stash keyed ``(seq, bucket, from)``).

Determinism contract being mirrored: the per-element fold sequence is a
pure function of the bracket (``test_reduce_schedule.py``) and the bucket
boundaries only partition the index space — they never reorder any
element's fold — so the bucketed reduce is **bit-identical** to the
monolithic one at every bucket size, on every transport, under every
arrival order.  Keep in lockstep with the Rust unit tests
(``bucketed_and_socket_reduce_bit_match_the_monolithic_path`` et al.).
"""

import random
import struct

from test_reduce_schedule import reduce_children, reduce_parent, reduce_schedule

# ── bucket_ranges (collective/mod.rs) ──────────────────────────────────────


def bucket_ranges(flat_len, bucket_kb):
    """Fixed-size bucket partition; kb == 0 means one monolithic bucket."""
    if flat_len == 0:
        return []
    per = flat_len if bucket_kb == 0 else max(bucket_kb * 1024 // 8, 1)
    return [(s, min(s + per, flat_len)) for s in range(0, flat_len, per)]


# ── frame wire format (collective/mod.rs) ──────────────────────────────────

FRAME_HEADER = struct.Struct("<QIII")  # seq, bucket, from, nelems


def encode_frame(seq, bucket, from_, payload_bits):
    """payload_bits: list of u64 f64 bit patterns (the Rust side encodes
    via ``to_bits`` so NaN payloads survive the wire)."""
    out = bytearray(FRAME_HEADER.pack(seq, bucket, from_, len(payload_bits)))
    for b in payload_bits:
        out += struct.pack("<Q", b)
    return bytes(out)


def decode_frame(buf, off=0):
    """Returns ((seq, bucket, from, payload_bits), next_off); None at a
    clean EOF; raises on a truncated frame."""
    if off == len(buf):
        return None
    if len(buf) - off < FRAME_HEADER.size:
        raise ValueError("stream ended mid-frame-header")
    seq, bucket, from_, nelems = FRAME_HEADER.unpack_from(buf, off)
    off += FRAME_HEADER.size
    if len(buf) - off < 8 * nelems:
        raise ValueError("stream ended mid-frame-body")
    bits = [struct.unpack_from("<Q", buf, off + 8 * i)[0] for i in range(nelems)]
    return (seq, bucket, from_, bits), off + 8 * nelems


# ── stash (collective/mod.rs::FrameStash) ──────────────────────────────────


class FrameStash:
    def __init__(self):
        self.map = {}

    def put(self, seq, bucket, from_, data):
        self.map[(seq, bucket, from_)] = data

    def take(self, seq, bucket, from_):
        return self.map.pop((seq, bucket, from_), None)

    def gc_below(self, seq):
        self.map = {k: v for k, v in self.map.items() if k[0] >= seq}


# ── the bucketed reduce simulation (dist.rs::execute_bucketed) ─────────────


def bucketed_reduce(payloads, bucket_kb, fold, rng=None, seq=7):
    """Folds rank payloads up the log-tree bracket bucket-by-bucket.

    ``payloads[r]`` is rank r's fully-accumulated flat payload (a rank's
    own accumulation always completes before any child fold — the pump
    only *drains* the transport at earlier units).  ``fold(a, b)`` folds a
    child element into a parent element.  ``rng`` shuffles each rank's
    frame arrival order; the stash-and-replay cursor makes the result
    independent of it.  Returns rank 0's folded payload.
    """
    n = len(payloads)
    flat_len = len(payloads[0])
    ranges = bucket_ranges(flat_len, bucket_kb)
    sent = {}  # (parent, bucket, child) -> frame payload
    for rank in range(n - 1, -1, -1):
        acc = list(payloads[rank])
        children = reduce_children(rank, n)  # (round, src), round order
        # adversarial delivery: every child frame for this rank arrives in
        # one shuffled burst and parks in the stash
        stash = FrameStash()
        inbox = [
            (b, src, sent.pop((rank, b, src)))
            for (_, src) in children
            for b in range(len(ranges))
        ]
        if rng is not None:
            rng.shuffle(inbox)
        for b, src, data in inbox:
            stash.put(seq, b, src, data)
        # the cursor: per bucket, children strictly in bracket round order
        for bi, (start, stop) in enumerate(ranges):
            for (_, src) in children:
                data = stash.take(seq, bi, src)
                assert data is not None, "frames-per-rank invariant broken"
                for i, x in enumerate(data):
                    acc[start + i] = fold(acc[start + i], x)
        if rank != 0:
            parent = reduce_parent(rank)
            for bi, (start, stop) in enumerate(ranges):
                sent[(parent, bi, rank)] = acc[start:stop]
    assert not sent, "undelivered frames"
    return acc


def monolithic_reduce(payloads, fold):
    """The typed-path reference: whole accumulators, same bracket."""
    acc = [list(p) for p in payloads]
    for rnd in reduce_schedule(len(payloads)):
        for dst, src in rnd:
            acc[dst] = [fold(a, b) for a, b in zip(acc[dst], acc[src])]
    return acc[0]


def bits(v):
    return struct.unpack("<Q", struct.pack("<d", v))[0]


# ── tests ──────────────────────────────────────────────────────────────────


def test_bucket_ranges_match_rust_fixtures():
    assert bucket_ranges(0, 0) == []
    assert bucket_ranges(12_345, 0) == [(0, 12_345)]
    # 64 KiB of f64 = 8192 elements per bucket
    assert bucket_ranges(20_000, 64) == [(0, 8192), (8192, 16_384), (16_384, 20_000)]
    for flat_len, kb in [(1, 0), (10_000, 1), (100_000, 64), (513, 1)]:
        ranges = bucket_ranges(flat_len, kb)
        assert ranges[0][0] == 0 and ranges[-1][1] == flat_len
        assert all(a[1] == b[0] for a, b in zip(ranges, ranges[1:]))
        assert all(start < stop for start, stop in ranges)


def test_bucketed_matches_monolithic_on_adversarial_arrival_orders():
    add = lambda a, b: a + b
    for n in [2, 3, 5, 8]:
        rng = random.Random(n)
        # magnitudes spread over 30 orders so any reassociation shows up
        payloads = [
            [rng.uniform(-1, 1) * 10 ** rng.randint(-15, 15) for _ in range(100)]
            for _ in range(n)
        ]
        ref = monolithic_reduce(payloads, add)
        for kb in [0, 1, 64]:
            for shuffle_seed in range(4):
                got = bucketed_reduce(
                    payloads, kb, add, rng=random.Random(shuffle_seed)
                )
                assert [bits(x) for x in got] == [bits(x) for x in ref], (n, kb)


def test_flattened_fold_order_is_rank_order_in_every_bucket():
    # label elements: fold = concat; every element of every bucket must
    # fold in rank order 0..n, for odd rank counts (byes) included
    concat = lambda a, b: a + b
    for n in [2, 3, 5, 7, 8]:
        payloads = [[[r]] * 13 for r in range(n)]  # 13 elems, 1-elem labels
        for kb in [0, 1]:
            got = bucketed_reduce(payloads, kb, concat, rng=random.Random(0))
            assert all(lab == list(range(n)) for lab in got), (n, kb)


def test_odd_rank_byes_fold_in_the_final_round():
    # n = 5: rank 4 is bye until the last round, but the flattened order
    # still ends ...3, 4 — the bye changes rounds, never order
    concat = lambda a, b: a + b
    got = bucketed_reduce([[[r]] for r in range(5)], 1, concat)
    assert got[0] == [0, 1, 2, 3, 4]


def test_cancellation_fixture_bucketed_equals_tree_not_serial():
    # the worst-case reassociation fixture shared with
    # tests/dist_equivalence.rs and the Rust collective unit tests
    vals = [1.0, 1e16, -1e16, 1.0]
    serial = vals[0]
    for v in vals[1:]:
        serial = serial + v
    add = lambda a, b: a + b
    tree = monolithic_reduce([[v] for v in vals], add)[0]
    assert serial == 1.0 and tree == 0.0, "fixture must exercise reassociation"
    for kb in [0, 1, 64]:
        got = bucketed_reduce([[v] for v in vals], kb, add, rng=random.Random(kb))
        assert bits(got[0]) == bits(tree), kb


def test_stash_replays_by_key_and_gcs_stale_steps():
    st = FrameStash()
    st.put(1, 0, 3, [1.0])
    st.put(2, 0, 3, [2.0])
    assert st.take(2, 0, 1) is None
    assert st.take(2, 0, 3) == [2.0]
    st.gc_below(2)
    assert not st.map, "seq-1 residue collected"


def test_frame_round_trip_preserves_nan_bits_and_aborts():
    payload = [
        bits(1.5),
        bits(-0.0),
        0x7FF8000000000001,  # NaN with payload: must survive the wire
        0x7FF80000DEAD0001,
        bits(float("inf")),
    ]
    wire = encode_frame(7, 3, 5, payload)
    assert len(wire) == FRAME_HEADER.size + 8 * len(payload)
    (seq, bucket, from_, got), off = decode_frame(wire)
    assert (seq, bucket, from_) == (7, 3, 5)
    assert got == payload
    assert decode_frame(wire, off) is None, "clean EOF"
    # abort marker (empty payload) chains with a real frame
    chained = encode_frame(1, 0, 2, []) + encode_frame(1, 1, 2, [bits(42.0)])
    (s, b, f, data), off = decode_frame(chained)
    assert data == [] and (s, b, f) == (1, 0, 2)
    (s, b, f, data), off = decode_frame(chained, off)
    assert data == [bits(42.0)]
    assert decode_frame(chained, off) is None


def test_truncated_frame_is_an_error_not_a_silent_eof():
    wire = encode_frame(1, 0, 1, [bits(1.0), bits(2.0)])
    for cut in [FRAME_HEADER.size - 2, len(wire) - 3]:
        try:
            decode_frame(wire[:cut])
        except ValueError:
            pass
        else:
            raise AssertionError(f"truncation at {cut} must raise")


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
