"""Eq. 1-5: tree loss (one DFS pass, per-token weights g_t/K) must equal the
sep-avg baseline (independent per-path passes, averaged) in both value and
parameter gradients — for SFT and RL objectives, on all three model kinds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import batching, model, treemeta
from compile.treemeta import NodeSpec


def sample_tree(rng, advantages=False):
    def seg(n):
        t = rng.integers(0, 64, n)
        tr = (rng.random(n) > 0.3).astype(np.float32)  # mixed user/model tokens
        adv = (rng.standard_normal(n).astype(np.float32)
               if advantages else np.ones(n, np.float32))
        return t, tr, adv

    return [NodeSpec(-1, *seg(5)),
            NodeSpec(0, *seg(3)),
            NodeSpec(1, *seg(4)),
            NodeSpec(1, *seg(2)),
            NodeSpec(0, *seg(4))]


def cap_for(meta, align=16):
    return ((meta.size + align) // align + 1) * align


def tree_loss_and_grads(cfg, params, nodes, capacity=None):
    extra = {}
    if cfg.kind == "hybrid":
        nodes = treemeta.pad_nodes_for_chunks(nodes, cfg.chunk_size)
        extra = dict(chunk_size=cfg.chunk_size, conv_kernel=cfg.conv_kernel)
    meta = treemeta.dfs_serialize(nodes)
    batch = batching.build_batch(meta, capacity or cap_for(meta), **extra)
    (loss, (wsum, _)), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, cfg, batch)
    return float(loss), grads, meta


def sepavg_loss_and_grads(cfg, params, nodes, capacity=None):
    """Baseline Eq. 1: every path independently, averaged by K."""
    K = len(treemeta.paths(nodes))
    total = 0.0
    grads_acc = None
    for path in treemeta.paths(nodes):
        extra = {}
        chain = []
        for d, n in enumerate(path):
            nd = nodes[n]
            chain.append(NodeSpec(d - 1, nd.tokens, nd.trainable, nd.advantage))
        if cfg.kind == "hybrid":
            chain = treemeta.pad_nodes_for_chunks(chain, cfg.chunk_size)
            extra = dict(chunk_size=cfg.chunk_size, conv_kernel=cfg.conv_kernel)
        meta = treemeta.dfs_serialize(chain)
        batch = batching.build_batch(meta, capacity or cap_for(meta), **extra)
        (loss, _), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, cfg, batch)
        total += float(loss)
        grads_acc = grads if grads_acc is None else jax.tree_util.tree_map(
            jnp.add, grads_acc, grads)
    scale = 1.0 / K
    return total * scale, jax.tree_util.tree_map(lambda g: g * scale, grads_acc)


def assert_grads_close(g1, g2, rtol=2e-3, atol=2e-5):
    flat1 = jax.tree_util.tree_leaves(g1)
    flat2 = jax.tree_util.tree_leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("cfg", [model.TINY, model.TINY_MOE, model.TINY_HYBRID],
                         ids=lambda c: c.name)
def test_sft_equivalence(cfg):
    rng = np.random.default_rng(0)
    nodes = sample_tree(rng)
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    if cfg.kind == "moe":
        # aux loss is NOT path-decomposable (it averages router stats over the
        # batch); the paper's equivalence claim is about the token objective,
        # so compare with aux disabled.
        cfg = type(cfg)(**{**cfg.__dict__, "aux_coef": 0.0, "name": "tiny-moe-noaux"})
    l_tree, g_tree, meta = tree_loss_and_grads(cfg, params, nodes)
    l_sep, g_sep = sepavg_loss_and_grads(cfg, params, nodes)
    assert abs(l_tree - l_sep) < 1e-4 * max(1.0, abs(l_sep))
    assert_grads_close(g_tree, g_sep)


def test_rl_advantage_equivalence():
    """Policy-gradient objective: ell_t = -A_t log p — same reduction."""
    cfg = model.TINY
    rng = np.random.default_rng(3)
    nodes = sample_tree(rng, advantages=True)
    params = model.init_params(jax.random.PRNGKey(2), cfg)
    l_tree, g_tree, _ = tree_loss_and_grads(cfg, params, nodes)
    l_sep, g_sep = sepavg_loss_and_grads(cfg, params, nodes)
    assert abs(l_tree - l_sep) < 1e-4 * max(1.0, abs(l_sep))
    assert_grads_close(g_tree, g_sep)


def test_weight_vector_is_g_over_k():
    rng = np.random.default_rng(4)
    nodes = sample_tree(rng)
    meta = treemeta.dfs_serialize(nodes)
    batch = batching.build_batch(meta, 32, numpy=True)
    K = meta.num_paths
    expect = meta.g / K
    tr = np.concatenate([n.trainable for n in nodes])
    np.testing.assert_allclose(batch["weights"][:meta.size], expect * tr, rtol=1e-6)


def test_custom_path_weights():
    """§3.1 generalization: arbitrary path weights w_k -> lambda_t = sum w_k.

    Uses lambda_t = 1 (every unique token once) vs manual computation."""
    cfg = model.TINY
    rng = np.random.default_rng(5)
    nodes = sample_tree(rng)
    meta = treemeta.dfs_serialize(nodes)
    params = model.init_params(jax.random.PRNGKey(6), cfg)
    batch = batching.build_batch(meta, 32, numpy=True)
    tr = np.concatenate([n.trainable for n in nodes])
    w = np.zeros(32, np.float32)
    w[:meta.size] = tr            # lambda_t = 1 on trainable tokens
    batch["weights"] = w
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, (wsum, _) = model.loss_fn(params, cfg, batch)
    # manual: -sum_t logp_t over unique trainable tokens
    lp = model.logprob_program(cfg)(params, batch)
    manual = -float(jnp.sum(jnp.asarray(w) * np.sign(np.abs(np.asarray(lp)))
                            * lp))
    # (sign trick: lp already zeroed at prev_idx < 0)
    assert abs(float(loss) - manual) < 1e-4 * max(1.0, abs(manual))


def test_prefix_token_counted_g_times():
    """Eq. 2 at the model level: duplicating a 2-branch tree's loss by hand."""
    cfg = model.TINY
    rng = np.random.default_rng(7)
    nodes = [NodeSpec(-1, rng.integers(0, 64, 4)),
             NodeSpec(0, rng.integers(0, 64, 3)),
             NodeSpec(0, rng.integers(0, 64, 3))]
    meta = treemeta.dfs_serialize(nodes)
    params = model.init_params(jax.random.PRNGKey(8), cfg)
    batch = batching.build_batch(meta, 16)
    lp = np.asarray(model.logprob_program(cfg)(params, batch))[:meta.size]
    loss, _ = model.loss_fn(params, cfg, batch)
    manual = -(lp[:4].sum() * 2 / 2 + lp[4:7].sum() / 2 + lp[7:10].sum() / 2)
    assert abs(float(loss) - manual) < 1e-4 * max(1.0, abs(manual))
