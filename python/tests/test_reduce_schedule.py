"""Python mirror of the Rust log-tree reduce pairing schedule.

Mirrors ``rust/src/coordinator/dist.rs``: ``reduce_depth`` /
``reduce_schedule`` / ``reduce_parent`` / ``reduce_children``.  The Rust
unit tests (``schedule_brackets_match_python_mirror`` et al.) hardcode the
exact brackets this mirror computes for ranks 1, 2, 3, 5, 8 — keep the two
in lockstep, like the PR 4 sharder mirrors.

Determinism contract being mirrored: the bracket is a pure function of
rank ids (round ``d`` merges rank ``r`` with ``r + 2**d`` whenever
``r % 2**(d+1) == 0`` and the partner exists), the destination is always
the lower rank id, odd tails get byes, depth is ``ceil(log2(n))``, and the
flattened merge order is exactly rank order ``0..n`` — the tree changes
grouping, never ordering.
"""

import math


def reduce_depth(n):
    assert n >= 1
    d = 0
    while (1 << d) < n:
        d += 1
    return d


def reduce_schedule(n):
    """rounds[d] = list of (dst, src) merges; dst absorbs src."""
    rounds = []
    d = 0
    while (1 << d) < n:
        stride = 1 << (d + 1)
        pairs = []
        for dst in range(0, n, stride):
            src = dst + (1 << d)
            if src < n:
                pairs.append((dst, src))
        rounds.append(pairs)
        d += 1
    return rounds


def reduce_parent(rank):
    return None if rank == 0 else rank & (rank - 1)


def reduce_children(rank, n):
    out = []
    for d in range(reduce_depth(n)):
        if rank % (1 << (d + 1)) == 0:
            src = rank + (1 << d)
            if src < n:
                out.append((d, src))
    return out


def test_brackets_match_rust_unit_tests():
    # the exact expectations hardcoded in rust/src/coordinator/dist.rs
    assert reduce_schedule(1) == []
    assert reduce_schedule(2) == [[(0, 1)]]
    assert reduce_schedule(3) == [[(0, 1)], [(0, 2)]]
    assert reduce_schedule(5) == [[(0, 1), (2, 3)], [(0, 2)], [(0, 4)]]
    assert reduce_schedule(8) == [
        [(0, 1), (2, 3), (4, 5), (6, 7)],
        [(0, 2), (4, 6)],
        [(0, 4)],
    ]


def test_depth_is_ceil_log2():
    for n in range(1, 65):
        want = 0 if n == 1 else math.ceil(math.log2(n))
        assert reduce_depth(n) == want, n
        assert len(reduce_schedule(n)) == want, n


def test_odd_rank_byes():
    # n = 5: rank 4 has no partner until the final round
    sched = reduce_schedule(5)
    assert all(4 not in pair for rnd in sched[:2] for pair in rnd)
    assert sched[2] == [(0, 4)]


def test_every_rank_merges_exactly_once_into_its_parent():
    for n in range(1, 65):
        sched = reduce_schedule(n)
        srcs = sorted(s for rnd in sched for (_, s) in rnd)
        assert srcs == list(range(1, n)), n
        for r in range(1, n):
            tz = (r & -r).bit_length() - 1
            assert (reduce_parent(r), r) in sched[tz], (n, r)


def test_child_views_union_to_schedule():
    for n in range(1, 65):
        sched = reduce_schedule(n)
        from_children = [[] for _ in sched]
        for r in range(n):
            for (d, src) in reduce_children(r, n):
                from_children[d].append((r, src))
        assert [sorted(x) for x in from_children] == [sorted(x) for x in sched], n


def test_flattened_merge_order_is_rank_order():
    # the tree reassociates the fold but never reorders it
    for n in range(1, 65):
        lab = [[i] for i in range(n)]
        for rnd in reduce_schedule(n):
            for (dst, src) in rnd:
                lab[dst] = lab[dst] + lab[src]
        assert lab[0] == list(range(n)), n


def test_worst_case_reassociation_fixture():
    # the fixture tests/dist_equivalence.rs uses: serial fold and tree fold
    # produce *different bits* (1.0 vs 0.0) while both stay within f64
    # reassociation tolerance of the accumulated magnitude
    vals = [1.0, 1e16, -1e16, 1.0]
    serial = 0.0
    acc = vals[0]
    for v in vals[1:]:
        acc = acc + v
    serial = acc
    lab = list(vals)
    for rnd in reduce_schedule(4):
        for (dst, src) in rnd:
            lab[dst] = lab[dst] + lab[src]
    tree = lab[0]
    assert serial == 1.0 and tree == 0.0
    scale = sum(abs(v) for v in vals)
    assert abs(serial - tree) <= 1e-12 * scale


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
