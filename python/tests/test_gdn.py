"""GDN tree state routing (Eq. 10) + tree-correct causal conv (App. A.3)
vs the per-token recurrent and per-path oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import treemeta
from compile.kernels import gdn, ref
from compile.treemeta import NodeSpec

TOL = 2e-5  # paper App. B.8: SSM hybrid f32 max-relative < 2e-5


def rand_inputs(rng, S, H, Dk, Dv):
    q = rng.standard_normal((S, H, Dk)).astype(np.float32) * 0.5
    k = rng.standard_normal((S, H, Dk)).astype(np.float32) * 0.5
    v = rng.standard_normal((S, H, Dv)).astype(np.float32) * 0.5
    g = -np.abs(rng.standard_normal((S, H))).astype(np.float32) * 0.3
    beta = rng.uniform(0.1, 0.9, (S, H)).astype(np.float32)
    return q, k, v, g, beta


def padded_tree(rng, chunk, max_nodes=8, max_seg=7):
    nodes = treemeta.pad_nodes_for_chunks(
        treemeta.random_tree(rng, max_nodes=max_nodes, max_seg=max_seg), chunk)
    meta = treemeta.dfs_serialize(nodes)
    cpm = treemeta.chunk_parent_map(meta, chunk)
    return nodes, meta, cpm


def transparent_pads(g, beta, pad_mask):
    """Pads must be state-transparent: g = 0, beta = 0 (gdn.py contract)."""
    g = g * (1 - pad_mask[:, None])
    beta = beta * (1 - pad_mask[:, None])
    return g.astype(np.float32), beta.astype(np.float32)


class TestChunkedGdn:
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]))
    def test_matches_recurrent(self, seed, chunk):
        rng = np.random.default_rng(seed)
        nodes, meta, cpm = padded_tree(rng, chunk)
        q, k, v, g, beta = rand_inputs(rng, meta.size, 2, 4, 6)
        g, beta = transparent_pads(g, beta, meta.pad_mask.astype(np.float32))
        o_ref = ref.gdn_recurrent_tree(q, k, v, g, beta,
                                       meta.node_start, meta.node_len,
                                       meta.node_parent)
        o, _ = gdn.gdn_tree_chunked(*map(jnp.asarray, (q, k, v, g, beta)),
                                    jnp.asarray(cpm), chunk)
        real = ~meta.pad_mask
        np.testing.assert_allclose(np.asarray(o)[real], np.asarray(o_ref)[real],
                                   atol=1e-4, rtol=1e-3)

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000))
    def test_matches_per_path(self, seed):
        """Forward equivalence (Eq. 6) for the SSM layer."""
        chunk = 4
        rng = np.random.default_rng(seed)
        nodes, meta, cpm = padded_tree(rng, chunk)
        q, k, v, g, beta = rand_inputs(rng, meta.size, 2, 4, 4)
        g, beta = transparent_pads(g, beta, meta.pad_mask.astype(np.float32))
        o_path = ref.gdn_per_path(q, k, v, g, beta, meta, nodes)
        o, _ = gdn.gdn_tree_chunked(*map(jnp.asarray, (q, k, v, g, beta)),
                                    jnp.asarray(cpm), chunk)
        real = ~meta.pad_mask
        np.testing.assert_allclose(np.asarray(o)[real], np.asarray(o_path)[real],
                                   atol=1e-4, rtol=1e-3)

    def test_sequential_routing_would_be_wrong(self):
        """Fig. 2: feeding the DFS-previous chunk's state into a sibling branch
        must give a different (wrong) result than tree routing."""
        rng = np.random.default_rng(9)
        chunk = 4
        nodes = [NodeSpec(-1, rng.integers(0, 9, 4)),
                 NodeSpec(0, rng.integers(0, 9, 4)),
                 NodeSpec(0, rng.integers(0, 9, 4))]
        meta = treemeta.dfs_serialize(nodes)
        cpm_tree = treemeta.chunk_parent_map(meta, chunk)      # [-1, 0, 0]
        cpm_seq = np.array([-1, 0, 1], np.int32)               # sequential
        q, k, v, g, beta = rand_inputs(rng, meta.size, 1, 4, 4)
        o_tree, _ = gdn.gdn_tree_chunked(*map(jnp.asarray, (q, k, v, g, beta)),
                                         jnp.asarray(cpm_tree), chunk)
        o_seq, _ = gdn.gdn_tree_chunked(*map(jnp.asarray, (q, k, v, g, beta)),
                                        jnp.asarray(cpm_seq), chunk)
        o_ref = ref.gdn_recurrent_tree(q, k, v, g, beta, meta.node_start,
                                       meta.node_len, meta.node_parent)
        last = slice(8, 12)  # sibling branch n2
        assert np.abs(np.asarray(o_tree)[last] - np.asarray(o_ref)[last]).max() < 1e-4
        assert np.abs(np.asarray(o_seq)[last] - np.asarray(o_ref)[last]).max() > 1e-3

    def test_state_gateway_injection(self):
        """App. B.7: running the subtree with initial_state = captured parent
        state reproduces the unsplit forward."""
        rng = np.random.default_rng(11)
        chunk = 4
        nodes = [NodeSpec(-1, rng.integers(0, 9, 8)),
                 NodeSpec(0, rng.integers(0, 9, 4)),
                 NodeSpec(1, rng.integers(0, 9, 4))]
        meta = treemeta.dfs_serialize(nodes)
        cpm = treemeta.chunk_parent_map(meta, chunk)
        q, k, v, g, beta = rand_inputs(rng, meta.size, 2, 4, 4)
        o_full, states = gdn.gdn_tree_chunked(
            *map(jnp.asarray, (q, k, v, g, beta)), jnp.asarray(cpm), chunk)
        # cut after node 1 (chunks 0..2 in parent, chunk 3 in child)
        cut_chunk = 2
        init = states[cut_chunk + 1]
        sl = slice(12, 16)
        o_child, _ = gdn.gdn_tree_chunked(
            jnp.asarray(q[sl]), jnp.asarray(k[sl]), jnp.asarray(v[sl]),
            jnp.asarray(g[sl]), jnp.asarray(beta[sl]),
            jnp.asarray(np.array([-1], np.int32)), chunk, initial_state=init)
        np.testing.assert_allclose(np.asarray(o_child), np.asarray(o_full)[sl],
                                   atol=1e-5)

    def test_grads_flow_to_initial_state(self):
        """The gateway state is a differentiable leaf (App. B.7 chaining)."""
        rng = np.random.default_rng(12)
        chunk = 4
        S, H, Dk, Dv = 8, 1, 4, 4
        q, k, v, g, beta = rand_inputs(rng, S, H, Dk, Dv)
        init = jnp.asarray(rng.standard_normal((H, Dk, Dv)).astype(np.float32) * 0.1)
        cpm = jnp.asarray(np.array([-1, 0], np.int32))

        def loss(init):
            o, _ = gdn.gdn_tree_chunked(*map(jnp.asarray, (q, k, v, g, beta)),
                                        cpm, chunk, initial_state=init)
            return jnp.sum(o ** 2)

        gr = jax.grad(loss)(init)
        assert np.abs(np.asarray(gr)).max() > 0
        # finite-difference check on one element
        eps = 1e-3
        e = np.zeros((H, Dk, Dv), np.float32); e[0, 1, 2] = eps
        fd = (loss(init + jnp.asarray(e)) - loss(init - jnp.asarray(e))) / (2 * eps)
        assert abs(float(fd) - float(np.asarray(gr)[0, 1, 2])) < 5e-2 * max(1.0, abs(float(fd)))


class TestPallasGdn:
    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([2, 4]))
    def test_matches_chunked(self, seed, chunk):
        rng = np.random.default_rng(seed)
        nodes, meta, cpm = padded_tree(rng, chunk, max_nodes=6)
        q, k, v, g, beta = rand_inputs(rng, meta.size, 2, 4, 4)
        g, beta = transparent_pads(g, beta, meta.pad_mask.astype(np.float32))
        args = (*map(jnp.asarray, (q, k, v, g, beta)), jnp.asarray(cpm), chunk)
        o_a, st_a = gdn.gdn_tree_chunked(*args)
        o_b, st_b = gdn.gdn_tree_pallas(*args)
        np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_b), atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_a), np.asarray(st_b), atol=1e-5)

    def test_matches_recurrent(self):
        rng = np.random.default_rng(3)
        chunk = 4
        nodes, meta, cpm = padded_tree(rng, chunk)
        q, k, v, g, beta = rand_inputs(rng, meta.size, 2, 4, 6)
        g, beta = transparent_pads(g, beta, meta.pad_mask.astype(np.float32))
        o_ref = ref.gdn_recurrent_tree(q, k, v, g, beta, meta.node_start,
                                       meta.node_len, meta.node_parent)
        o, _ = gdn.gdn_tree_pallas(*map(jnp.asarray, (q, k, v, g, beta)),
                                   jnp.asarray(cpm), chunk)
        real = ~meta.pad_mask
        np.testing.assert_allclose(np.asarray(o)[real], np.asarray(o_ref)[real],
                                   atol=1e-4, rtol=1e-3)


class TestTreeConv:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([2, 3, 4]))
    def test_matches_per_path(self, seed, K):
        rng = np.random.default_rng(seed)
        nodes = treemeta.random_tree(rng, max_nodes=int(rng.integers(1, 12)))
        meta = treemeta.dfs_serialize(nodes)
        C = 5
        x = rng.standard_normal((meta.size, C)).astype(np.float32)
        w = rng.standard_normal((C, K)).astype(np.float32) * 0.3
        b = rng.standard_normal(C).astype(np.float32) * 0.1
        o_ref = ref.conv_per_path(x, w, b, meta, nodes)
        idx = gdn.conv_gather_indices(meta.node_start, meta.node_len,
                                      meta.node_parent, K)
        o = gdn.tree_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                          jnp.asarray(idx))
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=1e-5, rtol=1e-4)

    def test_pads_skipped_in_window(self):
        """Fig. 4: the conv window crosses node boundaries via the *path*,
        skipping alignment pads entirely."""
        rng = np.random.default_rng(2)
        K = 3
        nodes = treemeta.pad_nodes_for_chunks(
            [NodeSpec(-1, rng.integers(0, 9, 5)),
             NodeSpec(0, rng.integers(0, 9, 3)),
             NodeSpec(0, rng.integers(0, 9, 2))], 4)
        meta = treemeta.dfs_serialize(nodes)
        C = 4
        x = rng.standard_normal((meta.size, C)).astype(np.float32)
        w = rng.standard_normal((C, K)).astype(np.float32) * 0.3
        b = np.zeros(C, np.float32)
        o_ref = ref.conv_per_path(x, w, b, meta, nodes)
        idx = gdn.conv_gather_indices(meta.node_start, meta.node_len,
                                      meta.node_parent, K, pad_mask=meta.pad_mask)
        o = gdn.tree_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                          jnp.asarray(idx))
        real = ~meta.pad_mask
        np.testing.assert_allclose(np.asarray(o)[real], np.asarray(o_ref)[real],
                                   atol=1e-5)

    def test_gateway_ctx(self):
        """App. B.7 conv-context injection: child partition sees the parent's
        last K-1 effective tokens as left context."""
        rng = np.random.default_rng(8)
        K, C = 4, 3
        # chain: root(6) -> leaf(4); cut between them.
        nodes = [NodeSpec(-1, rng.integers(0, 9, 6)),
                 NodeSpec(0, rng.integers(0, 9, 4))]
        meta = treemeta.dfs_serialize(nodes)
        x = rng.standard_normal((meta.size, C)).astype(np.float32)
        w = rng.standard_normal((C, K)).astype(np.float32) * 0.3
        b = rng.standard_normal(C).astype(np.float32) * 0.1
        idx_full = gdn.conv_gather_indices(meta.node_start, meta.node_len,
                                           meta.node_parent, K)
        o_full = gdn.tree_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                               jnp.asarray(idx_full))
        # child partition: node 1 alone, ctx = last K-1 tokens of node 0
        ctx = jnp.asarray(x[3:6])
        idx_child = gdn.conv_gather_indices(
            np.array([0]), np.array([4]), np.array([-1]), K, has_ctx=True)
        o_child = gdn.tree_conv(jnp.asarray(x[6:]), jnp.asarray(w),
                                jnp.asarray(b), jnp.asarray(idx_child), ctx=ctx)
        np.testing.assert_allclose(np.asarray(o_child), np.asarray(o_full)[6:],
                                    atol=1e-6)
