"""Python mirror of the parallel-ingestion ordering protocol.

Mirrors ``rust/src/ingest/parallel.rs`` + the ``SessionLru`` in
``rust/src/ingest/stream.rs``: the router replays the *single-threaded*
LRU eviction schedule over session ids only, stamps every flush with a
global sequence number, shard workers receive their sessions' commands
over FIFO channels, and the merger releases flushes in sequence order —
so the emitted session order is bit-identical to the single-threaded
``SessionFolder`` at any thread count, no matter how shards' completions
interleave.

Determinism contract being mirrored:

* LRU: every touch takes a fresh monotonic stamp; eviction removes the
  minimum live stamp; end-of-corpus drain flushes in last-touch order.
* Sharding: FNV-1a(session) % threads — sessions never split, distinct
  sessions never merge.
* Merge: flushes re-sequenced by the router-assigned global seq, so
  out-of-order shard completion cannot reorder emission.
* Errors: the failure with the lowest corpus line wins, exactly as the
  single-threaded reader (which would have stopped there) reports it.
"""

import itertools
import random

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x0000010000000001B3
MASK64 = (1 << 64) - 1


def shard_of(session, threads):
    """FNV-1a, the stable session -> shard map of ingest/parallel.rs."""
    h = FNV_OFFSET
    for b in session.encode():
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h % threads


class SessionLru:
    """Deterministic LRU clock (stream.rs SessionLru, payload-free)."""

    def __init__(self, cap):
        assert cap > 0
        self.cap = cap
        self.tick = 0
        self.stamp = {}  # session -> last-touch stamp

    def touch(self, session):
        """Returns the evicted session when a new one exceeds capacity."""
        if session in self.stamp:
            self.tick += 1
            self.stamp[session] = self.tick
            return None
        evicted = None
        if len(self.stamp) == self.cap:
            evicted = min(self.stamp, key=self.stamp.get)
            del self.stamp[evicted]
        self.tick += 1
        self.stamp[session] = self.tick
        return evicted

    def drain(self):
        """Close every open session in last-touch order."""
        out = sorted(self.stamp, key=self.stamp.get)
        self.stamp.clear()
        return out


def single_thread_flush_order(sessions, cap):
    """SessionFolder's flush schedule: evictions, then the finish drain."""
    lru = SessionLru(cap)
    order = []
    for s in sessions:
        ev = lru.touch(s)
        if ev is not None:
            order.append(ev)
    order.extend(lru.drain())
    return order


def parallel_flush_order(sessions, cap, threads, completion_rng):
    """The router/worker/merger protocol with adversarial completion.

    The router replays the identical LRU over session ids, assigning each
    flush a global seq and dispatching it to its owner shard's FIFO queue.
    Shards then *complete* their queued flushes in an arbitrary
    interleaving (only per-shard FIFO is guaranteed); the merger buffers
    by seq and releases in global order.
    """
    lru = SessionLru(cap)
    shard_q = [[] for _ in range(threads)]
    seq = 0
    for s in sessions:
        ev = lru.touch(s)
        if ev is not None:
            shard_q[shard_of(ev, threads)].append((seq, ev))
            seq += 1
    for s in lru.drain():
        shard_q[shard_of(s, threads)].append((seq, s))
        seq += 1

    # adversarial completion: interleave shard queues randomly (FIFO
    # within a shard), then re-sequence like the merger does
    heads = [0] * threads
    completed = []
    while any(heads[i] < len(shard_q[i]) for i in range(threads)):
        live = [i for i in range(threads) if heads[i] < len(shard_q[i])]
        i = completion_rng.choice(live)
        completed.append(shard_q[i][heads[i]])
        heads[i] += 1

    pending = {}
    out = []
    next_seq = 0
    for sq, s in completed:
        pending[sq] = s
        while next_seq in pending:
            out.append(pending.pop(next_seq))
            next_seq += 1
    assert not pending
    return out


def interleaved_stream(n_sessions, runs, group, rng):
    """Round-robin `group` sessions at a time (record.interleave_sessions)."""
    per = [[f"sess-{i}"] * rng.randint(1, runs) for i in range(n_sessions)]
    out = []
    for g in range(0, n_sessions, group):
        chunk = [list(p) for p in per[g : g + group]]
        for r in itertools.zip_longest(*chunk):
            out.extend(s for s in r if s is not None)
    return out


def test_fnv_shard_is_stable_and_total():
    assert shard_of("", 7) == FNV_OFFSET % 7
    # must not vary run to run, must cover [0, threads)
    for threads in (1, 2, 4, 7):
        shards = {shard_of(f"sess-{i}", threads) for i in range(64)}
        assert all(0 <= s < threads for s in shards)
        assert shard_of("sess-3", threads) == shard_of("sess-3", threads)
    assert shard_of("a", 1) == 0


def test_lru_eviction_is_least_recent_and_drain_is_last_touch():
    lru = SessionLru(2)
    assert lru.touch("a") is None
    assert lru.touch("b") is None
    assert lru.touch("a") is None  # refresh: b is now least recent
    assert lru.touch("c") == "b"
    assert lru.drain() == ["a", "c"]


def test_parallel_order_matches_single_thread_for_all_thread_counts():
    rng = random.Random(11)
    for trial in range(40):
        stream = interleaved_stream(
            n_sessions=rng.randint(2, 12),
            runs=5,
            group=rng.randint(1, 5),
            rng=rng,
        )
        cap = rng.randint(1, 4)
        want = single_thread_flush_order(stream, cap)
        for threads in (1, 2, 4, 7):
            got = parallel_flush_order(stream, cap, threads, random.Random(trial))
            assert got == want, (trial, threads, cap, stream)


def test_reopened_session_flushes_twice_in_both_schedules():
    # a b c evicts a (cap 2); a's reopen must flush as a *new* instance
    stream = ["a", "b", "c", "a", "a"]
    want = single_thread_flush_order(stream, 2)
    assert want.count("a") == 2
    got = parallel_flush_order(stream, 2, 4, random.Random(0))
    assert got == want


def test_lowest_line_error_wins():
    # parallel.rs: parse errors are detected in re-sequenced batch order,
    # late fold errors are min-merged during the drain — the reported
    # failure is always the one the single-threaded reader hits first
    errors = [(42, "late"), (7, "early"), (19, "mid")]
    best = None
    for line, err in errors:
        if best is None or line < best[0]:
            best = (line, err)
    assert best == (7, "early")


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
