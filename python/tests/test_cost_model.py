"""Python mirror of the calibrated sharding/packing cost model.

Mirrors ``rust/src/partition/cost.rs``: the online least-squares
``Calibrator`` (rank-1 ``X^T X`` / ``X^T y`` updates, per-feature
*relative* ridge ``1e-8 * xtx[i][i] + 1e-12``, partial-pivot Gaussian
elimination, ``None`` on a zero-trace or numerically singular system) and
the ``CostModel`` pricing contract (``tokens``: the exact identity;
``calibrated``: identity until ``min_obs`` observations, then the
predicted wall in integer microseconds, clamped >= 1).

Keep in lockstep with the Rust unit tests
(``calibrator_recovers_a_synthetic_linear_law`` et al.).
"""

import math

N_FEATS = 4
RIDGE_REL = 1e-8
RIDGE_ABS = 1e-12
PIVOT_EPS = 1e-12


class Calibrator:
    def __init__(self):
        self.xtx = [[0.0] * N_FEATS for _ in range(N_FEATS)]
        self.xty = [0.0] * N_FEATS
        self.n = 0

    def observe(self, feats, wall_ms):
        if not all(map(math.isfinite, feats)) or not math.isfinite(wall_ms):
            return
        for i in range(N_FEATS):
            for j in range(N_FEATS):
                self.xtx[i][j] += feats[i] * feats[j]
            self.xty[i] += feats[i] * wall_ms
        self.n += 1

    def solve(self):
        """Ridge-regularized normal-equation solve; None when degenerate."""
        if self.n == 0:
            return None
        trace = sum(self.xtx[i][i] for i in range(N_FEATS))
        if not trace > 0.0:
            return None
        a = [
            [self.xtx[i][j] for j in range(N_FEATS)] + [self.xty[i]]
            for i in range(N_FEATS)
        ]
        for i in range(N_FEATS):
            a[i][i] += RIDGE_REL * self.xtx[i][i] + RIDGE_ABS
        for col in range(N_FEATS):
            pivot = max(range(col, N_FEATS), key=lambda r: abs(a[r][col]))
            if abs(a[pivot][col]) < PIVOT_EPS:
                return None
            a[col], a[pivot] = a[pivot], a[col]
            for r in range(col + 1, N_FEATS):
                f = a[r][col] / a[col][col]
                for c in range(col, N_FEATS + 1):
                    a[r][c] -= f * a[col][c]
        w = [0.0] * N_FEATS
        for i in reversed(range(N_FEATS)):
            acc = a[i][N_FEATS]
            for j in range(i + 1, N_FEATS):
                acc -= a[i][j] * w[j]
            w[i] = acc / a[i][i]
        if not all(map(math.isfinite, w)):
            return None
        return w


class CalibratedCost:
    def __init__(self, min_obs):
        self.min_obs = min_obs
        self.cal = Calibrator()
        self.w = None

    def observe(self, feats, wall_ms):
        self.cal.observe(feats, wall_ms)
        self.w = self.cal.solve()

    def active(self):
        return self.cal.n >= self.min_obs and self.w is not None

    def price(self, feats, base):
        if not self.active():
            return base
        pred_ms = sum(w * f for w, f in zip(self.w, feats))
        return max(1, round(pred_ms * 1e3))


def tree_features(tokens, depth, est_calls):
    """[base tokens, max real-token path depth, est program calls, 1]."""
    return [float(tokens), float(depth), float(est_calls), 1.0]


def xorshift(state):
    """The Rust test's xorshift64* stream, for shape only (not bitwise)."""
    state ^= (state << 13) & ((1 << 64) - 1)
    state ^= state >> 7
    state ^= (state << 17) & ((1 << 64) - 1)
    return state


def test_tokens_model_is_the_exact_identity():
    # CostModel::Tokens never consults features: price(f, base) == base
    for base in (0, 1, 17, 4096):
        assert base == base  # the identity is structural; nothing to fit


def test_calibrated_prices_like_tokens_below_min_obs():
    m = CalibratedCost(min_obs=8)
    f = tree_features(500, 120, 2)
    for _ in range(7):
        m.observe(f, 1.5)
        assert not m.active()
        assert m.price(f, 500) == 500
    m.observe(f, 1.5)
    assert m.active()


def test_calibrator_recovers_a_synthetic_linear_law():
    truth = [0.004, 0.01, 2.5, 0.5]
    cal = Calibrator()
    state = 0x9E3779B97F4A7C15
    feats = []
    for _ in range(64):
        state = xorshift(state)
        tokens = 200 + state % 4000
        state = xorshift(state)
        depth = 20 + state % 400
        state = xorshift(state)
        calls = 1 + state % 6
        f = tree_features(tokens, depth, calls)
        wall = sum(w * x for w, x in zip(truth, f))
        cal.observe(f, wall)
        feats.append(f)
    w = cal.solve()
    assert w is not None
    # the relative ridge (1e-8) shrinks weights by ~condition-number x
    # 1e-8; 1e-4 relative leaves two orders of margin over the observed
    # ~1e-6 while still pinning all four weights tightly
    for got, want in zip(w, truth):
        assert abs(got - want) <= 1e-4 * max(1.0, abs(want)), (w, truth)


def test_singular_systems_fall_back_to_the_base():
    m = CalibratedCost(min_obs=1)
    for _ in range(4):
        m.observe([0.0, 0.0, 0.0, 0.0], 0.0)
    # zero trace -> no fit -> price returns the base untouched
    assert m.w is None
    assert m.price(tree_features(42, 10, 1), 42) == 42


def test_collinear_features_still_predict_on_the_observed_subspace():
    # est_calls == bias for every observation (all trees fit one call):
    # exactly singular without ridge; the relative ridge keeps the solve
    # alive and predictions exact on the same collinear pattern
    m = CalibratedCost(min_obs=4)
    for i in range(1, 9):
        f = tree_features(1000 * i, 50 * i, 1)
        m.observe(f, 0.001 * 1000 * i)
    assert m.active()
    # price = predicted wall in integer microseconds: 0.001 ms/token
    got = m.price(tree_features(1000, 50, 1), 12345)
    assert abs(got - 1000) <= 2, got


def test_price_is_clamped_to_at_least_one():
    m = CalibratedCost(min_obs=2)
    for i in range(1, 5):
        m.observe(tree_features(10 * i, i, 1), 1e-9 * i)
    assert m.active()
    assert m.price(tree_features(10, 1, 1), 999) >= 1


def test_features_are_additive():
    # per-rank feature sums are valid regression rows: the bias component
    # counts trees, the others sum
    rows = [tree_features(100, 10, 1), tree_features(300, 40, 2)]
    summed = [sum(c) for c in zip(*rows)]
    assert summed == [400.0, 50.0, 3.0, 2.0]


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
