"""Pallas tree-attention kernel vs per-branch oracles (Eq. 6 forward
equivalence + backward match), incl. the gateway (partition) case."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import treemeta
from compile.kernels import ref
from compile.kernels import tree_attention as ta
from compile.treemeta import NodeSpec

FWD_TOL = 1e-5
BWD_TOL = 1e-4


def rand_qkv(rng, S, H, D):
    q = rng.standard_normal((S, H, D)).astype(np.float32)
    k = rng.standard_normal((S, H, D)).astype(np.float32)
    v = rng.standard_normal((S, H, D)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def run_kernel(q, k, v, meta, **kw):
    q_exit, k_order, k_exit, k_bias = ta.whole_tree_meta(meta.subtree_exit)
    return ta.tree_attention(q, k, v, q_exit, k_order, k_exit, k_bias, **kw)


class TestForwardEquivalence:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000))
    def test_matches_per_path(self, seed):
        """Eq. 6: every token's output equals its standalone per-path value."""
        rng = np.random.default_rng(seed)
        nodes = treemeta.random_tree(rng, max_nodes=int(rng.integers(1, 14)))
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 2, 8)
        o_ref = ref.attention_per_path(q, k, v, meta, nodes)
        o_ker = run_kernel(q, k, v, meta)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=FWD_TOL, rtol=1e-4)

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000),
           st.sampled_from([(1, 4), (3, 16), (4, 32)]))
    def test_shape_sweep(self, seed, hd):
        H, D = hd
        rng = np.random.default_rng(seed)
        nodes = treemeta.random_tree(rng, max_nodes=8, max_seg=9)
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, H, D)
        o_ref = ref.attention_dense_mask(q, k, v, jnp.asarray(treemeta.dense_tree_mask(meta)))
        o_ker = run_kernel(q, k, v, meta)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=FWD_TOL, rtol=1e-4)

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([4, 16, 64, 128]))
    def test_block_size_invariance(self, seed, blk):
        """Output must not depend on the kernel block decomposition."""
        rng = np.random.default_rng(seed)
        nodes = treemeta.random_tree(rng, max_nodes=10, max_seg=8)
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 2, 8)
        o_a = run_kernel(q, k, v, meta, block_q=blk, block_k=blk)
        o_b = run_kernel(q, k, v, meta, block_q=ta.DEFAULT_BLOCK_Q)
        np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_b), atol=1e-5)

    def test_chain_tree_is_causal_attention(self):
        """A chain (single path) must reduce to plain causal attention."""
        rng = np.random.default_rng(0)
        nodes = [NodeSpec(-1, rng.integers(0, 9, 5)),
                 NodeSpec(0, rng.integers(0, 9, 4)),
                 NodeSpec(1, rng.integers(0, 9, 3))]
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 2, 8)
        causal = np.tril(np.ones((meta.size, meta.size), dtype=bool))
        o_ref = ref.attention_dense_mask(q, k, v, jnp.asarray(causal))
        o_ker = run_kernel(q, k, v, meta)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref), atol=FWD_TOL)

    def test_packed_forest_blocks_cross_segment(self):
        """Sequence packing as a forest-of-chains: segments must not attend
        each other (Krell et al. packing without cross-contamination)."""
        rng = np.random.default_rng(0)
        # emulate a 2-segment pack: exit vectors end each segment
        s1, s2 = 6, 10
        exits = np.concatenate([np.full(s1, s1, np.int32),
                                np.full(s2, s1 + s2, np.int32)])
        S = s1 + s2
        q, k, v = rand_qkv(rng, S, 2, 8)
        q_exit, k_order, k_exit, k_bias = ta.whole_tree_meta(exits)
        o = ta.tree_attention(q, k, v, q_exit, k_order, k_exit, k_bias)
        # segment 2 output must equal standalone attention over segment 2
        causal = np.tril(np.ones((s2, s2), dtype=bool))
        o2 = ref.attention_dense_mask(q[s1:], k[s1:], v[s1:], jnp.asarray(causal))
        np.testing.assert_allclose(np.asarray(o[s1:]), np.asarray(o2), atol=FWD_TOL)

    def test_padded_tree(self):
        rng = np.random.default_rng(5)
        nodes = treemeta.pad_nodes_for_chunks(
            treemeta.random_tree(rng, max_nodes=7), 4)
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 2, 8)
        o_ref = ref.attention_dense_mask(q, k, v, jnp.asarray(treemeta.dense_tree_mask(meta)))
        o_ker = run_kernel(q, k, v, meta)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref), atol=FWD_TOL)


class TestBackward:
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 10_000))
    def test_grads_match_dense_reference(self, seed):
        rng = np.random.default_rng(seed)
        nodes = treemeta.random_tree(rng, max_nodes=int(rng.integers(1, 12)))
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 2, 8)
        mask = jnp.asarray(treemeta.dense_tree_mask(meta))
        w = jnp.asarray(rng.standard_normal((meta.size, 2, 8)).astype(np.float32))

        def loss_ker(q, k, v):
            return jnp.sum(w * run_kernel(q, k, v, meta))

        def loss_ref(q, k, v):
            return jnp.sum(w * ref.attention_dense_mask(q, k, v, mask))

        g1 = jax.grad(loss_ker, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=BWD_TOL, rtol=1e-3)

    def test_prefix_grads_aggregate_branches(self):
        """The gradient of a shared-prefix token must sum contributions from
        all branches through it — the property plain prefix caching lacks."""
        rng = np.random.default_rng(1)
        nodes = [NodeSpec(-1, rng.integers(0, 9, 4)),
                 NodeSpec(0, rng.integers(0, 9, 3)),
                 NodeSpec(0, rng.integers(0, 9, 3))]
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 1, 4)

        def branch_loss(v_, sel):
            o = run_kernel(q, k, v_, meta)
            w = np.zeros(meta.size, np.float32)
            w[meta.node_start[sel]:meta.node_start[sel] + meta.node_len[sel]] = 1
            return jnp.sum(jnp.asarray(w)[:, None, None] * o)

        g_b1 = jax.grad(lambda v_: branch_loss(v_, 1))(v)
        g_b2 = jax.grad(lambda v_: branch_loss(v_, 2))(v)
        g_all = jax.grad(lambda v_: branch_loss(v_, 1) + branch_loss(v_, 2))(v)
        np.testing.assert_allclose(np.asarray(g_all), np.asarray(g_b1 + g_b2),
                                   atol=1e-5)
        # and the prefix (root node keys) really receives grad from both
        root = slice(0, meta.node_len[0])
        assert np.abs(np.asarray(g_b1)[root]).sum() > 0
        assert np.abs(np.asarray(g_b2)[root]).sum() > 0


class TestGateway:
    def test_child_partition_matches_unsplit(self):
        """Child-partition attention over gateway KV == unsplit tree attention
        (App. B.2/B.3 forward)."""
        rng = np.random.default_rng(2)
        # tree: root(4) -> [a(3) -> b(2), c(3)]; cut below node a.
        nodes = [NodeSpec(-1, rng.integers(0, 9, 4)),
                 NodeSpec(0, rng.integers(0, 9, 3)),
                 NodeSpec(1, rng.integers(0, 9, 2)),
                 NodeSpec(0, rng.integers(0, 9, 3))]
        meta = treemeta.dfs_serialize(nodes)
        S = meta.size
        q, k, v = rand_qkv(rng, S, 2, 8)
        o_full = run_kernel(q, k, v, meta)

        # child partition = node b's tokens (slots 7..9); gateway = slots 0..6
        # (root + a: all ancestors of b — no sibling filtering needed here)
        cs, ce = meta.node_start[2], meta.node_start[2] + meta.node_len[2]
        past = ce - (ce - cs) - 0  # = cs
        qc = q[cs:ce]
        # child-local tree: single chain node of len 2 -> exit = 2
        child_exit = jnp.asarray(np.full(ce - cs, ce - cs, np.int32))
        k_all = jnp.concatenate([k[:cs], k[cs:ce]])
        v_all = jnp.concatenate([v[:cs], v[cs:ce]])
        q_exit, k_order, k_exit, k_bias = ta.whole_tree_meta(
            np.asarray(child_exit), past_len=cs)
        o_child = ta.tree_attention(qc, k_all, v_all, q_exit, k_order, k_exit, k_bias)
        np.testing.assert_allclose(np.asarray(o_child), np.asarray(o_full[cs:ce]),
                                   atol=FWD_TOL)

    def test_ancestor_bias_blocks_siblings(self):
        """Eq. 16: gateway slice containing sibling tokens must be filtered."""
        rng = np.random.default_rng(4)
        # root(3) -> [s1(2), s2(2) -> leaf(2)]; partition P = {root, s1, s2},
        # child partition = {leaf}; gateway slice includes s1 (NOT an ancestor).
        nodes = [NodeSpec(-1, rng.integers(0, 9, 3)),
                 NodeSpec(0, rng.integers(0, 9, 2)),
                 NodeSpec(0, rng.integers(0, 9, 2)),
                 NodeSpec(2, rng.integers(0, 9, 2))]
        meta = treemeta.dfs_serialize(nodes)
        q, k, v = rand_qkv(rng, meta.size, 2, 8)
        o_full = run_kernel(q, k, v, meta)

        ls, le = meta.node_start[3], meta.node_start[3] + meta.node_len[3]
        qc = q[ls:le]
        child_exit = np.full(le - ls, le - ls, np.int32)
        # bias: 0 on root(0..2) and s2(5..6), -inf on s1(3..4)
        bias = np.zeros(ls, np.float32)
        bias[3:5] = ta.NEG_INF
        q_exit, k_order, k_exit, k_bias = ta.whole_tree_meta(
            child_exit, past_len=ls, past_bias=jnp.asarray(bias))
        o_child = ta.tree_attention(qc, k[:le], v[:le],
                                    q_exit, k_order, k_exit, k_bias)
        np.testing.assert_allclose(np.asarray(o_child), np.asarray(o_full[ls:le]),
                                   atol=FWD_TOL)

    def test_gateway_grads_flow(self):
        """d(child loss)/d(gateway KV) is nonzero only at visible slots."""
        rng = np.random.default_rng(6)
        S_child, A = 4, 6
        q = jnp.asarray(rng.standard_normal((S_child, 1, 4)).astype(np.float32))
        kc = jnp.asarray(rng.standard_normal((S_child, 1, 4)).astype(np.float32))
        vc = jnp.asarray(rng.standard_normal((S_child, 1, 4)).astype(np.float32))
        k_past = jnp.asarray(rng.standard_normal((A, 1, 4)).astype(np.float32))
        v_past = jnp.asarray(rng.standard_normal((A, 1, 4)).astype(np.float32))
        bias = np.zeros(A, np.float32)
        bias[2:4] = ta.NEG_INF  # blocked sibling slots
        child_exit = np.full(S_child, S_child, np.int32)
        q_exit, k_order, k_exit, k_bias = ta.whole_tree_meta(
            child_exit, past_len=A, past_bias=jnp.asarray(bias))

        def loss(k_past, v_past):
            o = ta.tree_attention(q, jnp.concatenate([k_past, kc]),
                                  jnp.concatenate([v_past, vc]),
                                  q_exit, k_order, k_exit, k_bias)
            return jnp.sum(o ** 2)

        gk, gv = jax.grad(loss, argnums=(0, 1))(k_past, v_past)
        gk, gv = np.asarray(gk), np.asarray(gv)
        assert np.abs(gk[2:4]).max() == 0 and np.abs(gv[2:4]).max() == 0
        assert np.abs(gk[:2]).max() > 0 and np.abs(gv[4:]).max() > 0
