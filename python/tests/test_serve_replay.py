"""Python mirror of the serve admission policy (``rust/src/serve/``).

Mirrors ``live.rs`` (the ripeness state machine) and ``source.rs`` (FIFO
ripe queue, fold credits, bounded-staleness cut path) *without* the trie
or the trainer: trees are stood in for by record counts, because every
claim under test is about **ordering**, not content.

Three properties, each of which the Rust replay gate relies on:

1. **Verdict order** — within one fold: end-marker flush, then LRU
   evictions (ascending last-touch), then idle flushes (ascending
   last-touch, stop at the first in-window session).  Quiesce flushes
   ascending last-touch.  End markers for unknown sessions are no-ops.
2. **Cut-composition invariance** — the ripe sequence is a pure function
   of arrival order, so batch composition depends only on
   ``(arrival order, trees_per_batch)``: an eager pump (fold to the cap
   before every cut) and a lazy pump (fold the bare minimum per cut)
   produce identical cut compositions on adversarial interleavings.
   This is the theorem that makes the journal sufficient for bit-exact
   replay: recording arrival order pins batch composition.
3. **Bounded staleness** — with ``ripe_cap = K * trees_per_batch`` and an
   eager pump, no entry waits more than ``K`` cuts between ripening and
   entering a batch (one session flush may overshoot the cap by
   ``flush_size - 1``, which the bound absorbs — same check as
   ``source.rs``).

Run directly: ``python3 python/tests/test_serve_replay.py`` (no pytest,
no jax).  Keep in lockstep with the Rust unit tests in ``live.rs`` /
``source.rs`` and ``rust/tests/serve_replay.rs``.
"""

import itertools
import random

END, REC = "end", "rec"


class Folder:
    """Mirror of ``live.rs::LiveFolder`` with record counts for trees."""

    def __init__(self, max_open, idle_timeout):
        assert max_open >= 1
        self.max_open = max_open
        self.idle_timeout = idle_timeout
        self.open = {}  # session -> [n_records, last_seq]
        self.by_touch = {}  # last_seq -> session (unique: one touch per seq)

    def _flush(self, session, reason):
        n, last = self.open.pop(session)
        del self.by_touch[last]
        return (session, reason, n)

    def fold(self, seq, kind, session):
        out = []
        if kind == END:
            if session in self.open:
                out.append(self._flush(session, "end"))
        else:
            if session in self.open:
                s = self.open[session]
                del self.by_touch[s[1]]
                s[0] += 1
                s[1] = seq
            else:
                self.open[session] = [1, seq]
            self.by_touch[seq] = session
            while len(self.open) > self.max_open:
                victim = self.by_touch[min(self.by_touch)]
                out.append(self._flush(victim, "lru"))
        if self.idle_timeout > 0:
            while self.by_touch:
                last = min(self.by_touch)
                if seq - last <= self.idle_timeout:
                    break
                out.append(self._flush(self.by_touch[last], "idle"))
        return out

    def quiesce(self):
        order = [self.by_touch[k] for k in sorted(self.by_touch)]
        return [self._flush(s, "quiesce") for s in order]


def ripe_sequence(arrivals, max_open=64, idle_timeout=0):
    """Fold a whole arrival list; flat list of (session, reason, n)."""
    f = Folder(max_open, idle_timeout)
    out = []
    for seq, (kind, session) in enumerate(arrivals, start=1):
        out.extend(f.fold(seq, kind, session))
    out.extend(f.quiesce())
    return out


class Source:
    """Mirror of ``source.rs::LiveSource``: fold credits + FIFO cuts.

    ``eager=True`` folds until the ripe queue reaches ``ripe_cap`` before
    every cut (the live pump); ``eager=False`` folds only until one batch
    can be cut (maximal laziness).  Composition must not depend on this.
    """

    def __init__(self, arrivals, cfg, eager):
        self.arrivals = list(arrivals)
        self.cfg = cfg
        self.eager = eager
        self.folder = Folder(cfg["max_open"], cfg["idle_timeout"])
        self.ripe = []  # FIFO of (session, reason, n, ripe_cut)
        self.seq = 0
        self.cuts = 0
        self.max_staleness = 0
        self.drained = False

    def _pump(self, need):
        while not self.drained:
            if self.eager:
                if len(self.ripe) >= self.cfg["ripe_cap"]:
                    return
            elif len(self.ripe) >= need:
                return
            if self.seq == len(self.arrivals):
                self.drained = True
                for g in self.folder.quiesce():
                    self.ripe.append(g + (self.cuts,))
                return
            kind, session = self.arrivals[self.seq]
            self.seq += 1
            for g in self.folder.fold(self.seq, kind, session):
                self.ripe.append(g + (self.cuts,))
            if not self.eager and len(self.ripe) >= need:
                return

    def cut(self, n):
        self._pump(n)
        if len(self.ripe) < n:
            return None  # spool exhausted mid-batch
        batch = self.ripe[:n]
        del self.ripe[:n]
        for (_, _, _, ripe_cut) in batch:
            stale = self.cuts - ripe_cut
            self.max_staleness = max(self.max_staleness, stale)
            assert stale <= self.cfg["staleness_bound"], (
                f"bounded-staleness contract violated: {stale} > "
                f"{self.cfg['staleness_bound']}"
            )
        self.cuts += 1
        return [(s, r, cnt) for (s, r, cnt, _) in batch]


def run_cuts(arrivals, cfg, eager):
    src = Source(arrivals, cfg, eager)
    out = []
    while True:
        b = src.cut(cfg["tpb"])
        if b is None:
            return out, src.max_staleness
        out.append(b)


def adversarial_arrivals(seed, n_sessions=12, avg_records=4):
    """Randomly interleaved sessions with hostile marker placement:
    ends before any record, double ends, ends for unknown sessions,
    post-end revivals (a new session instance under the same name)."""
    r = random.Random(seed)
    events = []
    for s in range(n_sessions):
        name = f"s{s:02d}"
        recs = [(REC, name)] * r.randint(1, 2 * avg_records)
        style = r.random()
        if style < 0.25:
            recs.append((END, name))  # well-behaved
        elif style < 0.45:
            recs += [(END, name), (END, name)]  # double end
        elif style < 0.6:
            recs.insert(0, (END, name))  # end before any record
        elif style < 0.75:
            cut = r.randint(1, len(recs))
            recs.insert(cut, (END, name))  # end mid-stream, then revival
        # else: no end marker at all (flushes via LRU/idle/quiesce)
        events.append(recs)
    for _ in range(3):
        events.append([(END, f"ghost{r.randint(0, 9)}")])  # never-seen ends
    out = []
    live = [e for e in events if e]
    while live:
        pick = r.randrange(len(live))
        out.append(live[pick].pop(0))
        if not live[pick]:
            live.pop(pick)
    return out


# ---------------------------------------------------------------- policy


def test_end_marker_flushes_and_unknown_end_is_noop():
    f = Folder(8, 0)
    assert f.fold(1, REC, "a") == []
    assert f.fold(2, REC, "a") == []
    assert f.fold(3, END, "a") == [("a", "end", 2)]
    assert f.fold(4, END, "a") == []  # double end: no-op
    assert f.fold(5, END, "ghost") == []  # never seen: no-op
    assert f.quiesce() == []


def test_lru_evicts_least_recently_touched():
    f = Folder(2, 0)
    f.fold(1, REC, "a")
    f.fold(2, REC, "b")
    f.fold(3, REC, "a")  # refreshes a: b is now oldest
    assert f.fold(4, REC, "c") == [("b", "lru", 1)]
    assert sorted(f.open) == ["a", "c"]


def test_idle_timeout_in_fold_steps_and_zero_disables():
    f = Folder(8, 2)
    f.fold(1, REC, "a")
    assert f.fold(2, REC, "b") == []
    assert f.fold(3, REC, "b") == []  # seq-last("a")=2, not > 2: in window
    assert f.fold(4, REC, "b") == [("a", "idle", 1)]
    g = Folder(8, 0)
    g.fold(1, REC, "a")
    for seq in range(2, 50):
        assert g.fold(seq, REC, "b") == []  # 0 disables idle flushing


def test_verdict_order_lru_before_idle_in_one_fold():
    # mirror of live.rs::one_fold_orders_lru_before_idle
    f = Folder(2, 3)
    f.fold(1, REC, "idle1")
    f.fold(2, REC, "keep")
    out = f.fold(6, REC, "new")  # overflows max_open AND ages both out
    assert out == [("idle1", "lru", 1), ("keep", "idle", 1)]


def test_quiesce_flushes_in_touch_order_and_is_idempotent():
    f = Folder(8, 0)
    f.fold(1, REC, "b")
    f.fold(2, REC, "a")
    f.fold(3, REC, "b")  # b touched last
    assert f.quiesce() == [("a", "quiesce", 1), ("b", "quiesce", 2)]
    assert f.quiesce() == []


def test_revival_after_flush_is_a_fresh_session_instance():
    f = Folder(8, 0)
    f.fold(1, REC, "a")
    f.fold(2, END, "a")
    assert f.fold(3, REC, "a") == []  # reopened, count restarts
    assert f.fold(4, END, "a") == [("a", "end", 1)]


def test_every_record_flushed_exactly_once():
    for seed in range(20):
        arrivals = adversarial_arrivals(seed)
        n_records = sum(1 for k, _ in arrivals if k == REC)
        for max_open, idle in [(64, 0), (4, 0), (64, 5), (3, 4)]:
            groups = ripe_sequence(arrivals, max_open, idle)
            assert sum(n for _, _, n in groups) == n_records, (seed, max_open, idle)


# ------------------------------------------------- composition invariance


def test_cut_composition_independent_of_pump_interleaving():
    cfg = {"max_open": 6, "idle_timeout": 0, "tpb": 3,
           "staleness_bound": 4, "ripe_cap": 12}
    for seed in range(25):
        arrivals = adversarial_arrivals(seed)
        eager, _ = run_cuts(arrivals, cfg, eager=True)
        lazy, _ = run_cuts(arrivals, cfg, eager=False)
        assert eager == lazy, f"composition diverged on seed {seed}"


def test_composition_is_replayable_from_arrival_order_alone():
    # shuffling *when* folds happen (pump strategy) never changes what is
    # cut; shuffling the *arrival order itself* does — the journal records
    # exactly the part that matters.
    cfg = {"max_open": 8, "idle_timeout": 3, "tpb": 2,
           "staleness_bound": 8, "ripe_cap": 16}
    arrivals = adversarial_arrivals(7)
    reference, _ = run_cuts(arrivals, cfg, eager=True)
    replay, _ = run_cuts(list(arrivals), cfg, eager=False)
    assert replay == reference
    swapped = list(arrivals)
    i = next(k for k in range(len(swapped) - 1)
             if swapped[k][1] != swapped[k + 1][1]
             and swapped[k][0] == REC and swapped[k + 1][0] == REC)
    swapped[i], swapped[i + 1] = swapped[i + 1], swapped[i]
    tampered, _ = run_cuts(swapped, cfg, eager=True)
    # not guaranteed to differ for *every* swap, but this generator's
    # sessions are LRU/idle-sensitive enough that it must here
    assert tampered != reference, "swap of two sessions' records went unnoticed"


# ------------------------------------------------------ bounded staleness


def test_staleness_bounded_by_k_with_default_cap():
    # the by-construction bound covers steady-state ripening (end / LRU /
    # idle verdicts, folded one credit at a time under the cap check); the
    # producer contract (docs/serve.md) therefore requires end markers —
    # a shutdown quiesce of many never-ended sessions floods the queue in
    # one fold and is exactly the case the cut path's hard error catches
    for k, tpb in itertools.product([1, 2, 4], [1, 2, 3]):
        cfg = {"max_open": 64, "idle_timeout": 0, "tpb": tpb,
               "staleness_bound": k, "ripe_cap": k * tpb}
        for seed in range(10):
            arrivals = adversarial_arrivals(seed, n_sessions=16)
            names = {s for _, s in arrivals}
            arrivals += [(END, s) for s in sorted(names)]  # all ended
            _, max_stale = run_cuts(arrivals, cfg, eager=True)
            assert max_stale <= k, (k, tpb, seed, max_stale)


def test_quiesce_flood_of_unended_sessions_trips_the_hard_error():
    cfg = {"max_open": 64, "idle_timeout": 0, "tpb": 1,
           "staleness_bound": 1, "ripe_cap": 1}
    arrivals = [(REC, f"s{s}") for s in range(6)]  # nobody ever ends
    try:
        run_cuts(arrivals, cfg, eager=True)
    except AssertionError as e:
        assert "bounded-staleness contract violated" in str(e)
    else:
        raise AssertionError("quiesce flood must violate a depth-1 bound")


def test_staleness_actually_reaches_the_bound():
    # the bound must be tight, not vacuous: with a deep cap and eager
    # pumping, early-ripened sessions genuinely wait
    cfg = {"max_open": 64, "idle_timeout": 0, "tpb": 1,
           "staleness_bound": 4, "ripe_cap": 4}
    arrivals = []
    for s in range(12):
        arrivals += [(REC, f"s{s}"), (END, f"s{s}")]
    _, max_stale = run_cuts(arrivals, cfg, eager=True)
    assert max_stale >= 2, f"bound never exercised (max {max_stale})"


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_"):
            fn()
            print(f"{name} OK")
