"""Pallas tree-attention kernel (paper §3.2 + Appendix A.1, FlashMask-style).

The tree attention mask on a DFS-serialized trajectory tree ("query i attends
key j iff j <= i and node(j) is an ancestor-or-self of node(i)") reduces to an
interval test on O(S) integer metadata (DESIGN.md §2):

    mask[i, j] = (k_order[j] <= i)  AND  (k_exit[j] >= q_exit[i])

plus an additive per-key bias ``k_bias`` used for (a) gateway ancestor
filtering at partition boundaries (App. B.3, Eq. 16) and (b) masking padded
key slots.  The same kernel therefore serves:

  * whole-tree DFS attention          (k_order = iota, k_exit = subtree_exit)
  * packed-linear baseline attention  (each packed segment = a chain tree)
  * child-partition attention over a gateway KV prefix
    (past keys: k_order = -1, k_exit = INT32_MAX, k_bias from Eq. 16)

Layout convention: q [S, H, D]; k, v [T, H, D] with T = A + S (A = gateway
length, 0 when none).  All metadata is host-computed (Rust serializer).

Hardware adaptation (DESIGN.md §4): on TPU the per-block min/max exit test is
the FlashMask block-skip; here each KV block is wrapped in ``lax.cond`` so the
skip survives in the lowered HLO.  ``interpret=True`` everywhere — CPU PJRT
cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30
PAST_EXIT = np.int32(2**31 - 1)

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _pick_block(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (kernel block size)."""
    b = min(n, pref)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(q_exit_ref, k_order_ref, k_exit_ref, k_bias_ref,
                q_ref, k_ref, v_ref, o_ref, lse_ref,
                *, sm_scale, block_q, block_k, kv_len, past_len):
    qb = pl.program_id(1)
    q = q_ref[0]                                   # [bq, D]
    q_exit = q_exit_ref[...]                       # [bq] i32
    qi = qb * block_q + jax.lax.iota(jnp.int32, block_q)
    q_exit_min = jnp.min(q_exit)
    q_max = qb * block_q + (block_q - 1)

    bq, d = q.shape
    m = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((bq,), dtype=jnp.float32)
    acc = jnp.zeros((bq, d), dtype=jnp.float32)

    for kb in range(kv_len // block_k):
        ks = kb * block_k
        k_order = k_order_ref[ks:ks + block_k]
        k_exit = k_exit_ref[ks:ks + block_k]
        k_bias = k_bias_ref[ks:ks + block_k]

        def compute(carry, ks=ks, k_order=k_order, k_exit=k_exit, k_bias=k_bias):
            m, l, acc = carry
            kblk = k_ref[0, ks:ks + block_k]       # [bk, D]
            vblk = v_ref[0, ks:ks + block_k]
            s = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32) * sm_scale
            s = s + k_bias[None, :]
            mask = (k_order[None, :] <= qi[:, None]) & (k_exit[None, :] >= q_exit[:, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=1))
            p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=1)
            acc_new = acc * alpha[:, None] + jnp.dot(p, vblk, preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        # Block skipping (FlashMask): causal skip for blocks fully past the
        # query block; cross-branch skip when no key subtree reaches any query.
        skip = jnp.max(k_exit) < q_exit_min
        if ks >= past_len:  # block contains no gateway keys -> causal skip valid
            skip = skip | (jnp.min(k_order) > q_max)
        m, l, acc = jax.lax.cond(skip, lambda c: c, compute, (m, l, acc))

    o_ref[0] = acc / l[:, None]
    lse_ref[0] = m + jnp.log(l)


def _fwd(q, k, v, q_exit, k_order, k_exit, k_bias, sm_scale, block_q, block_k):
    """q: [H, S, D]; k,v: [H, T, D] -> (o [H,S,D], lse [H,S])."""
    H, S, D = q.shape
    T = k.shape[1]
    bq = _pick_block(S, block_q)
    bk = _pick_block(T, block_k)
    past_len = T - S
    grid = (H, S // bq)
    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, block_q=bq, block_k=bk,
        kv_len=T, past_len=past_len)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda h, qb: (qb,)),
            pl.BlockSpec((T,), lambda h, qb: (0,)),
            pl.BlockSpec((T,), lambda h, qb: (0,)),
            pl.BlockSpec((T,), lambda h, qb: (0,)),
            pl.BlockSpec((1, bq, D), lambda h, qb: (h, qb, 0)),
            pl.BlockSpec((1, T, D), lambda h, qb: (h, 0, 0)),
            pl.BlockSpec((1, T, D), lambda h, qb: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qb: (h, qb, 0)),
            pl.BlockSpec((1, bq), lambda h, qb: (h, qb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, S, D), jnp.float32),
            jax.ShapeDtypeStruct((H, S), jnp.float32),
        ],
        interpret=True,
    )(q_exit, k_order, k_exit, k_bias, q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# Backward kernels (FlashAttention-2 style, recompute p from lse)
# ---------------------------------------------------------------------------

def _dq_kernel(q_exit_ref, k_order_ref, k_exit_ref, k_bias_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               *, sm_scale, block_q, block_k, kv_len, past_len):
    qb = pl.program_id(1)
    q = q_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]
    q_exit = q_exit_ref[...]
    qi = qb * block_q + jax.lax.iota(jnp.int32, block_q)
    q_exit_min = jnp.min(q_exit)
    q_max = qb * block_q + (block_q - 1)

    bq, d = q.shape
    dq = jnp.zeros((bq, d), dtype=jnp.float32)

    for kb in range(kv_len // block_k):
        ks = kb * block_k
        k_order = k_order_ref[ks:ks + block_k]
        k_exit = k_exit_ref[ks:ks + block_k]
        k_bias = k_bias_ref[ks:ks + block_k]

        def compute(dq, ks=ks, k_order=k_order, k_exit=k_exit, k_bias=k_bias):
            kblk = k_ref[0, ks:ks + block_k]
            vblk = v_ref[0, ks:ks + block_k]
            s = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32) * sm_scale
            s = s + k_bias[None, :]
            mask = (k_order[None, :] <= qi[:, None]) & (k_exit[None, :] >= q_exit[:, None])
            p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
            dp = jnp.dot(do, vblk.T, preferred_element_type=jnp.float32)
            ds = p * (dp - delta[:, None]) * sm_scale
            return dq + jnp.dot(ds, kblk, preferred_element_type=jnp.float32)

        skip = jnp.max(k_exit) < q_exit_min
        if ks >= past_len:
            skip = skip | (jnp.min(k_order) > q_max)
        dq = jax.lax.cond(skip, lambda c: c, compute, dq)

    dq_ref[0] = dq


def _dkv_kernel(q_exit_ref, k_order_ref, k_exit_ref, k_bias_ref,
                 q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                 dk_ref, dv_ref,
                 *, sm_scale, block_q, block_k, q_len, past_len):
    kb = pl.program_id(1)
    kblk = k_ref[0]                                 # [bk, D] (blocked over kv)
    vblk = v_ref[0]
    k_order = k_order_ref[...]                      # [bk]
    k_exit = k_exit_ref[...]
    k_bias = k_bias_ref[...]
    k_exit_max = jnp.max(k_exit)
    k_order_min = jnp.min(k_order)

    bk, d = kblk.shape
    dk = jnp.zeros((bk, d), dtype=jnp.float32)
    dv = jnp.zeros((bk, d), dtype=jnp.float32)

    for qb in range(q_len // block_q):
        qs = qb * block_q

        def compute(carry, qs=qs):
            dk, dv = carry
            q = q_ref[0, qs:qs + block_q]           # full-length q ref
            do = do_ref[0, qs:qs + block_q]
            lse = lse_ref[0, qs:qs + block_q]
            delta = delta_ref[0, qs:qs + block_q]
            q_exit = q_exit_ref[qs:qs + block_q]
            qi = qs + jax.lax.iota(jnp.int32, block_q)
            s = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32) * sm_scale
            s = s + k_bias[None, :]
            mask = (k_order[None, :] <= qi[:, None]) & (k_exit[None, :] >= q_exit[:, None])
            p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
            dv_new = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
            dp = jnp.dot(do, vblk.T, preferred_element_type=jnp.float32)
            ds = p * (dp - delta[:, None]) * sm_scale
            dk_new = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
            return dk_new, dv_new

        q_exit_blk = q_exit_ref[qs:qs + block_q]
        skip = k_exit_max < jnp.min(q_exit_blk)
        # causal: all queries in this block precede every key in the kv block
        skip = skip | (k_order_min > qs + block_q - 1)
        dk, dv = jax.lax.cond(skip, lambda c: c, compute, (dk, dv))

    dk_ref[0] = dk
    dv_ref[0] = dv


def _bwd(q, k, v, q_exit, k_order, k_exit, k_bias, o, lse, do,
         sm_scale, block_q, block_k):
    H, S, D = q.shape
    T = k.shape[1]
    bq = _pick_block(S, block_q)
    bk = _pick_block(T, block_k)
    past_len = T - S
    delta = jnp.sum(do * o, axis=-1)                # [H, S]

    dq_kernel = functools.partial(
        _dq_kernel, sm_scale=sm_scale, block_q=bq, block_k=bk,
        kv_len=T, past_len=past_len)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(H, S // bq),
        in_specs=[
            pl.BlockSpec((bq,), lambda h, qb: (qb,)),
            pl.BlockSpec((T,), lambda h, qb: (0,)),
            pl.BlockSpec((T,), lambda h, qb: (0,)),
            pl.BlockSpec((T,), lambda h, qb: (0,)),
            pl.BlockSpec((1, bq, D), lambda h, qb: (h, qb, 0)),
            pl.BlockSpec((1, T, D), lambda h, qb: (h, 0, 0)),
            pl.BlockSpec((1, T, D), lambda h, qb: (h, 0, 0)),
            pl.BlockSpec((1, bq, D), lambda h, qb: (h, qb, 0)),
            pl.BlockSpec((1, bq), lambda h, qb: (h, qb)),
            pl.BlockSpec((1, bq), lambda h, qb: (h, qb)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((H, S, D), jnp.float32),
        interpret=True,
    )(q_exit, k_order, k_exit, k_bias, q, k, v, do, lse, delta)

    dkv_kernel = functools.partial(
        _dkv_kernel, sm_scale=sm_scale, block_q=bq, block_k=bk,
        q_len=S, past_len=past_len)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(H, T // bk),
        in_specs=[
            pl.BlockSpec((S,), lambda h, kb: (0,)),
            pl.BlockSpec((bk,), lambda h, kb: (kb,)),
            pl.BlockSpec((bk,), lambda h, kb: (kb,)),
            pl.BlockSpec((bk,), lambda h, kb: (kb,)),
            pl.BlockSpec((1, S, D), lambda h, kb: (h, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda h, kb: (h, kb, 0)),
            pl.BlockSpec((1, bk, D), lambda h, kb: (h, kb, 0)),
            pl.BlockSpec((1, S, D), lambda h, kb: (h, 0, 0)),
            pl.BlockSpec((1, S), lambda h, kb: (h, 0)),
            pl.BlockSpec((1, S), lambda h, kb: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda h, kb: (h, kb, 0)),
            pl.BlockSpec((1, bk, D), lambda h, kb: (h, kb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, T, D), jnp.float32),
            jax.ShapeDtypeStruct((H, T, D), jnp.float32),
        ],
        interpret=True,
    )(q_exit, k_order, k_exit, k_bias, q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public API with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def _tree_attention_hsd(q, k, v, q_exit, k_order, k_exit, k_bias,
                        sm_scale, block_q, block_k):
    o, _ = _fwd(q, k, v, q_exit, k_order, k_exit, k_bias, sm_scale, block_q, block_k)
    return o


def _tree_attention_fwd(q, k, v, q_exit, k_order, k_exit, k_bias,
                        sm_scale, block_q, block_k):
    o, lse = _fwd(q, k, v, q_exit, k_order, k_exit, k_bias, sm_scale, block_q, block_k)
    return o, (q, k, v, q_exit, k_order, k_exit, k_bias, o, lse)


def _tree_attention_bwd(sm_scale, block_q, block_k, res, do):
    q, k, v, q_exit, k_order, k_exit, k_bias, o, lse = res
    dq, dk, dv = _bwd(q, k, v, q_exit, k_order, k_exit, k_bias, o, lse, do,
                      sm_scale, block_q, block_k)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return (dq, dk, dv, f0(q_exit), f0(k_order), f0(k_exit),
            jnp.zeros_like(k_bias))


_tree_attention_hsd.defvjp(_tree_attention_fwd, _tree_attention_bwd)


def tree_attention(q, k, v, q_exit, k_order, k_exit, k_bias,
                   sm_scale=None,
                   block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Tree-masked flash attention on a DFS-serialized sequence.

    Args:
      q: [S, H, D] queries (current tokens).
      k, v: [T, H, D] keys/values, T = past_len + S; the first ``past_len``
        rows are gateway KV from the parent partition (App. B), already
        RoPE-rotated at their true path positions.
      q_exit: [S] i32 subtree-exit of each query token's node (current space).
      k_order: [T] i32 -1 for gateway keys, DFS index for current keys.
      k_exit: [T] i32 PAST_EXIT sentinel for gateway keys, subtree-exit else.
      k_bias: [T] f32 additive bias: Eq. 16 ancestor filter on gateway keys,
        0 on current keys, NEG_INF on padded gateway slots.
    Returns: [S, H, D].
    """
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(q.shape[-1])
    qh = jnp.transpose(q, (1, 0, 2)).astype(jnp.float32)
    kh = jnp.transpose(k, (1, 0, 2)).astype(jnp.float32)
    vh = jnp.transpose(v, (1, 0, 2)).astype(jnp.float32)
    o = _tree_attention_hsd(qh, kh, vh,
                            q_exit.astype(jnp.int32), k_order.astype(jnp.int32),
                            k_exit.astype(jnp.int32), k_bias.astype(jnp.float32),
                            float(sm_scale), int(block_q), int(block_k))
    return jnp.transpose(o, (1, 0, 2))


def tree_attention_jnp(q, k, v, q_exit, k_order, k_exit, k_bias, sm_scale=None):
    """Dense-masked jnp fallback with identical semantics (XLA autodiff).

    Used for the ``--attn-impl=jnp`` AOT variant and as an in-test cross-check
    of the metadata convention (NOT the oracle — ref.py is built from first
    principles).
    """
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(q.shape[-1])
    S = q.shape[0]
    qi = jnp.arange(S, dtype=jnp.int32)
    mask = (k_order[None, :] <= qi[:, None]) & (k_exit[None, :] >= q_exit[:, None])
    s = jnp.einsum("qhd,khd->hqk", q, k) * sm_scale + k_bias[None, None, :]
    s = jnp.where(mask[None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask[None], jnp.exp(s - m), 0.0)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", p, v)


def whole_tree_meta(subtree_exit, past_len=0, past_bias=None):
    """Build (q_exit, k_order, k_exit, k_bias) for a whole-tree (no-gateway
    or gateway) call from the serializer's subtree_exit vector."""
    S = len(subtree_exit)
    q_exit = jnp.asarray(subtree_exit, dtype=jnp.int32)
    cur_order = jnp.arange(S, dtype=jnp.int32)
    if past_len == 0:
        return q_exit, cur_order, q_exit, jnp.zeros((S,), jnp.float32)
    k_order = jnp.concatenate([jnp.full((past_len,), -1, jnp.int32), cur_order])
    k_exit = jnp.concatenate([jnp.full((past_len,), PAST_EXIT, jnp.int32), q_exit])
    if past_bias is None:
        past_bias = jnp.zeros((past_len,), jnp.float32)
    k_bias = jnp.concatenate([past_bias, jnp.zeros((S,), jnp.float32)])
    return q_exit, k_order, k_exit, k_bias
