"""Pure-jnp oracles for the Tree Training kernels.

Three independent references:

  * ``attention_per_path``   -- the paper's sep-avg baseline (Eq. 1): run plain
    causal attention on every root-to-leaf path independently, scatter the
    outputs back to DFS token positions.  Forward equivalence (Eq. 6) demands
    the tree kernel match this exactly for every path.
  * ``attention_dense_mask`` -- dense-masked softmax attention over the DFS
    sequence using an explicit boolean tree mask.
  * ``gdn_recurrent_tree``   -- token-level recurrent Gated Delta Net with
    tree-routed state (the per-token form of the paper's Eq. 10), plus the
    per-path causal conv reference for Appendix A.3.

All oracles are deliberately simple/O(S^2) — clarity over speed.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention references
# ---------------------------------------------------------------------------

def attention_dense_mask(q, k, v, mask, sm_scale=None, bias=None):
    """Softmax attention with an explicit boolean mask.

    q: [S, H, D]; k,v: [T, H, D] (T >= S for the gateway case); mask: [S, T].
    """
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("qhd,khd->hqk", q, k) * sm_scale
    if bias is not None:
        s = s + bias[None, None, :]
    s = jnp.where(mask[None, :, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", p, v)


def attention_per_path(q, k, v, meta, node_specs, sm_scale=None):
    """Sep-avg baseline: per-path causal attention, scattered back to DFS slots.

    Shared-prefix tokens get identical outputs on every path through them
    (verified by the caller), so the scatter is well-defined.
    Returns [S, H, D] in DFS order.
    """
    from compile import treemeta

    out = np.zeros(q.shape, dtype=np.float64)
    for path in treemeta.paths(node_specs):
        idx = treemeta.path_token_indices(meta, path)
        qp, kp, vp = q[idx], k[idx], v[idx]
        L = len(idx)
        causal = np.tril(np.ones((L, L), dtype=bool))
        op = attention_dense_mask(qp, kp, vp, jnp.asarray(causal), sm_scale)
        out[idx] = np.asarray(op, dtype=np.float64)
    return jnp.asarray(out, dtype=q.dtype)


# ---------------------------------------------------------------------------
# Gated Delta Net references
# ---------------------------------------------------------------------------

def gdn_token_step(state, q_t, k_t, v_t, g_t, beta_t):
    """One token of the gated delta rule.

    state: [H, Dk, Dv].  Recurrence (paper §2 / Yang et al. 2025c):
        S_t = exp(g_t) * (I - beta_t k_t k_t^T) S_{t-1} + beta_t k_t v_t^T
        o_t = S_t^T q_t
    """
    decay = jnp.exp(g_t)[:, None, None]                       # [H,1,1]
    kT_S = jnp.einsum("hi,hij->hj", k_t, state)               # k^T S : [H, Dv]
    state = decay * (state - beta_t[:, None, None] * jnp.einsum("hi,hj->hij", k_t, kT_S))
    state = state + beta_t[:, None, None] * jnp.einsum("hi,hj->hij", k_t, v_t)
    o_t = jnp.einsum("hij,hi->hj", state, q_t)                # [H, Dv]
    return state, o_t


def gdn_recurrent_tree(q, k, v, g, beta, node_start, node_len, node_parent):
    """Token-level recurrent GDN with tree state routing.

    q,k: [S,H,Dk]; v: [S,H,Dv]; g,beta: [S,H].
    Each node's first token reads its parent node's *last-token* state
    (Eq. 10); within a node the state flows token-to-token.
    Returns out [S,H,Dv].
    """
    S, H, Dk = q.shape
    Dv = v.shape[-1]
    out = np.zeros((S, H, Dv), dtype=np.float64)
    end_state = {}
    zero = jnp.zeros((H, Dk, Dv), dtype=jnp.float64)
    for n in range(len(node_start)):
        s, ln = int(node_start[n]), int(node_len[n])
        par = int(node_parent[n])
        state = end_state[par] if par != -1 else zero
        for t in range(s, s + ln):
            state, o_t = gdn_token_step(
                state,
                q[t].astype(jnp.float64), k[t].astype(jnp.float64),
                v[t].astype(jnp.float64), g[t].astype(jnp.float64),
                beta[t].astype(jnp.float64),
            )
            out[t] = np.asarray(o_t)
        end_state[n] = state
    return jnp.asarray(out)


def gdn_per_path(q, k, v, g, beta, meta, node_specs):
    """Sep-avg GDN baseline: run the sequential recurrence per path, scatter back."""
    from compile import treemeta

    S, H, Dk = q.shape
    Dv = v.shape[-1]
    out = np.zeros((S, H, Dv), dtype=np.float64)
    zero = jnp.zeros((H, Dk, Dv), dtype=jnp.float64)
    for path in treemeta.paths(node_specs):
        idx = treemeta.path_token_indices(meta, path)
        state = zero
        for t in idx:
            state, o_t = gdn_token_step(
                state,
                q[t].astype(jnp.float64), k[t].astype(jnp.float64),
                v[t].astype(jnp.float64), g[t].astype(jnp.float64),
                beta[t].astype(jnp.float64),
            )
            out[t] = np.asarray(o_t)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Causal conv references (Appendix A.3)
# ---------------------------------------------------------------------------

def silu(x):
    return x * (1.0 / (1.0 + np.exp(-x)))


def conv_per_path(x, w, b, meta, node_specs, activation=True):
    """Per-path causal conv1d oracle.

    x: [S, C] channels-last; w: [C, K] depthwise kernel; b: [C].
    Each path is convolved independently with zero left-padding, outputs
    scattered back to DFS slots.
    """
    from compile import treemeta

    S, C = x.shape
    K = w.shape[1]
    out = np.zeros((S, C), dtype=np.float64)
    w64 = np.asarray(w, dtype=np.float64)
    b64 = np.asarray(b, dtype=np.float64)
    for path in treemeta.paths(node_specs):
        idx = treemeta.path_token_indices(meta, path)
        xp = np.asarray(x[idx], dtype=np.float64)          # [L, C]
        L = len(idx)
        xp_pad = np.concatenate([np.zeros((K - 1, C)), xp], axis=0)
        o = np.zeros((L, C))
        for t in range(L):
            o[t] = np.sum(xp_pad[t:t + K] * w64.T, axis=0)
        o = o + b64[None, :]
        if activation:
            o = silu(o)
        out[idx] = o
    return jnp.asarray(out)
