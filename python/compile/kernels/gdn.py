"""Gated Delta Net (GDN) with tree-routed state (paper §3.2, Appendix A.2/A.3).

Two implementations of the chunked gated delta rule with **tree state
routing** (each chunk reads its *parent* chunk's output state, Eq. 10):

  * ``gdn_tree_chunked``  -- jnp `lax.scan` over chunks carrying the
    ``all_states`` buffer (the paper's Appendix A.2 translated to JAX with the
    O(L^2) row loop replaced by a UT forward-substitution inverse).
  * ``gdn_tree_pallas``   -- the same math as a Pallas kernel: sequential grid
    over chunks, states buffer resident in the output ref (on TPU this is the
    VMEM-resident state of §3.3; per-node processing would bounce it through
    HBM every boundary).

plus the **tree-correct causal convolution** (Appendix A.3) expressed as a
per-token gather: token t's conv window is its K-1 *path predecessors* (never
DFS-adjacent sibling tokens), precomputed host-side as gather indices.

Chunk convention: the serializer pads every node segment to a multiple of
``chunk_size`` so each fixed-size chunk belongs to exactly one node;
``chunk_parent_map[i]`` is the chunk whose output state chunk i reads (-1 =
initial state).  Padding tokens carry g = 0 and beta = 0, which makes the
recurrence state-transparent:  S_t = exp(0) * (I - 0) S_{t-1} + 0 = S_{t-1}.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Shared within-chunk math (paper Appendix A.2, batched over heads)
# ---------------------------------------------------------------------------

def _ut_inverse(t_mat):
    """(I - T)^{-1} for strictly-lower-triangular T, by forward substitution.

    t_mat: [H, L, L].  Row recurrence (the paper's attn_rows loop):
        M[j] = T[j] + T[j] @ M      (T[j,k] = 0 for k >= j makes this exact)
    """
    H, L, _ = t_mat.shape

    def body(j, m):
        row = t_mat[:, j] + jnp.einsum("hk,hkl->hl", t_mat[:, j], m)
        return m.at[:, j].set(row)

    m = jax.lax.fori_loop(0, L, body, t_mat)
    return m + jnp.eye(L, dtype=t_mat.dtype)[None]


def gdn_chunk_math(q, k, v, g, beta, state):
    """One chunk of the tree-routed gated delta rule.

    q, k: [L, H, Dk]; v: [L, H, Dv]; g, beta: [L, H];
    state: [H, Dk, Dv] = parent chunk's output state.
    Returns (out [L, H, Dv], new_state [H, Dk, Dv]).
    """
    L = q.shape[0]
    # head-major
    qh = jnp.transpose(q, (1, 0, 2))            # [H, L, Dk]
    kh = jnp.transpose(k, (1, 0, 2))
    vh = jnp.transpose(v, (1, 0, 2))            # [H, L, Dv]
    gh = jnp.transpose(g, (1, 0))               # [H, L]
    bh = jnp.transpose(beta, (1, 0))

    g_cum = jnp.cumsum(gh, axis=-1)             # [H, L]
    # decay[i, j] = exp(g_cum[i] - g_cum[j]) for j <= i else 0
    decay = jnp.exp(g_cum[:, :, None] - g_cum[:, None, :])
    tril = jnp.tril(jnp.ones((L, L), dtype=bool))
    decay = jnp.where(tril[None], decay, 0.0)
    strict = jnp.tril(jnp.ones((L, L), dtype=bool), k=-1)

    k_beta = kh * bh[..., None]                 # [H, L, Dk]
    v_beta = vh * bh[..., None]                 # [H, L, Dv]

    t_mat = -(jnp.einsum("hid,hjd->hij", k_beta, kh) * decay)
    t_mat = jnp.where(strict[None], t_mat, 0.0)
    attn = _ut_inverse(t_mat)                   # [H, L, L]

    value_corr = jnp.einsum("hij,hjd->hid", attn, v_beta)                 # [H,L,Dv]
    k_cumdecay = jnp.einsum("hij,hjd->hid", attn, k_beta * jnp.exp(g_cum)[..., None])

    v_prime = jnp.einsum("hid,hde->hie", k_cumdecay, state)               # [H,L,Dv]
    v_new = value_corr - v_prime

    attn_within = jnp.einsum("hid,hjd->hij", qh, kh) * decay              # incl diag
    attn_inter = jnp.einsum("hid,hde->hie", qh * jnp.exp(g_cum)[..., None], state)
    out_h = attn_inter + jnp.einsum("hij,hjd->hid", attn_within, v_new)   # [H,L,Dv]

    last = g_cum[:, -1]                          # [H]
    k_decay = kh * jnp.exp(last[:, None, None] - g_cum[..., None])        # [H,L,Dk]
    new_state = state * jnp.exp(last)[:, None, None] + \
        jnp.einsum("hid,hie->hde", k_decay, v_new)
    return jnp.transpose(out_h, (1, 0, 2)), new_state


# ---------------------------------------------------------------------------
# jnp scan implementation (used by the exported model)
# ---------------------------------------------------------------------------

def gdn_tree_chunked(q, k, v, g, beta, chunk_parent_map, chunk_size,
                     initial_state=None):
    """Tree-routed chunked GDN over a DFS-serialized sequence.

    q, k: [S, H, Dk]; v: [S, H, Dv]; g (log decay), beta: [S, H];
    chunk_parent_map: [N] i32, N = S / chunk_size (-1 -> initial state).
    Returns (out [S, H, Dv], all_states [N+1, H, Dk, Dv]) — all_states[c+1]
    is the state after chunk c (the partition gateway reads these, App. B.7).
    """
    S, H, Dk = q.shape
    Dv = v.shape[-1]
    L = chunk_size
    assert S % L == 0, (S, L)
    N = S // L
    if initial_state is None:
        initial_state = jnp.zeros((H, Dk, Dv), dtype=jnp.float32)

    qc = q.reshape(N, L, H, Dk)
    kc = k.reshape(N, L, H, Dk)
    vc = v.reshape(N, L, H, Dv)
    gc = g.reshape(N, L, H)
    bc = beta.reshape(N, L, H)

    states0 = jnp.zeros((N + 1, H, Dk, Dv), dtype=jnp.float32)
    states0 = states0.at[0].set(initial_state)

    def body(carry, xs):
        states, i = carry
        qi, ki, vi, gi, bi, parent = xs
        ps = jax.lax.dynamic_index_in_dim(states, parent + 1, axis=0, keepdims=False)
        out_i, new_s = gdn_chunk_math(qi, ki, vi, gi, bi, ps)
        states = jax.lax.dynamic_update_index_in_dim(
            states, new_s.astype(states.dtype), i + 1, axis=0)
        return (states, i + 1), out_i

    (states, _), outs = jax.lax.scan(
        body, (states0, jnp.int32(0)),
        (qc, kc, vc, gc, bc, chunk_parent_map.astype(jnp.int32)))
    return outs.reshape(S, H, Dv), states


# ---------------------------------------------------------------------------
# Pallas kernel implementation
# ---------------------------------------------------------------------------

def _gdn_kernel(parent_ref, init_ref, q_ref, k_ref, v_ref, g_ref, b_ref,
                o_ref, states_ref, *, chunk_size):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        states_ref[0] = init_ref[...]

    # index dtype must match the platform default (int64 when x64 is on)
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    parent = parent_ref[i].astype(idt)
    state = states_ref[parent + 1]               # [H, Dk, Dv]
    out, new_state = gdn_chunk_math(
        q_ref[0], k_ref[0], v_ref[0], g_ref[0], b_ref[0], state)
    o_ref[0] = out
    states_ref[(i + 1).astype(idt) if hasattr(i, "astype") else i + 1] = new_state


def gdn_tree_pallas(q, k, v, g, beta, chunk_parent_map, chunk_size,
                    initial_state=None):
    """Pallas version of ``gdn_tree_chunked`` (same signature/returns).

    Sequential grid over chunks; the states buffer lives in the (revisited)
    output ref, so on TPU it is VMEM-resident across the whole partition —
    the §3.3 argument for DFS packing over per-node processing.
    """
    S, H, Dk = q.shape
    Dv = v.shape[-1]
    L = chunk_size
    assert S % L == 0, (S, L)
    N = S // L
    if initial_state is None:
        initial_state = jnp.zeros((H, Dk, Dv), dtype=jnp.float32)

    kernel = functools.partial(_gdn_kernel, chunk_size=L)
    out, states = pl.pallas_call(
        kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((N,), lambda i: (0,)),
            pl.BlockSpec((H, Dk, Dv), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, L, H, Dk), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, L, H, Dk), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, L, H, Dv), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, L, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, H), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, H, Dv), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((N + 1, H, Dk, Dv), lambda i: (0, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, L, H, Dv), jnp.float32),
            jax.ShapeDtypeStruct((N + 1, H, Dk, Dv), jnp.float32),
        ],
        interpret=True,
    )(chunk_parent_map.astype(jnp.int32), initial_state,
      q.reshape(N, L, H, Dk), k.reshape(N, L, H, Dk), v.reshape(N, L, H, Dv),
      g.reshape(N, L, H), beta.reshape(N, L, H))
    return out.reshape(S, H, Dv), states


# ---------------------------------------------------------------------------
# Tree-correct causal convolution (Appendix A.3) as a host-indexed gather
# ---------------------------------------------------------------------------

def tree_conv(x, w, b, conv_idx, ctx=None, activation=True):
    """Depthwise causal conv1d whose window follows the *tree path*.

    x: [S, C]; w: [C, K] (w[:, K-1] taps the current token); b: [C];
    conv_idx: [S, K] i32 gather indices into the extended input
        xx = concat([zeros(1, C), ctx (K-1 rows, optional), x]);
      index 0 is the zero row (missing history), 1..K-1 the gateway conv
      context from the parent partition (App. B.7), K-1+1+t the t-th token.
      Host-side the serializer guarantees conv_idx[t, K-1] == t's own slot and
      earlier taps point at *path predecessors*, skipping pads and sibling
      branches (Fig. 4).
    """
    S, C = x.shape
    K = w.shape[1]
    zero = jnp.zeros((1, C), dtype=x.dtype)
    if ctx is None:
        ctx = jnp.zeros((K - 1, C), dtype=x.dtype)
    xx = jnp.concatenate([zero, ctx, x], axis=0)         # [K + S, C]
    gathered = xx[conv_idx]                               # [S, K, C]
    out = jnp.einsum("skc,ck->sc", gathered, w) + b[None, :]
    if activation:
        out = out * jax.nn.sigmoid(out)                   # silu
    return out


MISSING = None  # tap sentinel: no history -> zero row


def conv_gather_indices(node_start, node_len, node_parent, kernel_size,
                        pad_mask=None, has_ctx=False):
    """Host-side builder for ``tree_conv``'s gather indices (numpy).

    For each DFS token t, tap j = K-1 is t itself and taps j < K-1 are its
    path predecessors (most recent at j = K-2), *skipping* tokens flagged in
    ``pad_mask`` and never crossing into sibling branches (Fig. 4).  Missing
    history resolves to the zero row; with ``has_ctx`` the first K-1 rows of
    the extended input are the parent partition's saved conv context
    (chronological order: row K-1 is the most recent predecessor), App. B.7.
    Mirrored in rust/src/tree/dfs.rs (cross-checked by fixture tests).
    """
    K = kernel_size
    S = int(node_start[-1] + node_len[-1])
    if pad_mask is None:
        pad_mask = np.zeros(S, dtype=bool)
    base = K  # xx layout: [zero row, ctx rows 1..K-1, tokens base..base+S-1]

    def slot(tap):
        if tap is MISSING:
            return 0
        if tap >= 0:
            return base + tap
        return K + tap  # tap = -d (d-th most recent ctx row) -> row K-d

    if has_ctx:
        root_chain = [-(d + 1) for d in range(K - 1)]  # most recent first
    else:
        root_chain = [MISSING] * (K - 1)

    idx = np.zeros((S, K), dtype=np.int32)
    entry_chain = {-1: root_chain}
    for n in range(len(node_start)):
        s, ln = int(node_start[n]), int(node_len[n])
        chain = list(entry_chain[int(node_parent[n])])
        for t in range(s, s + ln):
            idx[t, K - 1] = base + t
            for d in range(K - 1):  # d-th most recent predecessor -> tap K-2-d
                idx[t, K - 2 - d] = slot(chain[d])
            if not pad_mask[t]:
                chain = [t] + chain[:K - 2]
        entry_chain[n] = chain
    return idx


def conv_context_tail(x_slots, activation_input, kernel_size):
    """Last K-1 effective rows for a gateway conv context (host helper).

    ``x_slots``: [>=K-1, C] the pre-activation conv *inputs* at the cut node's
    last effective positions, chronological order.  Appendix A.3 saves the
    tail of the concatenated [parent_ctx; chunk] tensor; the gather
    formulation makes that exactly "the K-1 most recent real path tokens".
    """
    K = kernel_size
    return x_slots[-(K - 1):]
