"""Redundancy-Free Tree Partitioning — python mirror of the Rust planner
(rust/src/partition/), used by the pytest suite to validate the exported
part_fwd/part_bwd programs and as the reference for serializer parity.

A partition is a *connected subtree* cut at node boundaries (§3.3).  The
partition dependency graph is then a tree, and the backward pass chains
KV-gateway cotangents child -> parent in reverse topological order with f32
host accumulation (App. B.5/B.6).

Boundary loss terms: a child partition's first token is predicted by the
parent partition's cut-node last token, whose logits only the parent holds.
The planner therefore appends *virtual boundary-target slots* to the parent
batch: self-island tokens carrying (token = child-first-token, prev_idx =
cut-last-slot, weight = lambda of the child token); their own logits row is
never read.  This keeps  sum_partitions loss_sum == whole-tree loss_sum
exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from compile import batching, treemeta
from compile.kernels import tree_attention as ta
from compile.treemeta import NodeSpec


@dataclasses.dataclass
class PartitionSpec:
    """One partition: its nodes (original ids, pre-order), parent linkage."""
    nodes: List[int]                  # original node ids, partition-local preorder
    root: int                         # original id of the partition root
    parent_part: int                  # -1 for the tree-root partition
    cut_node: int                     # original id of the cut node in the parent
                                      # (== parent of self.root); -1 for root part
    # filled by plan():
    meta: treemeta.DfsMeta = None     # partition-local serialization
    weights: np.ndarray = None        # lambda from the FULL tree
    pos_offset: int = 0               # full-tree depth of partition root
    anc_slots: np.ndarray = None      # full-DFS slots of ancestor tokens (gateway)
    virtual: list = None              # [(prev_local_slot, token, weight)]


def partition_nodes(nodes: Sequence[NodeSpec], assignment: List[int]) -> List[PartitionSpec]:
    """Build PartitionSpecs from a node->partition assignment.

    Every partition must be a connected subtree; validated here (the Rust
    bin-packer guarantees it by construction).
    """
    n_parts = max(assignment) + 1
    parts: List[PartitionSpec] = []
    for p in range(n_parts):
        members = [i for i in range(len(nodes)) if assignment[i] == p]
        roots = [i for i in members
                 if nodes[i].parent == -1 or assignment[nodes[i].parent] != p]
        if len(roots) != 1:
            raise ValueError(f"partition {p} is not a connected subtree: roots={roots}")
        root = roots[0]
        for i in members:
            if i != root and assignment[nodes[i].parent] != p:
                raise ValueError(f"partition {p}: node {i} detached from root")
        cut = nodes[root].parent
        parts.append(PartitionSpec(
            nodes=members, root=root,
            parent_part=-1 if cut == -1 else assignment[cut],
            cut_node=cut))
    return parts


def plan(nodes: Sequence[NodeSpec], assignment: List[int]):
    """Full partition plan: per-partition metadata + gateway wiring."""
    full_meta = treemeta.dfs_serialize(nodes)
    parts = partition_nodes(nodes, assignment)

    # ancestor slots (full-DFS token indices) of each node's path, root->node
    def path_slots(n: int) -> np.ndarray:
        chain = []
        i = n
        while i != -1:
            chain.append(i)
            i = int(full_meta.node_parent[i])
        slots = []
        for i in reversed(chain):
            s, ln = int(full_meta.node_start[i]), int(full_meta.node_len[i])
            slots.extend(t for t in range(s, s + ln) if not full_meta.pad_mask[t])
        return np.array(slots, dtype=np.int64)

    for p in parts:
        local_ids = {orig: j for j, orig in enumerate(p.nodes)}
        local_nodes = []
        for orig in p.nodes:
            nd = nodes[orig]
            par = -1 if orig == p.root else local_ids[int(nd.parent)]
            local_nodes.append(NodeSpec(par, nd.tokens, nd.trainable,
                                        nd.advantage, nd.pad_tail))
        p.meta = treemeta.dfs_serialize(local_nodes)
        # full-tree lambda weights, sliced per node segment
        w = np.zeros(p.meta.size, np.float32)
        for orig in p.nodes:
            ls = int(p.meta.node_start[local_ids[orig]])
            fs = int(full_meta.node_start[orig])
            ln = int(full_meta.node_len[orig])
            w[ls:ls + ln] = full_meta.weights[fs:fs + ln]
        p.weights = w
        p.pos_offset = 0 if p.cut_node == -1 else (
            int(full_meta.node_depth_tokens[p.root]))
        p.anc_slots = (np.zeros(0, np.int64) if p.cut_node == -1
                       else path_slots(p.cut_node))
        p.virtual = []

    # boundary virtual targets: child-first tokens land in the parent batch
    for ci, c in enumerate(parts):
        if c.parent_part == -1:
            continue
        parent = parts[c.parent_part]
        lid = {orig: j for j, orig in enumerate(parent.nodes)}[c.cut_node]
        # parent-local slot of the cut node's last real token
        s = int(parent.meta.node_start[lid])
        ln = int(parent.meta.node_len[lid])
        last_real = None
        for t in range(s + ln - 1, s - 1, -1):
            if not parent.meta.pad_mask[t]:
                last_real = t
                break
        assert last_real is not None, "cut node with empty segment unsupported"
        # child's first real token + its full-tree weight
        cs = int(c.meta.node_start[0])
        first = None
        for t in range(cs, cs + int(c.meta.node_len[0])):
            if not c.meta.pad_mask[t]:
                first = t
                break
        tok = int(c.meta.tokens[first])
        wgt = float(c.weights[first])
        parent.virtual.append((last_real, tok, wgt))
        c.weights[first] = 0.0  # counted in the parent instead

    return full_meta, parts


def partition_batch(p: PartitionSpec, capacity: int, past_capacity: int,
                    chunk_size=None, conv_kernel=None, numpy=False) -> dict:
    """Assemble the padded model batch for one partition.

    Layout: [partition tokens | virtual boundary slots | pads] up to
    ``capacity``; gateway rows padded to ``past_capacity`` with -inf bias.
    """
    S = p.meta.size
    nv = len(p.virtual)
    if S + nv > capacity:
        raise ValueError(f"partition needs {S}+{nv} slots > capacity {capacity}")
    A = len(p.anc_slots)
    if A > past_capacity:
        raise ValueError(f"gateway needs {A} rows > capacity {past_capacity}")

    past_bias = np.full(past_capacity, ta.NEG_INF, np.float32)
    past_bias[:A] = 0.0
    b = batching.build_batch(p.meta, capacity, chunk_size=chunk_size,
                             conv_kernel=conv_kernel,
                             past_len=past_capacity, past_bias=past_bias,
                             gateway_ctx=p.cut_node != -1 and conv_kernel is not None,
                             numpy=True)
    # overwrite weights with full-tree lambdas (pads already 0)
    w = np.zeros(capacity, np.float32)
    w[:S] = p.weights
    # true path positions
    pos = np.array(b["pos_ids"], np.int32)
    pos[:S] = pos[:S] + p.pos_offset
    tok = np.array(b["tokens"], np.int32)
    prev = np.array(b["prev_idx"], np.int32)
    for j, (prev_slot, vtok, vw) in enumerate(p.virtual):
        slot = S + j
        tok[slot] = vtok
        prev[slot] = prev_slot
        w[slot] = vw
    b["tokens"], b["prev_idx"], b["weights"], b["pos_ids"] = tok, prev, w, pos
    if numpy:
        return b
    import jax.numpy as jnp
    return {k: jnp.asarray(v) for k, v in b.items()}


def topo_order(parts: List[PartitionSpec]) -> List[int]:
    order = []
    done = set()
    while len(order) < len(parts):
        for i, p in enumerate(parts):
            if i not in done and (p.parent_part == -1 or p.parent_part in done):
                order.append(i)
                done.add(i)
    return order
