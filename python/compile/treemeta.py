"""Trajectory-tree metadata for DFS serialization (python mirror of the Rust serializer).

A trajectory tree (paper §3.1) is a rooted tree of nodes, each holding a token
segment.  DFS serialization (Eq. 8) lays every token out exactly once, in
depth-first pre-order.  The model-side adaptations (§3.2) are all driven by
per-token metadata vectors computed here:

  pos_ids      -- per-path position (Eq. 9): ancestors' lengths + offset.
  subtree_exit -- exclusive DFS-token-space end of the token's node's subtree.
                  The tree attention mask reduces to an interval test
                  (DESIGN.md §2):  mask[i,j] = (j <= i) and (exit[j] >= exit[i]).
  g            -- number of root-to-leaf paths through the token's node.
  lambda_t     -- loss weight  g_t/K * trainable_t  (Eq. 4).

This module is build/test-time only; at runtime the Rust serializer
(rust/src/tree/dfs.rs) produces identical vectors (cross-checked by
rust/tests/serializer_parity.rs against JSON fixtures).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class NodeSpec:
    """One tree node: ``parent`` is an index into the node list (-1 for root).

    Nodes MUST be listed in DFS pre-order (parent before child, children of a
    node contiguous in recursive order); this matches how agentic trajectories
    are recorded and keeps the serializer allocation-free.

    ``pad_tail`` marks that many *trailing* tokens of ``tokens`` as alignment
    padding (used by the hybrid/SSM model to align node segments to the GDN
    chunk size).  Pads are attention self-islands, carry zero loss weight,
    zero position, and are skipped by the conv predecessor chain; the SSM
    recurrence is made transparent to them via g = 0, beta = 0 (gdn.py).
    """

    parent: int
    tokens: np.ndarray            # int32 [len]
    # per-token trainable mask (1.0 = model output, 0.0 = user/env input).
    trainable: Optional[np.ndarray] = None
    # per-token RL advantage (1.0 for SFT).
    advantage: Optional[np.ndarray] = None
    pad_tail: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, dtype=np.int32)
        n = len(self.tokens)
        if self.trainable is None:
            self.trainable = np.ones(n, dtype=np.float32)
        else:
            self.trainable = np.asarray(self.trainable, dtype=np.float32)
        if self.advantage is None:
            self.advantage = np.ones(n, dtype=np.float32)
        else:
            self.advantage = np.asarray(self.advantage, dtype=np.float32)
        assert 0 <= self.pad_tail <= n

    @property
    def real_len(self) -> int:
        return len(self.tokens) - self.pad_tail


@dataclasses.dataclass
class DfsMeta:
    """Per-token metadata of the DFS-serialized tree (all length S)."""

    tokens: np.ndarray        # int32 [S]
    pos_ids: np.ndarray       # int32 [S]  per-path positions (Eq. 9)
    subtree_exit: np.ndarray  # int32 [S]  exclusive subtree end, token space
    node_id: np.ndarray       # int32 [S]
    g: np.ndarray             # int32 [S]  paths through the token's node
    weights: np.ndarray       # float32 [S]  lambda_t = g/K * trainable * advantage
    # node table (length = #nodes, DFS order)
    node_start: np.ndarray    # int32 token-space start of node's own segment
    node_len: np.ndarray      # int32
    node_exit: np.ndarray     # int32 subtree end (exclusive)
    node_parent: np.ndarray   # int32 (-1 root)
    node_depth_tokens: np.ndarray  # int32 ancestor *real* token count
    num_paths: int            # K
    pad_mask: np.ndarray = None    # bool [S] alignment pads

    @property
    def size(self) -> int:
        return len(self.tokens)


def dfs_serialize(nodes: Sequence[NodeSpec]) -> DfsMeta:
    """Serialize a pre-order node list into DFS token order with metadata."""
    n_nodes = len(nodes)
    if n_nodes == 0:
        raise ValueError("empty tree")
    for i, nd in enumerate(nodes):
        if not (-1 <= nd.parent < i):
            raise ValueError(f"node {i}: parent {nd.parent} not in pre-order")
        if i == 0 and nd.parent != -1:
            raise ValueError("node 0 must be the root (parent == -1)")
        if i > 0 and nd.parent == -1:
            raise ValueError(f"node {i}: forest not allowed (single root)")

    seg_len = np.array([len(nd.tokens) for nd in nodes], dtype=np.int64)
    children: list[list[int]] = [[] for _ in range(n_nodes)]
    for i in range(1, n_nodes):
        children[nodes[i].parent].append(i)

    # leaves-under-node == paths through node (g_n), bottom-up.
    g_node = np.zeros(n_nodes, dtype=np.int64)
    for i in range(n_nodes - 1, -1, -1):
        if not children[i]:
            g_node[i] = 1
        else:
            g_node[i] = sum(g_node[c] for c in children[i])
    num_paths = int(g_node[0])

    # token-space start of each node's own segment, and subtree exit.
    # Pre-order layout: node's own tokens first, then children subtrees.
    node_start = np.zeros(n_nodes, dtype=np.int64)
    node_exit = np.zeros(n_nodes, dtype=np.int64)
    subtree_tokens = np.zeros(n_nodes, dtype=np.int64)
    for i in range(n_nodes - 1, -1, -1):
        subtree_tokens[i] = seg_len[i] + sum(subtree_tokens[c] for c in children[i])
    cursor = 0

    def assign(i: int):
        nonlocal cursor
        node_start[i] = cursor
        cursor += seg_len[i]
        for c in children[i]:
            assign(c)
        node_exit[i] = cursor

    # iterative to avoid recursion limits on deep trees
    stack = [(0, False)]
    while stack:
        i, done = stack.pop()
        if done:
            node_exit[i] = cursor
            continue
        node_start[i] = cursor
        cursor += seg_len[i]
        stack.append((i, True))
        for c in reversed(children[i]):
            stack.append((c, False))
    total = cursor

    # depth in *real* tokens (per-path position of node's first token, Eq. 9).
    real_len = np.array([nd.real_len for nd in nodes], dtype=np.int64)
    node_depth = np.zeros(n_nodes, dtype=np.int64)
    for i in range(1, n_nodes):
        p = nodes[i].parent
        node_depth[i] = node_depth[p] + real_len[p]

    tokens = np.zeros(total, dtype=np.int32)
    pos_ids = np.zeros(total, dtype=np.int32)
    subtree_exit = np.zeros(total, dtype=np.int32)
    node_id = np.zeros(total, dtype=np.int32)
    g = np.zeros(total, dtype=np.int32)
    weights = np.zeros(total, dtype=np.float32)
    pad_mask = np.zeros(total, dtype=bool)
    for i, nd in enumerate(nodes):
        s, e = node_start[i], node_start[i] + seg_len[i]
        r = s + nd.real_len
        tokens[s:e] = nd.tokens
        pos_ids[s:r] = node_depth[i] + np.arange(nd.real_len)
        subtree_exit[s:r] = node_exit[i]
        # alignment pads: self-island attention, zero weight/position
        subtree_exit[r:e] = np.arange(r, e) + 1
        pad_mask[r:e] = True
        node_id[s:e] = i
        g[s:e] = g_node[i]
        weights[s:r] = (g_node[i] / num_paths) * nd.trainable[:nd.real_len] \
            * nd.advantage[:nd.real_len]

    return DfsMeta(
        tokens=tokens,
        pos_ids=pos_ids,
        subtree_exit=subtree_exit,
        node_id=node_id,
        g=g,
        weights=weights,
        node_start=node_start.astype(np.int32),
        node_len=seg_len.astype(np.int32),
        node_exit=node_exit.astype(np.int32),
        node_parent=np.array([nd.parent for nd in nodes], dtype=np.int32),
        node_depth_tokens=node_depth.astype(np.int32),
        num_paths=num_paths,
        pad_mask=pad_mask,
    )


def paths(nodes: Sequence[NodeSpec]) -> list[list[int]]:
    """All root-to-leaf paths as node-index lists, DFS (leaf) order."""
    n_nodes = len(nodes)
    children: list[list[int]] = [[] for _ in range(n_nodes)]
    for i in range(1, n_nodes):
        children[nodes[i].parent].append(i)
    out: list[list[int]] = []

    def walk(i: int, acc: list[int]):
        acc = acc + [i]
        if not children[i]:
            out.append(acc)
        for c in children[i]:
            walk(c, acc)

    walk(0, [])
    return out


def path_token_indices(meta: DfsMeta, path: list[int]) -> np.ndarray:
    """DFS-token-space indices of a root-to-leaf path (real tokens only)."""
    idx = []
    for n in path:
        for t in range(meta.node_start[n], meta.node_start[n] + meta.node_len[n]):
            if not meta.pad_mask[t]:
                idx.append(t)
    return np.array(idx, dtype=np.int64)


def dense_tree_mask(meta: DfsMeta) -> np.ndarray:
    """O(S^2) boolean tree attention mask (§3.2): for tests only.

    mask[i, j] = (j <= i) and (node(j) is ancestor-or-self of node(i)).
    Built from first principles (ancestor chain), NOT from the interval trick,
    so tests can verify the interval reduction independently.
    """
    S = meta.size
    n_nodes = len(meta.node_parent)
    anc = np.zeros((n_nodes, n_nodes), dtype=bool)
    for i in range(n_nodes):
        j = i
        while j != -1:
            anc[i, j] = True
            j = int(meta.node_parent[j])
    mask = np.zeros((S, S), dtype=bool)
    for i in range(S):
        ni = meta.node_id[i]
        for j in range(i):
            # pads are never visible as keys (their exit is their own slot)
            mask[i, j] = anc[ni, meta.node_id[j]] and not meta.pad_mask[j]
        mask[i, i] = True              # diagonal always visible (incl. pads)
    return mask


def interval_tree_mask(subtree_exit: np.ndarray) -> np.ndarray:
    """The O(S) interval encoding expanded to a dense mask (kernel semantics)."""
    S = len(subtree_exit)
    i = np.arange(S)
    return (i[None, :] <= i[:, None]) & (subtree_exit[None, :] >= subtree_exit[:, None])


def pad_meta(meta_vec_exit: np.ndarray, pos_ids: np.ndarray, weights: np.ndarray,
             tokens: np.ndarray, capacity: int):
    """Pad per-token vectors to ``capacity``.

    Padding tokens are self-attending islands (exit = own index + 1), carry
    zero loss weight and position 0, so they perturb nothing.
    """
    S = len(tokens)
    if S > capacity:
        raise ValueError(f"sequence {S} exceeds capacity {capacity}")
    pad = capacity - S
    exit_p = np.concatenate([meta_vec_exit, np.arange(S, capacity, dtype=np.int32) + 1])
    pos_p = np.concatenate([pos_ids, np.zeros(pad, dtype=np.int32)])
    w_p = np.concatenate([weights, np.zeros(pad, dtype=np.float32)])
    tok_p = np.concatenate([tokens, np.zeros(pad, dtype=np.int32)])
    return exit_p.astype(np.int32), pos_p.astype(np.int32), w_p.astype(np.float32), tok_p.astype(np.int32)


def por(meta: DfsMeta, node_specs: Sequence[NodeSpec]) -> float:
    """Potential Overlap Ratio (Eq. 12): 1 - N_tree / N_flat (real tokens)."""
    flat = 0
    for p in paths(node_specs):
        flat += sum(node_specs[n].real_len for n in p)
    n_tree = sum(nd.real_len for nd in node_specs)
    return 1.0 - n_tree / flat


def pad_nodes_for_chunks(nodes: Sequence[NodeSpec], chunk_size: int,
                         pad_token: int = 0) -> list[NodeSpec]:
    """Pad every node segment to a multiple of ``chunk_size`` (hybrid model).

    Each GDN chunk must belong to exactly one node (the chunk is the unit of
    SSM state transfer, §3.2); alignment pads are state-transparent.
    """
    out = []
    for nd in nodes:
        assert nd.pad_tail == 0, "already padded"
        n = len(nd.tokens)
        pad = (-n) % chunk_size
        if n == 0:
            pad = chunk_size  # empty segments still need one chunk slot
        out.append(NodeSpec(
            parent=nd.parent,
            tokens=np.concatenate([nd.tokens, np.full(pad, pad_token, np.int32)]),
            trainable=np.concatenate([nd.trainable, np.zeros(pad, np.float32)]),
            advantage=np.concatenate([nd.advantage, np.ones(pad, np.float32)]),
            pad_tail=pad,
        ))
    return out


def chunk_parent_map(meta: DfsMeta, chunk_size: int) -> np.ndarray:
    """Per-chunk parent index for GDN tree state routing (Eq. 10).

    Chunk i reads the output state of chunk ``map[i]`` (-1 = initial state):
    the previous chunk when it belongs to the same node, else the *last*
    chunk of the parent node.  Requires chunk/node alignment
    (``pad_nodes_for_chunks``).
    """
    S = meta.size
    assert S % chunk_size == 0, (S, chunk_size)
    n_chunks = S // chunk_size
    chunk_node = meta.node_id[::chunk_size]
    for i in range(n_chunks):
        a = meta.node_id[i * chunk_size]
        b = meta.node_id[(i + 1) * chunk_size - 1]
        if a != b:
            raise ValueError(f"chunk {i} spans nodes {a}..{b}; pad segments first")
    cpm = np.zeros(n_chunks, dtype=np.int32)
    node_last_chunk: dict[int, int] = {}
    for i in range(n_chunks):
        n = int(chunk_node[i])
        if i > 0 and chunk_node[i - 1] == n:
            cpm[i] = i - 1
        else:
            par = int(meta.node_parent[n])
            cpm[i] = node_last_chunk[par] if par != -1 else -1
        node_last_chunk[n] = i
    return cpm


def random_tree(rng: np.random.Generator, max_nodes: int = 12,
                max_seg: int = 6, max_children: int = 3,
                vocab: int = 64, branch_p: float = 0.6,
                min_seg: int = 1) -> list[NodeSpec]:
    """Random trajectory tree in DFS pre-order (test utility)."""
    nodes = [NodeSpec(-1, rng.integers(0, vocab, rng.integers(min_seg, max_seg + 1)))]
    # grow by DFS so the pre-order invariant holds by construction
    frontier = [0]
    while frontier and len(nodes) < max_nodes:
        cur = frontier.pop()
        if rng.random() > branch_p and cur != 0:
            continue
        n_child = int(rng.integers(1, max_children + 1))
        for _ in range(n_child):
            if len(nodes) >= max_nodes:
                break
            nodes_idx = len(nodes)
            nodes.append(NodeSpec(cur, rng.integers(0, vocab, rng.integers(min_seg, max_seg + 1))))
            frontier.append(nodes_idx)
    # NOTE: frontier-pop order can violate pre-order (children must be
    # contiguous); rebuild in DFS order.
    return _reorder_preorder(nodes)


def _reorder_preorder(nodes: list[NodeSpec]) -> list[NodeSpec]:
    n = len(nodes)
    children: list[list[int]] = [[] for _ in range(n)]
    for i in range(1, n):
        children[nodes[i].parent].append(i)
    order: list[int] = []
    stack = [0]
    while stack:
        i = stack.pop()
        order.append(i)
        for c in reversed(children[i]):
            stack.append(c)
    remap = {old: new for new, old in enumerate(order)}
    out = []
    for old in order:
        nd = nodes[old]
        out.append(NodeSpec(
            parent=-1 if nd.parent == -1 else remap[nd.parent],
            tokens=nd.tokens, trainable=nd.trainable,
            advantage=nd.advantage, pad_tail=nd.pad_tail))
    return out
