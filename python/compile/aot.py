"""AOT export: lower every training program to HLO *text* + manifest.json.

HLO text (NOT ``lowered.compile()``/serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Exported per model config and shape bucket (DESIGN.md §2):

  step_<model>_c<C>            whole-tree / packed-baseline train step
  fwd_<model>_c<C>_a<A>        partition forward (emits per-layer KV)
  bwd_<model>_c<C>_a<A>        partition backward (chains KV cotangents)
  logprob_<model>_c<C>         per-token logprobs (eval scoring)

Also written:
  manifest.json                program table: exact flat input/output order
  params_<model>.bin           f32 initial parameters (manifest order)
  fixtures/*.json              serializer parity fixtures for the Rust tests

Python runs ONCE (``make artifacts``); the rust coordinator never imports it.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

BATCH_KEYS_BASE = ["tokens", "prev_idx", "pos_ids", "weights",
                   "q_exit", "k_order", "k_exit", "k_bias"]
BATCH_KEYS_HYBRID = BATCH_KEYS_BASE + ["chunk_parent_map", "ssm_pad", "conv_idx"]

I32 = jnp.int32
F32 = jnp.float32


def batch_keys(cfg: M.ModelConfig) -> List[str]:
    return BATCH_KEYS_HYBRID if cfg.kind == "hybrid" else BATCH_KEYS_BASE


def batch_specs(cfg: M.ModelConfig, C: int, A: int) -> Dict[str, jax.ShapeDtypeStruct]:
    T = A + C
    spec = {
        "tokens": jax.ShapeDtypeStruct((C,), I32),
        "prev_idx": jax.ShapeDtypeStruct((C,), I32),
        "pos_ids": jax.ShapeDtypeStruct((C,), I32),
        "weights": jax.ShapeDtypeStruct((C,), F32),
        "q_exit": jax.ShapeDtypeStruct((C,), I32),
        "k_order": jax.ShapeDtypeStruct((T,), I32),
        "k_exit": jax.ShapeDtypeStruct((T,), I32),
        "k_bias": jax.ShapeDtypeStruct((T,), F32),
    }
    if cfg.kind == "hybrid":
        spec["chunk_parent_map"] = jax.ShapeDtypeStruct((C // cfg.chunk_size,), I32)
        spec["ssm_pad"] = jax.ShapeDtypeStruct((C,), F32)
        spec["conv_idx"] = jax.ShapeDtypeStruct((C, cfg.conv_kernel), I32)
    return spec


def param_entries(cfg: M.ModelConfig):
    """Deterministic flat (name, leaf) list for params (manifest order)."""
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    entries = []
    for path, leaf in flat:
        name = ".".join(str(getattr(p, "key", p)) for p in path)
        entries.append((name, leaf))
    return entries, treedef, params


def n_attn_layers(cfg: M.ModelConfig) -> int:
    return sum(0 if cfg.is_gdn_layer(i) else 1 for i in range(cfg.n_layers))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_program(fn, arg_specs):
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


class Exporter:
    def __init__(self, out_dir: str):
        self.out = out_dir
        self.programs = []
        self.models = {}
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "fixtures"), exist_ok=True)

    def add_model(self, cfg: M.ModelConfig):
        entries, treedef, params = param_entries(cfg)
        self.models[cfg.name] = {
            "config": {k: v for k, v in cfg.__dict__.items()},
            "n_attn_layers": n_attn_layers(cfg),
            "n_gdn_layers": cfg.n_layers - n_attn_layers(cfg),
            "params": [{"name": n, "shape": list(l.shape)} for n, l in entries],
            "n_params": int(sum(np.prod(l.shape) for _, l in entries)),
        }
        # initial parameters: concatenated f32 (manifest order)
        path = os.path.join(self.out, f"params_{cfg.name}.bin")
        with open(path, "wb") as f:
            for _, leaf in entries:
                f.write(np.asarray(leaf, dtype=np.float32).tobytes())
        return entries, treedef

    def _emit(self, name: str, hlo: str, meta: dict):
        path = os.path.join(self.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        meta["name"] = name
        meta["file"] = f"{name}.hlo.txt"
        meta["sha256"] = hashlib.sha256(hlo.encode()).hexdigest()[:16]
        self.programs.append(meta)
        print(f"  wrote {name}: {len(hlo) / 1e6:.2f} MB HLO text")

    def export_step(self, cfg: M.ModelConfig, C: int):
        entries, treedef = self.add_model(cfg) if cfg.name not in self.models \
            else (param_entries(cfg)[0], param_entries(cfg)[1])
        keys = batch_keys(cfg)
        specs = batch_specs(cfg, C, 0)
        run = M.step_program(cfg)
        leaves = [l for _, l in entries]
        _, pdef = jax.tree_util.tree_flatten(
            M.init_params(jax.random.PRNGKey(0), cfg))

        def fn(*args):
            params = jax.tree_util.tree_unflatten(pdef, args[:len(leaves)])
            batch = dict(zip(keys, args[len(leaves):]))
            loss, wsum, grads = run(params, batch)
            gflat, _ = jax.tree_util.tree_flatten(grads)
            return (loss, wsum, *gflat)

        arg_specs = ([jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
                     + [specs[k] for k in keys])
        hlo = lower_program(fn, arg_specs)
        self._emit(f"step_{cfg.name}_c{C}", hlo, {
            "kind": "step", "model": cfg.name, "capacity": C, "past": 0,
            "inputs": [f"param:{n}" for n, _ in entries] + [f"batch:{k}" for k in keys],
            "outputs": ["loss_sum", "weight_sum"] + [f"grad:{n}" for n, _ in entries],
        })

    def export_logprob(self, cfg: M.ModelConfig, C: int):
        entries, _ = param_entries(cfg)[:2]
        leaves = [l for _, l in entries]
        _, pdef = jax.tree_util.tree_flatten(
            M.init_params(jax.random.PRNGKey(0), cfg))
        keys = batch_keys(cfg)
        specs = batch_specs(cfg, C, 0)
        run = M.logprob_program(cfg)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(pdef, args[:len(leaves)])
            batch = dict(zip(keys, args[len(leaves):]))
            return (run(params, batch),)

        arg_specs = ([jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
                     + [specs[k] for k in keys])
        hlo = lower_program(fn, arg_specs)
        self._emit(f"logprob_{cfg.name}_c{C}", hlo, {
            "kind": "logprob", "model": cfg.name, "capacity": C, "past": 0,
            "inputs": [f"param:{n}" for n, _ in entries] + [f"batch:{k}" for k in keys],
            "outputs": ["logprobs"],
        })

    def export_partition(self, cfg: M.ModelConfig, C: int, A: int):
        assert cfg.kind != "hybrid", "partitioned hybrid export: see DESIGN.md"
        entries, _ = param_entries(cfg)[:2]
        leaves = [l for _, l in entries]
        _, pdef = jax.tree_util.tree_flatten(
            M.init_params(jax.random.PRNGKey(0), cfg))
        keys = batch_keys(cfg)
        specs = batch_specs(cfg, C, A)
        na, H, hd = n_attn_layers(cfg), cfg.n_heads, cfg.head_dim
        kv_spec = jax.ShapeDtypeStruct((na, A, H, hd), F32)
        kvp_spec = jax.ShapeDtypeStruct((na, C, H, hd), F32)

        fwd = M.part_fwd_program(cfg)

        def fn_fwd(*args):
            params = jax.tree_util.tree_unflatten(pdef, args[:len(leaves)])
            batch = dict(zip(keys, args[len(leaves):len(leaves) + len(keys)]))
            k_in, v_in = args[len(leaves) + len(keys):]
            loss, wsum, k_part, v_part = fwd(params, batch, k_in, v_in)
            return (loss, wsum, k_part, v_part)

        arg_specs = ([jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
                     + [specs[k] for k in keys] + [kv_spec, kv_spec])
        self._emit(f"fwd_{cfg.name}_c{C}_a{A}", lower_program(fn_fwd, arg_specs), {
            "kind": "part_fwd", "model": cfg.name, "capacity": C, "past": A,
            "inputs": [f"param:{n}" for n, _ in entries]
            + [f"batch:{k}" for k in keys] + ["k_in", "v_in"],
            "outputs": ["loss_sum", "weight_sum", "k_part", "v_part"],
        })

        bwd = M.part_bwd_program(cfg)

        def fn_bwd(*args):
            params = jax.tree_util.tree_unflatten(pdef, args[:len(leaves)])
            batch = dict(zip(keys, args[len(leaves):len(leaves) + len(keys)]))
            k_in, v_in, d_k, d_v, cot = args[len(leaves) + len(keys):]
            loss, wsum, grads, d_k_in, d_v_in = bwd(
                params, batch, k_in, v_in, d_k, d_v, cot)
            gflat, _ = jax.tree_util.tree_flatten(grads)
            return (loss, wsum, *gflat, d_k_in, d_v_in)

        arg_specs = ([jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
                     + [specs[k] for k in keys]
                     + [kv_spec, kv_spec, kvp_spec, kvp_spec,
                        jax.ShapeDtypeStruct((), F32)])
        self._emit(f"bwd_{cfg.name}_c{C}_a{A}", lower_program(fn_bwd, arg_specs), {
            "kind": "part_bwd", "model": cfg.name, "capacity": C, "past": A,
            "inputs": [f"param:{n}" for n, _ in entries]
            + [f"batch:{k}" for k in keys]
            + ["k_in", "v_in", "d_k_part", "d_v_part", "loss_cot"],
            "outputs": ["loss_sum", "weight_sum"]
            + [f"grad:{n}" for n, _ in entries] + ["d_k_in", "d_v_in"],
        })

    def write_manifest(self):
        manifest = {"programs": self.programs, "models": self.models,
                    "format": 1}
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {len(self.programs)} programs, "
              f"{len(self.models)} models")


def write_fixtures(out_dir: str):
    """Serializer parity fixtures: random trees + expected metadata, consumed
    by rust/tests/serializer_parity.rs."""
    from compile import batching, treemeta
    fixtures = []
    for seed in range(8):
        rng = np.random.default_rng(seed)
        nodes = treemeta.random_tree(rng, max_nodes=int(rng.integers(1, 14)))
        meta = treemeta.dfs_serialize(nodes)
        cap = int(np.ceil((meta.size + 1) / 16) * 16)
        batch = batching.build_batch(meta, cap, numpy=True)
        fixtures.append({
            "seed": seed,
            "nodes": [{"parent": int(n.parent),
                       "tokens": n.tokens.tolist(),
                       "trainable": n.trainable.tolist()} for n in nodes],
            "capacity": cap,
            "num_paths": meta.num_paths,
            "expected": {k: np.asarray(v).reshape(-1).tolist()
                         for k, v in batch.items()},
        })
    path = os.path.join(out_dir, "fixtures", "serializer_parity.json")
    with open(path, "w") as f:
        json.dump(fixtures, f)
    print(f"  wrote fixtures: {len(fixtures)} trees")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny,tiny-moe,tiny-hybrid,small,small-moe,small-hybrid")
    ap.add_argument("--full", action="store_true", help="also export m100")
    args = ap.parse_args()

    ex = Exporter(args.out)
    wanted = args.models.split(",")
    if args.full:
        wanted.append("m100")

    # bucket table: (capacity C, gateway capacity A or None)
    BUCKETS = {
        "tiny": dict(step=[64], part=[(64, 64)], logprob=[64]),
        "tiny-moe": dict(step=[64], part=[(64, 64)], logprob=[]),
        "tiny-hybrid": dict(step=[64], part=[], logprob=[64]),
        "small": dict(step=[256], part=[(256, 256)], logprob=[256]),
        "small-moe": dict(step=[256], part=[], logprob=[]),
        "small-hybrid": dict(step=[256], part=[], logprob=[]),
        "m100": dict(step=[512], part=[(512, 512)], logprob=[]),
    }

    for name in wanted:
        cfg = M.CONFIGS[name]
        b = BUCKETS[name]
        print(f"[{name}] kind={cfg.kind} d={cfg.d_model} L={cfg.n_layers}")
        ex.add_model(cfg)
        for C in b["step"]:
            ex.export_step(cfg, C)
        for C in b["logprob"]:
            ex.export_logprob(cfg, C)
        for C, A in b["part"]:
            ex.export_partition(cfg, C, A)
    write_fixtures(args.out)
    ex.write_manifest()


if __name__ == "__main__":
    main()
