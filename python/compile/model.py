"""Layer-2: the JAX model family (dense / MoE / hybrid-GDN transformers).

Every variant shares one *tree-metadata calling convention* so a single
exported program serves whole-tree training, the packed-linear baseline
("a sequence is a special case of a prefix tree", §2), and partitioned
training with differentiable gateways (App. B):

  tokens [C] i32      DFS-serialized token ids (padded to capacity C)
  prev_idx [C] i32    DFS slot of each token's *path predecessor* (-1 = no
                      loss: root first tokens, pads).  The per-token loss
                      gathers logits at prev_idx — a branching node's last
                      token thereby predicts one target per child branch.
  pos_ids [C] i32     per-path positions (Eq. 9), RoPE inputs
  q_exit [C] i32      subtree-exit interval encoding of the tree mask
  weights [C] f32     lambda_t = g_t/K * trainable * advantage (Eq. 4);
                      per-token advantages make the same program serve RL
  hybrid extras: chunk_parent_map [C/chunk] i32, conv_idx [C, K_conv] i32

Gateway convention (partitioned training, dense/moe):
  k_in, v_in [n_layers, A, H, hd] f32   ancestor KV, already RoPE-rotated at
                                        true path positions, host-compacted
                                        to ancestors only (DESIGN.md §2)
  past_bias [A] f32                     0 = valid row, -inf = padded slot
Gateway outputs: the partition's own per-layer K/V (k_part, v_part), from
which the Rust coordinator gathers each cut node's child gateway.

The loss is returned as (loss_sum, weight_sum): loss_sum = sum_t lambda_t *
CE_t.  Gradients of loss_sum are linear in the per-tree contributions, so the
coordinator normalizes once per global batch (grads / weight_sum) — keeping
partition chaining exact (App. B.6).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import gdn as gdn_k
from compile.kernels import tree_attention as ta

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    kind: str = "dense"          # dense | moe | hybrid
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    head_dim: int = 16
    ffn_mult: int = 4
    # moe
    n_experts: int = 4
    top_k: int = 2
    aux_coef: float = 0.01
    # hybrid (GDN)
    gdn_every: int = 2           # layer i is GDN iff kind==hybrid and i%gdn_every==1
    chunk_size: int = 16
    conv_kernel: int = 4
    gdn_head_dim: int = 16
    # attention impl: pallas | jnp
    attn_impl: str = "pallas"
    rope_base: float = 10000.0

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def is_gdn_layer(self, i: int) -> bool:
        return self.kind == "hybrid" and (i % self.gdn_every == 1)

    @property
    def gdn_conv_dim(self) -> int:
        # conv runs over the mixed q|k|v channels (Qwen3.5-style GDN)
        return self.n_heads * (2 * self.gdn_head_dim + self.head_dim)

    def n_params(self, p=None) -> int:
        p = p or init_params(jax.random.PRNGKey(0), self)
        return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(p))


CONFIGS: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


TINY = register(ModelConfig(name="tiny"))
TINY_MOE = register(ModelConfig(name="tiny-moe", kind="moe"))
TINY_HYBRID = register(ModelConfig(name="tiny-hybrid", kind="hybrid",
                                   chunk_size=4))
# the e2e example model (~13M params at vocab 4096)
SMALL = register(ModelConfig(
    name="small", vocab=4096, d_model=256, n_layers=8, n_heads=8, head_dim=32))
SMALL_MOE = register(ModelConfig(
    name="small-moe", kind="moe", vocab=4096, d_model=256, n_layers=6,
    n_heads=8, head_dim=32, n_experts=8, top_k=2))
SMALL_HYBRID = register(ModelConfig(
    name="small-hybrid", kind="hybrid", vocab=4096, d_model=256, n_layers=6,
    n_heads=8, head_dim=32, chunk_size=32))
# ~100M-parameter config (paper-scale shape at laptop vocab)
M100 = register(ModelConfig(
    name="m100", vocab=16384, d_model=768, n_layers=12, n_heads=12,
    head_dim=64))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale=None):
    # float() keeps the scale weak-typed: numpy f64 scalars would otherwise
    # promote the whole parameter tree under jax_enable_x64 (test mode)
    scale = float(scale if scale is not None else 1.0 / np.sqrt(shape[0]))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 2)
    params: Dict[str, Any] = {
        "embed": _dense_init(keys[0], (cfg.vocab, cfg.d_model), 0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[i + 1], 12)
        layer: Dict[str, Any] = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if cfg.is_gdn_layer(i):
            H, dk, dv = cfg.n_heads, cfg.gdn_head_dim, cfg.head_dim
            layer.update({
                "gdn_qkv": _dense_init(lk[0], (cfg.d_model, cfg.gdn_conv_dim)),
                "gdn_conv_w": _dense_init(lk[1], (cfg.gdn_conv_dim, cfg.conv_kernel), 0.3),
                "gdn_conv_b": jnp.zeros((cfg.gdn_conv_dim,), jnp.float32),
                "gdn_gate": _dense_init(lk[2], (cfg.d_model, H)),
                "gdn_beta": _dense_init(lk[3], (cfg.d_model, H)),
                "gdn_out": _dense_init(lk[4], (H * dv, cfg.d_model)),
            })
        else:
            layer.update({
                "wq": _dense_init(lk[0], (cfg.d_model, cfg.qkv_dim)),
                "wk": _dense_init(lk[1], (cfg.d_model, cfg.qkv_dim)),
                "wv": _dense_init(lk[2], (cfg.d_model, cfg.qkv_dim)),
                "wo": _dense_init(lk[3], (cfg.qkv_dim, cfg.d_model)),
            })
        if cfg.kind == "moe" and i % 2 == 1:
            f = cfg.d_model * cfg.ffn_mult // 2
            layer.update({
                "router": _dense_init(lk[4], (cfg.d_model, cfg.n_experts)),
                "moe_w1": _dense_init(lk[5], (cfg.n_experts, cfg.d_model, f)),
                "moe_w3": _dense_init(lk[6], (cfg.n_experts, cfg.d_model, f)),
                "moe_w2": _dense_init(lk[7], (cfg.n_experts, f, cfg.d_model)),
            })
        else:
            f = cfg.d_model * cfg.ffn_mult
            layer.update({
                "w1": _dense_init(lk[8], (cfg.d_model, f)),
                "w3": _dense_init(lk[9], (cfg.d_model, f)),
                "w2": _dense_init(lk[10], (f, cfg.d_model)),
            })
        params[f"layer_{i}"] = layer
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def apply_rope(x, pos, base):
    """x: [S, H, D]; pos: [S] i32."""
    S, H, D = x.shape
    half = D // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    theta = pos.astype(jnp.float32)[:, None] * freqs[None, :]      # [S, half]
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([
        x1 * cos[:, None, :] - x2 * sin[:, None, :],
        x1 * sin[:, None, :] + x2 * cos[:, None, :],
    ], axis=-1)


def swiglu(x, w1, w3, w2):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def _top_k_by_argmax(probs, k):
    """Top-k values/indices via k argmax sweeps (HLO-parser-compatible)."""
    vals, idxs = [], []
    masked = probs
    for _ in range(k):
        i = jnp.argmax(masked, axis=-1)                   # [S]
        v = jnp.take_along_axis(masked, i[:, None], axis=-1)[:, 0]
        vals.append(v)
        idxs.append(i)
        masked = masked - jax.nn.one_hot(i, probs.shape[-1], dtype=probs.dtype) * 1e9
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_ffn(x, layer, cfg: ModelConfig):
    """Top-k token-choice MoE with dense dispatch (small-E regime).

    Returns (out, aux_loss).  Dense dispatch computes every expert on every
    token and mixes by routing weight — O(E) compute but exact and
    fixed-shape (the paper's 30B-MoE analog; see DESIGN.md §5).
    """
    S, D = x.shape
    logits = x @ layer["router"]                          # [S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    # iterated argmax instead of lax.top_k: the `topk` HLO op (largest=...)
    # postdates the xla_extension 0.5.1 text parser (see DESIGN.md §6)
    topv, topi = _top_k_by_argmax(probs, cfg.top_k)       # [S, k]
    gate = topv / jnp.sum(topv, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=x.dtype)   # [S, k, E]
    combine = jnp.einsum("sk,ske->se", gate, onehot)      # [S, E]
    # all-experts compute
    h = jnp.einsum("sd,edf->esf", x, layer["moe_w1"])
    h3 = jnp.einsum("sd,edf->esf", x, layer["moe_w3"])
    y = jnp.einsum("esf,efd->esd", jax.nn.silu(h) * h3, layer["moe_w2"])
    out = jnp.einsum("esd,se->sd", y, combine)
    # Switch-style load-balance aux: E * sum_e importance_e * load_e
    importance = jnp.mean(probs, axis=0)
    load = jnp.mean(combine > 0, axis=0).astype(x.dtype)
    aux = cfg.n_experts * jnp.sum(importance * load)
    return out, aux


def attention_layer(x, layer, cfg: ModelConfig, pos_ids, attn_meta,
                    k_in=None, v_in=None):
    """Tree attention block.  Returns (out, k_rot, v_heads) — K already
    RoPE-rotated (what the gateway caches, App. B.1)."""
    S = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ layer["wq"]).reshape(S, H, hd)
    k = (x @ layer["wk"]).reshape(S, H, hd)
    v = (x @ layer["wv"]).reshape(S, H, hd)
    q = apply_rope(q, pos_ids, cfg.rope_base)
    k = apply_rope(k, pos_ids, cfg.rope_base)
    if k_in is not None:
        k_all = jnp.concatenate([k_in, k], axis=0)
        v_all = jnp.concatenate([v_in, v], axis=0)
    else:
        k_all, v_all = k, v
    q_exit, k_order, k_exit, k_bias = attn_meta
    impl = ta.tree_attention if cfg.attn_impl == "pallas" else ta.tree_attention_jnp
    o = impl(q, k_all, v_all, q_exit, k_order, k_exit, k_bias)
    return o.reshape(S, H * hd) @ layer["wo"], k, v


def gdn_layer(x, layer, cfg: ModelConfig, chunk_parent_map, conv_idx,
              ssm_pad=None, ssm_state_in=None, conv_ctx_in=None):
    """GDN SSM block with tree routing.  Returns (out, all_states, conv_x).

    conv_x is the pre-conv mixed qkv (the gateway conv-context source,
    App. B.7); all_states[c+1] is the recurrent state after chunk c.
    ``ssm_pad`` (f32 0/1) makes alignment pads state-transparent:
    g = 0, beta = 0  =>  S_t = S_{t-1}.
    """
    S = x.shape[0]
    H, dk, dv = cfg.n_heads, cfg.gdn_head_dim, cfg.head_dim
    conv_x = x @ layer["gdn_qkv"]                          # [S, conv_dim]
    mixed = gdn_k.tree_conv(conv_x, layer["gdn_conv_w"], layer["gdn_conv_b"],
                            conv_idx, ctx=conv_ctx_in)
    qk, rest = jnp.split(mixed, [2 * H * dk], axis=-1)
    q, k = jnp.split(qk.reshape(S, H, 2 * dk), 2, axis=-1)
    v = rest.reshape(S, H, dv)
    # l2-normalized q/k (GDN convention)
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    g = -jax.nn.softplus(x @ layer["gdn_gate"])            # [S, H] log-decay <= 0
    beta = jax.nn.sigmoid(x @ layer["gdn_beta"])           # [S, H]
    if ssm_pad is not None:
        keep = (1.0 - ssm_pad)[:, None]
        g = g * keep
        beta = beta * keep
    out, states = gdn_k.gdn_tree_chunked(
        q, k, v, g, beta, chunk_parent_map, cfg.chunk_size,
        initial_state=ssm_state_in)
    return out.reshape(S, H * dv) @ layer["gdn_out"], states, conv_x


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, pos_ids, attn_meta,
            chunk_parent_map=None, conv_idx=None, ssm_pad=None,
            k_in=None, v_in=None, ssm_state_in=None, conv_ctx_in=None,
            collect_kv=False):
    """Shared trunk.  Returns (logits, aux_loss, cache_dict)."""
    x = params["embed"][tokens]
    aux_total = 0.0
    k_parts, v_parts, ssm_states, conv_xs = [], [], [], []
    attn_i = 0
    gdn_i = 0
    for i in range(cfg.n_layers):
        layer = params[f"layer_{i}"]
        h = rms_norm(x, layer["ln1"])
        if cfg.is_gdn_layer(i):
            o, states, conv_x = gdn_layer(
                h, layer, cfg, chunk_parent_map, conv_idx, ssm_pad=ssm_pad,
                ssm_state_in=None if ssm_state_in is None else ssm_state_in[gdn_i],
                conv_ctx_in=None if conv_ctx_in is None else conv_ctx_in[gdn_i])
            if collect_kv:
                ssm_states.append(states)
                conv_xs.append(conv_x)
            gdn_i += 1
        else:
            o, k_rot, v_h = attention_layer(
                h, layer, cfg, pos_ids, attn_meta,
                k_in=None if k_in is None else k_in[attn_i],
                v_in=None if v_in is None else v_in[attn_i])
            if collect_kv:
                k_parts.append(k_rot)
                v_parts.append(v_h)
            attn_i += 1
        x = x + o
        h = rms_norm(x, layer["ln2"])
        if "router" in layer:
            o, aux = moe_ffn(h, layer, cfg)
            aux_total = aux_total + aux
        else:
            o = swiglu(h, layer["w1"], layer["w3"], layer["w2"])
        x = x + o
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["embed"].T
    cache = {}
    if collect_kv:
        if k_parts:
            cache["k_part"] = jnp.stack(k_parts)   # [n_attn, S, H, hd]
            cache["v_part"] = jnp.stack(v_parts)
        if ssm_states:
            cache["ssm_states"] = jnp.stack(ssm_states)  # [n_gdn, N+1, H, dk, dv]
            cache["conv_x"] = jnp.stack(conv_xs)         # [n_gdn, S, conv_dim]
    return logits, aux_total, cache


def token_logprobs(logits, tokens, prev_idx):
    """Per-token log p(y_t | x_<t)) gathered at each token's path predecessor.

    Tokens with prev_idx < 0 (path roots, pads) get logprob 0 (excluded by
    weight masking).
    """
    S = tokens.shape[0]
    valid = prev_idx >= 0
    safe = jnp.maximum(prev_idx, 0)
    logp_rows = jax.nn.log_softmax(logits, axis=-1)[safe]        # [S, V]
    lp = jnp.take_along_axis(logp_rows, tokens[:, None], axis=-1)[:, 0]
    return jnp.where(valid, lp, 0.0), valid


def loss_fn(params, cfg: ModelConfig, batch, k_in=None, v_in=None,
            ssm_state_in=None, conv_ctx_in=None, collect_kv=False):
    """(loss_sum, (weight_sum, cache)).  loss_sum = sum_t lambda_t * CE_t."""
    attn_meta = (batch["q_exit"], batch["k_order"], batch["k_exit"], batch["k_bias"])
    logits, aux, cache = forward(
        params, cfg, batch["tokens"], batch["pos_ids"], attn_meta,
        chunk_parent_map=batch.get("chunk_parent_map"),
        conv_idx=batch.get("conv_idx"), ssm_pad=batch.get("ssm_pad"),
        k_in=k_in, v_in=v_in, ssm_state_in=ssm_state_in,
        conv_ctx_in=conv_ctx_in, collect_kv=collect_kv)
    lp, valid = token_logprobs(logits, batch["tokens"], batch["prev_idx"])
    w = batch["weights"] * valid.astype(jnp.float32)
    loss_sum = -jnp.sum(w * lp) + cfg.aux_coef * aux
    # |w|: RL advantages can be negative and must not cancel the
    # normalization denominator (coordinator divides grads by weight_sum)
    return loss_sum, (jnp.sum(jnp.abs(w)), cache)


# ---------------------------------------------------------------------------
# Exported program bodies (wrapped by aot.py)
# ---------------------------------------------------------------------------

def step_program(cfg: ModelConfig):
    """(params, batch) -> (loss_sum, weight_sum, grads)."""

    def run(params, batch):
        (loss, (wsum, _)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        return loss, wsum, grads

    return run


def part_fwd_program(cfg: ModelConfig):
    """(params, batch, k_in, v_in) -> (loss_sum, weight_sum, k_part, v_part).

    Topological-order partition forward (App. B.2): emits the partition's
    accumulated per-layer KV for its children's gateways.
    """

    def run(params, batch, k_in, v_in):
        loss, (wsum, cache) = loss_fn(params, cfg, batch,
                                      k_in=k_in, v_in=v_in, collect_kv=True)
        return loss, wsum, cache["k_part"], cache["v_part"]

    return run


def part_bwd_program(cfg: ModelConfig):
    """(params, batch, k_in, v_in, d_k_part, d_v_part, loss_cot)
       -> (loss_sum, weight_sum, grads, d_k_in, d_v_in).

    Reverse-order partition backward (App. B.6): recomputes the forward
    (XLA remat — the AOT analog of the retained graph) and chains the
    children's accumulated KV cotangents into parameter grads plus the
    gateway cotangent for this partition's own parent.
    """

    def run(params, batch, k_in, v_in, d_k_part, d_v_part, loss_cot):
        def f(params, k_in, v_in):
            loss, (wsum, cache) = loss_fn(params, cfg, batch,
                                          k_in=k_in, v_in=v_in, collect_kv=True)
            return loss, wsum, cache["k_part"], cache["v_part"]

        (loss, wsum, k_part, v_part), vjp = jax.vjp(f, params, k_in, v_in)
        zeros_w = jnp.zeros_like(wsum)
        grads, d_k_in, d_v_in = vjp((loss_cot, zeros_w, d_k_part, d_v_part))
        return loss, wsum, grads, d_k_in, d_v_in

    return run


def logprob_program(cfg: ModelConfig):
    """(params, batch) -> per-token weighted logprob [C] (eval scoring)."""

    def run(params, batch):
        attn_meta = (batch["q_exit"], batch["k_order"], batch["k_exit"],
                     batch["k_bias"])
        logits, _, _ = forward(params, cfg, batch["tokens"], batch["pos_ids"],
                               attn_meta,
                               chunk_parent_map=batch.get("chunk_parent_map"),
                               conv_idx=batch.get("conv_idx"),
                               ssm_pad=batch.get("ssm_pad"))
        lp, valid = token_logprobs(logits, batch["tokens"], batch["prev_idx"])
        return lp * valid.astype(jnp.float32)

    return run
