"""Host-side batch construction: DfsMeta -> model input dict.

This is the python mirror of the Rust coordinator's batch builder
(rust/src/trainer/batch.rs); the pytest suite uses it to verify the model
programs end-to-end, and JSON fixtures cross-check the two implementations.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from compile import treemeta
from compile.kernels import gdn as gdn_k
from compile.kernels import tree_attention as ta


def prev_indices(meta: treemeta.DfsMeta) -> np.ndarray:
    """Per-token path-predecessor DFS slot (-1 = none: root firsts, pads).

    The per-token loss ell_t = -log p(y_t | x_<t) gathers logits at this
    slot; a branching node's last token is the predecessor of several
    children's first tokens, so its logits row feeds multiple losses.
    """
    S = meta.size
    prev = np.full(S, -1, dtype=np.int32)
    node_last_real: dict[int, int] = {-1: -1}
    for n in range(len(meta.node_start)):
        s, ln = int(meta.node_start[n]), int(meta.node_len[n])
        last = node_last_real[int(meta.node_parent[n])]
        for t in range(s, s + ln):
            if meta.pad_mask[t]:
                continue
            prev[t] = last
            last = t
        node_last_real[n] = last
    return prev


def build_batch(meta: treemeta.DfsMeta, capacity: int,
                chunk_size: Optional[int] = None,
                conv_kernel: Optional[int] = None,
                past_len: int = 0,
                past_bias: Optional[np.ndarray] = None,
                gateway_ctx: bool = False,
                numpy: bool = False) -> dict:
    """Pad a serialized tree to ``capacity`` and assemble the model batch.

    ``past_len`` > 0 builds the gateway (child-partition) variant: keys
    0..past_len-1 are ancestor KV rows with additive ``past_bias``.
    """
    S = meta.size
    if S > capacity:
        raise ValueError(f"tree ({S} tokens) exceeds capacity {capacity}")
    pad = capacity - S

    exit_p, pos_p, w_p, tok_p = treemeta.pad_meta(
        meta.subtree_exit, meta.pos_ids, meta.weights, meta.tokens, capacity)
    prev = np.concatenate([prev_indices(meta), np.full(pad, -1, np.int32)])
    pad_mask = np.concatenate([meta.pad_mask, np.ones(pad, bool)])

    q_exit = exit_p
    cur_order = np.arange(capacity, dtype=np.int32)
    if past_len:
        k_order = np.concatenate([np.full(past_len, -1, np.int32), cur_order])
        k_exit = np.concatenate([np.full(past_len, ta.PAST_EXIT, np.int32), q_exit])
        pb = past_bias if past_bias is not None else np.zeros(past_len, np.float32)
        k_bias = np.concatenate([pb.astype(np.float32), np.zeros(capacity, np.float32)])
    else:
        k_order, k_exit = cur_order, q_exit
        k_bias = np.zeros(capacity, np.float32)

    batch = {
        "tokens": tok_p,
        "prev_idx": prev,
        "pos_ids": pos_p,
        "weights": w_p,
        "q_exit": q_exit.astype(np.int32),
        "k_order": k_order.astype(np.int32),
        "k_exit": k_exit.astype(np.int32),
        "k_bias": k_bias.astype(np.float32),
    }

    if chunk_size is not None:
        cpm = treemeta.chunk_parent_map(meta, chunk_size) if S else np.zeros(0, np.int32)
        n_pad_chunks = pad // chunk_size
        assert pad % chunk_size == 0, "capacity and tree must be chunk-aligned"
        # pad chunks chain among themselves, isolated from the tree
        pad_cpm = np.arange(len(cpm), len(cpm) + n_pad_chunks, dtype=np.int32) - 1
        if n_pad_chunks:
            pad_cpm[0] = -1
        batch["chunk_parent_map"] = np.concatenate([cpm, pad_cpm]).astype(np.int32)
        batch["ssm_pad"] = pad_mask.astype(np.float32)
    if conv_kernel is not None:
        idx = gdn_k.conv_gather_indices(
            meta.node_start, meta.node_len, meta.node_parent, conv_kernel,
            pad_mask=meta.pad_mask, has_ctx=gateway_ctx)
        base = conv_kernel
        pad_idx = np.zeros((pad, conv_kernel), np.int32)
        pad_idx[:, conv_kernel - 1] = base + S + np.arange(pad)
        batch["conv_idx"] = np.concatenate([idx, pad_idx]).astype(np.int32)

    if numpy:
        return batch
    return {k: jnp.asarray(v) for k, v in batch.items()}


def batch_for_path(nodes: Sequence[treemeta.NodeSpec], path: list[int],
                   capacity: int, **kw) -> dict:
    """Sep-avg baseline helper: one root-to-leaf path as a chain tree."""
    chain = []
    for d, n in enumerate(path):
        nd = nodes[n]
        chain.append(treemeta.NodeSpec(
            parent=d - 1, tokens=nd.tokens[:nd.real_len],
            trainable=nd.trainable[:nd.real_len],
            advantage=nd.advantage[:nd.real_len]))
    meta = treemeta.dfs_serialize(chain)
    return build_batch(meta, capacity, **kw), meta
