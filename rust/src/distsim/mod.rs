//! Cluster-scale cost model (DESIGN.md §5 substitution for the paper's
//! 64x-Hopper Megatron testbed) — a *calibration layer over real packing
//! output*, not a parallel implementation of it.
//!
//! The paper's headline metric is a *ratio* — tree vs baseline step time on
//! identical hardware — which our single-host measurement preserves exactly
//! (both sides run the same executables).  This module maps measured
//! per-rank loads onto a data-parallel cluster to sanity-check the paper's
//! *absolute shape*: per-step time = max over ranks of compute + exposed
//! collective time, with trees sharded whole (the §3.4 constraint).
//!
//! Sharding is **not** re-implemented here: [`simulate_step`] uses the one
//! shared LPT sharder ([`crate::partition::forest::shard_by_cost`]) that
//! the training planner itself uses, and [`simulate_rank_loads`] consumes
//! per-rank loads taken straight from a measured
//! [`crate::trainer::ShardedPlan`] — so the simulated critical path is the
//! critical path the real sharded pipeline would execute.  (A private
//! greedy sharder used to live here; it duplicated, and could disagree
//! with, the planner's placement.)

use crate::partition::forest::shard_by_cost;
use crate::tree::TrajectoryTree;

/// Hardware + parallelism description for one simulated rank.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub n_ranks: usize,
    /// Sustained model FLOP/s per rank (Hopper bf16 dense ~ 4e14 achievable).
    pub flops_per_rank: f64,
    /// All-reduce bus bandwidth per rank (bytes/s), ring model.
    pub allreduce_bw: f64,
    /// Model parameter count (gradient bytes = 2x for bf16).
    pub n_params: usize,
    /// FLOPs per token per forward (6 * n_params for dense transformer).
    pub flops_per_token: f64,
}

impl ClusterSpec {
    /// The paper's testbed shape: 64 Hopper GPUs, 32B-dense-scale model.
    pub fn paper_64xhopper(n_params: usize) -> Self {
        Self {
            n_ranks: 64,
            flops_per_rank: 4.0e14,
            allreduce_bw: 2.0e11,
            n_params,
            flops_per_token: 6.0 * n_params as f64,
        }
    }
}

/// Outcome of simulating one global batch.
#[derive(Debug, Clone)]
pub struct SimStep {
    pub compute_s: f64,
    pub allreduce_s: f64,
    pub total_s: f64,
    pub tokens: usize,
    /// The critical rank's token load (what `compute_s` is derived from).
    pub max_rank_tokens: usize,
}

/// Step time from **measured per-rank token loads** — the calibration entry
/// point: feed it `ShardedPlan::loads` (packed, post-reuse) or the
/// linearized counterpart and the simulated critical path is exactly the
/// load the real per-rank executors would run.
pub fn simulate_rank_loads(spec: &ClusterSpec, rank_loads: &[usize]) -> SimStep {
    let max_tokens = *rank_loads.iter().max().unwrap_or(&0);
    // fwd + bwd ~ 3x fwd FLOPs
    let compute_s = 3.0 * max_tokens as f64 * spec.flops_per_token / spec.flops_per_rank;
    // ring all-reduce: 2 * (n-1)/n * bytes / bw
    let grad_bytes = 2.0 * spec.n_params as f64;
    let allreduce_s =
        2.0 * (spec.n_ranks as f64 - 1.0) / spec.n_ranks as f64 * grad_bytes / spec.allreduce_bw;
    SimStep {
        compute_s,
        allreduce_s,
        total_s: compute_s + allreduce_s,
        tokens: rank_loads.iter().sum(),
        max_rank_tokens: max_tokens,
    }
}

/// Shard per-tree token costs with the planner's LPT sharder, then price
/// the resulting rank loads.  Convenience for callers that have raw per-tree
/// counts instead of a measured plan.
pub fn simulate_step(spec: &ClusterSpec, token_counts: &[usize]) -> SimStep {
    let shards = shard_by_cost(token_counts, spec.n_ranks)
        .expect("ClusterSpec.n_ranks >= 1");
    simulate_rank_loads(spec, &shards.loads)
}

/// Simulated tree-vs-baseline speedup for a dataset of trees: the compute
/// term scales with N_tree vs N_flat, the collective term is identical.
pub fn simulated_speedup(spec: &ClusterSpec, trees: &[TrajectoryTree]) -> f64 {
    let tree_steps: Vec<usize> = trees.iter().map(|t| t.n_tree()).collect();
    let flat_steps: Vec<usize> = trees.iter().map(|t| t.n_flat()).collect();
    let tree = simulate_step(spec, &tree_steps);
    let flat = simulate_step(spec, &flat_steps);
    flat.total_s / tree.total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::planner::PlanSpec;
    use crate::tree::{gen, metrics};

    #[test]
    fn speedup_tracks_por_at_scale() {
        // when compute dominates, simulated speedup approaches 1/(1-POR)
        let spec = ClusterSpec::paper_64xhopper(32_000_000_000);
        let trees: Vec<_> =
            (0..64).map(|s| gen::with_target_por(s, 0.8, 8, 60_000, 512, 1024)).collect();
        let sim = simulated_speedup(&spec, &trees);
        let bound = 1.0 / (1.0 - metrics::dataset_por(&trees));
        assert!(sim > 0.80 * bound, "sim {sim} vs bound {bound}");
        assert!(sim <= bound * 1.02);
    }

    #[test]
    fn collectives_damp_small_batches() {
        // tiny batches are allreduce-bound: speedup collapses toward 1
        let spec = ClusterSpec::paper_64xhopper(32_000_000_000);
        let trees: Vec<_> = (0..2).map(|s| gen::with_target_por(s, 0.7, 4, 60, 16, 64)).collect();
        let sim = simulated_speedup(&spec, &trees);
        let bound = 1.0 / (1.0 - metrics::dataset_por(&trees));
        assert!(sim < 1.5 && sim < bound / 2.0, "allreduce should dominate: {sim} (bound {bound})");
    }

    #[test]
    fn sharding_balances() {
        let spec = ClusterSpec { n_ranks: 4, ..ClusterSpec::paper_64xhopper(1_000_000) };
        let s = simulate_step(&spec, &[100, 100, 100, 100, 400]);
        // critical rank holds 400, not 800
        assert_eq!(s.max_rank_tokens, 400);
        let expect = 3.0 * 400.0 * spec.flops_per_token / spec.flops_per_rank;
        assert!((s.compute_s - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn simulation_consumes_measured_plan_loads() {
        // the calibration path: a real sharded plan's loads drive the sim,
        // and simulate_step over the same per-tree costs agrees exactly
        // (one sharder, no duplicate placement logic)
        let trees: Vec<_> = (0..12).map(|s| gen::uniform(s, 9, 5, 0.6)).collect();
        let plan = PlanSpec::for_host(8192).plan_sharded_tree(&trees, 4).unwrap();
        let spec = ClusterSpec { n_ranks: 4, ..ClusterSpec::paper_64xhopper(1_000_000) };
        let from_plan = simulate_rank_loads(&spec, &plan.loads);
        let costs: Vec<usize> = trees.iter().map(|t| t.n_tree()).collect();
        let from_costs = simulate_step(&spec, &costs);
        assert_eq!(from_plan.max_rank_tokens, from_costs.max_rank_tokens);
        assert_eq!(from_plan.tokens, from_costs.tokens);
        assert_eq!(from_plan.total_s, from_costs.total_s);
    }
}
