//! Training-run orchestration: config, data pipeline, run loop.
//!
//! The global-batch discipline follows §3.4: each batch is a set of complete
//! trees (a tree is one rollout's trajectory); shuffling permutes *trees*,
//! never tokens inside a tree, so Tree Training introduces no gradient bias
//! relative to the baseline order.
//!
//! The run loop no longer iterates trees one by one: each global batch is
//! first *planned* into a stream of packed device batches (Forest Packing —
//! whole trees and partition specs FFD-packed into shared program calls,
//! `partition::forest`) and then executed.  Gradient normalization stays at
//! the global-batch level (Eq. 5), so packing changes call count, never the
//! update.  `forest_packing: false` in the run config restores the seed's
//! one-call-per-tree behavior for ablations.

use std::path::PathBuf;
use std::sync::Arc;

use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::trainer::{AdamWConfig, BaselineTrainer, CsvSink, StepMetrics, TreeTrainer};
use crate::tree::TrajectoryTree;

pub use crate::trainer::metrics::CsvSink as MetricsSink;

/// Run configuration (JSON on disk; see configs/*.json).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub mode: Mode,
    pub steps: u64,
    pub trees_per_batch: usize,
    pub lr: f64,
    pub warmup: u64,
    pub seed: u64,
    /// JSONL corpus path; when absent, `synthetic` drives generation.
    pub corpus: Option<PathBuf>,
    /// `"trees"` (default): the corpus is already tree-structured.
    /// `"rollouts"`: raw linear rollout records, folded through the ingest
    /// radix trie at load time so a run trains straight from agentic logs.
    pub corpus_format: CorpusFormat,
    /// Ingestion knobs for the rollouts format (JSON key `ingest`:
    /// `{"max_seq_len": N, "max_open_sessions": N}`; defaults otherwise —
    /// raise `max_open_sessions` for heavily interleaved logs).
    pub ingest: crate::ingest::IngestConfig,
    pub synthetic: Option<SyntheticSpec>,
    pub metrics_csv: Option<PathBuf>,
    /// Cross-tree Forest Packing (default on; off = seed's per-tree calls).
    pub forest_packing: bool,
}

impl RunConfig {
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let mode = match v.get("mode").and_then(|m| m.as_str()).unwrap_or("tree") {
            "tree" => Mode::Tree,
            "baseline" => Mode::Baseline,
            other => anyhow::bail!("unknown mode {other}"),
        };
        Ok(Self {
            model: v.req_str("model")?.to_string(),
            mode,
            steps: v.req_usize("steps")? as u64,
            trees_per_batch: v.get("trees_per_batch").and_then(|x| x.as_usize()).unwrap_or(1),
            lr: v.get("lr").and_then(|x| x.as_f64()).unwrap_or(3e-4),
            warmup: v.get("warmup").and_then(|x| x.as_u64()).unwrap_or(0),
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(0),
            corpus: v.get("corpus").and_then(|x| x.as_str()).map(PathBuf::from),
            corpus_format: match v.get("corpus_format").and_then(|x| x.as_str()).unwrap_or("trees")
            {
                "trees" => CorpusFormat::Trees,
                "rollouts" => CorpusFormat::Rollouts,
                other => anyhow::bail!("unknown corpus_format {other} (trees|rollouts)"),
            },
            ingest: match v.get("ingest") {
                Some(i) => {
                    let cfg = crate::ingest::IngestConfig {
                        max_seq_len: i.get("max_seq_len").and_then(|x| x.as_usize()),
                        max_open_sessions: i
                            .get("max_open_sessions")
                            .and_then(|x| x.as_usize())
                            .unwrap_or(crate::ingest::IngestConfig::default().max_open_sessions),
                    };
                    anyhow::ensure!(
                        cfg.max_seq_len != Some(0),
                        "ingest.max_seq_len must be >= 1"
                    );
                    anyhow::ensure!(
                        cfg.max_open_sessions >= 1,
                        "ingest.max_open_sessions must be >= 1"
                    );
                    cfg
                }
                None => Default::default(),
            },
            synthetic: match v.get("synthetic") {
                Some(s) => Some(SyntheticSpec::from_json(s)?),
                None => None,
            },
            metrics_csv: v.get("metrics_csv").and_then(|x| x.as_str()).map(PathBuf::from),
            forest_packing: v.get("forest_packing").and_then(|x| x.as_bool()).unwrap_or(true),
        })
    }
}

/// On-disk layout of the `corpus` file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusFormat {
    /// JSONL of `TrajectoryTree`s (`tree/io.rs`).
    Trees,
    /// JSONL of linear `RolloutRecord`s, ingested at load time.
    Rollouts,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Tree Training (the paper's method).
    Tree,
    /// Sep-avg linearization + sequence packing (Eq. 1).
    Baseline,
}

#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub overlap: String, // low | medium | high | por:<x>
    pub n_trees: usize,
    pub turns: usize,
    pub vocab: i32,
}

impl SyntheticSpec {
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        Ok(Self {
            overlap: v.get("overlap").and_then(|x| x.as_str()).unwrap_or("high").to_string(),
            n_trees: v.get("n_trees").and_then(|x| x.as_usize()).unwrap_or(64),
            turns: v.get("turns").and_then(|x| x.as_usize()).unwrap_or(6),
            vocab: v.get("vocab").and_then(|x| x.as_i64()).unwrap_or(256) as i32,
        })
    }
}

impl SyntheticSpec {
    #[allow(clippy::wrong_self_convention)]
    pub fn generate(&self, seed: u64) -> crate::Result<Vec<TrajectoryTree>> {
        use crate::tree::gen::{self, Overlap};
        let mut out = Vec::with_capacity(self.n_trees);
        for i in 0..self.n_trees {
            let s = seed.wrapping_add(i as u64);
            let t = if let Some(p) = self.overlap.strip_prefix("por:") {
                let por: f64 = p.parse()?;
                gen::with_target_por(s, por, 6, 600, 24, self.vocab)
            } else {
                let ov = match self.overlap.as_str() {
                    "low" => Overlap::Low,
                    "medium" => Overlap::Medium,
                    "high" => Overlap::High,
                    other => anyhow::bail!("unknown overlap {other}"),
                };
                gen::agentic(s, ov, self.turns, self.vocab)
            };
            out.push(t);
        }
        Ok(out)
    }
}

/// Either trainer behind one interface.
pub enum AnyTrainer {
    Tree(TreeTrainer),
    Baseline(BaselineTrainer),
}

impl AnyTrainer {
    pub fn train_step(&mut self, trees: &[TrajectoryTree]) -> crate::Result<StepMetrics> {
        match self {
            Self::Tree(t) => t.train_step(trees),
            Self::Baseline(t) => t.train_step(trees),
        }
    }

    pub fn set_lr(&mut self, lr: f64) {
        match self {
            Self::Tree(t) => t.set_lr(lr),
            Self::Baseline(t) => t.set_lr(lr),
        }
    }

    pub fn eval_loss(&self, trees: &[TrajectoryTree]) -> crate::Result<(f64, f64)> {
        match self {
            Self::Tree(t) => t.eval_loss(trees),
            Self::Baseline(t) => t.eval_loss(trees),
        }
    }
}

/// The run loop: data -> trainer -> metrics.
pub struct Coordinator {
    pub cfg: RunConfig,
    pub trainer: AnyTrainer,
    pub data: Vec<TrajectoryTree>,
    sink: Option<CsvSink>,
}

impl Coordinator {
    pub fn new(rt: Arc<Runtime>, cfg: RunConfig) -> crate::Result<Self> {
        let opt = AdamWConfig { lr: cfg.lr, ..Default::default() };
        let trainer = match cfg.mode {
            Mode::Tree => {
                let mut t = TreeTrainer::new(rt, &cfg.model, opt)?;
                t.forest_packing = cfg.forest_packing;
                AnyTrainer::Tree(t)
            }
            Mode::Baseline => AnyTrainer::Baseline(BaselineTrainer::new(rt, &cfg.model, opt)?),
        };
        let data = if let Some(path) = &cfg.corpus {
            match cfg.corpus_format {
                // line-by-line load with `path:line` parse errors; the tree
                // set itself stays resident for cross-epoch shuffling (§3.4)
                CorpusFormat::Trees => crate::tree::io::load_corpus_iter(path)?
                    .collect::<crate::Result<Vec<_>>>()?,
                CorpusFormat::Rollouts => {
                    let (trees, stats) = crate::ingest::fold_corpus(path, &cfg.ingest)?;
                    crate::info!(
                        "ingest: {} rollouts ({} sessions) -> {} trees, measured \
                         prefix-reuse {:.2}x ({} -> {} tokens)",
                        stats.records_in,
                        stats.sessions,
                        stats.trees_out,
                        stats.reuse_ratio(),
                        stats.rollout_tokens_in,
                        stats.tree_tokens_out
                    );
                    trees
                }
            }
        } else if let Some(spec) = &cfg.synthetic {
            spec.generate(cfg.seed)?
        } else {
            anyhow::bail!("config needs `corpus` or `synthetic`")
        };
        anyhow::ensure!(!data.is_empty(), "empty dataset");
        let sink = match &cfg.metrics_csv {
            Some(p) => Some(CsvSink::create(p)?),
            None => None,
        };
        Ok(Self { cfg, trainer, data, sink })
    }

    /// Run the configured number of steps; returns per-step metrics.
    ///
    /// Each step: assemble the global batch of trees, *plan* it into packed
    /// device batches (tree mode), then execute the stream and update.
    pub fn run(&mut self) -> crate::Result<Vec<StepMetrics>> {
        let mut rng = crate::tree::gen::rng(self.cfg.seed);
        let mut order: Vec<usize> = (0..self.data.len()).collect();
        let mut cursor = 0usize;
        let mut all = Vec::with_capacity(self.cfg.steps as usize);
        for step in 0..self.cfg.steps {
            // epoch boundary: reshuffle between trees (§3.4)
            if cursor + self.cfg.trees_per_batch > order.len() {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let batch: Vec<TrajectoryTree> = order[cursor..cursor + self.cfg.trees_per_batch]
                .iter()
                .map(|&i| self.data[i].clone())
                .collect();
            cursor += self.cfg.trees_per_batch;
            let lr =
                crate::trainer::adamw::cosine_lr(self.cfg.lr, step, self.cfg.warmup, self.cfg.steps);
            self.trainer.set_lr(lr);
            let m = match &mut self.trainer {
                AnyTrainer::Tree(t) => {
                    let plan = t.plan_global_batch(&batch)?;
                    if step == 0 {
                        crate::info!(
                            "forest packing: {} trees -> {} program calls per global batch",
                            batch.len(),
                            plan.program_calls()
                        );
                    }
                    t.execute_plan(&plan)?
                }
                AnyTrainer::Baseline(t) => t.train_step(&batch)?,
            };
            if let Some(s) = &mut self.sink {
                s.log(&m)?;
            }
            if step % 10 == 0 || step + 1 == self.cfg.steps {
                crate::info!(
                    "train step={} loss={:.4} tok/s={:.0} wall_ms={} calls={}",
                    m.step,
                    m.loss,
                    m.tokens_per_sec(),
                    m.wall.as_millis(),
                    m.exec_calls
                );
            }
            all.push(m);
        }
        Ok(all)
    }
}
