//! Training-run orchestration: config, data pipeline, run loop.
//!
//! The global-batch discipline follows §3.4: each batch is a set of complete
//! trees (a tree is one rollout's trajectory); shuffling permutes *trees*,
//! never tokens inside a tree, so Tree Training introduces no gradient bias
//! relative to the baseline order.
//!
//! The run loop no longer iterates trees one by one: each global batch is
//! first *planned* into a stream of packed device batches (Forest Packing —
//! whole trees and partition specs FFD-packed into shared program calls,
//! `partition::forest`) and then executed.  Gradient normalization stays at
//! the global-batch level (Eq. 5), so packing changes call count, never the
//! update.  `forest_packing: false` in the run config restores the seed's
//! one-call-per-tree behavior for ablations.
//!
//! [`Coordinator::run`] itself is a thin [`pipeline`] driver over four
//! decoupled layers (docs/pipeline.md, docs/distributed.md): a
//! [`crate::data::CorpusSource`] streams `Arc`-shared trees in
//! epoch-shuffled order (resident, or shard-streamed under
//! `shuffle_window` for corpora that must not be fully resident), a
//! planner — on a background thread when `pipeline_depth > 0` — LPT-shards
//! each global batch across `ranks` whole-tree data-parallel ranks and
//! turns each rank share into a [`crate::trainer::StepPlan`], the [`dist`]
//! layer executes rank plans on a *persistent* per-rank worker pool (one
//! full trainer replica per rank, spawned once per run) whose fixed
//! log-tree gradient reduction runs on the worker threads, and the reduced
//! f64 gradient feeds one optimizer step on the primary engine — then the
//! identical update is broadcast so every replica stays bit-identical.

use std::path::PathBuf;
use std::sync::Arc;

use crate::data::{CorpusSource, ResidentSource, StreamingRolloutSource, StreamingTreeSource};
use crate::runtime::Runtime;
use crate::trainer::planner::PlanSpec;
use crate::trainer::{AdamWConfig, BaselineTrainer, CsvSink, StepMetrics, TreeTrainer};
use crate::tree::TrajectoryTree;
use crate::util::json::Json;

pub mod collective;
pub mod dist;
pub mod launcher;
pub mod pipeline;

pub use crate::trainer::metrics::CsvSink as MetricsSink;
pub use pipeline::{PipelineConfig, PipelineSummary, PlannedStep, StepExecutor};

/// Run configuration (JSON on disk; see configs/*.json).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub mode: Mode,
    pub steps: u64,
    pub trees_per_batch: usize,
    pub lr: f64,
    pub warmup: u64,
    pub seed: u64,
    /// JSONL corpus path; when absent, `synthetic` drives generation.
    pub corpus: Option<PathBuf>,
    /// `"trees"` (default): the corpus is already tree-structured.
    /// `"rollouts"`: raw linear rollout records, folded through the ingest
    /// radix trie at load time so a run trains straight from agentic logs.
    pub corpus_format: CorpusFormat,
    /// Ingestion knobs for the rollouts format (JSON key `ingest`:
    /// `{"max_seq_len": N, "max_open_sessions": N, "threads": N}`;
    /// defaults otherwise — raise `max_open_sessions` for heavily
    /// interleaved logs, `threads` for parallel folding with bit-identical
    /// output).
    pub ingest: crate::ingest::IngestConfig,
    pub synthetic: Option<SyntheticSpec>,
    pub metrics_csv: Option<PathBuf>,
    /// Cross-tree Forest Packing (default on; off = seed's per-tree calls).
    pub forest_packing: bool,
    /// Plan-queue depth of the pipelined run loop (default 1: double
    /// buffering — plan batch N+1 while batch N executes).  `0` restores
    /// the synchronous loop; both are step-for-step identical
    /// (docs/pipeline.md determinism contract).
    pub pipeline_depth: usize,
    /// `0` (default): the corpus stays resident.  `N > 0`: stream the
    /// corpus shard-by-shard with at most `N` trees resident, re-reading
    /// (rollouts: re-folding) the file each epoch.  Requires `corpus`.
    pub shuffle_window: usize,
    /// Data-parallel ranks each global batch is sharded across (whole
    /// trees, §3.4).  `1` (default) is the seed single-executor pipeline
    /// byte-for-byte; `N` runs per-rank executor workers with
    /// deterministic fixed-order gradient reduction (docs/distributed.md).
    pub ranks: usize,
    /// Cost model pricing the sharder/packer (`"tokens"` default:
    /// packed-token counts, bit-identical to the seed; `"calibrated"`:
    /// an online least-squares fit of measured per-rank execute walls —
    /// docs/distributed.md#calibrated-cost-model).  Calibrated runs price
    /// from wall clock and are NOT run-to-run bit-identical; the global
    /// batch (and thus the update) is unchanged, only rank placement.
    pub cost_model: CostModelChoice,
    /// Persisted calibration state (JSON key `cost_model_state`, requires
    /// `cost_model: "calibrated"`): the calibrated model warm-starts from
    /// this file's saved normal equations (missing file = cold start) and
    /// writes the accumulated state back after the run, so restarts keep
    /// learning instead of starting over.
    pub cost_model_state: Option<PathBuf>,
    /// Prefix-affine scheduling (docs/prefix_reuse.md, schedule tier):
    /// fingerprint shared root prefixes across the global batch, pack
    /// same-prefix trees into the same forest batch, order steps group-major
    /// and keep affine groups rank-local.  Default off — the seed plans,
    /// bit-for-bit.  Losses under affinity match within f64 tolerance only
    /// (reordering reassociates the Eq. 5 sums); the update set is unchanged.
    pub prefix_affinity: bool,
    /// Token budget of the trie-keyed prefix-activation cache (engine tier;
    /// `prefix_cache_tokens` in JSON).  `0` (default) disables it.  Entries
    /// never cross an optimizer update, so cache on ≡ off bit-for-bit
    /// within every step; on the XLA engine the cache is accounting-only.
    pub prefix_cache_tokens: usize,
    /// Bucket size (KiB of f64 payload) the gradient reduction is split
    /// into on the collective data plane (docs/distributed.md#collective).
    /// `0` (default) keeps the monolithic reduce — with the in-process
    /// transport that is the seed path bit-for-bit, no collective built.
    pub reduce_bucket_kb: usize,
    /// Collective transport: `"in_process"` (default) or `"socket"`
    /// (loopback TCP frames with a rendezvous file; multi-process-shaped).
    /// Any `(reduce_bucket_kb, collective)` config reduces to identical
    /// bits — see the determinism contract in docs/distributed.md.
    pub collective: dist::Transport,
}

impl RunConfig {
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let mode = match v.get("mode").and_then(|m| m.as_str()).unwrap_or("tree") {
            "tree" => Mode::Tree,
            "baseline" => Mode::Baseline,
            other => anyhow::bail!("unknown mode {other}"),
        };
        let cfg = Self {
            model: v.req_str("model")?.to_string(),
            mode,
            steps: v.req_usize("steps")? as u64,
            trees_per_batch: v.get("trees_per_batch").and_then(|x| x.as_usize()).unwrap_or(1),
            lr: v.get("lr").and_then(|x| x.as_f64()).unwrap_or(3e-4),
            warmup: v.get("warmup").and_then(|x| x.as_u64()).unwrap_or(0),
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(0),
            corpus: v.get("corpus").and_then(|x| x.as_str()).map(PathBuf::from),
            corpus_format: match v.get("corpus_format").and_then(|x| x.as_str()).unwrap_or("trees")
            {
                "trees" => CorpusFormat::Trees,
                "rollouts" => CorpusFormat::Rollouts,
                other => anyhow::bail!("unknown corpus_format {other} (trees|rollouts)"),
            },
            ingest: match v.get("ingest") {
                Some(i) => {
                    let cfg = crate::ingest::IngestConfig {
                        max_seq_len: i.get("max_seq_len").and_then(|x| x.as_usize()),
                        max_open_sessions: i
                            .get("max_open_sessions")
                            .and_then(|x| x.as_usize())
                            .unwrap_or(crate::ingest::IngestConfig::default().max_open_sessions),
                        threads: i.get("threads").and_then(|x| x.as_usize()).unwrap_or(1),
                    };
                    anyhow::ensure!(
                        cfg.max_seq_len != Some(0),
                        "ingest.max_seq_len must be >= 1"
                    );
                    anyhow::ensure!(
                        cfg.max_open_sessions >= 1,
                        "ingest.max_open_sessions must be >= 1"
                    );
                    anyhow::ensure!(cfg.threads >= 1, "ingest.threads must be >= 1");
                    cfg
                }
                None => Default::default(),
            },
            synthetic: match v.get("synthetic") {
                Some(s) => Some(SyntheticSpec::from_json(s)?),
                None => None,
            },
            metrics_csv: v.get("metrics_csv").and_then(|x| x.as_str()).map(PathBuf::from),
            forest_packing: v.get("forest_packing").and_then(|x| x.as_bool()).unwrap_or(true),
            pipeline_depth: v.get("pipeline_depth").and_then(|x| x.as_usize()).unwrap_or(1),
            shuffle_window: v.get("shuffle_window").and_then(|x| x.as_usize()).unwrap_or(0),
            ranks: v.get("ranks").and_then(|x| x.as_usize()).unwrap_or(1),
            cost_model: match v.get("cost_model").and_then(|x| x.as_str()).unwrap_or("tokens") {
                "tokens" => CostModelChoice::Tokens,
                "calibrated" => CostModelChoice::Calibrated,
                other => anyhow::bail!("unknown cost_model {other} (tokens|calibrated)"),
            },
            cost_model_state: v.get("cost_model_state").and_then(|x| x.as_str()).map(PathBuf::from),
            prefix_affinity: v.get("prefix_affinity").and_then(|x| x.as_bool()).unwrap_or(false),
            prefix_cache_tokens: v
                .get("prefix_cache_tokens")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            reduce_bucket_kb: v.get("reduce_bucket_kb").and_then(|x| x.as_usize()).unwrap_or(0),
            collective: match v.get("collective").and_then(|x| x.as_str()) {
                Some(s) => dist::Transport::parse(s)?,
                None => dist::Transport::InProcess,
            },
        };
        anyhow::ensure!(cfg.steps >= 1, "steps must be >= 1");
        anyhow::ensure!(cfg.ranks >= 1, "ranks must be >= 1");
        anyhow::ensure!(
            cfg.cost_model_state.is_none() || cfg.cost_model == CostModelChoice::Calibrated,
            "cost_model_state persists calibration; it requires cost_model: \"calibrated\""
        );
        anyhow::ensure!(
            cfg.shuffle_window == 0 || cfg.corpus.is_some(),
            "shuffle_window streams a corpus file; synthetic data is generated in memory"
        );
        Ok(cfg)
    }

    /// The reduction config handed to [`dist::TrainerPool::new_with`].
    pub fn reduce_options(&self) -> dist::ReduceOptions {
        dist::ReduceOptions {
            bucket_kb: self.reduce_bucket_kb,
            transport: self.collective,
            ..Default::default()
        }
    }
}

/// On-disk layout of the `corpus` file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusFormat {
    /// JSONL of `TrajectoryTree`s (`tree/io.rs`).
    Trees,
    /// JSONL of linear `RolloutRecord`s, ingested at load time.
    Rollouts,
}

/// Which cost model prices the LPT sharder and (once warm) the FFD packer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostModelChoice {
    /// Packed-token counts — the seed's exact behavior (default).
    Tokens,
    /// Online least-squares calibration from measured per-rank walls
    /// ([`crate::partition::CostModel::calibrated`]).
    Calibrated,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Tree Training (the paper's method).
    Tree,
    /// Sep-avg linearization + sequence packing (Eq. 1).
    Baseline,
}

#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub overlap: String, // low | medium | high | por:<x>
    pub n_trees: usize,
    pub turns: usize,
    pub vocab: i32,
}

impl SyntheticSpec {
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        Ok(Self {
            overlap: v.get("overlap").and_then(|x| x.as_str()).unwrap_or("high").to_string(),
            n_trees: v.get("n_trees").and_then(|x| x.as_usize()).unwrap_or(64),
            turns: v.get("turns").and_then(|x| x.as_usize()).unwrap_or(6),
            vocab: v.get("vocab").and_then(|x| x.as_i64()).unwrap_or(256) as i32,
        })
    }
}

impl SyntheticSpec {
    #[allow(clippy::wrong_self_convention)]
    pub fn generate(&self, seed: u64) -> crate::Result<Vec<TrajectoryTree>> {
        use crate::tree::gen::{self, Overlap};
        let mut out = Vec::with_capacity(self.n_trees);
        for i in 0..self.n_trees {
            let s = seed.wrapping_add(i as u64);
            let t = if let Some(p) = self.overlap.strip_prefix("por:") {
                let por: f64 = p.parse()?;
                gen::with_target_por(s, por, 6, 600, 24, self.vocab)
            } else {
                let ov = match self.overlap.as_str() {
                    "low" => Overlap::Low,
                    "medium" => Overlap::Medium,
                    "high" => Overlap::High,
                    other => anyhow::bail!("unknown overlap {other}"),
                };
                gen::agentic(s, ov, self.turns, self.vocab)
            };
            out.push(t);
        }
        Ok(out)
    }
}

/// Either trainer behind one interface, split into explicit plan/execute
/// halves: [`Self::plan_spec`] snapshots the engine-free planning data
/// (what the pipeline's planner thread owns) and [`dist::TrainerPool`]
/// consumes pre-built rank plans — both modes flow through the same
/// pipeline, Baseline's "plan" being its linearized chain packing.
pub enum AnyTrainer {
    Tree(TreeTrainer),
    Baseline(BaselineTrainer),
}

impl AnyTrainer {
    /// The engine-free plan half (`Send`; see [`crate::trainer::PlanSpec`]).
    pub fn plan_spec(&self) -> PlanSpec {
        match self {
            Self::Tree(t) => t.plan_spec(),
            Self::Baseline(t) => t.plan_spec(),
        }
    }

    /// Per-rank replica: an independent trainer whose engine owns its own
    /// parameters, literal cache, optimizer moments and program handles —
    /// the worker state of [`dist::TrainerPool`].  `device` is the device
    /// ordinal the replica's programs are compiled for
    /// ([`crate::runtime::Runtime::program_replica`]); the pool passes the
    /// rank index, wrapped onto the client's real device count.
    pub fn replicate(&self, device: usize) -> crate::Result<Self> {
        Ok(match self {
            Self::Tree(t) => Self::Tree(t.replicate(device)?),
            Self::Baseline(t) => Self::Baseline(t.replicate(device)?),
        })
    }

    /// Drain this trainer's engine prefix-cache counters (zeros when the
    /// cache is disabled, as on baseline engines).
    pub fn take_cache_stats(&self) -> crate::trainer::prefix_cache::CacheStats {
        match self {
            Self::Tree(t) => t.engine.take_cache_stats(),
            Self::Baseline(t) => t.engine.take_cache_stats(),
        }
    }

    /// Total f64 gradient elements across all parameters — the flat index
    /// space the bucketed collective addresses.
    pub fn grad_elems(&self) -> usize {
        match self {
            Self::Tree(t) => t.engine.params().iter().map(|p| p.len()).sum(),
            Self::Baseline(t) => t.engine.params().iter().map(|p| p.len()).sum(),
        }
    }

    pub fn train_step(&mut self, trees: &[TrajectoryTree]) -> crate::Result<StepMetrics> {
        match self {
            Self::Tree(t) => t.train_step(trees),
            Self::Baseline(t) => t.train_step(trees),
        }
    }

    pub fn set_lr(&mut self, lr: f64) {
        match self {
            Self::Tree(t) => t.set_lr(lr),
            Self::Baseline(t) => t.set_lr(lr),
        }
    }

    pub fn eval_loss(&self, trees: &[TrajectoryTree]) -> crate::Result<(f64, f64)> {
        match self {
            Self::Tree(t) => t.eval_loss(trees),
            Self::Baseline(t) => t.eval_loss(trees),
        }
    }
}

/// Build the configured corpus source (the data layer of docs/pipeline.md).
fn build_source(cfg: &RunConfig) -> crate::Result<Box<dyn CorpusSource>> {
    if let Some(path) = &cfg.corpus {
        match (cfg.corpus_format, cfg.shuffle_window) {
            // line-by-line load with `path:line` parse errors
            (CorpusFormat::Trees, 0) => {
                let trees = crate::tree::io::load_corpus_iter(path)?
                    .collect::<crate::Result<Vec<_>>>()?;
                Ok(Box::new(ResidentSource::new(trees, cfg.seed)?))
            }
            (CorpusFormat::Trees, w) => {
                Ok(Box::new(StreamingTreeSource::open(path, w, cfg.seed)?))
            }
            (CorpusFormat::Rollouts, 0) => {
                let (trees, stats) = crate::ingest::fold_corpus(path, &cfg.ingest)?;
                crate::info!(
                    "ingest: {} rollouts ({} sessions) -> {} trees, measured \
                     prefix-reuse {:.2}x ({} -> {} tokens)",
                    stats.records_in,
                    stats.sessions,
                    stats.trees_out,
                    stats.reuse_ratio(),
                    stats.rollout_tokens_in,
                    stats.tree_tokens_out
                );
                Ok(Box::new(ResidentSource::new(trees, cfg.seed)?))
            }
            (CorpusFormat::Rollouts, w) => Ok(Box::new(StreamingRolloutSource::open(
                path,
                cfg.ingest.clone(),
                w,
                cfg.seed,
            )?)),
        }
    } else if let Some(spec) = &cfg.synthetic {
        Ok(Box::new(ResidentSource::new(spec.generate(cfg.seed)?, cfg.seed)?))
    } else {
        anyhow::bail!("config needs `corpus` or `synthetic`")
    }
}

/// Adapts the trainer + metric sinks to the pipeline's executor seam.
/// Owns the run's persistent [`dist::TrainerPool`]: per-rank trainer
/// replicas spawned once, fed `Arc`-shared rank plans each step.
struct TrainerExecutor<'a> {
    trainer: &'a mut AnyTrainer,
    pool: dist::TrainerPool,
    sink: &'a mut Option<CsvSink>,
    steps: u64,
    /// 0-based count of executed steps — the log cadence (`m.step` is the
    /// engine's 1-based post-update counter, and the seed loop's cadence
    /// was 0-based: log the first step, every 10th, and the last).
    done: u64,
}

impl StepExecutor for TrainerExecutor<'_> {
    fn execute(&mut self, planned: &PlannedStep) -> crate::Result<StepMetrics> {
        if planned.step == 0 {
            crate::info!(
                "plan: {} trees -> {} program calls per global batch across {} rank(s) \
                 (load imbalance {:.3})",
                planned.trees,
                planned.plan.program_calls(),
                planned.plan.n_ranks(),
                planned.plan.rank_imbalance()
            );
        }
        self.trainer.set_lr(planned.lr);
        self.pool.execute_step(self.trainer, planned.lr, &planned.plan)
    }

    fn pool_spawn_ms(&self) -> f64 {
        self.pool.spawn_ms
    }

    fn on_step(&mut self, m: &StepMetrics) -> crate::Result<()> {
        if let Some(s) = self.sink.as_mut() {
            s.log(m)?;
        }
        let idx = self.done;
        self.done += 1;
        if idx % 10 == 0 || idx + 1 == self.steps {
            crate::info!(
                "train step={} loss={:.4} tok/s={:.0} wall_ms={} plan_ms={:.1} \
                 stall_ms={:.1} calls={}",
                m.step,
                m.loss,
                m.tokens_per_sec(),
                m.wall.as_millis(),
                m.plan_ms,
                m.stall_ms,
                m.exec_calls
            );
        }
        Ok(())
    }
}

/// The run loop: data layer -> pipeline -> trainer -> metrics.
pub struct Coordinator {
    pub cfg: RunConfig,
    pub trainer: AnyTrainer,
    /// Consumed by [`Self::run`] (the pipeline's planner owns it while the
    /// run is live).
    source: Option<Box<dyn CorpusSource>>,
    sink: Option<CsvSink>,
    /// Pipeline accounting of the last completed run.
    pub summary: Option<PipelineSummary>,
}

impl Coordinator {
    pub fn new(rt: Arc<Runtime>, cfg: RunConfig) -> crate::Result<Self> {
        let source = build_source(&cfg)?;
        Self::with_source(rt, cfg, source)
    }

    /// Construct with an explicit in-memory tree set, served resident
    /// under the run seed — for examples/tests that filter or synthesize
    /// data outside the config surface (the config's `corpus`/`synthetic`
    /// entries are then never loaded or generated).
    pub fn with_corpus(
        rt: Arc<Runtime>,
        cfg: RunConfig,
        trees: Vec<TrajectoryTree>,
    ) -> crate::Result<Self> {
        let source: Box<dyn CorpusSource> = Box::new(ResidentSource::new(trees, cfg.seed)?);
        Self::with_source(rt, cfg, source)
    }

    fn with_source(
        rt: Arc<Runtime>,
        cfg: RunConfig,
        source: Box<dyn CorpusSource>,
    ) -> crate::Result<Self> {
        let opt = AdamWConfig { lr: cfg.lr, ..Default::default() };
        let trainer = match cfg.mode {
            Mode::Tree => {
                let mut t = TreeTrainer::new(rt, &cfg.model, opt)?;
                t.forest_packing = cfg.forest_packing;
                t.prefix_affinity = cfg.prefix_affinity;
                t.engine.set_prefix_cache_tokens(cfg.prefix_cache_tokens);
                AnyTrainer::Tree(t)
            }
            Mode::Baseline => AnyTrainer::Baseline(BaselineTrainer::new(rt, &cfg.model, opt)?),
        };
        crate::info!(
            "data: {} (pipeline depth {}, ranks {})",
            source.describe(),
            cfg.pipeline_depth,
            cfg.ranks
        );
        let sink = match &cfg.metrics_csv {
            Some(p) => Some(CsvSink::create(p)?),
            None => None,
        };
        Ok(Self { cfg, trainer, source: Some(source), sink, summary: None })
    }

    /// Run the configured number of steps; returns per-step metrics.
    ///
    /// Planner side (background thread when `pipeline_depth > 0`): assemble
    /// the global batch, compute the scheduled LR, plan packed device
    /// batches.  Executor side (this thread): execute plans in step order
    /// and update.  See [`pipeline`] for the determinism contract.
    pub fn run(&mut self) -> crate::Result<Vec<StepMetrics>> {
        let source = self
            .source
            .take()
            .ok_or_else(|| anyhow::anyhow!("run() already consumed the corpus source"))?;
        let pcfg = PipelineConfig {
            mode: self.cfg.mode,
            steps: self.cfg.steps,
            trees_per_batch: self.cfg.trees_per_batch,
            depth: self.cfg.pipeline_depth,
            lr: self.cfg.lr,
            warmup: self.cfg.warmup,
            ranks: self.cfg.ranks,
        };
        let mut spec = self.trainer.plan_spec();
        let mut cost_model = None;
        if self.cfg.cost_model == CostModelChoice::Calibrated {
            // warm-up threshold: two full multi-rank steps at ranks=4
            // before the fit replaces token pricing
            let cm = match &self.cfg.cost_model_state {
                Some(p) => crate::partition::CostModel::calibrated_from_state(8, p)?,
                None => crate::partition::CostModel::calibrated(8),
            };
            spec = spec.with_cost_model(cm.clone());
            cost_model = Some(cm);
        }
        // the run's persistent rank pool: replicas + worker threads are
        // created HERE, once — never per optimizer step
        let pool = dist::TrainerPool::new_with(
            &self.trainer,
            self.cfg.ranks,
            self.cfg.reduce_options(),
        )?;
        let mut exec = TrainerExecutor {
            trainer: &mut self.trainer,
            pool,
            sink: &mut self.sink,
            steps: self.cfg.steps,
            done: 0,
        };
        let run_res = pipeline::run(&pcfg, spec, source, &mut exec);
        // join the pool either way so deferred replica-update errors
        // surface even when the run itself succeeded
        let TrainerExecutor { pool, .. } = exec;
        let finish_res = pool.finish();
        let (metrics, summary) = run_res?;
        finish_res?;
        // persist the accumulated calibration only after a clean run, so a
        // crashed run can't leave a half-trusted fit behind
        if let (Some(cm), Some(path)) = (&cost_model, &self.cfg.cost_model_state) {
            cm.save_state(path)?;
        }
        // callers surface the one-line summary (`tree-train train` prints
        // it; see PipelineSummary::log_line)
        self.summary = Some(summary);
        Ok(metrics)
    }
}
