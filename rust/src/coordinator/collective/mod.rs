//! The collective layer: transport-pluggable bucket reduction frames.
//!
//! `coordinator/dist.rs` splits gradient reduction into two planes.  The
//! **control plane** (typed `mpsc` channels) carries everything that is not
//! bulk payload: errors, execute walls, merge accounting, scalar sums and
//! digests — the machinery PR 5 proved deadlock-free and deterministic.
//! The **data plane** — this module — carries only the f64 gradient payload,
//! chopped into fixed parameter-range *buckets* ([`bucket_ranges`]), each
//! flowing child → parent along the same log-tree bracket the control plane
//! uses ([`crate::coordinator::dist::reduce_schedule`]).
//!
//! A [`Collective`] is one rank's endpoint on that tree.  Two transports
//! implement it:
//!
//! * [`ChannelCollective`] — in-process `mpsc` bus, the reference impl.
//! * [`SocketCollective`] — loopback TCP with a rendezvous file
//!   (Gloo-shaped: ranks publish listener addresses, children dial their
//!   bracket parent), multi-process capable; frames are length-prefixed
//!   ([`Frame::encode`]) so the wire format is process- and
//!   machine-boundary-clean.
//!
//! **Determinism contract.**  Frames are keyed `(seq, bucket, from)` and a
//! receiver folds a bucket's children strictly in bracket round order — an
//! out-of-order arrival waits in a [`FrameStash`] (the data-plane twin of
//! the control plane's stash-and-replay).  Because every bucket is folded
//! by the identical bracket the monolithic path uses, the per-element fold
//! sequence — own accumulation first, then children in round order — is
//! *identical* at every bucket size and on every transport, so bucketed
//! and socket reductions are bit-identical to the monolithic in-process
//! path, not merely tolerance-close (proof sketch in docs/distributed.md;
//! python mirror: `python/tests/test_bucket_reduce.py`).
//!
//! **Abort frames.**  A zero-length payload is an abort marker: a rank
//! whose execute failed still sends exactly one frame per bucket, so the
//! frames-per-rank-per-step invariant holds and no peer blocks forever.
//! The real error travels the control plane; an abort merely poisons the
//! bucket so partially-folded payloads are never mistaken for results.

use std::collections::HashMap;
use std::io::Read;
use std::ops::Range;

pub mod channel;
pub mod socket;

pub use channel::ChannelCollective;
pub use socket::SocketCollective;

/// Fixed frame header: `[u64 seq][u32 bucket][u32 from][u32 nelems]`,
/// little-endian, followed by `nelems` f64 payload words (bit-exact:
/// encoded via `to_bits`, so NaN payloads survive the wire).
pub const FRAME_HEADER_BYTES: usize = 8 + 4 + 4 + 4;

/// One bucket payload flowing child → parent in the reduce tree.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Pool step sequence number (stale frames from aborted steps are
    /// garbage-collected by [`Collective::gc_below`]).
    pub seq: u64,
    /// Bucket index into the step's [`bucket_ranges`].
    pub bucket: u32,
    /// Sending rank.
    pub from: u32,
    /// Folded bucket payload; **empty = abort marker**.
    pub data: Vec<f64>,
}

impl Frame {
    /// Abort marker: the sender's execute failed (or a child of it did),
    /// so this bucket carries no payload — only the frame-count invariant.
    pub fn is_abort(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes this frame occupies on the wire.
    pub fn wire_bytes(nelems: usize) -> usize {
        FRAME_HEADER_BYTES + 8 * nelems
    }

    /// Little-endian length-prefixed encoding (see [`FRAME_HEADER_BYTES`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::wire_bytes(self.data.len()));
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.bucket.to_le_bytes());
        out.extend_from_slice(&self.from.to_le_bytes());
        out.extend_from_slice(&(self.data.len() as u32).to_le_bytes());
        for v in &self.data {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        out
    }

    /// Decode one frame from a byte stream.  `Ok(None)` means the stream
    /// ended cleanly *at* a frame boundary (peer closed); EOF mid-frame is
    /// an error.  Unbounded: trusts the wire's `nelems` — prefer
    /// [`Frame::decode_from_bounded`] on sockets, where a corrupt or
    /// hostile header must not drive the payload allocation.
    pub fn decode_from<R: Read>(r: &mut R) -> std::io::Result<Option<Frame>> {
        Self::decode_from_bounded(r, None)
    }

    /// [`Frame::decode_from`] with an upper bound on the payload element
    /// count.  A header claiming more than `max_elems` is rejected as
    /// `InvalidData` *before* any payload allocation — without the bound a
    /// single corrupt header (`nelems = u32::MAX`) asks for a 32 GiB
    /// buffer and aborts the process.
    pub fn decode_from_bounded<R: Read>(
        r: &mut R,
        max_elems: Option<usize>,
    ) -> std::io::Result<Option<Frame>> {
        let mut head = [0u8; FRAME_HEADER_BYTES];
        let mut got = 0usize;
        while got < head.len() {
            let n = r.read(&mut head[got..])?;
            if n == 0 {
                if got == 0 {
                    return Ok(None);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "collective stream ended mid-frame-header",
                ));
            }
            got += n;
        }
        let seq = u64::from_le_bytes(head[0..8].try_into().unwrap());
        let bucket = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let from = u32::from_le_bytes(head[12..16].try_into().unwrap());
        let nelems = u32::from_le_bytes(head[16..20].try_into().unwrap()) as usize;
        if let Some(max) = max_elems {
            if nelems > max {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "frame header from rank {from} claims {nelems} f64 elems but this \
                         run's frames are bounded at {max} (corrupt stream or foreign dialer)"
                    ),
                ));
            }
        }
        let mut body = vec![0u8; 8 * nelems];
        r.read_exact(&mut body)?;
        let data = body
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect();
        Ok(Some(Frame { seq, bucket, from, data }))
    }
}

/// Split a flat payload of `flat_len` f64 elements into fixed-size buckets
/// of `bucket_kb` KiB each (the last bucket takes the remainder).
/// `bucket_kb == 0` means one monolithic bucket covering the whole payload
/// — the knob's "today's path" setting.
pub fn bucket_ranges(flat_len: usize, bucket_kb: usize) -> Vec<Range<usize>> {
    if flat_len == 0 {
        return Vec::new();
    }
    let per = if bucket_kb == 0 { flat_len } else { (bucket_kb * 1024 / 8).max(1) };
    (0..flat_len).step_by(per).map(|s| s..(s + per).min(flat_len)).collect()
}

/// Out-of-order frame parking: frames are keyed `(seq, bucket, from)` and
/// replayed when the receiver's bracket cursor reaches them — arrival
/// order can change wall clock, never fold order.
#[derive(Default)]
pub struct FrameStash {
    map: HashMap<(u64, u32, u32), Vec<f64>>,
}

impl FrameStash {
    pub fn put(&mut self, f: Frame) {
        self.map.insert((f.seq, f.bucket, f.from), f.data);
    }

    pub fn take(&mut self, seq: u64, bucket: u32, from: u32) -> Option<Vec<f64>> {
        self.map.remove(&(seq, bucket, from))
    }

    /// Drop frames from steps older than `seq` (aborted-step residue).
    pub fn gc_below(&mut self, seq: u64) {
        self.map.retain(|k, _| k.0 >= seq);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// One rank's endpoint on the bucket-reduction tree.  Topology is the
/// fixed log-tree bracket: a rank only ever sends *up* (to
/// `reduce_parent(rank)`) and receives from its bracket children — the
/// optimizer update stays replica-local (docs/distributed.md discusses the
/// measured AdamW-vs-broadcast crossover behind that choice).
pub trait Collective: Send {
    fn rank(&self) -> usize;
    fn n_ranks(&self) -> usize;

    /// Send a fully-folded bucket to this rank's bracket parent.  Returns
    /// the wire bytes spent.  Calling this on rank 0 (the root) is a
    /// protocol bug and errors.
    fn send_up(&mut self, seq: u64, bucket: u32, data: &[f64]) -> crate::Result<usize>;

    /// Send the abort marker for a bucket (empty payload; see module docs).
    fn send_abort(&mut self, seq: u64, bucket: u32) -> crate::Result<usize> {
        self.send_up(seq, bucket, &[])
    }

    /// Non-blocking: drain any delivered frames into the stash, then take
    /// the `(seq, bucket, src)` frame if present.
    fn try_take(&mut self, seq: u64, bucket: u32, src: usize) -> Option<Frame>;

    /// Non-blocking: drain delivered frames into the stash without taking
    /// any (the pump's early-unit work — keeps transport buffers small
    /// while the local accumulation is still running).  Implemented as a
    /// `try_take` with a key no frame can carry.
    fn drain(&mut self, seq: u64) {
        let _ = self.try_take(seq, u32::MAX, usize::MAX);
    }

    /// Blocking receive of the `(seq, bucket, src)` frame (stash first).
    fn recv(&mut self, seq: u64, bucket: u32, src: usize) -> crate::Result<Frame>;

    /// Drop parked frames from steps older than `seq`.
    fn gc_below(&mut self, seq: u64);
}

/// Shared receive logic for transports that deliver [`Frame`]s through an
/// in-process channel (the channel bus directly; sockets via per-connection
/// reader threads): stash-and-replay keyed `(seq, bucket, from)`.
///
/// `deadline` bounds the *total* wait.  It exists for the socket transport:
/// when a peer process dies its reader thread exits, but the other readers'
/// sender clones keep the shared channel alive, so a plain `recv()` would
/// block forever — exactly the multi-process hang the launcher's watchdog
/// must not rely on the OS to break.  `None` (the in-process bus) keeps the
/// untimed behavior: there a dead peer drops the only sender and `recv()`
/// itself errors.
pub(crate) fn recv_frame(
    rx: &std::sync::mpsc::Receiver<Frame>,
    stash: &mut FrameStash,
    seq: u64,
    bucket: u32,
    src: usize,
    deadline: Option<std::time::Duration>,
) -> crate::Result<Frame> {
    if let Some(data) = stash.take(seq, bucket, src as u32) {
        return Ok(Frame { seq, bucket, from: src as u32, data });
    }
    let until = deadline.map(|d| std::time::Instant::now() + d);
    loop {
        let f = match until {
            None => rx.recv().map_err(|_| {
                anyhow::anyhow!("collective peer rank {src} disconnected (bucket {bucket})")
            })?,
            Some(t) => {
                let left = t.saturating_duration_since(std::time::Instant::now());
                match rx.recv_timeout(left) {
                    Ok(f) => f,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => anyhow::bail!(
                        "collective peer rank {src}: no frame (seq {seq}, bucket {bucket}) \
                         within {:?} — peer process dead or hung",
                        deadline.unwrap()
                    ),
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => anyhow::bail!(
                        "collective peer rank {src} disconnected (bucket {bucket})"
                    ),
                }
            }
        };
        if f.seq < seq {
            continue; // stale frame from an aborted earlier step
        }
        if f.seq == seq && f.bucket == bucket && f.from == src as u32 {
            return Ok(f);
        }
        stash.put(f);
    }
}

/// Shared non-blocking drain + take.
pub(crate) fn try_take_frame(
    rx: &std::sync::mpsc::Receiver<Frame>,
    stash: &mut FrameStash,
    seq: u64,
    bucket: u32,
    src: usize,
) -> Option<Frame> {
    while let Ok(f) = rx.try_recv() {
        stash.put(f);
    }
    stash
        .take(seq, bucket, src as u32)
        .map(|data| Frame { seq, bucket, from: src as u32, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_ranges_partition_the_payload() {
        for (len, kb) in [(0usize, 0usize), (1, 0), (10_000, 0), (10_000, 1), (100_000, 64)] {
            let ranges = bucket_ranges(len, kb);
            if len == 0 {
                assert!(ranges.is_empty());
                continue;
            }
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, len);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
                assert!(!w[0].is_empty());
            }
            assert!(!ranges.last().unwrap().is_empty());
        }
    }

    #[test]
    fn bucket_zero_is_one_monolithic_bucket() {
        assert_eq!(bucket_ranges(12_345, 0), vec![0..12_345]);
    }

    #[test]
    fn bucket_size_in_elements_is_kb_over_eight() {
        // 64 KiB of f64 = 8192 elements per bucket
        let ranges = bucket_ranges(20_000, 64);
        assert_eq!(ranges, vec![0..8192, 8192..16_384, 16_384..20_000]);
    }

    #[test]
    fn frame_round_trips_bit_exactly() {
        let f = Frame {
            seq: 7,
            bucket: 3,
            from: 5,
            data: vec![1.5, -0.0, f64::NAN, f64::INFINITY, 1e-308, f64::from_bits(0x7ff80000dead0001)],
        };
        let bytes = f.encode();
        assert_eq!(bytes.len(), Frame::wire_bytes(f.data.len()));
        let g = Frame::decode_from(&mut bytes.as_slice()).unwrap().unwrap();
        assert_eq!(g.seq, 7);
        assert_eq!(g.bucket, 3);
        assert_eq!(g.from, 5);
        // bit compare: NaN != NaN under PartialEq, the wire must keep bits
        let a: Vec<u64> = f.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = g.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn abort_frame_round_trips_and_streams_chain() {
        let abort = Frame { seq: 1, bucket: 0, from: 2, data: vec![] };
        let real = Frame { seq: 1, bucket: 1, from: 2, data: vec![42.0] };
        let mut wire = abort.encode();
        wire.extend_from_slice(&real.encode());
        let mut r = wire.as_slice();
        let a = Frame::decode_from(&mut r).unwrap().unwrap();
        assert!(a.is_abort());
        let b = Frame::decode_from(&mut r).unwrap().unwrap();
        assert!(!b.is_abort());
        assert_eq!(b.data, vec![42.0]);
        assert!(Frame::decode_from(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_silent_eof() {
        let f = Frame { seq: 1, bucket: 0, from: 1, data: vec![1.0, 2.0] };
        let bytes = f.encode();
        let mut r = &bytes[..bytes.len() - 3];
        assert!(Frame::decode_from(&mut r).is_err());
        let mut r = &bytes[..FRAME_HEADER_BYTES - 2];
        assert!(Frame::decode_from(&mut r).is_err());
    }

    #[test]
    fn oversized_header_is_rejected_before_allocation() {
        // hand-craft a header claiming u32::MAX elements (a 32 GiB body)
        let mut wire = Vec::new();
        wire.extend_from_slice(&1u64.to_le_bytes()); // seq
        wire.extend_from_slice(&0u32.to_le_bytes()); // bucket
        wire.extend_from_slice(&1u32.to_le_bytes()); // from
        wire.extend_from_slice(&u32::MAX.to_le_bytes()); // nelems
        let err = Frame::decode_from_bounded(&mut wire.as_slice(), Some(512)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("bounded at 512"), "{err}");
    }

    #[test]
    fn bounded_decode_accepts_frames_at_the_bound() {
        let f = Frame { seq: 1, bucket: 0, from: 1, data: vec![1.0, 2.0, 3.0] };
        let bytes = f.encode();
        let g = Frame::decode_from_bounded(&mut bytes.as_slice(), Some(3)).unwrap().unwrap();
        assert_eq!(g.data, f.data);
        assert!(Frame::decode_from_bounded(&mut bytes.as_slice(), Some(2)).is_err());
    }

    #[test]
    fn stash_replays_by_key_and_gcs_stale_steps() {
        let mut st = FrameStash::default();
        st.put(Frame { seq: 1, bucket: 0, from: 3, data: vec![1.0] });
        st.put(Frame { seq: 2, bucket: 0, from: 3, data: vec![2.0] });
        assert_eq!(st.len(), 2);
        assert!(st.take(2, 0, 1).is_none());
        assert_eq!(st.take(2, 0, 3).unwrap(), vec![2.0]);
        st.gc_below(2);
        assert!(st.is_empty(), "seq-1 residue collected");
    }
}
