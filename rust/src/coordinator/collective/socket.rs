//! Socket collective transport: length-prefixed frames over loopback TCP
//! with a rendezvous file — the Gloo-shaped, multi-process-capable impl.
//!
//! Rendezvous: every rank with bracket children binds a listener on
//! `127.0.0.1:0` and appends `"<rank> <addr>\n"` to the rendezvous file
//! (`O_APPEND`, one small write per rank — atomic on every platform we
//! target).  A non-root rank polls the file for its bracket parent's line,
//! dials it, and sends a 4-byte little-endian hello carrying its rank.
//! Because every rank publishes *before* dialing its own parent, and a TCP
//! connect succeeds against a bound listener's backlog even before
//! `accept`, the rendezvous cannot deadlock; all waits are bounded by
//! [`CONNECT_TIMEOUT`].  Only `\n`-terminated lines are ever parsed (a
//! concurrent `O_APPEND` writer can be mid-flush when we `read`), a
//! duplicate line for the same rank is a hard error (stale file from a
//! crashed run), and a `run <id>` header pins the file to one run
//! generation ([`SocketOptions::run_id`]).
//!
//! Accepting: the hello is verified *inside* the accept loop, before the
//! connection counts against the expected-children tally — a foreign or
//! duplicate dialer (port scanner, stale peer from a previous run) is
//! dropped on the floor and the loop keeps waiting for the genuine
//! children.  (It used to count at `accept()` and let the reader thread
//! discard impostors, which permanently consumed an accept slot and turned
//! the real child's link into a 20 s timeout.)
//!
//! Delivery: one reader thread per accepted child connection decodes
//! [`Frame`]s into a shared in-process channel, so receive-side semantics
//! (stash-and-replay keyed `(seq, bucket, from)`) are *identical* to the
//! in-process transport — the transports differ only in how bytes move,
//! never in fold order.  Reader threads exit on clean EOF when the child's
//! endpoint drops at pool teardown.
//!
//! Failure bounds ([`SocketOptions::deadline`]): the parent stream gets an
//! OS write timeout (a dead parent's full socket buffer no longer blocks
//! `write_all` forever) and `recv` waits at most the deadline for a frame
//! (a dead *child's* reader thread exits, but the other readers' sender
//! clones keep the shared channel alive, so an untimed `recv` would hang).
//! Frame payload sizes are bounded by [`SocketOptions::max_frame_elems`]
//! before allocation.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::{recv_frame, try_take_frame, Collective, Frame, FrameStash};
use crate::coordinator::dist::{reduce_children, reduce_parent};

/// Upper bound on every rendezvous wait (parent line appearing, child
/// connections arriving).  Generous for a loopback single host; a missing
/// peer surfaces as an error here instead of a hang.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(20);

/// How long an accepted connection gets to produce its 4-byte hello before
/// it is dropped as a silent foreign dialer.
const HELLO_TIMEOUT: Duration = Duration::from_secs(2);

const POLL: Duration = Duration::from_millis(2);

/// Run-scoped hardening knobs for [`SocketCollective::connect_opts`].
/// The default (`SocketOptions::default()`) reproduces the PR 9 behavior:
/// unbounded frames, untimed waits, no generation check.
#[derive(Clone, Debug, Default)]
pub struct SocketOptions {
    /// Upper bound on a decoded frame's payload element count.  The pool
    /// sets this to the step's flat gradient length (plus control-plane
    /// slack), so a corrupt or hostile header cannot drive a 32 GiB
    /// allocation.  `None` = unbounded.
    pub max_frame_elems: Option<usize>,
    /// Per-peer read/write deadline: `send_up` to a dead parent and `recv`
    /// from a dead child error after this long instead of hanging.
    /// `None` = wait forever (single-process pool threads, where a dead
    /// peer is a panic that aborts the run anyway).
    pub deadline: Option<Duration>,
    /// Run generation this endpoint belongs to.  When set, the rendezvous
    /// file must open with a matching `run <id>` header (written by the
    /// launcher via [`write_run_header`]) — joining a stale file left by a
    /// crashed or concurrent run is refused instead of silently dialing
    /// its dead listeners.
    pub run_id: Option<String>,
}

/// One rank's endpoint on the socket bucket tree.
pub struct SocketCollective {
    rank: usize,
    n_ranks: usize,
    parent: Option<TcpStream>,
    rx: mpsc::Receiver<Frame>,
    stash: FrameStash,
    deadline: Option<Duration>,
}

impl SocketCollective {
    /// Join the rendezvous at `path` as `rank` of `n_ranks` with default
    /// [`SocketOptions`].  Every rank must call this concurrently (the
    /// pool runs the connects on parallel builder threads); returns once
    /// this rank's parent link is dialed and all child links are accepted.
    pub fn connect(path: &Path, rank: usize, n_ranks: usize) -> crate::Result<SocketCollective> {
        Self::connect_opts(path, rank, n_ranks, &SocketOptions::default())
    }

    /// [`SocketCollective::connect`] with explicit hardening options —
    /// the multi-process launcher path.
    pub fn connect_opts(
        path: &Path,
        rank: usize,
        n_ranks: usize,
        opts: &SocketOptions,
    ) -> crate::Result<SocketCollective> {
        // 0. refuse to join a rendezvous from a different run generation
        if let Some(id) = &opts.run_id {
            wait_for_run_header(path, id)?;
        }
        let children: Vec<usize> =
            reduce_children(rank, n_ranks).into_iter().map(|(_, src)| src).collect();
        // 1. publish before dialing anyone, so parents are always findable
        let listener = if children.is_empty() {
            None
        } else {
            let l = TcpListener::bind("127.0.0.1:0")?;
            let addr = l.local_addr()?;
            let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
            f.write_all(format!("{rank} {addr}\n").as_bytes())?;
            Some(l)
        };
        // 2. dial the bracket parent (poll the rendezvous for its line)
        let parent = match reduce_parent(rank) {
            None => None,
            Some(p) => {
                let addr = wait_for_line(path, p)?;
                let mut s = TcpStream::connect(addr.as_str())
                    .map_err(|e| anyhow::anyhow!("rank {rank} dialing parent {p} at {addr}: {e}"))?;
                s.set_nodelay(true)?;
                // a dead parent's full socket buffer must not block
                // `write_all` forever
                s.set_write_timeout(opts.deadline)?;
                s.write_all(&(rank as u32).to_le_bytes())?; // hello
                Some(s)
            }
        };
        // 3. accept connections until every bracket child has identified
        // itself by hello; each genuine child gets a reader thread
        // decoding frames into one shared channel.  Foreign, duplicate, or
        // silent dialers are dropped without consuming an accept slot.
        let (tx, rx) = mpsc::channel::<Frame>();
        if let Some(l) = listener {
            l.set_nonblocking(true)?;
            let deadline = Instant::now() + CONNECT_TIMEOUT;
            let mut pending = children.clone();
            while !pending.is_empty() {
                match l.accept() {
                    Ok((mut s, _)) => {
                        s.set_nonblocking(false)?;
                        s.set_read_timeout(Some(HELLO_TIMEOUT))?;
                        let mut hello = [0u8; 4];
                        if std::io::Read::read_exact(&mut s, &mut hello).is_err() {
                            continue; // silent or half-open dialer: not a child
                        }
                        let from = u32::from_le_bytes(hello) as usize;
                        let Some(i) = pending.iter().position(|&c| c == from) else {
                            continue; // foreign rank or duplicate hello: drop
                        };
                        pending.swap_remove(i);
                        s.set_read_timeout(None)?;
                        s.set_nodelay(true)?;
                        spawn_reader(rank, from, s, tx.clone(), opts.max_frame_elems)?;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "rank {rank}: children {pending:?} did not connect within {:?}",
                            CONNECT_TIMEOUT
                        );
                        std::thread::sleep(POLL);
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        Ok(SocketCollective {
            rank,
            n_ranks,
            parent,
            rx,
            stash: FrameStash::default(),
            deadline: opts.deadline,
        })
    }

    /// A fresh collision-free rendezvous path in the system temp dir.
    pub fn fresh_rendezvous(tag: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static IDS: AtomicU64 = AtomicU64::new(0);
        let id = IDS.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("tt-rdv-{}-{}-{tag}.txt", std::process::id(), id))
    }
}

/// Stamp `path` with the `run <id>` generation header.  The launcher calls
/// this once before spawning rank processes; children pass the same id via
/// [`SocketOptions::run_id`] and refuse any file carrying a different one.
pub fn write_run_header(path: &Path, run_id: &str) -> crate::Result<()> {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(format!("run {run_id}\n").as_bytes())?;
    Ok(())
}

/// Iterate only the *complete* (`\n`-terminated) lines of a rendezvous
/// snapshot.  `read_to_string` races the `O_APPEND` writers, so the last
/// line may be torn mid-address — parsing it would dial a truncated port.
fn complete_lines(text: &str) -> impl Iterator<Item = &str> {
    text.split_inclusive('\n').filter(|l| l.ends_with('\n')).map(|l| l.trim_end())
}

/// Poll the rendezvous file until its `run <id>` header appears, and error
/// if it names a different generation.
fn wait_for_run_header(path: &Path, run_id: &str) -> crate::Result<()> {
    let deadline = Instant::now() + CONNECT_TIMEOUT;
    loop {
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in complete_lines(&text) {
                if let Some(id) = line.strip_prefix("run ") {
                    anyhow::ensure!(
                        id == run_id,
                        "rendezvous {} belongs to run generation {id:?}, not {run_id:?} — \
                         stale file from another run; refusing to join",
                        path.display()
                    );
                    return Ok(());
                }
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "rendezvous {}: no `run` header within {:?}",
            path.display(),
            CONNECT_TIMEOUT
        );
        std::thread::sleep(POLL);
    }
}

/// Poll the rendezvous file until `rank`'s `"<rank> <addr>"` line appears.
/// Only `\n`-terminated lines count (see [`complete_lines`]); two complete
/// lines claiming the same rank mean a stale file from a crashed run and
/// are a hard error rather than a coin-flip dial.
fn wait_for_line(path: &Path, rank: usize) -> crate::Result<String> {
    let prefix = format!("{rank} ");
    let deadline = Instant::now() + CONNECT_TIMEOUT;
    loop {
        if let Ok(text) = std::fs::read_to_string(path) {
            let mut found: Option<String> = None;
            for line in complete_lines(&text) {
                if let Some(addr) = line.strip_prefix(&prefix) {
                    anyhow::ensure!(
                        found.is_none(),
                        "rendezvous {}: duplicate line for rank {rank} — stale file from a \
                         crashed run; remove it (or use a run id) and retry",
                        path.display()
                    );
                    found = Some(addr.trim().to_string());
                }
            }
            if let Some(addr) = found {
                return Ok(addr);
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "rendezvous {}: no line for rank {rank} within {:?}",
            path.display(),
            CONNECT_TIMEOUT
        );
        std::thread::sleep(POLL);
    }
}

/// Reader thread for one verified child connection: decode frames into the
/// shared channel until clean EOF.  A decode error (torn stream, oversized
/// header) drops the sender clone; the blocked receiver surfaces it as a
/// deadline timeout or disconnect instead of a hang.
fn spawn_reader(
    rank: usize,
    from: usize,
    mut s: TcpStream,
    tx: mpsc::Sender<Frame>,
    max_elems: Option<usize>,
) -> crate::Result<()> {
    std::thread::Builder::new()
        .name(format!("tt-coll-rx-{rank}-{from}"))
        .spawn(move || {
            while let Ok(Some(f)) = Frame::decode_from_bounded(&mut s, max_elems) {
                if tx.send(f).is_err() {
                    return; // endpoint dropped: stop reading
                }
            }
        })
        .map_err(|e| anyhow::anyhow!("spawn collective reader: {e}"))?;
    Ok(())
}

impl Collective for SocketCollective {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send_up(&mut self, seq: u64, bucket: u32, data: &[f64]) -> crate::Result<usize> {
        let rank = self.rank;
        let s = self
            .parent
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("rank 0 is the reduce root and has no parent"))?;
        let frame = Frame { seq, bucket, from: rank as u32, data: data.to_vec() };
        let bytes = frame.encode();
        s.write_all(&bytes)
            .map_err(|e| anyhow::anyhow!("rank {rank} bucket {bucket} send: {e}"))?;
        Ok(bytes.len())
    }

    fn try_take(&mut self, seq: u64, bucket: u32, src: usize) -> Option<Frame> {
        try_take_frame(&self.rx, &mut self.stash, seq, bucket, src)
    }

    fn recv(&mut self, seq: u64, bucket: u32, src: usize) -> crate::Result<Frame> {
        recv_frame(&self.rx, &mut self.stash, seq, bucket, src, self.deadline)
    }

    fn gc_below(&mut self, seq: u64) {
        self.stash.gc_below(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Connect `n` endpoints concurrently on scratch threads, returning
    /// them rank-ordered.
    fn mesh(n: usize, tag: &str) -> Vec<SocketCollective> {
        let path = SocketCollective::fresh_rendezvous(tag);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let p = path.clone();
                std::thread::spawn(move || SocketCollective::connect(&p, r, n).unwrap())
            })
            .collect();
        let out = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let _ = std::fs::remove_file(&path);
        out
    }

    #[test]
    fn two_rank_round_trip_preserves_bits() {
        let mut m = mesh(2, "pair");
        let mut c1 = m.remove(1);
        let mut c0 = m.remove(0);
        let payload = vec![1.5, f64::NAN, -0.0, 1e300];
        let sent = c1.send_up(3, 0, &payload).unwrap();
        assert_eq!(sent, Frame::wire_bytes(4));
        let f = c0.recv(3, 0, 1).unwrap();
        let a: Vec<u64> = payload.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = f.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn five_rank_tree_delivers_all_buckets_in_bracket_order() {
        // bracket for n=5: children of 0 are [1, 2, 4]; of 2: [3]
        let mut m = mesh(5, "tree");
        let mut c4 = m.remove(4);
        let mut c3 = m.remove(3);
        let mut c2 = m.remove(2);
        let mut c1 = m.remove(1);
        let mut c0 = m.remove(0);
        for b in 0..2u32 {
            c3.send_up(1, b, &[3.0 + b as f64]).unwrap();
            c4.send_up(1, b, &[4.0 + b as f64]).unwrap();
            c1.send_up(1, b, &[1.0 + b as f64]).unwrap();
        }
        for b in 0..2u32 {
            let f = c2.recv(1, b, 3).unwrap();
            c2.send_up(1, b, &[2.0 + b as f64 + f.data[0]]).unwrap();
        }
        for b in 0..2u32 {
            assert_eq!(c0.recv(1, b, 1).unwrap().data, vec![1.0 + b as f64]);
            assert_eq!(c0.recv(1, b, 2).unwrap().data, vec![5.0 + 2.0 * b as f64]);
            assert_eq!(c0.recv(1, b, 4).unwrap().data, vec![4.0 + b as f64]);
        }
    }

    #[test]
    fn abort_frames_cross_the_wire() {
        let mut m = mesh(2, "abort");
        let mut c1 = m.remove(1);
        let mut c0 = m.remove(0);
        c1.send_abort(9, 2).unwrap();
        let f = c0.recv(9, 2, 1).unwrap();
        assert!(f.is_abort());
    }

    #[test]
    fn torn_final_line_is_not_parsed_until_terminated() {
        let path = SocketCollective::fresh_rendezvous("torn");
        // the O_APPEND writer is "mid-flush": address cut inside the port
        std::fs::write(&path, "0 127.0.0.1:4").unwrap();
        let p = path.clone();
        let h = std::thread::spawn(move || wait_for_line(&p, 0));
        // give the poller time to read the torn snapshot; it must keep
        // waiting rather than return the truncated address
        std::thread::sleep(Duration::from_millis(50));
        assert!(!h.is_finished(), "torn line was parsed as an address");
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"2567\n").unwrap();
        drop(f);
        assert_eq!(h.join().unwrap().unwrap(), "127.0.0.1:42567");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn duplicate_rank_lines_are_a_hard_error() {
        let path = SocketCollective::fresh_rendezvous("dup");
        std::fs::write(&path, "0 127.0.0.1:1111\n0 127.0.0.1:2222\n").unwrap();
        let err = wait_for_line(&path, 0).unwrap_err();
        assert!(err.to_string().contains("duplicate line for rank 0"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_header_pins_the_generation() {
        let path = SocketCollective::fresh_rendezvous("gen");
        write_run_header(&path, "gen-A").unwrap();
        assert!(wait_for_run_header(&path, "gen-A").is_ok());
        let err = wait_for_run_header(&path, "gen-B").unwrap_err();
        assert!(err.to_string().contains("gen-A"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
