//! Socket collective transport: length-prefixed frames over loopback TCP
//! with a rendezvous file — the Gloo-shaped, multi-process-capable impl.
//!
//! Rendezvous: every rank with bracket children binds a listener on
//! `127.0.0.1:0` and appends `"<rank> <addr>\n"` to the rendezvous file
//! (`O_APPEND`, one small write per rank — atomic on every platform we
//! target).  A non-root rank polls the file for its bracket parent's line,
//! dials it, and sends a 4-byte little-endian hello carrying its rank.
//! Because every rank publishes *before* dialing its own parent, and a TCP
//! connect succeeds against a bound listener's backlog even before
//! `accept`, the rendezvous cannot deadlock; all waits are bounded by
//! [`CONNECT_TIMEOUT`].
//!
//! Delivery: one reader thread per accepted child connection decodes
//! [`Frame`]s into a shared in-process channel, so receive-side semantics
//! (stash-and-replay keyed `(seq, bucket, from)`) are *identical* to the
//! in-process transport — the transports differ only in how bytes move,
//! never in fold order.  Reader threads exit on clean EOF when the child's
//! endpoint drops at pool teardown.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::{recv_frame, try_take_frame, Collective, Frame, FrameStash};
use crate::coordinator::dist::{reduce_children, reduce_parent};

/// Upper bound on every rendezvous wait (parent line appearing, child
/// connections arriving).  Generous for a loopback single host; a missing
/// peer surfaces as an error here instead of a hang.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(20);

const POLL: Duration = Duration::from_millis(2);

/// One rank's endpoint on the socket bucket tree.
pub struct SocketCollective {
    rank: usize,
    n_ranks: usize,
    parent: Option<TcpStream>,
    rx: mpsc::Receiver<Frame>,
    stash: FrameStash,
}

impl SocketCollective {
    /// Join the rendezvous at `path` as `rank` of `n_ranks`.  Every rank
    /// must call this concurrently (the pool runs the connects on parallel
    /// builder threads); returns once this rank's parent link is dialed
    /// and all child links are accepted.
    pub fn connect(path: &Path, rank: usize, n_ranks: usize) -> crate::Result<SocketCollective> {
        let children: Vec<usize> =
            reduce_children(rank, n_ranks).into_iter().map(|(_, src)| src).collect();
        // 1. publish before dialing anyone, so parents are always findable
        let listener = if children.is_empty() {
            None
        } else {
            let l = TcpListener::bind("127.0.0.1:0")?;
            let addr = l.local_addr()?;
            let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
            f.write_all(format!("{rank} {addr}\n").as_bytes())?;
            Some(l)
        };
        // 2. dial the bracket parent (poll the rendezvous for its line)
        let parent = match reduce_parent(rank) {
            None => None,
            Some(p) => {
                let addr = wait_for_line(path, p)?;
                let mut s = TcpStream::connect(addr.as_str())
                    .map_err(|e| anyhow::anyhow!("rank {rank} dialing parent {p} at {addr}: {e}"))?;
                s.set_nodelay(true)?;
                s.write_all(&(rank as u32).to_le_bytes())?; // hello
                Some(s)
            }
        };
        // 3. accept one connection per bracket child; each gets a reader
        // thread decoding frames into one shared channel
        let (tx, rx) = mpsc::channel::<Frame>();
        if let Some(l) = listener {
            l.set_nonblocking(true)?;
            let deadline = Instant::now() + CONNECT_TIMEOUT;
            let mut accepted = 0usize;
            while accepted < children.len() {
                match l.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        spawn_reader(rank, s, children.clone(), tx.clone())?;
                        accepted += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "rank {rank}: only {accepted}/{} children connected within {:?}",
                            children.len(),
                            CONNECT_TIMEOUT
                        );
                        std::thread::sleep(POLL);
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        Ok(SocketCollective { rank, n_ranks, parent, rx, stash: FrameStash::default() })
    }

    /// A fresh collision-free rendezvous path in the system temp dir.
    pub fn fresh_rendezvous(tag: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static IDS: AtomicU64 = AtomicU64::new(0);
        let id = IDS.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("tt-rdv-{}-{}-{tag}.txt", std::process::id(), id))
    }
}

/// Poll the rendezvous file until `rank`'s `"<rank> <addr>"` line appears.
fn wait_for_line(path: &Path, rank: usize) -> crate::Result<String> {
    let prefix = format!("{rank} ");
    let deadline = Instant::now() + CONNECT_TIMEOUT;
    loop {
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                if let Some(addr) = line.strip_prefix(&prefix) {
                    return Ok(addr.trim().to_string());
                }
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "rendezvous {}: no line for rank {rank} within {:?}",
            path.display(),
            CONNECT_TIMEOUT
        );
        std::thread::sleep(POLL);
    }
}

/// Reader thread: verify the hello names a bracket child, then decode
/// frames into the shared channel until clean EOF.  A decode error or a
/// foreign hello drops the sender, which surfaces as "peer disconnected"
/// at the blocked receiver instead of a hang.
fn spawn_reader(
    rank: usize,
    mut s: TcpStream,
    children: Vec<usize>,
    tx: mpsc::Sender<Frame>,
) -> crate::Result<()> {
    std::thread::Builder::new()
        .name(format!("tt-coll-rx-{rank}"))
        .spawn(move || {
            let mut hello = [0u8; 4];
            if std::io::Read::read_exact(&mut s, &mut hello).is_err() {
                return;
            }
            let from = u32::from_le_bytes(hello) as usize;
            if !children.contains(&from) {
                return; // foreign connection: drop it, starve the recv
            }
            while let Ok(Some(f)) = Frame::decode_from(&mut s) {
                if tx.send(f).is_err() {
                    return; // endpoint dropped: stop reading
                }
            }
        })
        .map_err(|e| anyhow::anyhow!("spawn collective reader: {e}"))?;
    Ok(())
}

impl Collective for SocketCollective {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send_up(&mut self, seq: u64, bucket: u32, data: &[f64]) -> crate::Result<usize> {
        let rank = self.rank;
        let s = self
            .parent
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("rank 0 is the reduce root and has no parent"))?;
        let frame = Frame { seq, bucket, from: rank as u32, data: data.to_vec() };
        let bytes = frame.encode();
        s.write_all(&bytes)
            .map_err(|e| anyhow::anyhow!("rank {rank} bucket {bucket} send: {e}"))?;
        Ok(bytes.len())
    }

    fn try_take(&mut self, seq: u64, bucket: u32, src: usize) -> Option<Frame> {
        try_take_frame(&self.rx, &mut self.stash, seq, bucket, src)
    }

    fn recv(&mut self, seq: u64, bucket: u32, src: usize) -> crate::Result<Frame> {
        recv_frame(&self.rx, &mut self.stash, seq, bucket, src)
    }

    fn gc_below(&mut self, seq: u64) {
        self.stash.gc_below(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Connect `n` endpoints concurrently on scratch threads, returning
    /// them rank-ordered.
    fn mesh(n: usize, tag: &str) -> Vec<SocketCollective> {
        let path = SocketCollective::fresh_rendezvous(tag);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let p = path.clone();
                std::thread::spawn(move || SocketCollective::connect(&p, r, n).unwrap())
            })
            .collect();
        let out = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let _ = std::fs::remove_file(&path);
        out
    }

    #[test]
    fn two_rank_round_trip_preserves_bits() {
        let mut m = mesh(2, "pair");
        let mut c1 = m.remove(1);
        let mut c0 = m.remove(0);
        let payload = vec![1.5, f64::NAN, -0.0, 1e300];
        let sent = c1.send_up(3, 0, &payload).unwrap();
        assert_eq!(sent, Frame::wire_bytes(4));
        let f = c0.recv(3, 0, 1).unwrap();
        let a: Vec<u64> = payload.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = f.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn five_rank_tree_delivers_all_buckets_in_bracket_order() {
        // bracket for n=5: children of 0 are [1, 2, 4]; of 2: [3]
        let mut m = mesh(5, "tree");
        let mut c4 = m.remove(4);
        let mut c3 = m.remove(3);
        let mut c2 = m.remove(2);
        let mut c1 = m.remove(1);
        let mut c0 = m.remove(0);
        for b in 0..2u32 {
            c3.send_up(1, b, &[3.0 + b as f64]).unwrap();
            c4.send_up(1, b, &[4.0 + b as f64]).unwrap();
            c1.send_up(1, b, &[1.0 + b as f64]).unwrap();
        }
        for b in 0..2u32 {
            let f = c2.recv(1, b, 3).unwrap();
            c2.send_up(1, b, &[2.0 + b as f64 + f.data[0]]).unwrap();
        }
        for b in 0..2u32 {
            assert_eq!(c0.recv(1, b, 1).unwrap().data, vec![1.0 + b as f64]);
            assert_eq!(c0.recv(1, b, 2).unwrap().data, vec![5.0 + 2.0 * b as f64]);
            assert_eq!(c0.recv(1, b, 4).unwrap().data, vec![4.0 + b as f64]);
        }
    }

    #[test]
    fn abort_frames_cross_the_wire() {
        let mut m = mesh(2, "abort");
        let mut c1 = m.remove(1);
        let mut c0 = m.remove(0);
        c1.send_abort(9, 2).unwrap();
        let f = c0.recv(9, 2, 1).unwrap();
        assert!(f.is_abort());
    }
}
