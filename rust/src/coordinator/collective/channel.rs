//! In-process collective transport: the reference [`Collective`] impl.
//!
//! A bus of `mpsc` channels, one receiver per rank; each non-root rank
//! holds a sender to its bracket parent.  No serialization — frames move
//! as owned `Vec<f64>`s — but the byte accounting uses the same wire
//! format arithmetic as the socket transport so `collective_bytes` is
//! comparable across transports.

use std::sync::mpsc;

use super::{recv_frame, try_take_frame, Collective, Frame, FrameStash};
use crate::coordinator::dist::reduce_parent;

/// One rank's endpoint on the in-process bucket bus.
pub struct ChannelCollective {
    rank: usize,
    n_ranks: usize,
    parent_tx: Option<mpsc::Sender<Frame>>,
    rx: mpsc::Receiver<Frame>,
    stash: FrameStash,
}

impl ChannelCollective {
    /// Build the full bus: one endpoint per rank, wired along the reduce
    /// bracket (`endpoints[r]` is rank `r`'s).  Endpoints are `Send` and
    /// meant to be moved onto the rank worker threads.
    pub fn bus(n_ranks: usize) -> Vec<ChannelCollective> {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n_ranks).map(|_| mpsc::channel::<Frame>()).unzip();
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| ChannelCollective {
                rank,
                n_ranks,
                parent_tx: reduce_parent(rank).map(|p| txs[p].clone()),
                rx,
                stash: FrameStash::default(),
            })
            .collect()
    }
}

impl Collective for ChannelCollective {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send_up(&mut self, seq: u64, bucket: u32, data: &[f64]) -> crate::Result<usize> {
        let tx = self
            .parent_tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("rank 0 is the reduce root and has no parent"))?;
        let bytes = Frame::wire_bytes(data.len());
        tx.send(Frame { seq, bucket, from: self.rank as u32, data: data.to_vec() })
            .map_err(|_| anyhow::anyhow!("collective parent of rank {} disconnected", self.rank))?;
        Ok(bytes)
    }

    fn try_take(&mut self, seq: u64, bucket: u32, src: usize) -> Option<Frame> {
        try_take_frame(&self.rx, &mut self.stash, seq, bucket, src)
    }

    fn recv(&mut self, seq: u64, bucket: u32, src: usize) -> crate::Result<Frame> {
        // no deadline: a dead in-process peer drops the only sender clone,
        // so `recv()` itself errors — the socket-only hang can't happen here
        recv_frame(&self.rx, &mut self.stash, seq, bucket, src, None)
    }

    fn gc_below(&mut self, seq: u64) {
        self.stash.gc_below(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_route_to_the_bracket_parent() {
        // n = 4 bracket: 1 → 0, 3 → 2, 2 → 0
        let mut bus = ChannelCollective::bus(4);
        let mut c3 = bus.remove(3);
        let mut c2 = bus.remove(2);
        let mut c1 = bus.remove(1);
        let mut c0 = bus.remove(0);
        c1.send_up(1, 0, &[10.0]).unwrap();
        c3.send_up(1, 0, &[30.0]).unwrap();
        let f = c2.recv(1, 0, 3).unwrap();
        assert_eq!(f.data, vec![30.0]);
        c2.send_up(1, 0, &[30.0 + 2.0]).unwrap();
        assert_eq!(c0.recv(1, 0, 1).unwrap().data, vec![10.0]);
        assert_eq!(c0.recv(1, 0, 2).unwrap().data, vec![32.0]);
    }

    #[test]
    fn out_of_order_arrivals_wait_in_the_stash() {
        let mut bus = ChannelCollective::bus(2);
        let mut c1 = bus.remove(1);
        let mut c0 = bus.remove(0);
        // bucket 1 lands before bucket 0; recv order is still 0 then 1
        c1.send_up(5, 1, &[2.0]).unwrap();
        c1.send_up(5, 0, &[1.0]).unwrap();
        assert_eq!(c0.recv(5, 0, 1).unwrap().data, vec![1.0]);
        assert_eq!(c0.recv(5, 1, 1).unwrap().data, vec![2.0]);
    }

    #[test]
    fn try_take_is_non_blocking_and_keyed() {
        let mut bus = ChannelCollective::bus(2);
        let mut c1 = bus.remove(1);
        let mut c0 = bus.remove(0);
        assert!(c0.try_take(1, 0, 1).is_none());
        c1.send_up(1, 0, &[7.0]).unwrap();
        // wrong key leaves the frame parked
        assert!(c0.try_take(1, 1, 1).is_none());
        assert_eq!(c0.try_take(1, 0, 1).unwrap().data, vec![7.0]);
    }

    #[test]
    fn root_send_is_a_protocol_error() {
        let mut bus = ChannelCollective::bus(2);
        let mut c0 = bus.remove(0);
        assert!(c0.send_up(1, 0, &[1.0]).is_err());
    }

    #[test]
    fn stale_seq_frames_are_skipped_by_recv() {
        let mut bus = ChannelCollective::bus(2);
        let mut c1 = bus.remove(1);
        let mut c0 = bus.remove(0);
        c1.send_up(1, 0, &[1.0]).unwrap(); // aborted step's frame
        c1.send_up(2, 0, &[2.0]).unwrap();
        assert_eq!(c0.recv(2, 0, 1).unwrap().data, vec![2.0]);
    }
}
