//! Multi-process rank launcher over the collective wire
//! (docs/distributed.md#multi-process-launch).
//!
//! PR 9's socket [`Collective`] was built so no rank needs a shared address
//! space; this module is the step that actually takes it there.  A parent
//! **launcher** process spawns one `tree-train rank-worker` OS process per
//! rank, hands each the shared run config plus the rendezvous path, and
//! drives the same pipelined step loop the in-process pool runs — with the
//! typed control plane (errors, execute/merge walls, scalar sums, cache
//! stats, loss digests) serialized as length-prefixed [`Frame`]s alongside
//! the f64 data plane, on the same sockets.
//!
//! Two control links exist:
//!
//! * **The star** — every rank dials the launcher's listener (4-byte rank
//!   hello, then [`StarMsg`] frames both ways): `Ready`/`Heartbeat`/
//!   `Result`/`Err`/`Done` up, the broadcast `Apply` update down.
//! * **The mesh** — the bracket mesh of [`SocketCollective`]s, shared with
//!   the gradient data plane: data buckets use dense indices `0..n`, the
//!   typed per-rank accumulators (payload-stripped, [`MeshMsg`]) travel as
//!   bucket [`CTRL_BUCKET`] up the identical bracket, so the scalar/digest
//!   fold order is the in-process `worker_loop`'s, frame for frame.
//!
//! **Determinism.**  Planning is a pure function of `(seed, step)`, so
//! every rank process re-derives the parent's plans from the same corpus
//! and config instead of shipping them; replicas start from the same
//! seeded model and apply the identical broadcast update expression.  With
//! the PR 9 contract (every `(bucket, transport)` config folds bit-identically
//! to the monolithic typed path), `launch --ranks N` reproduces the
//! in-process pool's losses and fingerprints bit for bit — the gate
//! `tree-train launch` enforces.  Calibrated cost models are excluded by
//! construction (the launch path always plans with token costs): feeding
//! *measured* walls back into placement would fork the ranks' plans.
//!
//! **Failure.**  Children heartbeat over the star; the parent converts a
//! vanished process (star EOF, `try_wait` exit, heartbeat silence) into a
//! named-rank error within the deadline.  Inside the mesh, a dead peer
//! surfaces through the socket collective's per-peer deadline
//! ([`SocketOptions::deadline`]) and the PR 9 abort-marker path, so
//! surviving ranks unwind and exit instead of deadlocking; their exits are
//! in turn caught by the watchdog.  Rendezvous files live in one GC'd
//! directory, are keyed by a fresh run id, and carry a `run <id>` header
//! so a rank can never join a stale generation.

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::collective::socket::{self, SocketCollective, SocketOptions};
use crate::coordinator::collective::{Collective, Frame};
use crate::coordinator::dist::{self, reduce_children, reduce_depth, reduce_parent, RankWorker};
use crate::coordinator::pipeline::{
    self, fnv1a, HostRankAcc, HostUpdate, HostWorker, PipelineConfig, PipelineSummary,
    PlannedStep, StepExecutor,
};
use crate::coordinator::Mode;
use crate::data::CorpusSource;
use crate::trainer::planner::PlanSpec;
use crate::trainer::prefix_cache::{reuse_ratio, CacheStats, PrefixCache};
use crate::trainer::refmodel::RefModel;
use crate::trainer::StepMetrics;

/// Bucket id of every control-plane frame on the mesh and the star.  Data
/// buckets are dense indices from 0 and `u32::MAX` is reserved as
/// [`Collective::drain`]'s no-frame key, so this value collides with
/// neither.
pub const CTRL_BUCKET: u32 = u32::MAX - 1;

/// Embedding dim of the hermetic [`RefModel`] replicas (matches the
/// `dist-smoke` harness, so flat payloads are `vocab * HOST_DIM` f64s).
pub const HOST_DIM: usize = 8;

/// Default `--deadline-ms`: per-peer read/write deadline, heartbeat
/// staleness bound, and per-step result timeout.
pub const DEFAULT_DEADLINE_MS: u64 = 30_000;

/// Slack on top of the flat gradient length when bounding frame payloads:
/// covers the control messages' scalar fields, walls and error strings.
const CTRL_SLACK: usize = 4096;

const HEARTBEAT: Duration = Duration::from_millis(500);
const HELLO_TIMEOUT: Duration = Duration::from_secs(5);
const RX_POLL: Duration = Duration::from_millis(100);
const REAP_POLL: Duration = Duration::from_millis(10);

/// Rendezvous files older than this in the launch directory are residue of
/// crashed runs and get collected at the next launch.
const STALE_RDV_AGE: Duration = Duration::from_secs(15 * 60);

// ───────────────────────────── wire codec ──────────────────────────────
//
// Control messages are sequences of u64 words carried as the f64 payload
// of an ordinary collective Frame (`f64::from_bits` per word — both
// directions are pure transmutes in Rust, so arbitrary words survive the
// f64 round trip bit-exactly, NaN patterns included).  Layouts are
// mirrored by python/tests/test_launcher_protocol.py.

pub(crate) const TAG_READY: u64 = 1;
pub(crate) const TAG_HEARTBEAT: u64 = 2;
pub(crate) const TAG_RESULT: u64 = 3;
pub(crate) const TAG_ERR: u64 = 4;
pub(crate) const TAG_DONE: u64 = 5;
pub(crate) const TAG_APPLY: u64 = 6;
pub(crate) const TAG_MESH_ACC: u64 = 8;
pub(crate) const TAG_MESH_ERR: u64 = 9;

struct WordWriter {
    words: Vec<u64>,
}

impl WordWriter {
    fn new(tag: u64) -> Self {
        Self { words: vec![tag] }
    }

    fn u64(&mut self, v: u64) {
        self.words.push(v);
    }

    fn f64(&mut self, v: f64) {
        self.words.push(v.to_bits());
    }

    fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for x in v {
            self.f64(*x);
        }
    }

    /// Length + UTF-8 bytes padded to whole words (zero fill).
    fn str(&mut self, s: &str) {
        let b = s.as_bytes();
        self.u64(b.len() as u64);
        for chunk in b.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            self.words.push(u64::from_le_bytes(w));
        }
    }
}

struct WordReader<'a> {
    words: &'a [u64],
    pos: usize,
}

impl<'a> WordReader<'a> {
    fn new(words: &'a [u64]) -> Self {
        Self { words, pos: 0 }
    }

    fn u64(&mut self) -> crate::Result<u64> {
        let v = *self
            .words
            .get(self.pos)
            .ok_or_else(|| anyhow::anyhow!("truncated control message ({} words)", self.words.len()))?;
        self.pos += 1;
        Ok(v)
    }

    fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f64s(&mut self) -> crate::Result<Vec<f64>> {
        let n = self.u64()? as usize;
        anyhow::ensure!(
            n <= self.words.len().saturating_sub(self.pos),
            "control message claims {n} payload words but only {} remain",
            self.words.len() - self.pos
        );
        (0..n).map(|_| self.f64()).collect()
    }

    fn str(&mut self) -> crate::Result<String> {
        let len = self.u64()? as usize;
        let nwords = len.div_ceil(8);
        anyhow::ensure!(
            nwords <= self.words.len().saturating_sub(self.pos),
            "control message claims a {len}-byte string but the frame is shorter"
        );
        let mut bytes = Vec::with_capacity(len);
        for _ in 0..nwords {
            bytes.extend_from_slice(&self.u64()?.to_le_bytes());
        }
        bytes.truncate(len);
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }
}

/// Wrap control words into a wire [`Frame`] on [`CTRL_BUCKET`].
fn ctrl_frame(seq: u64, from: u32, words: Vec<u64>) -> Frame {
    Frame {
        seq,
        bucket: CTRL_BUCKET,
        from,
        data: words.into_iter().map(f64::from_bits).collect(),
    }
}

/// Recover the control words from a frame's f64 payload.
fn ctrl_words(f: &Frame) -> Vec<u64> {
    f.data.iter().map(|v| v.to_bits()).collect()
}

/// Rank 0's fully-reduced step, shipped launcher-ward over the star: the
/// scalar sums and digests the typed control plane used to hand the root
/// caller in-process, plus the folded flat gradient the launcher
/// broadcasts back in the `Apply`.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct StepResult {
    pub step: u64,
    pub loss_sum: f64,
    pub weight_sum: f64,
    pub d_embed: Vec<f64>,
    pub hash: u64,
    pub batches: u64,
    pub device_tokens: u64,
    /// hits, misses, hit_tokens, evictions.
    pub cache: [u64; 4],
    /// Per-rank execute walls, indexed by rank.
    pub rank_walls: Vec<f64>,
    pub reduce_ms: f64,
    pub reduce_overlap_ms: f64,
    pub bucket_overlap_ms: f64,
    pub collective_bytes: u64,
    pub buckets: u64,
}

/// Control messages on the launcher star.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum StarMsg {
    Ready { rank: u64 },
    Heartbeat { rank: u64, step: u64 },
    Result(Box<StepResult>),
    Err { rank: u64, step: u64, msg: String },
    Done { rank: u64 },
    /// The broadcast end-of-step update (launcher → every rank).
    Apply { step: u64, lr: f64, weight_sum: f64, d_embed: Vec<f64> },
}

impl StarMsg {
    pub(crate) fn encode(&self) -> Vec<u64> {
        match self {
            StarMsg::Ready { rank } => {
                let mut w = WordWriter::new(TAG_READY);
                w.u64(*rank);
                w.words
            }
            StarMsg::Heartbeat { rank, step } => {
                let mut w = WordWriter::new(TAG_HEARTBEAT);
                w.u64(*rank);
                w.u64(*step);
                w.words
            }
            StarMsg::Result(r) => {
                let mut w = WordWriter::new(TAG_RESULT);
                w.u64(r.step);
                w.f64(r.loss_sum);
                w.f64(r.weight_sum);
                w.f64s(&r.d_embed);
                w.u64(r.hash);
                w.u64(r.batches);
                w.u64(r.device_tokens);
                for c in r.cache {
                    w.u64(c);
                }
                w.f64s(&r.rank_walls);
                w.f64(r.reduce_ms);
                w.f64(r.reduce_overlap_ms);
                w.f64(r.bucket_overlap_ms);
                w.u64(r.collective_bytes);
                w.u64(r.buckets);
                w.words
            }
            StarMsg::Err { rank, step, msg } => {
                let mut w = WordWriter::new(TAG_ERR);
                w.u64(*rank);
                w.u64(*step);
                w.str(msg);
                w.words
            }
            StarMsg::Done { rank } => {
                let mut w = WordWriter::new(TAG_DONE);
                w.u64(*rank);
                w.words
            }
            StarMsg::Apply { step, lr, weight_sum, d_embed } => {
                let mut w = WordWriter::new(TAG_APPLY);
                w.u64(*step);
                w.f64(*lr);
                w.f64(*weight_sum);
                w.f64s(d_embed);
                w.words
            }
        }
    }

    pub(crate) fn decode(words: &[u64]) -> crate::Result<StarMsg> {
        let mut r = WordReader::new(words);
        Ok(match r.u64()? {
            TAG_READY => StarMsg::Ready { rank: r.u64()? },
            TAG_HEARTBEAT => StarMsg::Heartbeat { rank: r.u64()?, step: r.u64()? },
            TAG_RESULT => StarMsg::Result(Box::new(StepResult {
                step: r.u64()?,
                loss_sum: r.f64()?,
                weight_sum: r.f64()?,
                d_embed: r.f64s()?,
                hash: r.u64()?,
                batches: r.u64()?,
                device_tokens: r.u64()?,
                cache: [r.u64()?, r.u64()?, r.u64()?, r.u64()?],
                rank_walls: r.f64s()?,
                reduce_ms: r.f64()?,
                reduce_overlap_ms: r.f64()?,
                bucket_overlap_ms: r.f64()?,
                collective_bytes: r.u64()?,
                buckets: r.u64()?,
            })),
            TAG_ERR => StarMsg::Err { rank: r.u64()?, step: r.u64()?, msg: r.str()? },
            TAG_DONE => StarMsg::Done { rank: r.u64()? },
            TAG_APPLY => StarMsg::Apply {
                step: r.u64()?,
                lr: r.f64()?,
                weight_sum: r.f64()?,
                d_embed: r.f64s()?,
            },
            t => anyhow::bail!("unknown star control tag {t}"),
        })
    }
}

/// The typed per-rank accumulator on the mesh (payload-stripped — the
/// d_embed already folded up as data frames) plus the merge accounting the
/// in-process `worker_loop` carries in its `Subtree`.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum MeshMsg {
    Acc {
        loss_sum: f64,
        weight_sum: f64,
        hash: u64,
        batches: u64,
        /// hits, misses, hit_tokens, evictions.
        cache: [u64; 4],
        device_tokens: u64,
        merge_ms: f64,
        /// `(rank, execute wall ms)` pairs gathered in this subtree.
        walls: Vec<(u64, f64)>,
        /// Elapsed ms between this subtree's latest execute-finish and the
        /// moment of encoding — lets the receiver reconstruct a comparable
        /// `exec_end` instant without shipping clocks across processes.
        since_exec_end_ms: f64,
        bucket_overlap_ms: f64,
        collective_bytes: u64,
        buckets: u64,
    },
    Err { rank: u64, msg: String },
}

impl MeshMsg {
    pub(crate) fn encode(&self) -> Vec<u64> {
        match self {
            MeshMsg::Acc {
                loss_sum,
                weight_sum,
                hash,
                batches,
                cache,
                device_tokens,
                merge_ms,
                walls,
                since_exec_end_ms,
                bucket_overlap_ms,
                collective_bytes,
                buckets,
            } => {
                let mut w = WordWriter::new(TAG_MESH_ACC);
                w.f64(*loss_sum);
                w.f64(*weight_sum);
                w.u64(*hash);
                w.u64(*batches);
                for c in cache {
                    w.u64(*c);
                }
                w.u64(*device_tokens);
                w.f64(*merge_ms);
                w.u64(walls.len() as u64);
                for (r, ms) in walls {
                    w.u64(*r);
                    w.f64(*ms);
                }
                w.f64(*since_exec_end_ms);
                w.f64(*bucket_overlap_ms);
                w.u64(*collective_bytes);
                w.u64(*buckets);
                w.words
            }
            MeshMsg::Err { rank, msg } => {
                let mut w = WordWriter::new(TAG_MESH_ERR);
                w.u64(*rank);
                w.str(msg);
                w.words
            }
        }
    }

    pub(crate) fn decode(words: &[u64]) -> crate::Result<MeshMsg> {
        let mut r = WordReader::new(words);
        Ok(match r.u64()? {
            TAG_MESH_ACC => {
                let loss_sum = r.f64()?;
                let weight_sum = r.f64()?;
                let hash = r.u64()?;
                let batches = r.u64()?;
                let cache = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
                let device_tokens = r.u64()?;
                let merge_ms = r.f64()?;
                let n = r.u64()? as usize;
                anyhow::ensure!(n <= words.len(), "mesh acc claims {n} wall pairs");
                let mut walls = Vec::with_capacity(n);
                for _ in 0..n {
                    walls.push((r.u64()?, r.f64()?));
                }
                MeshMsg::Acc {
                    loss_sum,
                    weight_sum,
                    hash,
                    batches,
                    cache,
                    device_tokens,
                    merge_ms,
                    walls,
                    since_exec_end_ms: r.f64()?,
                    bucket_overlap_ms: r.f64()?,
                    collective_bytes: r.u64()?,
                    buckets: r.u64()?,
                }
            }
            TAG_MESH_ERR => MeshMsg::Err { rank: r.u64()?, msg: r.str()? },
            t => anyhow::bail!("unknown mesh control tag {t}"),
        })
    }
}

// ───────────────────────────── launcher (parent) ──────────────────────────────

/// Everything a launch run needs: the shared run geometry (forwarded
/// verbatim to every rank process) plus the launcher's own knobs.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    pub corpus: PathBuf,
    pub format: String,
    pub mode: Mode,
    pub steps: u64,
    pub trees_per_batch: usize,
    pub depth: usize,
    pub window: usize,
    pub capacity: usize,
    pub vocab: usize,
    pub seed: u64,
    pub lr: f64,
    pub warmup: u64,
    pub ranks: usize,
    pub bucket_kb: usize,
    /// Per-peer read/write deadline, heartbeat staleness bound and
    /// per-step result timeout ([`DEFAULT_DEADLINE_MS`]).
    pub deadline: Duration,
    /// Fault injection for the smoke gate: kill rank `.0`'s process when
    /// the parent reaches step `.1` — the run must then fail with an error
    /// naming that rank, within the deadline.
    pub kill: Option<(usize, u64)>,
}

fn mode_name(m: Mode) -> &'static str {
    match m {
        Mode::Tree => "tree",
        Mode::Baseline => "baseline",
    }
}

fn fresh_run_id() -> String {
    static IDS: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!("{}-{nanos:x}-{}", std::process::id(), IDS.fetch_add(1, Ordering::Relaxed))
}

/// The rendezvous directory all launches share, so stale files from
/// crashed runs have one place to be collected from.
fn rendezvous_dir() -> PathBuf {
    std::env::temp_dir().join("tt-launch")
}

/// Remove rendezvous files older than [`STALE_RDV_AGE`] — residue of
/// crashed runs.  Live runs are never touched: their files are younger,
/// and even a collision would be caught by the `run <id>` header check.
fn gc_stale_rendezvous(dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if !(name.starts_with("rdv-") && name.ends_with(".txt")) {
            continue;
        }
        let stale = e
            .metadata()
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok())
            .is_some_and(|age| age > STALE_RDV_AGE);
        if stale {
            let _ = std::fs::remove_file(e.path());
        }
    }
}

enum StarEvent {
    Msg(StarMsg),
    /// The rank's star link closed (process exit or torn stream).
    Gone,
}

/// Parent-side reader: one thread per rank link, decoding star frames into
/// the shared event channel; any EOF or decode error becomes `Gone`.
fn star_reader(
    rank: usize,
    mut s: TcpStream,
    tx: mpsc::Sender<(usize, StarEvent)>,
    max_elems: Option<usize>,
) {
    loop {
        match Frame::decode_from_bounded(&mut s, max_elems) {
            Ok(Some(f)) => match StarMsg::decode(&ctrl_words(&f)) {
                Ok(m) => {
                    if tx.send((rank, StarEvent::Msg(m))).is_err() {
                        return;
                    }
                }
                Err(_) => {
                    let _ = tx.send((rank, StarEvent::Gone));
                    return;
                }
            },
            Ok(None) | Err(_) => {
                let _ = tx.send((rank, StarEvent::Gone));
                return;
            }
        }
    }
}

/// The launcher's [`StepExecutor`]: owns the rank processes and the star,
/// and mirrors [`pipeline::HostExecutor`]'s step accounting — fingerprints
/// included — so `launch` CSVs are byte-comparable against the in-process
/// pool's.
pub struct LaunchExecutor {
    n: usize,
    deadline: Duration,
    kill: Option<(usize, u64)>,
    killed: Option<usize>,
    children: Vec<Child>,
    writers: Vec<TcpStream>,
    rx: mpsc::Receiver<(usize, StarEvent)>,
    done: Vec<bool>,
    last_hb: Vec<Instant>,
    rendezvous: PathBuf,
    /// Per-step fingerprints, identical in construction to
    /// [`pipeline::HostExecutor::fingerprints`].
    pub fingerprints: Vec<u64>,
}

impl LaunchExecutor {
    /// Stamp a fresh rendezvous generation, spawn one `rank-worker`
    /// process per rank, accept their star links (hello-verified) and wait
    /// until every rank reports `Ready` (mesh connected).
    pub fn spawn(cfg: &LaunchConfig) -> crate::Result<LaunchExecutor> {
        anyhow::ensure!(cfg.ranks >= 1, "launch needs at least one rank");
        if let Some((kr, _)) = cfg.kill {
            anyhow::ensure!(kr < cfg.ranks, "kill rank {kr} out of range for {} ranks", cfg.ranks);
        }
        let dir = rendezvous_dir();
        std::fs::create_dir_all(&dir)?;
        gc_stale_rendezvous(&dir);
        let run_id = fresh_run_id();
        let rdv = dir.join(format!("rdv-{run_id}.txt"));
        socket::write_run_header(&rdv, &run_id)?;

        let listener = TcpListener::bind("127.0.0.1:0")?;
        let star_addr = listener.local_addr()?;
        let exe = std::env::current_exe()?;
        let mut children = Vec::with_capacity(cfg.ranks);
        for r in 0..cfg.ranks {
            let spawned = Command::new(&exe)
                .arg("rank-worker")
                .args(["--rank", &r.to_string()])
                .args(["--ranks", &cfg.ranks.to_string()])
                .args(["--rendezvous", &rdv.display().to_string()])
                .args(["--run-id", &run_id])
                .args(["--parent-addr", &star_addr.to_string()])
                .args(["--corpus", &cfg.corpus.display().to_string()])
                .args(["--format", &cfg.format])
                .args(["--mode", mode_name(cfg.mode)])
                .args(["--steps", &cfg.steps.to_string()])
                .args(["--trees-per-batch", &cfg.trees_per_batch.to_string()])
                .args(["--pipeline-depth", &cfg.depth.to_string()])
                .args(["--shuffle-window", &cfg.window.to_string()])
                .args(["--capacity", &cfg.capacity.to_string()])
                .args(["--vocab", &cfg.vocab.to_string()])
                .args(["--seed", &cfg.seed.to_string()])
                // LR crosses the process boundary as bits, not decimal:
                // the fingerprint folds its exact bit pattern
                .args(["--lr-bits", &format!("{:016x}", cfg.lr.to_bits())])
                .args(["--warmup", &cfg.warmup.to_string()])
                .args(["--reduce-bucket-kb", &cfg.bucket_kb.to_string()])
                .args(["--deadline-ms", &(cfg.deadline.as_millis() as u64).to_string()])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .map_err(|e| anyhow::anyhow!("spawning rank {r} worker process: {e}"));
            match spawned {
                Ok(c) => children.push(c),
                Err(e) => {
                    kill_all(&mut children);
                    let _ = std::fs::remove_file(&rdv);
                    return Err(e);
                }
            }
        }
        match Self::connect_star(cfg, &listener, &mut children) {
            Ok((writers, rx)) => Ok(LaunchExecutor {
                n: cfg.ranks,
                deadline: cfg.deadline,
                kill: cfg.kill,
                killed: None,
                children,
                writers,
                rx,
                done: vec![false; cfg.ranks],
                last_hb: vec![Instant::now(); cfg.ranks],
                rendezvous: rdv,
                fingerprints: Vec::new(),
            }),
            Err(e) => {
                kill_all(&mut children);
                let _ = std::fs::remove_file(&rdv);
                Err(e)
            }
        }
    }

    /// Accept one hello-verified star connection per rank and wait for
    /// every rank's `Ready`.  A rank process dying during startup is
    /// reported by name instead of timing out anonymously.
    fn connect_star(
        cfg: &LaunchConfig,
        listener: &TcpListener,
        children: &mut [Child],
    ) -> crate::Result<(Vec<TcpStream>, mpsc::Receiver<(usize, StarEvent)>)> {
        let n = cfg.ranks;
        let star_max = Some(cfg.vocab * HOST_DIM + CTRL_SLACK);
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel::<(usize, StarEvent)>();
        let mut writers: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        let connect_deadline = Instant::now() + cfg.deadline.max(socket::CONNECT_TIMEOUT);
        let mut pending: Vec<usize> = (0..n).collect();
        while !pending.is_empty() {
            match listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    s.set_read_timeout(Some(HELLO_TIMEOUT))?;
                    let mut hello = [0u8; 4];
                    if s.read_exact(&mut hello).is_err() {
                        continue; // silent foreign dialer: no slot consumed
                    }
                    let r = u32::from_le_bytes(hello) as usize;
                    let Some(i) = pending.iter().position(|&p| p == r) else {
                        continue; // foreign rank or duplicate hello
                    };
                    pending.swap_remove(i);
                    s.set_read_timeout(None)?;
                    s.set_nodelay(true)?;
                    let w = s.try_clone()?;
                    w.set_write_timeout(Some(cfg.deadline))?;
                    writers[r] = Some(w);
                    let tx = tx.clone();
                    std::thread::Builder::new()
                        .name(format!("tt-launch-rx-{r}"))
                        .spawn(move || star_reader(r, s, tx, star_max))
                        .map_err(|e| anyhow::anyhow!("spawn star reader: {e}"))?;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    for (r, c) in children.iter_mut().enumerate() {
                        if let Ok(Some(status)) = c.try_wait() {
                            anyhow::bail!("rank {r} process exited during startup ({status})");
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < connect_deadline,
                        "ranks {pending:?} did not dial the launcher within {:?}",
                        cfg.deadline.max(socket::CONNECT_TIMEOUT)
                    );
                    std::thread::sleep(POLL_ACCEPT);
                }
                Err(e) => return Err(e.into()),
            }
        }
        // all links up; now wait for every rank's Ready (mesh connected)
        let mut ready = vec![false; n];
        while ready.iter().any(|r| !r) {
            match rx.recv_timeout(RX_POLL) {
                Ok((r, StarEvent::Msg(StarMsg::Ready { .. }))) => ready[r] = true,
                Ok((_, StarEvent::Msg(_))) => {}
                Ok((r, StarEvent::Gone)) => {
                    let status = exit_status_str(&mut children[r]);
                    anyhow::bail!("rank {r} process exited{status} before becoming ready");
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    for (r, c) in children.iter_mut().enumerate() {
                        if !ready[r] {
                            if let Ok(Some(status)) = c.try_wait() {
                                anyhow::bail!("rank {r} process exited ({status}) before becoming ready");
                            }
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < connect_deadline,
                        "ranks {:?} never reported ready",
                        ready
                            .iter()
                            .enumerate()
                            .filter(|(_, ok)| !**ok)
                            .map(|(r, _)| r)
                            .collect::<Vec<_>>()
                    );
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all star reader threads exited during startup")
                }
            }
        }
        Ok((writers.into_iter().map(|w| w.expect("accepted above")).collect(), rx))
    }

    /// Block until rank 0's result for `step`, watching heartbeats, child
    /// exits and star EOFs the whole time — any vanished rank becomes a
    /// named-rank error within the deadline, never a hang.
    fn await_result(&mut self, step: u64) -> crate::Result<StepResult> {
        let deadline_at = Instant::now() + self.deadline;
        loop {
            match self.rx.recv_timeout(RX_POLL) {
                Ok((r, StarEvent::Msg(m))) => match m {
                    StarMsg::Heartbeat { .. } => self.last_hb[r] = Instant::now(),
                    StarMsg::Ready { .. } => {}
                    StarMsg::Done { .. } => self.done[r] = true,
                    StarMsg::Err { rank, step: s, msg } => {
                        anyhow::bail!("rank {rank} failed at step {s}: {msg}")
                    }
                    StarMsg::Result(res) if res.step == step => {
                        self.last_hb[r] = Instant::now();
                        return Ok(*res);
                    }
                    StarMsg::Result(_) | StarMsg::Apply { .. } => {}
                },
                Ok((r, StarEvent::Gone)) => {
                    if !self.done[r] {
                        let status = exit_status_str(&mut self.children[r]);
                        anyhow::bail!(
                            "rank {r} process exited{status} before step {step} completed"
                        );
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    self.check_liveness(step)?;
                    anyhow::ensure!(
                        Instant::now() < deadline_at,
                        "no result for step {step} within {:?} — a rank is hung; aborting",
                        self.deadline
                    );
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all rank star links closed before step {step} completed")
                }
            }
        }
    }

    fn check_liveness(&mut self, step: u64) -> crate::Result<()> {
        for r in 0..self.n {
            if self.done[r] {
                continue;
            }
            if let Ok(Some(status)) = self.children[r].try_wait() {
                anyhow::bail!("rank {r} process exited ({status}) before step {step} completed");
            }
            let silent = self.last_hb[r].elapsed();
            anyhow::ensure!(
                silent < self.deadline,
                "rank {r}: no heartbeat for {silent:?} (deadline {:?}) — presumed hung",
                self.deadline
            );
        }
        Ok(())
    }

    /// Drain `Done` markers and reap every rank process; a nonzero exit is
    /// an error.  Called after the pipelined loop completes.
    pub fn finish(&mut self) -> crate::Result<()> {
        let deadline_at = Instant::now() + self.deadline;
        while self.done.iter().any(|d| !d) {
            match self.rx.recv_timeout(RX_POLL) {
                Ok((r, StarEvent::Msg(StarMsg::Done { .. }))) => self.done[r] = true,
                Ok((_, StarEvent::Msg(_))) => {}
                Ok((r, StarEvent::Gone)) => {
                    if !self.done[r] {
                        let status = exit_status_str(&mut self.children[r]);
                        anyhow::bail!("rank {r} process exited{status} before signalling done");
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    for r in 0..self.n {
                        if !self.done[r] {
                            if let Ok(Some(status)) = self.children[r].try_wait() {
                                anyhow::bail!(
                                    "rank {r} process exited ({status}) before signalling done"
                                );
                            }
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline_at,
                        "ranks {:?} never signalled done within {:?}",
                        (0..self.n).filter(|&r| !self.done[r]).collect::<Vec<_>>(),
                        self.deadline
                    );
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let reap_deadline = Instant::now() + self.deadline;
        for r in 0..self.n {
            loop {
                match self.children[r].try_wait()? {
                    Some(status) => {
                        anyhow::ensure!(status.success(), "rank {r} exited with {status}");
                        break;
                    }
                    None => {
                        anyhow::ensure!(
                            Instant::now() < reap_deadline,
                            "rank {r} did not exit within {:?} after done",
                            self.deadline
                        );
                        std::thread::sleep(REAP_POLL);
                    }
                }
            }
        }
        let _ = std::fs::remove_file(&self.rendezvous);
        Ok(())
    }
}

const POLL_ACCEPT: Duration = Duration::from_millis(2);

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        if c.try_wait().ok().flatten().is_none() {
            let _ = c.kill();
        }
        let _ = c.wait();
    }
}

fn exit_status_str(c: &mut Child) -> String {
    match c.try_wait() {
        Ok(Some(status)) => format!(" ({status})"),
        _ => String::new(),
    }
}

impl Drop for LaunchExecutor {
    fn drop(&mut self) {
        kill_all(&mut self.children);
        let _ = std::fs::remove_file(&self.rendezvous);
    }
}

impl StepExecutor for LaunchExecutor {
    fn execute(&mut self, planned: &PlannedStep) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        // fault injection: the smoke gate kills one rank here and asserts
        // the run fails fast with that rank named
        if let Some((kr, ks)) = self.kill {
            if planned.step == ks && self.killed.is_none() {
                let _ = self.children[kr].kill();
                self.killed = Some(kr);
            }
        }
        let res = self.await_result(planned.step)?;
        // cost-model feedback: a no-op under the token model, which is the
        // only model the launch path plans with (calibrated placement
        // would fork the ranks' plans)
        let cost_model_err = planned.plan.cost_model_err(&res.rank_walls);
        planned.plan.observe_walls(&res.rank_walls);
        // step fingerprint: identical expression to HostExecutor's
        let mut h = 0xcbf29ce484222325u64;
        fnv1a(&mut h, &planned.step.to_le_bytes());
        fnv1a(&mut h, &planned.lr.to_bits().to_le_bytes());
        fnv1a(&mut h, &res.hash.to_le_bytes());
        self.fingerprints.push(h);
        // broadcast the update; every replica applies the identical f64
        // expression, so rank models stay bit-identical to the pool's
        let words = StarMsg::Apply {
            step: res.step,
            lr: planned.lr,
            weight_sum: res.weight_sum,
            d_embed: res.d_embed.clone(),
        }
        .encode();
        let bytes = ctrl_frame(planned.step + 1, 0, words).encode();
        for (r, w) in self.writers.iter_mut().enumerate() {
            w.write_all(&bytes).map_err(|e| {
                anyhow::anyhow!("rank {r}: broadcasting step {} update: {e}", res.step)
            })?;
        }
        Ok(StepMetrics {
            step: planned.step,
            loss: if res.weight_sum > 0.0 { res.loss_sum / res.weight_sum } else { 0.0 },
            weight_sum: res.weight_sum,
            device_tokens: res.device_tokens as usize,
            tree_tokens: planned.plan.tree_tokens(),
            flat_tokens: planned.plan.flat_tokens(),
            wall: t0.elapsed(),
            exec_calls: res.batches,
            forest_batches: res.batches,
            grad_norm: 0.0,
            plan_ms: 0.0,
            stall_ms: 0.0,
            ranks: planned.plan.n_ranks() as u64,
            reduce_ms: res.reduce_ms,
            reduce_overlap_ms: res.reduce_overlap_ms,
            reduce_depth: reduce_depth(planned.plan.n_ranks()) as u64,
            rank_imbalance: planned.plan.rank_imbalance(),
            ingest_ms: 0.0,
            cost_model_err,
            staleness_steps: 0,
            ripe_queue_depth: 0,
            admitted_sessions: 0,
            xstep_reuse_ratio: reuse_ratio(planned.plan.tree_tokens() as u64, res.cache[2]),
            cache_hit_tokens: res.cache[2],
            cache_evictions: res.cache[3],
            reduce_buckets: res.buckets,
            bucket_overlap_ms: res.bucket_overlap_ms,
            collective_bytes: res.collective_bytes,
        })
    }
}

/// Run a full multi-process training run: spawn the rank fleet, drive the
/// pipelined plan loop (the parent plans too — it needs plan geometry for
/// metrics, and planning is `(seed, step)`-pure so every process derives
/// the identical schedule), then reap.  Returns per-step metrics, the
/// pipeline summary and the step fingerprints.
pub fn run_launch(
    cfg: &LaunchConfig,
    spec: PlanSpec,
    source: Box<dyn CorpusSource>,
) -> crate::Result<(Vec<StepMetrics>, PipelineSummary, Vec<u64>)> {
    let mut exec = LaunchExecutor::spawn(cfg)?;
    let pcfg = PipelineConfig {
        mode: cfg.mode,
        steps: cfg.steps,
        trees_per_batch: cfg.trees_per_batch,
        depth: cfg.depth,
        lr: cfg.lr,
        warmup: cfg.warmup,
        ranks: cfg.ranks,
    };
    let (metrics, summary) = pipeline::run(&pcfg, spec, source, &mut exec)?;
    exec.finish()?;
    let fps = std::mem::take(&mut exec.fingerprints);
    Ok((metrics, summary, fps))
}

// ───────────────────────────── rank worker (child) ──────────────────────────────

/// One rank process's identity + geometry, parsed from the `rank-worker`
/// command line the launcher passes.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub rank: usize,
    pub ranks: usize,
    pub rendezvous: PathBuf,
    pub run_id: String,
    pub parent_addr: String,
    pub mode: Mode,
    pub steps: u64,
    pub trees_per_batch: usize,
    pub depth: usize,
    pub vocab: usize,
    pub seed: u64,
    pub lr: f64,
    pub warmup: u64,
    pub bucket_kb: usize,
    pub deadline: Duration,
}

fn send_star(w: &Arc<Mutex<TcpStream>>, seq: u64, rank: usize, msg: &StarMsg) -> crate::Result<()> {
    let bytes = ctrl_frame(seq, rank as u32, msg.encode()).encode();
    let mut s = w.lock().map_err(|_| anyhow::anyhow!("star writer lock poisoned"))?;
    s.write_all(&bytes)
        .map_err(|e| anyhow::anyhow!("rank {rank}: star send to launcher: {e}"))?;
    Ok(())
}

/// The child-side [`StepExecutor`]: executes this rank's slice of each
/// re-derived plan through the same `execute_bucketed` machinery the
/// in-process pool workers run, merges bracket children's typed
/// accumulators off the mesh in round order, forwards (or, at rank 0,
/// reports) the result, then blocks for the broadcast `Apply`.
struct RankStepExecutor {
    worker: HostWorker,
    coll: Option<Box<dyn Collective>>,
    star_r: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    rank: usize,
    n: usize,
    children: Vec<usize>,
    bucket_kb: usize,
    cur_step: Arc<AtomicU64>,
    star_max: Option<usize>,
}

impl RankStepExecutor {
    fn recv_apply(&mut self, step: u64) -> crate::Result<(f64, f64, Vec<f64>)> {
        loop {
            let f = Frame::decode_from_bounded(&mut self.star_r, self.star_max)
                .map_err(|e| {
                    anyhow::anyhow!(
                        "rank {}: waiting for step {step} update from launcher: {e}",
                        self.rank
                    )
                })?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "rank {}: launcher closed the control link before the step {step} update",
                        self.rank
                    )
                })?;
            match StarMsg::decode(&ctrl_words(&f))? {
                StarMsg::Apply { step: s, lr, weight_sum, d_embed } => {
                    anyhow::ensure!(
                        s == step,
                        "rank {}: update for step {s} arrived while executing step {step}",
                        self.rank
                    );
                    return Ok((lr, weight_sum, d_embed));
                }
                // the launcher only sends Apply today; skip anything else
                // rather than die on future protocol chatter
                _ => continue,
            }
        }
    }
}

impl StepExecutor for RankStepExecutor {
    fn execute(&mut self, planned: &PlannedStep) -> crate::Result<StepMetrics> {
        self.cur_step.store(planned.step, Ordering::SeqCst);
        let seq = planned.step + 1; // matches RankPool's 1-based step seq
        anyhow::ensure!(
            planned.plan.n_ranks() == self.n,
            "plan has {} ranks but this launch runs {}",
            planned.plan.n_ranks(),
            self.n
        );
        let my_plan = &planned.plan.ranks[self.rank];
        let children = self.children.clone();
        // execute + data-plane fold: byte-for-byte the pool workers' path
        let mut sub: crate::Result<dist::Subtree<HostRankAcc>> = match self.coll.as_deref_mut() {
            Some(coll) => dist::execute_bucketed(
                &mut self.worker,
                self.rank,
                my_plan,
                seq,
                coll,
                self.bucket_kb,
                &children,
            ),
            None => {
                let t_exec = Instant::now();
                self.worker.execute(self.rank, my_plan).map(|(acc, device_tokens)| {
                    dist::Subtree {
                        acc,
                        device_tokens,
                        merge_ms: 0.0,
                        walls: vec![(self.rank, t_exec.elapsed().as_secs_f64() * 1e3)],
                        exec_end: Instant::now(),
                        bucket_overlap_ms: 0.0,
                        collective_bytes: 0,
                        buckets: 0,
                    }
                })
            }
        };
        // merge bracket children's typed accumulators in fixed round order
        // (stripped: payloads already folded in as data frames) — the
        // in-process worker_loop's merge, with CTRL frames as the channel
        if let Some(coll) = self.coll.as_deref_mut() {
            for &src in &children {
                let msg = coll
                    .recv(seq, CTRL_BUCKET, src)
                    .and_then(|f| MeshMsg::decode(&ctrl_words(&f)));
                match msg {
                    Err(e) => {
                        if sub.is_ok() {
                            sub = Err(e);
                        }
                    }
                    Ok(MeshMsg::Err { rank, msg }) => {
                        if sub.is_ok() {
                            sub = Err(anyhow::anyhow!("rank {rank}: {msg}"));
                        }
                    }
                    Ok(MeshMsg::Acc {
                        loss_sum,
                        weight_sum,
                        hash,
                        batches,
                        cache,
                        device_tokens,
                        merge_ms,
                        walls,
                        since_exec_end_ms,
                        bucket_overlap_ms,
                        collective_bytes,
                        buckets,
                    }) => {
                        if let Ok(a) = &mut sub {
                            let t0 = Instant::now();
                            let b_acc = HostRankAcc {
                                loss_sum,
                                weight_sum,
                                d_embed: Vec::new(),
                                hash,
                                batches,
                                cache: CacheStats {
                                    hits: cache[0],
                                    misses: cache[1],
                                    hit_tokens: cache[2],
                                    evictions: cache[3],
                                },
                            };
                            <HostWorker as RankWorker>::reduce_stripped(&mut a.acc, b_acc);
                            a.merge_ms += t0.elapsed().as_secs_f64() * 1e3 + merge_ms;
                            a.device_tokens += device_tokens as usize;
                            a.walls.extend(walls.iter().map(|&(r, w)| (r as usize, w)));
                            let b_end = Instant::now()
                                .checked_sub(Duration::from_secs_f64(
                                    (since_exec_end_ms / 1e3).max(0.0),
                                ))
                                .unwrap_or_else(Instant::now);
                            if b_end > a.exec_end {
                                a.exec_end = b_end;
                            }
                            a.bucket_overlap_ms += bucket_overlap_ms;
                            a.collective_bytes += collective_bytes;
                            a.buckets = a.buckets.max(buckets as u32);
                        }
                    }
                }
            }
        }
        // forward up the bracket (typed plane = CTRL frames), or report
        if reduce_parent(self.rank).is_some() {
            let coll = self.coll.as_deref_mut().expect("non-root rank has a mesh");
            match &mut sub {
                Ok(a) => {
                    <HostWorker as RankWorker>::strip_payload(&mut a.acc);
                    let since = Instant::now().saturating_duration_since(a.exec_end).as_secs_f64()
                        * 1e3;
                    let msg = MeshMsg::Acc {
                        loss_sum: a.acc.loss_sum,
                        weight_sum: a.acc.weight_sum,
                        hash: a.acc.hash,
                        batches: a.acc.batches,
                        cache: [
                            a.acc.cache.hits,
                            a.acc.cache.misses,
                            a.acc.cache.hit_tokens,
                            a.acc.cache.evictions,
                        ],
                        device_tokens: a.device_tokens as u64,
                        merge_ms: a.merge_ms,
                        walls: a.walls.iter().map(|&(r, w)| (r as u64, w)).collect(),
                        since_exec_end_ms: since,
                        bucket_overlap_ms: a.bucket_overlap_ms,
                        collective_bytes: a.collective_bytes,
                        buckets: a.buckets as u64,
                    };
                    let data: Vec<f64> =
                        msg.encode().into_iter().map(f64::from_bits).collect();
                    if let Err(e) = coll.send_up(seq, CTRL_BUCKET, &data) {
                        sub = Err(e);
                    }
                }
                Err(e) => {
                    // keep the one-ctrl-frame-per-child invariant so the
                    // bracket parent never hangs waiting on this rank
                    let msg = MeshMsg::Err { rank: self.rank as u64, msg: format!("{e:#}") };
                    let data: Vec<f64> =
                        msg.encode().into_iter().map(f64::from_bits).collect();
                    let _ = coll.send_up(seq, CTRL_BUCKET, &data);
                }
            }
        }
        let mut a = match sub {
            Ok(a) => a,
            Err(e) => {
                if reduce_parent(self.rank).is_none() {
                    let _ = send_star(
                        &self.writer,
                        seq,
                        self.rank,
                        &StarMsg::Err {
                            rank: self.rank as u64,
                            step: planned.step,
                            msg: format!("{e:#}"),
                        },
                    );
                }
                return Err(e);
            }
        };
        if reduce_parent(self.rank).is_none() {
            let reduce_done = Instant::now();
            let tail_ms = reduce_done.saturating_duration_since(a.exec_end).as_secs_f64() * 1e3;
            let mut rank_walls = vec![0.0f64; self.n];
            for &(r, w) in &a.walls {
                if r < self.n {
                    rank_walls[r] = w;
                }
            }
            let res = StepResult {
                step: planned.step,
                loss_sum: a.acc.loss_sum,
                weight_sum: a.acc.weight_sum,
                d_embed: std::mem::take(&mut a.acc.d_embed),
                hash: a.acc.hash,
                batches: a.acc.batches,
                device_tokens: a.device_tokens as u64,
                cache: [
                    a.acc.cache.hits,
                    a.acc.cache.misses,
                    a.acc.cache.hit_tokens,
                    a.acc.cache.evictions,
                ],
                rank_walls,
                reduce_ms: a.merge_ms,
                reduce_overlap_ms: (a.merge_ms - tail_ms).max(0.0),
                bucket_overlap_ms: a.bucket_overlap_ms,
                collective_bytes: a.collective_bytes,
                buckets: a.buckets as u64,
            };
            send_star(&self.writer, seq, self.rank, &StarMsg::Result(Box::new(res)))?;
        }
        // every rank blocks for the broadcast update and applies the
        // identical f64 expression — replicas stay bit-identical
        let (lr, weight_sum, d_embed) = self.recv_apply(planned.step)?;
        self.worker.apply(&HostUpdate { lr, weight_sum, d_embed })?;
        // the parent owns reporting; the child's metrics are discarded by
        // its local pipeline driver
        Ok(StepMetrics {
            step: planned.step,
            loss: 0.0,
            weight_sum: 0.0,
            device_tokens: 0,
            tree_tokens: 0,
            flat_tokens: 0,
            wall: Duration::ZERO,
            exec_calls: 0,
            forest_batches: 0,
            grad_norm: 0.0,
            plan_ms: 0.0,
            stall_ms: 0.0,
            ranks: self.n as u64,
            reduce_ms: 0.0,
            reduce_overlap_ms: 0.0,
            reduce_depth: 0,
            rank_imbalance: 1.0,
            ingest_ms: 0.0,
            cost_model_err: 0.0,
            staleness_steps: 0,
            ripe_queue_depth: 0,
            admitted_sessions: 0,
            xstep_reuse_ratio: 1.0,
            cache_hit_tokens: 0,
            cache_evictions: 0,
            reduce_buckets: 0,
            bucket_overlap_ms: 0.0,
            collective_bytes: 0,
        })
    }
}

/// Entry point of the `tree-train rank-worker` process: wire up the star
/// and the mesh, then drive this rank through the shared pipelined loop.
/// Planning re-derives the launcher's schedule exactly (`(seed, step)`-
/// pure); errors exit nonzero with the cause on stderr, after the star /
/// mesh control frames that let the other processes unwind.
pub fn run_worker(
    cfg: &WorkerConfig,
    spec: PlanSpec,
    source: Box<dyn CorpusSource>,
) -> crate::Result<()> {
    let rank = cfg.rank;
    let n = cfg.ranks;
    anyhow::ensure!(rank < n, "rank {rank} out of range for {n} ranks");
    let star_max = Some(cfg.vocab * HOST_DIM + CTRL_SLACK);
    // 1. dial the launcher star and identify
    let mut star = TcpStream::connect(&cfg.parent_addr).map_err(|e| {
        anyhow::anyhow!("rank {rank} dialing launcher at {}: {e}", cfg.parent_addr)
    })?;
    star.set_nodelay(true)?;
    star.set_write_timeout(Some(cfg.deadline))?;
    star.set_read_timeout(Some(cfg.deadline))?;
    star.write_all(&(rank as u32).to_le_bytes())?; // hello
    let writer = Arc::new(Mutex::new(star.try_clone()?));
    // 2. the gradient + typed-control mesh (none for a single rank)
    let coll: Option<Box<dyn Collective>> = if n > 1 {
        let sopts = SocketOptions {
            max_frame_elems: star_max,
            deadline: Some(cfg.deadline),
            run_id: Some(cfg.run_id.clone()),
        };
        Some(Box::new(SocketCollective::connect_opts(&cfg.rendezvous, rank, n, &sopts)?))
    } else {
        None
    };
    // 3. heartbeat thread: proves this process alive between results (the
    // writer mutex serializes it against the main thread's result sends)
    let stop = Arc::new(AtomicBool::new(false));
    let cur_step = Arc::new(AtomicU64::new(0));
    let hb = {
        let w = Arc::clone(&writer);
        let stop = Arc::clone(&stop);
        let cur = Arc::clone(&cur_step);
        std::thread::Builder::new()
            .name(format!("tt-launch-hb-{rank}"))
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let step = cur.load(Ordering::SeqCst);
                    if send_star(&w, 0, rank, &StarMsg::Heartbeat { rank: rank as u64, step })
                        .is_err()
                    {
                        return; // launcher gone; the main thread errors on its own
                    }
                    std::thread::sleep(HEARTBEAT);
                }
            })
            .map_err(|e| anyhow::anyhow!("spawn heartbeat thread: {e}"))?
    };
    send_star(&writer, 0, rank, &StarMsg::Ready { rank: rank as u64 })?;
    // 4. drive the shared pipelined loop
    let mut exec = RankStepExecutor {
        worker: HostWorker {
            model: RefModel::seeded(cfg.vocab, HOST_DIM, cfg.seed),
            run_model: true,
            cache: PrefixCache::new(0),
            updates: 0,
        },
        coll,
        star_r: star,
        writer: Arc::clone(&writer),
        rank,
        n,
        children: reduce_children(rank, n).into_iter().map(|(_, s)| s).collect(),
        bucket_kb: cfg.bucket_kb,
        cur_step: Arc::clone(&cur_step),
        star_max,
    };
    let pcfg = PipelineConfig {
        mode: cfg.mode,
        steps: cfg.steps,
        trees_per_batch: cfg.trees_per_batch,
        depth: cfg.depth,
        lr: cfg.lr,
        warmup: cfg.warmup,
        ranks: n,
    };
    let run_res = pipeline::run(&pcfg, spec, source, &mut exec).map(|_| ());
    stop.store(true, Ordering::SeqCst);
    let _ = hb.join();
    run_res?;
    send_star(&writer, cfg.steps + 1, rank, &StarMsg::Done { rank: rank as u64 })?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_star(msg: StarMsg) {
        // through the word codec AND the frame byte wire, like production
        let frame = ctrl_frame(7, 3, msg.encode());
        let bytes = frame.encode();
        let back = Frame::decode_from(&mut bytes.as_slice()).unwrap().unwrap();
        assert_eq!(back.bucket, CTRL_BUCKET);
        assert!(!back.is_abort(), "ctrl frames always carry at least the tag word");
        assert_eq!(StarMsg::decode(&ctrl_words(&back)).unwrap(), msg);
    }

    #[test]
    fn star_messages_round_trip_bit_exactly() {
        roundtrip_star(StarMsg::Ready { rank: 3 });
        roundtrip_star(StarMsg::Heartbeat { rank: 2, step: 41 });
        roundtrip_star(StarMsg::Done { rank: 0 });
        roundtrip_star(StarMsg::Err {
            rank: 1,
            step: 9,
            msg: "rank 1 exploded:执行失败 🚨".into(),
        });
        roundtrip_star(StarMsg::Apply {
            step: 5,
            lr: 1e-2,
            weight_sum: 384.0,
            d_embed: vec![1.5, -0.0, f64::NAN, f64::from_bits(0x7ff80000dead0001)],
        });
        roundtrip_star(StarMsg::Result(Box::new(StepResult {
            step: 12,
            loss_sum: 3.25,
            weight_sum: 128.0,
            d_embed: vec![0.5, f64::INFINITY, 1e-308],
            hash: 0xdeadbeefcafef00d,
            batches: 9,
            device_tokens: 4096,
            cache: [1, 2, 3, 4],
            rank_walls: vec![1.5, 2.5, 3.5],
            reduce_ms: 0.25,
            reduce_overlap_ms: 0.125,
            bucket_overlap_ms: 0.0625,
            collective_bytes: 65536,
            buckets: 4,
        })));
    }

    #[test]
    fn nan_payload_bits_survive_the_apply() {
        // PartialEq is false for NaN, so check bits explicitly
        let weird = f64::from_bits(0x7ff8_0000_0000_0001);
        let msg =
            StarMsg::Apply { step: 1, lr: 0.1, weight_sum: 1.0, d_embed: vec![weird, -0.0] };
        let frame = ctrl_frame(1, 0, msg.encode());
        let bytes = frame.encode();
        let back = Frame::decode_from(&mut bytes.as_slice()).unwrap().unwrap();
        match StarMsg::decode(&ctrl_words(&back)).unwrap() {
            StarMsg::Apply { d_embed, .. } => {
                assert_eq!(d_embed[0].to_bits(), weird.to_bits());
                assert_eq!(d_embed[1].to_bits(), (-0.0f64).to_bits());
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn mesh_messages_round_trip() {
        let acc = MeshMsg::Acc {
            loss_sum: 1.5,
            weight_sum: 2.5,
            hash: 77,
            batches: 3,
            cache: [9, 8, 7, 6],
            device_tokens: 1024,
            merge_ms: 0.5,
            walls: vec![(1, 1.25), (3, 2.75)],
            since_exec_end_ms: 0.03125,
            bucket_overlap_ms: 0.125,
            collective_bytes: 4096,
            buckets: 2,
        };
        assert_eq!(MeshMsg::decode(&acc.encode()).unwrap(), acc);
        let err = MeshMsg::Err { rank: 2, msg: "boom".into() };
        assert_eq!(MeshMsg::decode(&err.encode()).unwrap(), err);
    }

    #[test]
    fn truncated_control_words_error_cleanly() {
        let msg = StarMsg::Apply { step: 1, lr: 0.1, weight_sum: 1.0, d_embed: vec![1.0; 8] };
        let words = msg.encode();
        for cut in 0..words.len() {
            assert!(StarMsg::decode(&words[..cut]).is_err(), "cut at {cut} must not parse");
        }
    }

    #[test]
    fn ctrl_bucket_stays_clear_of_reserved_keys() {
        // drain() uses bucket u32::MAX as its impossible stash key; data
        // buckets are dense from 0 — CTRL_BUCKET must be neither
        assert_eq!(CTRL_BUCKET, u32::MAX - 1);
        assert_ne!(CTRL_BUCKET, u32::MAX);
    }

    #[test]
    fn stale_rendezvous_gc_spares_fresh_files() {
        let dir = std::env::temp_dir().join(format!("tt-launch-gc-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let fresh = dir.join("rdv-fresh.txt");
        std::fs::write(&fresh, "run x\n").unwrap();
        let other = dir.join("not-a-rendezvous.log");
        std::fs::write(&other, "keep").unwrap();
        gc_stale_rendezvous(&dir);
        assert!(fresh.exists(), "fresh rendezvous must survive GC");
        assert!(other.exists(), "non-rendezvous files are never touched");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
