//! The pipelined run loop: plan batch N+1 while batch N executes.
//!
//! Host-side planning (global-batch assembly + Forest Packing + partition
//! specs) used to sit on the critical path of every optimizer step.  This
//! module double-buffers it: a background **planner thread** owns the
//! [`CorpusSource`] (and with it the shuffle RNG) plus the LR schedule,
//! assembles each step's batch, plans it through a [`PlanSpec`], and hands
//! finished [`PlannedStep`]s to the main thread over a bounded channel of
//! depth `pipeline_depth`.  The main thread only executes.
//!
//! **Determinism contract.**  Everything order-sensitive — epoch shuffling,
//! batch assembly, the cosine LR schedule — lives on the planner side and
//! is a pure function of `(seed, step)`.  Plans are tagged with their step
//! id and the executor asserts it consumes them in order, so a pipelined
//! run is *step-for-step identical* to the synchronous loop
//! (`pipeline_depth: 0` runs the very same planner inline): same batches,
//! same LR, same losses, same update — only wall-clock changes.  Verified
//! by `tests/pipeline_equivalence.rs`.
//!
//! **Observability.**  Each step's [`StepMetrics`] gains `plan_ms` (host
//! planning cost) and `stall_ms` (time the executor actually waited for the
//! plan; equals `plan_ms` in synchronous mode, ~0 when the pipeline hides
//! planning), and the run returns a [`PipelineSummary`] with the means, the
//! prefetch hit rate and the corpus source's peak resident tree count.
//!
//! **Sharding.**  The planner shards every global batch across
//! `cfg.ranks` data-parallel ranks (whole trees, LPT by packed token
//! cost) and ships an `Arc`-shared [`ShardedPlan`]; executors run rank
//! plans on [`super::dist`]'s *persistent* rank-worker pool (per-rank
//! replicas, created once per run) with a fixed log-tree gradient
//! reduction that runs on the worker threads — off this executor
//! thread's critical path, so it overlaps the planner's next-step
//! planning.  `ranks: 1` is the seed single-executor pipeline
//! byte-for-byte (docs/distributed.md).

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::data::CorpusSource;
use crate::trainer::adamw::cosine_lr;
use crate::trainer::planner::{PlanSpec, ShardedPlan, StepPlan};
use crate::trainer::prefix_cache::{reuse_ratio, CacheStats, PrefixCache};
use crate::trainer::refmodel::{PrefixActs, RefModel};
use crate::trainer::StepMetrics;

use super::dist::{self, RankPool, RankWorker};
use super::Mode;

/// Run-loop geometry handed to [`run`] (a mode-agnostic slice of
/// [`super::RunConfig`]).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub mode: Mode,
    pub steps: u64,
    pub trees_per_batch: usize,
    /// Bounded plan-queue depth; `0` = synchronous (plan inline on the
    /// executor thread — the seed behavior, preserved for ablations).
    pub depth: usize,
    /// Base LR + warmup of the cosine schedule (computed planner-side so
    /// the executor is a pure plan consumer).
    pub lr: f64,
    pub warmup: u64,
    /// Data-parallel ranks each global batch is sharded across (whole
    /// trees, [`PlanSpec::plan_sharded_tree`]); `1` = the seed
    /// single-executor path, byte-for-byte.
    pub ranks: usize,
}

/// One fully-planned optimizer step, tagged with its step id.
pub struct PlannedStep {
    pub step: u64,
    /// Cosine-schedule LR for this step.
    pub lr: f64,
    /// Trees in this global batch.
    pub trees: usize,
    /// The per-rank plans (one rank when unsharded), `Arc`-shared so the
    /// executor can hand the same plan to every rank worker without a
    /// copy.
    pub plan: Arc<ShardedPlan>,
    /// Host planning time (batch assembly + sharding + packing).
    pub plan_ms: f64,
    /// Ingest time the corpus source spent producing this step's batch
    /// (streaming rollout folds; 0 for tree corpora) — drained from the
    /// source so the step that triggered the fold carries its cost.
    pub ingest_ms: f64,
    /// Serve-mode admission accounting for this batch, drained from the
    /// source ([`CorpusSource::take_serve_stats`]); `None` outside
    /// `tree-train serve`.
    pub serve: Option<crate::data::ServeStepStats>,
}

/// The execute half of the loop: consumes plans in step order.
pub trait StepExecutor {
    fn execute(&mut self, planned: &PlannedStep) -> crate::Result<StepMetrics>;

    /// Per-step observation hook (CSV sinks, progress logs); called after
    /// the driver fills `plan_ms`/`stall_ms`.
    fn on_step(&mut self, _m: &StepMetrics) -> crate::Result<()> {
        Ok(())
    }

    /// One-time rank-pool construction cost (replica + thread spawns),
    /// reported by the run summary for spawn-cost amortization.  `0` when
    /// the executor runs single-rank / poolless.
    fn pool_spawn_ms(&self) -> f64 {
        0.0
    }
}

/// Whole-run pipeline accounting.
#[derive(Debug, Clone)]
pub struct PipelineSummary {
    pub depth: usize,
    pub steps: u64,
    pub mean_plan_ms: f64,
    pub mean_stall_ms: f64,
    /// Steps whose plan was already buffered when the executor asked.
    pub prefetch_hits: u64,
    /// Peak simultaneously-resident tree count in the corpus source.
    pub peak_resident_trees: usize,
    /// One-time rank-pool construction cost (replicas + thread spawns; 0
    /// when single-rank).  Paid once per run — the old scoped-thread path
    /// paid a spawn/join per optimizer step instead.
    pub pool_spawn_ms: f64,
}

impl PipelineSummary {
    pub fn hit_rate(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.prefetch_hits as f64 / self.steps as f64
    }

    /// The rank-pool spawn cost amortized per executed step.
    pub fn spawn_amortized_ms(&self) -> f64 {
        self.pool_spawn_ms / (self.steps.max(1) as f64)
    }

    /// The one-line per-run summary `tree-train train` logs.
    pub fn log_line(&self) -> String {
        let mut line = format!(
            "pipeline: depth={} mean plan {:.2} ms, mean stall {:.2} ms, \
             prefetch hit rate {:.0}%, peak resident trees {}",
            self.depth,
            self.mean_plan_ms,
            self.mean_stall_ms,
            self.hit_rate() * 100.0,
            self.peak_resident_trees
        );
        if self.pool_spawn_ms > 0.0 {
            line.push_str(&format!(
                ", rank-pool spawn {:.2} ms once ({:.3} ms/step amortized)",
                self.pool_spawn_ms,
                self.spawn_amortized_ms()
            ));
        }
        line
    }
}

/// The planner half: source + spec + schedule, stepped in order.  Runs
/// inline (synchronous mode) or on the background thread (pipelined) —
/// the *same* code either way, which is what makes the two modes
/// equivalent by construction.
struct Planner {
    cfg: PipelineConfig,
    spec: PlanSpec,
    source: Box<dyn CorpusSource>,
    next_step: u64,
}

impl Planner {
    fn plan_next(&mut self) -> crate::Result<PlannedStep> {
        let step = self.next_step;
        self.next_step += 1;
        let t0 = Instant::now();
        let batch = self.source.next_batch(self.cfg.trees_per_batch)?;
        let ingest_ms = self.source.take_ingest_ms();
        let serve = self.source.take_serve_stats();
        let lr = cosine_lr(self.cfg.lr, step, self.cfg.warmup, self.cfg.steps);
        let plan = match self.cfg.mode {
            Mode::Tree => self.spec.plan_sharded_tree(&batch, self.cfg.ranks)?,
            Mode::Baseline => self.spec.plan_sharded_baseline(&batch, self.cfg.ranks)?,
        };
        Ok(PlannedStep {
            step,
            lr,
            trees: batch.len(),
            plan: Arc::new(plan),
            plan_ms: t0.elapsed().as_secs_f64() * 1e3,
            ingest_ms,
            serve,
        })
    }
}

/// Drive the run loop: `cfg.steps` steps of plan → execute, synchronous at
/// `depth == 0`, double-buffered through a planner thread otherwise.
pub fn run<E: StepExecutor>(
    cfg: &PipelineConfig,
    spec: PlanSpec,
    source: Box<dyn CorpusSource>,
    exec: &mut E,
) -> crate::Result<(Vec<StepMetrics>, PipelineSummary)> {
    anyhow::ensure!(cfg.trees_per_batch >= 1, "trees_per_batch must be >= 1");
    anyhow::ensure!(cfg.ranks >= 1, "ranks must be >= 1");
    let mut planner = Planner { cfg: cfg.clone(), spec, source, next_step: 0 };
    let mut all = Vec::with_capacity(cfg.steps as usize);
    let mut plan_total = 0.0f64;
    let mut stall_total = 0.0f64;
    let mut hits = 0u64;

    let peak_resident = if cfg.depth == 0 {
        // synchronous: the executor waits out every plan (stall == plan)
        for _ in 0..cfg.steps {
            let planned = planner.plan_next()?;
            let mut m = exec.execute(&planned)?;
            m.plan_ms = planned.plan_ms;
            m.stall_ms = planned.plan_ms;
            m.ingest_ms = planned.ingest_ms;
            if let Some(s) = planned.serve {
                m.staleness_steps = s.staleness_steps;
                m.ripe_queue_depth = s.ripe_queue_depth;
                m.admitted_sessions = s.admitted_sessions;
            }
            plan_total += m.plan_ms;
            stall_total += m.stall_ms;
            exec.on_step(&m)?;
            all.push(m);
        }
        planner.source.peak_resident()
    } else {
        let (tx, rx) = mpsc::sync_channel::<crate::Result<PlannedStep>>(cfg.depth);
        let steps = cfg.steps;
        let handle = std::thread::Builder::new()
            .name("tt-planner".into())
            .spawn(move || {
                for _ in 0..steps {
                    let item = planner.plan_next();
                    let failed = item.is_err();
                    // receiver gone (executor error) or planner error: stop
                    if tx.send(item).is_err() || failed {
                        break;
                    }
                }
                planner.source
            })
            .expect("spawn planner thread");
        for expected in 0..cfg.steps {
            // a buffered plan is a prefetch hit; otherwise the wait is the
            // residual (non-overlapped) planning cost
            let (item, stall_ms) = match rx.try_recv() {
                Ok(item) => {
                    hits += 1;
                    (item, 0.0)
                }
                Err(mpsc::TryRecvError::Empty) => {
                    let t0 = Instant::now();
                    let item = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("planner thread exited early"))?;
                    (item, t0.elapsed().as_secs_f64() * 1e3)
                }
                Err(mpsc::TryRecvError::Disconnected) => {
                    anyhow::bail!("planner thread exited early")
                }
            };
            let planned = item?;
            anyhow::ensure!(
                planned.step == expected,
                "pipeline step id mismatch: planned {} executed {expected}",
                planned.step
            );
            let mut m = exec.execute(&planned)?;
            m.plan_ms = planned.plan_ms;
            m.stall_ms = stall_ms;
            m.ingest_ms = planned.ingest_ms;
            if let Some(s) = planned.serve {
                m.staleness_steps = s.staleness_steps;
                m.ripe_queue_depth = s.ripe_queue_depth;
                m.admitted_sessions = s.admitted_sessions;
            }
            plan_total += m.plan_ms;
            stall_total += m.stall_ms;
            exec.on_step(&m)?;
            all.push(m);
        }
        drop(rx);
        let source = handle.join().map_err(|_| anyhow::anyhow!("planner thread panicked"))?;
        source.peak_resident()
    };

    let n = (cfg.steps as f64).max(1.0);
    Ok((
        all,
        PipelineSummary {
            depth: cfg.depth,
            steps: cfg.steps,
            mean_plan_ms: plan_total / n,
            mean_stall_ms: stall_total / n,
            prefetch_hits: hits,
            peak_resident_trees: peak_resident,
            pool_spawn_ms: exec.pool_spawn_ms(),
        },
    ))
}

/// A hermetic [`StepExecutor`] over the [`RefModel`] reference executor:
/// runs every planned device batch in pure f64 and (optionally) applies a
/// plain-SGD update to the embedding table, so end-to-end pipeline behavior
/// — including the step/LR coupling — is testable in environments without
/// the native PJRT backend.  Used by `tests/pipeline_equivalence.rs`,
/// `tests/dist_equivalence.rs`, `benches/pipeline_bench.rs` and the
/// `tree-train pipeline-smoke` / `dist-smoke` commands.
///
/// Multi-rank plans run on the same persistent [`RankPool`] machinery the
/// XLA trainers use: one [`RefModel`] *replica* per rank worker (created
/// once, at the first multi-rank step), log-tree reduction on the worker
/// threads, and the SGD update broadcast so replicas stay bit-identical to
/// this primary model.  A single-rank plan executes inline on the caller
/// thread against `self.model` — the seed path, byte-for-byte, zero
/// spawns.
pub struct HostExecutor {
    pub model: RefModel,
    /// Run the model for real (losses + gradients).  Overlap-timing
    /// benches disable it — the per-step cost becomes exactly
    /// `exec_floor` — and rely on fingerprints for equivalence.
    pub run_model: bool,
    /// Apply `embed -= lr * d_embed / weight_sum` each step (makes the
    /// loss stream depend on execution order — a stricter equivalence).
    pub sgd: bool,
    /// Optional per-step execution-time floor (sleep) emulating device
    /// latency — benches only: gives the planner something to overlap
    /// with, without burning the core the planner needs.
    pub exec_floor: Option<std::time::Duration>,
    /// One fingerprint per executed step: a hash of the step id, LR bits
    /// and every batch's metadata — "batch composition" as one number.
    pub fingerprints: Vec<u64>,
    /// Persistent per-rank worker pool, created at the first multi-rank
    /// step and reused for the rest of the run.
    pool: Option<RankPool<HostWorker>>,
    pool_spawn_ms: f64,
    /// Trie-keyed activation cache over forest members annotated by the
    /// affinity pass (docs/prefix_reuse.md) — the engine tier of cross-step
    /// prefix reuse, realized for the host executor: cached prefix rows are
    /// spliced into [`RefModel::step_cached`] bit-identically.  Budget 0
    /// (the default) is the seed path: no lookups, no inserts, no
    /// reordering of any f64 op.
    prefix_cache: PrefixCache<PrefixActs>,
    /// SGD updates applied so far — the host analog of
    /// `Engine::step_count`, and the cache's parameter version: every
    /// update hard-invalidates the cache (and each worker's).
    updates: u64,
    /// How the pool reduces (bucket size + collective transport).  The
    /// default is the monolithic typed path, byte-for-byte the seed.
    reduce: dist::ReduceOptions,
}

impl HostExecutor {
    pub fn new(vocab: usize, dim: usize, seed: u64) -> Self {
        Self {
            model: RefModel::seeded(vocab, dim, seed),
            run_model: true,
            sgd: true,
            exec_floor: None,
            fingerprints: Vec::new(),
            pool: None,
            pool_spawn_ms: 0.0,
            prefix_cache: PrefixCache::new(0),
            updates: 0,
            reduce: dist::ReduceOptions::default(),
        }
    }

    /// Enable the prefix-activation cache with a token budget (must be set
    /// before the first step; `0` keeps it off).
    pub fn with_prefix_cache(mut self, budget_tokens: usize) -> Self {
        self.prefix_cache = PrefixCache::new(budget_tokens);
        self
    }

    /// Select the reduce bucket size / collective transport (must be set
    /// before the first multi-rank step — the pool is built once).
    pub fn with_reduce(mut self, opts: dist::ReduceOptions) -> Self {
        self.reduce = opts;
        self
    }
}

/// FNV-1a over a byte stream (stable, dependency-free).
pub(crate) fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

/// Per-rank accumulator of the hermetic executor — the RefModel analog of
/// a rank's [`crate::trainer::GradBuffer`].
pub(crate) struct HostRankAcc {
    pub(crate) loss_sum: f64,
    pub(crate) weight_sum: f64,
    pub(crate) d_embed: Vec<f64>,
    /// FNV digest of this rank's batch metadata (folded cross-rank by the
    /// fixed log-tree bracket, so the step fingerprint is
    /// thread-schedule-free).
    pub(crate) hash: u64,
    pub(crate) batches: u64,
    /// This rank's prefix-cache counters for the step (summed cross-rank).
    pub(crate) cache: CacheStats,
}

impl HostRankAcc {
    pub(crate) fn fresh(embed_len: usize) -> Self {
        Self {
            loss_sum: 0.0,
            weight_sum: 0.0,
            d_embed: vec![0.0f64; embed_len],
            hash: 0xcbf29ce484222325u64,
            batches: 0,
            cache: CacheStats::default(),
        }
    }
}

/// One rank's persistent hermetic executor state: a [`RefModel`] replica —
/// the RefModel analog of [`dist::TrainerWorker`]'s engine replica.
pub(crate) struct HostWorker {
    pub(crate) model: RefModel,
    pub(crate) run_model: bool,
    /// Rank-local activation cache (same budget as the primary's; entries
    /// are never shared across ranks — affine sharding keeps each prefix
    /// group on one rank precisely so rank-local caches suffice).
    pub(crate) cache: PrefixCache<PrefixActs>,
    pub(crate) updates: u64,
}

/// The broadcast SGD update every replica applies (identical f64 math to
/// the primary's update, so replicas stay bit-identical).
pub(crate) struct HostUpdate {
    pub(crate) lr: f64,
    pub(crate) weight_sum: f64,
    pub(crate) d_embed: Vec<f64>,
}

impl RankWorker for HostWorker {
    type Acc = HostRankAcc;
    type Update = HostUpdate;

    fn execute(&mut self, _rank: usize, plan: &StepPlan) -> crate::Result<(HostRankAcc, usize)> {
        let mut acc = HostRankAcc::fresh(self.model.embed.len());
        let tokens =
            run_host_rank(&self.model, self.run_model, plan, &mut self.cache, &mut acc)?;
        acc.cache = self.cache.take_stats();
        Ok((acc, tokens))
    }

    fn reduce(a: &mut HostRankAcc, b: HostRankAcc) {
        a.loss_sum += b.loss_sum;
        a.weight_sum += b.weight_sum;
        for (g, d) in a.d_embed.iter_mut().zip(&b.d_embed) {
            *g += d;
        }
        fnv1a(&mut a.hash, &b.hash.to_le_bytes());
        a.batches += b.batches;
        a.cache.absorb(&b.cache);
    }

    fn apply(&mut self, u: &HostUpdate) -> crate::Result<()> {
        if u.weight_sum > 0.0 {
            for (e, g) in self.model.embed.iter_mut().zip(&u.d_embed) {
                *e -= u.lr * g / u.weight_sum;
            }
        }
        // the staleness contract, replica side: new parameter version,
        // whole cache dropped (mirrors the primary's post-update bump)
        self.updates += 1;
        self.cache.set_version(self.updates);
        Ok(())
    }

    // ── bucketed data plane: the flat payload is d_embed ──

    fn flat_grad_len(&self) -> Option<usize> {
        Some(self.model.embed.len())
    }

    fn read_payload(acc: &HostRankAcc, range: std::ops::Range<usize>, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&acc.d_embed[range]);
    }

    fn fold_payload(acc: &mut HostRankAcc, range: std::ops::Range<usize>, data: &[f64]) {
        for (g, &x) in acc.d_embed[range].iter_mut().zip(data) {
            *g += x;
        }
    }

    fn strip_payload(acc: &mut HostRankAcc) {
        acc.d_embed = Vec::new();
    }

    fn reduce_stripped(a: &mut HostRankAcc, b: HostRankAcc) {
        // field order mirrors `reduce` exactly, minus the payload fold —
        // the fingerprint digest in particular must fold child hashes in
        // the identical bracket order
        a.loss_sum += b.loss_sum;
        a.weight_sum += b.weight_sum;
        fnv1a(&mut a.hash, &b.hash.to_le_bytes());
        a.batches += b.batches;
        a.cache.absorb(&b.cache);
    }

    fn execute_hooked(
        &mut self,
        _rank: usize,
        plan: &StepPlan,
        on_unit: &mut dyn FnMut(&mut HostRankAcc, usize),
    ) -> crate::Result<(HostRankAcc, usize)> {
        let mut acc = HostRankAcc::fresh(self.model.embed.len());
        let tokens = run_host_rank_hooked(
            &self.model,
            self.run_model,
            plan,
            &mut self.cache,
            &mut acc,
            on_unit,
        )?;
        acc.cache = self.cache.take_stats();
        Ok((acc, tokens))
    }
}

/// Fold one batch's full metadata into the composition digest: every
/// channel the programs consume — tokens and weights, but also the
/// attention topology (prev_idx, k_order, k_exit, k_bias) and positions — a
/// divergence in any of them is a composition change even if token order
/// matches.  Deliberately blind to the cache: hit or miss, the fingerprint
/// is a function of the data alone.
fn hash_batch(b: &crate::trainer::Batch, acc: &mut HostRankAcc) {
    fnv1a(&mut acc.hash, &(b.capacity as u64).to_le_bytes());
    for t in &b.tokens {
        fnv1a(&mut acc.hash, &t.to_le_bytes());
    }
    for w in &b.weights {
        fnv1a(&mut acc.hash, &w.to_bits().to_le_bytes());
    }
    for v in [&b.prev_idx, &b.pos_ids, &b.q_exit, &b.k_order, &b.k_exit] {
        for x in v {
            fnv1a(&mut acc.hash, &x.to_le_bytes());
        }
    }
    for kb in &b.k_bias {
        fnv1a(&mut acc.hash, &kb.to_bits().to_le_bytes());
    }
}

/// Run one rank's plan against a (read-only) model.  Forest batches of a
/// tree plan go through [`RefModel::step_cached`], serving annotated shared
/// prefixes from `cache` bit-identically (a zero-budget cache degenerates
/// to the plain step — the seed path).
fn run_host_rank(
    model: &RefModel,
    run_model: bool,
    plan: &StepPlan,
    cache: &mut PrefixCache<PrefixActs>,
    acc: &mut HostRankAcc,
) -> crate::Result<usize> {
    run_host_rank_hooked(model, run_model, plan, cache, acc, &mut |_, _| {})
}

/// [`run_host_rank`] with a per-batch progress hook — the seam the bucketed
/// collective pumps through ([`dist::RankWorker::execute_hooked`]): called
/// after each device batch with the unit index ([`dist::plan_units`]).
fn run_host_rank_hooked(
    model: &RefModel,
    run_model: bool,
    plan: &StepPlan,
    cache: &mut PrefixCache<PrefixActs>,
    acc: &mut HostRankAcc,
    on_unit: &mut dyn FnMut(&mut HostRankAcc, usize),
) -> crate::Result<usize> {
    let mut device_tokens = 0usize;
    let mut unit = 0usize;
    let mut absorb = |acc: &mut HostRankAcc, out: crate::trainer::refmodel::RefStep| {
        acc.loss_sum += out.loss_sum;
        acc.weight_sum += out.weight_sum;
        for (g, d) in acc.d_embed.iter_mut().zip(&out.d_embed) {
            *g += d;
        }
    };
    match plan {
        StepPlan::Tree(p) => {
            anyhow::ensure!(
                p.relay.is_none(),
                "HostExecutor covers gateway-free plans (tree exceeds host capacity)"
            );
            for fb in &p.forests {
                if run_model {
                    let out = model.step_cached(fb, cache)?;
                    absorb(acc, out);
                }
                device_tokens += fb.batch.capacity;
                acc.batches += 1;
                hash_batch(&fb.batch, acc);
                on_unit(acc, unit);
                unit += 1;
            }
        }
        StepPlan::Baseline(p) => {
            for b in &p.batches {
                if run_model {
                    let out = model.step(b)?;
                    absorb(acc, out);
                }
                device_tokens += b.capacity;
                acc.batches += 1;
                hash_batch(b, acc);
                on_unit(acc, unit);
                unit += 1;
            }
        }
    }
    Ok(device_tokens)
}

impl StepExecutor for HostExecutor {
    fn execute(&mut self, planned: &PlannedStep) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        let n = planned.plan.n_ranks();
        let reduced = if n == 1 {
            // the seed single-executor path: inline on the caller thread
            // against the primary model, byte-for-byte, zero spawns
            let t_exec = Instant::now();
            let mut acc = HostRankAcc::fresh(self.model.embed.len());
            let tokens = run_host_rank(
                &self.model,
                self.run_model,
                &planned.plan.ranks[0],
                &mut self.prefix_cache,
                &mut acc,
            )?;
            acc.cache = self.prefix_cache.take_stats();
            dist::RankReduce {
                acc,
                device_tokens: tokens,
                rank_walls: vec![t_exec.elapsed().as_secs_f64() * 1e3],
                reduce_ms: 0.0,
                reduce_overlap_ms: 0.0,
                reduce_depth: 0,
                reduce_buckets: 0,
                bucket_overlap_ms: 0.0,
                collective_bytes: 0,
            }
        } else {
            // persistent pool of RefModel replicas — the same RankPool
            // machinery the XLA trainers drive, created once per run
            if self.pool.is_none() {
                let ts = Instant::now();
                let workers: Vec<HostWorker> = (0..n)
                    .map(|_| HostWorker {
                        model: self.model.clone(),
                        run_model: self.run_model,
                        cache: PrefixCache::new(self.prefix_cache.budget_tokens()),
                        updates: self.updates,
                    })
                    .collect();
                self.pool = Some(RankPool::new_with(workers, self.reduce.clone())?);
                self.pool_spawn_ms = ts.elapsed().as_secs_f64() * 1e3;
            }
            let pool = self.pool.as_mut().expect("pool created above");
            pool.execute(&planned.plan)?
        };
        // cost-model feedback, same seam as the XLA TrainerPool: score the
        // predicted imbalance against measured walls, then feed the walls
        // back (no-op under the default token model)
        let cost_model_err = planned.plan.cost_model_err(&reduced.rank_walls);
        planned.plan.observe_walls(&reduced.rank_walls);
        let acc = reduced.acc;
        // step fingerprint: step id + LR bits + the bracket-folded digest
        let mut h = 0xcbf29ce484222325u64;
        fnv1a(&mut h, &planned.step.to_le_bytes());
        fnv1a(&mut h, &planned.lr.to_bits().to_le_bytes());
        fnv1a(&mut h, &acc.hash.to_le_bytes());
        self.fingerprints.push(h);
        if self.sgd {
            if acc.weight_sum > 0.0 {
                for (e, g) in self.model.embed.iter_mut().zip(&acc.d_embed) {
                    *e -= planned.lr * g / acc.weight_sum;
                }
            }
            // the staleness contract: parameters changed, so every cached
            // prefix is stale — hard-invalidate before the next step
            self.updates += 1;
            self.prefix_cache.set_version(self.updates);
            if let Some(pool) = &mut self.pool {
                // replicas apply the identical update (same reduced
                // gradient, same LR, same f64 expression) and so stay
                // bit-identical to the primary; async on the workers
                pool.apply(HostUpdate {
                    lr: planned.lr,
                    weight_sum: acc.weight_sum,
                    d_embed: acc.d_embed.clone(),
                })?;
            }
        }
        if let Some(floor) = self.exec_floor {
            // sleep, not spin: a real device wait blocks without burning
            // the core, so the planner thread can actually overlap even
            // on a 2-vCPU CI runner
            let elapsed = t0.elapsed();
            if elapsed < floor {
                std::thread::sleep(floor - elapsed);
            }
        }
        Ok(StepMetrics {
            step: planned.step,
            loss: if acc.weight_sum > 0.0 { acc.loss_sum / acc.weight_sum } else { 0.0 },
            weight_sum: acc.weight_sum,
            device_tokens: reduced.device_tokens,
            tree_tokens: planned.plan.tree_tokens(),
            flat_tokens: planned.plan.flat_tokens(),
            wall: t0.elapsed(),
            exec_calls: acc.batches,
            forest_batches: acc.batches,
            grad_norm: 0.0,
            plan_ms: 0.0,
            stall_ms: 0.0,
            ranks: planned.plan.n_ranks() as u64,
            reduce_ms: reduced.reduce_ms,
            reduce_overlap_ms: reduced.reduce_overlap_ms,
            reduce_depth: reduced.reduce_depth as u64,
            rank_imbalance: planned.plan.rank_imbalance(),
            ingest_ms: 0.0,
            cost_model_err,
            staleness_steps: 0,
            ripe_queue_depth: 0,
            admitted_sessions: 0,
            xstep_reuse_ratio: reuse_ratio(
                planned.plan.tree_tokens() as u64,
                acc.cache.hit_tokens,
            ),
            cache_hit_tokens: acc.cache.hit_tokens,
            cache_evictions: acc.cache.evictions,
            reduce_buckets: reduced.reduce_buckets,
            bucket_overlap_ms: reduced.bucket_overlap_ms,
            collective_bytes: reduced.collective_bytes,
        })
    }

    fn pool_spawn_ms(&self) -> f64 {
        self.pool_spawn_ms
    }
}
