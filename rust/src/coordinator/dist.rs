//! Rank-sharded execution: a persistent per-rank worker pool with an
//! overlapped log-tree gradient reduction.
//!
//! The paper's testbed (§3.4) is data-parallel: each rank executes a
//! disjoint set of whole trees and the gradients are all-reduced before one
//! optimizer step.  This module is that layer for the single-host
//! reproduction, rebuilt around two ideas:
//!
//! * **Persistent rank workers.**  A [`RankPool`] spawns one worker thread
//!   per rank *once per run* (not per optimizer step, as the earlier
//!   scoped-thread version did) and feeds it `Arc`-shared [`ShardedPlan`]s
//!   over a per-rank channel.  Each worker owns its rank state outright —
//!   for the XLA trainers a full per-rank trainer **replica** whose
//!   [`crate::trainer::Engine`] holds its own parameter tensors, literal
//!   cache, optimizer moments and program handles.  Nothing is shared by
//!   `&`-reference across rank threads anymore, so the pool requires only
//!   `W: Send` — the old `Sync`-on-`&Engine` precondition (which made
//!   `ranks > 1` impossible to compile against a real PJRT backend whose
//!   handles are not `Sync`) is gone by construction.
//! * **Fixed-shape log-tree reduce.**  Rank accumulators are folded by the
//!   binary bracket of [`reduce_schedule`]: at round `d`, rank `r` (with
//!   `r % 2^(d+1) == 0`) absorbs rank `r + 2^d`.  Depth is
//!   `ceil(log2(ranks))` ([`reduce_depth`]), the pairing is a pure function
//!   of rank ids, and merges run *on the worker threads* (accumulators flow
//!   child → parent over peer channels), so the reduction is off the
//!   executor thread's critical path: early-round merges hide behind
//!   still-executing ranks, and the executor thread blocks parked on a
//!   channel — freeing its core for the pipeline's planner thread — instead
//!   of spinning through an O(ranks) serial fold.
//!
//! **Determinism contract** (docs/distributed.md):
//!
//! * `ranks == 1` executes inline on the caller thread against the caller's
//!   own trainer — no worker threads, no replica, no reduction — so it *is*
//!   the seed single-executor pipeline, bit-for-bit.
//! * `ranks == N` is bit-identical run-to-run: each rank's accumulation
//!   order is fixed by its plan, and the cross-rank fold is the fixed
//!   bracket above — thread scheduling and message arrival order can change
//!   wall-clock, never bits (out-of-round arrivals are stashed and merged
//!   in round order).
//! * `ranks == N` vs `ranks == 1` agree to f64 tolerance, not bitwise: the
//!   same per-call gradients are summed in a different association.
//! * **One-time bit change vs. PR 4:** the log-tree bracket *reassociates*
//!   the fold relative to the old serial rank-order reduce
//!   (`((g0+g1)+g2)+g3` became `(g0+g1)+(g2+g3)`), so `ranks >= 3` loss
//!   streams differ from the serial-fold era in the last bits while staying
//!   inside the same 1e-8 relative tolerance vs. `ranks == 1` that
//!   `dist-smoke` has always enforced.  The flattened merge order is still
//!   exactly rank order `0..N` — the tree changes grouping, never ordering.
//!
//! **Replica update discipline.**  After the primary engine applies the
//! Eq. 5 update, the *same* reduced [`GradBuffer`] and LR are broadcast to
//! every worker ([`RankPool::apply`]); each replica applies the identical
//! f64 AdamW math, so replicas stay bit-identical to the primary without
//! any parameter broadcast.  The apply runs asynchronously on the worker
//! threads (jobs are ordered per worker, so the next step's execute sees
//! the updated parameters) and overlaps the planner's next-step planning.
//!
//! [`thread_spawns`] counts every worker thread the pool ever spawned — the
//! probe `tests/dist_equivalence.rs` uses to assert the pool really is
//! created once per run (`ranks` spawns total, zero per subsequent step).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::trainer::planner::{ShardedPlan, StepPlan};
use crate::trainer::prefix_cache::{reuse_ratio, CacheStats};
use crate::trainer::{GradBuffer, StepMetrics};

use super::AnyTrainer;

// ───────────────────────── reduce pairing schedule ─────────────────────────

/// Depth of the fixed binary log-tree reduce: `ceil(log2(n_ranks))`
/// (`0` for a single rank — there is nothing to reduce).
pub fn reduce_depth(n_ranks: usize) -> u32 {
    let mut d = 0u32;
    while (1usize << d) < n_ranks {
        d += 1;
    }
    d
}

/// The fixed reduce bracket for `n_ranks`: `rounds[d]` lists the
/// `(dst, src)` merges of round `d` — `dst` absorbs `src`, and `dst` is
/// always the lower rank id, so the flattened merge order is exactly rank
/// order `0..n` while the grouping is a balanced binary tree.  Odd
/// tails get byes: a rank whose round-`d` partner does not exist simply
/// advances (e.g. `n = 5`: rank 4 waits until the final round).
/// Deterministic in rank ids alone — never in thread timing.
pub fn reduce_schedule(n_ranks: usize) -> Vec<Vec<(usize, usize)>> {
    let mut rounds = Vec::new();
    let mut d = 0usize;
    while (1usize << d) < n_ranks {
        let stride = 1usize << (d + 1);
        let mut pairs = Vec::new();
        for dst in (0..n_ranks).step_by(stride) {
            let src = dst + (1usize << d);
            if src < n_ranks {
                pairs.push((dst, src));
            }
        }
        rounds.push(pairs);
        d += 1;
    }
    rounds
}

/// The rank `src` sends its (sub-)reduction to: `src & (src - 1)` (clear
/// the lowest set bit).  Rank 0 is the root and never sends.
pub fn reduce_parent(rank: usize) -> Option<usize> {
    if rank == 0 {
        None
    } else {
        Some(rank & (rank - 1))
    }
}

/// The source ranks `rank` absorbs, as `(round, src)` in merge order.
pub fn reduce_children(rank: usize, n_ranks: usize) -> Vec<(u32, usize)> {
    let mut out = Vec::new();
    for d in 0..reduce_depth(n_ranks) {
        if rank % (1usize << (d + 1)) == 0 {
            let src = rank + (1usize << d);
            if src < n_ranks {
                out.push((d, src));
            }
        }
    }
    out
}

// ─────────────────────────── spawn-count probe ──────────────────────────────

static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// Total rank worker threads ever spawned by [`RankPool`]s in this process.
/// A pool spawns `n_ranks` threads at construction and none afterwards —
/// the per-step delta must be zero (asserted by `tests/dist_equivalence.rs`;
/// the old scoped-thread path spawned `n_ranks` *per optimizer step*).
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::SeqCst)
}

// ───────────────────────────── worker protocol ──────────────────────────────

/// Per-rank executor state owned by one pool worker thread for the whole
/// run.  Only `Send` is required: state is *moved* into the worker at pool
/// construction, never shared by reference across rank threads.
pub trait RankWorker: Send + 'static {
    /// Per-step accumulator (gradients, losses, digests).
    type Acc: Send + 'static;
    /// The broadcast end-of-step update every replica applies.
    type Update: Send + Sync + 'static;

    /// Execute this rank's plan into a fresh accumulator; returns the
    /// accumulator and the device tokens dispatched.
    fn execute(&mut self, rank: usize, plan: &StepPlan) -> crate::Result<(Self::Acc, usize)>;

    /// Fold a higher rank's accumulator into a lower rank's (the log-tree
    /// merge; `acc` is always the lower rank id's side).
    fn reduce(acc: &mut Self::Acc, other: Self::Acc);

    /// Apply the broadcast update to this worker's replica state.
    fn apply(&mut self, update: &Self::Update) -> crate::Result<()>;
}

/// One subtree of the in-flight reduction, flowing child → parent.
struct Subtree<B> {
    acc: B,
    device_tokens: usize,
    /// Total merge wall time accumulated inside this subtree.
    merge_ms: f64,
    /// Per-rank execute wall times `(rank, ms)` gathered inside this
    /// subtree — at the root, one entry per rank: the measurement the
    /// calibrated cost model learns from.
    walls: Vec<(usize, f64)>,
    /// Latest execute-finish instant inside this subtree (for the
    /// overlap accounting: merges before this instant hid behind
    /// still-executing ranks).
    exec_end: Instant,
}

struct PeerMsg<B> {
    seq: u64,
    from: usize,
    payload: crate::Result<Subtree<B>>,
}

struct RootMsg<B> {
    seq: u64,
    payload: crate::Result<Subtree<B>>,
    reduce_done: Instant,
}

enum Job<U> {
    Execute { seq: u64, plan: Arc<ShardedPlan> },
    Apply { update: Arc<U> },
}

/// Result of one pooled step: the fully reduced accumulator plus the
/// reduce-tree accounting surfaced into [`StepMetrics`].
pub struct RankReduce<B> {
    pub acc: B,
    /// Device tokens dispatched across all ranks.
    pub device_tokens: usize,
    /// Measured per-rank execute wall (ms), indexed by rank — the feedback
    /// signal for the calibrated cost model
    /// ([`crate::trainer::planner::ShardedPlan::observe_walls`]) and the
    /// measured side of the `cost_model_err` metric.
    pub rank_walls: Vec<f64>,
    /// Total merge work across the reduce tree (sum of merge wall times on
    /// every worker; 0 for a single rank).
    pub reduce_ms: f64,
    /// The share of `reduce_ms` that did *not* extend the step's critical
    /// path: merge work finished before the slowest rank finished
    /// executing, plus parallel-round work.  `reduce_ms -
    /// reduce_overlap_ms` is the residual tail the step actually paid.
    pub reduce_overlap_ms: f64,
    /// `ceil(log2(ranks))` — rounds of the fixed reduce bracket.
    pub reduce_depth: u32,
}

// ─────────────────────────────── the pool ───────────────────────────────────

enum PoolInner<W: RankWorker> {
    /// Single rank: the worker lives on the caller thread — the seed
    /// single-executor path, byte-for-byte, with zero thread spawns.
    Inline(W),
    Threads {
        job_txs: Vec<mpsc::Sender<Job<W::Update>>>,
        root_rx: mpsc::Receiver<RootMsg<W::Acc>>,
        handles: Vec<std::thread::JoinHandle<crate::Result<()>>>,
    },
}

/// A persistent pool of per-rank executor workers, created once per run.
///
/// Dropping the pool disconnects the job channels; workers drain and exit
/// on their own.  Call [`RankPool::finish`] for a clean join that surfaces
/// deferred [`RankWorker::apply`] errors (applies run asynchronously, so an
/// apply failure is reported at the next execute — or at `finish`).
pub struct RankPool<W: RankWorker> {
    inner: PoolInner<W>,
    n_ranks: usize,
    seq: u64,
}

impl<W: RankWorker> RankPool<W> {
    /// Spawn one worker thread per rank (none for a single rank), moving
    /// each worker's state onto its thread.  `workers[r]` becomes rank `r`.
    pub fn new(mut workers: Vec<W>) -> crate::Result<Self> {
        anyhow::ensure!(!workers.is_empty(), "rank pool needs at least one worker");
        let n = workers.len();
        if n == 1 {
            let w = workers.pop().expect("one worker");
            return Ok(Self { inner: PoolInner::Inline(w), n_ranks: 1, seq: 0 });
        }
        // per-rank peer channels carry subtree accumulators child → parent
        let (peer_txs, peer_rxs): (Vec<_>, Vec<_>) =
            (0..n).map(|_| mpsc::channel::<PeerMsg<W::Acc>>()).unzip();
        let (root_tx, root_rx) = mpsc::channel::<RootMsg<W::Acc>>();
        let mut job_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (rank, (worker, peer_rx)) in workers.into_iter().zip(peer_rxs).enumerate() {
            let (job_tx, job_rx) = mpsc::channel::<Job<W::Update>>();
            job_txs.push(job_tx);
            let parent_tx = reduce_parent(rank).map(|p| peer_txs[p].clone());
            let root = if rank == 0 { Some(root_tx.clone()) } else { None };
            let children: Vec<usize> =
                reduce_children(rank, n).into_iter().map(|(_, src)| src).collect();
            THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
            let handle = std::thread::Builder::new()
                .name(format!("tt-rank-{rank}"))
                .spawn(move || worker_loop(worker, rank, job_rx, peer_rx, parent_tx, root, children))
                .expect("spawn rank worker thread");
            handles.push(handle);
        }
        Ok(Self { inner: PoolInner::Threads { job_txs, root_rx, handles }, n_ranks: n, seq: 0 })
    }

    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    /// Dispatch one sharded step to every rank and wait for the log-tree
    /// reduced accumulator.  The caller thread blocks parked on a channel
    /// while workers execute and merge — its core is free for the
    /// pipeline's planner thread.
    pub fn execute(&mut self, plan: &Arc<ShardedPlan>) -> crate::Result<RankReduce<W::Acc>> {
        anyhow::ensure!(
            plan.n_ranks() == self.n_ranks,
            "plan has {} ranks but the pool was built for {} (rank count is fixed per run)",
            plan.n_ranks(),
            self.n_ranks
        );
        self.seq += 1;
        let seq = self.seq;
        match &mut self.inner {
            PoolInner::Inline(w) => {
                let t_exec = Instant::now();
                let (acc, device_tokens) = w.execute(0, &plan.ranks[0])?;
                Ok(RankReduce {
                    acc,
                    device_tokens,
                    rank_walls: vec![t_exec.elapsed().as_secs_f64() * 1e3],
                    reduce_ms: 0.0,
                    reduce_overlap_ms: 0.0,
                    reduce_depth: 0,
                })
            }
            PoolInner::Threads { job_txs, root_rx, .. } => {
                for tx in job_txs.iter() {
                    tx.send(Job::Execute { seq, plan: Arc::clone(plan) })
                        .map_err(|_| anyhow::anyhow!("rank worker exited before dispatch"))?;
                }
                let msg = loop {
                    let m = root_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("rank 0 worker disconnected"))?;
                    if m.seq == seq {
                        break m;
                    }
                    // stale root result from an aborted earlier step
                };
                let sub = msg.payload?;
                let tail_ms =
                    msg.reduce_done.saturating_duration_since(sub.exec_end).as_secs_f64() * 1e3;
                let mut rank_walls = vec![0.0f64; plan.n_ranks()];
                for (r, w) in &sub.walls {
                    rank_walls[*r] = *w;
                }
                Ok(RankReduce {
                    acc: sub.acc,
                    device_tokens: sub.device_tokens,
                    rank_walls,
                    reduce_ms: sub.merge_ms,
                    reduce_overlap_ms: (sub.merge_ms - tail_ms).max(0.0),
                    reduce_depth: reduce_depth(plan.n_ranks()),
                })
            }
        }
    }

    /// Broadcast the end-of-step update to every worker.  Asynchronous on a
    /// threaded pool: jobs are ordered per worker, so the next execute sees
    /// the applied update; an apply error surfaces at the next execute (or
    /// at [`Self::finish`]).
    pub fn apply(&mut self, update: W::Update) -> crate::Result<()> {
        match &mut self.inner {
            PoolInner::Inline(w) => w.apply(&update),
            PoolInner::Threads { job_txs, .. } => {
                let update = Arc::new(update);
                for tx in job_txs.iter() {
                    tx.send(Job::Apply { update: Arc::clone(&update) })
                        .map_err(|_| anyhow::anyhow!("rank worker exited before update"))?;
                }
                Ok(())
            }
        }
    }

    /// Shut the pool down and join every worker, surfacing any deferred
    /// apply error or worker panic.
    pub fn finish(self) -> crate::Result<()> {
        match self.inner {
            PoolInner::Inline(_) => Ok(()),
            PoolInner::Threads { job_txs, root_rx, handles } => {
                drop(job_txs);
                drop(root_rx);
                let mut first_err = None;
                for h in handles {
                    match h.join() {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            first_err.get_or_insert(e);
                        }
                        Err(_) => {
                            first_err.get_or_insert(anyhow::anyhow!("rank worker panicked"));
                        }
                    }
                }
                match first_err {
                    None => Ok(()),
                    Some(e) => Err(e),
                }
            }
        }
    }
}

/// Out-of-round-order child results, stashed until their round comes up so
/// the merge order is the fixed bracket regardless of arrival order.
type ChildStash<B> = HashMap<usize, (u64, crate::Result<Subtree<B>>)>;

fn recv_child<B>(
    peer_rx: &mpsc::Receiver<PeerMsg<B>>,
    stash: &mut ChildStash<B>,
    src: usize,
    seq: u64,
) -> crate::Result<Subtree<B>> {
    if let Some((s, payload)) = stash.remove(&src) {
        if s == seq {
            return payload;
        }
        // stale stash entry from an aborted step: fall through and wait
    }
    loop {
        let msg = peer_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("reduce peer rank {src} disconnected"))?;
        if msg.seq < seq {
            continue; // stale message from an aborted earlier step
        }
        if msg.from == src {
            return msg.payload;
        }
        stash.insert(msg.from, (msg.seq, msg.payload));
    }
}

fn worker_loop<W: RankWorker>(
    mut state: W,
    rank: usize,
    job_rx: mpsc::Receiver<Job<W::Update>>,
    peer_rx: mpsc::Receiver<PeerMsg<W::Acc>>,
    parent_tx: Option<mpsc::Sender<PeerMsg<W::Acc>>>,
    root_tx: Option<mpsc::Sender<RootMsg<W::Acc>>>,
    children: Vec<usize>,
) -> crate::Result<()> {
    let mut deferred: Option<anyhow::Error> = None;
    let mut stash: ChildStash<W::Acc> = HashMap::new();
    while let Ok(job) = job_rx.recv() {
        match job {
            Job::Apply { update } => {
                if deferred.is_none() {
                    deferred = match catch_unwind(AssertUnwindSafe(|| state.apply(&update))) {
                        Ok(Ok(())) => None,
                        Ok(Err(e)) => Some(e),
                        Err(_) => Some(anyhow::anyhow!("rank {rank} update apply panicked")),
                    };
                }
            }
            Job::Execute { seq, plan } => {
                let mut sub: crate::Result<Subtree<W::Acc>> = match deferred.take() {
                    Some(e) => Err(e),
                    None => {
                        let t_exec = Instant::now();
                        match catch_unwind(AssertUnwindSafe(|| {
                            state.execute(rank, &plan.ranks[rank])
                        })) {
                            Ok(Ok((acc, device_tokens))) => Ok(Subtree {
                                acc,
                                device_tokens,
                                merge_ms: 0.0,
                                walls: vec![(rank, t_exec.elapsed().as_secs_f64() * 1e3)],
                                exec_end: Instant::now(),
                            }),
                            Ok(Err(e)) => Err(e),
                            Err(_) => Err(anyhow::anyhow!("rank {rank} executor panicked")),
                        }
                    }
                };
                // merge children in fixed round order; errors anywhere in a
                // subtree propagate up, and the full receive schedule always
                // runs so no peer message is left behind (deadlock-free)
                for &src in &children {
                    match recv_child(&peer_rx, &mut stash, src, seq) {
                        Err(e) => {
                            if sub.is_ok() {
                                sub = Err(e);
                            }
                        }
                        Ok(b) => {
                            let Subtree {
                                acc: b_acc,
                                device_tokens: b_tokens,
                                merge_ms: b_merge,
                                walls: b_walls,
                                exec_end: b_end,
                            } = b;
                            let mut panicked = false;
                            if let Ok(a) = &mut sub {
                                let t0 = Instant::now();
                                if catch_unwind(AssertUnwindSafe(|| W::reduce(&mut a.acc, b_acc)))
                                    .is_err()
                                {
                                    panicked = true;
                                } else {
                                    a.merge_ms += t0.elapsed().as_secs_f64() * 1e3 + b_merge;
                                    a.device_tokens += b_tokens;
                                    a.walls.extend(b_walls);
                                    if b_end > a.exec_end {
                                        a.exec_end = b_end;
                                    }
                                }
                            }
                            if panicked {
                                sub = Err(anyhow::anyhow!("rank {rank} reduce panicked"));
                            }
                        }
                    }
                }
                if let Some(tx) = &parent_tx {
                    let _ = tx.send(PeerMsg { seq, from: rank, payload: sub });
                } else if let Some(tx) = &root_tx {
                    let _ = tx.send(RootMsg { seq, payload: sub, reduce_done: Instant::now() });
                }
            }
        }
    }
    match deferred {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

// ───────────────────────── the XLA trainer workers ──────────────────────────

/// Run one rank's plan against a trainer (replica on a worker thread, or
/// the caller's own trainer on the inline single-rank path).
fn run_rank(trainer: &AnyTrainer, plan: &StepPlan) -> crate::Result<(GradBuffer, usize)> {
    match (trainer, plan) {
        (AnyTrainer::Tree(t), StepPlan::Tree(p)) => {
            let mut gb = t.engine.grad_buffer();
            let tokens = t.run_plan(p, &mut gb)?;
            Ok((gb, tokens))
        }
        (AnyTrainer::Baseline(t), StepPlan::Baseline(p)) => {
            let mut gb = t.engine.grad_buffer();
            let tokens = t.run_plan(p, &mut gb)?;
            Ok((gb, tokens))
        }
        (AnyTrainer::Tree(_), StepPlan::Baseline(_)) => {
            anyhow::bail!("baseline rank plan handed to TreeTrainer (pipeline bug)")
        }
        (AnyTrainer::Baseline(_), StepPlan::Tree(_)) => {
            anyhow::bail!("tree rank plan handed to BaselineTrainer (pipeline bug)")
        }
    }
}

/// One rank's persistent executor state: a full trainer replica whose
/// engine owns its own parameters, literal cache, optimizer moments and
/// program handles ([`crate::trainer::Engine::replicate`]).
pub struct TrainerWorker {
    trainer: AnyTrainer,
}

/// The broadcast end-of-step update: every replica applies the identical
/// reduced gradient with the identical LR, so replicas stay bit-identical
/// to the primary engine without any parameter broadcast.
pub struct TrainerUpdate {
    pub lr: f64,
    pub gb: GradBuffer,
}

impl RankWorker for TrainerWorker {
    type Acc = GradBuffer;
    type Update = TrainerUpdate;

    fn execute(&mut self, _rank: usize, plan: &StepPlan) -> crate::Result<(GradBuffer, usize)> {
        run_rank(&self.trainer, plan)
    }

    fn reduce(acc: &mut GradBuffer, other: GradBuffer) {
        GradBuffer::merge_owned(acc, other);
    }

    fn apply(&mut self, update: &TrainerUpdate) -> crate::Result<()> {
        self.trainer.set_lr(update.lr);
        match &mut self.trainer {
            AnyTrainer::Tree(t) => t.engine.apply_update(&update.gb)?,
            AnyTrainer::Baseline(t) => t.engine.apply_update(&update.gb)?,
        };
        Ok(())
    }
}

/// The distributed step driver for the XLA trainers, owned by the run loop
/// for the whole run: `ranks == 1` executes inline on the caller's trainer
/// (the seed single-executor path, byte-for-byte, zero spawns);
/// `ranks >= 2` owns a [`RankPool`] of full trainer replicas created once.
pub struct TrainerPool {
    pool: Option<RankPool<TrainerWorker>>,
    /// One-time pool construction cost (engine replication + thread
    /// spawns), amortized across the run's steps
    /// ([`super::PipelineSummary`] reports the per-step share).
    pub spawn_ms: f64,
}

impl TrainerPool {
    /// Build the pool: replicate the primary trainer once per rank
    /// (`ranks >= 2`) or do nothing (`ranks == 1`).
    pub fn new(trainer: &AnyTrainer, ranks: usize) -> crate::Result<Self> {
        anyhow::ensure!(ranks >= 1, "ranks must be >= 1");
        if ranks == 1 {
            return Ok(Self { pool: None, spawn_ms: 0.0 });
        }
        let t0 = Instant::now();
        let workers = (0..ranks)
            .map(|_| Ok(TrainerWorker { trainer: trainer.replicate()? }))
            .collect::<crate::Result<Vec<_>>>()?;
        let pool = RankPool::new(workers)?;
        Ok(Self { pool: Some(pool), spawn_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// One sharded optimizer step: execute every rank plan (inline or on
    /// the persistent pool), log-tree-reduce the [`GradBuffer`]s, apply one
    /// Eq. 5-normalized update over the *global* (all-rank) weight sum on
    /// the primary engine, and broadcast the identical update to the
    /// replicas.
    pub fn execute_step(
        &mut self,
        trainer: &mut AnyTrainer,
        lr: f64,
        sharded: &Arc<ShardedPlan>,
    ) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        let reduced = match &mut self.pool {
            None => {
                anyhow::ensure!(
                    sharded.n_ranks() == 1,
                    "{}-rank plan on a single-rank pool (rank count is fixed per run)",
                    sharded.n_ranks()
                );
                let t_exec = Instant::now();
                let (acc, device_tokens) = run_rank(trainer, &sharded.ranks[0])?;
                RankReduce {
                    acc,
                    device_tokens,
                    rank_walls: vec![t_exec.elapsed().as_secs_f64() * 1e3],
                    reduce_ms: 0.0,
                    reduce_overlap_ms: 0.0,
                    reduce_depth: 0,
                }
            }
            Some(pool) => pool.execute(sharded)?,
        };
        // cost-model feedback: score the plan's predicted imbalance against
        // the measured per-rank walls, then feed the walls back as
        // regression rows (no-op under the default token model)
        let cost_model_err = sharded.cost_model_err(&reduced.rank_walls);
        sharded.observe_walls(&reduced.rank_walls);
        let loss = reduced.acc.mean_loss();
        let weight_sum = reduced.acc.weight_sum;
        let exec_calls = reduced.acc.exec_calls;
        // prefix-reuse accounting is rank-local: only the inline single-rank
        // path executes on the primary engine, so pooled runs report the
        // inert trio (replicas keep their own counters; docs/prefix_reuse.md)
        let (grad_norm, step, cache) = match trainer {
            AnyTrainer::Tree(t) => {
                let cache = t.engine.take_cache_stats();
                (t.engine.apply_update(&reduced.acc)?, t.engine.step_count(), cache)
            }
            AnyTrainer::Baseline(t) => (
                t.engine.apply_update(&reduced.acc)?,
                t.engine.step_count(),
                CacheStats::default(),
            ),
        };
        if let Some(pool) = &mut self.pool {
            // asynchronous: workers apply while the caller returns metrics
            // and the planner plans the next batch; per-worker job order
            // guarantees the next execute sees the updated parameters
            pool.apply(TrainerUpdate { lr, gb: reduced.acc })?;
        }
        Ok(StepMetrics {
            step,
            loss,
            weight_sum,
            device_tokens: reduced.device_tokens,
            tree_tokens: sharded.tree_tokens(),
            flat_tokens: sharded.flat_tokens(),
            wall: t0.elapsed(),
            exec_calls,
            forest_batches: sharded.device_batches() as u64,
            grad_norm,
            plan_ms: 0.0,
            stall_ms: 0.0,
            ranks: sharded.n_ranks() as u64,
            reduce_ms: reduced.reduce_ms,
            reduce_overlap_ms: reduced.reduce_overlap_ms,
            reduce_depth: reduced.reduce_depth as u64,
            rank_imbalance: sharded.rank_imbalance(),
            ingest_ms: 0.0,
            cost_model_err,
            staleness_steps: 0,
            ripe_queue_depth: 0,
            admitted_sessions: 0,
            xstep_reuse_ratio: reuse_ratio(sharded.tree_tokens() as u64, cache.hit_tokens),
            cache_hit_tokens: cache.hit_tokens,
            cache_evictions: cache.evictions,
        })
    }

    /// Join the pool, surfacing deferred apply errors.
    pub fn finish(self) -> crate::Result<()> {
        match self.pool {
            None => Ok(()),
            Some(p) => p.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::planner::PlanSpec;
    use crate::tree::gen;
    use crate::tree::TrajectoryTree;
    use std::time::Duration;

    fn sharded(n_trees: usize, n_ranks: usize) -> Arc<ShardedPlan> {
        let trees: Vec<TrajectoryTree> =
            (0..n_trees as u64).map(|s| gen::uniform(90 + s, 9, 5, 0.6)).collect();
        Arc::new(PlanSpec::for_host(4096).plan_sharded_tree(&trees, n_ranks).unwrap())
    }

    // ── pairing schedule (validated against the python mirror:
    //    python/tests/test_reduce_schedule.py) ──

    #[test]
    fn schedule_brackets_match_python_mirror() {
        assert_eq!(reduce_schedule(1), Vec::<Vec<(usize, usize)>>::new());
        assert_eq!(reduce_schedule(2), vec![vec![(0, 1)]]);
        assert_eq!(reduce_schedule(3), vec![vec![(0, 1)], vec![(0, 2)]]);
        assert_eq!(
            reduce_schedule(5),
            vec![vec![(0, 1), (2, 3)], vec![(0, 2)], vec![(0, 4)]]
        );
        assert_eq!(
            reduce_schedule(8),
            vec![
                vec![(0, 1), (2, 3), (4, 5), (6, 7)],
                vec![(0, 2), (4, 6)],
                vec![(0, 4)]
            ]
        );
    }

    #[test]
    fn depth_is_ceil_log2() {
        for (n, d) in [(1, 0u32), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4), (16, 4)] {
            assert_eq!(reduce_depth(n), d, "depth({n})");
            assert_eq!(reduce_schedule(n).len(), d as usize, "rounds({n})");
        }
    }

    #[test]
    fn odd_rank_byes_advance_to_the_right_round() {
        // n = 5: rank 4 has no partner in rounds 0/1 and is absorbed by
        // rank 0 only in the final round
        let sched = reduce_schedule(5);
        assert!(!sched[0].iter().any(|&(a, b)| a == 4 || b == 4));
        assert!(!sched[1].iter().any(|&(a, b)| a == 4 || b == 4));
        assert_eq!(sched[2], vec![(0, 4)]);
    }

    #[test]
    fn schedule_is_consistent_with_per_rank_views() {
        for n in 1..=17usize {
            let sched = reduce_schedule(n);
            // every rank > 0 is merged exactly once, as src, into its parent
            let mut srcs: Vec<usize> = sched.iter().flatten().map(|&(_, s)| s).collect();
            srcs.sort_unstable();
            assert_eq!(srcs, (1..n).collect::<Vec<_>>(), "n={n}");
            for r in 1..n {
                let round = r.trailing_zeros() as usize;
                let p = reduce_parent(r).unwrap();
                assert_eq!(p, r & (r - 1));
                assert!(sched[round].contains(&(p, r)), "n={n} r={r}");
            }
            // the union of child views is the schedule
            let mut from_children: Vec<Vec<(usize, usize)>> = vec![Vec::new(); sched.len()];
            for r in 0..n {
                for (d, src) in reduce_children(r, n) {
                    from_children[d as usize].push((r, src));
                }
            }
            for (a, b) in sched.iter().zip(&from_children) {
                let mut a = a.clone();
                let mut b = b.clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "n={n}");
            }
        }
    }

    // ── pool behavior ──

    /// Bracket-tracing worker: the reduced string is the exact merge
    /// association, regardless of worker finish order.
    struct TraceWorker;

    impl RankWorker for TraceWorker {
        type Acc = String;
        type Update = ();

        fn execute(&mut self, rank: usize, _plan: &StepPlan) -> crate::Result<(String, usize)> {
            // higher ranks finish *first*: arrival order is reversed
            std::thread::sleep(Duration::from_millis(4 * (8u64.saturating_sub(rank as u64))));
            Ok((rank.to_string(), 1))
        }

        fn reduce(acc: &mut String, other: String) {
            *acc = format!("({acc}+{other})");
        }

        fn apply(&mut self, _u: &()) -> crate::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn reduction_bracket_is_fixed_regardless_of_finish_order() {
        let plan = sharded(8, 4);
        let mut pool = RankPool::new(vec![TraceWorker, TraceWorker, TraceWorker, TraceWorker])
            .unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, "((0+1)+(2+3))");
        assert_eq!(r.device_tokens, 4);
        assert_eq!(r.reduce_depth, 2);
        assert_eq!(r.rank_walls.len(), 4, "one measured wall per rank");
        assert!(r.rank_walls.iter().all(|&w| w > 0.0), "walls: {:?}", r.rank_walls);
        // the trace workers sleep longest on rank 0: walls must reflect it
        assert!(r.rank_walls[0] > r.rank_walls[3], "walls: {:?}", r.rank_walls);
        // and again on the same (persistent) pool
        let r2 = pool.execute(&plan).unwrap();
        assert_eq!(r2.acc, "((0+1)+(2+3))");
        pool.finish().unwrap();
    }

    #[test]
    fn odd_rank_count_brackets_deterministically() {
        let plan = sharded(6, 5);
        let mut pool =
            RankPool::new((0..5).map(|_| TraceWorker).collect::<Vec<_>>()).unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, "(((0+1)+(2+3))+4)");
        assert_eq!(r.reduce_depth, 3);
        pool.finish().unwrap();
    }

    struct CountWorker {
        offset: f64,
    }

    impl RankWorker for CountWorker {
        type Acc = f64;
        type Update = f64;

        fn execute(&mut self, _rank: usize, _plan: &StepPlan) -> crate::Result<(f64, usize)> {
            Ok((self.offset, 7))
        }

        fn reduce(acc: &mut f64, other: f64) {
            *acc += other;
        }

        fn apply(&mut self, u: &f64) -> crate::Result<()> {
            self.offset += *u;
            Ok(())
        }
    }

    #[test]
    fn single_rank_runs_inline_with_zero_reduce() {
        // (the zero-spawn property is asserted via the thread_spawns probe
        // in tests/dist_equivalence.rs, where pool-creating tests are
        // serialized — the global counter is racy across parallel #[test]s)
        let plan = sharded(4, 1);
        let main_thread = std::thread::current().id();

        struct InlineProbe(std::thread::ThreadId);
        impl RankWorker for InlineProbe {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, _p: &StepPlan) -> crate::Result<(usize, usize)> {
                assert_eq!(std::thread::current().id(), self.0, "must run inline");
                Ok((1, 7))
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }

        let mut pool = RankPool::new(vec![InlineProbe(main_thread)]).unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, 1);
        assert_eq!(r.device_tokens, 7);
        assert_eq!(r.rank_walls.len(), 1);
        assert_eq!(r.reduce_ms, 0.0);
        assert_eq!(r.reduce_overlap_ms, 0.0);
        assert_eq!(r.reduce_depth, 0);
        pool.finish().unwrap();
    }

    #[test]
    fn pool_applies_updates_between_steps() {
        let plan = sharded(8, 4);
        let mut pool =
            RankPool::new((0..4).map(|_| CountWorker { offset: 1.0 }).collect::<Vec<_>>())
                .unwrap();
        assert_eq!(pool.execute(&plan).unwrap().acc, 4.0);
        pool.apply(0.5).unwrap();
        // job order per worker guarantees the apply lands before this
        assert_eq!(pool.execute(&plan).unwrap().acc, 6.0);
        pool.finish().unwrap();
    }

    struct FailWorker {
        fail: bool,
        fail_apply: bool,
    }

    impl RankWorker for FailWorker {
        type Acc = usize;
        type Update = ();

        fn execute(&mut self, rank: usize, _plan: &StepPlan) -> crate::Result<(usize, usize)> {
            if self.fail {
                anyhow::bail!("rank {rank} exploded")
            }
            Ok((1, 0))
        }

        fn reduce(acc: &mut usize, other: usize) {
            *acc += other;
        }

        fn apply(&mut self, _u: &()) -> crate::Result<()> {
            if self.fail_apply {
                anyhow::bail!("apply failed")
            }
            Ok(())
        }
    }

    #[test]
    fn rank_error_propagates_through_the_reduce_tree() {
        let plan = sharded(6, 3);
        let workers = (0..3)
            .map(|r| FailWorker { fail: r == 1, fail_apply: false })
            .collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("rank 1 exploded"), "got: {err}");
    }

    #[test]
    fn deferred_apply_error_surfaces_at_next_execute() {
        let plan = sharded(4, 2);
        let workers = (0..2)
            .map(|r| FailWorker { fail: false, fail_apply: r == 1 })
            .collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        pool.execute(&plan).unwrap();
        pool.apply(()).unwrap(); // async: error is deferred
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("apply failed"), "got: {err}");
    }

    #[test]
    fn deferred_apply_error_surfaces_at_finish() {
        let plan = sharded(4, 2);
        let workers = (0..2)
            .map(|r| FailWorker { fail: false, fail_apply: r == 0 })
            .collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        pool.execute(&plan).unwrap();
        pool.apply(()).unwrap();
        let err = pool.finish().unwrap_err();
        assert!(err.to_string().contains("apply failed"), "got: {err}");
    }

    #[test]
    fn empty_rank_plans_are_benign() {
        // more ranks than trees: empty rank plans execute as no-ops
        struct ForestCounter;
        impl RankWorker for ForestCounter {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, p: &StepPlan) -> crate::Result<(usize, usize)> {
                let StepPlan::Tree(g) = p else { panic!("tree mode") };
                Ok((g.forests.len(), g.forests.iter().map(|f| f.batch.capacity).sum()))
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }
        let plan = sharded(2, 4);
        let mut pool = RankPool::new((0..4).map(|_| ForestCounter).collect::<Vec<_>>()).unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, 2, "both trees execute exactly once");
        pool.finish().unwrap();
    }

    #[test]
    fn rank_count_mismatch_is_an_error() {
        let mut pool =
            RankPool::new((0..3).map(|_| CountWorker { offset: 0.0 }).collect::<Vec<_>>())
                .unwrap();
        let err = pool.execute(&sharded(6, 4)).unwrap_err();
        assert!(err.to_string().contains("fixed per run"), "got: {err}");
    }

    #[test]
    fn mode_mismatch_is_an_error_not_a_panic() {
        // a baseline plan handed to a tree-mode worker must surface as an
        // error through the pool, not poison it
        use crate::trainer::planner::BaselinePlan;
        struct TreeOnly;
        impl RankWorker for TreeOnly {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, p: &StepPlan) -> crate::Result<(usize, usize)> {
                match p {
                    StepPlan::Tree(_) => Ok((0, 0)),
                    StepPlan::Baseline(_) => anyhow::bail!("plan/trainer mode mismatch"),
                }
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }
        let plan = Arc::new(ShardedPlan {
            ranks: vec![StepPlan::Baseline(BaselinePlan {
                batches: vec![],
                tree_tokens: 0,
                flat_tokens: 0,
            })],
            loads: vec![0],
            rank_feats: vec![[0.0; 4]],
            cost: crate::partition::CostModel::Tokens,
        });
        let mut pool = RankPool::new(vec![TreeOnly]).unwrap();
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("mode mismatch"), "got: {err}");
    }

    #[test]
    fn worker_panic_is_an_error_not_a_deadlock() {
        struct PanicWorker {
            boom: bool,
        }
        impl RankWorker for PanicWorker {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, _p: &StepPlan) -> crate::Result<(usize, usize)> {
                if self.boom {
                    panic!("worker panic")
                }
                Ok((1, 0))
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }
        let plan = sharded(8, 4);
        let workers = (0..4).map(|r| PanicWorker { boom: r == 2 }).collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("panicked"), "got: {err}");
    }
}
