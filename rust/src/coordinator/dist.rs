//! Rank-sharded execution: a persistent per-rank worker pool with an
//! overlapped log-tree gradient reduction.
//!
//! The paper's testbed (§3.4) is data-parallel: each rank executes a
//! disjoint set of whole trees and the gradients are all-reduced before one
//! optimizer step.  This module is that layer for the single-host
//! reproduction, rebuilt around two ideas:
//!
//! * **Persistent rank workers.**  A [`RankPool`] spawns one worker thread
//!   per rank *once per run* (not per optimizer step, as the earlier
//!   scoped-thread version did) and feeds it `Arc`-shared [`ShardedPlan`]s
//!   over a per-rank channel.  Each worker owns its rank state outright —
//!   for the XLA trainers a full per-rank trainer **replica** whose
//!   [`crate::trainer::Engine`] holds its own parameter tensors, literal
//!   cache, optimizer moments and program handles.  Nothing is shared by
//!   `&`-reference across rank threads anymore, so the pool requires only
//!   `W: Send` — the old `Sync`-on-`&Engine` precondition (which made
//!   `ranks > 1` impossible to compile against a real PJRT backend whose
//!   handles are not `Sync`) is gone by construction.
//! * **Fixed-shape log-tree reduce.**  Rank accumulators are folded by the
//!   binary bracket of [`reduce_schedule`]: at round `d`, rank `r` (with
//!   `r % 2^(d+1) == 0`) absorbs rank `r + 2^d`.  Depth is
//!   `ceil(log2(ranks))` ([`reduce_depth`]), the pairing is a pure function
//!   of rank ids, and merges run *on the worker threads* (accumulators flow
//!   child → parent over peer channels), so the reduction is off the
//!   executor thread's critical path: early-round merges hide behind
//!   still-executing ranks, and the executor thread blocks parked on a
//!   channel — freeing its core for the pipeline's planner thread — instead
//!   of spinning through an O(ranks) serial fold.
//! * **Pluggable, bucketed collective** ([`crate::coordinator::collective`],
//!   behind [`ReduceOptions`]).  The reduction is split into a typed
//!   *control plane* (the channels above: errors, walls, scalar sums,
//!   digests — all of PR 5's machinery, unchanged) and an f64 *data plane*:
//!   the gradient payload travels as fixed parameter-range **buckets** over
//!   a [`Collective`] transport — in-process channels or length-prefixed
//!   frames on loopback sockets with a rendezvous file (Gloo-shaped,
//!   multi-process capable).  Each rank folds a bucket's children strictly
//!   in bracket round order and sends it up as soon as it is complete, from
//!   a hook *inside* execute ([`RankWorker::execute_hooked`]) — so bucket
//!   `b` can climb the tree while bucket `b+1` is still folding and while
//!   slower ranks are still executing, instead of the whole payload
//!   stalling on the last batch.  `reduce_bucket_kb = 0` with the
//!   in-process transport is byte-for-byte today's monolithic path (no
//!   collective is even constructed).
//!
//! **Determinism contract** (docs/distributed.md):
//!
//! * `ranks == 1` executes inline on the caller thread against the caller's
//!   own trainer — no worker threads, no replica, no reduction — so it *is*
//!   the seed single-executor pipeline, bit-for-bit.
//! * `ranks == N` is bit-identical run-to-run: each rank's accumulation
//!   order is fixed by its plan, and the cross-rank fold is the fixed
//!   bracket above — thread scheduling and message arrival order can change
//!   wall-clock, never bits (out-of-round arrivals are stashed and merged
//!   in round order).
//! * Bucketing and transport choice never change bits either: per payload
//!   element the fold sequence — own accumulation complete first, then
//!   children in bracket round order — is identical whether the payload is
//!   folded whole-buffer on the typed path or bucket-by-bucket on any
//!   collective transport, so every `(reduce_bucket_kb, transport)` config
//!   reduces to the *same bits* (proof sketch in docs/distributed.md;
//!   python mirror: `python/tests/test_bucket_reduce.py`).
//! * `ranks == N` vs `ranks == 1` agree to f64 tolerance, not bitwise: the
//!   same per-call gradients are summed in a different association.
//! * **One-time bit change vs. PR 4:** the log-tree bracket *reassociates*
//!   the fold relative to the old serial rank-order reduce
//!   (`((g0+g1)+g2)+g3` became `(g0+g1)+(g2+g3)`), so `ranks >= 3` loss
//!   streams differ from the serial-fold era in the last bits while staying
//!   inside the same 1e-8 relative tolerance vs. `ranks == 1` that
//!   `dist-smoke` has always enforced.  The flattened merge order is still
//!   exactly rank order `0..N` — the tree changes grouping, never ordering.
//!
//! **Replica update discipline.**  After the primary engine applies the
//! Eq. 5 update, the *same* reduced [`GradBuffer`] and LR are broadcast to
//! every worker ([`RankPool::apply`]); each replica applies the identical
//! f64 AdamW math, so replicas stay bit-identical to the primary without
//! any parameter broadcast.  The apply runs asynchronously on the worker
//! threads (jobs are ordered per worker, so the next step's execute sees
//! the updated parameters) and overlaps the planner's next-step planning.
//!
//! [`thread_spawns`] counts every worker thread the pool ever spawned — the
//! probe `tests/dist_equivalence.rs` uses to assert the pool really is
//! created once per run (`ranks` spawns total, zero per subsequent step).

use std::collections::HashMap;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::trainer::planner::{ShardedPlan, StepPlan};
use crate::trainer::prefix_cache::{reuse_ratio, CacheStats};
use crate::trainer::{GradBuffer, StepMetrics};

use super::collective::{bucket_ranges, ChannelCollective, Collective, SocketCollective};
use super::AnyTrainer;

// ───────────────────────── reduce pairing schedule ─────────────────────────

/// Depth of the fixed binary log-tree reduce: `ceil(log2(n_ranks))`
/// (`0` for a single rank — there is nothing to reduce).
pub fn reduce_depth(n_ranks: usize) -> u32 {
    let mut d = 0u32;
    while (1usize << d) < n_ranks {
        d += 1;
    }
    d
}

/// The fixed reduce bracket for `n_ranks`: `rounds[d]` lists the
/// `(dst, src)` merges of round `d` — `dst` absorbs `src`, and `dst` is
/// always the lower rank id, so the flattened merge order is exactly rank
/// order `0..n` while the grouping is a balanced binary tree.  Odd
/// tails get byes: a rank whose round-`d` partner does not exist simply
/// advances (e.g. `n = 5`: rank 4 waits until the final round).
/// Deterministic in rank ids alone — never in thread timing.
pub fn reduce_schedule(n_ranks: usize) -> Vec<Vec<(usize, usize)>> {
    let mut rounds = Vec::new();
    let mut d = 0usize;
    while (1usize << d) < n_ranks {
        let stride = 1usize << (d + 1);
        let mut pairs = Vec::new();
        for dst in (0..n_ranks).step_by(stride) {
            let src = dst + (1usize << d);
            if src < n_ranks {
                pairs.push((dst, src));
            }
        }
        rounds.push(pairs);
        d += 1;
    }
    rounds
}

/// The rank `src` sends its (sub-)reduction to: `src & (src - 1)` (clear
/// the lowest set bit).  Rank 0 is the root and never sends.
pub fn reduce_parent(rank: usize) -> Option<usize> {
    if rank == 0 {
        None
    } else {
        Some(rank & (rank - 1))
    }
}

/// The source ranks `rank` absorbs, as `(round, src)` in merge order.
pub fn reduce_children(rank: usize, n_ranks: usize) -> Vec<(u32, usize)> {
    let mut out = Vec::new();
    for d in 0..reduce_depth(n_ranks) {
        if rank % (1usize << (d + 1)) == 0 {
            let src = rank + (1usize << d);
            if src < n_ranks {
                out.push((d, src));
            }
        }
    }
    out
}

// ─────────────────────────── spawn-count probe ──────────────────────────────

static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// Total rank worker threads ever spawned by [`RankPool`]s in this process.
/// A pool spawns `n_ranks` threads at construction and none afterwards —
/// the per-step delta must be zero (asserted by `tests/dist_equivalence.rs`;
/// the old scoped-thread path spawned `n_ranks` *per optimizer step*).
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::SeqCst)
}

// ──────────────────────────── reduce options ────────────────────────────────

/// Which [`Collective`] transport carries the bucket data plane.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Transport {
    /// In-process `mpsc` bus (the reference impl; zero serialization).
    #[default]
    InProcess,
    /// Length-prefixed frames over loopback TCP with a rendezvous file —
    /// the Gloo-shaped, multi-process-capable transport.
    Socket,
}

impl Transport {
    pub fn parse(s: &str) -> crate::Result<Transport> {
        match s {
            "in_process" | "inprocess" | "channel" => Ok(Transport::InProcess),
            "socket" => Ok(Transport::Socket),
            other => anyhow::bail!("unknown collective transport {other:?} (in_process|socket)"),
        }
    }
}

/// How a [`RankPool`] reduces: bucket size and transport.  The default
/// (`bucket_kb == 0`, in-process) is byte-for-byte the monolithic typed
/// path — no collective is constructed at all.
#[derive(Clone, Debug, Default)]
pub struct ReduceOptions {
    /// Bucket size in KiB of f64 payload (`0` = one monolithic bucket; on
    /// the in-process transport `0` short-circuits to the legacy path).
    pub bucket_kb: usize,
    /// Data-plane transport.
    pub transport: Transport,
    /// Rendezvous file for the socket transport (auto-generated in the
    /// system temp dir when unset).
    pub rendezvous: Option<std::path::PathBuf>,
    /// Upper bound on a decoded socket frame's payload element count.
    /// `None` lets [`RankPool::new_with`] derive it from the workers'
    /// `flat_grad_len` (plus control-plane slack), so a corrupt or hostile
    /// frame header can never drive an unbounded allocation.
    pub max_frame_elems: Option<usize>,
    /// Per-peer read/write deadline on the socket transport: a blocked
    /// `send_up` to a dead parent or `recv` from a dead child errors after
    /// this long instead of hanging.  `None` (the default) keeps the
    /// untimed single-process behavior.
    pub deadline: Option<std::time::Duration>,
}

impl ReduceOptions {
    /// Whether this config routes payloads over a [`Collective`] at all.
    pub fn uses_collective(&self) -> bool {
        self.bucket_kb > 0 || self.transport == Transport::Socket
    }
}

// ───────────────────────────── worker protocol ──────────────────────────────

/// Per-rank executor state owned by one pool worker thread for the whole
/// run.  Only `Send` is required: state is *moved* into the worker at pool
/// construction, never shared by reference across rank threads.
///
/// The payload methods (`flat_grad_len` / `read_payload` / `fold_payload` /
/// `strip_payload` / `reduce_stripped` / `execute_hooked`) opt a worker
/// into the bucketed collective data plane; the defaults leave a worker on
/// the monolithic typed path regardless of [`ReduceOptions`], so simple
/// workers (tests, counters) never see buckets.
pub trait RankWorker: Send + 'static {
    /// Per-step accumulator (gradients, losses, digests).
    type Acc: Send + 'static;
    /// The broadcast end-of-step update every replica applies.
    type Update: Send + Sync + 'static;

    /// Execute this rank's plan into a fresh accumulator; returns the
    /// accumulator and the device tokens dispatched.
    fn execute(&mut self, rank: usize, plan: &StepPlan) -> crate::Result<(Self::Acc, usize)>;

    /// Fold a higher rank's accumulator into a lower rank's (the log-tree
    /// merge; `acc` is always the lower rank id's side).
    fn reduce(acc: &mut Self::Acc, other: Self::Acc);

    /// Apply the broadcast update to this worker's replica state.
    fn apply(&mut self, update: &Self::Update) -> crate::Result<()>;

    // ── bucketed data plane (optional; defaults = monolithic path) ──

    /// Length of the flat f64 payload the collective can bucket, identical
    /// on every rank.  `None` (the default) keeps the worker on the
    /// monolithic typed path.
    fn flat_grad_len(&self) -> Option<usize> {
        None
    }

    /// Copy the flat payload range into `out` (cleared first).
    fn read_payload(_acc: &Self::Acc, _range: Range<usize>, _out: &mut Vec<f64>) {}

    /// Element-wise add a child's bucket into the flat payload range.
    fn fold_payload(_acc: &mut Self::Acc, _range: Range<usize>, _data: &[f64]) {}

    /// Drop the payload before the accumulator travels the typed control
    /// plane (its payload already went up the collective).
    fn strip_payload(_acc: &mut Self::Acc) {}

    /// Merge a payload-stripped child accumulator: scalars and digests
    /// only.  Must fold those fields in exactly the order [`Self::reduce`]
    /// does, so control-plane sums stay bit-identical to the monolithic
    /// path.  The default delegates to `reduce` (correct whenever `reduce`
    /// tolerates an empty payload).
    fn reduce_stripped(acc: &mut Self::Acc, other: Self::Acc) {
        Self::reduce(acc, other);
    }

    /// [`Self::execute`] with a progress hook the pool uses to pump the
    /// collective *inside* the execute window: called after each device
    /// batch as `on_unit(&mut acc, unit_index)`.  The default ignores the
    /// hook (all bucket work then happens post-execute — correct, just
    /// zero overlap).
    fn execute_hooked(
        &mut self,
        rank: usize,
        plan: &StepPlan,
        on_unit: &mut dyn FnMut(&mut Self::Acc, usize),
    ) -> crate::Result<(Self::Acc, usize)> {
        let _ = on_unit;
        self.execute(rank, plan)
    }
}

/// Hook invocations [`RankWorker::execute_hooked`] will make for `plan`:
/// one per forest device batch plus one for the relay (tree mode), one per
/// packed batch (baseline).  The pump treats the last unit as the point
/// where every bucket's own accumulation is final — with a dense gradient
/// (the tied-softmax reference model touches every parameter row each
/// batch) no bucket is final earlier; a sparse backward would move
/// readiness earlier through this same seam.
pub fn plan_units(plan: &StepPlan) -> usize {
    match plan {
        StepPlan::Tree(p) => p.forests.len() + usize::from(p.relay.is_some()),
        StepPlan::Baseline(p) => p.batches.len(),
    }
}

/// One subtree of the in-flight reduction, flowing child → parent.
/// `pub(crate)` so the multi-process launcher's rank-worker runtime
/// ([`crate::coordinator::launcher`]) can drive the same bucketed execute.
pub(crate) struct Subtree<B> {
    pub(crate) acc: B,
    pub(crate) device_tokens: usize,
    /// Total merge wall time accumulated inside this subtree.
    pub(crate) merge_ms: f64,
    /// Per-rank execute wall times `(rank, ms)` gathered inside this
    /// subtree — at the root, one entry per rank: the measurement the
    /// calibrated cost model learns from.
    pub(crate) walls: Vec<(usize, f64)>,
    /// Latest execute-finish instant inside this subtree (for the
    /// overlap accounting: merges before this instant hid behind
    /// still-executing ranks).
    pub(crate) exec_end: Instant,
    /// Collective fold + send wall spent *inside* execute windows across
    /// this subtree (the bucketed path's overlap; 0 on the typed path).
    pub(crate) bucket_overlap_ms: f64,
    /// Wire bytes the subtree's ranks sent up the collective.
    pub(crate) collective_bytes: u64,
    /// Buckets per rank this step (0 on the monolithic typed path).
    pub(crate) buckets: u32,
}

struct PeerMsg<B> {
    seq: u64,
    from: usize,
    payload: crate::Result<Subtree<B>>,
}

struct RootMsg<B> {
    seq: u64,
    payload: crate::Result<Subtree<B>>,
    reduce_done: Instant,
}

enum Job<U> {
    Execute { seq: u64, plan: Arc<ShardedPlan> },
    Apply { update: Arc<U> },
}

/// Result of one pooled step: the fully reduced accumulator plus the
/// reduce-tree accounting surfaced into [`StepMetrics`].
pub struct RankReduce<B> {
    pub acc: B,
    /// Device tokens dispatched across all ranks.
    pub device_tokens: usize,
    /// Measured per-rank execute wall (ms), indexed by rank — the feedback
    /// signal for the calibrated cost model
    /// ([`crate::trainer::planner::ShardedPlan::observe_walls`]) and the
    /// measured side of the `cost_model_err` metric.
    pub rank_walls: Vec<f64>,
    /// Total merge work across the reduce tree (sum of merge wall times on
    /// every worker; 0 for a single rank).
    pub reduce_ms: f64,
    /// The share of `reduce_ms` that did *not* extend the step's critical
    /// path: merge work finished before the slowest rank finished
    /// executing, plus parallel-round work.  `reduce_ms -
    /// reduce_overlap_ms` is the residual tail the step actually paid.
    pub reduce_overlap_ms: f64,
    /// `ceil(log2(ranks))` — rounds of the fixed reduce bracket.
    pub reduce_depth: u32,
    /// Buckets the payload was split into (0 = monolithic typed path).
    pub reduce_buckets: u64,
    /// Collective fold + send wall hidden inside execute windows, summed
    /// across ranks (the bucketed path's measured overlap).
    pub bucket_overlap_ms: f64,
    /// Wire bytes sent over the collective, summed across ranks.
    pub collective_bytes: u64,
}

// ─────────────────────────────── the pool ───────────────────────────────────

enum PoolInner<W: RankWorker> {
    /// Single rank: the worker lives on the caller thread — the seed
    /// single-executor path, byte-for-byte, with zero thread spawns.
    Inline(W),
    Threads {
        job_txs: Vec<mpsc::Sender<Job<W::Update>>>,
        root_rx: mpsc::Receiver<RootMsg<W::Acc>>,
        handles: Vec<std::thread::JoinHandle<crate::Result<()>>>,
    },
}

/// A persistent pool of per-rank executor workers, created once per run.
///
/// Dropping the pool disconnects the job channels; workers drain and exit
/// on their own.  Call [`RankPool::finish`] for a clean join that surfaces
/// deferred [`RankWorker::apply`] errors (applies run asynchronously, so an
/// apply failure is reported at the next execute — or at `finish`).
pub struct RankPool<W: RankWorker> {
    inner: PoolInner<W>,
    n_ranks: usize,
    seq: u64,
}

impl<W: RankWorker> RankPool<W> {
    /// Spawn one worker thread per rank (none for a single rank), moving
    /// each worker's state onto its thread.  `workers[r]` becomes rank `r`.
    /// Monolithic in-process reduction (the seed path).
    pub fn new(workers: Vec<W>) -> crate::Result<Self> {
        Self::new_with(workers, ReduceOptions::default())
    }

    /// [`Self::new`] with an explicit bucket size and transport.  With the
    /// default options no collective is constructed and the pool is
    /// byte-for-byte the legacy monolithic path.
    pub fn new_with(mut workers: Vec<W>, opts: ReduceOptions) -> crate::Result<Self> {
        anyhow::ensure!(!workers.is_empty(), "rank pool needs at least one worker");
        let n = workers.len();
        if n == 1 {
            let w = workers.pop().expect("one worker");
            return Ok(Self { inner: PoolInner::Inline(w), n_ranks: 1, seq: 0 });
        }
        let mut opts = opts;
        if opts.max_frame_elems.is_none() {
            // bound socket frames by the step's flat gradient length: no
            // legitimate data frame is larger, and control frames (the
            // launcher path) are far smaller
            opts.max_frame_elems = workers[0].flat_grad_len();
        }
        let mut collectives = build_collectives(n, &opts)?;
        // per-rank peer channels carry subtree accumulators child → parent
        let (peer_txs, peer_rxs): (Vec<_>, Vec<_>) =
            (0..n).map(|_| mpsc::channel::<PeerMsg<W::Acc>>()).unzip();
        let (root_tx, root_rx) = mpsc::channel::<RootMsg<W::Acc>>();
        let mut job_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (rank, (worker, peer_rx)) in workers.into_iter().zip(peer_rxs).enumerate() {
            let (job_tx, job_rx) = mpsc::channel::<Job<W::Update>>();
            job_txs.push(job_tx);
            let parent_tx = reduce_parent(rank).map(|p| peer_txs[p].clone());
            let root = if rank == 0 { Some(root_tx.clone()) } else { None };
            let children: Vec<usize> =
                reduce_children(rank, n).into_iter().map(|(_, src)| src).collect();
            let coll = collectives[rank].take();
            let bucket_kb = opts.bucket_kb;
            THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
            let handle = std::thread::Builder::new()
                .name(format!("tt-rank-{rank}"))
                .spawn(move || {
                    worker_loop(worker, rank, job_rx, peer_rx, parent_tx, root, children, coll, bucket_kb)
                })
                .expect("spawn rank worker thread");
            handles.push(handle);
        }
        Ok(Self { inner: PoolInner::Threads { job_txs, root_rx, handles }, n_ranks: n, seq: 0 })
    }

    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    /// Dispatch one sharded step to every rank and wait for the log-tree
    /// reduced accumulator.  The caller thread blocks parked on a channel
    /// while workers execute and merge — its core is free for the
    /// pipeline's planner thread.
    pub fn execute(&mut self, plan: &Arc<ShardedPlan>) -> crate::Result<RankReduce<W::Acc>> {
        anyhow::ensure!(
            plan.n_ranks() == self.n_ranks,
            "plan has {} ranks but the pool was built for {} (rank count is fixed per run)",
            plan.n_ranks(),
            self.n_ranks
        );
        self.seq += 1;
        let seq = self.seq;
        match &mut self.inner {
            PoolInner::Inline(w) => {
                let t_exec = Instant::now();
                let (acc, device_tokens) = w.execute(0, &plan.ranks[0])?;
                Ok(RankReduce {
                    acc,
                    device_tokens,
                    rank_walls: vec![t_exec.elapsed().as_secs_f64() * 1e3],
                    reduce_ms: 0.0,
                    reduce_overlap_ms: 0.0,
                    reduce_depth: 0,
                    reduce_buckets: 0,
                    bucket_overlap_ms: 0.0,
                    collective_bytes: 0,
                })
            }
            PoolInner::Threads { job_txs, root_rx, .. } => {
                for tx in job_txs.iter() {
                    tx.send(Job::Execute { seq, plan: Arc::clone(plan) })
                        .map_err(|_| anyhow::anyhow!("rank worker exited before dispatch"))?;
                }
                let msg = loop {
                    let m = root_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("rank 0 worker disconnected"))?;
                    if m.seq == seq {
                        break m;
                    }
                    // stale root result from an aborted earlier step
                };
                let sub = msg.payload?;
                let tail_ms =
                    msg.reduce_done.saturating_duration_since(sub.exec_end).as_secs_f64() * 1e3;
                let mut rank_walls = vec![0.0f64; plan.n_ranks()];
                for (r, w) in &sub.walls {
                    rank_walls[*r] = *w;
                }
                Ok(RankReduce {
                    acc: sub.acc,
                    device_tokens: sub.device_tokens,
                    rank_walls,
                    reduce_ms: sub.merge_ms,
                    reduce_overlap_ms: (sub.merge_ms - tail_ms).max(0.0),
                    reduce_depth: reduce_depth(plan.n_ranks()),
                    reduce_buckets: sub.buckets as u64,
                    bucket_overlap_ms: sub.bucket_overlap_ms,
                    collective_bytes: sub.collective_bytes,
                })
            }
        }
    }

    /// Broadcast the end-of-step update to every worker.  Asynchronous on a
    /// threaded pool: jobs are ordered per worker, so the next execute sees
    /// the applied update; an apply error surfaces at the next execute (or
    /// at [`Self::finish`]).
    pub fn apply(&mut self, update: W::Update) -> crate::Result<()> {
        match &mut self.inner {
            PoolInner::Inline(w) => w.apply(&update),
            PoolInner::Threads { job_txs, .. } => {
                let update = Arc::new(update);
                for tx in job_txs.iter() {
                    tx.send(Job::Apply { update: Arc::clone(&update) })
                        .map_err(|_| anyhow::anyhow!("rank worker exited before update"))?;
                }
                Ok(())
            }
        }
    }

    /// Shut the pool down and join every worker, surfacing any deferred
    /// apply error or worker panic.
    pub fn finish(self) -> crate::Result<()> {
        match self.inner {
            PoolInner::Inline(_) => Ok(()),
            PoolInner::Threads { job_txs, root_rx, handles } => {
                drop(job_txs);
                drop(root_rx);
                let mut first_err = None;
                for h in handles {
                    match h.join() {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            first_err.get_or_insert(e);
                        }
                        Err(_) => {
                            first_err.get_or_insert(anyhow::anyhow!("rank worker panicked"));
                        }
                    }
                }
                match first_err {
                    None => Ok(()),
                    Some(e) => Err(e),
                }
            }
        }
    }
}

/// Out-of-round-order child results, stashed until their round comes up so
/// the merge order is the fixed bracket regardless of arrival order.
type ChildStash<B> = HashMap<usize, (u64, crate::Result<Subtree<B>>)>;

fn recv_child<B>(
    peer_rx: &mpsc::Receiver<PeerMsg<B>>,
    stash: &mut ChildStash<B>,
    src: usize,
    seq: u64,
) -> crate::Result<Subtree<B>> {
    if let Some((s, payload)) = stash.remove(&src) {
        if s == seq {
            return payload;
        }
        // stale stash entry from an aborted step: fall through and wait
    }
    loop {
        let msg = peer_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("reduce peer rank {src} disconnected"))?;
        if msg.seq < seq {
            continue; // stale message from an aborted earlier step
        }
        if msg.from == src {
            return msg.payload;
        }
        stash.insert(msg.from, (msg.seq, msg.payload));
    }
}

/// Construct the per-rank collective endpoints for `opts` — or all `None`
/// when the config stays on the monolithic typed path (the default: no
/// collective is even allocated).  Socket endpoints must rendezvous
/// concurrently, so they connect on parallel builder threads; a failed
/// rendezvous surfaces here, at pool construction, not mid-step.
fn build_collectives(
    n: usize,
    opts: &ReduceOptions,
) -> crate::Result<Vec<Option<Box<dyn Collective>>>> {
    if !opts.uses_collective() {
        return Ok((0..n).map(|_| None).collect());
    }
    match opts.transport {
        Transport::InProcess => Ok(ChannelCollective::bus(n)
            .into_iter()
            .map(|c| Some(Box::new(c) as Box<dyn Collective>))
            .collect()),
        Transport::Socket => {
            let auto = opts.rendezvous.is_none();
            let path = opts
                .rendezvous
                .clone()
                .unwrap_or_else(|| SocketCollective::fresh_rendezvous("pool"));
            let sopts = crate::coordinator::collective::socket::SocketOptions {
                max_frame_elems: opts.max_frame_elems,
                deadline: opts.deadline,
                run_id: None,
            };
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let p = path.clone();
                    let o = sopts.clone();
                    std::thread::spawn(move || SocketCollective::connect_opts(&p, r, n, &o))
                })
                .collect();
            let mut out = Vec::with_capacity(n);
            let mut first_err = None;
            for h in handles {
                match h.join() {
                    Ok(Ok(c)) => out.push(Some(Box::new(c) as Box<dyn Collective>)),
                    Ok(Err(e)) => {
                        first_err.get_or_insert(e);
                    }
                    Err(_) => {
                        first_err
                            .get_or_insert(anyhow::anyhow!("collective rendezvous thread panicked"));
                    }
                }
            }
            if auto {
                let _ = std::fs::remove_file(&path);
            }
            match first_err {
                None => Ok(out),
                Some(e) => Err(e),
            }
        }
    }
}

/// Keep the frames-per-rank invariant on a step this rank cannot execute
/// (a deferred apply error): every bucket still gets exactly one abort
/// frame, so the bracket parent's blocking receives never hang.  The real
/// error travels the typed control plane as always.
pub(crate) fn abort_all_buckets<W: RankWorker>(
    state: &W,
    coll: &mut dyn Collective,
    seq: u64,
    bucket_kb: usize,
) {
    coll.gc_below(seq);
    if reduce_parent(coll.rank()).is_none() {
        return;
    }
    let flat_len = state.flat_grad_len().unwrap_or(0);
    for b in 0..bucket_ranges(flat_len, bucket_kb).len() {
        let _ = coll.send_abort(seq, b as u32);
    }
}

/// The bucketed execute: run [`RankWorker::execute_hooked`] with a pump
/// that, after every device batch, drains arrived child frames and — once
/// the local accumulation is final (last unit) — folds children strictly in
/// bracket round order and sends complete buckets up, all *inside* the
/// execute window (`bucket_overlap_ms`).  A finish phase after execute
/// blocks for whatever is still missing and sends the remainder, so the
/// per-step frame invariant (each bucket received once per child, sent once
/// if non-root — abort on any failure) holds on every path out.
pub(crate) fn execute_bucketed<W: RankWorker>(
    state: &mut W,
    rank: usize,
    plan: &StepPlan,
    seq: u64,
    coll: &mut dyn Collective,
    bucket_kb: usize,
    children: &[usize],
) -> crate::Result<Subtree<W::Acc>> {
    let flat_len = state.flat_grad_len().unwrap_or(0);
    let ranges = bucket_ranges(flat_len, bucket_kb);
    let n_buckets = ranges.len();
    let is_root = reduce_parent(rank).is_none();
    let units = plan_units(plan);
    coll.gc_below(seq);
    // per-bucket bracket cursor into `children`, send state, poison flag
    let mut next_child = vec![0usize; n_buckets];
    let mut sent = vec![false; n_buckets];
    let mut poisoned = vec![false; n_buckets];
    let mut pump_ms = 0.0f64;
    let mut bytes = 0u64;
    let mut send_err: Option<anyhow::Error> = None;
    let mut scratch: Vec<f64> = Vec::new();

    let t_exec = Instant::now();
    let result = {
        let coll = &mut *coll;
        let ranges = &ranges;
        let next_child = &mut next_child;
        let sent = &mut sent;
        let poisoned = &mut poisoned;
        let pump_ms = &mut pump_ms;
        let bytes = &mut bytes;
        let send_err = &mut send_err;
        let scratch = &mut scratch;
        catch_unwind(AssertUnwindSafe(|| {
            state.execute_hooked(rank, plan, &mut |acc, unit| {
                if send_err.is_some() {
                    return;
                }
                let t0 = Instant::now();
                coll.drain(seq);
                if unit + 1 >= units {
                    // local accumulation is final: fold + forward buckets
                    for (b, range) in ranges.iter().enumerate() {
                        while next_child[b] < children.len() {
                            match coll.try_take(seq, b as u32, children[next_child[b]]) {
                                None => break,
                                Some(f) => {
                                    if f.is_abort() {
                                        poisoned[b] = true;
                                    } else if !poisoned[b] {
                                        W::fold_payload(acc, range.clone(), &f.data);
                                    }
                                    next_child[b] += 1;
                                }
                            }
                        }
                        if !is_root && !sent[b] && next_child[b] == children.len() {
                            let r = if poisoned[b] {
                                coll.send_abort(seq, b as u32)
                            } else {
                                W::read_payload(acc, range.clone(), scratch);
                                coll.send_up(seq, b as u32, scratch)
                            };
                            sent[b] = true;
                            match r {
                                Ok(n) => *bytes += n as u64,
                                Err(e) => {
                                    *send_err = Some(e);
                                    break;
                                }
                            }
                        }
                    }
                }
                *pump_ms += t0.elapsed().as_secs_f64() * 1e3;
            })
        }))
    };
    let exec_wall_ms = t_exec.elapsed().as_secs_f64() * 1e3;
    let exec_end = Instant::now();
    let mut out: crate::Result<(W::Acc, usize)> = match result {
        Ok(Ok(pair)) => Ok(pair),
        Ok(Err(e)) => Err(e),
        Err(_) => Err(anyhow::anyhow!("rank {rank} executor panicked")),
    };
    if let Some(e) = send_err {
        if out.is_ok() {
            out = Err(e);
        }
    }
    // finish: block for missing child frames (fold in cursor order), then
    // send every bucket not yet sent — real payload, or abort on failure
    let t_fin = Instant::now();
    let mut recv_err: Option<anyhow::Error> = None;
    for (b, range) in ranges.iter().enumerate() {
        while next_child[b] < children.len() {
            match coll.recv(seq, b as u32, children[next_child[b]]) {
                Ok(f) => {
                    if f.is_abort() {
                        poisoned[b] = true;
                    } else if !poisoned[b] {
                        if let Ok((acc, _)) = &mut out {
                            W::fold_payload(acc, range.clone(), &f.data);
                        }
                    }
                    next_child[b] += 1;
                }
                Err(e) => {
                    recv_err.get_or_insert(e);
                    poisoned[b] = true;
                    break; // peer gone: stop waiting on this bucket
                }
            }
        }
    }
    if let Some(e) = recv_err {
        if out.is_ok() {
            out = Err(e);
        }
    }
    if !is_root {
        for (b, range) in ranges.iter().enumerate() {
            if sent[b] {
                continue;
            }
            let r = if poisoned[b] || out.is_err() {
                coll.send_abort(seq, b as u32)
            } else {
                let acc = &out.as_ref().expect("checked ok").0;
                W::read_payload(acc, range.clone(), &mut scratch);
                coll.send_up(seq, b as u32, &scratch)
            };
            sent[b] = true;
            match r {
                Ok(n) => bytes += n as u64,
                Err(e) => {
                    // best effort: keep aborting the rest so peers unblock
                    if out.is_ok() {
                        out = Err(e);
                    }
                }
            }
        }
    }
    let finish_ms = t_fin.elapsed().as_secs_f64() * 1e3;
    let (acc, device_tokens) = out?;
    Ok(Subtree {
        acc,
        device_tokens,
        merge_ms: finish_ms,
        walls: vec![(rank, exec_wall_ms)],
        exec_end,
        bucket_overlap_ms: pump_ms,
        collective_bytes: bytes,
        buckets: n_buckets as u32,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<W: RankWorker>(
    mut state: W,
    rank: usize,
    job_rx: mpsc::Receiver<Job<W::Update>>,
    peer_rx: mpsc::Receiver<PeerMsg<W::Acc>>,
    parent_tx: Option<mpsc::Sender<PeerMsg<W::Acc>>>,
    root_tx: Option<mpsc::Sender<RootMsg<W::Acc>>>,
    children: Vec<usize>,
    mut collective: Option<Box<dyn Collective>>,
    bucket_kb: usize,
) -> crate::Result<()> {
    let mut deferred: Option<anyhow::Error> = None;
    let mut stash: ChildStash<W::Acc> = HashMap::new();
    while let Ok(job) = job_rx.recv() {
        match job {
            Job::Apply { update } => {
                if deferred.is_none() {
                    deferred = match catch_unwind(AssertUnwindSafe(|| state.apply(&update))) {
                        Ok(Ok(())) => None,
                        Ok(Err(e)) => Some(e),
                        Err(_) => Some(anyhow::anyhow!("rank {rank} update apply panicked")),
                    };
                }
            }
            Job::Execute { seq, plan } => {
                // the bucketed data plane engages only when a collective was
                // built for this pool AND the worker exposes a flat payload
                // (uniform across ranks — all workers are the same type)
                let bucketed =
                    collective.is_some() && state.flat_grad_len().is_some_and(|l| l > 0);
                let mut sub: crate::Result<Subtree<W::Acc>> = match deferred.take() {
                    Some(e) => {
                        if bucketed {
                            // still owe peers one frame per bucket
                            let coll = collective.as_deref_mut().expect("bucketed");
                            abort_all_buckets(&state, coll, seq, bucket_kb);
                        }
                        Err(e)
                    }
                    None if bucketed => execute_bucketed(
                        &mut state,
                        rank,
                        &plan.ranks[rank],
                        seq,
                        collective.as_deref_mut().expect("bucketed"),
                        bucket_kb,
                        &children,
                    ),
                    None => {
                        let t_exec = Instant::now();
                        match catch_unwind(AssertUnwindSafe(|| {
                            state.execute(rank, &plan.ranks[rank])
                        })) {
                            Ok(Ok((acc, device_tokens))) => Ok(Subtree {
                                acc,
                                device_tokens,
                                merge_ms: 0.0,
                                walls: vec![(rank, t_exec.elapsed().as_secs_f64() * 1e3)],
                                exec_end: Instant::now(),
                                bucket_overlap_ms: 0.0,
                                collective_bytes: 0,
                                buckets: 0,
                            }),
                            Ok(Err(e)) => Err(e),
                            Err(_) => Err(anyhow::anyhow!("rank {rank} executor panicked")),
                        }
                    }
                };
                // merge children in fixed round order; errors anywhere in a
                // subtree propagate up, and the full receive schedule always
                // runs so no peer message is left behind (deadlock-free).
                // In bucketed mode child payloads already arrived as frames,
                // so the typed accumulators come up stripped and merge via
                // `reduce_stripped` (scalars/digests only, same fold order).
                for &src in &children {
                    match recv_child(&peer_rx, &mut stash, src, seq) {
                        Err(e) => {
                            if sub.is_ok() {
                                sub = Err(e);
                            }
                        }
                        Ok(b) => {
                            let Subtree {
                                acc: b_acc,
                                device_tokens: b_tokens,
                                merge_ms: b_merge,
                                walls: b_walls,
                                exec_end: b_end,
                                bucket_overlap_ms: b_overlap,
                                collective_bytes: b_bytes,
                                buckets: b_buckets,
                            } = b;
                            let mut panicked = false;
                            if let Ok(a) = &mut sub {
                                let t0 = Instant::now();
                                if catch_unwind(AssertUnwindSafe(|| {
                                    if bucketed {
                                        W::reduce_stripped(&mut a.acc, b_acc)
                                    } else {
                                        W::reduce(&mut a.acc, b_acc)
                                    }
                                }))
                                .is_err()
                                {
                                    panicked = true;
                                } else {
                                    a.merge_ms += t0.elapsed().as_secs_f64() * 1e3 + b_merge;
                                    a.device_tokens += b_tokens;
                                    a.walls.extend(b_walls);
                                    if b_end > a.exec_end {
                                        a.exec_end = b_end;
                                    }
                                    a.bucket_overlap_ms += b_overlap;
                                    a.collective_bytes += b_bytes;
                                    a.buckets = a.buckets.max(b_buckets);
                                }
                            }
                            if panicked {
                                sub = Err(anyhow::anyhow!("rank {rank} reduce panicked"));
                            }
                        }
                    }
                }
                if let Some(tx) = &parent_tx {
                    if bucketed {
                        // payload already went up the collective; the typed
                        // plane carries only scalars/digests from here
                        if let Ok(a) = &mut sub {
                            W::strip_payload(&mut a.acc);
                        }
                    }
                    let _ = tx.send(PeerMsg { seq, from: rank, payload: sub });
                } else if let Some(tx) = &root_tx {
                    let _ = tx.send(RootMsg { seq, payload: sub, reduce_done: Instant::now() });
                }
            }
        }
    }
    match deferred {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

// ───────────────────────── the XLA trainer workers ──────────────────────────

/// Run one rank's plan against a trainer (replica on a worker thread, or
/// the caller's own trainer on the inline single-rank path), draining the
/// engine's prefix-cache counters into the accumulator so the pooled
/// reduce surfaces a *live* reuse trio — summed across ranks — instead of
/// the primary engine's inert zeros.
fn run_rank(trainer: &AnyTrainer, plan: &StepPlan) -> crate::Result<(GradBuffer, usize)> {
    run_rank_hooked(trainer, plan, &mut |_, _| {})
}

/// [`run_rank`] with the collective pump hook threaded through to the
/// trainer's per-device-batch loop ([`crate::trainer::TreeTrainer::run_plan_hooked`]).
fn run_rank_hooked(
    trainer: &AnyTrainer,
    plan: &StepPlan,
    on_unit: &mut dyn FnMut(&mut GradBuffer, usize),
) -> crate::Result<(GradBuffer, usize)> {
    let (mut gb, tokens) = match (trainer, plan) {
        (AnyTrainer::Tree(t), StepPlan::Tree(p)) => {
            let mut gb = t.engine.grad_buffer();
            let tokens = t.run_plan_hooked(p, &mut gb, on_unit)?;
            (gb, tokens)
        }
        (AnyTrainer::Baseline(t), StepPlan::Baseline(p)) => {
            let mut gb = t.engine.grad_buffer();
            let tokens = t.run_plan_hooked(p, &mut gb, on_unit)?;
            (gb, tokens)
        }
        (AnyTrainer::Tree(_), StepPlan::Baseline(_)) => {
            anyhow::bail!("baseline rank plan handed to TreeTrainer (pipeline bug)")
        }
        (AnyTrainer::Baseline(_), StepPlan::Tree(_)) => {
            anyhow::bail!("tree rank plan handed to BaselineTrainer (pipeline bug)")
        }
    };
    // cache counters ride the typed control plane (never the payload
    // buckets), so strip_payload keeps them intact
    gb.cache.absorb(&trainer.take_cache_stats());
    Ok((gb, tokens))
}

/// One rank's persistent executor state: a full trainer replica whose
/// engine owns its own parameters, literal cache, optimizer moments and
/// program handles ([`crate::trainer::Engine::replicate`]).
pub struct TrainerWorker {
    trainer: AnyTrainer,
}

/// The broadcast end-of-step update: every replica applies the identical
/// reduced gradient with the identical LR, so replicas stay bit-identical
/// to the primary engine without any parameter broadcast.
pub struct TrainerUpdate {
    pub lr: f64,
    pub gb: GradBuffer,
}

impl RankWorker for TrainerWorker {
    type Acc = GradBuffer;
    type Update = TrainerUpdate;

    fn execute(&mut self, _rank: usize, plan: &StepPlan) -> crate::Result<(GradBuffer, usize)> {
        run_rank(&self.trainer, plan)
    }

    fn reduce(acc: &mut GradBuffer, other: GradBuffer) {
        GradBuffer::merge_owned(acc, other);
    }

    fn apply(&mut self, update: &TrainerUpdate) -> crate::Result<()> {
        self.trainer.set_lr(update.lr);
        match &mut self.trainer {
            AnyTrainer::Tree(t) => t.engine.apply_update(&update.gb)?,
            AnyTrainer::Baseline(t) => t.engine.apply_update(&update.gb)?,
        };
        Ok(())
    }

    // ── bucketed data plane: flat views over the GradBuffer ──

    fn flat_grad_len(&self) -> Option<usize> {
        Some(self.trainer.grad_elems())
    }

    fn read_payload(acc: &GradBuffer, range: Range<usize>, out: &mut Vec<f64>) {
        acc.read_flat(range, out);
    }

    fn fold_payload(acc: &mut GradBuffer, range: Range<usize>, data: &[f64]) {
        acc.fold_flat(range, data);
    }

    fn strip_payload(acc: &mut GradBuffer) {
        acc.strip_grads();
    }

    fn reduce_stripped(acc: &mut GradBuffer, other: GradBuffer) {
        // exactly the scalar half of `merge`, in the same fold order
        acc.merge_scalars(&other);
    }

    fn execute_hooked(
        &mut self,
        _rank: usize,
        plan: &StepPlan,
        on_unit: &mut dyn FnMut(&mut GradBuffer, usize),
    ) -> crate::Result<(GradBuffer, usize)> {
        run_rank_hooked(&self.trainer, plan, on_unit)
    }
}

/// The distributed step driver for the XLA trainers, owned by the run loop
/// for the whole run: `ranks == 1` executes inline on the caller's trainer
/// (the seed single-executor path, byte-for-byte, zero spawns);
/// `ranks >= 2` owns a [`RankPool`] of full trainer replicas created once.
pub struct TrainerPool {
    pool: Option<RankPool<TrainerWorker>>,
    /// One-time pool construction cost (engine replication + thread
    /// spawns), amortized across the run's steps
    /// ([`super::PipelineSummary`] reports the per-step share).
    pub spawn_ms: f64,
}

impl TrainerPool {
    /// Build the pool: replicate the primary trainer once per rank
    /// (`ranks >= 2`) or do nothing (`ranks == 1`).  Monolithic reduce.
    pub fn new(trainer: &AnyTrainer, ranks: usize) -> crate::Result<Self> {
        Self::new_with(trainer, ranks, ReduceOptions::default())
    }

    /// [`Self::new`] with an explicit reduction config.  Rank `r`'s replica
    /// compiles its programs for device ordinal `r`
    /// ([`crate::coordinator::AnyTrainer::replicate`] — wrapped onto the
    /// client's real device count, so a single-device host still builds).
    pub fn new_with(
        trainer: &AnyTrainer,
        ranks: usize,
        opts: ReduceOptions,
    ) -> crate::Result<Self> {
        anyhow::ensure!(ranks >= 1, "ranks must be >= 1");
        if ranks == 1 {
            return Ok(Self { pool: None, spawn_ms: 0.0 });
        }
        let t0 = Instant::now();
        let workers = (0..ranks)
            .map(|r| Ok(TrainerWorker { trainer: trainer.replicate(r)? }))
            .collect::<crate::Result<Vec<_>>>()?;
        let pool = RankPool::new_with(workers, opts)?;
        Ok(Self { pool: Some(pool), spawn_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// One sharded optimizer step: execute every rank plan (inline or on
    /// the persistent pool), log-tree-reduce the [`GradBuffer`]s, apply one
    /// Eq. 5-normalized update over the *global* (all-rank) weight sum on
    /// the primary engine, and broadcast the identical update to the
    /// replicas.
    pub fn execute_step(
        &mut self,
        trainer: &mut AnyTrainer,
        lr: f64,
        sharded: &Arc<ShardedPlan>,
    ) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        let reduced = match &mut self.pool {
            None => {
                anyhow::ensure!(
                    sharded.n_ranks() == 1,
                    "{}-rank plan on a single-rank pool (rank count is fixed per run)",
                    sharded.n_ranks()
                );
                let t_exec = Instant::now();
                let (acc, device_tokens) = run_rank(trainer, &sharded.ranks[0])?;
                RankReduce {
                    acc,
                    device_tokens,
                    rank_walls: vec![t_exec.elapsed().as_secs_f64() * 1e3],
                    reduce_ms: 0.0,
                    reduce_overlap_ms: 0.0,
                    reduce_depth: 0,
                    reduce_buckets: 0,
                    bucket_overlap_ms: 0.0,
                    collective_bytes: 0,
                }
            }
            Some(pool) => pool.execute(sharded)?,
        };
        // cost-model feedback: score the plan's predicted imbalance against
        // the measured per-rank walls, then feed the walls back as
        // regression rows (no-op under the default token model)
        let cost_model_err = sharded.cost_model_err(&reduced.rank_walls);
        sharded.observe_walls(&reduced.rank_walls);
        let loss = reduced.acc.mean_loss();
        let weight_sum = reduced.acc.weight_sum;
        let exec_calls = reduced.acc.exec_calls;
        // prefix-reuse accounting rides the reduced accumulator: each rank
        // (replica or the inline primary) drains its own engine counters
        // into its GradBuffer inside run_rank, and the typed reduce sums
        // them — so multi-rank runs report the live trio, summed across
        // ranks, not the primary engine's inert zeros (docs/prefix_reuse.md)
        let cache: CacheStats = reduced.acc.cache;
        let (grad_norm, step) = match trainer {
            AnyTrainer::Tree(t) => (t.engine.apply_update(&reduced.acc)?, t.engine.step_count()),
            AnyTrainer::Baseline(t) => {
                (t.engine.apply_update(&reduced.acc)?, t.engine.step_count())
            }
        };
        if let Some(pool) = &mut self.pool {
            // asynchronous: workers apply while the caller returns metrics
            // and the planner plans the next batch; per-worker job order
            // guarantees the next execute sees the updated parameters
            pool.apply(TrainerUpdate { lr, gb: reduced.acc })?;
        }
        Ok(StepMetrics {
            step,
            loss,
            weight_sum,
            device_tokens: reduced.device_tokens,
            tree_tokens: sharded.tree_tokens(),
            flat_tokens: sharded.flat_tokens(),
            wall: t0.elapsed(),
            exec_calls,
            forest_batches: sharded.device_batches() as u64,
            grad_norm,
            plan_ms: 0.0,
            stall_ms: 0.0,
            ranks: sharded.n_ranks() as u64,
            reduce_ms: reduced.reduce_ms,
            reduce_overlap_ms: reduced.reduce_overlap_ms,
            reduce_depth: reduced.reduce_depth as u64,
            rank_imbalance: sharded.rank_imbalance(),
            ingest_ms: 0.0,
            cost_model_err,
            staleness_steps: 0,
            ripe_queue_depth: 0,
            admitted_sessions: 0,
            xstep_reuse_ratio: reuse_ratio(sharded.tree_tokens() as u64, cache.hit_tokens),
            cache_hit_tokens: cache.hit_tokens,
            cache_evictions: cache.evictions,
            reduce_buckets: reduced.reduce_buckets,
            bucket_overlap_ms: reduced.bucket_overlap_ms,
            collective_bytes: reduced.collective_bytes,
        })
    }

    /// Join the pool, surfacing deferred apply errors.
    pub fn finish(self) -> crate::Result<()> {
        match self.pool {
            None => Ok(()),
            Some(p) => p.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::planner::PlanSpec;
    use crate::tree::gen;
    use crate::tree::TrajectoryTree;
    use std::time::Duration;

    fn sharded(n_trees: usize, n_ranks: usize) -> Arc<ShardedPlan> {
        let trees: Vec<TrajectoryTree> =
            (0..n_trees as u64).map(|s| gen::uniform(90 + s, 9, 5, 0.6)).collect();
        Arc::new(PlanSpec::for_host(4096).plan_sharded_tree(&trees, n_ranks).unwrap())
    }

    // ── pairing schedule (validated against the python mirror:
    //    python/tests/test_reduce_schedule.py) ──

    #[test]
    fn schedule_brackets_match_python_mirror() {
        assert_eq!(reduce_schedule(1), Vec::<Vec<(usize, usize)>>::new());
        assert_eq!(reduce_schedule(2), vec![vec![(0, 1)]]);
        assert_eq!(reduce_schedule(3), vec![vec![(0, 1)], vec![(0, 2)]]);
        assert_eq!(
            reduce_schedule(5),
            vec![vec![(0, 1), (2, 3)], vec![(0, 2)], vec![(0, 4)]]
        );
        assert_eq!(
            reduce_schedule(8),
            vec![
                vec![(0, 1), (2, 3), (4, 5), (6, 7)],
                vec![(0, 2), (4, 6)],
                vec![(0, 4)]
            ]
        );
    }

    #[test]
    fn depth_is_ceil_log2() {
        for (n, d) in [(1, 0u32), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4), (16, 4)] {
            assert_eq!(reduce_depth(n), d, "depth({n})");
            assert_eq!(reduce_schedule(n).len(), d as usize, "rounds({n})");
        }
    }

    #[test]
    fn odd_rank_byes_advance_to_the_right_round() {
        // n = 5: rank 4 has no partner in rounds 0/1 and is absorbed by
        // rank 0 only in the final round
        let sched = reduce_schedule(5);
        assert!(!sched[0].iter().any(|&(a, b)| a == 4 || b == 4));
        assert!(!sched[1].iter().any(|&(a, b)| a == 4 || b == 4));
        assert_eq!(sched[2], vec![(0, 4)]);
    }

    #[test]
    fn schedule_is_consistent_with_per_rank_views() {
        for n in 1..=17usize {
            let sched = reduce_schedule(n);
            // every rank > 0 is merged exactly once, as src, into its parent
            let mut srcs: Vec<usize> = sched.iter().flatten().map(|&(_, s)| s).collect();
            srcs.sort_unstable();
            assert_eq!(srcs, (1..n).collect::<Vec<_>>(), "n={n}");
            for r in 1..n {
                let round = r.trailing_zeros() as usize;
                let p = reduce_parent(r).unwrap();
                assert_eq!(p, r & (r - 1));
                assert!(sched[round].contains(&(p, r)), "n={n} r={r}");
            }
            // the union of child views is the schedule
            let mut from_children: Vec<Vec<(usize, usize)>> = vec![Vec::new(); sched.len()];
            for r in 0..n {
                for (d, src) in reduce_children(r, n) {
                    from_children[d as usize].push((r, src));
                }
            }
            for (a, b) in sched.iter().zip(&from_children) {
                let mut a = a.clone();
                let mut b = b.clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "n={n}");
            }
        }
    }

    // ── pool behavior ──

    /// Bracket-tracing worker: the reduced string is the exact merge
    /// association, regardless of worker finish order.
    struct TraceWorker;

    impl RankWorker for TraceWorker {
        type Acc = String;
        type Update = ();

        fn execute(&mut self, rank: usize, _plan: &StepPlan) -> crate::Result<(String, usize)> {
            // higher ranks finish *first*: arrival order is reversed
            std::thread::sleep(Duration::from_millis(4 * (8u64.saturating_sub(rank as u64))));
            Ok((rank.to_string(), 1))
        }

        fn reduce(acc: &mut String, other: String) {
            *acc = format!("({acc}+{other})");
        }

        fn apply(&mut self, _u: &()) -> crate::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn reduction_bracket_is_fixed_regardless_of_finish_order() {
        let plan = sharded(8, 4);
        let mut pool = RankPool::new(vec![TraceWorker, TraceWorker, TraceWorker, TraceWorker])
            .unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, "((0+1)+(2+3))");
        assert_eq!(r.device_tokens, 4);
        assert_eq!(r.reduce_depth, 2);
        assert_eq!(r.rank_walls.len(), 4, "one measured wall per rank");
        assert!(r.rank_walls.iter().all(|&w| w > 0.0), "walls: {:?}", r.rank_walls);
        // the trace workers sleep longest on rank 0: walls must reflect it
        assert!(r.rank_walls[0] > r.rank_walls[3], "walls: {:?}", r.rank_walls);
        // and again on the same (persistent) pool
        let r2 = pool.execute(&plan).unwrap();
        assert_eq!(r2.acc, "((0+1)+(2+3))");
        pool.finish().unwrap();
    }

    #[test]
    fn odd_rank_count_brackets_deterministically() {
        let plan = sharded(6, 5);
        let mut pool =
            RankPool::new((0..5).map(|_| TraceWorker).collect::<Vec<_>>()).unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, "(((0+1)+(2+3))+4)");
        assert_eq!(r.reduce_depth, 3);
        pool.finish().unwrap();
    }

    struct CountWorker {
        offset: f64,
    }

    impl RankWorker for CountWorker {
        type Acc = f64;
        type Update = f64;

        fn execute(&mut self, _rank: usize, _plan: &StepPlan) -> crate::Result<(f64, usize)> {
            Ok((self.offset, 7))
        }

        fn reduce(acc: &mut f64, other: f64) {
            *acc += other;
        }

        fn apply(&mut self, u: &f64) -> crate::Result<()> {
            self.offset += *u;
            Ok(())
        }
    }

    #[test]
    fn single_rank_runs_inline_with_zero_reduce() {
        // (the zero-spawn property is asserted via the thread_spawns probe
        // in tests/dist_equivalence.rs, where pool-creating tests are
        // serialized — the global counter is racy across parallel #[test]s)
        let plan = sharded(4, 1);
        let main_thread = std::thread::current().id();

        struct InlineProbe(std::thread::ThreadId);
        impl RankWorker for InlineProbe {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, _p: &StepPlan) -> crate::Result<(usize, usize)> {
                assert_eq!(std::thread::current().id(), self.0, "must run inline");
                Ok((1, 7))
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }

        let mut pool = RankPool::new(vec![InlineProbe(main_thread)]).unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, 1);
        assert_eq!(r.device_tokens, 7);
        assert_eq!(r.rank_walls.len(), 1);
        assert_eq!(r.reduce_ms, 0.0);
        assert_eq!(r.reduce_overlap_ms, 0.0);
        assert_eq!(r.reduce_depth, 0);
        pool.finish().unwrap();
    }

    #[test]
    fn pool_applies_updates_between_steps() {
        let plan = sharded(8, 4);
        let mut pool =
            RankPool::new((0..4).map(|_| CountWorker { offset: 1.0 }).collect::<Vec<_>>())
                .unwrap();
        assert_eq!(pool.execute(&plan).unwrap().acc, 4.0);
        pool.apply(0.5).unwrap();
        // job order per worker guarantees the apply lands before this
        assert_eq!(pool.execute(&plan).unwrap().acc, 6.0);
        pool.finish().unwrap();
    }

    struct FailWorker {
        fail: bool,
        fail_apply: bool,
    }

    impl RankWorker for FailWorker {
        type Acc = usize;
        type Update = ();

        fn execute(&mut self, rank: usize, _plan: &StepPlan) -> crate::Result<(usize, usize)> {
            if self.fail {
                anyhow::bail!("rank {rank} exploded")
            }
            Ok((1, 0))
        }

        fn reduce(acc: &mut usize, other: usize) {
            *acc += other;
        }

        fn apply(&mut self, _u: &()) -> crate::Result<()> {
            if self.fail_apply {
                anyhow::bail!("apply failed")
            }
            Ok(())
        }
    }

    #[test]
    fn rank_error_propagates_through_the_reduce_tree() {
        let plan = sharded(6, 3);
        let workers = (0..3)
            .map(|r| FailWorker { fail: r == 1, fail_apply: false })
            .collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("rank 1 exploded"), "got: {err}");
    }

    #[test]
    fn deferred_apply_error_surfaces_at_next_execute() {
        let plan = sharded(4, 2);
        let workers = (0..2)
            .map(|r| FailWorker { fail: false, fail_apply: r == 1 })
            .collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        pool.execute(&plan).unwrap();
        pool.apply(()).unwrap(); // async: error is deferred
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("apply failed"), "got: {err}");
    }

    #[test]
    fn deferred_apply_error_surfaces_at_finish() {
        let plan = sharded(4, 2);
        let workers = (0..2)
            .map(|r| FailWorker { fail: false, fail_apply: r == 0 })
            .collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        pool.execute(&plan).unwrap();
        pool.apply(()).unwrap();
        let err = pool.finish().unwrap_err();
        assert!(err.to_string().contains("apply failed"), "got: {err}");
    }

    #[test]
    fn empty_rank_plans_are_benign() {
        // more ranks than trees: empty rank plans execute as no-ops
        struct ForestCounter;
        impl RankWorker for ForestCounter {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, p: &StepPlan) -> crate::Result<(usize, usize)> {
                let StepPlan::Tree(g) = p else { panic!("tree mode") };
                Ok((g.forests.len(), g.forests.iter().map(|f| f.batch.capacity).sum()))
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }
        let plan = sharded(2, 4);
        let mut pool = RankPool::new((0..4).map(|_| ForestCounter).collect::<Vec<_>>()).unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, 2, "both trees execute exactly once");
        pool.finish().unwrap();
    }

    #[test]
    fn rank_count_mismatch_is_an_error() {
        let mut pool =
            RankPool::new((0..3).map(|_| CountWorker { offset: 0.0 }).collect::<Vec<_>>())
                .unwrap();
        let err = pool.execute(&sharded(6, 4)).unwrap_err();
        assert!(err.to_string().contains("fixed per run"), "got: {err}");
    }

    #[test]
    fn mode_mismatch_is_an_error_not_a_panic() {
        // a baseline plan handed to a tree-mode worker must surface as an
        // error through the pool, not poison it
        use crate::trainer::planner::BaselinePlan;
        struct TreeOnly;
        impl RankWorker for TreeOnly {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, p: &StepPlan) -> crate::Result<(usize, usize)> {
                match p {
                    StepPlan::Tree(_) => Ok((0, 0)),
                    StepPlan::Baseline(_) => anyhow::bail!("plan/trainer mode mismatch"),
                }
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }
        let plan = Arc::new(ShardedPlan {
            ranks: vec![StepPlan::Baseline(BaselinePlan {
                batches: vec![],
                tree_tokens: 0,
                flat_tokens: 0,
            })],
            loads: vec![0],
            rank_feats: vec![[0.0; 4]],
            cost: crate::partition::CostModel::Tokens,
        });
        let mut pool = RankPool::new(vec![TreeOnly]).unwrap();
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("mode mismatch"), "got: {err}");
    }

    #[test]
    fn worker_panic_is_an_error_not_a_deadlock() {
        struct PanicWorker {
            boom: bool,
        }
        impl RankWorker for PanicWorker {
            type Acc = usize;
            type Update = ();
            fn execute(&mut self, _r: usize, _p: &StepPlan) -> crate::Result<(usize, usize)> {
                if self.boom {
                    panic!("worker panic")
                }
                Ok((1, 0))
            }
            fn reduce(acc: &mut usize, other: usize) {
                *acc += other;
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
        }
        let plan = sharded(8, 4);
        let workers = (0..4).map(|r| PanicWorker { boom: r == 2 }).collect::<Vec<_>>();
        let mut pool = RankPool::new(workers).unwrap();
        let err = pool.execute(&plan).unwrap_err();
        assert!(err.to_string().contains("panicked"), "got: {err}");
    }

    // ── bucketed collective data plane ──

    #[derive(Clone)]
    struct PayAcc {
        payload: Vec<f64>,
        scalar: f64,
    }

    /// Payload-capable worker: exercises the bucketed data plane end to
    /// end.  Accumulates its payload in `plan_units` pieces (so any
    /// premature child fold — before the local accumulation is final —
    /// would change bits), with values chosen to make f64 association
    /// visible: `execute` and `execute_hooked` are the same math.
    struct PayWorker {
        len: usize,
        /// Fail the first execute (then succeed), exercising abort frames
        /// and next-step recovery.
        fail_first: bool,
        executes: u64,
    }

    impl PayWorker {
        fn fleet(n: usize, len: usize) -> Vec<PayWorker> {
            (0..n).map(|_| PayWorker { len, fail_first: false, executes: 0 }).collect()
        }
    }

    impl RankWorker for PayWorker {
        type Acc = PayAcc;
        type Update = ();

        fn execute(&mut self, rank: usize, plan: &StepPlan) -> crate::Result<(PayAcc, usize)> {
            self.execute_hooked(rank, plan, &mut |_, _| {})
        }

        fn reduce(acc: &mut PayAcc, other: PayAcc) {
            for (a, b) in acc.payload.iter_mut().zip(&other.payload) {
                *a += b;
            }
            acc.scalar += other.scalar;
        }

        fn apply(&mut self, _u: &()) -> crate::Result<()> {
            Ok(())
        }

        fn flat_grad_len(&self) -> Option<usize> {
            Some(self.len)
        }

        fn read_payload(acc: &PayAcc, range: Range<usize>, out: &mut Vec<f64>) {
            out.clear();
            out.extend_from_slice(&acc.payload[range]);
        }

        fn fold_payload(acc: &mut PayAcc, range: Range<usize>, data: &[f64]) {
            for (a, b) in acc.payload[range].iter_mut().zip(data) {
                *a += b;
            }
        }

        fn strip_payload(acc: &mut PayAcc) {
            acc.payload = Vec::new();
        }

        fn reduce_stripped(acc: &mut PayAcc, other: PayAcc) {
            acc.scalar += other.scalar;
        }

        fn execute_hooked(
            &mut self,
            rank: usize,
            plan: &StepPlan,
            on_unit: &mut dyn FnMut(&mut PayAcc, usize),
        ) -> crate::Result<(PayAcc, usize)> {
            self.executes += 1;
            if self.fail_first && self.executes == 1 {
                anyhow::bail!("rank {rank} exploded");
            }
            let units = plan_units(plan).max(1);
            let mut acc =
                PayAcc { payload: vec![0.0; self.len], scalar: (rank + 1) as f64 };
            for u in 0..units {
                for (i, v) in acc.payload.iter_mut().enumerate() {
                    // values with non-trivial low bits, accumulated in
                    // `units` partial pieces
                    *v += ((rank + 1) as f64 / 3.0) * (i as f64 + 0.1) / units as f64;
                }
                on_unit(&mut acc, u);
            }
            Ok((acc, 1))
        }
    }

    fn pay_reduce(
        n: usize,
        len: usize,
        opts: ReduceOptions,
        plan: &Arc<ShardedPlan>,
    ) -> RankReduce<PayAcc> {
        let mut pool = RankPool::new_with(PayWorker::fleet(n, len), opts).unwrap();
        let r = pool.execute(plan).unwrap();
        pool.finish().unwrap();
        r
    }

    #[test]
    fn bucketed_and_socket_reduce_bit_match_the_monolithic_path() {
        const LEN: usize = 700; // 1 KiB buckets = 128 elems -> 6 buckets
        for n in [2usize, 3, 5] {
            let plan = sharded(2 * n, n);
            let legacy = pay_reduce(n, LEN, ReduceOptions::default(), &plan);
            assert_eq!(legacy.reduce_buckets, 0, "no collective on the default path");
            assert_eq!(legacy.collective_bytes, 0);
            for (kb, transport) in [
                (1usize, Transport::InProcess),
                (0, Transport::Socket),
                (1, Transport::Socket),
            ] {
                let opts =
                    ReduceOptions { bucket_kb: kb, transport, ..Default::default() };
                let r = pay_reduce(n, LEN, opts, &plan);
                let a: Vec<u64> = legacy.acc.payload.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u64> = r.acc.payload.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "n={n} kb={kb} {transport:?}: payload bits");
                assert_eq!(
                    legacy.acc.scalar.to_bits(),
                    r.acc.scalar.to_bits(),
                    "n={n} kb={kb} {transport:?}: control-plane scalar bits"
                );
                let want_buckets = bucket_ranges(LEN, kb).len() as u64;
                assert_eq!(r.reduce_buckets, want_buckets, "n={n} kb={kb}");
                assert!(r.collective_bytes > 0, "n={n} kb={kb}: frames moved");
                assert_eq!(r.device_tokens, legacy.device_tokens);
            }
        }
    }

    #[test]
    fn bucketed_reduce_survives_a_failed_step_and_recovers_bit_exact() {
        const LEN: usize = 300;
        let n = 4;
        let plan = sharded(8, n);
        let legacy = {
            // legacy pool, second step (PayWorker math is step-invariant)
            let mut pool = RankPool::new(PayWorker::fleet(n, LEN)).unwrap();
            pool.execute(&plan).unwrap();
            let r = pool.execute(&plan).unwrap();
            pool.finish().unwrap();
            r
        };
        for transport in [Transport::InProcess, Transport::Socket] {
            let mut workers = PayWorker::fleet(n, LEN);
            workers[1].fail_first = true;
            let opts = ReduceOptions { bucket_kb: 1, transport, ..Default::default() };
            let mut pool = RankPool::new_with(workers, opts).unwrap();
            let err = pool.execute(&plan).unwrap_err();
            assert!(err.to_string().contains("rank 1 exploded"), "got: {err}");
            // abort frames kept the frame invariant: the next step must
            // succeed and still bit-match the monolithic fold
            let r = pool.execute(&plan).unwrap();
            let a: Vec<u64> = legacy.acc.payload.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = r.acc.payload.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{transport:?}: post-failure step payload bits");
            pool.finish().unwrap();
        }
    }

    #[test]
    fn bucketed_cancellation_fixture_matches_monolithic_bits() {
        // PR 5's worst-case fixture: [1.0, 1e16, -1e16, 1.0] across 4
        // ranks — the bracket ((1+1e16)+((-1e16)+1)) = 0.0 while a serial
        // fold gives 1.0, so any fold-order slip shows up in the bits
        struct FixWorker {
            val: f64,
        }
        impl RankWorker for FixWorker {
            type Acc = PayAcc;
            type Update = ();
            fn execute(&mut self, _r: usize, _p: &StepPlan) -> crate::Result<(PayAcc, usize)> {
                Ok((PayAcc { payload: vec![self.val; 4], scalar: self.val }, 1))
            }
            fn reduce(acc: &mut PayAcc, other: PayAcc) {
                PayWorker::reduce(acc, other);
            }
            fn apply(&mut self, _u: &()) -> crate::Result<()> {
                Ok(())
            }
            fn flat_grad_len(&self) -> Option<usize> {
                Some(4)
            }
            fn read_payload(acc: &PayAcc, range: Range<usize>, out: &mut Vec<f64>) {
                PayWorker::read_payload(acc, range, out);
            }
            fn fold_payload(acc: &mut PayAcc, range: Range<usize>, data: &[f64]) {
                PayWorker::fold_payload(acc, range, data);
            }
            fn strip_payload(acc: &mut PayAcc) {
                PayWorker::strip_payload(acc);
            }
            fn reduce_stripped(acc: &mut PayAcc, other: PayAcc) {
                PayWorker::reduce_stripped(acc, other);
            }
        }
        let vals = [1.0f64, 1e16, -1e16, 1.0];
        let plan = sharded(8, 4);
        let fleet = || vals.iter().map(|&v| FixWorker { val: v }).collect::<Vec<_>>();
        let mut legacy_pool = RankPool::new(fleet()).unwrap();
        let legacy = legacy_pool.execute(&plan).unwrap();
        legacy_pool.finish().unwrap();
        assert_eq!(legacy.acc.payload, vec![0.0; 4], "bracket association");
        for transport in [Transport::InProcess, Transport::Socket] {
            let opts = ReduceOptions { bucket_kb: 1, transport, ..Default::default() };
            let mut pool = RankPool::new_with(fleet(), opts).unwrap();
            let r = pool.execute(&plan).unwrap();
            assert_eq!(r.acc.payload, vec![0.0; 4], "{transport:?}");
            assert_eq!(r.acc.scalar, 0.0, "{transport:?} scalar via typed plane");
            pool.finish().unwrap();
        }
    }

    #[test]
    fn workers_without_payload_ignore_the_collective_config() {
        // a configured collective must not disturb workers that don't
        // expose a flat payload (flat_grad_len = None): typed path as-is
        let plan = sharded(8, 4);
        let opts = ReduceOptions {
            bucket_kb: 64,
            transport: Transport::InProcess,
            ..Default::default()
        };
        let mut pool = RankPool::new_with(
            vec![TraceWorker, TraceWorker, TraceWorker, TraceWorker],
            opts,
        )
        .unwrap();
        let r = pool.execute(&plan).unwrap();
        assert_eq!(r.acc, "((0+1)+(2+3))");
        assert_eq!(r.reduce_buckets, 0);
        assert_eq!(r.collective_bytes, 0);
        pool.finish().unwrap();
    }
}
