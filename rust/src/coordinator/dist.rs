//! Rank-sharded execution with deterministic gradient reduction.
//!
//! The paper's testbed (§3.4) is data-parallel: each rank executes a
//! disjoint set of whole trees and the gradients are all-reduced before one
//! optimizer step.  This module is that layer for the single-host
//! reproduction: a [`ShardedPlan`] (one [`StepPlan`] per rank, trees
//! LPT-sharded whole by packed token cost) is executed by **one worker
//! thread per rank**, each accumulating into its private buffer, and the
//! rank buffers are reduced **in fixed rank order** into a single f64
//! accumulation before `apply_update`.
//!
//! **Determinism contract** (docs/distributed.md):
//!
//! * `ranks == 1` executes inline on the caller thread — no worker, no
//!   reduction — so it *is* the seed single-executor pipeline, bit-for-bit.
//! * `ranks == N` is bit-identical run-to-run: each rank's accumulation
//!   order is fixed by its plan, and the cross-rank reduction happens on
//!   the caller thread in rank order `0, 1, .., N-1` after every worker
//!   has joined — thread scheduling can change wall-clock, never bits.
//! * `ranks == N` vs `ranks == 1` agree to f64 tolerance, not bitwise:
//!   the same per-call gradients are summed in a different association
//!   (per-rank subtotals first).  Verified by `tests/pipeline_equivalence`
//!   and the CI `dist-smoke` job.
//!
//! [`execute_ranks`] is generic over the accumulator so the very same
//! pool + fixed-order reduce drives the XLA trainers ([`GradBuffer`]
//! buffers) and the hermetic [`super::pipeline::HostExecutor`] (RefModel
//! embedding gradients) — the determinism property is tested on the exact
//! code the real trainers run.
//!
//! **Thread-safety precondition.**  Rank workers share one engine by
//! `&`-reference, so `ranks > 1` requires the trainer (hence `Engine`,
//! hence the `xla` crate's client/executable handles) to be `Sync`.  The
//! vendored host-only `xla` crate is plain data, so this holds today and
//! `scope.spawn` *enforces* it at compile time: swapping in the real
//! PJRT-backed `xla` crate (whose handles wrap raw pointers) will fail to
//! compile here rather than race — the required fix is per-rank `Engine`
//! replicas (own parameter literals + device handles), tracked as a
//! ROADMAP open item.  Do not paper over that error with an unsafe `Sync`
//! impl: concurrent `run_literals` on one PJRT executable is a data race.

use std::time::Instant;

use crate::trainer::planner::{ShardedPlan, StepPlan};
use crate::trainer::{GradBuffer, StepMetrics};

use super::AnyTrainer;

/// Result of executing one sharded step's rank plans.
pub struct RankReduce<B> {
    /// The rank-order reduction of every rank's accumulator.
    pub acc: B,
    /// Device tokens dispatched across all ranks.
    pub device_tokens: usize,
    /// Wall time of the fixed-order reduction (0 for a single rank).
    pub reduce_ms: f64,
}

/// Execute each rank's plan and reduce the per-rank accumulators in fixed
/// rank order.  `run(rank, plan, acc)` must only touch its own `acc` (it
/// runs on the rank's worker thread); `reduce(lhs, rhs)` folds rank `r+1`'s
/// accumulator into the running reduction of ranks `0..=r`.
///
/// A single-rank plan short-circuits to an inline call — the seed
/// single-executor path, byte-for-byte.
pub fn execute_ranks<B, M, F, R>(
    sharded: &ShardedPlan,
    make: M,
    run: F,
    reduce: R,
) -> crate::Result<RankReduce<B>>
where
    B: Send,
    M: Fn() -> B + Sync,
    F: Fn(usize, &StepPlan, &mut B) -> crate::Result<usize> + Sync,
    R: Fn(&mut B, B),
{
    anyhow::ensure!(sharded.n_ranks() >= 1, "sharded plan has no ranks");
    if sharded.n_ranks() == 1 {
        let mut acc = make();
        let device_tokens = run(0, &sharded.ranks[0], &mut acc)?;
        return Ok(RankReduce { acc, device_tokens, reduce_ms: 0.0 });
    }
    let outcomes: Vec<crate::Result<(B, usize)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = sharded
            .ranks
            .iter()
            .enumerate()
            .map(|(rank, plan)| {
                let (run, make) = (&run, &make);
                scope.spawn(move || -> crate::Result<(B, usize)> {
                    let mut acc = make();
                    let tokens = run(rank, plan, &mut acc)?;
                    Ok((acc, tokens))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("rank executor thread panicked")),
            })
            .collect()
    });
    let mut acc: Option<B> = None;
    let mut device_tokens = 0usize;
    let mut reduce_ms = 0.0f64;
    for outcome in outcomes {
        let (rank_acc, tokens) = outcome?;
        device_tokens += tokens;
        match &mut acc {
            None => acc = Some(rank_acc),
            Some(a) => {
                let t0 = Instant::now();
                reduce(a, rank_acc);
                reduce_ms += t0.elapsed().as_secs_f64() * 1e3;
            }
        }
    }
    Ok(RankReduce { acc: acc.expect("n_ranks >= 2"), device_tokens, reduce_ms })
}

/// One sharded optimizer step for either trainer: execute every rank plan
/// on the worker pool, reduce the [`GradBuffer`]s in rank order, apply one
/// Eq. 5-normalized update over the *global* (all-rank) weight sum.
pub fn execute_sharded(
    trainer: &mut AnyTrainer,
    sharded: &ShardedPlan,
) -> crate::Result<StepMetrics> {
    let t0 = Instant::now();
    let (reduced, grad_norm, step) = match trainer {
        AnyTrainer::Tree(t) => {
            let reduced = execute_ranks(
                sharded,
                || t.engine.grad_buffer(),
                |_rank, plan, gb| match plan {
                    StepPlan::Tree(p) => t.run_plan(p, gb),
                    StepPlan::Baseline(_) => {
                        anyhow::bail!("baseline rank plan handed to TreeTrainer (pipeline bug)")
                    }
                },
                GradBuffer::merge_owned,
            )?;
            let grad_norm = t.engine.apply_update(&reduced.acc)?;
            (reduced, grad_norm, t.engine.step_count())
        }
        AnyTrainer::Baseline(t) => {
            let reduced = execute_ranks(
                sharded,
                || t.engine.grad_buffer(),
                |_rank, plan, gb| match plan {
                    StepPlan::Baseline(p) => t.run_plan(p, gb),
                    StepPlan::Tree(_) => {
                        anyhow::bail!("tree rank plan handed to BaselineTrainer (pipeline bug)")
                    }
                },
                GradBuffer::merge_owned,
            )?;
            let grad_norm = t.engine.apply_update(&reduced.acc)?;
            (reduced, grad_norm, t.engine.step_count())
        }
    };
    Ok(StepMetrics {
        step,
        loss: reduced.acc.mean_loss(),
        weight_sum: reduced.acc.weight_sum,
        device_tokens: reduced.device_tokens,
        tree_tokens: sharded.tree_tokens(),
        flat_tokens: sharded.flat_tokens(),
        wall: t0.elapsed(),
        exec_calls: reduced.acc.exec_calls,
        forest_batches: sharded.device_batches() as u64,
        grad_norm,
        plan_ms: 0.0,
        stall_ms: 0.0,
        ranks: sharded.n_ranks() as u64,
        reduce_ms: reduced.reduce_ms,
        rank_imbalance: sharded.rank_imbalance(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::planner::{BaselinePlan, PlanSpec};
    use crate::tree::gen;
    use crate::tree::TrajectoryTree;

    fn sharded(n_trees: usize, n_ranks: usize) -> ShardedPlan {
        let trees: Vec<TrajectoryTree> =
            (0..n_trees as u64).map(|s| gen::uniform(90 + s, 9, 5, 0.6)).collect();
        PlanSpec::for_host(4096).plan_sharded_tree(&trees, n_ranks).unwrap()
    }

    #[test]
    fn reduction_order_is_rank_order_regardless_of_finish_order() {
        // rank r sleeps inversely to its id, so worker *finish* order is
        // reversed — the reduced trace must still be rank order
        let plan = sharded(8, 4);
        let reduced = execute_ranks(
            &plan,
            Vec::new,
            |rank, _plan, acc: &mut Vec<usize>| {
                std::thread::sleep(std::time::Duration::from_millis(5 * (4 - rank as u64)));
                acc.push(rank);
                Ok(1)
            },
            |a, b| a.extend(b),
        )
        .unwrap();
        assert_eq!(reduced.acc, vec![0, 1, 2, 3]);
        assert_eq!(reduced.device_tokens, 4);
    }

    #[test]
    fn single_rank_runs_inline_with_zero_reduce() {
        let plan = sharded(4, 1);
        let main_thread = std::thread::current().id();
        let reduced = execute_ranks(
            &plan,
            || 0usize,
            |_r, _p, acc| {
                assert_eq!(std::thread::current().id(), main_thread, "must run inline");
                *acc += 1;
                Ok(7)
            },
            |a, b| *a += b,
        )
        .unwrap();
        assert_eq!(reduced.acc, 1);
        assert_eq!(reduced.device_tokens, 7);
        assert_eq!(reduced.reduce_ms, 0.0);
    }

    #[test]
    fn rank_error_propagates() {
        let plan = sharded(6, 3);
        let err = execute_ranks(
            &plan,
            || (),
            |rank, _p, _a| {
                if rank == 1 {
                    anyhow::bail!("rank 1 exploded")
                }
                Ok(0)
            },
            |_a, _b| {},
        )
        .unwrap_err();
        assert!(err.to_string().contains("rank 1 exploded"));
    }

    #[test]
    fn empty_rank_plans_are_benign() {
        // more ranks than trees: empty rank plans execute as no-ops
        let plan = sharded(2, 4);
        let reduced = execute_ranks(
            &plan,
            || 0usize,
            |_r, p, acc| {
                let StepPlan::Tree(g) = p else { panic!() };
                *acc += g.forests.len();
                Ok(g.forests.iter().map(|f| f.batch.capacity).sum())
            },
            |a, b| *a += b,
        )
        .unwrap();
        assert_eq!(reduced.acc, 2, "both trees execute exactly once");
    }

    #[test]
    fn mode_mismatch_is_an_error_not_a_panic() {
        // a baseline plan handed to a tree trainer must surface as an error
        let plan = ShardedPlan {
            ranks: vec![StepPlan::Baseline(BaselinePlan {
                batches: vec![],
                tree_tokens: 0,
                flat_tokens: 0,
            })],
            loads: vec![0],
        };
        let r = execute_ranks(
            &plan,
            || (),
            |_r, p, _a| match p {
                StepPlan::Tree(_) => Ok(0),
                StepPlan::Baseline(_) => anyhow::bail!("plan/trainer mode mismatch"),
            },
            |_a, _b| {},
        );
        assert!(r.unwrap_err().to_string().contains("mode mismatch"));
    }
}
