//! Tree (de)serialization: JSON on disk, one tree per file or JSONL corpora.

use std::io::{BufRead, Write};
use std::path::Path;

use super::node::TrajectoryTree;
use crate::util::json::Json;

pub fn save_json(tree: &TrajectoryTree, path: &Path) -> crate::Result<()> {
    std::fs::write(path, tree.to_json().to_string())?;
    Ok(())
}

pub fn load_json(path: &Path) -> crate::Result<TrajectoryTree> {
    let data = std::fs::read_to_string(path)?;
    TrajectoryTree::from_json(&Json::parse(&data)?)
}

/// JSONL corpus: one tree per line (the global-batch unit of §3.4 — shuffle
/// happens between trees, never inside one).
pub fn save_corpus(trees: &[TrajectoryTree], path: &Path) -> crate::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    for t in trees {
        writeln!(w, "{}", t.to_json().to_string())?;
    }
    Ok(())
}

pub fn load_corpus(path: &Path) -> crate::Result<Vec<TrajectoryTree>> {
    let f = std::fs::File::open(path)?;
    let mut out = Vec::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(TrajectoryTree::from_json(&Json::parse(&line)?)?);
    }
    Ok(out)
}

#[cfg(test)]
pub fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tree-train-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;

    #[test]
    fn roundtrip() {
        let dir = temp_dir("roundtrip");
        let t = gen::uniform(7, 10, 5, 0.5);
        let p = dir.join("tree.json");
        save_json(&t, &p).unwrap();
        assert_eq!(load_json(&p).unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corpus_roundtrip() {
        let dir = temp_dir("corpus");
        let trees: Vec<_> = (0..5).map(|s| gen::uniform(s, 8, 5, 0.5)).collect();
        let p = dir.join("corpus.jsonl");
        save_corpus(&trees, &p).unwrap();
        assert_eq!(load_corpus(&p).unwrap(), trees);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn supervision_preserved() {
        let dir = temp_dir("sup");
        let t = TrajectoryTree::new(vec![crate::NodeSpec::new(-1, vec![1, 2])
            .with_trainable(vec![0.0, 1.0])
            .with_advantage(vec![-1.0, 2.0])])
        .unwrap();
        let p = dir.join("t.json");
        save_json(&t, &p).unwrap();
        assert_eq!(load_json(&p).unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }
}
