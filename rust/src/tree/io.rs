//! Tree (de)serialization: JSON on disk, one tree per file or JSONL corpora.

use std::io::Write;
use std::path::Path;

use super::node::TrajectoryTree;
use crate::util::json::Json;

pub fn save_json(tree: &TrajectoryTree, path: &Path) -> crate::Result<()> {
    std::fs::write(path, tree.to_json().to_string())?;
    Ok(())
}

pub fn load_json(path: &Path) -> crate::Result<TrajectoryTree> {
    let data = std::fs::read_to_string(path)?;
    TrajectoryTree::from_json(&Json::parse(&data)?)
}

/// JSONL corpus: one tree per line (the global-batch unit of §3.4 — shuffle
/// happens between trees, never inside one).
pub fn save_corpus(trees: &[TrajectoryTree], path: &Path) -> crate::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    for t in trees {
        writeln!(w, "{}", t.to_json().to_string())?;
    }
    Ok(())
}

/// Streaming corpus reader: one tree per `next()` call, so million-tree
/// corpora never sit fully in RAM.  Parse errors carry `path:line`
/// (shared [`crate::util::jsonl::JsonlReader`] machinery).
pub struct CorpusIter {
    inner: crate::util::jsonl::JsonlReader<std::io::BufReader<std::fs::File>>,
}

impl Iterator for CorpusIter {
    type Item = crate::Result<TrajectoryTree>;

    fn next(&mut self) -> Option<Self::Item> {
        self.inner.next_record(TrajectoryTree::from_json)
    }
}

/// Open a JSONL corpus as a line-by-line iterator (bounded memory).
pub fn load_corpus_iter(path: &Path) -> crate::Result<CorpusIter> {
    Ok(CorpusIter { inner: crate::util::jsonl::JsonlReader::open(path)? })
}

pub fn load_corpus(path: &Path) -> crate::Result<Vec<TrajectoryTree>> {
    load_corpus_iter(path)?.collect()
}

/// Fresh per-process scratch directory (test support — shared by the
/// in-crate unit tests and the integration suites, which cannot see
/// `#[cfg(test)]` items).
pub fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tree-train-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;

    #[test]
    fn roundtrip() {
        let dir = temp_dir("roundtrip");
        let t = gen::uniform(7, 10, 5, 0.5);
        let p = dir.join("tree.json");
        save_json(&t, &p).unwrap();
        assert_eq!(load_json(&p).unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corpus_roundtrip() {
        let dir = temp_dir("corpus");
        let trees: Vec<_> = (0..5).map(|s| gen::uniform(s, 8, 5, 0.5)).collect();
        let p = dir.join("corpus.jsonl");
        save_corpus(&trees, &p).unwrap();
        assert_eq!(load_corpus(&p).unwrap(), trees);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corpus_iter_streams_and_matches_eager_load() {
        let dir = temp_dir("iter");
        let trees: Vec<_> = (0..4).map(|s| gen::uniform(100 + s, 8, 5, 0.5)).collect();
        let p = dir.join("corpus.jsonl");
        save_corpus(&trees, &p).unwrap();
        let streamed: Vec<_> =
            load_corpus_iter(&p).unwrap().collect::<crate::Result<Vec<_>>>().unwrap();
        assert_eq!(streamed, trees);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn parse_error_reports_line_number() {
        let dir = temp_dir("badline");
        let p = dir.join("corpus.jsonl");
        let good = gen::uniform(0, 6, 4, 0.5).to_json().to_string();
        std::fs::write(&p, format!("{good}\n\n{good}\nnot json at all\n")).unwrap();
        let err = load_corpus(&p).unwrap_err().to_string();
        assert!(err.contains(":4:"), "error should name line 4, got: {err}");
        // structurally-invalid tree on a valid-JSON line also carries the line
        std::fs::write(&p, format!("{good}\n{{\"nodes\":[]}}\n")).unwrap();
        let err = load_corpus(&p).unwrap_err().to_string();
        assert!(err.contains(":2:"), "error should name line 2, got: {err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn supervision_preserved() {
        let dir = temp_dir("sup");
        let t = TrajectoryTree::new(vec![crate::NodeSpec::new(-1, vec![1, 2])
            .with_trainable(vec![0.0, 1.0])
            .with_advantage(vec![-1.0, 2.0])])
        .unwrap();
        let p = dir.join("t.json");
        save_json(&t, &p).unwrap();
        assert_eq!(load_json(&p).unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }
}
