//! Tree overlap metrics: POR (Eq. 12) and the Fig. 6 depth profiles.

use super::node::TrajectoryTree;

/// Potential Overlap Ratio (Eq. 12): `1 - N_tree / N_flat` on real tokens.
///
/// The theoretical end-to-end speedup upper bound is `1 / (1 - POR)` (§4.1).
pub fn por(tree: &TrajectoryTree) -> f64 {
    let n_tree = tree.n_tree() as f64;
    let n_flat = tree.n_flat() as f64;
    if n_flat == 0.0 {
        return 0.0;
    }
    1.0 - n_tree / n_flat
}

/// Theoretical speedup upper bound `1/(1-POR)` (§4.1).
pub fn speedup_bound(tree: &TrajectoryTree) -> f64 {
    1.0 / (1.0 - por(tree))
}

/// POR of a *set* of trees (token-weighted, as in the paper's datasets).
pub fn dataset_por(trees: &[TrajectoryTree]) -> f64 {
    let n_tree: usize = trees.iter().map(|t| t.n_tree()).sum();
    let n_flat: usize = trees.iter().map(|t| t.n_flat()).sum();
    if n_flat == 0 {
        return 0.0;
    }
    1.0 - n_tree as f64 / n_flat as f64
}

/// Fig. 6 lower row: active trajectory count at every path depth.
///
/// `profile[d]` = number of root-to-leaf paths whose length exceeds `d`;
/// the area under the curve equals `N_flat`, while the unique-token count at
/// depth `d` is the number of distinct nodes covering that depth (area ratio
/// = the theoretical token reuse ratio).
pub fn active_trajectory_profile(tree: &TrajectoryTree) -> Vec<u32> {
    let mut lens: Vec<usize> = tree
        .paths()
        .iter()
        .map(|p| p.iter().map(|&n| tree.nodes[n].real_len()).sum())
        .collect();
    lens.sort_unstable();
    let max = *lens.last().unwrap_or(&0);
    let mut profile = vec![0u32; max];
    for d in 0..max {
        profile[d] = lens.iter().filter(|&&l| l > d).count() as u32;
    }
    profile
}

/// Unique-token coverage per depth (the denominator curve of Fig. 6).
pub fn unique_token_profile(tree: &TrajectoryTree) -> Vec<u32> {
    let meta = super::dfs::serialize(tree);
    let mut max_depth = 0usize;
    for t in 0..meta.size() {
        if !meta.pad_mask[t] {
            max_depth = max_depth.max(meta.pos_ids[t] as usize + 1);
        }
    }
    let mut profile = vec![0u32; max_depth];
    for t in 0..meta.size() {
        if !meta.pad_mask[t] {
            profile[meta.pos_ids[t] as usize] += 1;
        }
    }
    profile
}

/// FLOP accounting for the Fig. 5 / Fig. 8 token-count comparisons.
#[derive(Debug, Clone, Copy)]
pub struct TokenAccounting {
    /// Unique tokens in the tree (what Tree Training computes).
    pub n_tree: usize,
    /// Flattened per-path tokens (what the sep-avg baseline computes).
    pub n_flat: usize,
    pub por: f64,
    pub speedup_bound: f64,
}

pub fn accounting(tree: &TrajectoryTree) -> TokenAccounting {
    TokenAccounting {
        n_tree: tree.n_tree(),
        n_flat: tree.n_flat(),
        por: por(tree),
        speedup_bound: speedup_bound(tree),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::NodeSpec;

    #[test]
    fn por_two_branch() {
        // root 52, children 15/16: tree 83, flat 135 (§4.1 scaled example)
        let t = TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![0; 52]),
            NodeSpec::new(0, vec![0; 15]),
            NodeSpec::new(0, vec![0; 16]),
        ])
        .unwrap();
        assert!((por(&t) - (1.0 - 83.0 / 135.0)).abs() < 1e-12);
        assert!((speedup_bound(&t) - 135.0 / 83.0).abs() < 1e-9);
    }

    #[test]
    fn chain_has_zero_por() {
        let t = TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![0; 10]),
            NodeSpec::new(0, vec![0; 5]),
        ])
        .unwrap();
        assert_eq!(por(&t), 0.0);
    }

    #[test]
    fn profile_area_is_n_flat() {
        let t = TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![0; 4]),
            NodeSpec::new(0, vec![0; 3]),
            NodeSpec::new(0, vec![0; 5]),
        ])
        .unwrap();
        let p = active_trajectory_profile(&t);
        assert_eq!(p.iter().map(|&x| x as usize).sum::<usize>(), t.n_flat());
        let u = unique_token_profile(&t);
        assert_eq!(u.iter().map(|&x| x as usize).sum::<usize>(), t.n_tree());
    }
}
