//! Branch linearization: trees -> independent root-to-leaf chains.
//!
//! The exact inverse of ingestion (`crate::ingest` folds linear rollouts
//! back into trees): [`linearize`] spells every root-to-leaf path of a
//! trajectory tree as a standalone chain tree, which is what an agentic
//! runtime logs — one record per executed branch, shared prefixes repeated.
//! This is the *single* linearization in the crate: the sep-avg baseline
//! (`trainer::baseline`), the `quality` longest-path experiment,
//! `gen-data --linearize` and the ingest round-trip tests all route through
//! it, so "flatten" means the same thing everywhere (`N_flat` accounting,
//! Eq. 1).

use super::node::{NodeSpec, TrajectoryTree};

/// One root-to-leaf path of `tree` as an independent chain tree.
///
/// Alignment pads are stripped (`real_len`): a linearized branch is the raw
/// rollout, and chunk padding is re-applied downstream where needed.
pub fn path_chain(tree: &TrajectoryTree, path: &[usize]) -> TrajectoryTree {
    let nodes: Vec<NodeSpec> = path
        .iter()
        .enumerate()
        .map(|(d, &n)| {
            let nd = &tree.nodes[n];
            let real = nd.real_len();
            NodeSpec {
                parent: d as i32 - 1,
                tokens: nd.tokens[..real].to_vec(),
                trainable: nd.trainable[..real].to_vec(),
                advantage: nd.advantage[..real].to_vec(),
                pad_tail: 0,
            }
        })
        .collect();
    TrajectoryTree::new(nodes).expect("chain is a valid tree")
}

/// Every root-to-leaf path of `tree` as a chain tree, in DFS leaf order.
///
/// The token total over the result is `tree.n_flat()` — the sep-avg
/// baseline's cost — and feeding the chains back through `ingest` recovers
/// a tree with the same path set (the round-trip property tested in
/// `tests/ingest_roundtrip.rs`).
pub fn linearize(tree: &TrajectoryTree) -> Vec<TrajectoryTree> {
    tree.paths().iter().map(|p| path_chain(tree, p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig1() -> TrajectoryTree {
        TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1, 2, 3, 4]),
            NodeSpec::new(0, vec![5, 6]),
            NodeSpec::new(1, vec![7]),
            NodeSpec::new(1, vec![8, 9]),
            NodeSpec::new(0, vec![10, 11, 12]),
        ])
        .unwrap()
    }

    #[test]
    fn chains_cover_n_flat() {
        let t = fig1();
        let chains = linearize(&t);
        assert_eq!(chains.len(), t.num_paths());
        assert_eq!(chains.iter().map(|c| c.n_tree()).sum::<usize>(), t.n_flat());
        for c in &chains {
            assert_eq!(c.num_paths(), 1);
        }
    }

    #[test]
    fn chain_spells_the_path() {
        let t = fig1();
        let chains = linearize(&t);
        let toks: Vec<i32> = chains[1].nodes.iter().flat_map(|n| n.tokens.clone()).collect();
        assert_eq!(toks, vec![1, 2, 3, 4, 5, 6, 8, 9]);
    }

    #[test]
    fn pads_are_stripped() {
        let t = fig1().pad_for_chunks(4, 0);
        let chains = linearize(&t);
        assert!(chains.iter().all(|c| c.nodes.iter().all(|n| n.pad_tail == 0)));
        assert_eq!(chains.iter().map(|c| c.n_tree()).sum::<usize>(), 22);
    }

    #[test]
    fn supervision_travels_with_tokens() {
        let t = TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1, 2]).with_trainable(vec![0.0, 1.0]),
            NodeSpec::new(0, vec![3]).with_advantage(vec![2.5]),
        ])
        .unwrap();
        let c = &linearize(&t)[0];
        assert_eq!(c.nodes[0].trainable, vec![0.0, 1.0]);
        assert_eq!(c.nodes[1].advantage, vec![2.5]);
    }
}
