//! Synthetic trajectory-tree generators.
//!
//! The paper's real rollouts (SWE-smith tasks under Claude Code scaffolds,
//! Fig. 6) are proprietary; these generators reproduce the *shape* statistics
//! that determine every evaluation quantity — POR, branching factor, depth
//! profile, node-size distribution (DESIGN.md §5 substitution table):
//!
//! * [`with_target_por`] — controlled POR sweeps (Fig. 8): constant leaf
//!   count and total unique tokens, POR set by the shared-prefix depth.
//! * [`agentic`] — Fig. 6-style rollouts: multi-turn loops with concurrent
//!   tool fanout, think-mode branching (reasoning discarded between turns)
//!   and retokenization drift, giving sparse unbalanced trees.
//! * [`markov_segments`] — fills segments from a learnable 2-gram language
//!   so end-to-end training loss actually decreases (examples/agentic_sft).

use super::node::{NodeSpec, TrajectoryTree};
use crate::util::rng::Rng;

pub fn rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

/// A learnable synthetic language: deterministic 2-gram transitions with
/// noise.  `state` seeds the walk so different branches differ.
pub fn markov_segments(r: &mut Rng, vocab: i32, len: usize, state: &mut i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        // mostly-deterministic successor: tok' = (a*tok + b) % vocab
        let next = if r.gen_bool(0.85) {
            (*state * 31 + 17).rem_euclid(vocab)
        } else {
            r.i32(0, vocab)
        };
        out.push(next);
        *state = next;
    }
    out
}

/// Uniform random tree (tests, fuzzing).
pub fn uniform(seed: u64, max_nodes: usize, max_seg: usize, branch_p: f64) -> TrajectoryTree {
    let mut r = rng(seed);
    let mut nodes = vec![NodeSpec::new(-1, seg(&mut r, max_seg))];
    let mut frontier = vec![0usize];
    while let Some(cur) = frontier.pop() {
        if nodes.len() >= max_nodes {
            break;
        }
        if cur != 0 && !r.gen_bool(branch_p) {
            continue;
        }
        let n_child = r.usize(1, 4);
        for _ in 0..n_child {
            if nodes.len() >= max_nodes {
                break;
            }
            nodes.push(NodeSpec::new(cur as i32, seg(&mut r, max_seg)));
            frontier.push(nodes.len() - 1);
        }
    }
    reorder_preorder(nodes)
}

fn seg(r: &mut Rng, max_seg: usize) -> Vec<i32> {
    let n = r.usize(1, max_seg.max(1) + 1);
    (0..n).map(|_| r.i32(0, 64)).collect()
}

/// Controlled-POR tree (Fig. 8 sweeps): `k_leaves` branches off a shared
/// trunk; trunk depth chosen so POR(tree) == `target_por` while the unique
/// token count stays `total_tokens`.
///
/// With trunk `P` and per-branch `B = (T - P) / K`:
///   `POR = 1 - T / (T + P (K - 1))`  =>  `P = T * por / ((1 - por)(K - 1))`.
pub fn with_target_por(
    seed: u64,
    target_por: f64,
    k_leaves: usize,
    total_tokens: usize,
    node_len: usize,
    vocab: i32,
) -> TrajectoryTree {
    assert!(k_leaves >= 2);
    assert!((0.0..1.0).contains(&target_por));
    let t = total_tokens as f64;
    let p = (t * target_por / ((1.0 - target_por) * (k_leaves - 1) as f64))
        .round()
        .min(t - k_leaves as f64) as usize;
    let branch_total = total_tokens - p;
    let mut r = rng(seed);
    let mut state = r.i32(0, vocab);
    let mut nodes = Vec::new();

    // trunk as a chain of `node_len` segments
    let mut parent = -1i32;
    let mut left = p.max(1);
    while left > 0 {
        let l = left.min(node_len);
        nodes.push(NodeSpec::new(parent, markov_segments(&mut r, vocab, l, &mut state)));
        parent = (nodes.len() - 1) as i32;
        left -= l;
    }
    // K branches of ~equal length
    let per = (branch_total / k_leaves).max(1);
    for i in 0..k_leaves {
        let l = if i + 1 == k_leaves { branch_total - per * (k_leaves - 1) } else { per };
        let mut st = state.wrapping_add(i as i32 * 7 + 1).rem_euclid(vocab);
        let mut bparent = parent;
        let mut bleft = l.max(1);
        while bleft > 0 {
            let ll = bleft.min(node_len);
            nodes.push(NodeSpec::new(bparent, markov_segments(&mut r, vocab, ll, &mut st)));
            bparent = (nodes.len() - 1) as i32;
            bleft -= ll;
        }
    }
    reorder_preorder(nodes)
}

/// Graft a shared root-prefix chain ahead of `tree` — the synthetic analog
/// of a hot system prompt / repo context that many independent rollouts
/// open with.  The chain is `prefix_len` untrained tokens generated from
/// `group_seed` alone (split into `node_len`-token nodes), so every tree
/// grafted with the same `(group_seed, prefix_len, node_len, vocab)` carries
/// a byte-identical prefix — exactly what the cross-step affinity pass
/// fingerprints and the prefix cache reuses (docs/prefix_reuse.md;
/// `gen-data --hot-prefixes`).  The original tree rides below, its root
/// re-parented to the chain tail and all parent links shifted.
pub fn graft_prefix(
    tree: &TrajectoryTree,
    group_seed: u64,
    prefix_len: usize,
    node_len: usize,
    vocab: i32,
) -> TrajectoryTree {
    assert!(prefix_len >= 1 && node_len >= 1);
    let mut r = rng(group_seed);
    let mut state = r.i32(0, vocab);
    let mut nodes: Vec<NodeSpec> = Vec::new();
    let mut parent = -1i32;
    let mut left = prefix_len;
    while left > 0 {
        let l = left.min(node_len);
        let seg = markov_segments(&mut r, vocab, l, &mut state);
        let n = seg.len();
        // untrained: shared context is environment input, never supervised
        nodes.push(NodeSpec::new(parent, seg).with_trainable(vec![0.0; n]));
        parent = (nodes.len() - 1) as i32;
        left -= l;
    }
    let shift = nodes.len() as i32;
    for nd in &tree.nodes {
        let mut nd = nd.clone();
        nd.parent = if nd.parent < 0 { shift - 1 } else { nd.parent + shift };
        nodes.push(nd);
    }
    TrajectoryTree::new(nodes).expect("graft preserves preorder")
}

/// Overlap regimes of the paper's Fig. 6 rollouts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Overlap {
    /// POR ~ 0.28: early tool fanout, short shared context.
    Low,
    /// POR ~ 0.55: mixed tool fanout + drift re-branches.
    Medium,
    /// POR ~ 0.887: think-mode (long reasoning discarded every turn).
    High,
}

/// Agentic multi-turn rollout generator (Fig. 6 substitution).
///
/// Simulates a task loop: each turn appends environment input (untrained) +
/// model output (trained).  The overlap regime is governed by *where*
/// branches attach and how much of each turn's output survives:
///
/// * **Low** (paper ~28%): concurrent tool calls fan out right after the
///   prompt and each runs a long independent sub-trajectory — shared prefix
///   is short relative to the branches.
/// * **Medium** (~55%): think-mode with a moderate reasoning share — every
///   turn's discarded reasoning becomes a deep-attached leaf.
/// * **High** (paper ~88.7%): think-mode with a dominant reasoning share and
///   many turns — nearly everything generated shares the full deep prefix
///   (the paper notes high-POR trees come from long think-mode sessions).
pub fn agentic(seed: u64, overlap: Overlap, turns: usize, vocab: i32) -> TrajectoryTree {
    let mut r = rng(seed);
    let mut state = r.i32(0, vocab);
    let mut nodes: Vec<NodeSpec> = Vec::new();
    // root: task prompt (environment input, untrained); tool-fanout tasks
    // start from a larger shared context (files read up front)
    let prompt_len =
        if overlap == Overlap::Low { r.usize(64, 96) } else { r.usize(24, 48) };
    let prompt = markov_segments(&mut r, vocab, prompt_len, &mut state);
    let n = prompt.len();
    nodes.push(NodeSpec::new(-1, prompt).with_trainable(vec![0.0; n]));

    if overlap == Overlap::Low {
        // early fanout: concurrent tool sub-trajectories off the prompt;
        // POR ~ (W-1)*prompt / (W*(prompt+branch))
        let width = 4;
        for _ in 0..width {
            let mut st = state.wrapping_add(r.i32(1, 97)).rem_euclid(vocab);
            let mut branch_parent = 0i32;
            for _t in 0..(turns / 4).max(1) {
                let l = r.usize(18, 40);
                let out = markov_segments(&mut r, vocab, l, &mut st);
                nodes.push(NodeSpec::new(branch_parent, out));
                branch_parent = (nodes.len() - 1) as i32;
                let le = r.usize(4, 12);
                let env = markov_segments(&mut r, vocab, le, &mut st);
                let el = env.len();
                nodes.push(NodeSpec::new(branch_parent, env).with_trainable(vec![0.0; el]));
                branch_parent = (nodes.len() - 1) as i32;
            }
        }
        return reorder_preorder(nodes);
    }

    // think-mode trunk: each turn emits [think ; answer]; the next turn
    // keeps only the answer, so the full output forks off as a leaf.
    // POR ~ R/(1+R) with R = kept_per_turn * turns / (2 * tokens_per_turn).
    let (think_ratio, eff_turns) = match overlap {
        Overlap::Medium => (0.55, (turns / 2).max(2)),
        Overlap::High => (0.90, turns * 8),
        Overlap::Low => unreachable!(),
    };
    let mut trunk = 0i32;
    for _turn in 0..eff_turns {
        let out_len = r.usize(32, 80);
        let think_len = ((out_len as f64) * think_ratio) as usize;
        let ans_len = (out_len - think_len).max(1);
        let answer = markov_segments(&mut r, vocab, ans_len, &mut state);
        let mut st2 = state;
        let think = markov_segments(&mut r, vocab, think_len.max(1), &mut st2);
        // think node is a sibling leaf; answer continues the trunk
        nodes.push(NodeSpec::new(trunk, think));
        nodes.push(NodeSpec::new(trunk, answer));
        trunk = (nodes.len() - 1) as i32;
        // brief environment response (untrained)
        let le = r.usize(2, 8);
        let env = markov_segments(&mut r, vocab, le, &mut state);
        let el = env.len();
        nodes.push(NodeSpec::new(trunk, env).with_trainable(vec![0.0; el]));
        trunk = (nodes.len() - 1) as i32;
    }
    reorder_preorder(nodes)
}

/// Restore DFS pre-order after frontier-based growth (children contiguous).
fn reorder_preorder(nodes: Vec<NodeSpec>) -> TrajectoryTree {
    let n = nodes.len();
    let mut children = vec![Vec::new(); n];
    for (i, nd) in nodes.iter().enumerate().skip(1) {
        children[nd.parent as usize].push(i);
    }
    let mut order = Vec::with_capacity(n);
    let mut stack = vec![0usize];
    while let Some(i) = stack.pop() {
        order.push(i);
        for &c in children[i].iter().rev() {
            stack.push(c);
        }
    }
    let mut remap = vec![0usize; n];
    for (new, &old) in order.iter().enumerate() {
        remap[old] = new;
    }
    let out = order
        .iter()
        .map(|&old| {
            let nd = &nodes[old];
            NodeSpec {
                parent: if nd.parent < 0 { -1 } else { remap[nd.parent as usize] as i32 },
                ..nd.clone()
            }
        })
        .collect();
    TrajectoryTree::new(out).expect("reorder produced invalid tree")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::metrics::por;

    #[test]
    fn target_por_is_hit() {
        // max reachable POR with K leaves is 1 - 1/K, so use K = 16
        for &p in &[0.2, 0.4, 0.6, 0.8, 0.92] {
            let t = with_target_por(1, p, 16, 4000, 32, 512);
            let got = por(&t);
            assert!(
                (got - p).abs() < 0.03,
                "target {p} got {got} (tree {} nodes)",
                t.len()
            );
            // unique tokens held ~constant across the sweep
            assert!((t.n_tree() as i64 - 4000).abs() < 64);
        }
    }

    #[test]
    fn agentic_overlap_regimes_ordered() {
        let low = por(&agentic(3, Overlap::Low, 12, 512));
        let med = por(&agentic(3, Overlap::Medium, 12, 512));
        let high = por(&agentic(3, Overlap::High, 12, 512));
        assert!(low < med && med < high, "low {low} med {med} high {high}");
        assert!(high > 0.78, "think-mode should give high POR, got {high}");
        assert!((0.35..0.72).contains(&med), "medium regime off: {med}");
        assert!(low < 0.45, "tool fanout regime too overlapped: {low}");
    }

    #[test]
    fn uniform_valid() {
        for seed in 0..20 {
            let t = uniform(seed, 14, 6, 0.6);
            assert!(t.num_paths() >= 1);
            let m = super::super::dfs::serialize(&t);
            assert_eq!(m.size(), t.n_slots());
        }
    }

    #[test]
    fn grafted_prefix_is_shared_and_untrained() {
        let a = graft_prefix(&agentic(1, Overlap::Medium, 6, 256), 99, 96, 24, 256);
        let b = graft_prefix(&agentic(2, Overlap::Medium, 6, 256), 99, 96, 24, 256);
        let c = graft_prefix(&agentic(1, Overlap::Medium, 6, 256), 7, 96, 24, 256);
        // same group seed -> byte-identical 96-token chain, zero supervision
        let chain = |t: &TrajectoryTree| -> Vec<i32> {
            let mut toks = Vec::new();
            let mut i = 0usize;
            while toks.len() < 96 {
                assert!(t.nodes[i].trainable.iter().all(|&w| w == 0.0));
                toks.extend_from_slice(&t.nodes[i].tokens);
                i += 1;
            }
            toks.truncate(96);
            toks
        };
        assert_eq!(chain(&a), chain(&b));
        assert_ne!(chain(&a), chain(&c), "different groups diverge");
        // the body rides intact: unique tokens grew by exactly the prefix
        assert_eq!(a.n_tree(), agentic(1, Overlap::Medium, 6, 256).n_tree() + 96);
        assert_eq!(a.num_paths(), agentic(1, Overlap::Medium, 6, 256).num_paths());
    }

    #[test]
    fn markov_is_learnable() {
        // 85% of transitions follow the deterministic rule
        let mut r = rng(0);
        let mut state = 5;
        let seg = markov_segments(&mut r, 512, 4000, &mut state);
        let mut hits = 0;
        for w in seg.windows(2) {
            if w[1] == (w[0] * 31 + 17).rem_euclid(512) {
                hits += 1;
            }
        }
        assert!(hits as f64 / seg.len() as f64 > 0.75);
    }
}
