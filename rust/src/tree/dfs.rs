//! DFS serialization (Eq. 8) and per-token metadata (§3.2).
//!
//! The serializer walks the tree once and emits, for every token:
//!
//! * `pos_ids` — per-path position (Eq. 9): RoPE must see the same position
//!   the token would have in its standalone path.
//! * `subtree_exit` — exclusive DFS end of the token's node's subtree.  The
//!   tree attention mask ("j attends-able by i iff j <= i and node(j) is an
//!   ancestor-or-self of node(i)") reduces to the interval test
//!   `(j <= i) && (exit[j] >= exit[i])`, so the kernel needs O(S) metadata.
//! * `g` — number of root-to-leaf paths through the node, and the loss
//!   weight `lambda_t = g_t/K * trainable * advantage` (Eq. 4).
//! * `prev_idx` — path-predecessor slot: the per-token loss gathers logits
//!   there, so a branching node's last token predicts one target per branch.
//! * GDN extras: chunk parent map (Eq. 10 state routing) and causal-conv
//!   gather taps (App. A.3).
//!
//! Exactly mirrored by `python/compile/treemeta.py` + `batching.py`
//! (cross-checked by `rust/tests/serializer_parity.rs` against fixtures).

use super::node::TrajectoryTree;

/// Sentinel subtree-exit for gateway (past) keys: always visible modulo bias.
pub const PAST_EXIT: i32 = i32::MAX;
/// Additive mask bias for blocked attention entries.
pub const NEG_INF: f32 = -1e30;

/// Per-token metadata of the DFS-serialized tree.
#[derive(Debug, Clone, PartialEq)]
pub struct DfsMeta {
    pub tokens: Vec<i32>,
    pub pos_ids: Vec<i32>,
    pub subtree_exit: Vec<i32>,
    pub node_id: Vec<i32>,
    pub g: Vec<i32>,
    /// `lambda_t = g_t/K * trainable_t * advantage_t` (0 on pads).
    pub weights: Vec<f32>,
    pub pad_mask: Vec<bool>,
    // node table (DFS order)
    pub node_start: Vec<i32>,
    pub node_len: Vec<i32>,
    pub node_exit: Vec<i32>,
    pub node_parent: Vec<i32>,
    /// Ancestor *real* token count = per-path position of the node's first
    /// token (Eq. 9 / Eq. 17 depth-based offsets).
    pub node_depth_tokens: Vec<i32>,
    pub num_paths: usize,
}

impl DfsMeta {
    pub fn size(&self) -> usize {
        self.tokens.len()
    }

    /// DFS token slots of one root-to-leaf path (real tokens only).
    pub fn path_token_indices(&self, path: &[usize]) -> Vec<usize> {
        let mut idx = Vec::new();
        for &n in path {
            let s = self.node_start[n] as usize;
            for t in s..s + self.node_len[n] as usize {
                if !self.pad_mask[t] {
                    idx.push(t);
                }
            }
        }
        idx
    }
}

/// Serialize a trajectory tree into DFS token order with metadata.
pub fn serialize(tree: &TrajectoryTree) -> DfsMeta {
    let n_nodes = tree.nodes.len();
    let children = tree.children();

    // g_n = leaves under n == paths through n, bottom-up
    let mut g_node = vec![0i64; n_nodes];
    for i in (0..n_nodes).rev() {
        g_node[i] = if children[i].is_empty() {
            1
        } else {
            children[i].iter().map(|&c| g_node[c]).sum()
        };
    }
    let num_paths = g_node[0] as usize;

    // iterative pre-order: node_start + subtree exit
    let mut node_start = vec![0i64; n_nodes];
    let mut node_exit = vec![0i64; n_nodes];
    let mut cursor = 0i64;
    let mut stack: Vec<(usize, bool)> = vec![(0, false)];
    while let Some((i, done)) = stack.pop() {
        if done {
            node_exit[i] = cursor;
            continue;
        }
        node_start[i] = cursor;
        cursor += tree.nodes[i].tokens.len() as i64;
        stack.push((i, true));
        for &c in children[i].iter().rev() {
            stack.push((c, false));
        }
    }
    let total = cursor as usize;

    // depth in *real* tokens
    let mut node_depth = vec![0i64; n_nodes];
    for i in 1..n_nodes {
        let p = tree.nodes[i].parent as usize;
        node_depth[i] = node_depth[p] + tree.nodes[p].real_len() as i64;
    }

    let mut m = DfsMeta {
        tokens: vec![0; total],
        pos_ids: vec![0; total],
        subtree_exit: vec![0; total],
        node_id: vec![0; total],
        g: vec![0; total],
        weights: vec![0.0; total],
        pad_mask: vec![false; total],
        node_start: node_start.iter().map(|&x| x as i32).collect(),
        node_len: tree.nodes.iter().map(|n| n.tokens.len() as i32).collect(),
        node_exit: node_exit.iter().map(|&x| x as i32).collect(),
        node_parent: tree.nodes.iter().map(|n| n.parent).collect(),
        node_depth_tokens: node_depth.iter().map(|&x| x as i32).collect(),
        num_paths,
    };

    for (i, nd) in tree.nodes.iter().enumerate() {
        let s = node_start[i] as usize;
        let real = nd.real_len();
        for (j, &tok) in nd.tokens.iter().enumerate() {
            let t = s + j;
            m.tokens[t] = tok;
            m.node_id[t] = i as i32;
            m.g[t] = g_node[i] as i32;
            if j < real {
                m.pos_ids[t] = (node_depth[i] + j as i64) as i32;
                m.subtree_exit[t] = node_exit[i] as i32;
                m.weights[t] =
                    (g_node[i] as f32 / num_paths as f32) * nd.trainable[j] * nd.advantage[j];
            } else {
                // alignment pads: self-island, zero weight/position
                m.pos_ids[t] = 0;
                m.subtree_exit[t] = (t + 1) as i32;
                m.pad_mask[t] = true;
            }
        }
    }
    m
}

/// Per-token path-predecessor slots (-1 = none: root firsts, pads).
pub fn prev_indices(meta: &DfsMeta) -> Vec<i32> {
    let s_total = meta.size();
    let mut prev = vec![-1i32; s_total];
    // node -> last real slot on its path (incl. ancestors)
    let mut node_last: Vec<i32> = vec![-1; meta.node_start.len()];
    for n in 0..meta.node_start.len() {
        let par = meta.node_parent[n];
        let mut last = if par < 0 { -1 } else { node_last[par as usize] };
        let s = meta.node_start[n] as usize;
        for t in s..s + meta.node_len[n] as usize {
            if meta.pad_mask[t] {
                continue;
            }
            prev[t] = last;
            last = t as i32;
        }
        node_last[n] = last;
    }
    prev
}

/// Per-chunk parent index for GDN tree state routing (Eq. 10).
///
/// Chunk `i` reads the output state of chunk `map[i]` (-1 = initial state):
/// the previous chunk of the same node, else the parent node's last chunk.
/// Requires chunk/node alignment (`TrajectoryTree::pad_for_chunks`).
pub fn chunk_parent_map(meta: &DfsMeta, chunk: usize) -> crate::Result<Vec<i32>> {
    let s_total = meta.size();
    if s_total % chunk != 0 {
        anyhow::bail!("sequence {s_total} not chunk-aligned ({chunk})");
    }
    let n_chunks = s_total / chunk;
    let mut cpm = vec![0i32; n_chunks];
    let mut node_last_chunk = vec![-1i32; meta.node_start.len()];
    for i in 0..n_chunks {
        let a = meta.node_id[i * chunk];
        let b = meta.node_id[(i + 1) * chunk - 1];
        if a != b {
            anyhow::bail!("chunk {i} spans nodes {a}..{b}; pad segments first");
        }
        let n = a as usize;
        cpm[i] = if i > 0 && meta.node_id[(i - 1) * chunk] == a {
            (i - 1) as i32
        } else {
            let par = meta.node_parent[n];
            if par < 0 { -1 } else { node_last_chunk[par as usize] }
        };
        node_last_chunk[n] = i as i32;
    }
    Ok(cpm)
}

/// Causal-conv gather taps (App. A.3): token `t`'s tap `j = K-1` is itself;
/// taps `j < K-1` are its path predecessors (most recent at `K-2`), skipping
/// pads and never crossing sibling branches.  Missing history -> zero row 0;
/// with `has_ctx`, rows 1..K-1 are the parent partition's conv context
/// (chronological; row K-1 most recent).  Mirrors `gdn.conv_gather_indices`.
pub fn conv_gather_indices(meta: &DfsMeta, kernel: usize, has_ctx: bool) -> Vec<i32> {
    let k = kernel;
    let s_total = meta.size();
    let base = k as i32; // xx layout: [zero | ctx 1..K-1 | tokens]
    // tap encoding: >=0 token slot; -d = d-th most recent ctx row; i32::MIN missing
    const MISSING: i64 = i64::MIN;
    let slot = |tap: i64| -> i32 {
        if tap == MISSING {
            0
        } else if tap >= 0 {
            base + tap as i32
        } else {
            (k as i64 + tap) as i32 // -d -> row K-d
        }
    };
    let root_chain: Vec<i64> = if has_ctx {
        (1..k as i64).map(|d| -d).collect()
    } else {
        vec![MISSING; k - 1]
    };
    let mut idx = vec![0i32; s_total * k];
    let mut entry_chain: Vec<Vec<i64>> = vec![Vec::new(); meta.node_start.len()];
    for n in 0..meta.node_start.len() {
        let par = meta.node_parent[n];
        let mut chain =
            if par < 0 { root_chain.clone() } else { entry_chain[par as usize].clone() };
        let s = meta.node_start[n] as usize;
        for t in s..s + meta.node_len[n] as usize {
            idx[t * k + (k - 1)] = base + t as i32;
            for d in 0..k - 1 {
                idx[t * k + (k - 2 - d)] = slot(chain[d]);
            }
            if !meta.pad_mask[t] {
                chain.insert(0, t as i64);
                chain.truncate(k - 1);
            }
        }
        entry_chain[n] = chain;
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::NodeSpec;

    fn fig1() -> TrajectoryTree {
        TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1, 2, 3, 4]),
            NodeSpec::new(0, vec![5, 6, 7]),
            NodeSpec::new(1, vec![8, 9]),
            NodeSpec::new(1, vec![10, 11, 12, 13, 14]),
            NodeSpec::new(0, vec![15, 16, 17]),
        ])
        .unwrap()
    }

    #[test]
    fn serialize_fig1() {
        let t = fig1();
        let m = serialize(&t);
        assert_eq!(m.num_paths, 3);
        assert_eq!(m.size(), 17);
        // g: n0 on 3 paths, n1 on 2
        assert_eq!(&m.g[0..4], &[3, 3, 3, 3]);
        assert_eq!(&m.g[4..7], &[2, 2, 2]);
        // sibling nodes share position ranges (§3.2)
        let n3_first = m.node_start[2] as usize;
        let n4_first = m.node_start[3] as usize;
        assert_eq!(m.pos_ids[n3_first], 7);
        assert_eq!(m.pos_ids[n4_first], 7);
        assert_eq!(m.pos_ids[m.node_start[4] as usize], 4);
    }

    #[test]
    fn interval_mask_matches_ancestor_mask() {
        let t = fig1();
        let m = serialize(&t);
        let s = m.size();
        // first-principles ancestor mask
        let n_nodes = t.nodes.len();
        let mut anc = vec![vec![false; n_nodes]; n_nodes];
        for i in 0..n_nodes {
            let mut j = i as i32;
            while j >= 0 {
                anc[i][j as usize] = true;
                j = m.node_parent[j as usize];
            }
        }
        for i in 0..s {
            for j in 0..s {
                let dense = if i == j {
                    true
                } else {
                    j < i
                        && anc[m.node_id[i] as usize][m.node_id[j] as usize]
                        && !m.pad_mask[j]
                };
                let interval = j <= i && m.subtree_exit[j] >= m.subtree_exit[i];
                assert_eq!(dense, interval, "mismatch at ({i},{j})");
            }
        }
    }

    #[test]
    fn positions_match_paths() {
        let t = fig1();
        let m = serialize(&t);
        for p in t.paths() {
            for (k, t_idx) in m.path_token_indices(&p).iter().enumerate() {
                assert_eq!(m.pos_ids[*t_idx], k as i32);
            }
        }
    }

    #[test]
    fn weights_sum_to_flat_over_k() {
        let t = fig1();
        let m = serialize(&t);
        let sum: f32 = m.weights.iter().sum();
        assert!((sum - t.n_flat() as f32 / t.num_paths() as f32).abs() < 1e-4);
    }

    #[test]
    fn prev_idx_crosses_node_boundary() {
        let t = fig1();
        let m = serialize(&t);
        let prev = prev_indices(&m);
        assert_eq!(prev[0], -1);
        assert_eq!(prev[1], 0);
        // n1's first token's predecessor is n0's last (slot 3)
        assert_eq!(prev[m.node_start[1] as usize], 3);
        // both n2's and n3's first tokens point at n1's last (slot 6)
        assert_eq!(prev[m.node_start[2] as usize], 6);
        assert_eq!(prev[m.node_start[3] as usize], 6);
        // sibling branch n4's first points at n0's last (slot 3)
        assert_eq!(prev[m.node_start[4] as usize], 3);
    }

    #[test]
    fn chunk_map_tree_routing() {
        let t = fig1().pad_for_chunks(4, 0);
        let m = serialize(&t);
        let cpm = chunk_parent_map(&m, 4).unwrap();
        assert_eq!(cpm[0], -1);
        for (i, &p) in cpm.iter().enumerate() {
            assert!(p < i as i32, "parent chunk must precede (DFS pre-order)");
        }
    }

    #[test]
    fn chunk_map_rejects_unaligned() {
        let t = fig1();
        let m = serialize(&t);
        assert!(chunk_parent_map(&m, 4).is_err());
    }

    #[test]
    fn conv_taps_follow_path() {
        let t = fig1();
        let m = serialize(&t);
        let k = 3;
        let idx = conv_gather_indices(&m, k, false);
        let base = k as i32;
        // n4's first token (slot 14): taps = [n0 slot 2, n0 slot 3, self]
        let s = m.node_start[4] as usize;
        assert_eq!(&idx[s * k..(s + 1) * k], &[base + 2, base + 3, base + 14]);
        // root's first token: missing history -> zero rows
        assert_eq!(&idx[0..k], &[0, 0, base]);
    }
}
