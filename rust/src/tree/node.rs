//! Tree node model: DFS pre-order node lists with per-token supervision.

use crate::util::json::Json;

/// One tree node.  `parent` indexes the node list (-1 for the root).
///
/// Nodes are stored in DFS pre-order (parent before child, each node's
/// children contiguous in recursive order) — the natural order in which an
/// agentic runtime records branching trajectories.
///
/// `pad_tail` marks that many trailing tokens as alignment padding (hybrid
/// GDN models pad node segments to the SSM chunk size, §3.2).  Pads are
/// attention self-islands with zero loss weight; the SSM recurrence is made
/// transparent to them (g = 0, beta = 0).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub parent: i32,
    pub tokens: Vec<i32>,
    /// 1.0 = model output (trained), 0.0 = user/environment input.
    pub trainable: Vec<f32>,
    /// Per-token RL advantage (1.0 for SFT).
    pub advantage: Vec<f32>,
    pub pad_tail: usize,
}

impl NodeSpec {
    pub fn new(parent: i32, tokens: Vec<i32>) -> Self {
        let n = tokens.len();
        Self { parent, tokens, trainable: vec![1.0; n], advantage: vec![1.0; n], pad_tail: 0 }
    }

    pub fn with_trainable(mut self, trainable: Vec<f32>) -> Self {
        assert_eq!(trainable.len(), self.tokens.len());
        self.trainable = trainable;
        self
    }

    pub fn with_advantage(mut self, advantage: Vec<f32>) -> Self {
        assert_eq!(advantage.len(), self.tokens.len());
        self.advantage = advantage;
        self
    }

    /// Segment length excluding alignment pads.
    pub fn real_len(&self) -> usize {
        self.tokens.len() - self.pad_tail
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

impl NodeSpec {
    /// JSON encoding (corpus format): omits all-default supervision vectors.
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("parent", Json::num(self.parent as f64)),
            ("tokens", Json::arr_i32(&self.tokens)),
        ];
        if self.trainable.iter().any(|&x| x != 1.0) {
            kv.push(("trainable", Json::arr_f32(&self.trainable)));
        }
        if self.advantage.iter().any(|&x| x != 1.0) {
            kv.push(("advantage", Json::arr_f32(&self.advantage)));
        }
        if self.pad_tail != 0 {
            kv.push(("pad_tail", Json::num(self.pad_tail as f64)));
        }
        Json::obj(kv)
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let parent = v.req("parent")?.as_i64().ok_or_else(|| anyhow::anyhow!("parent"))? as i32;
        let tokens = v.req("tokens")?.to_vec_i32()?;
        let n = tokens.len();
        let trainable = match v.get("trainable") {
            Some(t) => t.to_vec_f32()?,
            None => vec![1.0; n],
        };
        let advantage = match v.get("advantage") {
            Some(t) => t.to_vec_f32()?,
            None => vec![1.0; n],
        };
        let pad_tail = v.get("pad_tail").and_then(|x| x.as_usize()).unwrap_or(0);
        anyhow::ensure!(trainable.len() == n && advantage.len() == n, "vector lengths");
        Ok(Self { parent, tokens, trainable, advantage, pad_tail })
    }
}

/// A trajectory tree: validated DFS pre-order node list.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectoryTree {
    pub nodes: Vec<NodeSpec>,
}

impl TrajectoryTree {
    /// Build from a pre-order node list, validating the ordering invariants.
    pub fn new(nodes: Vec<NodeSpec>) -> crate::Result<Self> {
        if nodes.is_empty() {
            anyhow::bail!("empty tree");
        }
        for (i, n) in nodes.iter().enumerate() {
            if i == 0 {
                if n.parent != -1 {
                    anyhow::bail!("node 0 must be the root");
                }
            } else if n.parent < 0 || n.parent as usize >= i {
                anyhow::bail!("node {i}: parent {} violates pre-order", n.parent);
            }
            if n.trainable.len() != n.tokens.len() || n.advantage.len() != n.tokens.len() {
                anyhow::bail!("node {i}: supervision vectors mismatch segment length");
            }
            if n.pad_tail > n.tokens.len() {
                anyhow::bail!("node {i}: pad_tail exceeds segment");
            }
        }
        Ok(Self { nodes })
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Total token count (the paper's `N_tree`), excluding alignment pads.
    pub fn n_tree(&self) -> usize {
        self.nodes.iter().map(|n| n.real_len()).sum()
    }

    /// Total token count including alignment pads (device footprint).
    pub fn n_slots(&self) -> usize {
        self.nodes.iter().map(|n| n.len()).sum()
    }

    /// Children lists (index-based).
    pub fn children(&self) -> Vec<Vec<usize>> {
        let mut ch = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            ch[n.parent as usize].push(i);
        }
        ch
    }

    /// All root-to-leaf paths as node-index lists (DFS leaf order).
    pub fn paths(&self) -> Vec<Vec<usize>> {
        let ch = self.children();
        let mut out = Vec::new();
        let mut stack: Vec<(usize, Vec<usize>)> = vec![(0, vec![0])];
        while let Some((i, acc)) = stack.pop() {
            if ch[i].is_empty() {
                out.push(acc.clone());
            }
            for &c in ch[i].iter().rev() {
                let mut next = acc.clone();
                next.push(c);
                stack.push((c, next));
            }
        }
        // stack-pop order reverses sibling order at the leaf level; restore
        // DFS order by sorting on the path's node sequence (pre-order ids
        // are DFS-monotone).
        out.sort();
        out
    }

    /// Number of root-to-leaf paths (`K`).
    pub fn num_paths(&self) -> usize {
        let ch = self.children();
        ch.iter().filter(|c| c.is_empty()).count()
    }

    /// Flattened (sep-avg baseline) token count: every path independently.
    pub fn n_flat(&self) -> usize {
        self.paths()
            .iter()
            .map(|p| p.iter().map(|&n| self.nodes[n].real_len()).sum::<usize>())
            .sum()
    }

    /// Pad every node segment to a multiple of `chunk` (hybrid models).
    pub fn pad_for_chunks(&self, chunk: usize, pad_token: i32) -> Self {
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                assert_eq!(n.pad_tail, 0, "already padded");
                let len = n.tokens.len();
                let mut pad = (chunk - len % chunk) % chunk;
                if len == 0 {
                    pad = chunk;
                }
                let mut tokens = n.tokens.clone();
                let mut trainable = n.trainable.clone();
                let mut advantage = n.advantage.clone();
                tokens.extend(std::iter::repeat(pad_token).take(pad));
                trainable.extend(std::iter::repeat(0.0).take(pad));
                advantage.extend(std::iter::repeat(1.0).take(pad));
                NodeSpec { parent: n.parent, tokens, trainable, advantage, pad_tail: pad }
            })
            .collect();
        Self { nodes }
    }

    /// Split any segment longer than `max_len` into a chain of nodes.
    ///
    /// Semantically the identity (a segment split into chained nodes spells
    /// the same paths); required before bin packing when a single node
    /// exceeds the partition capacity (§3.3).
    pub fn split_long_segments(&self, max_len: usize) -> Self {
        assert!(max_len > 0);
        let mut nodes: Vec<NodeSpec> = Vec::with_capacity(self.nodes.len());
        // old id -> new id of the *last* piece (children attach there)
        let mut tail = vec![0usize; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            assert_eq!(n.pad_tail, 0, "split before chunk padding");
            let parent = if i == 0 { -1i32 } else { tail[n.parent as usize] as i32 };
            if n.tokens.len() <= max_len {
                nodes.push(NodeSpec { parent, ..n.clone() });
                tail[i] = nodes.len() - 1;
                continue;
            }
            let mut prev = parent;
            let mut s = 0;
            while s < n.tokens.len() {
                let e = (s + max_len).min(n.tokens.len());
                nodes.push(NodeSpec {
                    parent: prev,
                    tokens: n.tokens[s..e].to_vec(),
                    trainable: n.trainable[s..e].to_vec(),
                    advantage: n.advantage[s..e].to_vec(),
                    pad_tail: 0,
                });
                prev = (nodes.len() - 1) as i32;
                s = e;
            }
            tail[i] = nodes.len() - 1;
        }
        Self { nodes }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("nodes", Json::Arr(self.nodes.iter().map(|n| n.to_json()).collect()))])
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let nodes = v
            .req_arr("nodes")?
            .iter()
            .map(NodeSpec::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Self::new(nodes)
    }

    /// Longest root-to-leaf path in real tokens (common-practice baseline
    /// for §4.7, and the partition peak-memory bound).
    pub fn longest_path(&self) -> Vec<usize> {
        self.paths()
            .into_iter()
            .max_by_key(|p| p.iter().map(|&n| self.nodes[n].real_len()).sum::<usize>())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig1() -> TrajectoryTree {
        // the paper's Figure-1 tree: K=3
        TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1, 2, 3, 4]),
            NodeSpec::new(0, vec![5, 6]),
            NodeSpec::new(1, vec![7]),
            NodeSpec::new(1, vec![8, 9]),
            NodeSpec::new(0, vec![10, 11, 12]),
        ])
        .unwrap()
    }

    #[test]
    fn counts() {
        let t = fig1();
        assert_eq!(t.num_paths(), 3);
        assert_eq!(t.n_tree(), 12);
        // paths: [0,1,2]=7, [0,1,3]=8, [0,4]=7 -> 22
        assert_eq!(t.n_flat(), 22);
    }

    #[test]
    fn paths_in_dfs_order() {
        let t = fig1();
        assert_eq!(t.paths(), vec![vec![0, 1, 2], vec![0, 1, 3], vec![0, 4]]);
    }

    #[test]
    fn rejects_non_preorder() {
        assert!(TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1]),
            NodeSpec::new(2, vec![2]),
            NodeSpec::new(0, vec![3]),
        ])
        .is_err());
    }

    #[test]
    fn chunk_padding() {
        let t = fig1().pad_for_chunks(4, 0);
        assert!(t.nodes.iter().all(|n| n.len() % 4 == 0));
        assert_eq!(t.n_tree(), 12); // real tokens unchanged
        assert_eq!(t.nodes[1].pad_tail, 2);
    }

    #[test]
    fn split_segments() {
        let t = fig1().split_long_segments(2);
        assert!(t.nodes.iter().all(|n| n.len() <= 2));
        assert_eq!(t.n_tree(), 12);
        assert_eq!(t.num_paths(), 3);
        assert_eq!(t.n_flat(), 22); // identity on path token counts
    }

    #[test]
    fn longest_path() {
        let t = fig1();
        assert_eq!(t.longest_path(), vec![0, 1, 3]);
    }
}
