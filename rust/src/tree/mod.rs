//! Trajectory trees (paper §3.1) and their DFS serialization (§3.2).
//!
//! A trajectory tree is a rooted tree whose nodes hold token segments; each
//! root-to-leaf path spells a complete agentic trajectory.  Everything the
//! model needs about the tree is reduced to per-token metadata vectors by
//! [`dfs::serialize`] — the tree attention mask becomes a two-integer
//! interval test, positions become explicit RoPE inputs, and the loss
//! becomes a per-token weighted sum (Eq. 4).

pub mod dfs;
pub mod gen;
pub mod io;
pub mod linearize;
pub mod metrics;
pub mod node;

pub use dfs::{serialize, DfsMeta};
pub use linearize::{linearize, path_chain};
pub use node::{NodeSpec, TrajectoryTree};
