//! Cross-tree prefix affinity: the schedule-level reuse tier.
//!
//! The ingest trie already merges shared prefixes *within* one session's
//! rollouts; Forest Packing already deduplicates them *within* one packed
//! batch.  What neither sees is that two different trees — different
//! sessions, different tasks run from the same system prompt — often share
//! a long token prefix, and whether that prefix is computed once or twice
//! per optimizer step depends entirely on whether the planner lands the two
//! trees in the same `ForestBatch` ("Schedule-Level Shared-Prefix Reuse",
//! PAPERS.md).
//!
//! This module builds that signal: a token-level trie over every tree's
//! *root-chain stream* — the `(token, trainable-bits, advantage-bits)`
//! triples along the unique single-child path from the root, exactly the
//! divergence discipline of the ingest trie's `NodeSig` fingerprints (a
//! supervision flip is a divergence even when tokens agree, because merged
//! prefixes must restore gradients exactly).  Each tree is annotated with
//! its deepest trie node shared by at least one *other* tree; trees
//! annotated with the same node form an **affine group** with a common
//! `prefix_len` and an FNV-1a `prefix_sig` over the shared triples.
//!
//! Consumers:
//!
//! * [`AffinityIndex::affine_order`] / [`AffinityIndex::affine_bins`] —
//!   group-major FFD packing, so same-prefix trees land in the same
//!   capacity-`C` bin (and consecutive bins when a group overflows one),
//!   maximizing within-step and adjacent-step overlap.
//! * [`shard_affine`] — LPT sharding of whole *groups* (summed member
//!   cost), so an affine group never splits across data-parallel ranks and
//!   the engine-level cache ([`crate::trainer::prefix_cache`]) sees every
//!   member of a group on one rank.
//! * [`annotate_members`] — stamps the per-member `prefix_len`/`prefix_sig`
//!   onto packed [`ForestBatch`]es, which is what the activation cache
//!   keys on at execute time.
//!
//! DFS pre-order serialization puts the root chain in a member's *first*
//! `prefix_len` slots, and every chain slot's visible key set is exactly
//! the earlier chain slots (`q_exit = k_exit =` member end for the whole
//! chain), so forward activations for those slots are a pure function of
//! (prefix triples, positions, parameters) — the invariant the engine-level
//! cache relies on for bit-identical reuse (docs/prefix_reuse.md).

use std::borrow::Borrow;

use crate::tree::TrajectoryTree;

use super::forest::{ForestBatch, RankShards};

/// One root-chain element: `(token, trainable f32 bits, advantage f32
/// bits)` — the same triple the ingest trie splits on.
pub type PrefixTriple = (i32, u32, u32);

/// Root-chain streams longer than this are truncated before indexing —
/// bounds trie memory on degenerate chain-only corpora without affecting
/// correctness (a truncated match is still a valid shared prefix).
pub const MAX_STREAM: usize = 4096;

/// FNV-1a 64-bit offset basis (shared with the pipeline fingerprints).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// The root-chain stream of a tree: tokens of the root node and of every
/// single-child descendant, ending with the first multi-child node's own
/// tokens (they are shared by all its branches, hence part of the shared
/// prefix) or the sole leaf's.  Nodes carrying alignment pads stop the
/// stream *before* their tokens, so stream index `t` always equals member
/// slot `t` under DFS serialization.
pub fn prefix_stream(tree: &TrajectoryTree) -> Vec<PrefixTriple> {
    let ch = tree.children();
    let mut out = Vec::new();
    let mut cur = 0usize;
    loop {
        let n = &tree.nodes[cur];
        if n.pad_tail != 0 {
            break;
        }
        for t in 0..n.tokens.len() {
            if out.len() >= MAX_STREAM {
                return out;
            }
            out.push((n.tokens[t], n.trainable[t].to_bits(), n.advantage[t].to_bits()));
        }
        if ch[cur].len() != 1 {
            break;
        }
        cur = ch[cur][0];
    }
    out
}

/// FNV-1a fingerprint of the first `len` triples of a stream.
pub fn prefix_sig(stream: &[PrefixTriple], len: usize) -> u64 {
    let mut h = FNV_OFFSET;
    for &(tok, tr, adv) in &stream[..len] {
        fnv1a(&mut h, &tok.to_le_bytes());
        fnv1a(&mut h, &tr.to_le_bytes());
        fnv1a(&mut h, &adv.to_le_bytes());
    }
    h
}

/// Per-tree affinity annotation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreePrefix {
    /// Index into [`AffinityIndex::groups`].
    pub group: usize,
    /// Shared-prefix length in tokens (0 = no other tree shares a prefix).
    pub prefix_len: usize,
    /// [`prefix_sig`] over the shared triples (0 when `prefix_len == 0`).
    pub sig: u64,
}

/// A set of trees annotated with the same deepest shared trie node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AffineGroup {
    /// Member tree indices in ascending input order.
    pub members: Vec<usize>,
    pub prefix_len: usize,
    pub sig: u64,
}

#[derive(Debug, Clone, Default)]
struct TrieNode {
    children: Vec<(PrefixTriple, usize)>,
    count: u32,
}

/// The cross-tree prefix signature index.
///
/// Groups are numbered in order of first member appearance, and every
/// tie-break below is deterministic, so the index — and everything planned
/// from it — is reproducible run-to-run (the affinity ∘ sharding
/// determinism gate in `tests/prefix_reuse_equivalence.rs`).
#[derive(Debug, Clone)]
pub struct AffinityIndex {
    pub trees: Vec<TreePrefix>,
    pub groups: Vec<AffineGroup>,
}

impl AffinityIndex {
    /// Index a batch of trees (accepts `&[Tree]` or `&[Arc<Tree>]`).
    pub fn build<T: Borrow<TrajectoryTree>>(trees: &[T]) -> Self {
        let streams: Vec<Vec<PrefixTriple>> =
            trees.iter().map(|t| prefix_stream(t.borrow())).collect();
        // token-level trie with per-node pass counts
        let mut arena: Vec<TrieNode> = vec![TrieNode::default()];
        let mut paths: Vec<Vec<usize>> = Vec::with_capacity(streams.len());
        for s in &streams {
            let mut cur = 0usize;
            let mut path = Vec::with_capacity(s.len());
            for &trip in s {
                let next = match arena[cur].children.iter().find(|(k, _)| *k == trip) {
                    Some(&(_, c)) => c,
                    None => {
                        arena.push(TrieNode::default());
                        let c = arena.len() - 1;
                        arena[cur].children.push((trip, c));
                        c
                    }
                };
                arena[next].count += 1;
                path.push(next);
                cur = next;
            }
            paths.push(path);
        }
        // deepest node on each tree's path shared by >= 2 trees
        let mut group_of_node: Vec<Option<usize>> = vec![None; arena.len()];
        let mut annots = Vec::with_capacity(streams.len());
        let mut groups: Vec<AffineGroup> = Vec::new();
        for (i, path) in paths.iter().enumerate() {
            let mut best: Option<(usize, usize)> = None; // (node, depth)
            for (d, &node) in path.iter().enumerate() {
                if arena[node].count >= 2 {
                    best = Some((node, d + 1));
                }
            }
            let (group, prefix_len, sig) = match best {
                Some((node, depth)) => {
                    let sig = prefix_sig(&streams[i], depth);
                    let g = match group_of_node[node] {
                        Some(g) => g,
                        None => {
                            groups.push(AffineGroup { members: Vec::new(), prefix_len: depth, sig });
                            group_of_node[node] = Some(groups.len() - 1);
                            groups.len() - 1
                        }
                    };
                    (g, depth, sig)
                }
                None => {
                    // singleton group: keeps "every tree is in exactly one
                    // group" so ordering/sharding need no special case
                    groups.push(AffineGroup { members: Vec::new(), prefix_len: 0, sig: 0 });
                    (groups.len() - 1, 0, 0)
                }
            };
            groups[group].members.push(i);
            annots.push(TreePrefix { group, prefix_len, sig });
        }
        Self { trees: annots, groups }
    }

    /// Number of trees indexed.
    pub fn len(&self) -> usize {
        self.trees.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trees.is_empty()
    }

    /// Group-major visiting order: groups by decreasing summed cost (ties
    /// keep first-appearance order), members within a group by decreasing
    /// cost (ties keep input order).  This is the affine analogue of the
    /// FFD decreasing-cost order — the heaviest *prefix community* seeds
    /// the bins first, and its members are consecutive so they co-locate.
    pub fn affine_order(&self, costs: &[usize]) -> Vec<usize> {
        assert_eq!(costs.len(), self.trees.len(), "affine_order: cost arity");
        let group_cost: Vec<usize> = self
            .groups
            .iter()
            .map(|g| g.members.iter().map(|&i| costs[i]).sum())
            .collect();
        let mut gorder: Vec<usize> = (0..self.groups.len()).collect();
        gorder.sort_by_key(|&g| std::cmp::Reverse(group_cost[g]));
        let mut out = Vec::with_capacity(costs.len());
        for &g in &gorder {
            let mut ms = self.groups[g].members.clone();
            ms.sort_by_key(|&i| std::cmp::Reverse(costs[i]));
            out.extend(ms);
        }
        out
    }

    /// Prefix-affine FFD: visit trees in [`Self::affine_order`]; each tree
    /// prefers the first bin already holding a same-group member (so a
    /// group overflowing one bin stays in as few bins as possible), then
    /// plain first-fit, else opens a new bin.  Feasibility is always slot
    /// `sizes` against the hard `capacity`; `costs` only orders.
    pub fn affine_bins(
        &self,
        sizes: &[usize],
        costs: &[usize],
        capacity: usize,
    ) -> crate::Result<Vec<Vec<usize>>> {
        anyhow::ensure!(
            sizes.len() == self.trees.len() && costs.len() == self.trees.len(),
            "affine_bins: {} sizes / {} costs for {} trees",
            sizes.len(),
            costs.len(),
            self.trees.len()
        );
        // (used slots, member ids, groups present)
        let mut bins: Vec<(usize, Vec<usize>, Vec<usize>)> = Vec::new();
        for i in self.affine_order(costs) {
            let s = sizes[i];
            anyhow::ensure!(
                s <= capacity,
                "tree of {s} slots exceeds capacity {capacity}; partition it instead"
            );
            let g = self.trees[i].group;
            let slot = bins
                .iter()
                .position(|b| b.2.contains(&g) && b.0 + s <= capacity)
                .or_else(|| bins.iter().position(|b| b.0 + s <= capacity));
            match slot {
                Some(bi) => {
                    bins[bi].0 += s;
                    bins[bi].1.push(i);
                    if !bins[bi].2.contains(&g) {
                        bins[bi].2.push(g);
                    }
                }
                None => bins.push((s, vec![i], vec![g])),
            }
        }
        Ok(bins.into_iter().map(|(_, ids, _)| ids).collect())
    }
}

/// LPT-shard whole affine *groups* across ranks: group cost = summed member
/// cost, placement via the same deterministic [`super::forest::shard_by_cost`],
/// then each rank's groups expand to their member trees in ascending input
/// order.  A group never splits across ranks, so the engine-level cache
/// (per-rank state) sees every member of a group — the rank-local
/// composition contract of docs/prefix_reuse.md.
pub fn shard_affine(
    index: &AffinityIndex,
    costs: &[usize],
    n_ranks: usize,
) -> crate::Result<RankShards> {
    anyhow::ensure!(costs.len() == index.trees.len(), "shard_affine: cost arity");
    let group_costs: Vec<usize> = index
        .groups
        .iter()
        .map(|g| g.members.iter().map(|&i| costs[i]).sum())
        .collect();
    let shards = super::forest::shard_by_cost(&group_costs, n_ranks)?;
    let ranks: Vec<Vec<usize>> = shards
        .ranks
        .iter()
        .map(|gs| {
            let mut ms: Vec<usize> =
                gs.iter().flat_map(|&g| index.groups[g].members.iter().copied()).collect();
            ms.sort_unstable(); // ascending input order, like shard_by_cost
            ms
        })
        .collect();
    Ok(RankShards { ranks, loads: shards.loads })
}

/// Stamp each packed member's shared-prefix annotation (`prefix_len` /
/// `prefix_sig`) from the index it was packed under.  Members of singleton
/// groups keep the zero annotation — the cache never keys on them.
pub fn annotate_members(forests: &mut [ForestBatch], index: &AffinityIndex) {
    for fb in forests.iter_mut() {
        for m in &mut fb.members {
            let a = &index.trees[m.source];
            if a.prefix_len > 0 {
                m.prefix_len = a.prefix_len.min(m.len);
                m.prefix_sig = a.sig;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::NodeSpec;

    /// chain tree: `prefix` as the root node, then one branch node per leaf
    fn tree_with_prefix(prefix: &[i32], leaves: &[&[i32]]) -> TrajectoryTree {
        let mut nodes = vec![NodeSpec::new(-1, prefix.to_vec())];
        for l in leaves {
            nodes.push(NodeSpec::new(0, l.to_vec()));
        }
        TrajectoryTree::new(nodes).unwrap()
    }

    #[test]
    fn stream_follows_the_root_chain_and_stops_at_divergence() {
        // root [1,2] -> single child [3] -> two children
        let t = TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1, 2]),
            NodeSpec::new(0, vec![3]),
            NodeSpec::new(1, vec![4]),
            NodeSpec::new(1, vec![5]),
        ])
        .unwrap();
        let s = prefix_stream(&t);
        assert_eq!(s.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn supervision_flip_diverges_like_the_ingest_trie() {
        let a = tree_with_prefix(&[7, 8, 9], &[&[1], &[2]]);
        let mut b = tree_with_prefix(&[7, 8, 9], &[&[3], &[4]]);
        b.nodes[0].trainable[1] = 0.0; // same tokens, different supervision
        let idx = AffinityIndex::build(&[a.clone(), b]);
        // token 7 matches, token 8 diverges on trainable bits
        assert_eq!(idx.trees[0].prefix_len, 1);
        assert_eq!(idx.trees[0].group, idx.trees[1].group);
        // identical supervision groups at the full prefix
        let b2 = tree_with_prefix(&[7, 8, 9], &[&[3], &[4]]);
        let idx2 = AffinityIndex::build(&[a, b2]);
        assert_eq!(idx2.trees[0].prefix_len, 3);
        assert_eq!(idx2.trees[0].sig, idx2.trees[1].sig);
    }

    #[test]
    fn deepest_shared_node_wins_and_shallow_sharers_split_off() {
        let a = tree_with_prefix(&[1, 2, 3, 4], &[&[9], &[8]]);
        let c = tree_with_prefix(&[1, 2, 3, 5], &[&[9], &[8]]);
        let b = tree_with_prefix(&[1, 2, 7], &[&[9], &[8]]);
        let idx = AffinityIndex::build(&[a, b, c]);
        // a and c share depth 3 ([1,2,3]); b only shares depth 2 ([1,2])
        assert_eq!(idx.trees[0].prefix_len, 3);
        assert_eq!(idx.trees[2].prefix_len, 3);
        assert_eq!(idx.trees[0].group, idx.trees[2].group);
        assert_eq!(idx.trees[1].prefix_len, 2);
        assert_ne!(idx.trees[1].group, idx.trees[0].group);
    }

    #[test]
    fn loner_trees_get_singleton_groups() {
        let a = tree_with_prefix(&[1, 2], &[&[3]]);
        let b = tree_with_prefix(&[4, 5], &[&[6]]);
        let idx = AffinityIndex::build(&[a, b]);
        assert_eq!(idx.trees[0].prefix_len, 0);
        assert_eq!(idx.trees[1].prefix_len, 0);
        assert_ne!(idx.trees[0].group, idx.trees[1].group);
        assert_eq!(idx.groups.len(), 2);
    }

    #[test]
    fn affine_order_is_group_major_by_total_cost() {
        // group A = {0, 1} (cost 5 + 2), group B = {2} (cost 6)
        let t0 = tree_with_prefix(&[1, 1, 1], &[&[2], &[3]]);
        let t1 = tree_with_prefix(&[1, 1, 1], &[&[4], &[5]]);
        let t2 = tree_with_prefix(&[9, 9], &[&[2], &[3]]);
        let idx = AffinityIndex::build(&[t0, t1, t2]);
        // A totals 7 > B's 6: A first, heavier member first
        assert_eq!(idx.affine_order(&[5, 2, 6]), vec![0, 1, 2]);
        // flip the costs: B totals 9 > A's 4; within A, tree 1 outweighs 0
        assert_eq!(idx.affine_order(&[1, 3, 9]), vec![2, 1, 0]);
    }

    #[test]
    fn affine_bins_colocate_groups_then_first_fit() {
        let t = |p: &[i32]| tree_with_prefix(p, &[&[100], &[101]]);
        // two groups of two; sizes chosen so plain FFD would interleave
        let trees = [t(&[1, 1]), t(&[2, 2]), t(&[1, 1]), t(&[2, 2])];
        let idx = AffinityIndex::build(&trees);
        let bins = idx.affine_bins(&[6, 6, 4, 4], &[6, 6, 4, 4], 10).unwrap();
        // group {0,2} packs together, group {1,3} packs together
        let find = |i: usize| bins.iter().position(|b| b.contains(&i)).unwrap();
        assert_eq!(find(0), find(2));
        assert_eq!(find(1), find(3));
        assert_ne!(find(0), find(1));
    }

    #[test]
    fn affine_bins_respect_capacity_and_cover_all() {
        let t = |p: &[i32]| tree_with_prefix(p, &[&[100], &[101]]);
        let trees = [t(&[1]), t(&[1]), t(&[1]), t(&[2]), t(&[2])];
        let sizes = [7usize, 6, 5, 4, 3];
        let idx = AffinityIndex::build(&trees);
        let bins = idx.affine_bins(&sizes, &sizes, 12).unwrap();
        let mut seen: Vec<usize> = bins.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        for b in &bins {
            assert!(b.iter().map(|&i| sizes[i]).sum::<usize>() <= 12);
        }
    }

    #[test]
    fn shard_affine_keeps_groups_rank_local() {
        let t = |p: &[i32]| tree_with_prefix(p, &[&[100], &[101]]);
        let trees =
            [t(&[1, 1]), t(&[2, 2]), t(&[1, 1]), t(&[2, 2]), t(&[3, 3]), t(&[3, 3])];
        let idx = AffinityIndex::build(&trees);
        let costs = [10usize, 10, 10, 10, 10, 10];
        let shards = shard_affine(&idx, &costs, 3).unwrap();
        let rank_of = |i: usize| shards.ranks.iter().position(|r| r.contains(&i)).unwrap();
        for g in &idx.groups {
            let r0 = rank_of(g.members[0]);
            for &m in &g.members {
                assert_eq!(rank_of(m), r0, "group split across ranks");
            }
        }
        let mut seen: Vec<usize> = shards.ranks.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn index_is_reproducible() {
        let trees: Vec<TrajectoryTree> = (0..12)
            .map(|i| {
                let p: Vec<i32> = (0..(i % 4 + 2)).map(|k| (k % 3) as i32 + 1).collect();
                tree_with_prefix(&p, &[&[i as i32 + 50], &[i as i32 + 90]])
            })
            .collect();
        let a = AffinityIndex::build(&trees);
        let b = AffinityIndex::build(&trees);
        assert_eq!(a.trees, b.trees);
        assert_eq!(a.groups, b.groups);
    }
}
