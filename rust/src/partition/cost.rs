//! Calibrated per-tree execution-cost model for sharding and packing.
//!
//! The LPT rank sharder ([`super::forest::shard_by_cost`]) and the FFD
//! forest packer ([`super::forest::pack_forest`]) both order work by a
//! scalar *cost* per tree.  The seed uses the packed token count — exact
//! for the token-proportional parts of a step, blind to per-call overhead
//! (program launches, gateway relays, host-side batch assembly) and to
//! depth effects.  [`CostModel`] is the seam between those planners and a
//! better estimate:
//!
//! * [`CostModel::Tokens`] — the default.  `price()` returns the token
//!   base *unchanged*, so every seed plan, equivalence suite and
//!   determinism gate is bit-identical to the pre-seam code.
//! * [`CostModel::Calibrated`] — a 4-feature linear model
//!   `wall ≈ w · [tokens, depth, est_calls, 1]` fit online by ridge-
//!   regularized least squares from *measured per-rank execute walls*
//!   (fed back by the executor via [`CostModel::observe`]).  Until
//!   `min_obs` observations have accumulated it prices exactly like
//!   `Tokens`, so warmup steps stay on the seed schedule.
//!
//! **Determinism caveat** (docs/distributed.md): a calibrated model prices
//! from *measured wall clock*, so two runs of the same corpus may shard
//! differently once calibration kicks in.  Losses stay within the f64
//! sharding tolerance (the global batch never changes — only its rank
//! placement), but calibrated runs are not run-to-run bit-identical the
//! way the default is.  Every bit-exactness gate therefore runs on
//! `Tokens`.

use std::sync::{Arc, Mutex};

use crate::tree::TrajectoryTree;
use crate::util::json::Json;

/// Feature-vector width: `[tokens, depth, est_calls, 1.0]`.
pub const N_FEATS: usize = 4;

/// The per-tree feature vector the calibrated model prices on:
/// `[base, depth, est_calls, 1.0]` where `base` is the planner's token
/// cost for the mode (`n_tree` packed tokens for tree mode, `n_flat` for
/// the baseline), `depth` is the deepest root-to-leaf real-token path
/// (partition-relay length and attention-window growth both scale with
/// it), and `est_calls = ceil(base / capacity)` approximates the program
/// invocations the tree will occupy (per-call launch overhead).
pub fn tree_features(tree: &TrajectoryTree, base: usize, capacity: usize) -> [f64; N_FEATS] {
    let mut depth = vec![0usize; tree.nodes.len()];
    let mut max_depth = 0usize;
    for (i, n) in tree.nodes.iter().enumerate() {
        let above = if n.parent < 0 { 0 } else { depth[n.parent as usize] };
        depth[i] = above + n.real_len();
        max_depth = max_depth.max(depth[i]);
    }
    let est_calls = if capacity == 0 { 1 } else { base.div_ceil(capacity).max(1) };
    [base as f64, max_depth as f64, est_calls as f64, 1.0]
}

/// Online normal-equation accumulator for the 4-feature linear fit.
///
/// `observe` is a rank-1 update of `XᵀX` and `Xᵀy`; `solve` adds a small
/// ridge (scaled to the feature magnitudes, so near-collinear features —
/// e.g. depth ≈ tokens on chain-shaped corpora — stay solvable) and runs
/// Gaussian elimination with partial pivoting on the 4×4 system.
#[derive(Debug, Clone, Default)]
pub struct Calibrator {
    xtx: [[f64; N_FEATS]; N_FEATS],
    xty: [f64; N_FEATS],
    n: u64,
}

impl Calibrator {
    pub fn observe(&mut self, x: &[f64; N_FEATS], y: f64) {
        if !y.is_finite() || x.iter().any(|v| !v.is_finite()) {
            return;
        }
        for i in 0..N_FEATS {
            for j in 0..N_FEATS {
                self.xtx[i][j] += x[i] * x[j];
            }
            self.xty[i] += x[i] * y;
        }
        self.n += 1;
    }

    pub fn n_obs(&self) -> u64 {
        self.n
    }

    /// Solve the ridge-regularized normal equations; `None` while the
    /// system is empty or numerically singular even after regularization.
    pub fn solve(&self) -> Option<[f64; N_FEATS]> {
        if self.n == 0 {
            return None;
        }
        let trace: f64 = (0..N_FEATS).map(|i| self.xtx[i][i]).sum();
        if !(trace > 0.0) {
            return None; // degenerate: no real feature mass observed
        }
        // per-feature relative ridge: invariant to feature units, strong
        // enough to break exact collinearity (e.g. est_calls ≡ bias on a
        // corpus where every tree fits one call), weak enough (1e-8
        // relative) not to bias a well-conditioned fit measurably
        let mut a = [[0.0f64; N_FEATS + 1]; N_FEATS];
        for i in 0..N_FEATS {
            for j in 0..N_FEATS {
                a[i][j] = self.xtx[i][j];
            }
            a[i][i] += 1e-8 * self.xtx[i][i] + 1e-12;
            a[i][N_FEATS] = self.xty[i];
        }
        // Gaussian elimination with partial pivoting
        for col in 0..N_FEATS {
            let pivot = (col..N_FEATS)
                .max_by(|&p, &q| a[p][col].abs().total_cmp(&a[q][col].abs()))
                .expect("non-empty pivot range");
            if a[pivot][col].abs() < 1e-12 {
                return None;
            }
            a.swap(col, pivot);
            for row in (col + 1)..N_FEATS {
                let f = a[row][col] / a[col][col];
                for k in col..=N_FEATS {
                    a[row][k] -= f * a[col][k];
                }
            }
        }
        let mut w = [0.0f64; N_FEATS];
        for col in (0..N_FEATS).rev() {
            let mut acc = a[col][N_FEATS];
            for k in (col + 1)..N_FEATS {
                acc -= a[col][k] * w[k];
            }
            w[col] = acc / a[col][col];
        }
        if w.iter().all(|v| v.is_finite()) {
            Some(w)
        } else {
            None
        }
    }

    /// Serialize the full normal-equation state (not just the solved
    /// weights): a warm-started run keeps *accumulating* observations on
    /// top of the previous run's, so the fit sharpens across restarts
    /// instead of resetting.  f64s round-trip exactly through the JSON
    /// writer (Rust's shortest-representation `Display`).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> =
            self.xtx.iter().map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v)).collect())).collect();
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("xtx", Json::Arr(rows)),
            ("xty", Json::Arr(self.xty.iter().map(|&v| Json::Num(v)).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let n = v.req("n")?.as_u64().ok_or_else(|| anyhow::anyhow!("`n` not a number"))?;
        let row_f64 = |r: &Json| -> crate::Result<[f64; N_FEATS]> {
            let a = r.as_arr().ok_or_else(|| anyhow::anyhow!("expected array"))?;
            anyhow::ensure!(a.len() == N_FEATS, "expected {N_FEATS} entries, got {}", a.len());
            let mut out = [0.0f64; N_FEATS];
            for (o, x) in out.iter_mut().zip(a) {
                *o = x.as_f64().ok_or_else(|| anyhow::anyhow!("not a number"))?;
            }
            Ok(out)
        };
        let rows = v.req_arr("xtx")?;
        anyhow::ensure!(rows.len() == N_FEATS, "`xtx` must be {N_FEATS}x{N_FEATS}");
        let mut xtx = [[0.0f64; N_FEATS]; N_FEATS];
        for (o, r) in xtx.iter_mut().zip(rows) {
            *o = row_f64(r)?;
        }
        let xty = row_f64(v.req("xty")?)?;
        Ok(Self { xtx, xty, n })
    }
}

/// Shared state of one calibrated model: planner threads price through it
/// while the executor feeds measured walls back in — the `Arc` is cloned
/// into every [`crate::trainer::planner::ShardedPlan`], so feedback needs
/// no extra plumbing.
#[derive(Debug)]
pub struct CalibratedCost {
    /// Observations required before predictions replace the token base.
    min_obs: u64,
    inner: Mutex<CalState>,
}

#[derive(Debug, Default)]
struct CalState {
    cal: Calibrator,
    /// Last solved weights (refit on every observe — the system is 4×4,
    /// the solve is ~100 flops).
    w: Option<[f64; N_FEATS]>,
}

/// The cost seam consumed by rank sharding and forest packing.
#[derive(Debug, Clone)]
pub enum CostModel {
    /// Price every tree at exactly its token base (the seed behavior,
    /// bit-for-bit). `observe` is a no-op.
    Tokens,
    Calibrated(Arc<CalibratedCost>),
}

impl Default for CostModel {
    fn default() -> Self {
        Self::Tokens
    }
}

impl CostModel {
    /// A fresh calibrated model that prices like [`Self::Tokens`] until
    /// `min_obs` per-rank wall observations have been absorbed.
    pub fn calibrated(min_obs: u64) -> Self {
        Self::Calibrated(Arc::new(CalibratedCost {
            min_obs,
            inner: Mutex::new(CalState::default()),
        }))
    }

    /// Price one tree: `Tokens` returns `base` unchanged; a calibrated
    /// model with enough observations returns the predicted wall in
    /// integer microseconds (clamped ≥ 1 so no real tree is free).
    pub fn price(&self, feats: &[f64; N_FEATS], base: usize) -> usize {
        match self {
            Self::Tokens => base,
            Self::Calibrated(c) => {
                let st = c.inner.lock().expect("cost model lock");
                match (st.cal.n_obs() >= c.min_obs, &st.w) {
                    (true, Some(w)) => {
                        let pred: f64 = w.iter().zip(feats).map(|(a, b)| a * b).sum::<f64>() * 1e3;
                        if pred.is_finite() {
                            (pred.round() as i64).max(1) as usize
                        } else {
                            base
                        }
                    }
                    _ => base,
                }
            }
        }
    }

    /// Feed one measured per-rank wall (ms) for a rank whose trees summed
    /// to `feats` (feature vectors are additive, so the rank total is a
    /// valid regression row). No-op on `Tokens`.
    pub fn observe(&self, feats: &[f64; N_FEATS], wall_ms: f64) {
        if let Self::Calibrated(c) = self {
            let mut st = c.inner.lock().expect("cost model lock");
            st.cal.observe(feats, wall_ms);
            st.w = st.cal.solve();
        }
    }

    /// Are predictions live (calibrated + past `min_obs`)?  While false,
    /// pricing — and therefore every plan — is identical to [`Self::Tokens`].
    pub fn active(&self) -> bool {
        match self {
            Self::Tokens => false,
            Self::Calibrated(c) => {
                let st = c.inner.lock().expect("cost model lock");
                st.cal.n_obs() >= c.min_obs && st.w.is_some()
            }
        }
    }

    /// Observations absorbed so far (0 for `Tokens`).
    pub fn n_obs(&self) -> u64 {
        match self {
            Self::Tokens => 0,
            Self::Calibrated(c) => c.inner.lock().expect("cost model lock").cal.n_obs(),
        }
    }

    /// A calibrated model warm-started from a previous run's saved state
    /// ([`Self::save_state`]): the persisted normal equations seed the
    /// accumulator, so pricing can be live from the very first step (if
    /// the saved run already had >= `min_obs` observations) instead of
    /// re-learning from scratch — the restart path of a long-lived
    /// `tree-train serve` process.  A missing file is not an error: the
    /// first run of a pair has nothing to warm-start from.
    pub fn calibrated_from_state(min_obs: u64, path: &std::path::Path) -> crate::Result<Self> {
        let cal = match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Calibrator::default(),
            Err(e) => anyhow::bail!("reading cost-model state {}: {e}", path.display()),
            Ok(s) => {
                let v = Json::parse(&s)
                    .map_err(|e| anyhow::anyhow!("cost-model state {}: {e}", path.display()))?;
                Calibrator::from_json(&v)
                    .map_err(|e| anyhow::anyhow!("cost-model state {}: {e}", path.display()))?
            }
        };
        let w = cal.solve();
        Ok(Self::Calibrated(Arc::new(CalibratedCost {
            min_obs,
            inner: Mutex::new(CalState { cal, w }),
        })))
    }

    /// Persist the accumulated calibration for the next run's warm start.
    /// No-op on `Tokens` (there is nothing to save).
    pub fn save_state(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Self::Calibrated(c) = self {
            let st = c.inner.lock().expect("cost model lock");
            std::fs::write(path, st.cal.to_json().to_string_pretty())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;

    #[test]
    fn tokens_model_is_the_exact_identity() {
        let m = CostModel::Tokens;
        for base in [0usize, 1, 17, 4096, 1_000_000] {
            assert_eq!(m.price(&[base as f64, 3.0, 1.0, 1.0], base), base);
        }
        assert!(!m.active());
        m.observe(&[1.0, 1.0, 1.0, 1.0], 5.0); // no-op
        assert_eq!(m.n_obs(), 0);
    }

    #[test]
    fn calibrated_prices_like_tokens_below_min_obs() {
        let m = CostModel::calibrated(8);
        assert!(!m.active());
        for i in 0..7u64 {
            m.observe(&[100.0 + i as f64, 10.0, 1.0, 1.0], 1.0 + i as f64);
            assert!(!m.active(), "obs {i}: below min_obs must stay inactive");
            assert_eq!(m.price(&[500.0, 10.0, 1.0, 1.0], 500), 500);
        }
    }

    #[test]
    fn calibrator_recovers_a_synthetic_linear_law() {
        // wall = 0.004*tokens + 0.01*depth + 2.5*calls + 0.5
        let truth = [0.004, 0.01, 2.5, 0.5];
        let mut cal = Calibrator::default();
        let mut rng = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            (rng >> 11) as f64 / (1u64 << 53) as f64
        };
        for _ in 0..64 {
            let x = [
                200.0 + 4000.0 * next(),
                20.0 + 300.0 * next(),
                1.0 + (4.0 * next()).floor(),
                1.0,
            ];
            let y: f64 = truth.iter().zip(&x).map(|(a, b)| a * b).sum();
            cal.observe(&x, y);
        }
        let w = cal.solve().expect("well-conditioned system must solve");
        // the relative ridge (1e-8) shrinks weights by roughly the
        // condition number x 1e-8 (~1e-6 here); 1e-4 leaves two orders of
        // margin while still pinning all four weights tightly
        for (wi, ti) in w.iter().zip(&truth) {
            assert!(
                (wi - ti).abs() < 1e-4 * (1.0 + ti.abs()),
                "recovered {w:?}, expected {truth:?}"
            );
        }
    }

    #[test]
    fn calibrated_model_predicts_after_min_obs() {
        // wall = 0.001*tokens (pure token-proportional): predictions must
        // order trees exactly like the token base once active
        let m = CostModel::calibrated(4);
        for i in 1..=6u64 {
            let tokens = 1000.0 * i as f64;
            m.observe(&[tokens, 50.0 * i as f64, 1.0, 1.0], 0.001 * tokens);
        }
        assert!(m.active());
        let small = m.price(&[1000.0, 50.0, 1.0, 1.0], 7);
        let large = m.price(&[4000.0, 200.0, 1.0, 1.0], 7);
        assert!(large > small, "prices must track the law: {small} vs {large}");
        // 0.001*1000 ms = 1 ms = 1000 µs
        assert!((small as i64 - 1000).abs() <= 2, "1 ms ≈ 1000 µs, got {small}");
    }

    #[test]
    fn singular_systems_fall_back_to_the_base() {
        // every observation identical: tokens/depth/calls are collinear
        // with the bias up to scale, yet ridge keeps the solve finite —
        // and if it ever went singular, price() must return base
        let m = CostModel::calibrated(2);
        for _ in 0..4 {
            m.observe(&[0.0, 0.0, 0.0, 0.0], 0.0);
        }
        // all-zero features: XᵀX is the zero matrix, solve must refuse
        assert_eq!(m.price(&[100.0, 1.0, 1.0, 1.0], 42), 42);
    }

    #[test]
    fn calibrator_state_roundtrips_bit_exactly() {
        let mut cal = Calibrator::default();
        for i in 0..16 {
            let x = [100.0 + 7.13 * i as f64, 10.0 + 0.37 * i as f64, 1.0 + (i % 3) as f64, 1.0];
            cal.observe(&x, 0.004 * x[0] + 0.01 * x[1] + 2.5 * x[2] + 0.5);
        }
        let restored = Calibrator::from_json(&Json::parse(&cal.to_json().to_string()).unwrap())
            .expect("state parses back");
        assert_eq!(restored.n, cal.n);
        // exact f64 round-trip, so the restored solve is bit-identical
        assert_eq!(restored.xtx, cal.xtx);
        assert_eq!(restored.xty, cal.xty);
        assert_eq!(restored.solve(), cal.solve());
    }

    #[test]
    fn saved_state_warm_starts_a_new_model() {
        let dir = std::env::temp_dir().join(format!("tt-cost-state-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cost_model.json");
        // run 1: learn past min_obs, save
        let m1 = CostModel::calibrated(4);
        for i in 1..=6u64 {
            let tokens = 1000.0 * i as f64;
            m1.observe(&[tokens, 50.0 * i as f64, 1.0, 1.0], 0.001 * tokens);
        }
        assert!(m1.active());
        m1.save_state(&path).unwrap();
        // run 2: warm-started model predicts from step 0 and prices
        // identically to the model that learned live
        let m2 = CostModel::calibrated_from_state(4, &path).unwrap();
        assert!(m2.active(), "warm start must carry the observation count");
        assert_eq!(m2.n_obs(), 6);
        let feats = [2500.0, 125.0, 1.0, 1.0];
        assert_eq!(m2.price(&feats, 7), m1.price(&feats, 7));
        // and keeps accumulating on top of the restored equations
        m2.observe(&[7000.0, 350.0, 1.0, 1.0], 7.0);
        assert_eq!(m2.n_obs(), 7);
        // a missing state file is a cold start, not an error
        let m3 = CostModel::calibrated_from_state(4, &dir.join("absent.json")).unwrap();
        assert!(!m3.active());
        assert_eq!(m3.n_obs(), 0);
        // garbage state is a hard error (never silently re-learn)
        std::fs::write(&path, "not json").unwrap();
        assert!(CostModel::calibrated_from_state(4, &path).is_err());
        // Tokens has no state: save is a no-op that creates nothing
        let none = dir.join("tokens.json");
        CostModel::Tokens.save_state(&none).unwrap();
        assert!(!none.exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn features_are_additive_and_depth_is_the_longest_path() {
        let t = gen::uniform(11, 9, 5, 0.6);
        let f = tree_features(&t, t.n_tree(), 4096);
        assert_eq!(f[0], t.n_tree() as f64);
        let max_path = t
            .paths()
            .iter()
            .map(|p| p.iter().map(|&n| t.nodes[n].real_len()).sum::<usize>())
            .max()
            .unwrap();
        assert_eq!(f[1], max_path as f64, "depth = deepest root-to-leaf real tokens");
        assert_eq!(f[2], 1.0, "tree under capacity is one call");
        assert_eq!(f[3], 1.0, "bias feature");
        let g = tree_features(&t, t.n_tree(), 10);
        assert!(g[2] >= 2.0, "tiny capacity means multiple estimated calls");
    }
}
