//! Cross-tree Forest Packing (§3.3–3.4 generalized to the global batch).
//!
//! A packed device batch is a *prefix forest*: the tree-attention interval
//! test `(k_order[j] <= i) && (k_exit[j] >= q_exit[i])` is evaluated on
//! host-provided metadata, so concatenating several DFS-serialized trees at
//! slot offsets yields a block-diagonal mask with **zero** cross-tree
//! leakage — exactly the mechanism the sep-avg baseline already used for
//! packed chains ("a sequence is a special case of a prefix tree", §2), now
//! applied to whole trees and to partition specs:
//!
//! * [`pack_forest`] — first-fit-decreasing packs whole small trees into
//!   capacity-`C` `step` batches.  One program call trains several trees;
//!   the call count per global batch drops by roughly the packing factor.
//! * [`schedule_partition_calls`] — packs partition specs (possibly from
//!   different trees) into shared `part_fwd`/`part_bwd` calls.  Gateway
//!   isolation needs no new program export: a packed member occupying query
//!   slots `[o, o+n)` gets its gateway rows published with `k_order = o`
//!   (blocks every earlier member: `k_order > i`) and `k_exit = o + n`
//!   (blocks every later member: `k_exit < q_exit`), while staying visible
//!   to its own member exactly like the seed's `-1 / PAST_EXIT` sentinels.
//!
//! Packing trades host gateway-KV peak memory for program-call count: the
//! level-ordered packed schedule can hold one KV cache per in-flight call,
//! whereas the unpacked per-tree topological order retains the §3.3
//! one-root-to-leaf-chain bound.  Both schedules are produced here; the
//! trainer picks per its `forest_packing` flag.

use crate::trainer::batch::{Batch, BatchOptions};
use crate::tree::dfs::{self, DfsMeta, NEG_INF};

use super::plan::Plan;

// ───────────────────────── rank-aware tree sharding ───────────────────────

/// Deterministic assignment of whole trees to data-parallel ranks
/// (§3.4: a tree never splits across ranks), produced by [`shard_by_cost`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankShards {
    /// Item indices per rank, each rank's list in ascending input order —
    /// so a 1-rank shard is the identity and per-rank planning sees trees
    /// in exactly the order the unsharded planner would.
    pub ranks: Vec<Vec<usize>>,
    /// Summed cost per rank (the LPT load).
    pub loads: Vec<usize>,
}

impl RankShards {
    pub fn n_ranks(&self) -> usize {
        self.ranks.len()
    }

    /// Load-imbalance ratio: max rank load over mean rank load (`>= 1.0`;
    /// `1.0` = perfectly balanced).  An empty batch reports `1.0`.
    pub fn imbalance(&self) -> f64 {
        load_imbalance(&self.loads)
    }
}

/// Max-over-mean load ratio of a rank-load vector (`>= 1.0`; `1.0` =
/// perfectly balanced, also the zero-total convention).  The one imbalance
/// definition shared by [`RankShards`], the planner's sharded plans and the
/// metrics CSV.
pub fn load_imbalance(loads: &[usize]) -> f64 {
    let total: usize = loads.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / loads.len() as f64;
    *loads.iter().max().unwrap() as f64 / mean
}

/// LPT (longest-processing-time) shard items across `n_ranks` by cost:
/// items in decreasing cost order each go to the currently least-loaded
/// rank.  Tie-breaking is fully deterministic — equal costs keep input
/// order (stable sort), equal loads pick the lowest rank id — so sharded
/// plans are reproducible run-to-run and machine-to-machine.
///
/// Used for whole-tree data-parallel sharding (cost = packed post-reuse
/// token count) and by [`crate::distsim`] as the one cluster sharder.
pub fn shard_by_cost(costs: &[usize], n_ranks: usize) -> crate::Result<RankShards> {
    anyhow::ensure!(n_ranks >= 1, "shard_by_cost needs n_ranks >= 1");
    let mut order: Vec<usize> = (0..costs.len()).collect();
    // stable: equal-cost items stay in input order
    order.sort_by_key(|&i| std::cmp::Reverse(costs[i]));
    let mut ranks: Vec<Vec<usize>> = vec![Vec::new(); n_ranks];
    let mut loads = vec![0usize; n_ranks];
    for &i in &order {
        // min_by_key returns the first minimum: lowest rank id wins ties
        let r = (0..n_ranks).min_by_key(|&r| loads[r]).unwrap();
        loads[r] += costs[i];
        ranks[r].push(i);
    }
    for r in &mut ranks {
        r.sort_unstable(); // restore input order within the rank
    }
    Ok(RankShards { ranks, loads })
}

// ───────────────────────── whole-tree forest packing ──────────────────────

/// One packed tree inside a [`ForestBatch`].
#[derive(Debug, Clone)]
pub struct ForestMember {
    /// Index into the meta list handed to [`pack_forest`] / [`concat_metas`].
    pub source: usize,
    /// First slot of this member's region in the packed batch.
    pub slot_offset: usize,
    /// Region length (= the member meta's size).
    pub len: usize,
    /// Shared root-chain prefix length in slots (0 = no cross-tree sharing).
    /// Stamped by [`super::affinity::annotate_members`] after packing; the
    /// engine-level activation cache keys its lookups on this region.
    pub prefix_len: usize,
    /// FNV-1a fingerprint of the shared prefix triples (0 when unshared).
    pub prefix_sig: u64,
}

/// A packed prefix-forest `step` batch and its member layout.
#[derive(Debug, Clone)]
pub struct ForestBatch {
    pub members: Vec<ForestMember>,
    pub batch: Batch,
}

impl ForestBatch {
    /// Real (non-pad) tokens across members — the §4.1 unique-token count.
    pub fn real_tokens(&self, metas: &[DfsMeta]) -> usize {
        self.members
            .iter()
            .map(|m| metas[m.source].pad_mask.iter().filter(|&&p| !p).count())
            .sum()
    }
}

/// Concatenate tree metas into one forest batch (offsets applied), padding
/// the tail to `capacity` with inert self-island slots.  The baseline's
/// chain packing is the special case where every meta is a chain.
pub fn concat_metas(
    metas: &[DfsMeta],
    ids: &[usize],
    capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<ForestBatch> {
    let hybrid = opts.chunk_size.is_some();
    let chunk = opts.chunk_size.unwrap_or(1);
    let kconv = opts.conv_kernel.unwrap_or(0);
    anyhow::ensure!(
        !hybrid || capacity % chunk == 0,
        "capacity {capacity} not chunk-aligned ({chunk})"
    );
    let mut b = Batch {
        capacity,
        past_len: 0,
        tokens: Vec::with_capacity(capacity),
        prev_idx: Vec::with_capacity(capacity),
        pos_ids: Vec::with_capacity(capacity),
        weights: Vec::with_capacity(capacity),
        q_exit: Vec::with_capacity(capacity),
        k_order: (0..capacity as i32).collect(),
        k_exit: Vec::new(),
        k_bias: vec![0.0; capacity],
        chunk_parent_map: Vec::new(),
        ssm_pad: Vec::new(),
        conv_idx: Vec::new(),
    };
    let mut members = Vec::with_capacity(ids.len());
    for &i in ids {
        let m = &metas[i];
        let o = b.tokens.len() as i32;
        members.push(ForestMember {
            source: i,
            slot_offset: o as usize,
            len: m.size(),
            prefix_len: 0,
            prefix_sig: 0,
        });
        b.tokens.extend(&m.tokens);
        b.pos_ids.extend(&m.pos_ids);
        b.weights.extend(&m.weights);
        b.q_exit.extend(m.subtree_exit.iter().map(|&e| e + o));
        let prev = dfs::prev_indices(m);
        b.prev_idx.extend(prev.iter().map(|&p| if p < 0 { -1 } else { p + o }));
        if hybrid {
            anyhow::ensure!(
                m.size() % chunk == 0,
                "member of {} slots not chunk-aligned ({chunk}); pad_for_chunks first",
                m.size()
            );
            let chunk_off = (o as usize / chunk) as i32;
            let cpm = dfs::chunk_parent_map(m, chunk)?;
            b.chunk_parent_map
                .extend(cpm.iter().map(|&p| if p < 0 { -1 } else { p + chunk_off }));
            b.ssm_pad.extend(m.pad_mask.iter().map(|&x| if x { 1.0 } else { 0.0 }));
        }
        if kconv > 0 {
            let idx = dfs::conv_gather_indices(m, kconv, false);
            // token refs (>= base) shift by the pack offset; zero row stays
            b.conv_idx.extend(idx.iter().map(|&x| if x >= kconv as i32 { x + o } else { x }));
        }
    }
    // pad to capacity: self-islands, zero weight
    let s = b.tokens.len();
    anyhow::ensure!(s <= capacity, "packing overflow: {s} slots > capacity {capacity}");
    for t in s..capacity {
        b.tokens.push(0);
        b.pos_ids.push(0);
        b.weights.push(0.0);
        b.q_exit.push((t + 1) as i32);
        b.prev_idx.push(-1);
        if hybrid {
            b.ssm_pad.push(1.0);
        }
        if kconv > 0 {
            let mut row = vec![0i32; kconv];
            row[kconv - 1] = kconv as i32 + t as i32;
            b.conv_idx.extend(row);
        }
    }
    if hybrid {
        // pad chunks chain among themselves, isolated from every member
        for i in s / chunk..capacity / chunk {
            b.chunk_parent_map.push(if i == s / chunk { -1 } else { i as i32 - 1 });
        }
    }
    b.k_exit = b.q_exit.clone();
    Ok(ForestBatch { members, batch: b })
}

/// First-fit-decreasing packing of tree metas into capacity-`C` forest
/// batches.  Every meta must fit the capacity on its own (oversized trees
/// take the partition path instead).
pub fn pack_forest(
    metas: &[DfsMeta],
    capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<Vec<ForestBatch>> {
    let sizes: Vec<usize> = metas.iter().map(|m| m.size()).collect();
    pack_forest_by_cost(metas, &sizes, capacity, opts)
}

/// [`pack_forest`] with an explicit per-meta *cost* ordering: metas are
/// visited in decreasing `costs[i]` (stable — equal costs keep input
/// order), while bin feasibility is still checked on slot size (capacity
/// is a hard device constraint; cost only orders the fit attempts).
/// `costs[i] = metas[i].size()` reproduces [`pack_forest`] exactly; a
/// calibrated [`crate::partition::cost::CostModel`] supplies predicted
/// walls instead, so the trees that dominate measured wall-clock seed the
/// bins first (the FFD quality guarantee follows the ordering metric).
pub fn pack_forest_by_cost(
    metas: &[DfsMeta],
    costs: &[usize],
    capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<Vec<ForestBatch>> {
    anyhow::ensure!(
        costs.len() == metas.len(),
        "pack_forest_by_cost: {} costs for {} metas",
        costs.len(),
        metas.len()
    );
    let mut order: Vec<usize> = (0..metas.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(costs[i]));
    let mut bins: Vec<(usize, Vec<usize>)> = Vec::new(); // (used slots, meta ids)
    for &i in &order {
        let s = metas[i].size();
        anyhow::ensure!(
            s <= capacity,
            "tree of {s} slots exceeds capacity {capacity}; partition it instead"
        );
        match bins.iter_mut().find(|b| b.0 + s <= capacity) {
            Some(b) => {
                b.0 += s;
                b.1.push(i);
            }
            None => bins.push((s, vec![i])),
        }
    }
    bins.iter().map(|(_, ids)| concat_metas(metas, ids, capacity, opts)).collect()
}

// ──────────────────── cross-tree partition-call packing ───────────────────

/// One partition spec packed into a [`PartCall`].
#[derive(Debug, Clone)]
pub struct PackedMember {
    /// Index into the plan list (one plan per oversized tree).
    pub tree: usize,
    /// Partition index within that plan.
    pub part: usize,
    /// First query slot of this member's region.
    pub slot_offset: usize,
    /// Region length: partition meta size + virtual boundary slots.
    pub slots: usize,
    /// First gateway row assigned to this member in the shared past block.
    pub gw_offset: usize,
    /// Gateway rows (= the partition's ancestor slots).
    pub gw_rows: usize,
}

/// One `part_fwd`/`part_bwd` program call over packed partition specs.
#[derive(Debug, Clone)]
pub struct PartCall {
    pub members: Vec<PackedMember>,
    /// False when no member partition has children: its KV is never read,
    /// so the forward program call is skipped entirely (§3.3 leaf rule).
    pub needs_fwd: bool,
}

/// Level-ordered schedule of packed partition calls over many trees.
#[derive(Debug, Clone)]
pub struct RelaySchedule {
    pub calls: Vec<PartCall>,
    /// `(tree, part)` -> `(call index, slot offset)`.
    pub location: Vec<Vec<(usize, usize)>>,
}

impl RelaySchedule {
    pub fn n_calls(&self) -> usize {
        self.calls.len()
    }

    /// Program invocations this schedule will execute (fwd where needed +
    /// one bwd per call) — the packing metric reported by the benches.
    pub fn program_calls(&self) -> usize {
        self.calls.len() + self.calls.iter().filter(|c| c.needs_fwd).count()
    }
}

/// Pack partition specs from `plans` into shared calls.
///
/// Dependencies are respected by *level*: a partition at gateway depth `d`
/// reads KV only from partitions at depths `< d`, so calls are grouped
/// level-by-level (FFD within a level, under both the slot capacity and the
/// shared gateway-row capacity).  With `pack = false` the schedule degrades
/// to one call per partition in per-tree topological order — the seed
/// behavior, preserving the §3.3 peak-memory bound.
pub fn schedule_partition_calls(
    plans: &[Plan],
    capacity: usize,
    past_capacity: usize,
    pack: bool,
) -> crate::Result<RelaySchedule> {
    let has_child: Vec<Vec<bool>> = plans
        .iter()
        .map(|pl| {
            let mut h = vec![false; pl.parts.len()];
            for p in &pl.parts {
                if p.parent_part >= 0 {
                    h[p.parent_part as usize] = true;
                }
            }
            h
        })
        .collect();
    for (ti, pl) in plans.iter().enumerate() {
        for (pi, p) in pl.parts.iter().enumerate() {
            anyhow::ensure!(
                p.needed_slots() <= capacity,
                "tree {ti} partition {pi}: {} slots > capacity {capacity}",
                p.needed_slots()
            );
            anyhow::ensure!(
                p.anc_slots.len() <= past_capacity,
                "tree {ti} partition {pi}: {} gateway rows > capacity {past_capacity}",
                p.anc_slots.len()
            );
        }
    }

    let mut location: Vec<Vec<(usize, usize)>> =
        plans.iter().map(|pl| vec![(usize::MAX, usize::MAX); pl.parts.len()]).collect();
    let mut calls: Vec<PartCall> = Vec::new();

    let push_call = |members: Vec<(usize, usize)>,
                         calls: &mut Vec<PartCall>,
                         location: &mut Vec<Vec<(usize, usize)>>| {
        let mut slot = 0usize;
        let mut gw = 0usize;
        let mut packed = Vec::with_capacity(members.len());
        let mut needs_fwd = false;
        for (ti, pi) in members {
            let p = &plans[ti].parts[pi];
            let slots = p.needed_slots();
            let rows = p.anc_slots.len();
            location[ti][pi] = (calls.len(), slot);
            needs_fwd |= has_child[ti][pi];
            packed.push(PackedMember {
                tree: ti,
                part: pi,
                slot_offset: slot,
                slots,
                gw_offset: gw,
                gw_rows: rows,
            });
            slot += slots;
            gw += rows;
        }
        calls.push(PartCall { members: packed, needs_fwd });
    };

    if !pack {
        // seed-compatible: one call per partition, per-tree topological order
        for (ti, pl) in plans.iter().enumerate() {
            for &pi in &pl.topo {
                push_call(vec![(ti, pi)], &mut calls, &mut location);
            }
        }
        return Ok(RelaySchedule { calls, location });
    }

    // gateway depth per partition (parents have strictly smaller depth)
    let mut level: Vec<Vec<usize>> = plans.iter().map(|pl| vec![0; pl.parts.len()]).collect();
    let mut max_level = 0usize;
    for (ti, pl) in plans.iter().enumerate() {
        for &pi in &pl.topo {
            let lp = pl.parts[pi].parent_part;
            level[ti][pi] = if lp < 0 { 0 } else { level[ti][lp as usize] + 1 };
            max_level = max_level.max(level[ti][pi]);
        }
    }
    for l in 0..=max_level {
        let mut items: Vec<(usize, usize)> = Vec::new();
        for (ti, pl) in plans.iter().enumerate() {
            for pi in 0..pl.parts.len() {
                if level[ti][pi] == l {
                    items.push((ti, pi));
                }
            }
        }
        items.sort_by_key(|&(ti, pi)| std::cmp::Reverse(plans[ti].parts[pi].needed_slots()));
        // FFD bins under (slot, gateway-row) capacities
        let mut bins: Vec<(usize, usize, Vec<(usize, usize)>)> = Vec::new();
        for (ti, pi) in items {
            let s = plans[ti].parts[pi].needed_slots();
            let g = plans[ti].parts[pi].anc_slots.len();
            match bins
                .iter_mut()
                .find(|b| b.0 + s <= capacity && b.1 + g <= past_capacity)
            {
                Some(b) => {
                    b.0 += s;
                    b.1 += g;
                    b.2.push((ti, pi));
                }
                None => bins.push((s, g, vec![(ti, pi)])),
            }
        }
        for (_, _, ids) in bins {
            push_call(ids, &mut calls, &mut location);
        }
    }
    Ok(RelaySchedule { calls, location })
}

/// Build the padded model batch for one packed partition call.
///
/// Mirrors `Plan::partition_batch` member-by-member at slot offsets, with
/// the shared gateway block published per member region (module docs):
/// row of a member at `[o, o+n)` gets `k_order = o`, `k_exit = o + n`,
/// bias 0; unused rows are fully inert (`k_order = i32::MAX`, bias `-inf`).
pub fn packed_partition_batch(
    plans: &[Plan],
    call: &PartCall,
    capacity: usize,
    past_capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<Batch> {
    anyhow::ensure!(
        opts.chunk_size.is_none() && opts.conv_kernel.is_none(),
        "partitioned hybrid models are not exported (DESIGN.md §2)"
    );
    let used_slots: usize = call.members.iter().map(|m| m.slots).sum();
    let used_rows: usize = call.members.iter().map(|m| m.gw_rows).sum();
    anyhow::ensure!(
        used_slots <= capacity,
        "packed call needs {used_slots} slots > capacity {capacity}"
    );
    anyhow::ensure!(
        used_rows <= past_capacity,
        "packed call needs {used_rows} gateway rows > capacity {past_capacity}"
    );

    // inert defaults; member regions overwrite their ranges
    let mut tokens = vec![0i32; capacity];
    let mut prev_idx = vec![-1i32; capacity];
    let mut pos_ids = vec![0i32; capacity];
    let mut weights = vec![0.0f32; capacity];
    let mut q_exit: Vec<i32> = (0..capacity as i32).map(|t| t + 1).collect();

    // shared gateway block
    let mut gw_order = vec![i32::MAX; past_capacity];
    let mut gw_exit = vec![0i32; past_capacity];
    let mut gw_bias = vec![NEG_INF; past_capacity];

    for m in &call.members {
        let p = &plans[m.tree].parts[m.part];
        let meta = &p.meta;
        let s = meta.size();
        let o = m.slot_offset;
        anyhow::ensure!(s + p.virtuals.len() == m.slots, "member slot accounting mismatch");
        tokens[o..o + s].copy_from_slice(&meta.tokens);
        weights[o..o + s].copy_from_slice(&p.weights);
        for (t, &e) in meta.subtree_exit.iter().enumerate() {
            q_exit[o + t] = e + o as i32;
        }
        let prev = dfs::prev_indices(meta);
        for (t, &pv) in prev.iter().enumerate() {
            prev_idx[o + t] = if pv < 0 { -1 } else { pv + o as i32 };
        }
        // Eq. 17 depth-based global positions (pads included, like
        // partition_batch's offset over the first `s` slots)
        for (t, &pos) in meta.pos_ids.iter().enumerate() {
            pos_ids[o + t] = pos + p.pos_offset;
        }
        for (j, &(prev_slot, tok, w)) in p.virtuals.iter().enumerate() {
            let slot = o + s + j;
            tokens[slot] = tok;
            prev_idx[slot] = (o + prev_slot) as i32;
            weights[slot] = w;
            // q_exit stays the inert self-island default
        }
        for r in 0..m.gw_rows {
            gw_order[m.gw_offset + r] = o as i32;
            gw_exit[m.gw_offset + r] = (o + m.slots) as i32;
            gw_bias[m.gw_offset + r] = 0.0;
        }
    }

    let mut k_order = gw_order;
    k_order.extend(0..capacity as i32);
    let mut k_exit = gw_exit;
    k_exit.extend(&q_exit);
    let mut k_bias = gw_bias;
    k_bias.extend(std::iter::repeat(0.0f32).take(capacity));

    Ok(Batch {
        capacity,
        past_len: past_capacity,
        tokens,
        prev_idx,
        pos_ids,
        weights,
        q_exit,
        k_order,
        k_exit,
        k_bias,
        chunk_parent_map: Vec::new(),
        ssm_pad: Vec::new(),
        conv_idx: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{greedy_pack, plan};
    use crate::tree::{gen, serialize};

    fn metas(n: usize) -> Vec<DfsMeta> {
        (0..n as u64).map(|s| serialize(&gen::uniform(s, 10, 5, 0.6))).collect()
    }

    #[test]
    fn shard_single_rank_is_identity_order() {
        let costs = [30usize, 7, 19, 19, 2];
        let s = shard_by_cost(&costs, 1).unwrap();
        assert_eq!(s.ranks, vec![vec![0, 1, 2, 3, 4]]);
        assert_eq!(s.loads, vec![77]);
        assert_eq!(s.imbalance(), 1.0);
    }

    #[test]
    fn shard_covers_every_item_exactly_once() {
        let costs: Vec<usize> = (0..23).map(|i| (i * 37) % 11 + 1).collect();
        let s = shard_by_cost(&costs, 4).unwrap();
        let mut seen: Vec<usize> = s.ranks.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..costs.len()).collect::<Vec<_>>());
        for (r, ids) in s.ranks.iter().enumerate() {
            assert_eq!(s.loads[r], ids.iter().map(|&i| costs[i]).sum::<usize>());
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "rank {r} not input-ordered");
        }
        assert!(s.imbalance() >= 1.0);
    }

    #[test]
    fn shard_is_deterministic_on_adversarial_costs() {
        // duplicate-size, zero-token and all-identical items exercise every
        // tie-break: the assignment must be bit-identical across calls
        for costs in [
            vec![5usize, 5, 5, 5, 5, 5, 5],          // all identical
            vec![0, 0, 0, 0],                        // zero-token trees
            vec![9, 3, 9, 0, 3, 9, 0, 3],            // duplicates + zeros
        ] {
            let a = shard_by_cost(&costs, 3).unwrap();
            let b = shard_by_cost(&costs, 3).unwrap();
            assert_eq!(a, b, "sharding of {costs:?} must be reproducible");
        }
        // all-zero costs: every placement sees equal (zero) loads, so the
        // lowest-rank-id tie-break sends them all to rank 0 — degenerate
        // but deterministic, which is the contract
        let z = shard_by_cost(&[0, 0, 0, 0], 3).unwrap();
        assert_eq!(z.ranks, vec![vec![0, 1, 2, 3], vec![], vec![]]);
        assert_eq!(z.imbalance(), 1.0); // zero total defines balanced
    }

    #[test]
    fn shard_lpt_balances_against_one_giant() {
        // the distsim regression: 4 ranks, one 400-token tree + 4 x 100
        let s = shard_by_cost(&[100, 100, 100, 100, 400], 4).unwrap();
        assert_eq!(*s.loads.iter().max().unwrap(), 400);
        assert_eq!(s.loads.iter().sum::<usize>(), 800);
    }

    #[test]
    fn shard_more_ranks_than_trees_leaves_empty_ranks() {
        let s = shard_by_cost(&[10, 20], 4).unwrap();
        assert_eq!(s.ranks.iter().filter(|r| r.is_empty()).count(), 2);
        assert_eq!(s.loads.iter().filter(|&&l| l == 0).count(), 2);
    }

    #[test]
    fn forest_packs_multiple_trees_per_batch() {
        let ms = metas(6);
        let max = ms.iter().map(|m| m.size()).max().unwrap();
        let cap = 3 * max;
        let batches = pack_forest(&ms, cap, &BatchOptions::default()).unwrap();
        assert!(batches.len() < ms.len(), "packing must reduce call count");
        assert!(batches.iter().any(|b| b.members.len() >= 2));
        // every tree appears exactly once
        let mut seen: Vec<usize> =
            batches.iter().flat_map(|b| b.members.iter().map(|m| m.source)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..ms.len()).collect::<Vec<_>>());
    }

    #[test]
    fn cost_ordered_packing_with_sizes_is_the_default_packing() {
        let ms = metas(6);
        let cap = 3 * ms.iter().map(|m| m.size()).max().unwrap();
        let sizes: Vec<usize> = ms.iter().map(|m| m.size()).collect();
        let a = pack_forest(&ms, cap, &BatchOptions::default()).unwrap();
        let b = pack_forest_by_cost(&ms, &sizes, cap, &BatchOptions::default()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.batch, y.batch, "size costs must reproduce pack_forest exactly");
        }
    }

    #[test]
    fn cost_ordered_packing_reorders_by_cost_not_size() {
        let ms = metas(6);
        let cap = 3 * ms.iter().map(|m| m.size()).max().unwrap();
        // reversed costs: the smallest tree is now the most expensive
        let mut costs: Vec<usize> = ms.iter().map(|m| m.size()).collect();
        costs.reverse();
        let packed = pack_forest_by_cost(&ms, &costs, cap, &BatchOptions::default()).unwrap();
        // still a complete, capacity-respecting packing of every tree
        let mut seen: Vec<usize> =
            packed.iter().flat_map(|b| b.members.iter().map(|m| m.source)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..ms.len()).collect::<Vec<_>>());
        for b in &packed {
            assert!(b.members.iter().map(|m| m.len).sum::<usize>() <= cap);
        }
        // and the highest-cost meta seeds the first bin
        let max_cost = (0..ms.len()).max_by_key(|&i| (costs[i], ms.len() - i)).unwrap();
        assert_eq!(packed[0].members[0].source, max_cost);
    }

    #[test]
    fn cost_length_mismatch_is_an_error() {
        let ms = metas(3);
        let err = pack_forest_by_cost(&ms, &[1, 2], 4096, &BatchOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("costs"), "got: {err}");
    }

    #[test]
    fn forest_mask_is_block_diagonal() {
        let ms = metas(3);
        let cap: usize = ms.iter().map(|m| m.size()).sum::<usize>() + 5;
        let fb = concat_metas(&ms, &[0, 1, 2], cap, &BatchOptions::default()).unwrap();
        let mask = crate::masks::dense_mask(&fb.batch.q_exit);
        let region_of = |t: usize| {
            fb.members
                .iter()
                .position(|m| t >= m.slot_offset && t < m.slot_offset + m.len)
        };
        for i in 0..cap {
            for j in 0..=i {
                if mask[i][j] && i != j {
                    assert_eq!(
                        region_of(i),
                        region_of(j),
                        "cross-member attention at ({i},{j})"
                    );
                    assert!(region_of(i).is_some(), "pad slot {i} attends {j}");
                }
            }
        }
        // within a member, the mask must equal the singleton mask
        for m in &fb.members {
            let single = crate::masks::dense_mask(&ms[m.source].subtree_exit);
            for i in 0..m.len {
                for j in 0..m.len {
                    assert_eq!(
                        mask[m.slot_offset + i][m.slot_offset + j],
                        single[i][j],
                        "member {} local ({i},{j})",
                        m.source
                    );
                }
            }
        }
    }

    #[test]
    fn forest_conserves_weights_and_tokens() {
        let ms = metas(5);
        let cap = 2 * ms.iter().map(|m| m.size()).max().unwrap();
        let batches = pack_forest(&ms, cap, &BatchOptions::default()).unwrap();
        let packed_w: f64 =
            batches.iter().flat_map(|b| b.batch.weights.iter()).map(|&w| w as f64).sum();
        let meta_w: f64 = ms.iter().flat_map(|m| m.weights.iter()).map(|&w| w as f64).sum();
        assert!((packed_w - meta_w).abs() < 1e-6);
        let real: usize = batches.iter().map(|b| b.real_tokens(&ms)).sum();
        let want: usize = ms.iter().map(|m| m.pad_mask.iter().filter(|&&p| !p).count()).sum();
        assert_eq!(real, want);
    }

    fn two_partitioned_trees() -> Vec<Plan> {
        (0..2u64)
            .map(|s| {
                let t = gen::uniform(s + 3, 12, 5, 0.7).split_long_segments(14);
                let assign = greedy_pack(&t, 16).unwrap();
                plan(&t, &assign).unwrap()
            })
            .collect()
    }

    #[test]
    fn packed_schedule_beats_singleton_call_count() {
        let plans = two_partitioned_trees();
        let n_parts: usize = plans.iter().map(|p| p.parts.len()).sum();
        if n_parts < 3 {
            return; // degenerate seed; other seeds cover it
        }
        let single = schedule_partition_calls(&plans, 64, 64, false).unwrap();
        let packed = schedule_partition_calls(&plans, 64, 64, true).unwrap();
        assert_eq!(single.n_calls(), n_parts);
        assert!(packed.n_calls() < single.n_calls(), "packing must merge calls");
        assert!(packed.program_calls() < single.program_calls());
        // every partition placed exactly once, with consistent offsets
        for (ti, pl) in plans.iter().enumerate() {
            for pi in 0..pl.parts.len() {
                let (ci, off) = packed.location[ti][pi];
                let m = packed.calls[ci]
                    .members
                    .iter()
                    .find(|m| m.tree == ti && m.part == pi)
                    .expect("member placed");
                assert_eq!(m.slot_offset, off);
            }
        }
    }

    #[test]
    fn packed_schedule_respects_dependencies() {
        let plans = two_partitioned_trees();
        let sched = schedule_partition_calls(&plans, 64, 64, true).unwrap();
        for (ci, call) in sched.calls.iter().enumerate() {
            for m in &call.members {
                let parent = plans[m.tree].parts[m.part].parent_part;
                if parent >= 0 {
                    let (pc, _) = sched.location[m.tree][parent as usize];
                    assert!(pc < ci, "parent call {pc} must precede child call {ci}");
                }
            }
        }
    }

    #[test]
    fn packed_gateway_rows_isolate_members() {
        let plans = two_partitioned_trees();
        let sched = schedule_partition_calls(&plans, 64, 64, true).unwrap();
        let Some(call) = sched.calls.iter().find(|c| {
            c.members.len() >= 2 && c.members.iter().any(|m| m.gw_rows > 0)
        }) else {
            return;
        };
        let b = packed_partition_batch(&plans, call, 64, 64, &BatchOptions::default()).unwrap();
        // mask[i][row]: gateway row visible to query i iff
        // k_order <= i && k_exit >= q_exit[i] (bias finite)
        for m in &call.members {
            for r in 0..m.gw_rows {
                let row = m.gw_offset + r;
                assert_eq!(b.k_bias[row], 0.0);
                for i in 0..b.capacity {
                    let visible = b.k_order[row] <= i as i32 && b.k_exit[row] >= b.q_exit[i];
                    let own = i >= m.slot_offset && i < m.slot_offset + m.slots;
                    assert_eq!(visible, own, "gateway row {row} vs query {i}");
                }
            }
        }
        // unused rows are blocked for every query
        let used: usize = call.members.iter().map(|m| m.gw_rows).sum();
        for row in used..64 {
            assert!(b.k_bias[row] < -1e29);
            for i in 0..b.capacity {
                assert!(b.k_order[row] > i as i32);
            }
        }
    }

    #[test]
    fn packed_batch_weights_match_plan() {
        let plans = two_partitioned_trees();
        let sched = schedule_partition_calls(&plans, 64, 64, true).unwrap();
        let mut packed_sum = 0.0f64;
        for call in &sched.calls {
            let b =
                packed_partition_batch(&plans, call, 64, 64, &BatchOptions::default()).unwrap();
            packed_sum += b.weights.iter().map(|&w| w as f64).sum::<f64>();
        }
        let mut plan_sum = 0.0f64;
        for pl in &plans {
            for p in &pl.parts {
                plan_sum += p.weights.iter().map(|&w| w as f64).sum::<f64>();
                plan_sum += p.virtuals.iter().map(|v| v.2 as f64).sum::<f64>();
            }
        }
        assert!((packed_sum - plan_sum).abs() < 1e-6);
    }
}
