//! Redundancy-Free Tree Partitioning (§3.3, Appendix B).
//!
//! When a tree exceeds the device token capacity `C`, it is cut into
//! *connected subtrees at node boundaries* — the only cut discipline under
//! which the partition dependency graph is itself a tree, bounding backward
//! peak memory by a single root-to-leaf path (§3.3 "Partitioning").
//!
//! * [`binpack`] — minimize the number of partitions subject to capacity
//!   (the paper uses OR-Tools; we ship a bottom-up greedy packer plus an
//!   exact branch-and-bound used to bound the greedy in tests).
//! * [`plan`] — turns an assignment into executable metadata: per-partition
//!   DFS serialization, full-tree loss weights, ancestor gateway slots,
//!   depth-based position offsets (Eq. 17) and virtual boundary targets.
//! * [`forest`] — cross-tree Forest Packing: FFD-packs whole small trees
//!   and partition specs from many trees into capacity-`C` prefix-forest
//!   device batches, so one program call trains several trees at once.
//!   Also home of [`forest::shard_by_cost`], the deterministic LPT sharder
//!   that places whole trees onto data-parallel ranks (§3.4) for both the
//!   training planner and the `distsim` cost model.
//! * [`cost`] — the per-tree execution-cost seam both orderings consume:
//!   the exact token-count default, or a least-squares model calibrated
//!   online from measured per-rank execute walls (`cost_model:
//!   "calibrated"`).
//! * [`affinity`] — the cross-tree prefix signature index (root-chain
//!   trie, `NodeSig`-style divergence discipline): prefix-affine FFD bins
//!   and group-local LPT sharding so trees sharing hot prefixes land in
//!   the same forest batch, same rank, adjacent steps — the schedule tier
//!   of cross-step prefix reuse (docs/prefix_reuse.md), behind the
//!   `prefix_affinity` knob (off = seed-exact plans).

pub mod affinity;
pub mod binpack;
pub mod cost;
pub mod forest;
pub mod plan;
pub mod validate;

pub use affinity::{prefix_sig, prefix_stream, AffineGroup, AffinityIndex, TreePrefix};
pub use binpack::{exact_min_partitions, greedy_pack};
pub use cost::{tree_features, Calibrator, CostModel};
pub use forest::{
    concat_metas, load_imbalance, pack_forest, pack_forest_by_cost, shard_by_cost, ForestBatch,
    RankShards, RelaySchedule,
};
pub use plan::{plan, PartitionSpec, Plan};
pub use validate::validate_assignment;
