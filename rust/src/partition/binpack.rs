//! Connected-subtree bin packing: minimize partitions under a token budget.
//!
//! Cost model per partition: its nodes' token slots (segments incl. chunk
//! pads) **plus one virtual boundary-target slot per outgoing cut** (the
//! parent-side loss terms for child-partition first tokens, plan.rs).
//!
//! [`greedy_pack`] is a bottom-up merge: at each node, children components
//! are merged smallest-first while the budget holds; the rest are cut.  This
//! maximizes merges locally (exchange argument) and is within one partition
//! of optimal on every tree we property-test; [`exact_min_partitions`]
//! (branch & bound over cut-edge subsets) provides the test oracle — our
//! stand-in for the paper's OR-Tools solver.

use crate::tree::TrajectoryTree;

/// Bottom-up greedy packing.  Returns a node -> partition assignment with
/// partition ids in pre-order of their roots.
pub fn greedy_pack(tree: &TrajectoryTree, capacity: usize) -> crate::Result<Vec<usize>> {
    let n = tree.nodes.len();
    let children = tree.children();
    for nd in &tree.nodes {
        anyhow::ensure!(
            nd.len() <= capacity,
            "node segment of {} slots exceeds capacity {capacity}; \
             split_long_segments first (leave headroom for boundary slots)",
            nd.len()
        );
    }

    // comp_size[c] = slots of the (packed) component rooted at c
    let mut comp_size = vec![0usize; n];
    let mut cut_edge = vec![false; n]; // cut_edge[c]: edge (parent(c), c) is cut
    // per-child merge marker: each node is some parent's child exactly once,
    // so one flat bool vec replaces the former O(fanout²) `Vec::contains`
    // scan (quadratic on wide-fanout trees, e.g. concurrent tool fanout)
    let mut is_merged = vec![false; n];
    for i in (0..n).rev() {
        let mut kids: Vec<usize> = children[i].clone();
        kids.sort_by_key(|&c| comp_size[c]);
        let mut size = tree.nodes[i].len();
        let mut n_merged = 0usize;
        for &c in &kids {
            // merging c costs comp_size[c]; cutting costs 1 virtual slot
            if size + comp_size[c] + (kids.len() - n_merged - 1) <= capacity {
                size += comp_size[c];
                is_merged[c] = true;
                n_merged += 1;
            }
        }
        for &c in &kids {
            if !is_merged[c] {
                cut_edge[c] = true;
                size += 1; // virtual boundary-target slot
            }
        }
        anyhow::ensure!(
            size <= capacity,
            "node {i}: segment + cut slots ({size}) exceed capacity {capacity}"
        );
        comp_size[i] = size;
    }
    Ok(assignment_from_cuts(tree, &cut_edge))
}

/// Partition assignment from a cut-edge indicator (ids in root pre-order).
pub fn assignment_from_cuts(tree: &TrajectoryTree, cut_edge: &[bool]) -> Vec<usize> {
    let n = tree.nodes.len();
    let mut assign = vec![usize::MAX; n];
    let mut next = 0usize;
    for i in 0..n {
        let p = tree.nodes[i].parent;
        if p < 0 || cut_edge[i] {
            assign[i] = next;
            next += 1;
        } else {
            assign[i] = assign[p as usize];
        }
    }
    assign
}

/// Slot usage per partition under the packing cost model.
pub fn partition_slots(tree: &TrajectoryTree, assignment: &[usize]) -> Vec<usize> {
    let n_parts = assignment.iter().copied().max().unwrap_or(0) + 1;
    let mut slots = vec![0usize; n_parts];
    for (i, nd) in tree.nodes.iter().enumerate() {
        slots[assignment[i]] += nd.len();
    }
    // virtual boundary slots: one per cut edge, charged to the parent side
    for (i, nd) in tree.nodes.iter().enumerate() {
        if nd.parent >= 0 {
            let p = assignment[nd.parent as usize];
            if p != assignment[i] {
                slots[p] += 1;
            }
        }
    }
    slots
}

/// Exact minimum partition count via branch & bound over cut-edge subsets.
/// Exponential — test oracle for small trees only.
pub fn exact_min_partitions(tree: &TrajectoryTree, capacity: usize) -> Option<usize> {
    let n = tree.nodes.len();
    let edges: Vec<usize> = (1..n).collect();
    let mut best: Option<usize> = None;
    let mut cut = vec![false; n];
    fn rec(
        tree: &TrajectoryTree,
        edges: &[usize],
        idx: usize,
        cut: &mut Vec<bool>,
        capacity: usize,
        best: &mut Option<usize>,
    ) {
        let n_cuts = cut.iter().filter(|&&c| c).count();
        if let Some(b) = *best {
            if n_cuts + 1 >= b {
                return; // bound: partitions = cuts + 1
            }
        }
        if idx == edges.len() {
            let assign = assignment_from_cuts(tree, cut);
            let slots = partition_slots(tree, &assign);
            if slots.iter().all(|&s| s <= capacity) {
                let parts = n_cuts + 1;
                if best.map_or(true, |b| parts < b) {
                    *best = Some(parts);
                }
            }
            return;
        }
        rec(tree, edges, idx + 1, cut, capacity, best);
        cut[edges[idx]] = true;
        rec(tree, edges, idx + 1, cut, capacity, best);
        cut[edges[idx]] = false;
    }
    rec(tree, &edges, 0, &mut cut, capacity, &mut best);
    best
}

/// Token accounting of *standard* tree partitioning (no differentiable
/// boundaries, Fig. 5 middle bar): every child partition re-includes its
/// ancestor path tokens, so boundary prefixes are recomputed.
pub fn standard_partition_tokens(tree: &TrajectoryTree, assignment: &[usize]) -> usize {
    let meta = crate::tree::serialize(tree);
    let n_parts = assignment.iter().copied().max().unwrap_or(0) + 1;
    let mut total = 0usize;
    for p in 0..n_parts {
        let members: Vec<usize> =
            (0..tree.nodes.len()).filter(|&i| assignment[i] == p).collect();
        let own: usize = members.iter().map(|&i| tree.nodes[i].real_len()).sum();
        // the partition root's ancestors get re-included (recomputed)
        let root = members
            .iter()
            .copied()
            .find(|&i| {
                tree.nodes[i].parent < 0
                    || assignment[tree.nodes[i].parent as usize] != p
            })
            .unwrap();
        let mut anc = 0usize;
        let mut j = tree.nodes[root].parent;
        while j >= 0 {
            anc += tree.nodes[j as usize].real_len();
            j = tree.nodes[j as usize].parent;
        }
        let _ = &meta;
        total += own + anc;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;

    #[test]
    fn greedy_respects_capacity() {
        for seed in 0..30 {
            let t = gen::uniform(seed, 16, 8, 0.6);
            let cap = 24;
            if let Ok(assign) = greedy_pack(&t, cap) {
                for (p, &s) in partition_slots(&t, &assign).iter().enumerate() {
                    assert!(s <= cap, "seed {seed}: partition {p} has {s} slots");
                }
                crate::partition::validate_assignment(&t, &assign).unwrap();
            }
        }
    }

    #[test]
    fn greedy_single_partition_when_fits() {
        let t = gen::uniform(0, 10, 4, 0.5);
        let assign = greedy_pack(&t, 10_000).unwrap();
        assert!(assign.iter().all(|&p| p == 0));
    }

    #[test]
    fn greedy_close_to_exact() {
        for seed in 0..15 {
            let t = gen::uniform(seed, 10, 6, 0.6);
            let cap = 20;
            let (greedy, exact) = match (greedy_pack(&t, cap), exact_min_partitions(&t, cap)) {
                (Ok(a), Some(e)) => {
                    (a.iter().copied().max().unwrap() + 1, e)
                }
                _ => continue,
            };
            assert!(greedy >= exact);
            assert!(
                greedy <= exact + 1,
                "seed {seed}: greedy {greedy} vs exact {exact}"
            );
        }
    }

    #[test]
    fn oversized_segment_rejected() {
        let t = crate::TrajectoryTree::new(vec![crate::NodeSpec::new(-1, vec![0; 100])]).unwrap();
        assert!(greedy_pack(&t, 50).is_err());
        // leave headroom for the virtual boundary slot of each cut
        let split = t.split_long_segments(45);
        let assign = greedy_pack(&split, 50).unwrap();
        for s in partition_slots(&split, &assign) {
            assert!(s <= 50);
        }
    }

    #[test]
    fn wide_fanout_packs_fast_and_valid() {
        // regression for the former O(fanout²) merged-membership scan: a
        // root with tens of thousands of children must pack in
        // linearithmic time and keep the capacity/connectivity invariants.
        // (capacity must exceed the fanout: each cut child charges one
        // virtual boundary slot to the parent partition.)
        let fanout = 50_000usize;
        let mut nodes = vec![crate::NodeSpec::new(-1, vec![0; 3])];
        for _ in 0..fanout {
            nodes.push(crate::NodeSpec::new(0, vec![1, 2]));
        }
        let t = crate::TrajectoryTree::new(nodes).unwrap();
        let cap = 60_000;
        let t0 = std::time::Instant::now();
        let assign = greedy_pack(&t, cap).unwrap();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "wide-fanout packing took {:?}",
            t0.elapsed()
        );
        for (p, &s) in partition_slots(&t, &assign).iter().enumerate() {
            assert!(s <= cap, "partition {p} has {s} slots");
        }
        crate::partition::validate_assignment(&t, &assign).unwrap();
        // the root merges what fits and cuts the rest into own partitions
        let n_parts = assign.iter().copied().max().unwrap() + 1;
        assert!(n_parts >= 2, "fanout beyond capacity must be cut: {n_parts}");
    }

    #[test]
    fn greedy_pack_deterministic_on_duplicate_size_children() {
        // every child component has the same size, so the smallest-first
        // merge order is decided purely by tie-breaking: it must be the
        // stable (input-order) one, identically on every call — sharded
        // plans re-partition trees per rank and must reproduce bit-for-bit
        let mut nodes = vec![crate::NodeSpec::new(-1, vec![0; 4])];
        for _ in 0..12 {
            nodes.push(crate::NodeSpec::new(0, vec![1; 5])); // 12 equal children
        }
        let t = crate::TrajectoryTree::new(nodes).unwrap();
        let a = greedy_pack(&t, 30).unwrap();
        for _ in 0..5 {
            assert_eq!(greedy_pack(&t, 30).unwrap(), a, "tie-break must be stable");
        }
        crate::partition::validate_assignment(&t, &a).unwrap();
        // merged set is the *first* children in input order: with stable
        // smallest-first ordering, ids 1..=k merge and the rest are cut
        let merged: Vec<usize> = (1..=12).filter(|&c| a[c] == a[0]).collect();
        assert_eq!(merged, (1..=merged.len()).collect::<Vec<_>>());
    }

    #[test]
    fn greedy_pack_deterministic_on_zero_token_nodes() {
        // zero-token segments (empty tool results, stripped messages) give
        // zero-size components — every merge decision is a tie
        let mut nodes = vec![crate::NodeSpec::new(-1, vec![0; 3])];
        for i in 0..6 {
            let parent = if i % 2 == 0 { 0 } else { i as i32 };
            nodes.push(crate::NodeSpec::new(parent, vec![]));
        }
        let t = crate::TrajectoryTree::new(nodes).unwrap();
        let a = greedy_pack(&t, 8).unwrap();
        assert_eq!(greedy_pack(&t, 8).unwrap(), a);
        crate::partition::validate_assignment(&t, &a).unwrap();
        for s in partition_slots(&t, &a) {
            assert!(s <= 8);
        }
    }

    #[test]
    fn greedy_pack_identical_trees_get_identical_assignments() {
        // all-trees-identical: structurally equal trees must partition
        // identically regardless of which rank (or call site) packs them
        let proto = gen::uniform(11, 14, 6, 0.6);
        let copy = crate::TrajectoryTree::new(proto.nodes.clone()).unwrap();
        let a = greedy_pack(&proto, 24).unwrap();
        let b = greedy_pack(&copy, 24).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn standard_partitioning_recomputes_boundaries() {
        // Fig. 5: standard partitioning pays ancestor recomputation;
        // redundancy-free pays exactly n_tree.
        let t = gen::with_target_por(1, 0.5, 4, 800, 16, 128);
        let assign = greedy_pack(&t, 300).unwrap();
        let n_parts = assign.iter().copied().max().unwrap() + 1;
        if n_parts > 1 {
            let std_tokens = standard_partition_tokens(&t, &assign);
            assert!(std_tokens > t.n_tree());
            assert!(std_tokens < t.n_flat());
        }
    }
}
