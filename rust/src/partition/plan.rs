//! Partition plan: assignment -> executable per-partition metadata.
//!
//! Mirrors `python/compile/partplan.py` (validated against it by the pytest
//! partition-equivalence suite before this port):
//!
//! * per-partition DFS serialization (a connected subtree is itself a tree);
//! * loss weights `lambda_t` sliced from the **full** tree (a partition does
//!   not know K or g on its own);
//! * ancestor gateway slots: full-DFS indices of the partition root's path
//!   tokens — the child attends these via the gateway KV (compacted form of
//!   Eq. 16's ancestor filter, DESIGN.md §2);
//! * depth-based position offset (Eq. 17): pos_offset != gateway length in
//!   general, which is why positions are explicit model inputs;
//! * virtual boundary targets: the parent carries the CE terms of each child
//!   partition's first token (whose logits live in the parent).

use crate::tree::dfs::DfsMeta;
use crate::tree::{serialize, NodeSpec, TrajectoryTree};

use super::validate::validate_assignment;

/// One partition with everything needed to build its batch and gateway.
#[derive(Debug, Clone)]
pub struct PartitionSpec {
    /// Original node ids (ascending = pre-order restriction).
    pub nodes: Vec<usize>,
    pub root: usize,
    pub parent_part: i32,
    /// Original id of the cut node (parent of `root`); -1 for the root part.
    pub cut_node: i32,
    /// Partition-local serialization.
    pub meta: DfsMeta,
    /// Full-tree lambda weights aligned to `meta`'s token order.
    pub weights: Vec<f32>,
    /// Eq. 17 depth offset of the partition root's first token.
    pub pos_offset: i32,
    /// Full-DFS slots of the root's ancestor tokens (gateway rows, in path
    /// order root -> cut node).
    pub anc_slots: Vec<usize>,
    /// (local prev slot, token, weight) boundary targets for children.
    pub virtuals: Vec<(usize, i32, f32)>,
}

impl PartitionSpec {
    /// Slots this partition occupies in its batch (tokens + virtuals).
    pub fn needed_slots(&self) -> usize {
        self.meta.size() + self.virtuals.len()
    }
}

/// A complete partition plan over one tree.
#[derive(Debug, Clone)]
pub struct Plan {
    pub full_meta: DfsMeta,
    pub parts: Vec<PartitionSpec>,
    /// full-DFS slot -> (partition, local slot).
    pub owner: Vec<(u32, u32)>,
    /// Topological order (parents before children).
    pub topo: Vec<usize>,
}

pub fn plan(tree: &TrajectoryTree, assignment: &[usize]) -> crate::Result<Plan> {
    validate_assignment(tree, assignment)?;
    let full_meta = serialize(tree);
    let n_parts = assignment.iter().copied().max().unwrap_or(0) + 1;

    let mut parts = Vec::with_capacity(n_parts);
    let mut owner = vec![(u32::MAX, u32::MAX); full_meta.size()];
    // one pass over nodes (ascending => pre-order restriction per part),
    // instead of the former O(n_parts · n) filter-per-partition scan
    let mut members_by_part: Vec<Vec<usize>> = vec![Vec::new(); n_parts];
    for (i, &p) in assignment.iter().enumerate() {
        members_by_part[p].push(i);
    }
    for p in 0..n_parts {
        let members: Vec<usize> = std::mem::take(&mut members_by_part[p]);
        let root = *members
            .iter()
            .find(|&&i| {
                tree.nodes[i].parent < 0 || assignment[tree.nodes[i].parent as usize] != p
            })
            .expect("validated");
        let local_id = |orig: usize| members.binary_search(&orig).expect("member");
        let local_nodes: Vec<NodeSpec> = members
            .iter()
            .map(|&orig| {
                let nd = &tree.nodes[orig];
                NodeSpec {
                    parent: if orig == root {
                        -1
                    } else {
                        local_id(nd.parent as usize) as i32
                    },
                    ..nd.clone()
                }
            })
            .collect();
        let local_tree = TrajectoryTree::new(local_nodes)?;
        let meta = serialize(&local_tree);

        // full-tree lambda weights sliced per node segment + owner map
        let mut weights = vec![0.0f32; meta.size()];
        for (li, &orig) in members.iter().enumerate() {
            let ls = meta.node_start[li] as usize;
            let fs = full_meta.node_start[orig] as usize;
            let ln = full_meta.node_len[orig] as usize;
            weights[ls..ls + ln].copy_from_slice(&full_meta.weights[fs..fs + ln]);
            for t in 0..ln {
                owner[fs + t] = (p as u32, (ls + t) as u32);
            }
        }

        let cut_node = tree.nodes[root].parent;
        let mut anc_slots = Vec::new();
        if cut_node >= 0 {
            // path root -> cut node, real tokens only
            let mut chain = Vec::new();
            let mut j = cut_node;
            while j >= 0 {
                chain.push(j as usize);
                j = tree.nodes[j as usize].parent;
            }
            for &n in chain.iter().rev() {
                let s = full_meta.node_start[n] as usize;
                for t in s..s + full_meta.node_len[n] as usize {
                    if !full_meta.pad_mask[t] {
                        anc_slots.push(t);
                    }
                }
            }
        }

        parts.push(PartitionSpec {
            nodes: members,
            root,
            parent_part: if cut_node < 0 { -1 } else { assignment[cut_node as usize] as i32 },
            cut_node,
            pos_offset: full_meta.node_depth_tokens[root],
            meta,
            weights,
            anc_slots,
            virtuals: Vec::new(),
        });
    }

    // virtual boundary targets: child-first token loss lands in the parent
    for ci in 0..parts.len() {
        if parts[ci].parent_part < 0 {
            continue;
        }
        let cut = parts[ci].cut_node as usize;
        let pp = parts[ci].parent_part as usize;
        // parent-local slot of the cut node's last real token
        let plid = parts[pp].nodes.binary_search(&cut).expect("cut in parent");
        let (s, ln) =
            (parts[pp].meta.node_start[plid] as usize, parts[pp].meta.node_len[plid] as usize);
        let last_real = (s..s + ln)
            .rev()
            .find(|&t| !parts[pp].meta.pad_mask[t])
            .ok_or_else(|| anyhow::anyhow!("cut node with empty segment unsupported"))?;
        // child's first real token + its full-tree weight
        let cs = parts[ci].meta.node_start[0] as usize;
        let cl = parts[ci].meta.node_len[0] as usize;
        let first = (cs..cs + cl)
            .find(|&t| !parts[ci].meta.pad_mask[t])
            .ok_or_else(|| anyhow::anyhow!("child root with empty segment unsupported"))?;
        let tok = parts[ci].meta.tokens[first];
        let w = parts[ci].weights[first];
        parts[ci].weights[first] = 0.0; // counted in the parent instead
        parts[pp].virtuals.push((last_real, tok, w));
    }

    // topological order (parents first)
    let mut topo = Vec::with_capacity(parts.len());
    let mut done = vec![false; parts.len()];
    while topo.len() < parts.len() {
        for i in 0..parts.len() {
            if !done[i]
                && (parts[i].parent_part < 0 || done[parts[i].parent_part as usize])
            {
                topo.push(i);
                done[i] = true;
            }
        }
    }

    Ok(Plan { full_meta, parts, owner, topo })
}

impl Plan {
    /// Build the padded model batch for one partition (mirrors
    /// `partplan.partition_batch`).
    pub fn partition_batch(
        &self,
        pi: usize,
        capacity: usize,
        past_capacity: usize,
        opts: &crate::trainer::batch::BatchOptions,
    ) -> crate::Result<crate::trainer::batch::Batch> {
        let p = &self.parts[pi];
        let s = p.meta.size();
        let nv = p.virtuals.len();
        anyhow::ensure!(
            s + nv <= capacity,
            "partition needs {s}+{nv} slots > capacity {capacity}"
        );
        let a = p.anc_slots.len();
        anyhow::ensure!(a <= past_capacity, "gateway needs {a} rows > capacity {past_capacity}");

        let mut o = opts.clone();
        o.past_len = past_capacity;
        o.past_bias = Some(crate::trainer::batch::gateway_bias(a, past_capacity));
        o.gateway_ctx = p.cut_node >= 0 && opts.conv_kernel.is_some();
        let mut b = crate::trainer::batch::build_batch(&p.meta, capacity, &o)?;
        // full-tree lambdas (pads already zero)
        b.weights[..s].copy_from_slice(&p.weights);
        for w in b.weights[s..].iter_mut() {
            *w = 0.0;
        }
        b.offset_positions(p.pos_offset, s);
        for (j, &(prev_slot, tok, w)) in p.virtuals.iter().enumerate() {
            b.set_virtual_target(s + j, tok, prev_slot as i32, w);
        }
        Ok(b)
    }

    /// Sum over partitions of unique real tokens — must equal `N_tree`
    /// (the paper's zero-redundancy guarantee, Fig. 5 right bar).
    pub fn total_real_tokens(&self) -> usize {
        self.parts
            .iter()
            .map(|p| p.meta.pad_mask.iter().filter(|&&x| !x).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::greedy_pack;
    use crate::trainer::batch::BatchOptions;
    use crate::tree::gen;

    fn tree3() -> TrajectoryTree {
        TrajectoryTree::new(vec![
            NodeSpec::new(-1, vec![1, 2, 3, 4, 5]),
            NodeSpec::new(0, vec![6, 7, 8]),
            NodeSpec::new(1, vec![9, 10, 11, 12]),
            NodeSpec::new(1, vec![13, 14]),
            NodeSpec::new(0, vec![15, 16, 17, 18]),
        ])
        .unwrap()
    }

    #[test]
    fn zero_redundancy() {
        let t = tree3();
        let plan = plan(&t, &[0, 1, 1, 2, 3]).unwrap();
        assert_eq!(plan.total_real_tokens(), t.n_tree());
    }

    #[test]
    fn weights_conserved() {
        // sum of weights across partitions (incl. virtuals) == full tree sum
        let t = tree3();
        let p = plan(&t, &[0, 1, 1, 2, 3]).unwrap();
        let full: f32 = p.full_meta.weights.iter().sum();
        let mut parts_sum = 0.0f32;
        for part in &p.parts {
            parts_sum += part.weights.iter().sum::<f32>();
            parts_sum += part.virtuals.iter().map(|v| v.2).sum::<f32>();
        }
        // minus the losses that exist in neither (tree-root first token has
        // no predecessor and its weight is excluded by prev_idx = -1 at
        // batch level, but the *weight vector* still carries it in both)
        assert!((full - parts_sum).abs() < 1e-5);
    }

    #[test]
    fn positions_are_global() {
        let t = tree3();
        let p = plan(&t, &[0, 1, 1, 2, 3]).unwrap();
        // partition rooted at node 3 (original) has pos_offset = |n0| + |n1|
        let pi = p.parts.iter().position(|x| x.root == 3).unwrap();
        assert_eq!(p.parts[pi].pos_offset, 8);
        let b = p
            .partition_batch(pi, 16, 16, &BatchOptions::default())
            .unwrap();
        assert_eq!(b.pos_ids[0], 8);
    }

    #[test]
    fn ancestor_slots_follow_path() {
        let t = tree3();
        let p = plan(&t, &[0, 1, 1, 2, 3]).unwrap();
        let pi = p.parts.iter().position(|x| x.root == 3).unwrap();
        // ancestors of node 3: n0 (slots 0..5) + n1 (slots 5..8)
        assert_eq!(p.parts[pi].anc_slots, (0..8).collect::<Vec<_>>());
        let pj = p.parts.iter().position(|x| x.root == 4).unwrap();
        assert_eq!(p.parts[pj].anc_slots, (0..5).collect::<Vec<_>>());
    }

    #[test]
    fn virtual_targets_cover_cut_edges() {
        let t = tree3();
        let p = plan(&t, &[0, 1, 1, 2, 3]).unwrap();
        let total_virtuals: usize = p.parts.iter().map(|x| x.virtuals.len()).sum();
        assert_eq!(total_virtuals, 3); // three cut edges
        // the partition holding node 1 carries node 3's first-token target
        let pp = p.parts.iter().position(|x| x.root == 1).unwrap();
        assert_eq!(p.parts[pp].virtuals.len(), 1);
        let (prev_slot, tok, w) = p.parts[pp].virtuals[0];
        assert_eq!(tok, 13);
        assert!(w > 0.0);
        // prev slot = local slot of node 1's last token (local layout: n1 0..3, n2 3..7)
        assert_eq!(prev_slot, 2);
    }

    #[test]
    fn topo_parents_first() {
        for seed in 0..10 {
            let t = gen::uniform(seed, 12, 5, 0.6);
            if let Ok(assign) = greedy_pack(&t, 16) {
                let p = plan(&t, &assign).unwrap();
                let mut seen = vec![false; p.parts.len()];
                for &i in &p.topo {
                    if p.parts[i].parent_part >= 0 {
                        assert!(seen[p.parts[i].parent_part as usize]);
                    }
                    seen[i] = true;
                }
            }
        }
    }
}
