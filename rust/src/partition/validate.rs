//! Partition-plan invariants (proptest targets):
//! connectivity, single parent partition, capacity, token conservation.

use crate::tree::TrajectoryTree;

/// Validate that `assignment` forms connected subtrees with a tree-shaped
/// partition dependency graph (§3.3's memory-bound requirement).
pub fn validate_assignment(tree: &TrajectoryTree, assignment: &[usize]) -> crate::Result<()> {
    anyhow::ensure!(assignment.len() == tree.nodes.len(), "assignment length");
    let n_parts = assignment.iter().copied().max().unwrap_or(0) + 1;

    let mut roots = vec![Vec::new(); n_parts];
    for (i, nd) in tree.nodes.iter().enumerate() {
        let p = assignment[i];
        anyhow::ensure!(p < n_parts, "partition id gap");
        if nd.parent < 0 || assignment[nd.parent as usize] != p {
            roots[p].push(i);
        }
    }
    for (p, r) in roots.iter().enumerate() {
        anyhow::ensure!(
            r.len() == 1,
            "partition {p} must be a single connected subtree (roots: {r:?})"
        );
    }
    // single parent partition (dependency graph is a tree): holds by
    // construction given connectivity, but assert for belt and braces.
    for (p, r) in roots.iter().enumerate() {
        let root = r[0];
        let par = tree.nodes[root].parent;
        if par >= 0 {
            let pp = assignment[par as usize];
            anyhow::ensure!(pp != p, "partition {p} root not actually a boundary");
        }
    }
    // token conservation (single pass — the former per-partition scan was
    // O(n_parts · n), quadratic on wide-fanout trees)
    let total: usize = tree.nodes.iter().map(|nd| nd.len()).sum();
    anyhow::ensure!(total == tree.n_slots(), "token slots not conserved");
    Ok(())
}

/// Peak-memory bound check (§3.3): the deepest chain of partitions must
/// cover at most one root-to-leaf path of gateway rows.
pub fn max_gateway_rows(tree: &TrajectoryTree, assignment: &[usize]) -> usize {
    let n_parts = assignment.iter().copied().max().unwrap_or(0) + 1;
    let mut max_rows = 0usize;
    for p in 0..n_parts {
        let root = (0..tree.nodes.len())
            .find(|&i| {
                assignment[i] == p
                    && (tree.nodes[i].parent < 0
                        || assignment[tree.nodes[i].parent as usize] != p)
            })
            .unwrap();
        let mut rows = 0usize;
        let mut j = tree.nodes[root].parent;
        while j >= 0 {
            rows += tree.nodes[j as usize].real_len();
            j = tree.nodes[j as usize].parent;
        }
        max_rows = max_rows.max(rows);
    }
    max_rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::greedy_pack;
    use crate::tree::gen;

    #[test]
    fn gateway_rows_bounded_by_longest_path() {
        for seed in 0..20 {
            let t = gen::uniform(seed, 14, 6, 0.6);
            if let Ok(assign) = greedy_pack(&t, 20) {
                let longest: usize = t
                    .longest_path()
                    .iter()
                    .map(|&n| t.nodes[n].real_len())
                    .sum();
                assert!(max_gateway_rows(&t, &assign) <= longest);
            }
        }
    }

    #[test]
    fn detects_disconnected() {
        let t = TrajectoryTree::new(vec![
            crate::NodeSpec::new(-1, vec![1]),
            crate::NodeSpec::new(0, vec![2]),
            crate::NodeSpec::new(0, vec![3]),
        ])
        .unwrap();
        // {n1, n2} are siblings: not a connected subtree
        assert!(validate_assignment(&t, &[0, 1, 1]).is_err());
        assert!(validate_assignment(&t, &[0, 1, 2]).is_ok());
    }
}
