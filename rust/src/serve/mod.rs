//! `tree-train serve` — the continuous-ingestion training service.
//!
//! Batch training (`tree-train train`) folds a finished corpus; serving
//! trains *while producers are still writing*.  Concurrent rollout
//! producers append records to a spool directory ([`spool`]); an online
//! fold keeps one live radix trie per open session ([`live`]); a
//! deterministic ripeness policy decides when a session's tree is
//! cuttable; [`source::LiveSource`] bridges ripe trees into the existing
//! pipelined planner/executor/rank-pool stack *unchanged* — serving is a
//! data-layer feature, not a trainer fork.
//!
//! Three contracts, each enforced in code rather than by convention:
//!
//! * **Bounded staleness** — once ripe, a tree must enter a batch within
//!   `staleness_bound` optimizer steps.  With the default
//!   `ripe_cap = staleness_bound × trees_per_batch` this holds by
//!   construction (FIFO queue, bounded depth); the cut path still hard-
//!   errors if it is ever exceeded.
//! * **Flat memory** — the source folds only while the ripe queue has
//!   room; the spool on disk is the producer-side buffer, so trainer
//!   memory is bounded by `ripe_cap` trees plus the open-session tries.
//! * **Bit-exact replay** — every admission decision is journaled
//!   ([`journal`]); `tree-train serve --replay <journal>` re-executes the
//!   run and fails unless losses, batch-composition fingerprints, and
//!   final ingest stats are identical.  The journal is the proof that a
//!   live, timing-dependent run was equivalent to a deterministic one.
//!
//! See `docs/serve.md` for the operational guide.

pub mod journal;
pub mod live;
pub mod source;
pub mod spool;

pub use source::{LiveSource, ServeShared, SourceConfig};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::coordinator::pipeline::{self, HostExecutor, PipelineConfig};
use crate::coordinator::{Mode, StepExecutor};
use crate::ingest::IngestStats;
use crate::trainer::{CsvSink, PlanSpec, StepMetrics};
use crate::util::json::Json;
use crate::Result;

use journal::{Event, JournalWriter, ReplayScript};

/// The full serve configuration, journaled verbatim as the `config`
/// header: replay reads its policy from the journal, never the CLI, so a
/// journal is self-describing.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeParams {
    pub mode: Mode,
    pub steps: u64,
    pub trees_per_batch: usize,
    /// Max optimizer steps a ripe tree may wait before entering a batch.
    pub staleness_bound: u64,
    /// Ripe-queue depth at which the pump stops folding (fold credits).
    pub ripe_cap: usize,
    pub max_open_sessions: usize,
    /// Idle flush threshold in fold steps; 0 disables idle flushing.
    pub idle_timeout: u64,
    pub max_seq_len: Option<usize>,
    /// Packed device-batch token capacity ([`PlanSpec::for_host`]).
    pub capacity: usize,
    pub vocab: usize,
    pub seed: u64,
    pub lr: f64,
    pub warmup: u64,
    pub ranks: usize,
    pub pipeline_depth: usize,
    pub poll_ms: u64,
    pub stall_timeout_ms: u64,
    /// Whether the run priced sharding with the measured-wall calibrated
    /// model.  Such runs are NOT bit-replayable (pricing feeds wall-clock
    /// measurements back into rank placement, and rank placement changes
    /// the loss-reduction bracket) — replay refuses these journals.
    pub calibrated: bool,
}

impl Default for ServeParams {
    fn default() -> Self {
        Self {
            mode: Mode::Tree,
            steps: 8,
            trees_per_batch: 4,
            staleness_bound: 8,
            ripe_cap: 32, // staleness_bound * trees_per_batch
            max_open_sessions: 64,
            idle_timeout: 0,
            max_seq_len: None,
            capacity: 256,
            vocab: 64,
            seed: 17,
            lr: 1e-2,
            warmup: 0,
            ranks: 1,
            pipeline_depth: 2,
            poll_ms: 5,
            stall_timeout_ms: 10_000,
            calibrated: false,
        }
    }
}

impl ServeParams {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.steps >= 1, "steps must be >= 1");
        anyhow::ensure!(self.trees_per_batch >= 1, "trees_per_batch must be >= 1");
        anyhow::ensure!(self.staleness_bound >= 1, "staleness_bound must be >= 1");
        anyhow::ensure!(
            self.ripe_cap >= self.trees_per_batch,
            "ripe_cap {} cannot fill one batch of {} (fold credits must cover a cut)",
            self.ripe_cap,
            self.trees_per_batch
        );
        anyhow::ensure!(self.max_open_sessions >= 1, "max_open_sessions must be >= 1");
        anyhow::ensure!(self.ranks >= 1, "ranks must be >= 1");
        anyhow::ensure!(self.capacity >= 1, "capacity must be >= 1");
        anyhow::ensure!(self.vocab >= 2, "vocab must be >= 2");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("mode", Json::str(match self.mode {
                Mode::Tree => "tree",
                Mode::Baseline => "baseline",
            })),
            ("steps", Json::num(self.steps as f64)),
            ("trees_per_batch", Json::num(self.trees_per_batch as f64)),
            ("staleness_bound", Json::num(self.staleness_bound as f64)),
            ("ripe_cap", Json::num(self.ripe_cap as f64)),
            ("max_open_sessions", Json::num(self.max_open_sessions as f64)),
            ("idle_timeout", Json::num(self.idle_timeout as f64)),
            ("capacity", Json::num(self.capacity as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", Json::Num(self.lr)),
            ("warmup", Json::num(self.warmup as f64)),
            ("ranks", Json::num(self.ranks as f64)),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
            ("poll_ms", Json::num(self.poll_ms as f64)),
            ("stall_timeout_ms", Json::num(self.stall_timeout_ms as f64)),
            ("calibrated", Json::Bool(self.calibrated)),
        ];
        if let Some(m) = self.max_seq_len {
            kv.push(("max_seq_len", Json::num(m as f64)));
        }
        Json::obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let u = |k: &str, dv: u64| -> Result<u64> {
            match v.get(k) {
                Some(x) => x.as_u64().ok_or_else(|| anyhow::anyhow!("`{k}` not a u64")),
                None => Ok(dv),
            }
        };
        let us = |k: &str, dv: usize| -> Result<usize> {
            match v.get(k) {
                Some(x) => x.as_usize().ok_or_else(|| anyhow::anyhow!("`{k}` not a usize")),
                None => Ok(dv),
            }
        };
        let p = Self {
            mode: match v.get("mode").and_then(|x| x.as_str()).unwrap_or("tree") {
                "tree" => Mode::Tree,
                "baseline" => Mode::Baseline,
                other => anyhow::bail!("unknown mode {other:?} (tree|baseline)"),
            },
            steps: u("steps", d.steps)?,
            trees_per_batch: us("trees_per_batch", d.trees_per_batch)?,
            staleness_bound: u("staleness_bound", d.staleness_bound)?,
            ripe_cap: us("ripe_cap", d.ripe_cap)?,
            max_open_sessions: us("max_open_sessions", d.max_open_sessions)?,
            idle_timeout: u("idle_timeout", d.idle_timeout)?,
            max_seq_len: match v.get("max_seq_len") {
                Some(x) => {
                    Some(x.as_usize().ok_or_else(|| anyhow::anyhow!("`max_seq_len` not a usize"))?)
                }
                None => None,
            },
            capacity: us("capacity", d.capacity)?,
            vocab: us("vocab", d.vocab)?,
            seed: u("seed", d.seed)?,
            lr: match v.get("lr") {
                Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("`lr` not a number"))?,
                None => d.lr,
            },
            warmup: u("warmup", d.warmup)?,
            ranks: us("ranks", d.ranks)?,
            pipeline_depth: us("pipeline_depth", d.pipeline_depth)?,
            poll_ms: u("poll_ms", d.poll_ms)?,
            stall_timeout_ms: u("stall_timeout_ms", d.stall_timeout_ms)?,
            calibrated: v.get("calibrated").and_then(|x| x.as_bool()).unwrap_or(false),
        };
        p.validate()?;
        Ok(p)
    }

    fn source_config(&self) -> SourceConfig {
        SourceConfig {
            staleness_bound: self.staleness_bound,
            ripe_cap: self.ripe_cap,
            max_open_sessions: self.max_open_sessions,
            idle_timeout: self.idle_timeout,
            max_seq_len: self.max_seq_len,
            poll_ms: self.poll_ms,
            stall_timeout_ms: self.stall_timeout_ms,
        }
    }
}

/// Executor wrapper: delegates the actual step to the hermetic
/// [`HostExecutor`] and journals (live) or verifies (replay) every loss as
/// exact f64 bits.
struct ServeExecutor {
    inner: HostExecutor,
    /// Live: append a `loss` event per step (executor-thread side of the
    /// shared journal).
    journal: Option<Arc<Mutex<JournalWriter>>>,
    /// Replay: step → (loss bits, lr bits) to verify against.
    expect: Option<HashMap<u64, (u64, u64)>>,
    sink: Option<CsvSink>,
}

impl StepExecutor for ServeExecutor {
    fn execute(&mut self, planned: &pipeline::PlannedStep) -> Result<StepMetrics> {
        let m = self.inner.execute(planned)?;
        let loss_bits = m.loss.to_bits();
        let lr_bits = planned.lr.to_bits();
        if let Some(j) = &self.journal {
            j.lock().expect("journal lock").append(&Event::Loss {
                step: planned.step,
                loss_bits,
                lr_bits,
            })?;
        }
        if let Some(expect) = &self.expect {
            let &(jl, jr) = expect.get(&planned.step).ok_or_else(|| {
                anyhow::anyhow!("journal has no loss event for step {}", planned.step)
            })?;
            anyhow::ensure!(
                jl == loss_bits && jr == lr_bits,
                "replay diverged at step {}: loss {} (bits {loss_bits:#018x}) vs journaled \
                 bits {jl:#018x}",
                planned.step,
                m.loss
            );
        }
        Ok(m)
    }

    fn on_step(&mut self, m: &StepMetrics) -> Result<()> {
        if let Some(s) = &mut self.sink {
            s.log(m)?;
        }
        Ok(())
    }

    fn pool_spawn_ms(&self) -> f64 {
        self.inner.pool_spawn_ms()
    }
}

/// Inputs of one serve invocation (CLI or test harness).
pub struct ServeOptions {
    pub spool: PathBuf,
    /// Live mode: journal output path (required unless replaying).
    pub journal: Option<PathBuf>,
    /// Replay mode: a recorded journal to re-execute bit-for-bit.  The
    /// policy half of `params` is ignored (the journal header wins).
    pub replay: Option<PathBuf>,
    pub params: ServeParams,
    pub metrics_csv: Option<PathBuf>,
    /// Warm-start the calibrated cost model from this state file and save
    /// back after the run.  Incompatible with `replay` (see
    /// [`ServeParams::calibrated`]).
    pub cost_model_state: Option<PathBuf>,
}

/// What a serve run produced, for the CLI summary line and the
/// integration tests.
pub struct ServeReport {
    pub metrics: Vec<StepMetrics>,
    /// One batch-composition fingerprint per executed step.
    pub fingerprints: Vec<u64>,
    pub stats: IngestStats,
    pub cuts: u64,
    pub replayed: bool,
}

/// Run the service (live or replay) to completion.  Shared by
/// `tree-train serve` and `tests/serve_replay.rs` so the CLI and the
/// equivalence gate exercise the identical driver.
pub fn run(opts: &ServeOptions) -> Result<ServeReport> {
    let replaying = opts.replay.is_some();
    anyhow::ensure!(
        !(replaying && opts.cost_model_state.is_some()),
        "--cost-model-state feeds measured wall clocks into rank placement, which changes \
         the loss-reduction bracket — a replay could not be bit-exact; drop one of the flags"
    );
    let mut params = opts.params.clone();
    params.calibrated = opts.cost_model_state.is_some();

    // replay reads the authoritative config from the journal header
    let script = match &opts.replay {
        Some(path) => {
            let script = ReplayScript::load(path)?;
            params = ServeParams::from_json(&script.params)?;
            anyhow::ensure!(
                !params.calibrated,
                "this journal was recorded with calibrated cost pricing and is not \
                 bit-replayable; re-record without --cost-model-state"
            );
            Some(script)
        }
        None => None,
    };
    params.validate()?;

    let shared = ServeShared::default();
    let mut journal_writer = None;
    let source: Box<dyn crate::data::CorpusSource> = match &script {
        Some(s) => {
            Box::new(LiveSource::replay(&opts.spool, params.source_config(), s.feed.clone(), shared.clone())?)
        }
        None => {
            let jpath = opts
                .journal
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("live serve needs --journal <path>"))?;
            let mut w = JournalWriter::create(jpath)?;
            w.append(&Event::Config(params.to_json()))?;
            let w = Arc::new(Mutex::new(w));
            journal_writer = Some(w.clone());
            Box::new(LiveSource::live(&opts.spool, params.source_config(), w, shared.clone())?)
        }
    };

    let mut spec = PlanSpec::for_host(params.capacity);
    let mut cost_model = None;
    if let Some(state) = &opts.cost_model_state {
        let cm = crate::partition::CostModel::calibrated_from_state(8, state)?;
        spec = spec.with_cost_model(cm.clone());
        cost_model = Some(cm);
    }

    let pcfg = PipelineConfig {
        mode: params.mode,
        steps: params.steps,
        trees_per_batch: params.trees_per_batch,
        depth: params.pipeline_depth,
        lr: params.lr,
        warmup: params.warmup,
        ranks: params.ranks,
    };
    let sink = match &opts.metrics_csv {
        Some(p) => Some(CsvSink::create(p)?),
        None => None,
    };
    let mut exec = ServeExecutor {
        inner: HostExecutor::new(params.vocab, 8, params.seed),
        journal: journal_writer.clone(),
        expect: script.as_ref().map(|s| s.losses.clone()),
        sink,
    };
    let (metrics, _summary) = pipeline::run(&pcfg, spec, source, &mut exec)?;

    let (stats, cuts) = {
        let s = shared.lock().expect("shared lock");
        (s.stats, s.cuts)
    };
    if let Some(w) = &journal_writer {
        w.lock().expect("journal lock").append(&Event::Stats {
            steps: metrics.len() as u64,
            stats,
        })?;
    }
    if let Some(script) = &script {
        anyhow::ensure!(
            metrics.len() as u64 == script.steps,
            "replay executed {} steps but the journal recorded {}",
            metrics.len(),
            script.steps
        );
        anyhow::ensure!(
            stats == script.stats,
            "replay diverged: final ingest stats {stats:?} != journaled {:?}",
            script.stats
        );
    }
    if let (Some(cm), Some(path)) = (&cost_model, &opts.cost_model_state) {
        cm.save_state(path)?;
    }
    Ok(ServeReport {
        fingerprints: exec.inner.fingerprints.clone(),
        metrics,
        stats,
        cuts,
        replayed: replaying,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_roundtrip_and_validate() {
        let mut p = ServeParams::default();
        p.mode = Mode::Baseline;
        p.max_seq_len = Some(128);
        p.lr = 0.0125;
        p.calibrated = true;
        let j = Json::parse(&p.to_json().to_string()).unwrap();
        let back = ServeParams::from_json(&j).unwrap();
        assert_eq!(back, p);
        // defaults fill the gaps
        let sparse = Json::parse(r#"{"steps": 3}"#).unwrap();
        let q = ServeParams::from_json(&sparse).unwrap();
        assert_eq!(q.steps, 3);
        assert_eq!(q.trees_per_batch, ServeParams::default().trees_per_batch);
        assert_eq!(q.max_seq_len, None);
        // a cap that cannot fill one batch is rejected
        let bad = Json::parse(r#"{"ripe_cap": 2, "trees_per_batch": 4}"#).unwrap();
        assert!(ServeParams::from_json(&bad).is_err());
    }

    #[test]
    fn lr_bits_survive_the_params_roundtrip() {
        let mut p = ServeParams::default();
        p.lr = 0.1 + 0.2; // 0.30000000000000004 — a classic round-trip trap
        let j = Json::parse(&p.to_json().to_string()).unwrap();
        let back = ServeParams::from_json(&j).unwrap();
        assert_eq!(back.lr.to_bits(), p.lr.to_bits());
    }
}
