//! [`LiveSource`]: the [`CorpusSource`] that bridges ripe session trees
//! into the existing pipelined planner/executor stack — plus its replay
//! twin, which feeds the *journaled* admission sequence back through the
//! identical fold/cut code and cross-checks every decision.
//!
//! ## The determinism argument
//!
//! The ripe queue order is a pure function of the spool *arrival order*
//! (each fold's ripeness verdicts are deterministic — see
//! [`super::live::LiveFolder`]), and every cut takes a FIFO prefix of the
//! queue.  So batch composition depends only on (arrival order,
//! trees_per_batch) — never on how the pump loop interleaved with
//! optimizer steps.  The journal pins down the one non-deterministic
//! input, arrival order, as a list of (file, line) coordinates; the
//! per-cut `upto_seq` additionally freezes *how far* the pump ran before
//! each cut so replay reproduces queue-depth and staleness metrics
//! bit-for-bit, not just batch contents.
//!
//! ## Back-pressure
//!
//! The source folds new spool lines only while the ripe queue holds fewer
//! than `ripe_cap` trees ("fold credits").  Producers are never blocked —
//! the spool on disk *is* the buffer — but trainer memory stays flat:
//! resident trees ≤ ripe_cap + one session flush.  When the queue cannot
//! fill a batch the source stalls in `poll_ms` sleeps up to
//! `stall_timeout_ms`, then errors out rather than hanging a CI run.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::data::{CorpusSource, ServeStepStats};
use crate::ingest::IngestStats;
use crate::tree::node::TrajectoryTree;
use crate::Result;

use super::journal::{batch_fingerprint, Event, JournalWriter};
use super::live::{LiveFolder, RipeGroup};
use super::spool::{SpoolCursors, SpoolRecord, SpoolWatcher};

/// One ripe tree waiting to be cut into a batch, stamped with the cut
/// counter at ripening time so staleness is measured in optimizer steps.
struct RipeEntry {
    tree: Arc<TrajectoryTree>,
    ripe_cut: u64,
}

/// Where spool records come from.
enum Feed {
    /// Live tailing; arrival order is recorded to the journal.
    Live { watcher: SpoolWatcher, poll_ms: u64, stall_timeout_ms: u64 },
    /// Journal-driven: arrivals and cut points are dictated by the
    /// recorded events; every decision is re-derived and cross-checked.
    Replay { cursors: SpoolCursors, feed: VecDeque<Event> },
}

/// End-of-run state shared with the driver (the pipeline consumes the
/// boxed source, so final stats must escape by a side channel).
#[derive(Default)]
pub struct ServeSharedState {
    pub stats: IngestStats,
    pub cuts: u64,
}

pub type ServeShared = Arc<Mutex<ServeSharedState>>;

/// Knobs of the admission policy (a subset of [`super::ServeParams`],
/// duplicated here so the source does not depend on the CLI layer).
pub struct SourceConfig {
    pub staleness_bound: u64,
    pub ripe_cap: usize,
    pub max_open_sessions: usize,
    /// Idle flush threshold in fold steps; 0 disables.
    pub idle_timeout: u64,
    pub max_seq_len: Option<usize>,
    pub poll_ms: u64,
    pub stall_timeout_ms: u64,
}

pub struct LiveSource {
    feed: Feed,
    folder: LiveFolder,
    ripe: VecDeque<RipeEntry>,
    /// Journal writer in live mode (shared with the executor wrapper,
    /// which appends loss events from the other pipeline thread).
    journal: Option<Arc<Mutex<JournalWriter>>>,
    shared: ServeShared,
    staleness_bound: u64,
    ripe_cap: usize,
    /// Fold sequence number of the last folded spool line.
    seq: u64,
    /// Cuts performed so far == the next cut's step id.
    cut_count: u64,
    /// Sessions ripened since the previous cut.
    admitted_since_cut: u64,
    quiesced: bool,
    peak_resident: usize,
    ingest_ms: f64,
    last_stats: Option<ServeStepStats>,
}

impl LiveSource {
    pub fn live(
        spool: &std::path::Path,
        cfg: SourceConfig,
        journal: Arc<Mutex<JournalWriter>>,
        shared: ServeShared,
    ) -> Result<Self> {
        let watcher = SpoolWatcher::open(spool)?;
        Ok(Self::build(
            Feed::Live { watcher, poll_ms: cfg.poll_ms, stall_timeout_ms: cfg.stall_timeout_ms },
            cfg,
            Some(journal),
            shared,
        ))
    }

    pub fn replay(
        spool: &std::path::Path,
        cfg: SourceConfig,
        feed: Vec<Event>,
        shared: ServeShared,
    ) -> Result<Self> {
        let cursors = SpoolCursors::open(spool)?;
        Ok(Self::build(Feed::Replay { cursors, feed: feed.into() }, cfg, None, shared))
    }

    fn build(
        feed: Feed,
        cfg: SourceConfig,
        journal: Option<Arc<Mutex<JournalWriter>>>,
        shared: ServeShared,
    ) -> Self {
        Self {
            feed,
            folder: LiveFolder::new(cfg.max_open_sessions, cfg.idle_timeout, cfg.max_seq_len),
            ripe: VecDeque::new(),
            journal,
            shared,
            staleness_bound: cfg.staleness_bound,
            ripe_cap: cfg.ripe_cap,
            seq: 0,
            cut_count: 0,
            admitted_since_cut: 0,
            quiesced: false,
            peak_resident: 0,
            ingest_ms: 0.0,
            last_stats: None,
        }
    }

    fn journal_event(&self, ev: &Event) -> Result<()> {
        if let Some(j) = &self.journal {
            j.lock().expect("journal lock").append(ev)?;
        }
        Ok(())
    }

    fn publish_shared(&self) {
        let mut s = self.shared.lock().expect("shared lock");
        s.stats = self.folder.stats();
        s.cuts = self.cut_count;
    }

    /// Admit one ripened group into the queue (common to live and replay).
    fn admit(&mut self, group: RipeGroup) {
        self.admitted_since_cut += 1;
        for t in group.trees {
            self.ripe.push_back(RipeEntry { tree: Arc::new(t), ripe_cut: self.cut_count });
        }
        self.peak_resident =
            self.peak_resident.max(self.ripe.len() + self.folder.open_sessions());
    }

    /// Live: fold one decoded spool record; journal the arrival and every
    /// ripeness verdict it produced.
    fn fold_live(&mut self, file: String, line: u64, rec: SpoolRecord) -> Result<()> {
        self.seq += 1;
        let seq = self.seq;
        self.journal_event(&Event::Arrive { seq, file, line })?;
        let groups = match rec {
            SpoolRecord::Shutdown => {
                self.quiesced = true;
                self.folder.quiesce()
            }
            other => self.folder.fold(seq, &other)?,
        };
        for g in groups {
            self.journal_event(&Event::Ripe {
                seq,
                session: g.session.clone(),
                reason: g.reason,
                trees: g.trees.len() as u64,
            })?;
            self.admit(g);
        }
        if self.quiesced {
            self.journal_event(&Event::Quiesce { seq })?;
            self.publish_shared();
        }
        Ok(())
    }

    /// Live pump loop: fold while credits remain, stall-wait while the
    /// queue cannot fill a batch.
    fn pump_live(&mut self, need: usize) -> Result<()> {
        let mut waited_ms: u64 = 0;
        loop {
            if self.quiesced || self.ripe.len() >= self.ripe_cap {
                return Ok(());
            }
            let t0 = Instant::now();
            let next = match &mut self.feed {
                Feed::Live { watcher, .. } => watcher.next_line()?,
                Feed::Replay { .. } => unreachable!("pump_live on a replay feed"),
            };
            match next {
                Some(l) => {
                    let rec = l.decode()?;
                    self.fold_live(l.file, l.line, rec)?;
                    self.ingest_ms += t0.elapsed().as_secs_f64() * 1e3;
                    waited_ms = 0;
                }
                None => {
                    self.ingest_ms += t0.elapsed().as_secs_f64() * 1e3;
                    if self.ripe.len() >= need {
                        // enough for this batch; don't wait for more
                        return Ok(());
                    }
                    let (poll_ms, stall_timeout_ms) = match &self.feed {
                        Feed::Live { poll_ms, stall_timeout_ms, .. } => {
                            (*poll_ms, *stall_timeout_ms)
                        }
                        Feed::Replay { .. } => unreachable!(),
                    };
                    anyhow::ensure!(
                        waited_ms < stall_timeout_ms,
                        "spool stalled: {} ripe trees after waiting {stall_timeout_ms} ms for a \
                         batch of {need} (producers gone? write {{\"shutdown\":true}} to end the \
                         run)",
                        self.ripe.len()
                    );
                    // sleep is intentionally outside the ingest_ms clock:
                    // waiting for producers is not fold work
                    std::thread::sleep(std::time::Duration::from_millis(poll_ms.max(1)));
                    waited_ms += poll_ms.max(1);
                }
            }
        }
    }

    /// Replay pump: consume journal events up to (not including) the next
    /// `cut`, re-deriving and cross-checking every verdict.
    fn pump_replay(&mut self) -> Result<()> {
        loop {
            let ev = match &mut self.feed {
                Feed::Replay { feed, .. } => match feed.front() {
                    Some(Event::Cut { .. }) => return Ok(()),
                    _ => feed.pop_front(),
                },
                Feed::Live { .. } => unreachable!("pump_replay on a live feed"),
            };
            let Some(ev) = ev else {
                anyhow::bail!(
                    "journal ended mid-run: no cut event for step {} (truncated journal?)",
                    self.cut_count
                );
            };
            match ev {
                Event::Arrive { seq, file, line } => {
                    anyhow::ensure!(
                        seq == self.seq + 1,
                        "journal arrive seq {seq} after {} — journal corrupt",
                        self.seq
                    );
                    let t0 = Instant::now();
                    let l = match &mut self.feed {
                        Feed::Replay { cursors, .. } => cursors.line_at(&file, line)?,
                        Feed::Live { .. } => unreachable!(),
                    };
                    let rec = l.decode()?;
                    self.seq = seq;
                    let groups = match rec {
                        SpoolRecord::Shutdown => {
                            self.quiesced = true;
                            self.folder.quiesce()
                        }
                        other => self.folder.fold(seq, &other)?,
                    };
                    // the verdicts this fold produced must match the next
                    // journal events exactly, in order
                    for g in groups {
                        let expect = match &mut self.feed {
                            Feed::Replay { feed, .. } => feed.pop_front(),
                            Feed::Live { .. } => unreachable!(),
                        };
                        match expect {
                            Some(Event::Ripe { seq: jseq, session, reason, trees }) => {
                                anyhow::ensure!(
                                    jseq == seq
                                        && session == g.session
                                        && reason == g.reason
                                        && trees == g.trees.len() as u64,
                                    "replay diverged at seq {seq}: derived ripe \
                                     ({}, {:?}, {} trees) but journal says \
                                     ({session}, {reason:?}, {trees} trees)",
                                    g.session,
                                    g.reason,
                                    g.trees.len()
                                )
                            }
                            other => anyhow::bail!(
                                "replay diverged at seq {seq}: derived a ripe verdict for {} \
                                 but journal has {other:?}",
                                g.session
                            ),
                        }
                        self.admit(g);
                    }
                    if self.quiesced {
                        let expect = match &mut self.feed {
                            Feed::Replay { feed, .. } => feed.pop_front(),
                            Feed::Live { .. } => unreachable!(),
                        };
                        anyhow::ensure!(
                            matches!(expect, Some(Event::Quiesce { seq: q }) if q == seq),
                            "journal missing quiesce after the shutdown arrival at seq {seq}"
                        );
                        self.publish_shared();
                    }
                    self.ingest_ms += t0.elapsed().as_secs_f64() * 1e3;
                }
                Event::Ripe { seq, session, .. } => anyhow::bail!(
                    "replay diverged: journal has a ripe verdict for {session} at seq {seq} \
                     that this fold did not produce"
                ),
                Event::Quiesce { seq } => {
                    anyhow::bail!("replay diverged: unexpected quiesce at seq {seq}")
                }
                other => anyhow::bail!("unexpected journal event in feed: {other:?}"),
            }
        }
    }

    /// Cut `n` trees off the FIFO front; enforce the staleness contract;
    /// journal (live) or verify (replay) the cut record.
    fn cut(&mut self, n: usize) -> Result<Vec<Arc<TrajectoryTree>>> {
        anyhow::ensure!(
            self.ripe.len() >= n,
            "ripe queue holds {} trees, cannot cut a batch of {n}{}",
            self.ripe.len(),
            if self.quiesced { " (stream quiesced — lower --max-steps or feed more data)" } else { "" }
        );
        let step = self.cut_count;
        let mut batch = Vec::with_capacity(n);
        let mut max_staleness = 0u64;
        for _ in 0..n {
            let e = self.ripe.pop_front().expect("length checked above");
            let staleness = step - e.ripe_cut;
            max_staleness = max_staleness.max(staleness);
            batch.push(e.tree);
        }
        anyhow::ensure!(
            max_staleness <= self.staleness_bound,
            "bounded-staleness contract violated: a tree waited {max_staleness} steps in the \
             ripe queue (bound {}) — raise --staleness-bound or lower --ripe-cap",
            self.staleness_bound
        );
        let fp = batch_fingerprint(step as usize, &batch);
        let cut = Event::Cut {
            step,
            upto_seq: self.seq,
            trees: n as u64,
            fp,
            max_staleness,
            queue_depth: self.ripe.len() as u64,
            admitted: self.admitted_since_cut,
        };
        match &mut self.feed {
            Feed::Live { .. } => self.journal_event(&cut)?,
            Feed::Replay { feed, .. } => {
                let journaled = feed.pop_front();
                anyhow::ensure!(
                    journaled.as_ref() == Some(&cut),
                    "replay diverged at cut {step}: derived {cut:?} but journal says \
                     {journaled:?}"
                );
            }
        }
        self.last_stats = Some(ServeStepStats {
            staleness_steps: max_staleness,
            ripe_queue_depth: self.ripe.len() as u64,
            admitted_sessions: self.admitted_since_cut,
        });
        self.admitted_since_cut = 0;
        self.cut_count += 1;
        self.publish_shared();
        Ok(batch)
    }
}

impl CorpusSource for LiveSource {
    fn next_tree(&mut self) -> Result<Arc<TrajectoryTree>> {
        // a tree-at-a-time interface would let the planner split one cut
        // across two optimizer steps, breaking the journal's batch
        // boundaries — refuse loudly rather than silently drifting
        anyhow::bail!("LiveSource serves whole batches; use next_batch")
    }

    fn next_batch(&mut self, n: usize) -> Result<Vec<Arc<TrajectoryTree>>> {
        match &self.feed {
            Feed::Live { .. } => self.pump_live(n)?,
            Feed::Replay { .. } => self.pump_replay()?,
        }
        self.cut(n)
    }

    fn epoch_len(&self) -> Option<usize> {
        None // a live stream has no epochs
    }

    fn peak_resident(&self) -> usize {
        self.peak_resident
    }

    fn take_ingest_ms(&mut self) -> f64 {
        std::mem::take(&mut self.ingest_ms)
    }

    fn take_serve_stats(&mut self) -> Option<ServeStepStats> {
        self.last_stats.take()
    }

    fn describe(&self) -> String {
        let mode = match self.feed {
            Feed::Live { .. } => "live",
            Feed::Replay { .. } => "replay",
        };
        format!(
            "serve[{mode}]: staleness_bound={}, ripe_cap={}",
            self.staleness_bound, self.ripe_cap
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::path::PathBuf;

    fn cfg() -> SourceConfig {
        SourceConfig {
            staleness_bound: 8,
            ripe_cap: 16,
            max_open_sessions: 4,
            idle_timeout: 0,
            max_seq_len: None,
            poll_ms: 1,
            stall_timeout_ms: 50,
        }
    }

    fn spool_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tt-src-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_seg(dir: &std::path::Path, file: &str, lines: &[String]) {
        let mut f = std::fs::File::create(dir.join(file)).unwrap();
        for l in lines {
            writeln!(f, "{l}").unwrap();
        }
    }

    fn rollout(session: &str, tokens: &[i32]) -> String {
        let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
        format!("{{\"session\":\"{session}\",\"tokens\":[{}]}}", toks.join(","))
    }

    fn live_pair(dir: &std::path::Path, journal: &std::path::Path) -> (LiveSource, ServeShared) {
        let shared = ServeShared::default();
        let w = Arc::new(Mutex::new(JournalWriter::create(journal).unwrap()));
        let src = LiveSource::live(dir, cfg(), w, shared.clone()).unwrap();
        (src, shared)
    }

    #[test]
    fn live_cut_then_replay_reproduces_everything() {
        let dir = spool_dir("roundtrip");
        // two sessions ending, then shutdown; s1 branches at token 3 but
        // shares a root, so each session still emits exactly one tree
        write_seg(
            &dir,
            "seg-000.jsonl",
            &[
                rollout("s1", &[1, 2, 3]),
                rollout("s2", &[9, 8]),
                rollout("s1", &[1, 2, 4]),
                "{\"session\":\"s1\",\"end\":true}".into(),
                "{\"session\":\"s2\",\"end\":true}".into(),
                "{\"shutdown\":true}".into(),
            ],
        );
        let journal = dir.join("journal.jsonl");
        let (mut src, shared) = live_pair(&dir, &journal);
        let b0 = src.next_batch(2).unwrap();
        assert_eq!(b0.len(), 2);
        let s0 = src.take_serve_stats().unwrap();
        assert_eq!(s0.admitted_sessions, 2);
        assert_eq!(s0.staleness_steps, 0);
        assert_eq!(s0.ripe_queue_depth, 0);
        let live_stats = shared.lock().unwrap().stats;
        assert_eq!(live_stats.sessions, 2);
        assert_eq!(live_stats.records_in, 3);
        // asking for another batch after quiesce with an empty queue fails
        assert!(src.next_batch(1).is_err());
        drop(src);

        // replay from the journal: identical batch, stats, and metrics
        let script = super::super::journal::read_journal(&journal).unwrap();
        let feed: Vec<Event> = script
            .into_iter()
            .filter(|e| !matches!(e, Event::Config(_) | Event::Loss { .. } | Event::Stats { .. }))
            .collect();
        let shared2 = ServeShared::default();
        let mut rep = LiveSource::replay(&dir, cfg(), feed, shared2.clone()).unwrap();
        let r0 = rep.next_batch(2).unwrap();
        assert_eq!(b0.len(), r0.len());
        for (a, b) in b0.iter().zip(&r0) {
            assert_eq!(a.nodes, b.nodes, "replayed batch trees differ");
        }
        assert_eq!(rep.take_serve_stats().unwrap(), s0);
        assert_eq!(shared2.lock().unwrap().stats, live_stats);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replay_detects_spool_tampering() {
        let dir = spool_dir("tamper");
        write_seg(
            &dir,
            "seg.jsonl",
            &[
                rollout("s", &[1, 2]),
                "{\"session\":\"s\",\"end\":true}".into(),
                "{\"shutdown\":true}".into(),
            ],
        );
        let journal = dir.join("journal.jsonl");
        let (mut src, _) = live_pair(&dir, &journal);
        src.next_batch(1).unwrap();
        drop(src);
        // tamper: change a token after the run
        write_seg(
            &dir,
            "seg.jsonl",
            &[
                rollout("s", &[1, 7]),
                "{\"session\":\"s\",\"end\":true}".into(),
                "{\"shutdown\":true}".into(),
            ],
        );
        let feed: Vec<Event> = super::super::journal::read_journal(&journal)
            .unwrap()
            .into_iter()
            .filter(|e| !matches!(e, Event::Config(_) | Event::Loss { .. } | Event::Stats { .. }))
            .collect();
        let mut rep = LiveSource::replay(&dir, cfg(), feed, ServeShared::default()).unwrap();
        let err = rep.next_batch(1).unwrap_err().to_string();
        assert!(err.contains("diverged"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stall_times_out_instead_of_hanging() {
        let dir = spool_dir("stall");
        write_seg(&dir, "seg.jsonl", &[rollout("s", &[1])]);
        let journal = dir.join("journal.jsonl");
        let (mut src, _) = live_pair(&dir, &journal);
        // the lone session never ends and nothing else arrives → stall
        let err = src.next_batch(1).unwrap_err().to_string();
        assert!(err.contains("stalled"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn staleness_is_stamped_in_cuts_not_wall_clock() {
        let dir = spool_dir("stale");
        // 3 sessions ripen before the first cut; batches of 1 → the third
        // tree waits 2 cuts
        write_seg(
            &dir,
            "seg.jsonl",
            &[
                rollout("a", &[1]),
                "{\"session\":\"a\",\"end\":true}".into(),
                rollout("b", &[2]),
                "{\"session\":\"b\",\"end\":true}".into(),
                rollout("c", &[3]),
                "{\"session\":\"c\",\"end\":true}".into(),
                "{\"shutdown\":true}".into(),
            ],
        );
        let journal = dir.join("journal.jsonl");
        let (mut src, _) = live_pair(&dir, &journal);
        src.next_batch(1).unwrap();
        assert_eq!(src.take_serve_stats().unwrap().staleness_steps, 0);
        src.next_batch(1).unwrap();
        assert_eq!(src.take_serve_stats().unwrap().staleness_steps, 1);
        src.next_batch(1).unwrap();
        assert_eq!(src.take_serve_stats().unwrap().staleness_steps, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn staleness_bound_is_a_hard_error() {
        let dir = spool_dir("bound");
        write_seg(
            &dir,
            "seg.jsonl",
            &[
                rollout("a", &[1]),
                "{\"session\":\"a\",\"end\":true}".into(),
                rollout("b", &[2]),
                "{\"session\":\"b\",\"end\":true}".into(),
                rollout("c", &[3]),
                "{\"session\":\"c\",\"end\":true}".into(),
                "{\"shutdown\":true}".into(),
            ],
        );
        let journal = dir.join("journal.jsonl");
        let shared = ServeShared::default();
        let w = Arc::new(Mutex::new(JournalWriter::create(&journal).unwrap()));
        let mut c = cfg();
        c.staleness_bound = 1;
        let mut src = LiveSource::live(&dir, c, w, shared).unwrap();
        src.next_batch(1).unwrap();
        src.next_batch(1).unwrap(); // staleness 1 == bound: allowed
        let err = src.next_batch(1).unwrap_err().to_string();
        assert!(err.contains("staleness"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn next_tree_is_refused() {
        let dir = spool_dir("whole");
        write_seg(&dir, "seg.jsonl", &[rollout("s", &[1])]);
        let journal = dir.join("journal.jsonl");
        let (mut src, _) = live_pair(&dir, &journal);
        assert!(src.next_tree().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
