//! The ingestion spool: a watched directory of append-only JSONL segments
//! that concurrent producers write rollout records into.
//!
//! Contract with producers (kept deliberately thin so any process that can
//! append lines to a file can feed the trainer):
//!
//! * each producer owns one or more `*.jsonl` segment files in the spool
//!   directory and only ever **appends whole lines** to them;
//! * a line is either a [`crate::ingest::RolloutRecord`], a session end
//!   marker `{"session": "...", "end": true}`, or the global shutdown
//!   marker `{"shutdown": true}`;
//! * files are never truncated or rewritten (rotation = start a new file).
//!
//! The watcher polls: it re-scans the directory for new `*.jsonl` segments
//! and re-reads each known segment to its current EOF, buffering the bytes
//! after the last complete newline until the producer finishes the line
//! (torn writes are invisible to the fold).  Consumption order is
//! **deterministic given the bytes on disk at each poll**: segments are
//! walked in lexicographic filename order and a segment is drained to its
//! last complete line before the next is consulted.  Live arrival order is
//! still timing-dependent across polls — that is exactly what the journal
//! records (file, line) coordinates to pin down for replay.
//!
//! These files *grow concurrently*, so this reader must stay on plain
//! `read` calls — never [`crate::util::mmap::Mmap`], whose length is fixed
//! at map time (see that module's docs).

use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};

use crate::ingest::RolloutRecord;
use crate::util::json::Json;
use crate::Result;

/// One decoded spool line.
#[derive(Debug, Clone, PartialEq)]
pub enum SpoolRecord {
    Record(RolloutRecord),
    /// `{"session": "...", "end": true}` — the producer finished this
    /// session; its tree is ripe now.
    End { session: String },
    /// `{"shutdown": true}` — quiesce: flush everything and stop pumping.
    Shutdown,
}

impl SpoolRecord {
    pub fn parse(v: &Json) -> Result<Self> {
        if v.get("shutdown").and_then(|x| x.as_bool()) == Some(true) {
            return Ok(SpoolRecord::Shutdown);
        }
        if v.get("end").and_then(|x| x.as_bool()) == Some(true) {
            return Ok(SpoolRecord::End { session: v.req_str("session")?.to_string() });
        }
        Ok(SpoolRecord::Record(RolloutRecord::from_json(v)?))
    }
}

/// An undecoded line with its provenance — the coordinate the journal
/// records so replay can find the identical bytes.
#[derive(Debug)]
pub struct SpoolLine {
    /// Segment basename (spool-relative, so journals relocate with the
    /// spool directory).
    pub file: String,
    /// 1-based *physical* line number within the segment (blank lines
    /// count, so the coordinate matches what an editor shows).
    pub line: u64,
    pub raw: String,
}

impl SpoolLine {
    pub fn decode(&self) -> Result<SpoolRecord> {
        Json::parse(&self.raw)
            .and_then(|v| SpoolRecord::parse(&v))
            .map_err(|e| anyhow::anyhow!("{}:{}: {e}", self.file, self.line))
    }
}

/// Tail state for one growing segment file.
struct Segment {
    f: File,
    /// Bytes after the last newline seen so far (a torn producer write).
    partial: Vec<u8>,
    /// Complete lines read but not yet consumed, with physical line numbers.
    ready: VecDeque<(u64, String)>,
    /// Physical lines fully read off this segment so far.
    line_no: u64,
}

impl Segment {
    fn open(path: &Path) -> std::io::Result<Self> {
        Ok(Self { f: File::open(path)?, partial: Vec::new(), ready: VecDeque::new(), line_no: 0 })
    }

    /// Read to the segment's current EOF, splitting complete lines into
    /// `ready`.  Blank lines advance the physical line counter but are not
    /// queued (the fold never sees them — mirroring the corpus reader).
    fn refill(&mut self) -> std::io::Result<()> {
        let mut buf = [0u8; 64 * 1024];
        loop {
            let n = self.f.read(&mut buf)?;
            if n == 0 {
                break;
            }
            self.partial.extend_from_slice(&buf[..n]);
        }
        while let Some(pos) = self.partial.iter().position(|&b| b == b'\n') {
            let mut line: Vec<u8> = self.partial.drain(..=pos).collect();
            line.pop(); // the \n
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            self.line_no += 1;
            if line.iter().all(|b| b.is_ascii_whitespace()) {
                continue;
            }
            let s = String::from_utf8(line).map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
            })?;
            self.ready.push_back((self.line_no, s));
        }
        Ok(())
    }
}

/// Polling watcher over a spool directory.
pub struct SpoolWatcher {
    dir: PathBuf,
    /// Keyed by basename: BTreeMap gives the lexicographic walk order.
    segments: BTreeMap<String, Segment>,
}

impl SpoolWatcher {
    pub fn open(dir: &Path) -> Result<Self> {
        anyhow::ensure!(dir.is_dir(), "spool {} is not a directory", dir.display());
        let mut w = Self { dir: dir.to_path_buf(), segments: BTreeMap::new() };
        w.rescan()?;
        Ok(w)
    }

    /// Pick up newly created `*.jsonl` segments.
    fn rescan(&mut self) -> Result<()> {
        for entry in std::fs::read_dir(&self.dir)
            .map_err(|e| anyhow::anyhow!("spool {}: {e}", self.dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if !name.ends_with(".jsonl") || self.segments.contains_key(&name) {
                continue;
            }
            self.segments.insert(name, Segment::open(&entry.path())?);
        }
        Ok(())
    }

    /// Next complete line, or `None` if every segment is drained to its
    /// current EOF (the caller decides whether to sleep-and-retry or give
    /// up — back-pressure policy lives in the source, not here).
    ///
    /// Two passes with a directory rescan between them, so a freshly
    /// created segment is seen without waiting for the next poll cycle.
    pub fn next_line(&mut self) -> Result<Option<SpoolLine>> {
        for pass in 0..2 {
            for (name, seg) in self.segments.iter_mut() {
                if seg.ready.is_empty() {
                    seg.refill().map_err(|e| anyhow::anyhow!("spool {name}: {e}"))?;
                }
                if let Some((line, raw)) = seg.ready.pop_front() {
                    return Ok(Some(SpoolLine { file: name.clone(), line, raw }));
                }
            }
            if pass == 0 {
                self.rescan()?;
            }
        }
        Ok(None)
    }
}

/// Replay-side reader: sequential cursors into *finished* spool segments,
/// addressed by the `(file, line)` coordinates the journal recorded.
pub struct SpoolCursors {
    dir: PathBuf,
    cursors: BTreeMap<String, SegmentCursor>,
}

struct SegmentCursor {
    r: BufReader<File>,
    line_no: u64,
}

impl SegmentCursor {
    /// Advance to physical line `target` (1-based) and return it.  Journal
    /// line numbers within one file are strictly increasing (the live
    /// watcher consumes each segment front-to-back), so a plain forward
    /// scan suffices — seeking backwards is a corrupted-journal error.
    fn line_at(&mut self, target: u64, file: &str) -> Result<String> {
        anyhow::ensure!(
            target > self.line_no,
            "journal rewinds {file} to line {target} (already at {}) — journal/spool mismatch",
            self.line_no
        );
        let mut buf = String::new();
        loop {
            buf.clear();
            let n = self.r.read_line(&mut buf)?;
            anyhow::ensure!(n > 0, "{file}:{target}: spool ended early (journal/spool mismatch)");
            self.line_no += 1;
            if self.line_no == target {
                while buf.ends_with('\n') || buf.ends_with('\r') {
                    buf.pop();
                }
                return Ok(buf);
            }
        }
    }
}

impl SpoolCursors {
    pub fn open(dir: &Path) -> Result<Self> {
        anyhow::ensure!(dir.is_dir(), "spool {} is not a directory", dir.display());
        Ok(Self { dir: dir.to_path_buf(), cursors: BTreeMap::new() })
    }

    pub fn line_at(&mut self, file: &str, line: u64) -> Result<SpoolLine> {
        if !self.cursors.contains_key(file) {
            anyhow::ensure!(
                !file.contains('/') && !file.contains('\\') && file != "..",
                "journal names a non-basename segment {file:?}"
            );
            let path = self.dir.join(file);
            let f = File::open(&path)
                .map_err(|e| anyhow::anyhow!("spool segment {}: {e}", path.display()))?;
            self.cursors
                .insert(file.to_string(), SegmentCursor { r: BufReader::new(f), line_no: 0 });
        }
        let cur = self.cursors.get_mut(file).expect("just inserted");
        let raw = cur.line_at(line, file)?;
        Ok(SpoolLine { file: file.to_string(), line, raw })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tt-spool-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn append(dir: &Path, file: &str, text: &str) {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join(file))
            .unwrap();
        f.write_all(text.as_bytes()).unwrap();
    }

    #[test]
    fn parses_records_markers_and_shutdown() {
        let rec = Json::parse(r#"{"session":"s","tokens":[1,2]}"#).unwrap();
        assert!(matches!(SpoolRecord::parse(&rec).unwrap(), SpoolRecord::Record(_)));
        let end = Json::parse(r#"{"session":"s","end":true}"#).unwrap();
        assert_eq!(SpoolRecord::parse(&end).unwrap(), SpoolRecord::End { session: "s".into() });
        let down = Json::parse(r#"{"shutdown":true}"#).unwrap();
        assert_eq!(SpoolRecord::parse(&down).unwrap(), SpoolRecord::Shutdown);
        // end:false is NOT a marker — it must parse as a record (and fail,
        // since it has no tokens)
        let not_end = Json::parse(r#"{"session":"s","end":false}"#).unwrap();
        assert!(SpoolRecord::parse(&not_end).is_err());
    }

    #[test]
    fn watcher_walks_segments_in_name_order_and_tails_growth() {
        let dir = tmpdir("tail");
        append(&dir, "b.jsonl", "{\"x\":3}\n");
        append(&dir, "a.jsonl", "{\"x\":1}\n{\"x\":2}\n");
        let mut w = SpoolWatcher::open(&dir).unwrap();
        let got = |w: &mut SpoolWatcher| {
            let l = w.next_line().unwrap().unwrap();
            (l.file.clone(), l.line, l.raw.clone())
        };
        assert_eq!(got(&mut w), ("a.jsonl".into(), 1, "{\"x\":1}".into()));
        assert_eq!(got(&mut w), ("a.jsonl".into(), 2, "{\"x\":2}".into()));
        assert_eq!(got(&mut w), ("b.jsonl".into(), 1, "{\"x\":3}".into()));
        assert!(w.next_line().unwrap().is_none(), "drained");
        // producer appends more + a brand-new segment; same watcher sees both
        append(&dir, "a.jsonl", "{\"x\":4}\n");
        append(&dir, "c.jsonl", "{\"x\":5}\n");
        assert_eq!(got(&mut w), ("a.jsonl".into(), 3, "{\"x\":4}".into()));
        assert_eq!(got(&mut w), ("c.jsonl".into(), 1, "{\"x\":5}".into()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn watcher_holds_torn_lines_until_the_newline_lands() {
        let dir = tmpdir("torn");
        append(&dir, "s.jsonl", "{\"x\":1}\n{\"x\":");
        let mut w = SpoolWatcher::open(&dir).unwrap();
        assert_eq!(w.next_line().unwrap().unwrap().raw, "{\"x\":1}");
        assert!(w.next_line().unwrap().is_none(), "half a line is not a line");
        append(&dir, "s.jsonl", "2}\n");
        let l = w.next_line().unwrap().unwrap();
        assert_eq!((l.line, l.raw.as_str()), (2, "{\"x\":2}"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blank_lines_count_physically_but_are_not_served() {
        let dir = tmpdir("blank");
        append(&dir, "s.jsonl", "{\"x\":1}\n\n  \n{\"x\":2}\n");
        let mut w = SpoolWatcher::open(&dir).unwrap();
        assert_eq!(w.next_line().unwrap().unwrap().line, 1);
        let l = w.next_line().unwrap().unwrap();
        assert_eq!((l.line, l.raw.as_str()), (4, "{\"x\":2}"));
        // the replay cursor agrees on the coordinate
        let mut c = SpoolCursors::open(&dir).unwrap();
        assert_eq!(c.line_at("s.jsonl", 4).unwrap().raw, "{\"x\":2}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cursors_refuse_rewinds_and_short_files() {
        let dir = tmpdir("cursor");
        append(&dir, "s.jsonl", "{\"x\":1}\n{\"x\":2}\n");
        let mut c = SpoolCursors::open(&dir).unwrap();
        assert_eq!(c.line_at("s.jsonl", 2).unwrap().raw, "{\"x\":2}");
        let err = c.line_at("s.jsonl", 1).unwrap_err().to_string();
        assert!(err.contains("rewinds"), "got: {err}");
        let err = c.line_at("s.jsonl", 99).unwrap_err().to_string();
        assert!(err.contains("ended early"), "got: {err}");
        assert!(c.line_at("../evil.jsonl", 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
