//! The serve replay journal: an append-only JSONL record of every
//! *admission decision* the live service makes, precise enough that
//! `tree-train serve --replay <journal>` re-executes the run bit-for-bit.
//!
//! What gets journaled (one JSON object per line, tagged by `"ev"`):
//!
//! * `config`  — the full [`super::ServeParams`] snapshot (replay ignores
//!   the CLI's ripeness flags and trusts this header instead).
//! * `arrive`  — one spool record folded: its fold sequence number plus the
//!   (segment file, physical line) coordinate it was read from.  Replay
//!   re-reads the *same spool bytes* at that coordinate, so the journal
//!   stays small: it records positions, not payloads.
//! * `ripe`    — a session's tree became cuttable (end marker / idle /
//!   LRU pressure / quiesce) and its trees entered the ripe queue.
//! * `quiesce` — the shutdown marker was folded; all open sessions were
//!   flushed (their individual `ripe` events precede this line).
//! * `cut`     — a batch was cut: the FIFO prefix of the ripe queue up to
//!   `upto_seq`, fingerprinted with FNV-1a over the full tree contents.
//! * `loss`    — the executed step's loss and LR as exact f64 bit patterns
//!   (hex strings — JSON doubles would round-trip, but hex makes the
//!   bit-exactness contract impossible to miss).
//! * `stats`   — final [`IngestStats`] + executed step count, written
//!   last; replay verifies its own totals against it.
//!
//! Why positions instead of payloads: the spool is already the durable
//! record of *what* arrived; the journal is the durable record of *when it
//! was admitted and what was decided*.  Replaying therefore needs both
//! files — which also means replay catches spool tampering (a changed
//! token changes a tree fingerprint and the cut check fails).

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::Arc;

use crate::ingest::IngestStats;
use crate::tree::node::TrajectoryTree;
use crate::util::json::Json;
use crate::Result;

/// FNV-1a 64-bit.  Same constants as the coordinator's batch
/// fingerprinter (`coordinator/pipeline.rs`), re-declared here because
/// that helper is deliberately private to its module.
fn fnv1a(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// Content fingerprint of one trajectory tree: node count, then per node
/// the parent index, real length, real tokens, and the f32 bit patterns of
/// the supervision vectors.  Everything the executor's loss can depend on
/// is folded in; padding layout is not (it is derived downstream).
pub fn tree_fingerprint(tree: &TrajectoryTree) -> u64 {
    let mut h = fnv1a(&(tree.nodes.len() as u64).to_le_bytes(), FNV_OFFSET);
    for n in &tree.nodes {
        h = fnv1a(&(n.parent as i64).to_le_bytes(), h);
        let real = n.real_len();
        h = fnv1a(&(real as u64).to_le_bytes(), h);
        for &t in &n.tokens[..real] {
            h = fnv1a(&t.to_le_bytes(), h);
        }
        for &w in &n.trainable[..real] {
            h = fnv1a(&w.to_bits().to_le_bytes(), h);
        }
        for &a in &n.advantage[..real] {
            h = fnv1a(&a.to_bits().to_le_bytes(), h);
        }
    }
    h
}

/// Fingerprint of one cut batch: the step index plus each member tree's
/// fingerprint, in cut order.  Order-sensitive on purpose — the batch
/// composition contract covers ordering, not just membership.
pub fn batch_fingerprint(step: usize, trees: &[Arc<TrajectoryTree>]) -> u64 {
    let mut h = fnv1a(&(step as u64).to_le_bytes(), FNV_OFFSET);
    for t in trees {
        h = fnv1a(&tree_fingerprint(t).to_le_bytes(), h);
    }
    h
}

/// Why a session's tree entered the ripe queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RipeReason {
    /// Producer wrote an explicit `{"session": .., "end": true}` marker.
    End,
    /// No record touched the session for `idle_timeout` fold steps.
    Idle,
    /// Evicted by `max_open_sessions` LRU pressure.
    Lru,
    /// Flushed by the shutdown marker.
    Quiesce,
}

impl RipeReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RipeReason::End => "end",
            RipeReason::Idle => "idle",
            RipeReason::Lru => "lru",
            RipeReason::Quiesce => "quiesce",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "end" => RipeReason::End,
            "idle" => RipeReason::Idle,
            "lru" => RipeReason::Lru,
            "quiesce" => RipeReason::Quiesce,
            other => anyhow::bail!("unknown ripe reason {other:?}"),
        })
    }
}

/// One journal line.  u64 bit values (`fp`, `loss`, `lr`) are serialized
/// as `"0x…"` hex strings so no numeric round-trip is involved.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Config(Json),
    Arrive { seq: u64, file: String, line: u64 },
    Ripe { seq: u64, session: String, reason: RipeReason, trees: u64 },
    Quiesce { seq: u64 },
    Cut {
        step: u64,
        /// Highest fold sequence number applied before this cut — replay
        /// pumps exactly this far, decoupling batch composition from the
        /// live run's pump/cut thread interleaving.
        upto_seq: u64,
        trees: u64,
        fp: u64,
        max_staleness: u64,
        queue_depth: u64,
        admitted: u64,
    },
    Loss { step: u64, loss_bits: u64, lr_bits: u64 },
    Stats { steps: u64, stats: IngestStats },
}

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:#018x}"))
}

fn parse_hex(v: &Json, key: &str) -> Result<u64> {
    let s = v
        .req_str(key)
        .map_err(|_| anyhow::anyhow!("journal `{key}` must be a \"0x…\" string"))?;
    let digits = s
        .strip_prefix("0x")
        .ok_or_else(|| anyhow::anyhow!("journal `{key}` missing 0x prefix: {s:?}"))?;
    u64::from_str_radix(digits, 16).map_err(|e| anyhow::anyhow!("journal `{key}` {s:?}: {e}"))
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    v.req(key)?.as_u64().ok_or_else(|| anyhow::anyhow!("journal `{key}` not a u64"))
}

impl Event {
    pub fn to_json(&self) -> Json {
        match self {
            Event::Config(params) => {
                Json::obj(vec![("ev", Json::str("config")), ("params", params.clone())])
            }
            Event::Arrive { seq, file, line } => Json::obj(vec![
                ("ev", Json::str("arrive")),
                ("seq", Json::num(*seq as f64)),
                ("file", Json::str(file)),
                ("line", Json::num(*line as f64)),
            ]),
            Event::Ripe { seq, session, reason, trees } => Json::obj(vec![
                ("ev", Json::str("ripe")),
                ("seq", Json::num(*seq as f64)),
                ("session", Json::str(session)),
                ("reason", Json::str(reason.as_str())),
                ("trees", Json::num(*trees as f64)),
            ]),
            Event::Quiesce { seq } => {
                Json::obj(vec![("ev", Json::str("quiesce")), ("seq", Json::num(*seq as f64))])
            }
            Event::Cut { step, upto_seq, trees, fp, max_staleness, queue_depth, admitted } => {
                Json::obj(vec![
                    ("ev", Json::str("cut")),
                    ("step", Json::num(*step as f64)),
                    ("upto_seq", Json::num(*upto_seq as f64)),
                    ("trees", Json::num(*trees as f64)),
                    ("fp", hex(*fp)),
                    ("max_staleness", Json::num(*max_staleness as f64)),
                    ("queue_depth", Json::num(*queue_depth as f64)),
                    ("admitted", Json::num(*admitted as f64)),
                ])
            }
            Event::Loss { step, loss_bits, lr_bits } => Json::obj(vec![
                ("ev", Json::str("loss")),
                ("step", Json::num(*step as f64)),
                ("loss", hex(*loss_bits)),
                ("lr", hex(*lr_bits)),
            ]),
            Event::Stats { steps, stats } => Json::obj(vec![
                ("ev", Json::str("stats")),
                ("steps", Json::num(*steps as f64)),
                ("ingest", stats.to_json()),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let ev = v.req_str("ev")?;
        Ok(match ev {
            "config" => Event::Config(v.req("params")?.clone()),
            "arrive" => Event::Arrive {
                seq: req_u64(v, "seq")?,
                file: v.req_str("file")?.to_string(),
                line: req_u64(v, "line")?,
            },
            "ripe" => Event::Ripe {
                seq: req_u64(v, "seq")?,
                session: v.req_str("session")?.to_string(),
                reason: RipeReason::parse(v.req_str("reason")?)?,
                trees: req_u64(v, "trees")?,
            },
            "quiesce" => Event::Quiesce { seq: req_u64(v, "seq")? },
            "cut" => Event::Cut {
                step: req_u64(v, "step")?,
                upto_seq: req_u64(v, "upto_seq")?,
                trees: req_u64(v, "trees")?,
                fp: parse_hex(v, "fp")?,
                max_staleness: req_u64(v, "max_staleness")?,
                queue_depth: req_u64(v, "queue_depth")?,
                admitted: req_u64(v, "admitted")?,
            },
            "loss" => Event::Loss {
                step: req_u64(v, "step")?,
                loss_bits: parse_hex(v, "loss")?,
                lr_bits: parse_hex(v, "lr")?,
            },
            "stats" => Event::Stats {
                steps: req_u64(v, "steps")?,
                stats: IngestStats::from_json(v.req("ingest")?)?,
            },
            other => anyhow::bail!("unknown journal event {other:?}"),
        })
    }
}

/// Append-only journal writer.  Flushes after every event: the journal is
/// the crash-recovery record, so a torn tail must be at most one line.
pub struct JournalWriter {
    w: BufWriter<File>,
}

impl JournalWriter {
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path)
            .map_err(|e| anyhow::anyhow!("create journal {}: {e}", path.display()))?;
        Ok(Self { w: BufWriter::new(f) })
    }

    pub fn append(&mut self, ev: &Event) -> Result<()> {
        let line = ev.to_json().to_string();
        self.w.write_all(line.as_bytes())?;
        self.w.write_all(b"\n")?;
        self.w.flush()?;
        Ok(())
    }
}

/// Read a whole journal back as events, with `path:line` error context.
pub fn read_journal(path: &Path) -> Result<Vec<Event>> {
    let mut reader = crate::util::jsonl::JsonlReader::open(path)?;
    let mut out = Vec::new();
    while let Some(ev) = reader.next_record(Event::from_json) {
        out.push(ev?);
    }
    Ok(out)
}

/// A parsed journal split into the shapes replay consumes:
///
/// * `params`  — the config header (a [`super::ServeParams`] JSON blob).
/// * `feed`    — arrive/ripe/quiesce/cut events in journal order.  These
///   four are written by the planner-side source under one lock, so their
///   relative order in the file is the admission order.
/// * `losses`  — step → (loss bits, lr bits), written by the executor side
///   (may interleave with feed events in the file; keyed lookup makes the
///   interleaving irrelevant).
/// * `stats`   — the final stats trailer.
pub struct ReplayScript {
    pub params: Json,
    pub feed: Vec<Event>,
    pub losses: std::collections::HashMap<u64, (u64, u64)>,
    pub steps: u64,
    pub stats: IngestStats,
}

impl ReplayScript {
    pub fn load(path: &Path) -> Result<Self> {
        let events = read_journal(path)?;
        let mut params = None;
        let mut feed = Vec::new();
        let mut losses = std::collections::HashMap::new();
        let mut trailer = None;
        for ev in events {
            match ev {
                Event::Config(p) => {
                    anyhow::ensure!(params.is_none(), "journal has two config headers");
                    params = Some(p);
                }
                Event::Loss { step, loss_bits, lr_bits } => {
                    losses.insert(step, (loss_bits, lr_bits));
                }
                Event::Stats { steps, stats } => {
                    anyhow::ensure!(trailer.is_none(), "journal has two stats trailers");
                    trailer = Some((steps, stats));
                }
                other => feed.push(other),
            }
        }
        let params = params.ok_or_else(|| anyhow::anyhow!("journal missing config header"))?;
        let (steps, stats) = trailer.ok_or_else(|| {
            anyhow::anyhow!("journal missing stats trailer (did the live run finish?)")
        })?;
        Ok(Self { params, feed, losses, steps, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::NodeSpec;

    fn tree(tokens: Vec<i32>) -> TrajectoryTree {
        TrajectoryTree::new(vec![NodeSpec::new(-1, tokens)]).unwrap()
    }

    #[test]
    fn fingerprint_is_content_sensitive_and_order_sensitive() {
        let a = Arc::new(tree(vec![1, 2, 3]));
        let b = Arc::new(tree(vec![1, 2, 4]));
        assert_ne!(tree_fingerprint(&a), tree_fingerprint(&b));
        assert_eq!(tree_fingerprint(&a), tree_fingerprint(&a.clone()));
        let ab = batch_fingerprint(0, &[a.clone(), b.clone()]);
        let ba = batch_fingerprint(0, &[b, a]);
        assert_ne!(ab, ba, "batch fingerprint must cover ordering");
    }

    #[test]
    fn fingerprint_covers_supervision_bits() {
        let base = tree(vec![5, 6]);
        let mut adv = base.clone();
        adv.nodes[0].advantage[1] = 0.25;
        assert_ne!(tree_fingerprint(&base), tree_fingerprint(&adv));
        let mut tr = base.clone();
        tr.nodes[0].trainable[0] = 0.0;
        assert_ne!(tree_fingerprint(&base), tree_fingerprint(&tr));
    }

    #[test]
    fn events_roundtrip_through_json() {
        let evs = vec![
            Event::Config(Json::obj(vec![("steps", Json::num(4.0))])),
            Event::Arrive { seq: 1, file: "seg-000.jsonl".into(), line: 3 },
            Event::Ripe { seq: 1, session: "s0".into(), reason: RipeReason::End, trees: 1 },
            Event::Quiesce { seq: 9 },
            Event::Cut {
                step: 0,
                upto_seq: 7,
                trees: 4,
                fp: 0xdeadbeefcafef00d,
                max_staleness: 2,
                queue_depth: 1,
                admitted: 3,
            },
            Event::Loss { step: 0, loss_bits: f64::to_bits(1.5), lr_bits: f64::to_bits(1e-3) },
            Event::Stats { steps: 4, stats: IngestStats { records_in: 12, ..Default::default() } },
        ];
        for ev in &evs {
            let j = Json::parse(&ev.to_json().to_string()).unwrap();
            assert_eq!(&Event::from_json(&j).unwrap(), ev, "roundtrip {ev:?}");
        }
    }

    #[test]
    fn hex_bit_patterns_survive_exactly() {
        // a loss whose decimal print would lose bits if anyone "helpfully"
        // reformatted it — hex encoding sidesteps the question entirely
        let bits = 0x3ff0000000000001u64; // 1.0 + 1 ulp
        let ev = Event::Loss { step: 3, loss_bits: bits, lr_bits: f64::to_bits(0.1) };
        let j = Json::parse(&ev.to_json().to_string()).unwrap();
        match Event::from_json(&j).unwrap() {
            Event::Loss { loss_bits, .. } => assert_eq!(loss_bits, bits),
            other => panic!("wrong event {other:?}"),
        }
        assert!(j.get("loss").unwrap().as_str().unwrap().starts_with("0x"));
    }

    #[test]
    fn writer_and_reader_roundtrip_and_script_splits() {
        let dir = std::env::temp_dir()
            .join(format!("tt-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        let mut w = JournalWriter::create(&path).unwrap();
        w.append(&Event::Config(Json::obj(vec![("vocab", Json::num(64.0))]))).unwrap();
        w.append(&Event::Arrive { seq: 1, file: "a.jsonl".into(), line: 1 }).unwrap();
        w.append(&Event::Loss { step: 0, loss_bits: 7, lr_bits: 8 }).unwrap();
        w.append(&Event::Ripe { seq: 1, session: "s".into(), reason: RipeReason::Lru, trees: 1 })
            .unwrap();
        w.append(&Event::Stats { steps: 1, stats: IngestStats::default() }).unwrap();
        drop(w);
        let script = ReplayScript::load(&path).unwrap();
        assert_eq!(script.params.get("vocab").unwrap().as_u64(), Some(64));
        assert_eq!(script.feed.len(), 2, "arrive + ripe stay in feed order");
        assert_eq!(script.losses.get(&0), Some(&(7, 8)));
        assert_eq!(script.steps, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn script_load_rejects_truncated_journals() {
        let dir = std::env::temp_dir()
            .join(format!("tt-journal-trunc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        let mut w = JournalWriter::create(&path).unwrap();
        w.append(&Event::Config(Json::obj(vec![]))).unwrap();
        drop(w);
        let err = ReplayScript::load(&path).unwrap_err().to_string();
        assert!(err.contains("stats trailer"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
