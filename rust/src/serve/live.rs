//! The live folder: open-session tries plus the deterministic ripeness
//! policy.
//!
//! Unlike the batch [`crate::ingest::SessionFolder`] (which flushes only
//! on LRU pressure and at end-of-corpus), a serving folder must decide
//! *while the stream is still running* when a session's tree is cuttable.
//! Three triggers, checked in a fixed order inside each fold step so the
//! verdict is a pure function of the arrival sequence:
//!
//! 1. **End marker** — the producer says the session is done.  Flush it
//!    immediately ([`RipeReason::End`]).
//! 2. **LRU pressure** — more than `max_open_sessions` tries are open
//!    after applying the record.  Flush least-recently-touched until back
//!    under the cap ([`RipeReason::Lru`]).
//! 3. **Idle timeout** — a session untouched for more than `idle_timeout`
//!    *fold steps* (not wall clock!  wall clock would make ripeness
//!    timing-dependent and kill replay) is flushed ([`RipeReason::Idle`]),
//!    scanned in ascending last-touch order.
//!
//! Recency is tracked by fold sequence number.  Each fold touches exactly
//! one session, so last-touch values are unique and a
//! `BTreeMap<last_seq, session>` gives a deterministic LRU order for free.

use std::collections::{BTreeMap, HashMap};

use crate::ingest::trie::PrefixStore;
use crate::ingest::IngestStats;
use crate::tree::node::TrajectoryTree;
use crate::Result;

use super::journal::RipeReason;
use super::spool::SpoolRecord;

/// One ripened session: its emitted trees plus why it ripened.  Trees are
/// in the store's deterministic emit order.
#[derive(Debug)]
pub struct RipeGroup {
    pub session: String,
    pub reason: RipeReason,
    pub trees: Vec<TrajectoryTree>,
}

struct OpenSession {
    store: PrefixStore,
    /// Fold sequence number of the last record that touched this session.
    last_seq: u64,
}

/// Open-session state + ripeness policy.  `fold` is the only mutation
/// entry point, which is what makes live and replay behavior identical:
/// both sides call it with the same records in the same order.
pub struct LiveFolder {
    max_open: usize,
    /// Idle flush threshold in fold steps; 0 disables idle flushing.
    idle_timeout: u64,
    max_seq_len: Option<usize>,
    open: HashMap<String, OpenSession>,
    /// last_seq → session, ascending = least recently touched first.
    by_touch: BTreeMap<u64, String>,
    stats: IngestStats,
}

impl LiveFolder {
    pub fn new(max_open: usize, idle_timeout: u64, max_seq_len: Option<usize>) -> Self {
        assert!(max_open >= 1, "need at least one open session");
        Self {
            max_open,
            idle_timeout,
            max_seq_len,
            open: HashMap::new(),
            by_touch: BTreeMap::new(),
            stats: IngestStats::default(),
        }
    }

    pub fn open_sessions(&self) -> usize {
        self.open.len()
    }

    /// Cumulative stats over everything flushed so far.
    pub fn stats(&self) -> IngestStats {
        self.stats
    }

    fn flush(&mut self, session: &str, reason: RipeReason) -> RipeGroup {
        let s = self.open.remove(session).expect("flushing a session that is not open");
        self.by_touch.remove(&s.last_seq);
        let (trees, delta) = crate::ingest::stream::flush_delta(s.store, self.max_seq_len);
        self.stats.absorb(&delta);
        RipeGroup { session: session.to_string(), reason, trees }
    }

    /// Fold one spool record under fold sequence number `seq` (strictly
    /// increasing, one per folded line).  Returns the sessions that
    /// ripened, in verdict order: end-marker flush first, then LRU
    /// evictions, then idle flushes.
    ///
    /// A `Shutdown` record is NOT handled here — the caller sees it in the
    /// stream and calls [`Self::quiesce`]; keeping the terminal transition
    /// out of `fold` means `fold` never consumes the folder.
    pub fn fold(&mut self, seq: u64, rec: &SpoolRecord) -> Result<Vec<RipeGroup>> {
        let mut out = Vec::new();
        match rec {
            SpoolRecord::Shutdown => {
                anyhow::bail!("shutdown marker must go through LiveFolder::quiesce")
            }
            SpoolRecord::End { session } => {
                // end marker for an unknown (never seen or already
                // flushed) session is a no-op: producers may double-end
                // defensively, and an LRU eviction can race a marker
                if self.open.contains_key(session.as_str()) {
                    out.push(self.flush(session, RipeReason::End));
                }
            }
            SpoolRecord::Record(r) => {
                let entry = self.open.entry(r.session.clone());
                let s = match entry {
                    std::collections::hash_map::Entry::Occupied(o) => {
                        let s = o.into_mut();
                        self.by_touch.remove(&s.last_seq);
                        s
                    }
                    std::collections::hash_map::Entry::Vacant(v) => {
                        v.insert(OpenSession { store: PrefixStore::new(), last_seq: 0 })
                    }
                };
                s.store.insert(&r.tokens, &r.trainable, &r.advantage)?;
                s.last_seq = seq;
                self.by_touch.insert(seq, r.session.clone());
                // LRU pressure after the insert, oldest first
                while self.open.len() > self.max_open {
                    let victim =
                        self.by_touch.values().next().expect("open implies by_touch").clone();
                    out.push(self.flush(&victim, RipeReason::Lru));
                }
            }
        }
        // idle scan last: ascending last-touch, stop at the first session
        // inside the window (BTreeMap iteration is ordered)
        if self.idle_timeout > 0 {
            loop {
                let victim = match self.by_touch.iter().next() {
                    Some((&last, name)) if seq - last > self.idle_timeout => name.clone(),
                    _ => break,
                };
                out.push(self.flush(&victim, RipeReason::Idle));
            }
        }
        Ok(out)
    }

    /// Shutdown: flush every open session in ascending last-touch order.
    pub fn quiesce(&mut self) -> Vec<RipeGroup> {
        let order: Vec<String> = self.by_touch.values().cloned().collect();
        order.into_iter().map(|s| self.flush(&s, RipeReason::Quiesce)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::RolloutRecord;

    fn rec(session: &str, tokens: &[i32]) -> SpoolRecord {
        SpoolRecord::Record(RolloutRecord {
            session: session.into(),
            tokens: tokens.to_vec(),
            trainable: vec![1.0; tokens.len()],
            advantage: vec![1.0; tokens.len()],
        })
    }

    fn end(session: &str) -> SpoolRecord {
        SpoolRecord::End { session: session.into() }
    }

    fn names(groups: &[RipeGroup]) -> Vec<(&str, RipeReason)> {
        groups.iter().map(|g| (g.session.as_str(), g.reason)).collect()
    }

    #[test]
    fn end_marker_flushes_immediately_and_merges_prefixes() {
        let mut f = LiveFolder::new(8, 0, None);
        assert!(f.fold(1, &rec("s", &[1, 2, 3])).unwrap().is_empty());
        assert!(f.fold(2, &rec("s", &[1, 2, 4])).unwrap().is_empty());
        let groups = f.fold(3, &end("s")).unwrap();
        assert_eq!(names(&groups), vec![("s", RipeReason::End)]);
        let t = &groups[0].trees[0];
        assert!(t.nodes.len() >= 3, "shared [1,2] prefix split into a branch");
        assert_eq!(f.open_sessions(), 0);
        assert_eq!(f.stats().records_in, 2);
        assert_eq!(f.stats().sessions, 1);
        // double-end is a silent no-op
        assert!(f.fold(4, &end("s")).unwrap().is_empty());
    }

    #[test]
    fn lru_pressure_evicts_least_recently_touched() {
        let mut f = LiveFolder::new(2, 0, None);
        f.fold(1, &rec("a", &[1])).unwrap();
        f.fold(2, &rec("b", &[2])).unwrap();
        f.fold(3, &rec("a", &[1, 9])).unwrap(); // refresh a: b is now oldest
        let groups = f.fold(4, &rec("c", &[3])).unwrap();
        assert_eq!(names(&groups), vec![("b", RipeReason::Lru)]);
        assert_eq!(f.open_sessions(), 2);
    }

    #[test]
    fn idle_timeout_counts_fold_steps_not_wall_clock() {
        let mut f = LiveFolder::new(8, 2, None);
        f.fold(1, &rec("old", &[1])).unwrap();
        f.fold(2, &rec("hot", &[2])).unwrap();
        assert!(f.fold(3, &rec("hot", &[2, 5])).unwrap().is_empty(), "gap 2 = in window");
        let groups = f.fold(4, &rec("hot", &[2, 6])).unwrap();
        assert_eq!(names(&groups), vec![("old", RipeReason::Idle)]);
        // timeout 0 disables the scan entirely
        let mut g = LiveFolder::new(8, 0, None);
        g.fold(1, &rec("x", &[1])).unwrap();
        assert!(g.fold(1000, &rec("y", &[2])).unwrap().is_empty());
    }

    #[test]
    fn quiesce_flushes_everything_in_touch_order() {
        let mut f = LiveFolder::new(8, 0, None);
        f.fold(1, &rec("b", &[1])).unwrap();
        f.fold(2, &rec("a", &[2])).unwrap();
        f.fold(3, &rec("b", &[1, 7])).unwrap();
        let groups = f.quiesce();
        assert_eq!(
            names(&groups),
            vec![("a", RipeReason::Quiesce), ("b", RipeReason::Quiesce)],
            "ascending last-touch, not name order"
        );
        assert_eq!(f.open_sessions(), 0);
        assert!(f.quiesce().is_empty(), "idempotent");
    }

    #[test]
    fn verdict_order_is_deterministic_within_one_fold() {
        // one record can trigger LRU and idle flushes in the same step;
        // order must be: (no end) → LRU evictions → idle flushes
        let mut f = LiveFolder::new(2, 3, None);
        f.fold(1, &rec("idle1", &[1])).unwrap();
        f.fold(2, &rec("keep", &[2])).unwrap();
        // seq jumps to 6: inserting "new" overflows the cap (evict idle1,
        // the oldest) and then the idle scan catches nothing further
        // (keep: 6-2=4 > 3 → also idle!)
        let groups = f.fold(6, &rec("new", &[3])).unwrap();
        assert_eq!(
            names(&groups),
            vec![("idle1", RipeReason::Lru), ("keep", RipeReason::Idle)]
        );
    }

    #[test]
    fn shutdown_record_is_rejected_by_fold() {
        let mut f = LiveFolder::new(2, 0, None);
        assert!(f.fold(1, &SpoolRecord::Shutdown).is_err());
    }

    #[test]
    fn stats_match_the_batch_folder_on_the_same_stream() {
        // same records through LiveFolder (all end-flushed) and through
        // flush-by-quiesce must absorb to identical totals
        let recs =
            [("a", vec![1, 2, 3]), ("b", vec![1, 2]), ("a", vec![1, 2, 9]), ("b", vec![1, 2])];
        let mut f = LiveFolder::new(8, 0, None);
        for (i, (s, t)) in recs.iter().enumerate() {
            f.fold(i as u64 + 1, &rec(s, t)).unwrap();
        }
        let groups = f.quiesce();
        let trees: usize = groups.iter().map(|g| g.trees.len()).sum();
        let st = f.stats();
        assert_eq!(st.records_in, 4);
        assert_eq!(st.sessions, 2);
        assert_eq!(st.trees_out as usize, trees);
        assert_eq!(st.subsumed_records, 1, "b's duplicate rollout is subsumed");
    }
}
