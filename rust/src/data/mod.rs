//! Corpus sources: the data layer between on-disk corpora and the run loop.
//!
//! The run loop ([`crate::coordinator::pipeline`]) consumes one abstraction:
//! an endless, epoch-shuffled stream of reference-counted trees.
//! [`CorpusSource::next_tree`] hands out `Arc<TrajectoryTree>`, so global
//! batches are O(1) per tree to assemble (no per-step deep clones) and the
//! stream never drops an epoch tail — when `trees_per_batch` does not divide
//! the corpus, a batch simply spans the epoch boundary and every tree still
//! trains exactly once per epoch.
//!
//! Three implementations, one determinism contract:
//!
//! * [`ResidentSource`] — the whole corpus in memory (the seed behavior;
//!   also serves synthetic corpora, which are generated in memory anyway).
//! * [`StreamingTreeSource`] — a tree-format JSONL corpus read
//!   shard-by-shard: at most `shuffle_window` trees are resident at once,
//!   each epoch re-reads the file, so a multi-GB corpus trains in bounded
//!   memory.
//! * [`StreamingRolloutSource`] — raw linear rollout logs folded through
//!   the ingest radix trie ([`crate::ingest`]) shard-by-shard: resident
//!   memory is bounded by `shuffle_window` trees plus
//!   `max_open_sessions` open tries, never by corpus size.
//!
//! **Determinism contract** (verified by `tests/pipeline_equivalence.rs`):
//! epoch 0 is served in corpus order; every later epoch Fisher-Yates
//! shuffles each shard (a window of at most `shuffle_window` consecutive
//! trees; the whole corpus for the resident source) with the run-seed RNG.
//! Each epoch's permutation is drawn fresh from the continuing RNG stream,
//! so a streaming source whose window covers the corpus reproduces the
//! resident order *exactly* — streaming is a memory knob, not a data-order
//! change.  With a smaller window, shuffling is local to each shard: the
//! trade is shuffle globality for memory, never epoch coverage.

pub mod resident;
pub mod streaming;

pub use resident::ResidentSource;
pub use streaming::{StreamingRolloutSource, StreamingTreeSource};

use std::sync::Arc;

use crate::tree::TrajectoryTree;

/// An endless, epoch-shuffled stream of shared trees (see module docs for
/// the determinism contract).  `Send`, so the pipeline's planner thread can
/// own it.
pub trait CorpusSource: Send {
    /// Next tree in epoch-shuffled order; wraps to the next epoch at corpus
    /// end (batches therefore carry epoch tails instead of dropping them).
    fn next_tree(&mut self) -> crate::Result<Arc<TrajectoryTree>>;

    /// Assemble one global batch — `n` consecutive stream trees.
    fn next_batch(&mut self, n: usize) -> crate::Result<Vec<Arc<TrajectoryTree>>> {
        (0..n).map(|_| self.next_tree()).collect()
    }

    /// Trees per epoch when known without a corpus scan (resident sources;
    /// streaming sources learn it after the first full pass).
    fn epoch_len(&self) -> Option<usize>;

    /// Peak simultaneously-resident tree count observed so far — the
    /// memory-bound claim the streaming sources exist to make
    /// (≤ `shuffle_window` for tree corpora, ≤ `shuffle_window` + one
    /// session flush for rollout corpora; the corpus size for resident).
    fn peak_resident(&self) -> usize;

    /// Milliseconds spent ingesting (reading/folding rollouts) since the
    /// last call — drained, so the planner can attribute ingest time to
    /// the step that paid it (`StepMetrics::ingest_ms`).  Sources that
    /// serve pre-built trees report 0.
    fn take_ingest_ms(&mut self) -> f64 {
        0.0
    }

    /// One-line description for run logs.
    fn describe(&self) -> String;

    /// Serve-mode admission accounting for the batch most recently handed
    /// out by [`CorpusSource::next_batch`] — drained, so the planner can
    /// stamp the owning step ([`crate::trainer::StepMetrics`]'s
    /// `staleness_steps` / `ripe_queue_depth` / `admitted_sessions`).
    /// `None` for every source except the continuous-ingestion
    /// [`crate::serve::LiveSource`].
    fn take_serve_stats(&mut self) -> Option<ServeStepStats> {
        None
    }
}

/// Per-batch admission accounting from the continuous-ingestion service
/// (`tree-train serve`, docs/serve.md), drained through
/// [`CorpusSource::take_serve_stats`] and copied into the step's
/// [`crate::trainer::StepMetrics`] by the pipeline driver.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStepStats {
    /// Max optimizer steps any tree in the batch waited in the ripe queue
    /// (0 when every tree ripened since the previous cut).
    pub staleness_steps: u64,
    /// Ripe trees still queued after this batch was cut.
    pub ripe_queue_depth: u64,
    /// Sessions whose trees ripened since the previous cut.
    pub admitted_sessions: u64,
}
