//! Streaming corpus sources: bounded-memory shard-based epoch shuffling.
//!
//! Both sources serve the same contract as [`super::ResidentSource`] —
//! an endless epoch stream of `Arc` trees — while keeping at most one
//! *shard* (`shuffle_window` trees) resident.  An epoch is the file read
//! front to back as a sequence of shards; shards are shuffled internally
//! (epoch ≥ 1) with the continuing run-seed RNG and drained in order, so
//! the stream is deterministic and, when the window covers the corpus,
//! bit-identical to the resident source.  Each epoch re-reads (and for
//! rollouts, re-folds) the file — the deliberate trade of the paper's
//! "large trajectory trees in practice" regime: re-parsing is cheap and
//! sequential; corpus-sized RAM is not.

use std::collections::VecDeque;
use std::io::BufReader;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::ingest::{IngestConfig, IngestStats, ParallelIngest, RolloutReader, SessionFolder};
use crate::tree::io::{load_corpus_iter, CorpusIter};
use crate::tree::TrajectoryTree;
use crate::util::rng::Rng;

use super::CorpusSource;

/// Shared shard state: the drained-from queue, epoch/shuffle bookkeeping
/// and the peak-resident accounting both streaming sources report.
struct ShardState {
    window: usize,
    rng: Rng,
    shard: VecDeque<Arc<TrajectoryTree>>,
    /// Epochs *finished* (0 while the first pass is still streaming —
    /// shards of epoch 0 are served in corpus order, later ones shuffled).
    epochs_done: u64,
    seen_this_epoch: usize,
    epoch_len: Option<usize>,
    peak_resident: usize,
}

impl ShardState {
    fn new(window: usize, seed: u64) -> Self {
        Self {
            window,
            rng: crate::tree::gen::rng(seed),
            shard: VecDeque::new(),
            epochs_done: 0,
            seen_this_epoch: 0,
            epoch_len: None,
            peak_resident: 0,
        }
    }

    /// Install `buf` as the live shard (shuffled from epoch 1 on).
    fn install(&mut self, mut buf: Vec<Arc<TrajectoryTree>>) {
        debug_assert!(!buf.is_empty());
        self.seen_this_epoch += buf.len();
        if self.epochs_done > 0 {
            self.rng.shuffle(&mut buf);
        }
        self.peak_resident = self.peak_resident.max(buf.len());
        self.shard = buf.into();
    }

    /// Record an end-of-file; errors on an empty corpus.
    fn rollover(&mut self, path: &Path) -> crate::Result<()> {
        anyhow::ensure!(self.seen_this_epoch > 0, "empty corpus {}", path.display());
        self.epoch_len = Some(self.seen_this_epoch);
        self.seen_this_epoch = 0;
        self.epochs_done += 1;
        Ok(())
    }
}

/// Streaming source over a tree-format JSONL corpus (`tree/io.rs`): at most
/// `shuffle_window` trees resident, each epoch re-reads the file.
pub struct StreamingTreeSource {
    path: PathBuf,
    reader: Option<CorpusIter>,
    state: ShardState,
}

impl StreamingTreeSource {
    pub fn open(path: &Path, shuffle_window: usize, seed: u64) -> crate::Result<Self> {
        anyhow::ensure!(shuffle_window >= 1, "shuffle_window must be >= 1");
        let mut src = Self {
            path: path.to_path_buf(),
            reader: None,
            state: ShardState::new(shuffle_window, seed),
        };
        src.refill()?; // surface open/parse/empty errors at construction
        Ok(src)
    }

    fn refill(&mut self) -> crate::Result<()> {
        debug_assert!(self.state.shard.is_empty());
        loop {
            if self.reader.is_none() {
                self.reader = Some(load_corpus_iter(&self.path)?);
            }
            let reader = self.reader.as_mut().expect("just ensured");
            let mut buf = Vec::new();
            while buf.len() < self.state.window {
                match reader.next() {
                    Some(t) => buf.push(Arc::new(t?)),
                    None => break,
                }
            }
            if buf.is_empty() {
                // end of epoch: close, account, reopen on the next loop
                self.reader = None;
                self.state.rollover(&self.path)?;
                continue;
            }
            self.state.install(buf);
            return Ok(());
        }
    }
}

impl CorpusSource for StreamingTreeSource {
    fn next_tree(&mut self) -> crate::Result<Arc<TrajectoryTree>> {
        if self.state.shard.is_empty() {
            self.refill()?;
        }
        Ok(self.state.shard.pop_front().expect("refill leaves a non-empty shard"))
    }

    fn epoch_len(&self) -> Option<usize> {
        self.state.epoch_len
    }

    fn peak_resident(&self) -> usize {
        self.state.peak_resident
    }

    fn describe(&self) -> String {
        format!(
            "streaming trees: {} (window {})",
            self.path.display(),
            self.state.window
        )
    }
}

/// Streaming source over raw linear rollout logs: records fold through the
/// ingest radix trie ([`crate::ingest::SessionFolder`]) as they are read,
/// and completed trees are sharded/shuffled exactly like the tree source.
/// Resident memory: ≤ `shuffle_window` trees (plus the trees of at most one
/// session flush in flight) + `max_open_sessions` open tries — never the
/// corpus.  Each epoch re-folds the file; the fold is deterministic, so so
/// is the stream.
///
/// With `IngestConfig::threads > 1` the fold runs through the sharded
/// parallel ingester ([`ParallelIngest`], fresh per epoch) instead of the
/// inline folder.  Its tree order is bit-identical to the single-threaded
/// fold, so shard composition — and therefore the whole run — does not
/// depend on the thread count; only ingest wall time does.
pub struct StreamingRolloutSource {
    path: PathBuf,
    cfg: IngestConfig,
    reader: Option<RolloutReader<BufReader<std::fs::File>>>,
    folder: Option<SessionFolder>,
    /// Live parallel ingester (`cfg.threads > 1` only; one per epoch).
    par: Option<ParallelIngest>,
    /// Fold/pump milliseconds since the last [`CorpusSource::take_ingest_ms`].
    ingest_ms: f64,
    /// Folded trees not yet sharded (file order; carries the ≤ one-flush
    /// overshoot between shards).
    pending: VecDeque<Arc<TrajectoryTree>>,
    /// The file is exhausted and `pending` holds the epoch tail: serve it
    /// out as shards *before* accounting the epoch boundary and re-folding.
    rollover_due: bool,
    state: ShardState,
    /// First-epoch ingest accounting (logged once at the first epoch end).
    stats: Option<IngestStats>,
}

impl StreamingRolloutSource {
    pub fn open(
        path: &Path,
        cfg: IngestConfig,
        shuffle_window: usize,
        seed: u64,
    ) -> crate::Result<Self> {
        anyhow::ensure!(shuffle_window >= 1, "shuffle_window must be >= 1");
        let mut src = Self {
            path: path.to_path_buf(),
            cfg,
            reader: None,
            folder: None,
            par: None,
            ingest_ms: 0.0,
            pending: VecDeque::new(),
            rollover_due: false,
            state: ShardState::new(shuffle_window, seed),
            stats: None,
        };
        src.refill()?;
        Ok(src)
    }

    /// First-epoch ingest statistics, once the first full fold completed.
    pub fn stats(&self) -> Option<&IngestStats> {
        self.stats.as_ref()
    }

    fn track_peak(&mut self) {
        let resident = self.pending.len() + self.state.shard.len();
        self.state.peak_resident = self.state.peak_resident.max(resident);
    }

    /// Log + record the first full epoch's fold statistics.
    fn note_first_epoch(&mut self, stats: IngestStats) {
        if self.stats.is_none() && stats.records_in > 0 {
            crate::info!(
                "ingest(stream): {} rollouts ({} sessions) -> {} trees, \
                 measured prefix-reuse {:.2}x ({} -> {} tokens)",
                stats.records_in,
                stats.sessions,
                stats.trees_out,
                stats.reuse_ratio(),
                stats.rollout_tokens_in,
                stats.tree_tokens_out
            );
            self.stats = Some(stats);
        }
    }

    /// Fold records into `pending` until a full window is buffered or the
    /// epoch ends; `true` when the epoch ended.  The wall time spent here
    /// accumulates into `ingest_ms`.
    fn pump(&mut self) -> crate::Result<bool> {
        let t0 = std::time::Instant::now();
        let ended =
            if self.cfg.threads > 1 { self.pump_parallel() } else { self.pump_serial() };
        self.ingest_ms += t0.elapsed().as_secs_f64() * 1e3;
        ended
    }

    /// Parallel fold: pull trees (in single-thread-identical order) from a
    /// per-epoch [`ParallelIngest`]; workers pause on backpressure while
    /// the window is full.
    fn pump_parallel(&mut self) -> crate::Result<bool> {
        if self.par.is_none() {
            self.par = Some(ParallelIngest::spawn_path(&self.path, &self.cfg, self.cfg.threads)?);
        }
        while self.pending.len() < self.state.window {
            // re-borrow per pull so `pending`/`track_peak` stay reachable
            match self.par.as_mut().expect("just ensured").next_tree() {
                Some(t) => {
                    self.pending.push_back(Arc::new(t?));
                    self.track_peak();
                }
                None => {
                    let report = self.par.take().expect("checked above").finish()?;
                    self.note_first_epoch(report.stats);
                    return Ok(true);
                }
            }
        }
        Ok(false)
    }

    fn pump_serial(&mut self) -> crate::Result<bool> {
        if self.folder.is_none() {
            self.folder = Some(SessionFolder::new(self.cfg.clone()));
            self.reader = Some(RolloutReader::open(&self.path)?);
        }
        let mut out = Vec::new();
        while self.pending.len() < self.state.window {
            match self.reader.as_mut().expect("set with folder").next() {
                Some(rec) => {
                    self.folder.as_mut().expect("set above").push(&rec?, &mut out)?;
                }
                None => {
                    // end of file: drain open sessions one LRU flush at a
                    // time so memory stays sharded even at the epoch tail
                    if !self.folder.as_mut().expect("set above").flush_lru(&mut out) {
                        let folder = self.folder.take().expect("checked above");
                        self.reader = None;
                        let mut tail = Vec::new();
                        let stats = folder.finish(&mut tail);
                        debug_assert!(tail.is_empty(), "drained folder has no sessions left");
                        self.note_first_epoch(stats);
                        return Ok(true);
                    }
                }
            }
            self.pending.extend(out.drain(..).map(Arc::new));
            self.track_peak();
        }
        Ok(false)
    }

    fn refill(&mut self) -> crate::Result<()> {
        debug_assert!(self.state.shard.is_empty());
        loop {
            // top up the buffer — unless the epoch tail is still draining
            if self.pending.len() < self.state.window && !self.rollover_due && self.pump()? {
                self.rollover_due = true;
            }
            if self.pending.is_empty() {
                // nothing buffered: the epoch just ended (or the corpus is
                // empty, which rollover rejects)
                self.state.rollover(&self.path)?;
                self.rollover_due = false;
                continue;
            }
            let take = self.pending.len().min(self.state.window);
            let buf: Vec<Arc<TrajectoryTree>> = self.pending.drain(..take).collect();
            self.state.install(buf);
            self.track_peak();
            return Ok(());
        }
    }
}

impl CorpusSource for StreamingRolloutSource {
    fn next_tree(&mut self) -> crate::Result<Arc<TrajectoryTree>> {
        if self.state.shard.is_empty() {
            self.refill()?;
        }
        Ok(self.state.shard.pop_front().expect("refill leaves a non-empty shard"))
    }

    fn epoch_len(&self) -> Option<usize> {
        self.state.epoch_len
    }

    fn peak_resident(&self) -> usize {
        self.state.peak_resident
    }

    fn take_ingest_ms(&mut self) -> f64 {
        std::mem::take(&mut self.ingest_ms)
    }

    fn describe(&self) -> String {
        format!(
            "streaming rollouts: {} (window {}, max_open_sessions {}, ingest threads {})",
            self.path.display(),
            self.state.window,
            self.cfg.max_open_sessions,
            self.cfg.threads.max(1)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ResidentSource;
    use crate::ingest::{records_from_tree, save_rollouts, RolloutRecord};
    use crate::tree::gen;
    use crate::tree::io::{save_corpus, temp_dir};

    fn corpus(n: usize) -> Vec<TrajectoryTree> {
        (0..n as u64).map(|s| gen::uniform(40 + s, 8, 5, 0.5)).collect()
    }

    fn drain(src: &mut dyn CorpusSource, n: usize) -> Vec<Arc<TrajectoryTree>> {
        (0..n).map(|_| src.next_tree().unwrap()).collect()
    }

    #[test]
    fn full_window_matches_resident_exactly() {
        let dir = temp_dir("stream-full");
        let trees = corpus(7);
        let path = dir.join("corpus.jsonl");
        save_corpus(&trees, &path).unwrap();
        let mut resident = ResidentSource::new(trees.clone(), 11).unwrap();
        // window > corpus: one shard per epoch, same Fisher-Yates stream
        let mut streaming = StreamingTreeSource::open(&path, 64, 11).unwrap();
        for step in 0..trees.len() * 3 {
            assert_eq!(
                resident.next_tree().unwrap(),
                streaming.next_tree().unwrap(),
                "diverged at stream position {step}"
            );
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn small_window_bounds_memory_and_covers_epochs() {
        let dir = temp_dir("stream-window");
        let trees = corpus(12);
        let path = dir.join("corpus.jsonl");
        save_corpus(&trees, &path).unwrap();
        let window = 4;
        let mut src = StreamingTreeSource::open(&path, window, 5).unwrap();
        for epoch in 0..3 {
            let seen = drain(&mut src, trees.len());
            for t in &trees {
                assert_eq!(
                    seen.iter().filter(|s| &***s == t).count(),
                    1,
                    "epoch {epoch}: every tree exactly once"
                );
            }
        }
        assert_eq!(src.epoch_len(), Some(trees.len()));
        assert!(
            src.peak_resident() <= window,
            "peak resident {} must be bounded by the window {window}, not corpus {}",
            src.peak_resident(),
            trees.len()
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn epoch_zero_streams_in_file_order() {
        let dir = temp_dir("stream-order");
        let trees = corpus(9);
        let path = dir.join("corpus.jsonl");
        save_corpus(&trees, &path).unwrap();
        let mut src = StreamingTreeSource::open(&path, 2, 0).unwrap();
        for t in &trees {
            assert_eq!(&*src.next_tree().unwrap(), t);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn empty_corpus_errors_at_open() {
        let dir = temp_dir("stream-empty");
        let path = dir.join("corpus.jsonl");
        std::fs::write(&path, "").unwrap();
        let err = StreamingTreeSource::open(&path, 4, 0).unwrap_err().to_string();
        assert!(err.contains("empty corpus"), "got: {err}");
        std::fs::remove_dir_all(dir).ok();
    }

    fn rollout_corpus(dir: &Path) -> (PathBuf, Vec<RolloutRecord>) {
        let trees = corpus(6);
        let records: Vec<RolloutRecord> = trees
            .iter()
            .enumerate()
            .flat_map(|(i, t)| records_from_tree(t, &format!("sess-{i:03}")))
            .collect();
        let path = dir.join("rollouts.jsonl");
        save_rollouts(&records, &path).unwrap();
        (path, records)
    }

    #[test]
    fn rollouts_full_window_matches_resident_fold() {
        let dir = temp_dir("stream-rollouts");
        let (path, _) = rollout_corpus(&dir);
        let cfg = IngestConfig::default();
        let (folded, _) = crate::ingest::fold_corpus(&path, &cfg).unwrap();
        let mut resident = ResidentSource::new(folded.clone(), 21).unwrap();
        let mut streaming = StreamingRolloutSource::open(&path, cfg, 1024, 21).unwrap();
        for step in 0..folded.len() * 3 {
            assert_eq!(
                resident.next_tree().unwrap(),
                streaming.next_tree().unwrap(),
                "diverged at stream position {step}"
            );
        }
        assert!(streaming.stats().is_some(), "first epoch must record ingest stats");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rollouts_small_window_covers_each_epoch() {
        let dir = temp_dir("stream-rollouts-win");
        let (path, _) = rollout_corpus(&dir);
        let cfg = IngestConfig::default();
        let (folded, _) = crate::ingest::fold_corpus(&path, &cfg).unwrap();
        let window = 2;
        let mut src = StreamingRolloutSource::open(&path, cfg, window, 3).unwrap();
        for epoch in 0..2 {
            let seen = drain(&mut src, folded.len());
            for t in &folded {
                assert_eq!(
                    seen.iter().filter(|s| &***s == t).count(),
                    1,
                    "epoch {epoch}: every folded tree exactly once"
                );
            }
        }
        // bound: window + at most one session flush in flight (sessions
        // here are single-tree, so the overshoot is at most one tree)
        assert!(
            src.peak_resident() <= window + 1,
            "peak {} too high for window {window}",
            src.peak_resident()
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rollouts_parallel_threads_do_not_change_the_stream() {
        let dir = temp_dir("stream-rollouts-par");
        let (path, _) = rollout_corpus(&dir);
        let serial_cfg = IngestConfig { max_open_sessions: 3, ..Default::default() };
        let par_cfg = IngestConfig { threads: 4, ..serial_cfg.clone() };
        let mut serial = StreamingRolloutSource::open(&path, serial_cfg, 4, 17).unwrap();
        let mut par = StreamingRolloutSource::open(&path, par_cfg, 4, 17).unwrap();
        let n = {
            let (folded, _) = crate::ingest::fold_corpus(
                &path,
                &IngestConfig { max_open_sessions: 3, ..Default::default() },
            )
            .unwrap();
            folded.len()
        };
        for step in 0..n * 2 {
            assert_eq!(
                serial.next_tree().unwrap(),
                par.next_tree().unwrap(),
                "parallel ingest changed the stream at position {step}"
            );
        }
        assert_eq!(serial.stats(), par.stats(), "first-epoch stats must match");
        assert!(par.take_ingest_ms() > 0.0, "fold time must be attributed");
        assert_eq!(par.take_ingest_ms(), 0.0, "take drains the accumulator");
        std::fs::remove_dir_all(dir).ok();
    }
}
