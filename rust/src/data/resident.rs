//! The resident corpus source: every tree in memory, `Arc`-shared.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::tree::TrajectoryTree;
use crate::util::rng::Rng;

use super::CorpusSource;

/// Whole-corpus source (the seed behavior, minus the per-batch deep clones
/// and the epoch-tail drop).  Epoch 0 is corpus order; each later epoch is
/// one fresh Fisher-Yates permutation of the corpus drawn from the run-seed
/// RNG — exactly the shard shuffle of the streaming sources with a window
/// covering the corpus, which is what makes resident vs. streaming a pure
/// memory trade.
pub struct ResidentSource {
    pristine: Vec<Arc<TrajectoryTree>>,
    rng: Rng,
    epoch: VecDeque<Arc<TrajectoryTree>>,
    epochs_started: u64,
}

impl ResidentSource {
    pub fn new(trees: Vec<TrajectoryTree>, seed: u64) -> crate::Result<Self> {
        Self::from_shared(trees.into_iter().map(Arc::new).collect(), seed)
    }

    pub fn from_shared(trees: Vec<Arc<TrajectoryTree>>, seed: u64) -> crate::Result<Self> {
        anyhow::ensure!(!trees.is_empty(), "empty dataset");
        Ok(Self {
            pristine: trees,
            rng: crate::tree::gen::rng(seed),
            epoch: VecDeque::new(),
            epochs_started: 0,
        })
    }
}

impl CorpusSource for ResidentSource {
    fn next_tree(&mut self) -> crate::Result<Arc<TrajectoryTree>> {
        if self.epoch.is_empty() {
            // epoch boundary: reshuffle between trees (§3.4) — Arc clones,
            // so starting an epoch is O(n) pointers, not O(corpus tokens)
            let mut next: Vec<Arc<TrajectoryTree>> = self.pristine.clone();
            if self.epochs_started > 0 {
                self.rng.shuffle(&mut next);
            }
            self.epochs_started += 1;
            self.epoch = next.into();
        }
        Ok(self.epoch.pop_front().expect("pristine is non-empty"))
    }

    fn epoch_len(&self) -> Option<usize> {
        Some(self.pristine.len())
    }

    fn peak_resident(&self) -> usize {
        self.pristine.len()
    }

    fn describe(&self) -> String {
        format!("resident corpus: {} trees", self.pristine.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;

    fn trees(n: usize) -> Vec<TrajectoryTree> {
        (0..n as u64).map(|s| gen::uniform(s, 8, 5, 0.5)).collect()
    }

    #[test]
    fn epoch_zero_is_corpus_order() {
        let data = trees(5);
        let mut src = ResidentSource::new(data.clone(), 7).unwrap();
        for t in &data {
            assert_eq!(&*src.next_tree().unwrap(), t);
        }
    }

    #[test]
    fn later_epochs_are_permutations_and_deterministic() {
        let data = trees(6);
        let mut a = ResidentSource::new(data.clone(), 9).unwrap();
        let mut b = ResidentSource::new(data.clone(), 9).unwrap();
        // drain epoch 0 + two shuffled epochs; both sources agree step for
        // step, and each epoch covers every tree exactly once
        for epoch in 0..3 {
            let mut seen = Vec::new();
            for _ in 0..data.len() {
                let x = a.next_tree().unwrap();
                let y = b.next_tree().unwrap();
                assert_eq!(x, y, "same-seed sources diverged in epoch {epoch}");
                seen.push(x);
            }
            for t in &data {
                assert_eq!(
                    seen.iter().filter(|s| &***s == t).count(),
                    1,
                    "epoch {epoch} must cover each tree exactly once"
                );
            }
        }
    }

    #[test]
    fn tail_carries_across_epochs() {
        // 5 trees, batches of 2: 5 batches = 2 full epochs, no tree dropped
        let data = trees(5);
        let mut src = ResidentSource::new(data.clone(), 3).unwrap();
        let mut seen = Vec::new();
        for _ in 0..5 {
            seen.extend(src.next_batch(2).unwrap());
        }
        for t in &data {
            assert_eq!(
                seen.iter().filter(|s| &***s == t).count(),
                2,
                "every tree trains exactly twice in two epochs"
            );
        }
    }

    #[test]
    fn batches_share_not_clone() {
        let data = trees(3);
        let mut src = ResidentSource::new(data, 1).unwrap();
        let t = src.next_tree().unwrap();
        // 1 in pristine + 1 in the in-flight epoch queue... the handed-out
        // Arc must alias the resident tree, not deep-copy it
        assert!(Arc::strong_count(&t) >= 2, "batch trees must be shared, not cloned");
    }

    #[test]
    fn empty_corpus_is_rejected() {
        assert!(ResidentSource::new(Vec::new(), 0).is_err());
    }
}
