//! Micro-bench harness (criterion is not vendored): warmup + timed
//! iterations, reporting mean / p50 / p90 and derived throughput.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p90  ({} iters)",
            self.name, self.mean, self.p50, self.p90, self.iters
        );
    }

    pub fn report_throughput(&self, elems: usize, unit: &str) {
        let per_sec = elems as f64 / self.mean.as_secs_f64();
        println!(
            "{:<44} {:>10.3?} mean  {:>12.0} {unit}/s  ({} iters)",
            self.name, self.mean, per_sec, self.iters
        );
    }
}

/// Run `f` with auto-scaled iteration count (~`budget` total runtime).
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((budget.as_secs_f64() / once.as_secs_f64()) as usize).clamp(5, 10_000);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed());
    }
    times.sort_unstable();
    let mean = times.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: times[iters / 2],
        p90: times[iters * 9 / 10],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = bench("noop", Duration::from_millis(20), || 1 + 1);
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p90);
    }
}
