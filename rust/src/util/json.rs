//! Minimal JSON: parser + writer + accessors.
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number edge cases
//! beyond f64; preserves object insertion order (manifest param order is
//! load-bearing).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------------- parse
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == b.len(), "trailing bytes at {}", p.i);
        Ok(v)
    }

    // ---------------------------------------------------------------- write
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------ accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Required-field helpers with path-aware errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("`{key}` not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow::anyhow!("`{key}` not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow::anyhow!("`{key}` not an array"))
    }

    // --------------------------------------------------------- constructors
    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_i32(v: &[i32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_vec_f32(&self) -> anyhow::Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32).ok_or_else(|| anyhow::anyhow!("not a number")))
            .collect()
    }

    pub fn to_vec_i32(&self) -> anyhow::Result<Vec<i32>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|v| v.as_f64().map(|x| x as i32).ok_or_else(|| anyhow::anyhow!("not a number")))
            .collect()
    }

    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(kv) => kv.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

/// Read-modify-write one top-level key of a JSON object file: parse the
/// existing object, replace or append `key`, prune any other top-level key
/// not listed in `keep` (stale sections from older schemas), write back
/// pretty-printed.  Lets independent emitters (`tree-train distsim`'s
/// projection, `tree-train dist-smoke`'s measured sweep, `tree-train
/// serve`'s bench section) share one results file without clobbering each
/// other's sections.
///
/// A missing file starts fresh; an existing but unparseable or non-object
/// file is an **error** — never silently overwritten (a truncated write
/// must not quietly destroy the sibling section; delete the file to
/// reset).
///
/// Concurrent writers are detected, not assumed away: the file is
/// re-read immediately before the write and, if its bytes changed since
/// the merge snapshot, the merge is retried against the new contents (a
/// bounded number of times) instead of silently dropping the other
/// writer's section.  The write itself goes through a same-directory temp
/// file + rename, so a competing reader (or the race check of a competing
/// writer) never observes a truncated file.  The remaining
/// re-read-to-rename window is best-effort — two smoke jobs sharing a
/// BENCH file is the workload, not a lock-free database.
pub fn update_json_file_key(
    path: &std::path::Path,
    key: &str,
    value: Json,
    keep: &[&str],
) -> anyhow::Result<()> {
    update_json_file_key_hooked(path, key, value, keep, || {})
}

/// [`update_json_file_key`] with a test seam: `between` runs after the
/// merge snapshot is taken and before the pre-write race check, which is
/// exactly where a concurrent writer interleaves.
pub(crate) fn update_json_file_key_hooked(
    path: &std::path::Path,
    key: &str,
    value: Json,
    keep: &[&str],
    mut between: impl FnMut(),
) -> anyhow::Result<()> {
    const ATTEMPTS: u32 = 4;
    let read_raw = |path: &std::path::Path| -> anyhow::Result<Option<String>> {
        match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => anyhow::bail!("reading {}: {e}", path.display()),
            Ok(s) => Ok(Some(s)),
        }
    };
    for attempt in 1..=ATTEMPTS {
        let snapshot = read_raw(path)?;
        let mut kv: Vec<(String, Json)> = match &snapshot {
            None => Vec::new(),
            Some(s) => match Json::parse(s) {
                Ok(Json::Obj(kv)) => kv
                    .into_iter()
                    .filter(|(k, _)| k == key || keep.contains(&k.as_str()))
                    .collect(),
                _ => anyhow::bail!(
                    "{} exists but is not a parseable JSON object — refusing to \
                     clobber it (delete the file to reset)",
                    path.display()
                ),
            },
        };
        match kv.iter_mut().find(|(k, _)| k == key) {
            Some((_, v)) => *v = value.clone(),
            None => kv.push((key.to_string(), value.clone())),
        }
        between();
        if read_raw(path)? != snapshot {
            // another writer landed since the snapshot: re-merge against
            // its output so both sections survive
            anyhow::ensure!(
                attempt < ATTEMPTS,
                "{}: still changing underneath after {ATTEMPTS} merge \
                 attempts — giving up rather than dropping a concurrent \
                 writer's section",
                path.display()
            );
            continue;
        }
        let tmp = path.with_file_name(format!(
            "{}.tmp.{}",
            path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default(),
            std::process::id()
        ));
        // bench writers target results/ paths that may not exist yet (a
        // fresh checkout, a sweep writing into --csv-dir): create the
        // parent before the temp write, so the rename has a home
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| anyhow::anyhow!("creating {}: {e}", parent.display()))?;
            }
        }
        std::fs::write(&tmp, Json::Obj(kv).to_string_pretty())?;
        std::fs::rename(&tmp, path)?;
        return Ok(());
    }
    unreachable!("loop returns or bails")
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected `{}` at {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number `{s}`: {e}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                anyhow::ensure!(
                                    self.b.get(self.i) == Some(&b'\\')
                                        && self.b.get(self.i + 1) == Some(&b'u'),
                                    "lone surrogate"
                                );
                                let hex2 = std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 6;
                                char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                                    .ok_or_else(|| anyhow::anyhow!("bad surrogate pair"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?
                            };
                            out.push(ch);
                        }
                        other => anyhow::bail!("bad escape `\\{}`", other as char),
                    }
                }
                c => {
                    // recover full utf8 char
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let s = std::str::from_utf8(&self.b[self.i - 1..self.i - 1 + len])?;
                        out.push_str(s);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => anyhow::bail!("expected , or ] got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => anyhow::bail!("expected , or }} got `{}` at {}", c as char, self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"nested": true, "s": "hi\nthere"}, "n": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("s").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn preserves_object_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![("x", Json::arr_i32(&[1, 2, 3])), ("y", Json::str("s"))]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn update_json_file_key_preserves_kept_sections_and_prunes_stale_keys() {
        let dir = std::env::temp_dir().join(format!("tt-json-key-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merged.json");
        // fresh file: creates the object
        update_json_file_key(&path, "a", Json::num(1.0), &["b"]).unwrap();
        // second key: preserves the first (listed in keep)
        update_json_file_key(&path, "b", Json::str("x"), &["a"]).unwrap();
        // overwrite: replaces in place, still preserving the kept sibling
        update_json_file_key(&path, "a", Json::num(2.0), &["b"]).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        // stale keys from an older schema are pruned on the next write
        std::fs::write(&path, r#"{"legacy": 7, "b": "x"}"#).unwrap();
        update_json_file_key(&path, "a", Json::num(3.0), &["b"]).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(v.get("legacy").is_none(), "stale top-level keys must be pruned");
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn update_json_file_key_creates_missing_parent_directories() {
        let root = std::env::temp_dir().join(format!("tt-json-mkdir-{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        // two levels deep, neither existing: the writer must create them
        // rather than fail the temp-file write (fresh checkouts have no
        // results/ directory yet)
        let path = root.join("results").join("nested").join("bench.json");
        update_json_file_key(&path, "rows", Json::arr_i32(&[1, 2]), &[]).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(v.get("rows").is_some());
        // and an update into the now-existing directory still round-trips
        update_json_file_key(&path, "rows", Json::arr_i32(&[3]), &[]).unwrap();
        std::fs::remove_dir_all(root).ok();
    }

    #[test]
    fn update_json_file_key_refuses_to_clobber_garbage() {
        let dir = std::env::temp_dir().join(format!("tt-json-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("truncated.json");
        std::fs::write(&path, "{\"measured_sweep\": {\"rows\": [").unwrap();
        let err = update_json_file_key(&path, "projection", Json::num(1.0), &[]).unwrap_err();
        assert!(err.to_string().contains("refusing to clobber"), "got: {err}");
        // the broken file is left untouched for inspection
        assert!(std::fs::read_to_string(&path).unwrap().starts_with("{\"measured_sweep\""));
        // a parseable but non-object file (e.g. a bare array) is just as
        // unmergeable and must also refuse
        std::fs::write(&path, "[1, 2, 3]").unwrap();
        let err = update_json_file_key(&path, "projection", Json::num(1.0), &[]).unwrap_err();
        assert!(err.to_string().contains("refusing to clobber"), "got: {err}");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "[1, 2, 3]");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn update_json_file_key_remerges_after_a_concurrent_writer() {
        let dir = std::env::temp_dir().join(format!("tt-json-race-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merged.json");
        update_json_file_key(&path, "mine", Json::num(1.0), &["theirs"]).unwrap();
        // a concurrent writer lands its section between our merge snapshot
        // and our write; the naive read-merge-write would drop it
        let mut raced = false;
        let p2 = path.clone();
        update_json_file_key_hooked(&path, "mine", Json::num(2.0), &["theirs"], || {
            if !raced {
                raced = true;
                update_json_file_key(&p2, "theirs", Json::str("kept"), &["mine"]).unwrap();
            }
        })
        .unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("mine").unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("theirs").unwrap().as_str(),
            Some("kept"),
            "the concurrent writer's section must survive the re-merge"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn update_json_file_key_gives_up_under_sustained_interference() {
        let dir = std::env::temp_dir().join(format!("tt-json-spin-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merged.json");
        // the file changes on *every* attempt: the retry loop must bail
        // with a diagnostic instead of spinning or clobbering
        let mut n = 0u32;
        let p2 = path.clone();
        let err = update_json_file_key_hooked(&path, "mine", Json::num(1.0), &[], || {
            n += 1;
            std::fs::write(&p2, format!("{{\"spin\": {n}}}")).unwrap();
        })
        .unwrap_err();
        assert!(err.to_string().contains("concurrent writer"), "got: {err}");
        std::fs::remove_dir_all(dir).ok();
    }
}
