//! Deterministic PRNG: SplitMix64 core (Steele et al. 2014) — small, fast,
//! and reproducible across platforms (synthetic data generators, shuffles).

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform integer in [lo, hi) — unbiased enough for data generation.
    #[inline]
    pub fn gen_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    #[inline]
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as i64, hi as i64) as usize
    }

    #[inline]
    pub fn i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.gen_range(lo as i64, hi as i64) as i32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize(0, i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.gen_range(-5, 12);
            assert!((-5..12).contains(&x));
        }
    }

    #[test]
    fn f64_uniformish() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
