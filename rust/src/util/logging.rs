//! Leveled stderr logging.  `TT_LOG` = error|warn|info|debug (default info).

use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: OnceLock<Level> = OnceLock::new();

pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("TT_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    })
}

pub fn log(lvl: Level, args: std::fmt::Arguments) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
