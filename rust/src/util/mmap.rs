//! Read-only file memory mapping (no libc dependency: the two syscalls are
//! declared directly).
//!
//! The chunked [`super::jsonl::LineReader`] still copies every byte
//! kernel→buffer; mapping the corpus lets the line splitter and the JSON
//! parser read straight out of the page cache (ROADMAP item 5's last
//! read-path copy).  Only for **immutable** files: the mapping's length is
//! fixed at map time, so a concurrently growing file (e.g. a live spool
//! segment — see `docs/serve.md`) silently stops at the mapped length, and a
//! truncated one faults.  Growing inputs stay on the chunked reader.

use std::fs::File;

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// An owned read-only mapping of a whole file; unmapped on drop.  An empty
/// file maps to an empty slice without touching the syscall (mmap rejects
/// zero lengths).
pub struct Mmap {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

// The mapping is private + read-only: no aliasing mutation is possible
// through it, so moving/sharing across threads is sound.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    #[cfg(unix)]
    pub fn map(file: &File) -> std::io::Result<Self> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len();
        if len == 0 {
            return Ok(Self { ptr: std::ptr::null_mut(), len: 0 });
        }
        if len > usize::MAX as u64 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "file too large to map",
            ));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len as usize,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Self { ptr, len: len as usize })
    }

    /// Non-unix targets: report unsupported and let callers fall back to
    /// the chunked reader.
    #[cfg(not(unix))]
    pub fn map(_file: &File) -> std::io::Result<Self> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "mmap unavailable"))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // Safety: ptr/len describe a live PROT_READ mapping owned by self.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if !self.ptr.is_null() && self.len > 0 {
            // Safety: exactly the region mapped in `map`, unmapped once.
            unsafe {
                sys::munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let path =
            std::env::temp_dir().join(format!("tt-mmap-{}-{name}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(contents).unwrap();
        path
    }

    #[test]
    fn maps_file_contents_byte_for_byte() {
        let body = b"alpha\nbeta\n\xff\x00binary tail";
        let path = tmp("bytes", body);
        let m = Mmap::map(&File::open(&path).unwrap()).unwrap();
        assert_eq!(&*m, &body[..]);
        assert_eq!(m.len(), body.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let path = tmp("empty", b"");
        let m = Mmap::map(&File::open(&path).unwrap()).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), b"");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mapping_survives_file_deletion() {
        // unix semantics: the pages stay valid until unmap even after the
        // directory entry is gone — corpus readers can outlive cleanup
        let path = tmp("unlink", b"still here");
        let m = Mmap::map(&File::open(&path).unwrap()).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(&*m, b"still here");
    }
}
