//! In-tree substrates (the vendored build has only the `xla` closure, so
//! everything else a framework needs is implemented here):
//!
//! * [`json`]  — minimal JSON parser/writer (manifest, configs, corpora).
//! * [`jsonl`] — streaming JSONL line reader with `label:line` errors.
//! * [`mmap`]  — read-only file mapping (zero-copy corpus read path).
//! * [`rng`]   — SplitMix64 deterministic PRNG (generators, shuffles).
//! * [`bench`] — micro-bench harness (warmup + timed iterations, p50/mean).
//! * [`logging`] — leveled stderr logging controlled by `TT_LOG`.

pub mod bench;
pub mod json;
pub mod jsonl;
pub mod logging;
pub mod mmap;
pub mod rng;
