//! Streaming JSONL line reader shared by the tree-corpus and rollout
//! readers: skips blank lines, counts lines, and decorates every parse
//! error with `label:line` so a bad record in a million-line corpus is
//! findable.  Typed readers supply their record parser per `next_record`
//! call and stay thin wrappers.
//!
//! The read path is zero-copy per line: [`LineReader`] fills a reusable
//! chunk buffer (growing only for oversized lines) and hands out borrowed
//! byte slices, so the hot ingestion loop performs no per-line `String`
//! allocation — the JSON parser reads straight out of the chunk.

use std::io::Read;
use std::path::Path;

use super::json::Json;

/// Default chunk size: large enough that refills are rare relative to
/// lines, small enough to stay cache-friendly.
const CHUNK: usize = 128 * 1024;

/// Chunked line splitter over any [`Read`]: lines are borrowed slices into
/// a reusable internal buffer (valid until the next call).  Handles a final
/// line without trailing newline and strips a trailing `\r` (CRLF logs).
pub struct LineReader<R: Read> {
    src: R,
    buf: Vec<u8>,
    /// Next unconsumed byte / end of valid bytes in `buf`.
    start: usize,
    end: usize,
    eof: bool,
}

impl<R: Read> LineReader<R> {
    pub fn new(src: R) -> Self {
        Self::with_capacity(CHUNK, src)
    }

    pub fn with_capacity(cap: usize, src: R) -> Self {
        Self { src, buf: vec![0; cap.max(64)], start: 0, end: 0, eof: false }
    }

    /// Locate the next line, returning its byte range in `self.buf`.
    /// Separated from [`Self::next_line`] so the borrow of `buf` starts
    /// only after all mutation is done.
    fn fill_line(&mut self) -> std::io::Result<Option<(usize, usize)>> {
        loop {
            if let Some(i) = self.buf[self.start..self.end].iter().position(|&b| b == b'\n') {
                let a = self.start;
                let mut b = self.start + i;
                self.start = b + 1;
                if b > a && self.buf[b - 1] == b'\r' {
                    b -= 1;
                }
                return Ok(Some((a, b)));
            }
            if self.eof {
                if self.start < self.end {
                    let (a, mut b) = (self.start, self.end);
                    self.start = self.end;
                    if b > a && self.buf[b - 1] == b'\r' {
                        b -= 1;
                    }
                    return Ok(Some((a, b)));
                }
                return Ok(None);
            }
            // no newline in the window: compact the partial line to the
            // front, then refill the tail of the buffer
            if self.start > 0 {
                self.buf.copy_within(self.start..self.end, 0);
                self.end -= self.start;
                self.start = 0;
            } else if self.end == self.buf.len() {
                // one line larger than the whole buffer: grow
                self.buf.resize(self.buf.len() * 2, 0);
            }
            let n = self.src.read(&mut self.buf[self.end..])?;
            if n == 0 {
                self.eof = true;
            }
            self.end += n;
        }
    }

    /// Next line as a borrowed byte slice (no allocation); `None` at EOF.
    pub fn next_line(&mut self) -> Option<std::io::Result<&[u8]>> {
        match self.fill_line() {
            Err(e) => Some(Err(e)),
            Ok(None) => None,
            Ok(Some((a, b))) => Some(Ok(&self.buf[a..b])),
        }
    }
}

pub struct JsonlReader<R: Read> {
    lines: LineReader<R>,
    label: String,
    line_no: usize,
}

impl JsonlReader<std::io::BufReader<std::fs::File>> {
    pub fn open(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(Self::new(std::io::BufReader::new(f), &path.display().to_string()))
    }
}

impl<R: Read> JsonlReader<R> {
    pub fn new(reader: R, label: &str) -> Self {
        Self { lines: LineReader::new(reader), label: label.to_string(), line_no: 0 }
    }

    /// Next non-blank line, JSON-parsed and fed to `parse`; errors from
    /// either stage carry `label:line`.  The line is parsed in place out of
    /// the chunk buffer — no per-line copy.
    pub fn next_record<T>(
        &mut self,
        parse: impl FnOnce(&Json) -> crate::Result<T>,
    ) -> Option<crate::Result<T>> {
        loop {
            let line = match self.lines.next_line()? {
                Ok(l) => l,
                Err(e) => {
                    return Some(Err(anyhow::anyhow!(
                        "{}:{}: read error: {e}",
                        self.label,
                        self.line_no + 1
                    )))
                }
            };
            self.line_no += 1;
            if line.iter().all(|b| b.is_ascii_whitespace()) {
                continue;
            }
            let parsed = std::str::from_utf8(line)
                .map_err(|e| anyhow::anyhow!("invalid utf-8: {e}"))
                .and_then(Json::parse)
                .and_then(|v| parse(&v));
            return Some(
                parsed.map_err(|e| anyhow::anyhow!("{}:{}: {e}", self.label, self.line_no)),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skips_blanks_counts_lines_and_decorates_errors() {
        let src = "{\"x\": 1}\n\n  \n{\"x\": 2}\nnot json\n";
        let mut r = JsonlReader::new(src.as_bytes(), "mem");
        let get = |r: &mut JsonlReader<&[u8]>| {
            r.next_record(|v| v.req("x").and_then(|x| x.as_i64().ok_or_else(|| anyhow::anyhow!("x"))))
        };
        assert_eq!(get(&mut r).unwrap().unwrap(), 1);
        assert_eq!(get(&mut r).unwrap().unwrap(), 2);
        let err = get(&mut r).unwrap().unwrap_err().to_string();
        assert!(err.contains("mem:5:"), "expected mem:5: in {err}");
        assert!(get(&mut r).is_none());
    }

    #[test]
    fn record_parser_errors_also_carry_position() {
        let src = "{\"x\": 1}\n{\"y\": 1}\n";
        let mut r = JsonlReader::new(src.as_bytes(), "f.jsonl");
        assert!(r.next_record(|v| v.req("x").cloned()).unwrap().is_ok());
        let err = r.next_record(|v| v.req("x").cloned()).unwrap().unwrap_err().to_string();
        assert!(err.contains("f.jsonl:2:"), "{err}");
    }

    #[test]
    fn line_reader_splits_across_chunk_boundaries() {
        // a tiny buffer forces compaction + refill inside lines
        let src = "alpha\nbeta-which-is-longer\r\n\ngamma";
        let mut lr = LineReader::with_capacity(64, src.as_bytes());
        let mut got: Vec<String> = Vec::new();
        while let Some(l) = lr.next_line() {
            got.push(String::from_utf8(l.unwrap().to_vec()).unwrap());
        }
        assert_eq!(got, vec!["alpha", "beta-which-is-longer", "", "gamma"]);
    }

    #[test]
    fn line_reader_grows_for_oversized_lines() {
        let long = "x".repeat(5000);
        let src = format!("{long}\nshort\n");
        let mut lr = LineReader::with_capacity(64, src.as_bytes());
        assert_eq!(lr.next_line().unwrap().unwrap().len(), 5000);
        assert_eq!(lr.next_line().unwrap().unwrap(), b"short");
        assert!(lr.next_line().is_none());
    }

    #[test]
    fn final_line_without_newline_is_yielded() {
        let mut lr = LineReader::new("a\nb".as_bytes());
        assert_eq!(lr.next_line().unwrap().unwrap(), b"a");
        assert_eq!(lr.next_line().unwrap().unwrap(), b"b");
        assert!(lr.next_line().is_none());
    }
}
