//! Streaming JSONL line reader shared by the tree-corpus and rollout
//! readers: skips blank lines, counts lines, and decorates every parse
//! error with `label:line` so a bad record in a million-line corpus is
//! findable.  Typed readers supply their record parser per `next_record`
//! call and stay thin wrappers.
//!
//! The read path is zero-copy per line: [`LineReader`] fills a reusable
//! chunk buffer (growing only for oversized lines) and hands out borrowed
//! byte slices, so the hot ingestion loop performs no per-line `String`
//! allocation — the JSON parser reads straight out of the chunk.
//!
//! Two line-splitting backends behind one reader:
//!
//! * [`MmapLineReader`] — the whole file mapped read-only
//!   ([`super::mmap::Mmap`]); lines are slices of the page cache itself,
//!   removing even the kernel→buffer copy of the chunked path.  The
//!   default for [`JsonlReader::open`] on regular files.
//! * [`LineReader`] — chunked copy into a reusable buffer; the fallback
//!   for non-seekable inputs (in-memory tests, pipes) and for anything
//!   still *growing* while read — an mmap's length is fixed at map time,
//!   so live spool segments (`tree-train serve`) must use this path.

use std::io::Read;
use std::path::Path;

use super::json::Json;
use super::mmap::Mmap;

/// Default chunk size: large enough that refills are rare relative to
/// lines, small enough to stay cache-friendly.
const CHUNK: usize = 128 * 1024;

/// Chunked line splitter over any [`Read`]: lines are borrowed slices into
/// a reusable internal buffer (valid until the next call).  Handles a final
/// line without trailing newline and strips a trailing `\r` (CRLF logs).
pub struct LineReader<R: Read> {
    src: R,
    buf: Vec<u8>,
    /// Next unconsumed byte / end of valid bytes in `buf`.
    start: usize,
    end: usize,
    eof: bool,
}

impl<R: Read> LineReader<R> {
    pub fn new(src: R) -> Self {
        Self::with_capacity(CHUNK, src)
    }

    pub fn with_capacity(cap: usize, src: R) -> Self {
        Self { src, buf: vec![0; cap.max(64)], start: 0, end: 0, eof: false }
    }

    /// Locate the next line, returning its byte range in `self.buf`.
    /// Separated from [`Self::next_line`] so the borrow of `buf` starts
    /// only after all mutation is done.
    fn fill_line(&mut self) -> std::io::Result<Option<(usize, usize)>> {
        loop {
            if let Some(i) = self.buf[self.start..self.end].iter().position(|&b| b == b'\n') {
                let a = self.start;
                let mut b = self.start + i;
                self.start = b + 1;
                if b > a && self.buf[b - 1] == b'\r' {
                    b -= 1;
                }
                return Ok(Some((a, b)));
            }
            if self.eof {
                if self.start < self.end {
                    let (a, mut b) = (self.start, self.end);
                    self.start = self.end;
                    if b > a && self.buf[b - 1] == b'\r' {
                        b -= 1;
                    }
                    return Ok(Some((a, b)));
                }
                return Ok(None);
            }
            // no newline in the window: compact the partial line to the
            // front, then refill the tail of the buffer
            if self.start > 0 {
                self.buf.copy_within(self.start..self.end, 0);
                self.end -= self.start;
                self.start = 0;
            } else if self.end == self.buf.len() {
                // one line larger than the whole buffer: grow
                self.buf.resize(self.buf.len() * 2, 0);
            }
            let n = self.src.read(&mut self.buf[self.end..])?;
            if n == 0 {
                self.eof = true;
            }
            self.end += n;
        }
    }

    /// Next line as a borrowed byte slice (no allocation); `None` at EOF.
    pub fn next_line(&mut self) -> Option<std::io::Result<&[u8]>> {
        match self.fill_line() {
            Err(e) => Some(Err(e)),
            Ok(None) => None,
            Ok(Some((a, b))) => Some(Ok(&self.buf[a..b])),
        }
    }
}

/// Line splitter over a read-only mapped file: the same blank/CRLF/final-
/// line semantics as [`LineReader`], but lines borrow the mapping directly
/// (no copy, no read syscalls after the map).
pub struct MmapLineReader {
    map: Mmap,
    pos: usize,
}

impl MmapLineReader {
    pub fn new(map: Mmap) -> Self {
        Self { map, pos: 0 }
    }

    pub fn open(path: &Path) -> std::io::Result<Self> {
        Ok(Self::new(Mmap::map(&std::fs::File::open(path)?)?))
    }

    /// Next line as a slice of the mapping; `None` at end of file.
    pub fn next_line(&mut self) -> Option<&[u8]> {
        let bytes = self.map.bytes();
        if self.pos >= bytes.len() {
            return None;
        }
        let a = self.pos;
        let (mut b, next) = match bytes[a..].iter().position(|&x| x == b'\n') {
            Some(i) => (a + i, a + i + 1),
            None => (bytes.len(), bytes.len()),
        };
        if b > a && bytes[b - 1] == b'\r' {
            b -= 1;
        }
        self.pos = next;
        Some(&self.map.bytes()[a..b])
    }
}

/// The two line backends one [`JsonlReader`] can run on.
enum Lines<R: Read> {
    Chunked(LineReader<R>),
    Mapped(MmapLineReader),
}

impl<R: Read> Lines<R> {
    fn next_line(&mut self) -> Option<std::io::Result<&[u8]>> {
        match self {
            Lines::Chunked(lr) => lr.next_line(),
            Lines::Mapped(m) => m.next_line().map(Ok),
        }
    }
}

pub struct JsonlReader<R: Read> {
    lines: Lines<R>,
    label: String,
    line_no: usize,
}

impl JsonlReader<std::io::BufReader<std::fs::File>> {
    /// Open a corpus file, mmap-backed when the platform allows it (the
    /// chunked copy is the transparent fallback).  Only for files that are
    /// complete on disk — a still-growing file must go through
    /// [`Self::new`] on a plain reader instead.
    pub fn open(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let label = path.display().to_string();
        match Mmap::map(&f) {
            Ok(map) => Ok(Self {
                lines: Lines::Mapped(MmapLineReader::new(map)),
                label,
                line_no: 0,
            }),
            Err(_) => Ok(Self::new(std::io::BufReader::new(f), &label)),
        }
    }

    /// Open with the chunked reader unconditionally (the pre-mmap
    /// behavior); equivalence-tested against the mapped path below.
    pub fn open_chunked(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(Self::new(std::io::BufReader::new(f), &path.display().to_string()))
    }
}

impl<R: Read> JsonlReader<R> {
    pub fn new(reader: R, label: &str) -> Self {
        Self { lines: Lines::Chunked(LineReader::new(reader)), label: label.to_string(), line_no: 0 }
    }

    /// Next non-blank line, JSON-parsed and fed to `parse`; errors from
    /// either stage carry `label:line`.  The line is parsed in place out of
    /// the chunk buffer — no per-line copy.
    pub fn next_record<T>(
        &mut self,
        parse: impl FnOnce(&Json) -> crate::Result<T>,
    ) -> Option<crate::Result<T>> {
        loop {
            let line = match self.lines.next_line()? {
                Ok(l) => l,
                Err(e) => {
                    return Some(Err(anyhow::anyhow!(
                        "{}:{}: read error: {e}",
                        self.label,
                        self.line_no + 1
                    )))
                }
            };
            self.line_no += 1;
            if line.iter().all(|b| b.is_ascii_whitespace()) {
                continue;
            }
            let parsed = std::str::from_utf8(line)
                .map_err(|e| anyhow::anyhow!("invalid utf-8: {e}"))
                .and_then(Json::parse)
                .and_then(|v| parse(&v));
            return Some(
                parsed.map_err(|e| anyhow::anyhow!("{}:{}: {e}", self.label, self.line_no)),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skips_blanks_counts_lines_and_decorates_errors() {
        let src = "{\"x\": 1}\n\n  \n{\"x\": 2}\nnot json\n";
        let mut r = JsonlReader::new(src.as_bytes(), "mem");
        let get = |r: &mut JsonlReader<&[u8]>| {
            r.next_record(|v| v.req("x").and_then(|x| x.as_i64().ok_or_else(|| anyhow::anyhow!("x"))))
        };
        assert_eq!(get(&mut r).unwrap().unwrap(), 1);
        assert_eq!(get(&mut r).unwrap().unwrap(), 2);
        let err = get(&mut r).unwrap().unwrap_err().to_string();
        assert!(err.contains("mem:5:"), "expected mem:5: in {err}");
        assert!(get(&mut r).is_none());
    }

    #[test]
    fn record_parser_errors_also_carry_position() {
        let src = "{\"x\": 1}\n{\"y\": 1}\n";
        let mut r = JsonlReader::new(src.as_bytes(), "f.jsonl");
        assert!(r.next_record(|v| v.req("x").cloned()).unwrap().is_ok());
        let err = r.next_record(|v| v.req("x").cloned()).unwrap().unwrap_err().to_string();
        assert!(err.contains("f.jsonl:2:"), "{err}");
    }

    #[test]
    fn line_reader_splits_across_chunk_boundaries() {
        // a tiny buffer forces compaction + refill inside lines
        let src = "alpha\nbeta-which-is-longer\r\n\ngamma";
        let mut lr = LineReader::with_capacity(64, src.as_bytes());
        let mut got: Vec<String> = Vec::new();
        while let Some(l) = lr.next_line() {
            got.push(String::from_utf8(l.unwrap().to_vec()).unwrap());
        }
        assert_eq!(got, vec!["alpha", "beta-which-is-longer", "", "gamma"]);
    }

    #[test]
    fn line_reader_grows_for_oversized_lines() {
        let long = "x".repeat(5000);
        let src = format!("{long}\nshort\n");
        let mut lr = LineReader::with_capacity(64, src.as_bytes());
        assert_eq!(lr.next_line().unwrap().unwrap().len(), 5000);
        assert_eq!(lr.next_line().unwrap().unwrap(), b"short");
        assert!(lr.next_line().is_none());
    }

    #[test]
    fn final_line_without_newline_is_yielded() {
        let mut lr = LineReader::new("a\nb".as_bytes());
        assert_eq!(lr.next_line().unwrap().unwrap(), b"a");
        assert_eq!(lr.next_line().unwrap().unwrap(), b"b");
        assert!(lr.next_line().is_none());
    }

    fn tmp_file(name: &str, contents: &str) -> std::path::PathBuf {
        let path =
            std::env::temp_dir().join(format!("tt-jsonl-{}-{name}", std::process::id()));
        std::fs::write(&path, contents).unwrap();
        path
    }

    #[test]
    fn mapped_and_chunked_readers_split_lines_identically() {
        // every edge the chunked tests exercise, through both backends
        let body = "alpha\nbeta-which-is-longer\r\n\ngamma\nlast-no-newline";
        let path = tmp_file("equiv", body);
        let mut mapped = Vec::new();
        let mut m = MmapLineReader::open(&path).unwrap();
        while let Some(l) = m.next_line() {
            mapped.push(String::from_utf8(l.to_vec()).unwrap());
        }
        let mut chunked = Vec::new();
        let mut lr = LineReader::with_capacity(64, body.as_bytes());
        while let Some(l) = lr.next_line() {
            chunked.push(String::from_utf8(l.unwrap().to_vec()).unwrap());
        }
        assert_eq!(mapped, chunked);
        assert_eq!(mapped, vec!["alpha", "beta-which-is-longer", "", "gamma", "last-no-newline"]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mapped_reader_handles_the_empty_file() {
        let path = tmp_file("empty", "");
        let mut m = MmapLineReader::open(&path).unwrap();
        assert!(m.next_line().is_none());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn open_and_open_chunked_parse_identical_records() {
        let body = "{\"x\": 1}\n\n{\"x\": 2}\nbad json\n{\"x\": 3}";
        let path = tmp_file("open", body);
        let drain = |mut r: JsonlReader<std::io::BufReader<std::fs::File>>| {
            let mut out: Vec<String> = Vec::new();
            while let Some(rec) = r.next_record(|v| v.req("x").and_then(|x| {
                x.as_i64().ok_or_else(|| anyhow::anyhow!("x not a number"))
            })) {
                out.push(match rec {
                    Ok(x) => format!("ok:{x}"),
                    Err(e) => {
                        assert!(e.to_string().contains(":4:"), "line in {e}");
                        "err-at-line:4".to_string()
                    }
                });
            }
            out
        };
        let via_mmap = drain(JsonlReader::open(&path).unwrap());
        let via_chunk = drain(JsonlReader::open_chunked(&path).unwrap());
        assert_eq!(via_mmap, via_chunk);
        assert_eq!(via_mmap, vec!["ok:1", "ok:2", "err-at-line:4", "ok:3"]);
        std::fs::remove_file(path).ok();
    }
}
