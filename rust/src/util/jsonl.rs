//! Streaming JSONL line reader shared by the tree-corpus and rollout
//! readers: skips blank lines, counts lines, and decorates every parse
//! error with `label:line` so a bad record in a million-line corpus is
//! findable.  Typed readers supply their record parser per `next_record`
//! call and stay thin wrappers.

use std::io::BufRead;
use std::path::Path;

use super::json::Json;

pub struct JsonlReader<R: BufRead> {
    lines: std::io::Lines<R>,
    label: String,
    line_no: usize,
}

impl JsonlReader<std::io::BufReader<std::fs::File>> {
    pub fn open(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(Self::new(std::io::BufReader::new(f), &path.display().to_string()))
    }
}

impl<R: BufRead> JsonlReader<R> {
    pub fn new(reader: R, label: &str) -> Self {
        Self { lines: reader.lines(), label: label.to_string(), line_no: 0 }
    }

    /// Next non-blank line, JSON-parsed and fed to `parse`; errors from
    /// either stage carry `label:line`.
    pub fn next_record<T>(
        &mut self,
        parse: impl FnOnce(&Json) -> crate::Result<T>,
    ) -> Option<crate::Result<T>> {
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => {
                    return Some(Err(anyhow::anyhow!(
                        "{}:{}: read error: {e}",
                        self.label,
                        self.line_no + 1
                    )))
                }
            };
            self.line_no += 1;
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(&line).and_then(|v| parse(&v));
            return Some(
                parsed.map_err(|e| anyhow::anyhow!("{}:{}: {e}", self.label, self.line_no)),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skips_blanks_counts_lines_and_decorates_errors() {
        let src = "{\"x\": 1}\n\n  \n{\"x\": 2}\nnot json\n";
        let mut r = JsonlReader::new(src.as_bytes(), "mem");
        let get = |r: &mut JsonlReader<&[u8]>| {
            r.next_record(|v| v.req("x").and_then(|x| x.as_i64().ok_or_else(|| anyhow::anyhow!("x"))))
        };
        assert_eq!(get(&mut r).unwrap().unwrap(), 1);
        assert_eq!(get(&mut r).unwrap().unwrap(), 2);
        let err = get(&mut r).unwrap().unwrap_err().to_string();
        assert!(err.contains("mem:5:"), "expected mem:5: in {err}");
        assert!(get(&mut r).is_none());
    }

    #[test]
    fn record_parser_errors_also_carry_position() {
        let src = "{\"x\": 1}\n{\"y\": 1}\n";
        let mut r = JsonlReader::new(src.as_bytes(), "f.jsonl");
        assert!(r.next_record(|v| v.req("x").cloned()).unwrap().is_ok());
        let err = r.next_record(|v| v.req("x").cloned()).unwrap().unwrap_err().to_string();
        assert!(err.contains("f.jsonl:2:"), "{err}");
    }
}
