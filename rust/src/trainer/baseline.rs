//! The sep-avg baseline (Eq. 1) with sequence packing (§4.2).
//!
//! Every root-to-leaf path is linearized into an independent chain and
//! chains are first-fit-decreasing packed into capacity-`C` batches.  A
//! packed batch is a *prefix forest* — "a sequence is a special case of a
//! prefix tree" (§2) — so it runs through the **same** exported `step`
//! program as Tree Training, with metadata that simply never shares
//! prefixes.  The speedup comparison is therefore kernel-for-kernel fair:
//! the baseline pays `N_flat` tokens where Tree Training pays `N_tree`.

use std::sync::Arc;
use std::time::Instant;

use crate::runtime::{HostTensor, Program, Runtime};
use xla::Literal;
use crate::tree::dfs::DfsMeta;
use crate::tree::{NodeSpec, TrajectoryTree};

use super::adamw::{AdamW, AdamWConfig};
use super::batch::{Batch, BatchOptions};
use super::grads::GradBuffer;
use super::metrics::StepMetrics;

pub struct BaselineTrainer {
    pub rt: Arc<Runtime>,
    pub model: String,
    pub params: Vec<HostTensor>,
    param_lits: Vec<Literal>,
    pub opt: AdamW,
    step_prog: Arc<Program>,
    pub capacity: usize,
    hybrid: Option<(usize, usize)>,
    step_count: u64,
}

/// One path of a tree as an independent chain tree.
pub fn path_chain(tree: &TrajectoryTree, path: &[usize]) -> TrajectoryTree {
    let nodes: Vec<NodeSpec> = path
        .iter()
        .enumerate()
        .map(|(d, &n)| {
            let nd = &tree.nodes[n];
            let real = nd.real_len();
            NodeSpec {
                parent: d as i32 - 1,
                tokens: nd.tokens[..real].to_vec(),
                trainable: nd.trainable[..real].to_vec(),
                advantage: nd.advantage[..real].to_vec(),
                pad_tail: 0,
            }
        })
        .collect();
    TrajectoryTree::new(nodes).expect("chain is a valid tree")
}

/// First-fit-decreasing packing of chain metas into capacity-C batches.
pub fn pack_chains(
    chains: &[DfsMeta],
    capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<Vec<Batch>> {
    let mut order: Vec<usize> = (0..chains.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(chains[i].size()));
    let mut bins: Vec<(usize, Vec<usize>)> = Vec::new(); // (used, chain ids)
    for &i in &order {
        let s = chains[i].size();
        anyhow::ensure!(s <= capacity, "path of {s} tokens exceeds capacity {capacity}");
        match bins.iter_mut().find(|(used, _)| used + s <= capacity) {
            Some((used, ids)) => {
                *used += s;
                ids.push(i);
            }
            None => bins.push((s, vec![i])),
        }
    }
    bins.iter().map(|(_, ids)| concat_chains(chains, ids, capacity, opts)).collect()
}

/// Concatenate chain metas into one forest batch (offsets applied).
fn concat_chains(
    chains: &[DfsMeta],
    ids: &[usize],
    capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<Batch> {
    let hybrid = opts.chunk_size.is_some();
    let chunk = opts.chunk_size.unwrap_or(1);
    let kconv = opts.conv_kernel.unwrap_or(0);
    let mut b = Batch {
        capacity,
        past_len: 0,
        tokens: Vec::with_capacity(capacity),
        prev_idx: Vec::with_capacity(capacity),
        pos_ids: Vec::with_capacity(capacity),
        weights: Vec::with_capacity(capacity),
        q_exit: Vec::with_capacity(capacity),
        k_order: (0..capacity as i32).collect(),
        k_exit: Vec::new(),
        k_bias: vec![0.0; capacity],
        chunk_parent_map: Vec::new(),
        ssm_pad: Vec::new(),
        conv_idx: Vec::new(),
    };
    for &i in ids {
        let m = &chains[i];
        let o = b.tokens.len() as i32;
        b.tokens.extend(&m.tokens);
        b.pos_ids.extend(&m.pos_ids);
        b.weights.extend(&m.weights);
        b.q_exit.extend(m.subtree_exit.iter().map(|&e| e + o));
        let prev = crate::tree::dfs::prev_indices(m);
        b.prev_idx.extend(prev.iter().map(|&p| if p < 0 { -1 } else { p + o }));
        if hybrid {
            let chunk_off = (o as usize / chunk) as i32;
            let cpm = crate::tree::dfs::chunk_parent_map(m, chunk)?;
            b.chunk_parent_map
                .extend(cpm.iter().map(|&p| if p < 0 { -1 } else { p + chunk_off }));
            b.ssm_pad.extend(m.pad_mask.iter().map(|&x| if x { 1.0 } else { 0.0 }));
        }
        if kconv > 0 {
            let idx = crate::tree::dfs::conv_gather_indices(m, kconv, false);
            // token refs (>= base) shift by the pack offset; zero row stays
            b.conv_idx.extend(idx.iter().map(|&x| if x >= kconv as i32 { x + o } else { x }));
        }
    }
    // pad to capacity: self-islands, zero weight
    let s = b.tokens.len();
    anyhow::ensure!(s <= capacity, "packing overflow");
    for t in s..capacity {
        b.tokens.push(0);
        b.pos_ids.push(0);
        b.weights.push(0.0);
        b.q_exit.push((t + 1) as i32);
        b.prev_idx.push(-1);
        if hybrid {
            b.ssm_pad.push(1.0);
        }
        if kconv > 0 {
            let mut row = vec![0i32; kconv];
            row[kconv - 1] = kconv as i32 + t as i32;
            b.conv_idx.extend(row);
        }
    }
    if hybrid {
        anyhow::ensure!(s % chunk == 0 && capacity % chunk == 0, "pack not chunk-aligned");
        for i in s / chunk..capacity / chunk {
            b.chunk_parent_map.push(if i == s / chunk { -1 } else { i as i32 - 1 });
        }
    }
    b.k_exit = b.q_exit.clone();
    Ok(b)
}

impl BaselineTrainer {
    pub fn new(rt: Arc<Runtime>, model: &str, opt_cfg: AdamWConfig) -> crate::Result<Self> {
        let info = rt.manifest.model(model)?.clone();
        let params = rt.manifest.load_params(model)?;
        let step_prog = rt.find_program("step", model, 0)?;
        let capacity = step_prog.info.capacity;
        let hybrid = if info.kind() == "hybrid" {
            Some((info.chunk_size(), info.conv_kernel()))
        } else {
            None
        };
        let opt = AdamW::new(opt_cfg, &params);
        let param_lits = params
            .iter()
            .map(|p| p.to_literal())
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Self {
            rt,
            model: model.to_string(),
            params,
            param_lits,
            opt,
            step_prog,
            capacity,
            hybrid,
            step_count: 0,
        })
    }

    fn batch_options(&self) -> BatchOptions {
        BatchOptions {
            chunk_size: self.hybrid.map(|(c, _)| c),
            conv_kernel: self.hybrid.map(|(_, k)| k),
            ..Default::default()
        }
    }

    /// Linearize the global batch into packed chain batches.
    pub fn pack_trees(&self, trees: &[TrajectoryTree]) -> crate::Result<Vec<Batch>> {
        let mut chains = Vec::new();
        for tree in trees {
            for path in tree.paths() {
                let mut chain = path_chain(tree, &path);
                // long paths must still fit: split then chain is unchanged,
                // so instead pack at capacity via segment splitting
                if chain.n_tree() > self.capacity {
                    chain = chain.split_long_segments(self.capacity);
                    anyhow::bail!(
                        "path of {} tokens exceeds baseline capacity {} — the \
                         baseline cannot sequence-pack it (tree training would \
                         partition it); reduce path length or export a larger \
                         bucket ({} nodes)",
                        chain.n_tree(),
                        self.capacity,
                        chain.len()
                    );
                }
                if let Some((chunk, _)) = self.hybrid {
                    chain = chain.pad_for_chunks(chunk, 0);
                }
                chains.push(crate::tree::serialize(&chain));
            }
        }
        pack_chains(&chains, self.capacity, &self.batch_options())
    }

    fn run_step(&self, batch: &Batch) -> crate::Result<Vec<HostTensor>> {
        let c = batch.capacity;
        let mut owned: Vec<Literal> = Vec::new();
        let mut slots: Vec<Option<usize>> = Vec::with_capacity(self.step_prog.info.inputs.len());
        for name in &self.step_prog.info.inputs {
            if name.starts_with("param:") {
                slots.push(None);
                continue;
            }
            let tensor = if let Some(key) = name.strip_prefix("batch:") {
                match key {
                    "tokens" => HostTensor::i32(vec![c], batch.tokens.clone()),
                    "prev_idx" => HostTensor::i32(vec![c], batch.prev_idx.clone()),
                    "pos_ids" => HostTensor::i32(vec![c], batch.pos_ids.clone()),
                    "weights" => HostTensor::f32(vec![c], batch.weights.clone()),
                    "q_exit" => HostTensor::i32(vec![c], batch.q_exit.clone()),
                    "k_order" => HostTensor::i32(vec![c], batch.k_order.clone()),
                    "k_exit" => HostTensor::i32(vec![c], batch.k_exit.clone()),
                    "k_bias" => HostTensor::f32(vec![c], batch.k_bias.clone()),
                    "chunk_parent_map" => HostTensor::i32(
                        vec![batch.chunk_parent_map.len()],
                        batch.chunk_parent_map.clone(),
                    ),
                    "ssm_pad" => HostTensor::f32(vec![c], batch.ssm_pad.clone()),
                    "conv_idx" => {
                        let k = batch.conv_idx.len() / c;
                        HostTensor::i32(vec![c, k], batch.conv_idx.clone())
                    }
                    other => anyhow::bail!("unknown batch key {other}"),
                }
            } else {
                anyhow::bail!("unexpected step input {name}");
            };
            owned.push(tensor.to_literal()?);
            slots.push(Some(owned.len() - 1));
        }
        let mut refs: Vec<&Literal> = Vec::with_capacity(slots.len());
        let mut p_iter = self.param_lits.iter();
        for s in &slots {
            refs.push(match s {
                None => p_iter.next().unwrap(),
                Some(i) => &owned[*i],
            });
        }
        self.step_prog.run_literals(&refs)
    }

    /// One optimizer step over the linearized global batch.
    pub fn train_step(&mut self, trees: &[TrajectoryTree]) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        let batches = self.pack_trees(trees)?;
        let mut gb = GradBuffer::zeros(&self.params);
        let mut device_tokens = 0usize;
        for b in &batches {
            let outputs = self.run_step(b)?;
            gb.add_outputs(&outputs, 2);
            device_tokens += b.capacity;
        }
        let grads = gb.normalized();
        let grad_norm = AdamW::grad_norm(&grads);
        self.opt.update(&mut self.params, &grads);
        self.param_lits =
            self.params.iter().map(|p| p.to_literal()).collect::<crate::Result<Vec<_>>>()?;
        self.step_count += 1;
        Ok(StepMetrics {
            step: self.step_count,
            loss: gb.mean_loss(),
            weight_sum: gb.weight_sum,
            device_tokens,
            tree_tokens: trees.iter().map(|t| t.n_tree()).sum(),
            flat_tokens: trees.iter().map(|t| t.n_flat()).sum(),
            wall: t0.elapsed(),
            exec_calls: gb.exec_calls,
            grad_norm,
        })
    }

    /// Loss-only evaluation on packed chains.
    pub fn eval_loss(&self, trees: &[TrajectoryTree]) -> crate::Result<(f64, f64)> {
        let batches = self.pack_trees(trees)?;
        let mut gb = GradBuffer::zeros(&self.params);
        for b in &batches {
            let outputs = self.run_step(b)?;
            gb.add_outputs(&outputs, 2);
        }
        Ok((gb.mean_loss(), gb.weight_sum))
    }

    pub fn set_lr(&mut self, lr: f64) {
        self.opt.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;

    #[test]
    fn packing_preserves_tokens_and_weights() {
        let t = gen::uniform(4, 10, 6, 0.6);
        let chains: Vec<DfsMeta> = t
            .paths()
            .iter()
            .map(|p| crate::tree::serialize(&path_chain(&t, p)))
            .collect();
        let total: usize = chains.iter().map(|m| m.size()).sum();
        let batches = pack_chains(&chains, total.max(32), &BatchOptions::default()).unwrap();
        let packed_w: f32 = batches.iter().flat_map(|b| b.weights.iter()).sum();
        let chain_w: f32 = chains.iter().flat_map(|m| m.weights.iter()).sum();
        assert!((packed_w - chain_w).abs() < 1e-4);
        assert_eq!(batches.iter().map(|b| b.capacity).sum::<usize>() >= total, true);
    }

    #[test]
    fn packed_segments_do_not_cross_attend() {
        let t = gen::uniform(5, 8, 5, 0.6);
        let chains: Vec<DfsMeta> = t
            .paths()
            .iter()
            .map(|p| crate::tree::serialize(&path_chain(&t, p)))
            .collect();
        let cap: usize = chains.iter().map(|m| m.size()).sum::<usize>() + 4;
        let b = &pack_chains(&chains, cap, &BatchOptions::default()).unwrap()[0];
        // derive segment ids from prev_idx root chains (packing reorders
        // chains, so original order is not the layout order)
        let total: usize = chains.iter().map(|m| m.size()).sum();
        let root_of = |mut i: usize| {
            while b.prev_idx[i] >= 0 {
                i = b.prev_idx[i] as usize;
            }
            i
        };
        for i in 0..total {
            for j in 0..=i {
                let live = b.q_exit[j] >= b.q_exit[i];
                assert_eq!(live, root_of(i) == root_of(j), "cross-pack attention at ({i},{j})");
            }
        }
    }

    #[test]
    fn prev_idx_offsets_stay_in_segment() {
        let t = gen::uniform(6, 8, 5, 0.6);
        let chains: Vec<DfsMeta> = t
            .paths()
            .iter()
            .map(|p| crate::tree::serialize(&path_chain(&t, p)))
            .collect();
        let cap: usize = chains.iter().map(|m| m.size()).sum::<usize>() + 8;
        let b = &pack_chains(&chains, cap, &BatchOptions::default()).unwrap()[0];
        for (tk, &p) in b.prev_idx.iter().enumerate() {
            if p >= 0 {
                assert!((p as usize) < tk);
            }
        }
    }
}
