//! The sep-avg baseline (Eq. 1) with sequence packing (§4.2), as a thin
//! strategy on the shared execution [`Engine`].
//!
//! Every root-to-leaf path is linearized into an independent chain and
//! chains are first-fit-decreasing packed into capacity-`C` batches.  A
//! packed batch is a *prefix forest* — "a sequence is a special case of a
//! prefix tree" (§2) — so it runs through the **same** exported `step`
//! program as Tree Training, with metadata that simply never shares
//! prefixes.  Chain packing is literally [`crate::partition::forest`]'s
//! whole-tree packing applied to chain trees, so the speedup comparison is
//! kernel-for-kernel *and* packer-for-packer fair: the baseline pays
//! `N_flat` tokens where Tree Training pays `N_tree`.

use std::sync::Arc;
use std::time::Instant;

use crate::partition::forest;
use crate::runtime::{HostTensor, Runtime};
use crate::tree::dfs::DfsMeta;
use crate::tree::TrajectoryTree;

use super::adamw::AdamWConfig;
use super::batch::{Batch, BatchOptions};
use super::engine::Engine;
use super::grads::GradBuffer;
use super::metrics::StepMetrics;
use super::planner::{BaselinePlan, PlanSpec};

pub struct BaselineTrainer {
    pub engine: Engine,
}

/// First-fit-decreasing packing of chain metas into capacity-C batches
/// (chains are trees; this is forest packing on degenerate trees).
pub fn pack_chains(
    chains: &[DfsMeta],
    capacity: usize,
    opts: &BatchOptions,
) -> crate::Result<Vec<Batch>> {
    for m in chains {
        anyhow::ensure!(
            m.size() <= capacity,
            "path of {} tokens exceeds capacity {capacity}",
            m.size()
        );
    }
    Ok(forest::pack_forest(chains, capacity, opts)?
        .into_iter()
        .map(|fb| fb.batch)
        .collect())
}

impl BaselineTrainer {
    pub fn new(rt: Arc<Runtime>, model: &str, opt_cfg: AdamWConfig) -> crate::Result<Self> {
        Ok(Self { engine: Engine::new(rt, model, opt_cfg)? })
    }

    /// Per-rank replica: an independent engine ([`Engine::replicate`])
    /// compiled for device ordinal `device` — the rank worker state of the
    /// distributed step (`coordinator/dist.rs`).
    pub fn replicate(&self, device: usize) -> crate::Result<Self> {
        Ok(Self { engine: self.engine.replicate(device)? })
    }

    pub fn params(&self) -> &[HostTensor] {
        self.engine.params()
    }

    pub fn capacity(&self) -> usize {
        self.engine.capacity()
    }

    /// Snapshot the engine-free planning half of this trainer.  Baseline
    /// chain packing always packs (a packed batch of chains is just a
    /// prefix forest that never shares), so `forest_packing` is fixed on.
    pub fn plan_spec(&self) -> PlanSpec {
        PlanSpec::from_engine(&self.engine, None, true)
    }

    /// Linearize the global batch into packed chain batches.
    pub fn pack_trees(&self, trees: &[TrajectoryTree]) -> crate::Result<Vec<Batch>> {
        Ok(self.plan_spec().plan_baseline(trees)?.batches)
    }

    /// One optimizer step over the linearized global batch.  Outside the
    /// pipeline there is nothing to overlap with, so planning is timed
    /// here: `wall` covers plan + execute (the seed accounting the paper
    /// figures compare on) and `plan_ms`/`stall_ms` record the plan share.
    pub fn train_step(&mut self, trees: &[TrajectoryTree]) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        let plan = self.plan_spec().plan_baseline(trees)?;
        let plan_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut m = self.execute_plan(&plan)?;
        m.wall = t0.elapsed();
        m.plan_ms = plan_ms;
        m.stall_ms = plan_ms;
        Ok(m)
    }

    /// Execute a plan's chain batches, accumulating into `gb`; returns the
    /// device token count.  The per-rank unit of the distributed step
    /// ([`crate::coordinator::dist`]) — mirrors `TreeTrainer::run_plan`.
    pub fn run_plan(&self, plan: &BaselinePlan, gb: &mut GradBuffer) -> crate::Result<usize> {
        self.run_plan_hooked(plan, gb, &mut |_, _| {})
    }

    /// [`Self::run_plan`] with a per-batch progress hook — the seam the
    /// bucketed collective pumps through
    /// ([`crate::coordinator::dist::RankWorker::execute_hooked`]): called
    /// after each packed batch with the unit index
    /// ([`crate::coordinator::dist::plan_units`]).
    pub fn run_plan_hooked(
        &self,
        plan: &BaselinePlan,
        gb: &mut GradBuffer,
        on_unit: &mut dyn FnMut(&mut GradBuffer, usize),
    ) -> crate::Result<usize> {
        let mut device_tokens = 0usize;
        for (i, b) in plan.batches.iter().enumerate() {
            self.engine.run_step_into(b, gb)?;
            device_tokens += b.capacity;
            on_unit(gb, i);
        }
        Ok(device_tokens)
    }

    /// Execute a pre-built [`BaselinePlan`] and apply the optimizer update.
    pub fn execute_plan(&mut self, plan: &BaselinePlan) -> crate::Result<StepMetrics> {
        let t0 = Instant::now();
        let mut gb = self.engine.grad_buffer();
        let device_tokens = self.run_plan(plan, &mut gb)?;
        let grad_norm = self.engine.apply_update(&gb)?;
        Ok(StepMetrics {
            step: self.engine.step_count(),
            loss: gb.mean_loss(),
            weight_sum: gb.weight_sum,
            device_tokens,
            tree_tokens: plan.tree_tokens,
            flat_tokens: plan.flat_tokens,
            wall: t0.elapsed(),
            exec_calls: gb.exec_calls,
            forest_batches: plan.batches.len() as u64,
            grad_norm,
            plan_ms: 0.0,
            stall_ms: 0.0,
            ranks: 1,
            reduce_ms: 0.0,
            reduce_overlap_ms: 0.0,
            reduce_depth: 0,
            rank_imbalance: 1.0,
            ingest_ms: 0.0,
            cost_model_err: 0.0,
            staleness_steps: 0,
            ripe_queue_depth: 0,
            admitted_sessions: 0,
            // the sep-avg baseline has no shared-prefix structure to reuse
            xstep_reuse_ratio: 1.0,
            cache_hit_tokens: 0,
            cache_evictions: 0,
            reduce_buckets: 0,
            bucket_overlap_ms: 0.0,
            collective_bytes: 0,
        })
    }

    /// Loss-only evaluation on packed chains.
    pub fn eval_loss(&self, trees: &[TrajectoryTree]) -> crate::Result<(f64, f64)> {
        let batches = self.pack_trees(trees)?;
        let mut gb = self.engine.grad_buffer();
        for b in &batches {
            self.engine.run_step_into(b, &mut gb)?;
        }
        Ok((gb.mean_loss(), gb.weight_sum))
    }

    pub fn set_lr(&mut self, lr: f64) {
        self.engine.set_lr(lr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;
    // The one linearization in the crate (shared with ingest round-trips and
    // `gen-data --linearize`): a chain is `tree::path_chain` output.
    use crate::tree::linearize::path_chain;

    #[test]
    fn packing_preserves_tokens_and_weights() {
        let t = gen::uniform(4, 10, 6, 0.6);
        let chains: Vec<DfsMeta> = t
            .paths()
            .iter()
            .map(|p| crate::tree::serialize(&path_chain(&t, p)))
            .collect();
        let total: usize = chains.iter().map(|m| m.size()).sum();
        let batches = pack_chains(&chains, total.max(32), &BatchOptions::default()).unwrap();
        let packed_w: f32 = batches.iter().flat_map(|b| b.weights.iter()).sum();
        let chain_w: f32 = chains.iter().flat_map(|m| m.weights.iter()).sum();
        assert!((packed_w - chain_w).abs() < 1e-4);
        assert!(batches.iter().map(|b| b.capacity).sum::<usize>() >= total);
    }

    #[test]
    fn packed_segments_do_not_cross_attend() {
        let t = gen::uniform(5, 8, 5, 0.6);
        let chains: Vec<DfsMeta> = t
            .paths()
            .iter()
            .map(|p| crate::tree::serialize(&path_chain(&t, p)))
            .collect();
        let cap: usize = chains.iter().map(|m| m.size()).sum::<usize>() + 4;
        let b = &pack_chains(&chains, cap, &BatchOptions::default()).unwrap()[0];
        // derive segment ids from prev_idx root chains (packing reorders
        // chains, so original order is not the layout order)
        let total: usize = chains.iter().map(|m| m.size()).sum();
        let root_of = |mut i: usize| {
            while b.prev_idx[i] >= 0 {
                i = b.prev_idx[i] as usize;
            }
            i
        };
        for i in 0..total {
            for j in 0..=i {
                let live = b.q_exit[j] >= b.q_exit[i];
                assert_eq!(live, root_of(i) == root_of(j), "cross-pack attention at ({i},{j})");
            }
        }
    }

    #[test]
    fn prev_idx_offsets_stay_in_segment() {
        let t = gen::uniform(6, 8, 5, 0.6);
        let chains: Vec<DfsMeta> = t
            .paths()
            .iter()
            .map(|p| crate::tree::serialize(&path_chain(&t, p)))
            .collect();
        let cap: usize = chains.iter().map(|m| m.size()).sum::<usize>() + 8;
        let b = &pack_chains(&chains, cap, &BatchOptions::default()).unwrap()[0];
        for (tk, &p) in b.prev_idx.iter().enumerate() {
            if p >= 0 {
                assert!((p as usize) < tk);
            }
        }
    }
}
