//! f64 gradient accumulation across trees / partitions in one global batch.

use crate::runtime::HostTensor;

/// Flat per-parameter gradient accumulator (f64, App. B.5 discipline).
pub struct GradBuffer {
    pub grads: Vec<Vec<f64>>,
    pub loss_sum: f64,
    pub weight_sum: f64,
    pub exec_calls: u64,
}

impl GradBuffer {
    pub fn zeros(params: &[HostTensor]) -> Self {
        Self {
            grads: params.iter().map(|p| vec![0.0; p.len()]).collect(),
            loss_sum: 0.0,
            weight_sum: 0.0,
            exec_calls: 0,
        }
    }

    /// Add one program call's outputs: loss_sum, weight_sum and the grads
    /// located at `grad_base..grad_base + n_params` in `outputs`.
    pub fn add_outputs(&mut self, outputs: &[HostTensor], grad_base: usize) {
        self.loss_sum += outputs[0].first_f32() as f64;
        self.weight_sum += outputs[1].first_f32() as f64;
        self.exec_calls += 1;
        let n = self.grads.len();
        for (acc, t) in self.grads.iter_mut().zip(&outputs[grad_base..grad_base + n]) {
            for (a, &g) in acc.iter_mut().zip(t.as_f32()) {
                *a += g as f64;
            }
        }
    }

    /// Reduce another rank's accumulator into this one (f64, element-wise).
    /// The distributed step ([`crate::coordinator::dist`]) folds rank
    /// buffers by a **fixed log-tree bracket** (pairing a pure function of
    /// rank ids, `self` always the lower rank side), so the reduced
    /// gradient is bit-identical run-to-run regardless of executor thread
    /// scheduling or message arrival order.
    pub fn merge(&mut self, other: &GradBuffer) {
        debug_assert_eq!(self.grads.len(), other.grads.len());
        self.loss_sum += other.loss_sum;
        self.weight_sum += other.weight_sum;
        self.exec_calls += other.exec_calls;
        for (acc, g) in self.grads.iter_mut().zip(&other.grads) {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x;
            }
        }
    }

    /// [`Self::merge`] in the owned-rhs fold shape the
    /// [`crate::coordinator::dist::RankPool`] reduce consumes.
    pub fn merge_owned(acc: &mut GradBuffer, other: GradBuffer) {
        acc.merge(&other);
    }

    /// Normalized gradients (divide by the global-batch weight sum): makes
    /// tree and sep-avg baselines directly comparable (see trainer docs).
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        let z = if self.weight_sum > 0.0 { 1.0 / self.weight_sum } else { 0.0 };
        self.grads.iter().map(|g| g.iter().map(|&x| x * z).collect()).collect()
    }

    pub fn mean_loss(&self) -> f64 {
        if self.weight_sum > 0.0 {
            self.loss_sum / self.weight_sum
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_normalizes() {
        let params = vec![HostTensor::zeros_f32(vec![2])];
        let mut gb = GradBuffer::zeros(&params);
        let outs = vec![
            HostTensor::scalar_f32(2.0),
            HostTensor::scalar_f32(4.0),
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
        ];
        gb.add_outputs(&outs, 2);
        gb.add_outputs(&outs, 2);
        assert_eq!(gb.loss_sum, 4.0);
        assert_eq!(gb.weight_sum, 8.0);
        assert_eq!(gb.normalized()[0], vec![0.25, 0.5]);
        assert_eq!(gb.mean_loss(), 0.5);
    }

    #[test]
    fn merge_equals_accumulating_in_one_buffer() {
        let params = vec![HostTensor::zeros_f32(vec![2])];
        let outs_a = vec![
            HostTensor::scalar_f32(2.0),
            HostTensor::scalar_f32(4.0),
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
        ];
        let outs_b = vec![
            HostTensor::scalar_f32(1.0),
            HostTensor::scalar_f32(2.0),
            HostTensor::f32(vec![2], vec![-0.5, 3.0]),
        ];
        // one buffer taking both calls...
        let mut whole = GradBuffer::zeros(&params);
        whole.add_outputs(&outs_a, 2);
        whole.add_outputs(&outs_b, 2);
        // ...vs two rank buffers reduced in order
        let mut r0 = GradBuffer::zeros(&params);
        r0.add_outputs(&outs_a, 2);
        let mut r1 = GradBuffer::zeros(&params);
        r1.add_outputs(&outs_b, 2);
        r0.merge(&r1);
        assert_eq!(r0.loss_sum, whole.loss_sum);
        assert_eq!(r0.weight_sum, whole.weight_sum);
        assert_eq!(r0.exec_calls, whole.exec_calls);
        assert_eq!(r0.grads, whole.grads);
    }
}
