//! f64 gradient accumulation across trees / partitions in one global batch.

use std::ops::Range;

use super::prefix_cache::CacheStats;
use crate::runtime::HostTensor;

/// Flat per-parameter gradient accumulator (f64, App. B.5 discipline).
pub struct GradBuffer {
    pub grads: Vec<Vec<f64>>,
    pub loss_sum: f64,
    pub weight_sum: f64,
    pub exec_calls: u64,
    /// Per-rank engine prefix-cache counters drained into the accumulator
    /// after execute, so pooled reduces surface a *live* reuse trio instead
    /// of the primary engine's inert zeros (docs/prefix_reuse.md).
    pub cache: CacheStats,
}

impl GradBuffer {
    pub fn zeros(params: &[HostTensor]) -> Self {
        Self {
            grads: params.iter().map(|p| vec![0.0; p.len()]).collect(),
            loss_sum: 0.0,
            weight_sum: 0.0,
            exec_calls: 0,
            cache: CacheStats::default(),
        }
    }

    /// Add one program call's outputs: loss_sum, weight_sum and the grads
    /// located at `grad_base..grad_base + n_params` in `outputs`.
    pub fn add_outputs(&mut self, outputs: &[HostTensor], grad_base: usize) {
        self.loss_sum += outputs[0].first_f32() as f64;
        self.weight_sum += outputs[1].first_f32() as f64;
        self.exec_calls += 1;
        let n = self.grads.len();
        for (acc, t) in self.grads.iter_mut().zip(&outputs[grad_base..grad_base + n]) {
            for (a, &g) in acc.iter_mut().zip(t.as_f32()) {
                *a += g as f64;
            }
        }
    }

    /// Reduce another rank's accumulator into this one (f64, element-wise).
    /// The distributed step ([`crate::coordinator::dist`]) folds rank
    /// buffers by a **fixed log-tree bracket** (pairing a pure function of
    /// rank ids, `self` always the lower rank side), so the reduced
    /// gradient is bit-identical run-to-run regardless of executor thread
    /// scheduling or message arrival order.
    pub fn merge(&mut self, other: &GradBuffer) {
        debug_assert_eq!(self.grads.len(), other.grads.len());
        self.merge_scalars(other);
        for (acc, g) in self.grads.iter_mut().zip(&other.grads) {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x;
            }
        }
    }

    /// The non-payload half of [`Self::merge`]: loss / weight sums, call
    /// counts and cache counters.  The bucketed collective path folds the
    /// gradient payload separately (in the identical bracket order) and
    /// merges child accumulators *stripped* — this is the merge it uses.
    pub fn merge_scalars(&mut self, other: &GradBuffer) {
        self.loss_sum += other.loss_sum;
        self.weight_sum += other.weight_sum;
        self.exec_calls += other.exec_calls;
        self.cache.absorb(&other.cache);
    }

    /// [`Self::merge`] in the owned-rhs fold shape the
    /// [`crate::coordinator::dist::RankPool`] reduce consumes.
    pub fn merge_owned(acc: &mut GradBuffer, other: GradBuffer) {
        acc.merge(&other);
    }

    // ── flat bucket views (collective data plane; no copies unless a
    //    bucket actually crosses the wire) ──

    /// Total f64 payload elements across all parameter gradients — the
    /// flat index space [`Self::read_flat`] / [`Self::fold_flat`] address.
    pub fn flat_len(&self) -> usize {
        self.grads.iter().map(|g| g.len()).sum()
    }

    /// Copy the flat range `range` (spanning parameter boundaries) into
    /// `out` (cleared first).
    pub fn read_flat(&self, range: Range<usize>, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(range.len());
        let mut base = 0usize;
        for g in &self.grads {
            let lo = range.start.max(base);
            let hi = range.end.min(base + g.len());
            if lo < hi {
                out.extend_from_slice(&g[lo - base..hi - base]);
            }
            base += g.len();
            if base >= range.end {
                break;
            }
        }
        debug_assert_eq!(out.len(), range.len(), "flat range out of bounds");
    }

    /// Element-wise add `data` into the flat range `range` — the bucket
    /// fold.  `data.len()` must equal `range.len()`.
    pub fn fold_flat(&mut self, range: Range<usize>, data: &[f64]) {
        debug_assert_eq!(data.len(), range.len());
        let mut base = 0usize;
        let mut off = 0usize;
        for g in &mut self.grads {
            let glen = g.len();
            let lo = range.start.max(base);
            let hi = range.end.min(base + glen);
            if lo < hi {
                let n = hi - lo;
                for (a, &x) in g[lo - base..hi - base].iter_mut().zip(&data[off..off + n]) {
                    *a += x;
                }
                off += n;
            }
            base += glen;
            if base >= range.end {
                break;
            }
        }
        debug_assert_eq!(off, data.len(), "flat range out of bounds");
    }

    /// Drop the gradient payload, keeping scalars: what a non-root rank
    /// sends up the typed control plane once its payload has already
    /// traveled the collective data plane.
    pub fn strip_grads(&mut self) {
        self.grads = Vec::new();
    }

    /// Normalized gradients (divide by the global-batch weight sum): makes
    /// tree and sep-avg baselines directly comparable (see trainer docs).
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        let z = if self.weight_sum > 0.0 { 1.0 / self.weight_sum } else { 0.0 };
        self.grads.iter().map(|g| g.iter().map(|&x| x * z).collect()).collect()
    }

    pub fn mean_loss(&self) -> f64 {
        if self.weight_sum > 0.0 {
            self.loss_sum / self.weight_sum
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_normalizes() {
        let params = vec![HostTensor::zeros_f32(vec![2])];
        let mut gb = GradBuffer::zeros(&params);
        let outs = vec![
            HostTensor::scalar_f32(2.0),
            HostTensor::scalar_f32(4.0),
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
        ];
        gb.add_outputs(&outs, 2);
        gb.add_outputs(&outs, 2);
        assert_eq!(gb.loss_sum, 4.0);
        assert_eq!(gb.weight_sum, 8.0);
        assert_eq!(gb.normalized()[0], vec![0.25, 0.5]);
        assert_eq!(gb.mean_loss(), 0.5);
    }

    #[test]
    fn merge_equals_accumulating_in_one_buffer() {
        let params = vec![HostTensor::zeros_f32(vec![2])];
        let outs_a = vec![
            HostTensor::scalar_f32(2.0),
            HostTensor::scalar_f32(4.0),
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
        ];
        let outs_b = vec![
            HostTensor::scalar_f32(1.0),
            HostTensor::scalar_f32(2.0),
            HostTensor::f32(vec![2], vec![-0.5, 3.0]),
        ];
        // one buffer taking both calls...
        let mut whole = GradBuffer::zeros(&params);
        whole.add_outputs(&outs_a, 2);
        whole.add_outputs(&outs_b, 2);
        // ...vs two rank buffers reduced in order
        let mut r0 = GradBuffer::zeros(&params);
        r0.add_outputs(&outs_a, 2);
        let mut r1 = GradBuffer::zeros(&params);
        r1.add_outputs(&outs_b, 2);
        r0.merge(&r1);
        assert_eq!(r0.loss_sum, whole.loss_sum);
        assert_eq!(r0.weight_sum, whole.weight_sum);
        assert_eq!(r0.exec_calls, whole.exec_calls);
        assert_eq!(r0.grads, whole.grads);
    }

    fn two_param_buffer() -> GradBuffer {
        GradBuffer {
            grads: vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0]],
            loss_sum: 1.0,
            weight_sum: 2.0,
            exec_calls: 3,
            cache: CacheStats::default(),
        }
    }

    #[test]
    fn flat_views_span_parameter_boundaries() {
        let gb = two_param_buffer();
        assert_eq!(gb.flat_len(), 5);
        let mut out = Vec::new();
        gb.read_flat(0..5, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        gb.read_flat(2..4, &mut out);
        assert_eq!(out, vec![3.0, 4.0], "crosses the param boundary");
        gb.read_flat(4..5, &mut out);
        assert_eq!(out, vec![5.0]);
    }

    #[test]
    fn fold_flat_matches_merge_per_bucket() {
        // folding a peer bucket-by-bucket must equal the monolithic merge
        let mut bucketed = two_param_buffer();
        let mut monolithic = two_param_buffer();
        let peer = GradBuffer {
            grads: vec![vec![0.5, -1.0, 0.25], vec![10.0, -20.0]],
            loss_sum: 0.5,
            weight_sum: 1.0,
            exec_calls: 1,
            cache: CacheStats::default(),
        };
        monolithic.merge(&peer);
        let mut buf = Vec::new();
        for range in [0..2usize, 2..4, 4..5] {
            peer.read_flat(range.clone(), &mut buf);
            bucketed.fold_flat(range, &buf);
        }
        bucketed.merge_scalars(&peer);
        assert_eq!(bucketed.grads, monolithic.grads);
        assert_eq!(bucketed.loss_sum, monolithic.loss_sum);
        assert_eq!(bucketed.exec_calls, monolithic.exec_calls);
    }

    #[test]
    fn strip_keeps_scalars_and_cache() {
        let mut gb = two_param_buffer();
        gb.cache.hit_tokens = 7;
        gb.strip_grads();
        assert_eq!(gb.flat_len(), 0);
        assert_eq!(gb.loss_sum, 1.0);
        assert_eq!(gb.exec_calls, 3);
        assert_eq!(gb.cache.hit_tokens, 7);
        // merging a stripped peer through the scalar path never touches
        // the payload (merge would debug_assert on the length mismatch)
        let mut full = two_param_buffer();
        full.merge_scalars(&gb);
        assert_eq!(full.loss_sum, 2.0);
        assert_eq!(full.grads, two_param_buffer().grads);
    }
}
