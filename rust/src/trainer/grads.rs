//! f64 gradient accumulation across trees / partitions in one global batch.

use crate::runtime::HostTensor;

/// Flat per-parameter gradient accumulator (f64, App. B.5 discipline).
pub struct GradBuffer {
    pub grads: Vec<Vec<f64>>,
    pub loss_sum: f64,
    pub weight_sum: f64,
    pub exec_calls: u64,
}

impl GradBuffer {
    pub fn zeros(params: &[HostTensor]) -> Self {
        Self {
            grads: params.iter().map(|p| vec![0.0; p.len()]).collect(),
            loss_sum: 0.0,
            weight_sum: 0.0,
            exec_calls: 0,
        }
    }

    /// Add one program call's outputs: loss_sum, weight_sum and the grads
    /// located at `grad_base..grad_base + n_params` in `outputs`.
    pub fn add_outputs(&mut self, outputs: &[HostTensor], grad_base: usize) {
        self.loss_sum += outputs[0].first_f32() as f64;
        self.weight_sum += outputs[1].first_f32() as f64;
        self.exec_calls += 1;
        let n = self.grads.len();
        for (acc, t) in self.grads.iter_mut().zip(&outputs[grad_base..grad_base + n]) {
            for (a, &g) in acc.iter_mut().zip(t.as_f32()) {
                *a += g as f64;
            }
        }
    }

    /// Normalized gradients (divide by the global-batch weight sum): makes
    /// tree and sep-avg baselines directly comparable (see trainer docs).
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        let z = if self.weight_sum > 0.0 { 1.0 / self.weight_sum } else { 0.0 };
        self.grads.iter().map(|g| g.iter().map(|&x| x * z).collect()).collect()
    }

    pub fn mean_loss(&self) -> f64 {
        if self.weight_sum > 0.0 {
            self.loss_sum / self.weight_sum
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_normalizes() {
        let params = vec![HostTensor::zeros_f32(vec![2])];
        let mut gb = GradBuffer::zeros(&params);
        let outs = vec![
            HostTensor::scalar_f32(2.0),
            HostTensor::scalar_f32(4.0),
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
        ];
        gb.add_outputs(&outs, 2);
        gb.add_outputs(&outs, 2);
        assert_eq!(gb.loss_sum, 4.0);
        assert_eq!(gb.weight_sum, 8.0);
        assert_eq!(gb.normalized()[0], vec![0.25, 0.5]);
        assert_eq!(gb.mean_loss(), 0.5);
    }
}
