//! The unified execution engine: parameter state, program dispatch and the
//! optimizer step, shared by every training strategy.
//!
//! Before this layer existed, `TreeTrainer` and `BaselineTrainer` each
//! carried their own copy of the parameter-literal cache, the
//! manifest-ordered input marshalling, the f64 `GradBuffer` plumbing and the
//! AdamW update.  The engine owns all of that once:
//!
//! * **params / param_lits** — host parameters plus their cached XLA
//!   literals, rebuilt only after an optimizer update (the hot-path
//!   optimization: ~MBs of weights are *not* re-converted per program call);
//! * **program dispatch** — `step`, `part_fwd`, `part_bwd` handles resolved
//!   from the manifest, with [`Engine::run_prog`] marshalling batch vectors
//!   and extra tensors in each program's recorded input order;
//! * **optimizer** — Eq. 5 global-batch weight normalization followed by an
//!   AdamW update and a literal-cache refresh.
//!
//! Strategies ([`super::TreeTrainer`], [`super::BaselineTrainer`]) reduce to
//! *planning*: they decide which batches exist (Forest Packing, partition
//! relays, chain packing) and feed them through the engine.

use std::sync::{Arc, Mutex};

use crate::gateway::KvCache;
use crate::runtime::{HostTensor, Program, Runtime};
use xla::Literal;

use super::adamw::{AdamW, AdamWConfig};
use super::batch::{Batch, BatchOptions};
use super::grads::GradBuffer;
use super::prefix_cache::{CacheStats, PrefixCache};

pub struct Engine {
    pub rt: Arc<Runtime>,
    pub model: String,
    params: Vec<HostTensor>,
    /// Cached parameter literals (rebuilt after each optimizer update).
    param_lits: Vec<Literal>,
    opt: AdamW,
    step_prog: Arc<Program>,
    fwd_prog: Option<Arc<Program>>,
    bwd_prog: Option<Arc<Program>>,
    capacity: usize,
    past_capacity: usize,
    n_attn: usize,
    heads: usize,
    head_dim: usize,
    hybrid: Option<(usize, usize)>, // (chunk_size, conv_kernel)
    step_count: u64,
    /// Accounting-only prefix cache (docs/prefix_reuse.md "Engine path"):
    /// the exported `step` program recomputes every slot, so the device tier
    /// tracks *would-be* hits — `()` payloads — to surface cross-step reuse
    /// headroom in `StepMetrics` without changing any computed bit.  The
    /// cache version IS `step_count`: [`Engine::apply_update`] bumps it, so
    /// no entry (here or in any host-tier cache keyed off
    /// [`Engine::step_count`]) survives an Eq. 5 parameter update.  Behind a
    /// `Mutex` because dispatch paths take `&self`; contention is nil (one
    /// lock per annotated forest member).
    prefix_cache: Mutex<PrefixCache<()>>,
}

impl Engine {
    pub fn new(rt: Arc<Runtime>, model: &str, opt_cfg: AdamWConfig) -> crate::Result<Self> {
        let info = rt.manifest.model(model)?.clone();
        let params = rt.manifest.load_params(model)?;
        let step_prog = rt.find_program("step", model, 0)?;
        let capacity = step_prog.info.capacity;
        let (fwd_prog, bwd_prog, past_capacity) = match rt.manifest.find("part_fwd", model, 0) {
            Ok(p) => {
                let a = p.past;
                (
                    Some(rt.program(&p.name.clone())?),
                    Some(rt.find_program("part_bwd", model, 0)?),
                    a,
                )
            }
            Err(_) => (None, None, 0),
        };
        let hybrid = if info.kind() == "hybrid" {
            Some((info.chunk_size(), info.conv_kernel()))
        } else {
            None
        };
        let opt = AdamW::new(opt_cfg, &params);
        let param_lits = params
            .iter()
            .map(|p| p.to_literal())
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Self {
            rt,
            model: model.to_string(),
            params,
            param_lits,
            opt,
            step_prog,
            fwd_prog,
            bwd_prog,
            capacity,
            past_capacity,
            n_attn: info.n_attn_layers,
            heads: info.n_heads(),
            head_dim: info.head_dim(),
            hybrid,
            step_count: 0,
            prefix_cache: Mutex::new(PrefixCache::new(0)),
        })
    }

    /// Clone this engine into an independent per-rank replica: own
    /// parameter tensors, own literal cache, own optimizer state (step +
    /// f64 moments), own program handles — compiled fresh through
    /// [`Runtime::program_replica`] for device ordinal
    /// `device % device_count`, bypassing the shared cache, so no
    /// execution handle is shared across rank worker threads and on a real
    /// multi-device PJRT backend each rank's programs are lowered for its
    /// own device (see `coordinator/dist.rs`, which passes the rank id).
    ///
    /// The replica starts bit-identical to `self`; applying the same
    /// reduced gradient stream with the same LR keeps it that way.  Memory
    /// cost per replica ≈ params (f32) + cached literals + the AdamW f64
    /// moments: ~24 bytes per parameter on top of the primary
    /// (docs/distributed.md).
    pub fn replicate(&self, device: usize) -> crate::Result<Self> {
        let step_prog = self.rt.program_replica(&self.step_prog.info.name, device)?;
        let (fwd_prog, bwd_prog) = match (&self.fwd_prog, &self.bwd_prog) {
            (Some(f), Some(b)) => (
                Some(self.rt.program_replica(&f.info.name, device)?),
                Some(self.rt.program_replica(&b.info.name, device)?),
            ),
            _ => (None, None),
        };
        let param_lits = self
            .params
            .iter()
            .map(|p| p.to_literal())
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Self {
            rt: self.rt.clone(),
            model: self.model.clone(),
            params: self.params.clone(),
            param_lits,
            opt: self.opt.clone(),
            step_prog,
            fwd_prog,
            bwd_prog,
            capacity: self.capacity,
            past_capacity: self.past_capacity,
            n_attn: self.n_attn,
            heads: self.heads,
            head_dim: self.head_dim,
            hybrid: self.hybrid,
            step_count: self.step_count,
            // replicas share the budget but start cold: entries are
            // rank-local accounting, never parameter state
            prefix_cache: Mutex::new(PrefixCache::new(
                self.prefix_cache.lock().unwrap().budget_tokens(),
            )),
        })
    }

    // ── state accessors ────────────────────────────────────────────────

    pub fn params(&self) -> &[HostTensor] {
        &self.params
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Device token capacity of the `step` program.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// `(capacity, gateway rows)` of the partition programs, when exported.
    pub fn part_caps(&self) -> Option<(usize, usize)> {
        self.fwd_prog.as_ref().map(|p| (p.info.capacity, self.past_capacity))
    }

    pub fn has_part_programs(&self) -> bool {
        self.fwd_prog.is_some()
    }

    /// `(chunk_size, conv_kernel)` for hybrid-GDN models.
    pub fn hybrid(&self) -> Option<(usize, usize)> {
        self.hybrid
    }

    pub fn kv_dims(&self) -> (usize, usize, usize) {
        (self.n_attn, self.heads, self.head_dim)
    }

    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    pub fn batch_options(&self) -> BatchOptions {
        BatchOptions {
            chunk_size: self.hybrid.map(|(c, _)| c),
            conv_kernel: self.hybrid.map(|(_, k)| k),
            ..Default::default()
        }
    }

    pub fn grad_buffer(&self) -> GradBuffer {
        GradBuffer::zeros(&self.params)
    }

    // ── prefix-reuse accounting (docs/prefix_reuse.md) ─────────────────

    /// (Re)size the accounting prefix cache.  `0` disables it (the
    /// default: seed-exact, zero overhead).
    pub fn set_prefix_cache_tokens(&mut self, budget_tokens: usize) {
        *self.prefix_cache.get_mut().unwrap() = PrefixCache::new(budget_tokens);
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_cache.lock().unwrap().enabled()
    }

    /// Record one annotated forest member against the accounting cache:
    /// counts a hit (and `prefix_len` reusable slots) if the fingerprint is
    /// live under the current parameter version, else a miss + insert.
    /// Purely observational — the `step` program still computes every slot.
    pub fn note_prefix(&self, sig: u64, prefix_len: usize) -> bool {
        let mut cache = self.prefix_cache.lock().unwrap();
        if cache.lookup(sig, prefix_len).is_some() {
            true
        } else {
            cache.insert(sig, prefix_len, ());
            false
        }
    }

    /// Drain the accounting counters accumulated since the last drain
    /// (the `take_ingest_ms` idiom; feeds the `xstep_reuse_ratio` /
    /// `cache_hit_tokens` / `cache_evictions` metrics columns).
    pub fn take_cache_stats(&self) -> CacheStats {
        self.prefix_cache.lock().unwrap().take_stats()
    }

    // ── program dispatch ───────────────────────────────────────────────

    /// Run a program: cached parameter literals + freshly-built batch/extra
    /// literals, in the program's recorded input order.
    pub fn run_prog(
        &self,
        prog: &Program,
        batch: &Batch,
        extra: &[(&str, HostTensor)],
    ) -> crate::Result<Vec<HostTensor>> {
        let c = batch.capacity;
        let t = batch.past_len + c;
        let mut owned: Vec<Literal> = Vec::new();
        let mut slots: Vec<Option<usize>> = Vec::with_capacity(prog.info.inputs.len());
        let mut p_count = 0usize;
        for name in &prog.info.inputs {
            if name.starts_with("param:") {
                slots.push(None);
                p_count += 1;
                continue;
            }
            let tensor = if let Some(key) = name.strip_prefix("batch:") {
                match key {
                    "tokens" => HostTensor::i32(vec![c], batch.tokens.clone()),
                    "prev_idx" => HostTensor::i32(vec![c], batch.prev_idx.clone()),
                    "pos_ids" => HostTensor::i32(vec![c], batch.pos_ids.clone()),
                    "weights" => HostTensor::f32(vec![c], batch.weights.clone()),
                    "q_exit" => HostTensor::i32(vec![c], batch.q_exit.clone()),
                    "k_order" => HostTensor::i32(vec![t], batch.k_order.clone()),
                    "k_exit" => HostTensor::i32(vec![t], batch.k_exit.clone()),
                    "k_bias" => HostTensor::f32(vec![t], batch.k_bias.clone()),
                    "chunk_parent_map" => HostTensor::i32(
                        vec![batch.chunk_parent_map.len()],
                        batch.chunk_parent_map.clone(),
                    ),
                    "ssm_pad" => HostTensor::f32(vec![c], batch.ssm_pad.clone()),
                    "conv_idx" => {
                        let k = batch.conv_idx.len() / c;
                        HostTensor::i32(vec![c, k], batch.conv_idx.clone())
                    }
                    other => anyhow::bail!("unknown batch key {other}"),
                }
            } else {
                extra
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, t)| t.clone())
                    .ok_or_else(|| anyhow::anyhow!("missing extra input {name}"))?
            };
            owned.push(tensor.to_literal()?);
            slots.push(Some(owned.len() - 1));
        }
        anyhow::ensure!(p_count == self.param_lits.len(), "param count mismatch");
        let mut refs: Vec<&Literal> = Vec::with_capacity(slots.len());
        let mut p_iter = self.param_lits.iter();
        for s in &slots {
            refs.push(match s {
                None => p_iter.next().unwrap(),
                Some(i) => &owned[*i],
            });
        }
        prog.run_literals(&refs)
    }

    /// One `step` call; accumulate its loss/weight/grad outputs.
    pub fn run_step_into(&self, batch: &Batch, gb: &mut GradBuffer) -> crate::Result<()> {
        let outputs = self.run_prog(self.step_prog.as_ref(), batch, &[])?;
        gb.add_outputs(&outputs, 2);
        Ok(())
    }

    /// One `part_fwd` call with the gathered gateway KV; returns the
    /// partition-call KV cache (`[n_attn, capacity, heads, head_dim]`).
    pub fn run_part_fwd(&self, batch: &Batch, k_in: &KvCache) -> crate::Result<KvCache> {
        let fwd = self
            .fwd_prog
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no part_fwd exported for {}", self.model))?;
        let (na, h, hd) = (self.n_attn, self.heads, self.head_dim);
        let a = self.past_capacity;
        let c = fwd.info.capacity;
        let extras = [
            ("k_in", HostTensor::f32(vec![na, a, h, hd], k_in.k.clone())),
            ("v_in", HostTensor::f32(vec![na, a, h, hd], k_in.v.clone())),
        ];
        let outputs = self.run_prog(fwd, batch, &extras)?;
        let mut cache = KvCache::zeros(na, c, h, hd);
        cache.k.copy_from_slice(outputs[2].as_f32());
        cache.v.copy_from_slice(outputs[3].as_f32());
        Ok(cache)
    }

    /// One `part_bwd` call: gateway KV + incoming KV cotangents; returns the
    /// raw outputs `[loss_sum, weight_sum, grads.., d_k_in, d_v_in]`.
    pub fn run_part_bwd(
        &self,
        batch: &Batch,
        k_in: &KvCache,
        d_k: Vec<f32>,
        d_v: Vec<f32>,
    ) -> crate::Result<Vec<HostTensor>> {
        let bwd = self
            .bwd_prog
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no part_bwd exported for {}", self.model))?;
        let (na, h, hd) = (self.n_attn, self.heads, self.head_dim);
        let a = self.past_capacity;
        let c = bwd.info.capacity;
        let extras = [
            ("k_in", HostTensor::f32(vec![na, a, h, hd], k_in.k.clone())),
            ("v_in", HostTensor::f32(vec![na, a, h, hd], k_in.v.clone())),
            ("d_k_part", HostTensor::f32(vec![na, c, h, hd], d_k)),
            ("d_v_part", HostTensor::f32(vec![na, c, h, hd], d_v)),
            ("loss_cot", HostTensor::scalar_f32(1.0)),
        ];
        self.run_prog(bwd, batch, &extras)
    }

    // ── optimizer ──────────────────────────────────────────────────────

    /// Eq. 5: normalize by the global-batch weight sum, clip/update with
    /// AdamW, refresh the literal cache.  Returns the pre-clip grad norm.
    pub fn apply_update(&mut self, gb: &GradBuffer) -> crate::Result<f64> {
        let grads = gb.normalized();
        let grad_norm = AdamW::grad_norm(&grads);
        self.opt.update(&mut self.params, &grads);
        self.param_lits = self
            .params
            .iter()
            .map(|p| p.to_literal())
            .collect::<crate::Result<Vec<_>>>()?;
        self.step_count += 1;
        // the staleness contract: the new parameter version hard-invalidates
        // every cached prefix — no entry crosses an Eq. 5 update
        self.prefix_cache.get_mut().unwrap().set_version(self.step_count);
        Ok(grad_norm)
    }

    pub fn set_lr(&mut self, lr: f64) {
        self.opt.cfg.lr = lr;
    }
}
