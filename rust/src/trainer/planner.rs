//! The *plan* half of both training strategies, as engine-free data.
//!
//! Planning a global batch — Forest Packing whole trees into `step` calls,
//! partitioning oversized trees and packing their specs into relay calls
//! (tree mode), or linearizing paths and sequence-packing the chains
//! (baseline mode) — consumes nothing from the [`super::Engine`] but a
//! handful of scalars: the device capacity, the partition-program caps, the
//! hybrid chunking geometry.  [`PlanSpec`] captures exactly those scalars,
//! so the whole planning layer is a pure `Send` function of
//! `(spec, trees) -> StepPlan` that can run on a background thread while
//! the engine executes the previous step's plan
//! ([`crate::coordinator::pipeline`]).
//!
//! [`TreeTrainer`](super::TreeTrainer) and
//! [`BaselineTrainer`](super::BaselineTrainer) keep their public planning
//! entry points, now as thin delegates to their [`PlanSpec`].

use std::borrow::{Borrow, Cow};

use crate::partition::forest::{self, ForestBatch, RelaySchedule};
use crate::partition::{greedy_pack, plan, Plan};
use crate::tree::linearize::path_chain;
use crate::tree::TrajectoryTree;

use super::baseline::pack_chains;
use super::batch::{Batch, BatchOptions};
use super::engine::Engine;

/// Everything one tree-mode optimizer step will execute, fully planned up
/// front: the packed `step` batches plus the partition-relay schedule.
/// Built by [`PlanSpec::plan_tree`]; the coordinator treats it as an opaque
/// stream of device batches.
pub struct GlobalPlan {
    pub forests: Vec<ForestBatch>,
    pub relay: Option<RelayPlan>,
    pub tree_tokens: usize,
    pub flat_tokens: usize,
}

pub struct RelayPlan {
    pub plans: Vec<Plan>,
    pub schedule: RelaySchedule,
}

impl GlobalPlan {
    /// Program calls this plan will execute (the packing metric).
    pub fn program_calls(&self) -> usize {
        self.forests.len() + self.relay.as_ref().map_or(0, |r| r.schedule.program_calls())
    }
}

/// A baseline-mode step, planned: every root-to-leaf path linearized and
/// sequence-packed into capacity-`C` batches (Eq. 1 + §4.2 packing).
pub struct BaselinePlan {
    pub batches: Vec<Batch>,
    pub tree_tokens: usize,
    pub flat_tokens: usize,
}

/// One planned optimizer step, either mode — what flows from the planner
/// side of the pipeline to the executor side.
pub enum StepPlan {
    Tree(GlobalPlan),
    Baseline(BaselinePlan),
}

impl StepPlan {
    pub fn program_calls(&self) -> usize {
        match self {
            Self::Tree(p) => p.program_calls(),
            Self::Baseline(p) => p.batches.len(),
        }
    }

    pub fn tree_tokens(&self) -> usize {
        match self {
            Self::Tree(p) => p.tree_tokens,
            Self::Baseline(p) => p.tree_tokens,
        }
    }

    pub fn flat_tokens(&self) -> usize {
        match self {
            Self::Tree(p) => p.flat_tokens,
            Self::Baseline(p) => p.flat_tokens,
        }
    }
}

/// The engine-derived scalars planning needs — plain data, `Clone + Send`,
/// valid for the lifetime of the exported programs (capacities never change
/// after export, so a spec snapshot taken at run start stays correct).
#[derive(Debug, Clone)]
pub struct PlanSpec {
    /// Device token capacity of the `step` program.
    pub capacity: usize,
    /// `(capacity, gateway rows)` of the partition programs, when exported.
    pub part_caps: Option<(usize, usize)>,
    /// `(chunk_size, conv_kernel)` for hybrid-GDN models.
    pub hybrid: Option<(usize, usize)>,
    pub opts: BatchOptions,
    /// Partition-packing token budget override (≤ partition capacity).
    pub partition_budget: Option<usize>,
    /// Cross-tree Forest Packing (off = seed's one-call-per-tree path).
    pub forest_packing: bool,
}

impl PlanSpec {
    /// Snapshot the planning-relevant scalars of an engine.
    pub fn from_engine(
        engine: &Engine,
        partition_budget: Option<usize>,
        forest_packing: bool,
    ) -> Self {
        Self {
            capacity: engine.capacity(),
            part_caps: engine.part_caps(),
            hybrid: engine.hybrid(),
            opts: engine.batch_options(),
            partition_budget,
            forest_packing,
        }
    }

    /// A device-free spec (no partition programs, no hybrid chunking) —
    /// the planning surface used by host-only tests, benches and the
    /// `pipeline-smoke` command, where [`crate::trainer::refmodel::RefModel`]
    /// stands in for the exported programs.
    pub fn for_host(capacity: usize) -> Self {
        Self {
            capacity,
            part_caps: None,
            hybrid: None,
            opts: BatchOptions::default(),
            partition_budget: None,
            forest_packing: true,
        }
    }

    /// Chunk-pad a tree for hybrid models; borrows unchanged trees (no
    /// per-tree deep clone on the dense/MoE planning path).
    pub fn prepare<'a>(&self, tree: &'a TrajectoryTree) -> Cow<'a, TrajectoryTree> {
        match self.hybrid {
            Some((chunk, _)) => Cow::Owned(tree.pad_for_chunks(chunk, 0)),
            None => Cow::Borrowed(tree),
        }
    }

    /// Partition one oversized (prepared) tree into an executable plan.
    pub fn partition_tree(&self, tree: &TrajectoryTree) -> crate::Result<Plan> {
        let (c, _) = self.part_caps.ok_or_else(|| {
            anyhow::anyhow!("tree exceeds capacity and no part_fwd exported")
        })?;
        anyhow::ensure!(
            self.hybrid.is_none(),
            "partitioned hybrid models are not exported (DESIGN.md §2)"
        );
        let budget = self.partition_budget.unwrap_or(c).min(c);
        // leave virtual-slot headroom: a node may cut several children
        let tree = tree.split_long_segments(budget - budget / 8);
        let assignment = greedy_pack(&tree, budget)?;
        plan(&tree, &assignment)
    }

    /// Plan a whole global batch of trees as packed device batches (§3.4:
    /// each batch is tree-complete; shuffling happens between trees
    /// upstream).  Accepts both `&[TrajectoryTree]` and the coordinator's
    /// reference-counted `&[Arc<TrajectoryTree>]` batches.
    pub fn plan_tree<T: Borrow<TrajectoryTree>>(&self, trees: &[T]) -> crate::Result<GlobalPlan> {
        let mut metas = Vec::new();
        let mut plans = Vec::new();
        for tree in trees {
            let prepared = self.prepare(tree.borrow());
            if prepared.n_slots() <= self.capacity {
                metas.push(crate::tree::serialize(&prepared));
            } else {
                plans.push(self.partition_tree(&prepared)?);
            }
        }
        let forests = if self.forest_packing {
            forest::pack_forest(&metas, self.capacity, &self.opts)?
        } else {
            (0..metas.len())
                .map(|i| forest::concat_metas(&metas, &[i], self.capacity, &self.opts))
                .collect::<crate::Result<Vec<_>>>()?
        };
        let relay = if plans.is_empty() {
            None
        } else {
            let (c, a) = self.part_caps.expect("partition_tree checked");
            let schedule = forest::schedule_partition_calls(&plans, c, a, self.forest_packing)?;
            Some(RelayPlan { plans, schedule })
        };
        Ok(GlobalPlan {
            forests,
            relay,
            tree_tokens: trees.iter().map(|t| t.borrow().n_tree()).sum(),
            flat_tokens: trees.iter().map(|t| t.borrow().n_flat()).sum(),
        })
    }

    /// Linearize a global batch into packed chain batches (the baseline's
    /// "plan": sep-avg linearization + sequence packing).
    pub fn plan_baseline<T: Borrow<TrajectoryTree>>(
        &self,
        trees: &[T],
    ) -> crate::Result<BaselinePlan> {
        let mut chains = Vec::new();
        for tree in trees {
            let tree = tree.borrow();
            for path in tree.paths() {
                let mut chain = path_chain(tree, &path);
                if chain.n_tree() > self.capacity {
                    anyhow::bail!(
                        "path of {} tokens exceeds baseline capacity {} — the \
                         baseline cannot sequence-pack it (tree training would \
                         partition it); reduce path length or export a larger \
                         bucket ({} nodes)",
                        chain.n_tree(),
                        self.capacity,
                        chain.len()
                    );
                }
                if let Some((chunk, _)) = self.hybrid {
                    chain = chain.pad_for_chunks(chunk, 0);
                }
                chains.push(crate::tree::serialize(&chain));
            }
        }
        Ok(BaselinePlan {
            batches: pack_chains(&chains, self.capacity, &self.opts)?,
            tree_tokens: trees.iter().map(|t| t.borrow().n_tree()).sum(),
            flat_tokens: trees.iter().map(|t| t.borrow().n_flat()).sum(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;
    use std::sync::Arc;

    fn spec(capacity: usize) -> PlanSpec {
        PlanSpec::for_host(capacity)
    }

    #[test]
    fn arc_and_owned_batches_plan_identically() {
        let trees: Vec<TrajectoryTree> = (0..4).map(|s| gen::uniform(s, 9, 5, 0.6)).collect();
        let shared: Vec<Arc<TrajectoryTree>> = trees.iter().cloned().map(Arc::new).collect();
        let sp = spec(4096);
        let a = sp.plan_tree(&trees).unwrap();
        let b = sp.plan_tree(&shared).unwrap();
        assert_eq!(a.tree_tokens, b.tree_tokens);
        assert_eq!(a.flat_tokens, b.flat_tokens);
        assert_eq!(a.forests.len(), b.forests.len());
        for (x, y) in a.forests.iter().zip(&b.forests) {
            assert_eq!(x.batch, y.batch);
        }
    }

    #[test]
    fn prepare_borrows_without_hybrid() {
        let t = gen::uniform(1, 8, 5, 0.5);
        match spec(1024).prepare(&t) {
            Cow::Borrowed(_) => {}
            Cow::Owned(_) => panic!("dense planning must not deep-clone the tree"),
        }
    }

    #[test]
    fn baseline_plan_counts_flat_tokens() {
        let trees: Vec<TrajectoryTree> = (0..3).map(|s| gen::uniform(10 + s, 9, 5, 0.6)).collect();
        let sp = spec(4096);
        let p = sp.plan_baseline(&trees).unwrap();
        assert_eq!(p.flat_tokens, trees.iter().map(|t| t.n_flat()).sum::<usize>());
        assert_eq!(p.tree_tokens, trees.iter().map(|t| t.n_tree()).sum::<usize>());
        assert!(!p.batches.is_empty());
        let packed_w: f32 = p.batches.iter().flat_map(|b| b.weights.iter()).sum();
        assert!(packed_w > 0.0);
    }

    #[test]
    fn oversized_tree_without_part_programs_is_an_error() {
        let t = gen::with_target_por(3, 0.6, 4, 600, 24, 128);
        let err = spec(64).plan_tree(std::slice::from_ref(&t)).unwrap_err().to_string();
        assert!(err.contains("no part_fwd"), "got: {err}");
    }
}
