//! The *plan* half of both training strategies, as engine-free data.
//!
//! Planning a global batch — Forest Packing whole trees into `step` calls,
//! partitioning oversized trees and packing their specs into relay calls
//! (tree mode), or linearizing paths and sequence-packing the chains
//! (baseline mode) — consumes nothing from the [`super::Engine`] but a
//! handful of scalars: the device capacity, the partition-program caps, the
//! hybrid chunking geometry.  [`PlanSpec`] captures exactly those scalars,
//! so the whole planning layer is a pure `Send` function of
//! `(spec, trees) -> StepPlan` that can run on a background thread while
//! the engine executes the previous step's plan
//! ([`crate::coordinator::pipeline`]).
//!
//! [`TreeTrainer`](super::TreeTrainer) and
//! [`BaselineTrainer`](super::BaselineTrainer) keep their public planning
//! entry points, now as thin delegates to their [`PlanSpec`].

use std::borrow::{Borrow, Cow};

use crate::partition::affinity;
use crate::partition::cost::{self, CostModel};
use crate::partition::forest::{self, ForestBatch, RelaySchedule};
use crate::partition::{greedy_pack, plan, Plan};
use crate::tree::linearize::path_chain;
use crate::tree::TrajectoryTree;

use super::baseline::pack_chains;
use super::batch::{Batch, BatchOptions};
use super::engine::Engine;

/// Everything one tree-mode optimizer step will execute, fully planned up
/// front: the packed `step` batches plus the partition-relay schedule.
/// Built by [`PlanSpec::plan_tree`]; the coordinator treats it as an opaque
/// stream of device batches.
pub struct GlobalPlan {
    pub forests: Vec<ForestBatch>,
    pub relay: Option<RelayPlan>,
    pub tree_tokens: usize,
    pub flat_tokens: usize,
}

pub struct RelayPlan {
    pub plans: Vec<Plan>,
    pub schedule: RelaySchedule,
}

impl GlobalPlan {
    /// Program calls this plan will execute (the packing metric).
    pub fn program_calls(&self) -> usize {
        self.forests.len() + self.relay.as_ref().map_or(0, |r| r.schedule.program_calls())
    }
}

/// A baseline-mode step, planned: every root-to-leaf path linearized and
/// sequence-packed into capacity-`C` batches (Eq. 1 + §4.2 packing).
pub struct BaselinePlan {
    pub batches: Vec<Batch>,
    pub tree_tokens: usize,
    pub flat_tokens: usize,
}

/// One rank's planned optimizer-step share, either mode.
pub enum StepPlan {
    Tree(GlobalPlan),
    Baseline(BaselinePlan),
}

impl StepPlan {
    pub fn program_calls(&self) -> usize {
        match self {
            Self::Tree(p) => p.program_calls(),
            Self::Baseline(p) => p.batches.len(),
        }
    }

    pub fn tree_tokens(&self) -> usize {
        match self {
            Self::Tree(p) => p.tree_tokens,
            Self::Baseline(p) => p.tree_tokens,
        }
    }

    pub fn flat_tokens(&self) -> usize {
        match self {
            Self::Tree(p) => p.flat_tokens,
            Self::Baseline(p) => p.flat_tokens,
        }
    }

    /// Packed device batches this rank plan executes (`step` calls for the
    /// forest path, chain batches for the baseline).
    pub fn device_batches(&self) -> usize {
        match self {
            Self::Tree(p) => p.forests.len(),
            Self::Baseline(p) => p.batches.len(),
        }
    }
}

/// One global batch planned as `n_ranks` per-rank [`StepPlan`]s — what flows
/// from the planner side of the pipeline to the executor side, where it is
/// `Arc`-shared to the persistent rank-worker pool: worker `r` reads
/// `ranks[r]` off the shared plan, no per-rank copy
/// (`crate::coordinator::dist`).
///
/// Trees are LPT-sharded whole across ranks by *packed* (post-reuse) token
/// cost ([`forest::shard_by_cost`]), honoring the §3.4 constraint that a
/// tree never splits across ranks, then each rank runs the ordinary Forest
/// Packing over its own tree set.  Rank 0 of a 1-rank plan is byte-identical
/// to the unsharded plan: sharding restores input order within each rank,
/// so the single rank sees the exact tree sequence the unsharded planner
/// would.
pub struct ShardedPlan {
    pub ranks: Vec<StepPlan>,
    /// Per-rank model-priced load the sharder balanced on: packed token
    /// counts under the default [`CostModel::Tokens`], predicted wall
    /// microseconds once a calibrated model is active.
    pub loads: Vec<usize>,
    /// Per-rank summed cost-feature vectors (`[tokens, depth, est_calls,
    /// tree_count]` — feature vectors are additive), kept so the executor
    /// can feed measured per-rank walls back as regression rows.
    pub rank_feats: Vec<[f64; cost::N_FEATS]>,
    /// The model that priced this plan (an `Arc` clone for calibrated
    /// models, so executor-side [`Self::observe_walls`] feedback reaches
    /// the planner's copy with no extra plumbing).
    pub cost: CostModel,
}

impl ShardedPlan {
    pub fn n_ranks(&self) -> usize {
        self.ranks.len()
    }

    /// Relative error of the plan's *predicted* rank imbalance against the
    /// imbalance actually measured from per-rank execute walls:
    /// `|pred − meas| / meas`, both as max-over-mean ratios.  `0.0` for a
    /// single rank (nothing to balance) or when no walls were measured.
    pub fn cost_model_err(&self, walls: &[f64]) -> f64 {
        if self.n_ranks() <= 1 || walls.len() != self.n_ranks() {
            return 0.0;
        }
        let total: f64 = walls.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        let mean = total / walls.len() as f64;
        let meas = walls.iter().cloned().fold(0.0f64, f64::max) / mean;
        if meas <= 0.0 {
            return 0.0;
        }
        (self.rank_imbalance() - meas).abs() / meas
    }

    /// Feed measured per-rank execute walls (ms, indexed by rank) back
    /// into the pricing model as regression rows.  Empty ranks are skipped
    /// — a zero-feature row teaches nothing.  No-op under
    /// [`CostModel::Tokens`].
    pub fn observe_walls(&self, walls: &[f64]) {
        if walls.len() != self.n_ranks() {
            return;
        }
        for (r, &w) in walls.iter().enumerate() {
            if self.loads[r] > 0 && w > 0.0 {
                self.cost.observe(&self.rank_feats[r], w);
            }
        }
    }

    /// Max-over-mean rank load (`>= 1.0`; `1.0` = perfectly balanced) —
    /// the shared [`forest::load_imbalance`] definition.
    pub fn rank_imbalance(&self) -> f64 {
        forest::load_imbalance(&self.loads)
    }

    /// Program calls across every rank (the packing metric).
    pub fn program_calls(&self) -> usize {
        self.ranks.iter().map(|p| p.program_calls()).sum()
    }

    pub fn tree_tokens(&self) -> usize {
        self.ranks.iter().map(|p| p.tree_tokens()).sum()
    }

    pub fn flat_tokens(&self) -> usize {
        self.ranks.iter().map(|p| p.flat_tokens()).sum()
    }

    /// Packed device batches summed across ranks.
    pub fn device_batches(&self) -> usize {
        self.ranks.iter().map(|p| p.device_batches()).sum()
    }
}

/// The engine-derived scalars planning needs — plain data, `Clone + Send`,
/// valid for the lifetime of the exported programs (capacities never change
/// after export, so a spec snapshot taken at run start stays correct).
#[derive(Debug, Clone)]
pub struct PlanSpec {
    /// Device token capacity of the `step` program.
    pub capacity: usize,
    /// `(capacity, gateway rows)` of the partition programs, when exported.
    pub part_caps: Option<(usize, usize)>,
    /// `(chunk_size, conv_kernel)` for hybrid-GDN models.
    pub hybrid: Option<(usize, usize)>,
    pub opts: BatchOptions,
    /// Partition-packing token budget override (≤ partition capacity).
    pub partition_budget: Option<usize>,
    /// Cross-tree Forest Packing (off = seed's one-call-per-tree path).
    pub forest_packing: bool,
    /// The per-tree cost seam rank sharding and FFD packing order by.
    /// [`CostModel::Tokens`] (the default everywhere) prices exactly the
    /// token base — plans are bit-identical to the pre-seam planner; a
    /// calibrated model reprices from measured per-rank walls once warm
    /// (`cost_model: "calibrated"`).
    pub cost: CostModel,
    /// Prefix-affine scheduling (docs/prefix_reuse.md): pack trees sharing
    /// hot cross-tree prefixes into the same forest batch (and, sharded,
    /// onto the same rank), ordering same-prefix work consecutively so the
    /// engine-level activation cache hits across adjacent `step` calls.
    /// Off (the default) takes the untouched seed planning path — plans
    /// are bit-for-bit what they were before this knob existed.  Ignored
    /// under hybrid chunk padding (pads break the slot/stream alignment
    /// the cache keys on).
    pub prefix_affinity: bool,
}

impl PlanSpec {
    /// Snapshot the planning-relevant scalars of an engine.
    pub fn from_engine(
        engine: &Engine,
        partition_budget: Option<usize>,
        forest_packing: bool,
    ) -> Self {
        Self {
            capacity: engine.capacity(),
            part_caps: engine.part_caps(),
            hybrid: engine.hybrid(),
            opts: engine.batch_options(),
            partition_budget,
            forest_packing,
            cost: CostModel::Tokens,
            prefix_affinity: false,
        }
    }

    /// A device-free spec (no partition programs, no hybrid chunking) —
    /// the planning surface used by host-only tests, benches and the
    /// `pipeline-smoke` command, where [`crate::trainer::refmodel::RefModel`]
    /// stands in for the exported programs.
    pub fn for_host(capacity: usize) -> Self {
        Self {
            capacity,
            part_caps: None,
            hybrid: None,
            opts: BatchOptions::default(),
            partition_budget: None,
            forest_packing: true,
            cost: CostModel::Tokens,
            prefix_affinity: false,
        }
    }

    /// Swap the cost seam (builder-style): `Tokens` keeps the exact seed
    /// plans; a calibrated model starts pricing from measured walls once
    /// it has absorbed enough observations.
    pub fn with_cost_model(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Toggle prefix-affine scheduling (builder-style); off is the
    /// seed-exact default.
    pub fn with_prefix_affinity(mut self, on: bool) -> Self {
        self.prefix_affinity = on;
        self
    }

    /// Affinity is live only without hybrid chunk padding: pads break the
    /// slot-index/prefix-stream alignment the activation cache keys on.
    fn affine(&self) -> bool {
        self.prefix_affinity && self.hybrid.is_none()
    }

    /// Chunk-pad a tree for hybrid models; borrows unchanged trees (no
    /// per-tree deep clone on the dense/MoE planning path).
    pub fn prepare<'a>(&self, tree: &'a TrajectoryTree) -> Cow<'a, TrajectoryTree> {
        match self.hybrid {
            Some((chunk, _)) => Cow::Owned(tree.pad_for_chunks(chunk, 0)),
            None => Cow::Borrowed(tree),
        }
    }

    /// Partition one oversized (prepared) tree into an executable plan.
    pub fn partition_tree(&self, tree: &TrajectoryTree) -> crate::Result<Plan> {
        let (c, _) = self.part_caps.ok_or_else(|| {
            anyhow::anyhow!("tree exceeds capacity and no part_fwd exported")
        })?;
        anyhow::ensure!(
            self.hybrid.is_none(),
            "partitioned hybrid models are not exported (DESIGN.md §2)"
        );
        let budget = self.partition_budget.unwrap_or(c).min(c);
        // leave virtual-slot headroom: a node may cut several children
        let tree = tree.split_long_segments(budget - budget / 8);
        let assignment = greedy_pack(&tree, budget)?;
        plan(&tree, &assignment)
    }

    /// Plan a whole global batch of trees as packed device batches (§3.4:
    /// each batch is tree-complete; shuffling happens between trees
    /// upstream).  Accepts both `&[TrajectoryTree]` and the coordinator's
    /// reference-counted `&[Arc<TrajectoryTree>]` batches.
    pub fn plan_tree<T: Borrow<TrajectoryTree>>(&self, trees: &[T]) -> crate::Result<GlobalPlan> {
        let mut metas = Vec::new();
        let mut meta_costs = Vec::new();
        let mut fit_trees: Vec<&TrajectoryTree> = Vec::new();
        let mut plans = Vec::new();
        let affine = self.affine();
        // price the FFD ordering only once a calibrated model is live —
        // the default (and any cold calibrated model) takes the exact
        // pack_forest path, so seed plans stay bit-identical
        let price_packing = (self.forest_packing || affine) && self.cost.active();
        for tree in trees {
            let prepared = self.prepare(tree.borrow());
            if prepared.n_slots() <= self.capacity {
                if price_packing {
                    let t = tree.borrow();
                    let feats = cost::tree_features(t, t.n_tree(), self.capacity);
                    meta_costs.push(self.cost.price(&feats, prepared.n_slots()));
                }
                if affine {
                    fit_trees.push(tree.borrow());
                }
                metas.push(crate::tree::serialize(&prepared));
            } else {
                plans.push(self.partition_tree(&prepared)?);
            }
        }
        let forests = if affine {
            // prefix-affine path: same-prefix trees co-locate in a bin (or
            // in consecutive bins when a group overflows one), and members
            // carry their prefix annotations for the activation cache
            let idx = affinity::AffinityIndex::build(&fit_trees);
            let sizes: Vec<usize> = metas.iter().map(|m| m.size()).collect();
            let costs: &[usize] = if price_packing { &meta_costs } else { &sizes };
            let mut fs = if self.forest_packing {
                idx.affine_bins(&sizes, costs, self.capacity)?
                    .into_iter()
                    .map(|ids| forest::concat_metas(&metas, &ids, self.capacity, &self.opts))
                    .collect::<crate::Result<Vec<_>>>()?
            } else {
                // one call per tree, but in group-major order so the cache
                // still hits across the consecutive single-tree batches
                idx.affine_order(costs)
                    .into_iter()
                    .map(|i| forest::concat_metas(&metas, &[i], self.capacity, &self.opts))
                    .collect::<crate::Result<Vec<_>>>()?
            };
            affinity::annotate_members(&mut fs, &idx);
            fs
        } else if self.forest_packing {
            if price_packing {
                forest::pack_forest_by_cost(&metas, &meta_costs, self.capacity, &self.opts)?
            } else {
                forest::pack_forest(&metas, self.capacity, &self.opts)?
            }
        } else {
            (0..metas.len())
                .map(|i| forest::concat_metas(&metas, &[i], self.capacity, &self.opts))
                .collect::<crate::Result<Vec<_>>>()?
        };
        let relay = if plans.is_empty() {
            None
        } else {
            let (c, a) = self.part_caps.expect("partition_tree checked");
            let schedule = forest::schedule_partition_calls(&plans, c, a, self.forest_packing)?;
            Some(RelayPlan { plans, schedule })
        };
        Ok(GlobalPlan {
            forests,
            relay,
            tree_tokens: trees.iter().map(|t| t.borrow().n_tree()).sum(),
            flat_tokens: trees.iter().map(|t| t.borrow().n_flat()).sum(),
        })
    }

    /// Linearize a global batch into packed chain batches (the baseline's
    /// "plan": sep-avg linearization + sequence packing).
    pub fn plan_baseline<T: Borrow<TrajectoryTree>>(
        &self,
        trees: &[T],
    ) -> crate::Result<BaselinePlan> {
        let mut chains = Vec::new();
        for tree in trees {
            let tree = tree.borrow();
            for path in tree.paths() {
                let mut chain = path_chain(tree, &path);
                if chain.n_tree() > self.capacity {
                    anyhow::bail!(
                        "path of {} tokens exceeds baseline capacity {} — the \
                         baseline cannot sequence-pack it (tree training would \
                         partition it); reduce path length or export a larger \
                         bucket ({} nodes)",
                        chain.n_tree(),
                        self.capacity,
                        chain.len()
                    );
                }
                if let Some((chunk, _)) = self.hybrid {
                    chain = chain.pad_for_chunks(chunk, 0);
                }
                chains.push(crate::tree::serialize(&chain));
            }
        }
        Ok(BaselinePlan {
            batches: pack_chains(&chains, self.capacity, &self.opts)?,
            tree_tokens: trees.iter().map(|t| t.borrow().n_tree()).sum(),
            flat_tokens: trees.iter().map(|t| t.borrow().n_flat()).sum(),
        })
    }

    /// Plan a global batch as `n_ranks` per-rank tree-mode plans: LPT-shard
    /// whole trees by packed (post-reuse, `n_tree`) token cost, then Forest
    /// Pack each rank independently.  `n_ranks == 1` is byte-identical to
    /// [`Self::plan_tree`] over the same trees.
    ///
    /// With [`Self::prefix_affinity`] on, whole *affine groups* are LPT-
    /// sharded instead (summed member cost), so trees sharing a prefix
    /// never split across ranks and each rank's activation cache sees its
    /// whole group.
    pub fn plan_sharded_tree<T: Borrow<TrajectoryTree>>(
        &self,
        trees: &[T],
        n_ranks: usize,
    ) -> crate::Result<ShardedPlan> {
        self.plan_sharded(trees, n_ranks, self.affine(), |t| self.tree_base_cost(t), |rt| {
            Ok(StepPlan::Tree(self.plan_tree(rt)?))
        })
    }

    /// Base sharding cost of one tree-mode tree.  A tree that fits the
    /// `step` capacity prices its packed (post-reuse) `n_tree`.  An
    /// oversized tree takes the partition-relay path *on whatever rank owns
    /// it* — whole-tree sharding already pins the relay calls there — so it
    /// prices the device slots those calls will actually occupy (estimated
    /// call count × partition capacity, each call a full padded program
    /// invocation).  This closes the ROADMAP item-5 leftover: relay work
    /// rides the same [`CostModel`] seam, and LPT charges the owning rank
    /// for the calls pinned to it instead of undercounting them as raw
    /// tree tokens.
    fn tree_base_cost(&self, t: &TrajectoryTree) -> usize {
        match self.part_caps {
            Some((pc, _)) if t.n_slots() > self.capacity => {
                let budget = self.partition_budget.unwrap_or(pc).min(pc);
                t.n_slots().div_ceil(budget).max(1) * pc
            }
            _ => t.n_tree(),
        }
    }

    /// Baseline counterpart of [`Self::plan_sharded_tree`]: the sep-avg
    /// baseline pays flattened tokens, so ranks are balanced on `n_flat` —
    /// the load a linearizing trainer would actually execute.  Affinity
    /// never applies: linearized chains share no packed prefixes.
    pub fn plan_sharded_baseline<T: Borrow<TrajectoryTree>>(
        &self,
        trees: &[T],
        n_ranks: usize,
    ) -> crate::Result<ShardedPlan> {
        self.plan_sharded(trees, n_ranks, false, |t| t.n_flat(), |rt| {
            Ok(StepPlan::Baseline(self.plan_baseline(rt)?))
        })
    }

    fn plan_sharded<T: Borrow<TrajectoryTree>>(
        &self,
        trees: &[T],
        n_ranks: usize,
        affine: bool,
        base_cost: impl Fn(&TrajectoryTree) -> usize,
        plan_rank: impl Fn(&[&TrajectoryTree]) -> crate::Result<StepPlan>,
    ) -> crate::Result<ShardedPlan> {
        let feats: Vec<[f64; cost::N_FEATS]> = trees
            .iter()
            .map(|t| {
                let t = t.borrow();
                cost::tree_features(t, base_cost(t), self.capacity)
            })
            .collect();
        // CostModel::Tokens returns the base unchanged, so the default LPT
        // input — and with it every shard and load — is exactly the
        // pre-seam token sharding, bit for bit
        let costs: Vec<usize> = trees
            .iter()
            .zip(&feats)
            .map(|(t, f)| self.cost.price(f, base_cost(t.borrow())))
            .collect();
        let shards = if affine {
            let borrowed: Vec<&TrajectoryTree> = trees.iter().map(|t| t.borrow()).collect();
            let idx = affinity::AffinityIndex::build(&borrowed);
            affinity::shard_affine(&idx, &costs, n_ranks)?
        } else {
            forest::shard_by_cost(&costs, n_ranks)?
        };
        let mut ranks = Vec::with_capacity(n_ranks);
        let mut rank_feats = Vec::with_capacity(n_ranks);
        for ids in &shards.ranks {
            let rank_trees: Vec<&TrajectoryTree> =
                ids.iter().map(|&i| trees[i].borrow()).collect();
            ranks.push(plan_rank(&rank_trees)?);
            let mut f = [0.0f64; cost::N_FEATS];
            for &i in ids {
                for (acc, v) in f.iter_mut().zip(&feats[i]) {
                    *acc += v;
                }
            }
            rank_feats.push(f);
        }
        Ok(ShardedPlan { ranks, loads: shards.loads, rank_feats, cost: self.cost.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::gen;
    use std::sync::Arc;

    fn spec(capacity: usize) -> PlanSpec {
        PlanSpec::for_host(capacity)
    }

    #[test]
    fn arc_and_owned_batches_plan_identically() {
        let trees: Vec<TrajectoryTree> = (0..4).map(|s| gen::uniform(s, 9, 5, 0.6)).collect();
        let shared: Vec<Arc<TrajectoryTree>> = trees.iter().cloned().map(Arc::new).collect();
        let sp = spec(4096);
        let a = sp.plan_tree(&trees).unwrap();
        let b = sp.plan_tree(&shared).unwrap();
        assert_eq!(a.tree_tokens, b.tree_tokens);
        assert_eq!(a.flat_tokens, b.flat_tokens);
        assert_eq!(a.forests.len(), b.forests.len());
        for (x, y) in a.forests.iter().zip(&b.forests) {
            assert_eq!(x.batch, y.batch);
        }
    }

    #[test]
    fn prepare_borrows_without_hybrid() {
        let t = gen::uniform(1, 8, 5, 0.5);
        match spec(1024).prepare(&t) {
            Cow::Borrowed(_) => {}
            Cow::Owned(_) => panic!("dense planning must not deep-clone the tree"),
        }
    }

    #[test]
    fn baseline_plan_counts_flat_tokens() {
        let trees: Vec<TrajectoryTree> = (0..3).map(|s| gen::uniform(10 + s, 9, 5, 0.6)).collect();
        let sp = spec(4096);
        let p = sp.plan_baseline(&trees).unwrap();
        assert_eq!(p.flat_tokens, trees.iter().map(|t| t.n_flat()).sum::<usize>());
        assert_eq!(p.tree_tokens, trees.iter().map(|t| t.n_tree()).sum::<usize>());
        assert!(!p.batches.is_empty());
        let packed_w: f32 = p.batches.iter().flat_map(|b| b.weights.iter()).sum();
        assert!(packed_w > 0.0);
    }

    #[test]
    fn oversized_tree_without_part_programs_is_an_error() {
        let t = gen::with_target_por(3, 0.6, 4, 600, 24, 128);
        let err = spec(64).plan_tree(std::slice::from_ref(&t)).unwrap_err().to_string();
        assert!(err.contains("no part_fwd"), "got: {err}");
    }

    #[test]
    fn one_rank_shard_is_byte_identical_to_unsharded_plan() {
        let trees: Vec<TrajectoryTree> = (0..6).map(|s| gen::uniform(40 + s, 9, 5, 0.6)).collect();
        let sp = spec(4096);
        let flat = sp.plan_tree(&trees).unwrap();
        let sharded = sp.plan_sharded_tree(&trees, 1).unwrap();
        assert_eq!(sharded.n_ranks(), 1);
        assert_eq!(sharded.loads, vec![trees.iter().map(|t| t.n_tree()).sum::<usize>()]);
        let StepPlan::Tree(rank0) = &sharded.ranks[0] else { panic!("tree-mode rank plan") };
        assert_eq!(rank0.forests.len(), flat.forests.len());
        for (a, b) in rank0.forests.iter().zip(&flat.forests) {
            assert_eq!(a.batch, b.batch, "rank 0 of a 1-rank plan must be the seed plan");
        }
        assert_eq!(sharded.tree_tokens(), flat.tree_tokens);
        assert_eq!(sharded.flat_tokens(), flat.flat_tokens);
        assert_eq!(sharded.rank_imbalance(), 1.0);
    }

    #[test]
    fn sharded_plan_conserves_tokens_and_is_reproducible() {
        let trees: Vec<TrajectoryTree> = (0..9).map(|s| gen::uniform(50 + s, 9, 5, 0.6)).collect();
        let sp = spec(4096);
        let a = sp.plan_sharded_tree(&trees, 4).unwrap();
        assert_eq!(a.n_ranks(), 4);
        assert_eq!(a.tree_tokens(), trees.iter().map(|t| t.n_tree()).sum::<usize>());
        assert_eq!(a.flat_tokens(), trees.iter().map(|t| t.n_flat()).sum::<usize>());
        assert_eq!(a.loads.iter().sum::<usize>(), a.tree_tokens());
        assert!(a.rank_imbalance() >= 1.0);
        // reproducible batch-for-batch (the determinism contract)
        let b = sp.plan_sharded_tree(&trees, 4).unwrap();
        assert_eq!(a.loads, b.loads);
        for (x, y) in a.ranks.iter().zip(&b.ranks) {
            let (StepPlan::Tree(px), StepPlan::Tree(py)) = (x, y) else { panic!("tree mode") };
            assert_eq!(px.forests.len(), py.forests.len());
            for (fx, fy) in px.forests.iter().zip(&py.forests) {
                assert_eq!(fx.batch, fy.batch);
            }
        }
    }

    #[test]
    fn sharded_baseline_balances_on_flat_tokens() {
        let trees: Vec<TrajectoryTree> = (0..7).map(|s| gen::uniform(60 + s, 9, 5, 0.6)).collect();
        let sp = spec(4096);
        let p = sp.plan_sharded_baseline(&trees, 3).unwrap();
        assert_eq!(p.loads.iter().sum::<usize>(), trees.iter().map(|t| t.n_flat()).sum::<usize>());
        for r in &p.ranks {
            assert!(matches!(r, StepPlan::Baseline(_)));
        }
        assert_eq!(p.flat_tokens(), trees.iter().map(|t| t.n_flat()).sum::<usize>());
    }

    #[test]
    fn sharded_plan_carries_additive_rank_features() {
        let trees: Vec<TrajectoryTree> = (0..6).map(|s| gen::uniform(70 + s, 9, 5, 0.6)).collect();
        let p = spec(4096).plan_sharded_tree(&trees, 3).unwrap();
        assert_eq!(p.rank_feats.len(), 3);
        let tok: f64 = p.rank_feats.iter().map(|f| f[0]).sum();
        assert_eq!(tok, trees.iter().map(|t| t.n_tree()).sum::<usize>() as f64);
        let count: f64 = p.rank_feats.iter().map(|f| f[3]).sum();
        assert_eq!(count, trees.len() as f64, "bias feature counts trees per rank");
        assert!(matches!(p.cost, CostModel::Tokens), "default seam is the token model");
    }

    #[test]
    fn cost_model_err_compares_predicted_and_measured_imbalance() {
        let trees: Vec<TrajectoryTree> = (0..8).map(|s| gen::uniform(80 + s, 9, 5, 0.6)).collect();
        let p = spec(4096).plan_sharded_tree(&trees, 4).unwrap();
        // perfectly equal measured walls: measured imbalance is 1.0, so the
        // error is exactly the predicted imbalance's excess over 1.0
        let err = p.cost_model_err(&[5.0, 5.0, 5.0, 5.0]);
        assert!((err - (p.rank_imbalance() - 1.0)).abs() < 1e-12);
        // walls matching the predicted loads: zero error
        let walls: Vec<f64> = p.loads.iter().map(|&l| l as f64).collect();
        assert!(p.cost_model_err(&walls) < 1e-12);
        // degenerate inputs are quiet zeros
        assert_eq!(p.cost_model_err(&[1.0, 2.0]), 0.0, "length mismatch");
        assert_eq!(p.cost_model_err(&[0.0, 0.0, 0.0, 0.0]), 0.0, "no measured time");
        let single = spec(4096).plan_sharded_tree(&trees, 1).unwrap();
        assert_eq!(single.cost_model_err(&[5.0]), 0.0, "single rank");
    }

    #[test]
    fn observe_walls_feeds_only_nonempty_ranks() {
        let trees: Vec<TrajectoryTree> = (0..2).map(|s| gen::uniform(s, 8, 4, 0.5)).collect();
        let sp = spec(4096).with_cost_model(CostModel::calibrated(64));
        let p = sp.plan_sharded_tree(&trees, 4).unwrap();
        p.observe_walls(&[3.0, 3.0, 3.0, 3.0]);
        assert_eq!(sp.cost.n_obs(), 2, "two empty ranks must be skipped");
        // Tokens: observing is a no-op
        let q = spec(4096).plan_sharded_tree(&trees, 4).unwrap();
        q.observe_walls(&[3.0, 3.0, 3.0, 3.0]);
        assert_eq!(q.cost.n_obs(), 0);
    }

    #[test]
    fn warm_calibrated_model_reprices_sharding_but_conserves_the_batch() {
        // teach a call-count law: wall = 1 ms per tree, blind to tokens —
        // the opposite of the token baseline
        let m = CostModel::calibrated(2);
        for i in 1..=4u64 {
            let i = i as f64;
            m.observe(&[800.0 * i, 90.0 * i, i, i], i);
        }
        assert!(m.active());
        let trees: Vec<TrajectoryTree> = (0..9).map(|s| gen::uniform(50 + s, 9, 5, 0.6)).collect();
        let sp = spec(4096).with_cost_model(m);
        let p = sp.plan_sharded_tree(&trees, 3).unwrap();
        // loads are now predicted microseconds, not tokens...
        assert!(p.loads.iter().sum::<usize>() != trees.iter().map(|t| t.n_tree()).sum::<usize>());
        // ...but the global batch is untouched: every tree plans exactly once
        assert_eq!(p.tree_tokens(), trees.iter().map(|t| t.n_tree()).sum::<usize>());
        assert_eq!(p.flat_tokens(), trees.iter().map(|t| t.n_flat()).sum::<usize>());
        assert_eq!(p.n_ranks(), 3);
        // per-tree-cost law prices every tree ~equally: 9 trees over 3
        // ranks must balance to 3 trees per rank
        let counts: Vec<f64> = p.rank_feats.iter().map(|f| f[3]).collect();
        assert_eq!(counts, vec![3.0, 3.0, 3.0], "call-count law balances tree counts");
    }

    fn prefixed(group: i32, leaf_seed: i32, prefix_len: usize) -> TrajectoryTree {
        use crate::tree::NodeSpec;
        let prefix: Vec<i32> = (0..prefix_len as i32).map(|k| group * 7 + k % 5 + 1).collect();
        TrajectoryTree::new(vec![
            NodeSpec::new(-1, prefix),
            NodeSpec::new(0, vec![leaf_seed, leaf_seed + 1, leaf_seed + 2]),
            NodeSpec::new(0, vec![leaf_seed + 3, leaf_seed + 4]),
        ])
        .unwrap()
    }

    #[test]
    fn affinity_packs_same_prefix_trees_together_and_annotates() {
        // 17 slots per tree; capacity 35 fits exactly two, so plain FFD
        // would pair input-adjacent trees — affinity must pair by prefix
        let trees = vec![
            prefixed(1, 10, 12),
            prefixed(2, 20, 12),
            prefixed(1, 30, 12),
            prefixed(2, 40, 12),
        ];
        let sp = spec(35).with_prefix_affinity(true);
        let p = sp.plan_tree(&trees).unwrap();
        assert_eq!(p.tree_tokens, trees.iter().map(|t| t.n_tree()).sum::<usize>());
        let forest_of = |src: usize| {
            p.forests
                .iter()
                .position(|f| f.members.iter().any(|m| m.source == src))
                .unwrap()
        };
        assert_eq!(forest_of(0), forest_of(2), "group 1 co-locates");
        assert_eq!(forest_of(1), forest_of(3), "group 2 co-locates");
        assert_ne!(forest_of(0), forest_of(1));
        for f in &p.forests {
            for m in &f.members {
                assert_eq!(m.prefix_len, 12, "shared root chain annotated");
                assert_ne!(m.prefix_sig, 0);
            }
        }
        // reproducible batch-for-batch
        let q = sp.plan_tree(&trees).unwrap();
        for (a, b) in p.forests.iter().zip(&q.forests) {
            assert_eq!(a.batch, b.batch);
        }
    }

    #[test]
    fn affinity_without_packing_orders_group_major() {
        let trees = vec![prefixed(1, 10, 8), prefixed(2, 20, 8), prefixed(1, 30, 8)];
        let mut sp = spec(64).with_prefix_affinity(true);
        sp.forest_packing = false;
        let p = sp.plan_tree(&trees).unwrap();
        assert_eq!(p.forests.len(), 3, "one call per tree without packing");
        let order: Vec<usize> = p.forests.iter().map(|f| f.members[0].source).collect();
        // group {0, 2} (2 trees) outweighs singleton {1}
        assert_eq!(order, vec![0, 2, 1]);
        assert_eq!(p.forests[0].members[0].prefix_len, 8);
        assert_eq!(p.forests[2].members[0].prefix_len, 0, "loner carries no annotation");
    }

    #[test]
    fn affine_sharding_keeps_groups_rank_local_and_reproducible() {
        let trees = vec![
            prefixed(1, 10, 16),
            prefixed(2, 20, 16),
            prefixed(1, 30, 16),
            prefixed(2, 40, 16),
            prefixed(3, 50, 16),
            prefixed(3, 60, 16),
        ];
        let sp = spec(128).with_prefix_affinity(true);
        let p = sp.plan_sharded_tree(&trees, 3).unwrap();
        assert_eq!(p.tree_tokens(), trees.iter().map(|t| t.n_tree()).sum::<usize>());
        // three equal-cost groups over three ranks: one whole group each
        assert_eq!(p.rank_imbalance(), 1.0);
        let per_group = trees[0].n_tree() * 2;
        for r in &p.ranks {
            assert_eq!(r.tree_tokens(), per_group, "each rank owns exactly one group");
        }
        let q = sp.plan_sharded_tree(&trees, 3).unwrap();
        assert_eq!(p.loads, q.loads);
        for (x, y) in p.ranks.iter().zip(&q.ranks) {
            let (StepPlan::Tree(px), StepPlan::Tree(py)) = (x, y) else { panic!("tree mode") };
            for (fx, fy) in px.forests.iter().zip(&py.forests) {
                assert_eq!(fx.batch, fy.batch);
            }
        }
    }

    #[test]
    fn oversized_trees_price_their_relay_calls_when_sharding() {
        let small: Vec<TrajectoryTree> = (0..3).map(|s| gen::uniform(90 + s, 8, 4, 0.5)).collect();
        let big = gen::with_target_por(3, 0.6, 4, 600, 24, 128);
        let mut sp = spec(256);
        sp.part_caps = Some((128, 1024)); // ample gateway rows: deep cuts carry per-token ancestors
        assert!(big.n_slots() > sp.capacity, "fixture must exceed step capacity");
        let mut trees = small.clone();
        trees.push(big.clone());
        let p = sp.plan_sharded_tree(&trees, 2).unwrap();
        // the oversized tree prices its relay footprint (calls x partition
        // capacity), not raw tokens, so LPT charges the owning rank for
        // the partition calls pinned there
        let expect_big = big.n_slots().div_ceil(128).max(1) * 128;
        let expect: usize = small.iter().map(|t| t.n_tree()).sum::<usize>() + expect_big;
        assert_eq!(p.loads.iter().sum::<usize>(), expect);
        assert!(*p.loads.iter().max().unwrap() >= expect_big);
        // without partition programs the base cost is untouched seed n_tree
        let host = spec(4096).plan_sharded_tree(&small, 2).unwrap();
        assert_eq!(host.loads.iter().sum::<usize>(), small.iter().map(|t| t.n_tree()).sum());
    }

    #[test]
    fn sharding_more_ranks_than_trees_yields_empty_rank_plans() {
        let trees: Vec<TrajectoryTree> = (0..2).map(|s| gen::uniform(s, 8, 4, 0.5)).collect();
        let p = spec(4096).plan_sharded_tree(&trees, 4).unwrap();
        assert_eq!(p.n_ranks(), 4);
        let empty = p
            .ranks
            .iter()
            .filter(|r| matches!(r, StepPlan::Tree(g) if g.forests.is_empty()))
            .count();
        assert_eq!(empty, 2, "two ranks must carry no trees");
        assert_eq!(p.tree_tokens(), trees.iter().map(|t| t.n_tree()).sum::<usize>());
    }
}
