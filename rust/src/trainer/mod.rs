//! Training loop components, layered as engine → strategies:
//!
//! * [`Engine`] — the unified execution core: parameters + cached literals,
//!   manifest-ordered program dispatch (`step`/`part_fwd`/`part_bwd`), the
//!   f64 [`GradBuffer`] contract and the Eq. 5-normalized AdamW update.
//! * [`TreeTrainer`] — the paper's method as a thin strategy: Forest Packing
//!   of whole trees into shared `step` calls (§3.4), Redundancy-Free Tree
//!   Partitioning with the differentiable-gateway gradient relay — packed
//!   cross-tree — when a tree exceeds capacity (§3.3, App. B).
//! * [`BaselineTrainer`] — the sep-avg baseline (Eq. 1): linearize every
//!   root-to-leaf path and train with sequence packing (Krell et al.), the
//!   "current standard practice" of §4.2.  Both strategies execute the
//!   *same* exported programs through the *same* engine and packer — a
//!   packed batch of chains is just a prefix forest — so the speedup
//!   comparison is apples-to-apples.
//! * [`PlanSpec`] — the *plan* half of both strategies as engine-free
//!   `Send` data: Forest Packing, partitioning and chain packing consume
//!   only a handful of engine scalars, so the pipeline
//!   ([`crate::coordinator::pipeline`]) can plan batch N+1 on a background
//!   thread while the engine executes batch N.
//! * [`AdamW`] — host-side optimizer over f32 parameter tensors with f64
//!   moments (master-weight style).
//! * [`refmodel::RefModel`] — first-principles f64 reference executor over
//!   batch metadata; powers the packing equivalence property tests in
//!   environments without the native PJRT backend.
//! * [`prefix_cache::PrefixCache`] — trie-keyed LRU cache of prefix forward
//!   activations (the engine tier of cross-step prefix reuse,
//!   docs/prefix_reuse.md): entries keyed by `(prefix_sig, prefix_len)`
//!   from the affinity pass, hard-invalidated on every Eq. 5 optimizer
//!   update so cache on ≡ cache off bit-for-bit.

pub mod adamw;
pub mod baseline;
pub mod batch;
pub mod engine;
pub mod grads;
pub mod metrics;
pub mod planner;
pub mod prefix_cache;
pub mod refmodel;
pub mod tree_trainer;

pub use adamw::{AdamW, AdamWConfig};
pub use baseline::BaselineTrainer;
pub use batch::{build_batch, Batch, BatchOptions};
pub use engine::Engine;
pub use grads::GradBuffer;
pub use metrics::{CsvSink, StepMetrics};
pub use prefix_cache::{reuse_ratio, CacheStats, PrefixCache};
pub use planner::{BaselinePlan, PlanSpec, ShardedPlan, StepPlan};
pub use tree_trainer::{GlobalPlan, TreeTrainer};
