//! Training loop components.
//!
//! * [`TreeTrainer`] — the paper's method: one DFS pass per tree when it
//!   fits the device capacity; Redundancy-Free Tree Partitioning with the
//!   differentiable-gateway gradient relay when it does not (§3.3, App. B).
//! * [`BaselineTrainer`] — the sep-avg baseline (Eq. 1): linearize every
//!   root-to-leaf path and train with sequence packing (Krell et al.), the
//!   "current standard practice" of §4.2.  Both trainers execute the *same*
//!   exported programs — a packed batch of chains is just a prefix forest —
//!   so the speedup comparison is apples-to-apples.
//! * [`AdamW`] — host-side optimizer over f32 parameter tensors with f64
//!   moments (master-weight style).

pub mod adamw;
pub mod baseline;
pub mod batch;
pub mod grads;
pub mod metrics;
pub mod tree_trainer;

pub use adamw::{AdamW, AdamWConfig};
pub use baseline::BaselineTrainer;
pub use batch::{build_batch, Batch, BatchOptions};
pub use grads::GradBuffer;
pub use metrics::{CsvSink, StepMetrics};
pub use tree_trainer::TreeTrainer;
